"""AOT emission smoke tests: HLO text artifacts parse-ably produced."""

import os

from compile import aot


def test_estimator_hlo_text():
    text = aot.lower_estimator()
    assert "HloModule" in text
    assert "f32[2,64]" in text  # output curve shape
    assert "f32[256,6]" in text  # phase table parameter


def test_taskwork_hlo_text():
    text = aot.lower_taskwork()
    assert "HloModule" in text
    assert "f32[64,64]" in text


def test_manifest_fields():
    man = aot.manifest()
    for key in ("pad_phases=256", "time_grid=64", "num_fields=6",
                "taskwork_dim=64", "taskwork_iters=8"):
        assert key in man


def test_main_writes_artifacts(tmp_path):
    out = tmp_path / "model.hlo.txt"
    import sys
    argv = sys.argv
    sys.argv = ["aot", "--out", str(out)]
    try:
        aot.main()
    finally:
        sys.argv = argv
    assert out.exists() and out.stat().st_size > 0
    assert (tmp_path / "taskwork.hlo.txt").exists()
    assert (tmp_path / "manifest.txt").exists()
