"""Layer-2 model checks: estimator wrapper + taskwork power iteration."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from compile.kernels.release_estimator import pack_phases


def test_estimator_model_shape_and_tuple():
    phases = pack_phases([(1.0, 2.0, 3.0, 0.0, 100.0, 0.0)])
    tgrid = jnp.linspace(0, 10, model.TIME_GRID if hasattr(model, "TIME_GRID") else 64,
                         dtype=jnp.float32)
    out = model.estimator_model(phases, tgrid)
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (2, 64)
    np.testing.assert_allclose(
        np.asarray(out[0]), np.asarray(ref.release_curve_ref(phases, tgrid)),
        atol=1e-4, rtol=1e-4)


def _stochastic(key, n):
    a = jax.random.uniform(key, (n, n), dtype=jnp.float32) + 0.01
    return a / a.sum(axis=0, keepdims=True)


def test_taskwork_l1_normalized():
    key = jax.random.PRNGKey(0)
    a = _stochastic(key, model.TASKWORK_DIM)
    x = jnp.ones((model.TASKWORK_DIM,), jnp.float32) / model.TASKWORK_DIM
    (out,) = model.taskwork_model(a, x)
    assert out.shape == (model.TASKWORK_DIM,)
    np.testing.assert_allclose(float(jnp.sum(jnp.abs(out))), 1.0, atol=1e-4)


def test_taskwork_deterministic():
    key = jax.random.PRNGKey(7)
    a = _stochastic(key, model.TASKWORK_DIM)
    x = jnp.ones((model.TASKWORK_DIM,), jnp.float32)
    (o1,) = model.taskwork_model(a, x)
    (o2,) = model.taskwork_model(a, x)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


def test_taskwork_matches_manual_unroll():
    key = jax.random.PRNGKey(3)
    a = _stochastic(key, model.TASKWORK_DIM)
    x = jnp.ones((model.TASKWORK_DIM,), jnp.float32)
    v = x
    for _ in range(model.TASKWORK_ITERS):
        v = a @ v
        v = v / (jnp.sum(jnp.abs(v)) + 1e-9)
    (out,) = model.taskwork_model(a, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(v), atol=1e-5, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_taskwork_converges_to_positive_vector(seed):
    key = jax.random.PRNGKey(seed)
    a = _stochastic(key, model.TASKWORK_DIM)
    x = jnp.ones((model.TASKWORK_DIM,), jnp.float32)
    (out,) = model.taskwork_model(a, x)
    o = np.asarray(out)
    assert np.all(np.isfinite(o))
    assert np.all(o >= -1e-6)  # positive matrix keeps the iterate nonnegative
