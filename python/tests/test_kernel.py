"""Kernel-vs-oracle correctness: the CORE signal for Layer 1.

The Pallas release-estimator kernel must agree with the pure-jnp oracle
(`kernels/ref.py`) on hand-written edge cases and on hypothesis-generated
phase tables / time grids.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.release_estimator import (
    NUM_FIELDS,
    PAD_PHASES,
    FieldIdx,
    pack_phases,
    release_curve,
)

ATOL = 1e-4
RTOL = 1e-4


def grid(t0, t1, n):
    return jnp.linspace(t0, t1, n, dtype=jnp.float32)


def assert_matches_ref(phases, tgrid, time_block=32):
    got = np.asarray(release_curve(phases, tgrid, time_block=time_block))
    want = np.asarray(ref.release_curve_ref(phases, tgrid))
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)
    return got


# ---------------------------------------------------------------- edge cases


def test_empty_table_is_zero():
    out = assert_matches_ref(pack_phases([]), grid(0, 100, 64))
    assert np.all(out == 0.0)


def test_single_phase_ramp_shape():
    # gamma=10, dps=20, c=8: ramp 0 -> 8 over [10, 30], zero outside.
    phases = pack_phases([(10.0, 20.0, 8.0, 0.0, 100.0, 0.0)])
    t = jnp.array([0.0, 10.0, 20.0, 30.0, 31.0] + [1000.0] * 59, dtype=jnp.float32)
    out = assert_matches_ref(phases, t)
    np.testing.assert_allclose(out[0, :5], [0.0, 0.0, 4.0, 8.0, 0.0], atol=ATOL)
    assert np.all(out[1] == 0.0)  # SD phase contributes nothing to LD


def test_category_split():
    rows = [
        (0.0, 10.0, 4.0, 0.0, 50.0, 0.0),  # SD
        (0.0, 10.0, 6.0, 0.0, 50.0, 1.0),  # LD
    ]
    out = assert_matches_ref(pack_phases(rows), grid(0, 10, 64))
    # at t=10 both ramps are complete
    np.testing.assert_allclose(out[0, -1], 4.0, atol=ATOL)
    np.testing.assert_allclose(out[1, -1], 6.0, atol=ATOL)


def test_zero_dps_is_step():
    # dps == 0: all tasks started together; release is a step at gamma.
    phases = pack_phases([(10.0, 0.0, 5.0, 0.0, 100.0, 0.0)])
    t = jnp.array([9.0, 10.0, 10.5] + [500.0] * 61, dtype=jnp.float32)
    out = assert_matches_ref(phases, t)
    assert out[0, 0] == 0.0
    np.testing.assert_allclose(out[0, 1], 5.0, atol=1e-2)
    assert out[0, 2] == 0.0  # outside the zero-width window


def test_job_interval_gates_release():
    # Window [10, 30] but job interval [0, 15]: nothing after beta.
    phases = pack_phases([(10.0, 20.0, 8.0, 0.0, 15.0, 0.0)])
    t = jnp.array([12.0, 15.0, 20.0] + [500.0] * 61, dtype=jnp.float32)
    out = assert_matches_ref(phases, t)
    assert out[0, 0] > 0.0
    assert out[0, 1] > 0.0
    assert out[0, 2] == 0.0


def test_phase_before_alpha_is_zero():
    phases = pack_phases([(5.0, 10.0, 8.0, 20.0, 100.0, 1.0)])
    out = assert_matches_ref(phases, grid(0, 18, 64))
    assert np.all(out == 0.0)


def test_full_pad_table():
    rows = [
        (float(i), 10.0 + i % 7, 1.0 + i % 5, 0.0, 1e4, float(i % 2))
        for i in range(PAD_PHASES)
    ]
    assert_matches_ref(pack_phases(rows), grid(0, 300, 64))


def test_release_bounded_by_total_containers():
    rows = [(float(5 * i), 10.0, 3.0, 0.0, 1e4, 0.0) for i in range(40)]
    out = assert_matches_ref(pack_phases(rows), grid(0, 250, 64))
    assert np.all(out[0] <= 40 * 3.0 + 1e-3)
    assert np.all(out >= 0.0)


@pytest.mark.parametrize("t_len,blk", [(32, 32), (64, 32), (64, 64), (128, 32), (256, 64)])
def test_time_block_shapes(t_len, blk):
    rows = [(3.0, 7.0, 2.0, 0.0, 1e4, 0.0), (5.0, 9.0, 4.0, 0.0, 1e4, 1.0)]
    phases = pack_phases(rows)
    tgrid = grid(0, 20, t_len)
    got = np.asarray(release_curve(phases, tgrid, time_block=blk))
    want = np.asarray(ref.release_curve_ref(phases, tgrid))
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


def test_bad_time_block_raises():
    with pytest.raises(ValueError):
        release_curve(pack_phases([]), grid(0, 1, 48), time_block=32)


def test_pack_overflow_raises():
    with pytest.raises(ValueError):
        pack_phases([(0.0,) * NUM_FIELDS] * (PAD_PHASES + 1))


# ------------------------------------------------------------- property sweep

finite = st.floats(min_value=0.0, max_value=5e3, allow_nan=False, width=32)


@st.composite
def phase_rows(draw):
    n = draw(st.integers(min_value=0, max_value=24))
    rows = []
    for _ in range(n):
        alpha = draw(finite)
        beta = alpha + draw(finite)
        gamma = alpha + draw(st.floats(0.0, 1e3, width=32))
        dps = draw(st.floats(0.0, 500.0, width=32))
        c = draw(st.floats(0.0, 64.0, width=32))
        cat = float(draw(st.booleans()))
        rows.append((gamma, dps, c, alpha, beta, cat))
    return rows


@settings(max_examples=40, deadline=None)
@given(rows=phase_rows(), t0=finite, span=st.floats(1.0, 5e3, width=32))
def test_kernel_matches_ref_property(rows, t0, span):
    phases = pack_phases(rows)
    tgrid = grid(t0, t0 + span, 64)
    assert_matches_ref(phases, tgrid)


@settings(max_examples=20, deadline=None)
@given(rows=phase_rows())
def test_curves_nonnegative_and_bounded(rows):
    phases = pack_phases(rows)
    out = np.asarray(release_curve(phases, grid(0, 6e3, 64)))
    assert np.all(out >= 0.0)
    total_c = sum(r[2] for r in rows)
    assert np.all(out.sum(axis=0) <= total_c + 1e-2)


# ----------------------------------------------------- extra robustness


def test_accepts_f64_inputs_by_casting():
    rows = [(10.0, 20.0, 8.0, 0.0, 100.0, 0.0)]
    phases64 = jnp.asarray(rows + [(0.0,) * NUM_FIELDS] * (PAD_PHASES - 1),
                           dtype=jnp.float64 if jax.config.jax_enable_x64 else jnp.float32)
    t = grid(0, 50, 64).astype(phases64.dtype)
    out = release_curve(phases64, t)
    assert out.dtype == jnp.float32
    want = ref.release_curve_ref(phases64, t)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=ATOL, rtol=RTOL)


def test_large_beta_sentinel_matches_rust_side():
    # Rust saturates beta=f64::MAX to 3e38 before packing; the kernel must
    # treat that as "job still running".
    phases = pack_phases([(10.0, 20.0, 8.0, 0.0, 3.0e38, 1.0)])
    out = assert_matches_ref(phases, grid(0, 40, 64))
    assert out[1].max() > 0.0


def test_overlapping_phases_superpose():
    rows = [
        (0.0, 100.0, 10.0, 0.0, 1e6, 0.0),
        (50.0, 100.0, 20.0, 0.0, 1e6, 0.0),
    ]
    out = assert_matches_ref(pack_phases(rows), jnp.asarray(
        [75.0] + [1e6] * 63, dtype=jnp.float32))
    # At t=75: phase1 ramp 7.5, phase2 ramp (25/100)*20 = 5 -> 12.5.
    np.testing.assert_allclose(out[0, 0], 12.5, atol=1e-3)
