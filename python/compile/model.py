"""Layer-2 JAX compute graphs for the DRESS reproduction.

Two graphs are AOT-lowered to HLO text (see ``aot.py``) and executed from
the Rust coordinator via PJRT:

* :func:`estimator_model` — the scheduling hot-spot: batched evaluation of
  the per-category resource-release curves F_SD(t), F_LD(t) (Eq. 1-3),
  delegating the inner loop to the Layer-1 Pallas kernel.

* :func:`taskwork_model` — the *work a simulated task performs* in the
  end-to-end example: a PageRank-style power iteration (``lax.scan``, not
  unrolled — see DESIGN.md §Perf), matching the paper's HiBench PageRank /
  NWeight workloads.  This grounds the simulator in real PJRT compute.

Python never runs on the request path; these are build-time definitions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.release_estimator import (
    PAD_PHASES,
    TIME_GRID,
    NUM_FIELDS,
    release_curve,
)

#: Matrix side for the task-work power iteration.
TASKWORK_DIM = 64
#: Power-iteration steps per task work unit.
TASKWORK_ITERS = 8


def estimator_model(phases, tgrid):
    """F(t) evaluation for the coordinator (tuple-returning for AOT).

    Args:
      phases: f32[PAD_PHASES, NUM_FIELDS] packed phase table.
      tgrid: f32[TIME_GRID] future time points (relative ms).

    Returns:
      1-tuple of f32[2, TIME_GRID]: SD and LD release curves.
    """
    return (release_curve(phases, tgrid),)


def taskwork_model(a, x):
    """One task work unit: ``TASKWORK_ITERS`` steps of normalized power
    iteration on a synthetic adjacency matrix (PageRank-like).

    Args:
      a: f32[TASKWORK_DIM, TASKWORK_DIM] column-stochastic-ish matrix.
      x: f32[TASKWORK_DIM] initial rank vector.

    Returns:
      1-tuple of f32[TASKWORK_DIM]: the converged-ish rank vector (L1 norm 1).
    """

    def step(v, _):
        v = a @ v
        v = v / (jnp.sum(jnp.abs(v)) + 1e-9)
        return v, None

    out, _ = jax.lax.scan(step, x, None, length=TASKWORK_ITERS)
    return (out,)


def estimator_example_args():
    """ShapeDtypeStructs matching the estimator artifact signature."""
    return (
        jax.ShapeDtypeStruct((PAD_PHASES, NUM_FIELDS), jnp.float32),
        jax.ShapeDtypeStruct((TIME_GRID,), jnp.float32),
    )


def taskwork_example_args():
    """ShapeDtypeStructs matching the taskwork artifact signature."""
    return (
        jax.ShapeDtypeStruct((TASKWORK_DIM, TASKWORK_DIM), jnp.float32),
        jax.ShapeDtypeStruct((TASKWORK_DIM,), jnp.float32),
    )
