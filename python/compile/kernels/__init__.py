"""Layer-1 Pallas kernels for the DRESS resource-release estimator.

`release_estimator` is the compute hot-spot: Eq. (1)-(3) of the paper,
evaluated for a padded table of phases over a grid of future time points,
reduced per job category (SD / LD).  `ref` holds the pure-jnp oracle the
kernel is validated against (pytest + hypothesis).
"""

from .release_estimator import (  # noqa: F401
    NUM_FIELDS,
    PAD_PHASES,
    TIME_GRID,
    FieldIdx,
    pack_phases,
    release_curve,
    release_curve_fn,
)
from . import ref  # noqa: F401
