"""Pure-jnp correctness oracle for the release-estimator Pallas kernel.

Implements Eq. (1)-(3) of the DRESS paper with no pallas machinery; the
kernel (and the Rust `estimator::release_model`) must agree with this to
float32 tolerance.  Kept deliberately naive and readable.
"""

from __future__ import annotations

import jax.numpy as jnp

from .release_estimator import EPS, FieldIdx


def phase_release(t, gamma, dps, c, alpha, beta):
    """Eq. (3): containers released by one phase at time t (scalar/broadcast).

    p_j(t) = ((t - gamma) / dps) * c  inside the release window, 0 outside,
    gated by the job activity interval [alpha, beta] (Eq. 2).
    """
    # dps == 0 degenerates to a step: all containers release at gamma.
    frac = jnp.where(
        dps <= EPS, 1.0, jnp.clip((t - gamma) / jnp.maximum(dps, EPS), 0.0, 1.0)
    )
    in_window = (t >= gamma) & (t <= gamma + dps)
    in_job = (t >= alpha) & (t <= beta)
    return jnp.where(in_window & in_job, frac * c, 0.0)


def release_curve_ref(phases, tgrid):
    """Oracle for :func:`release_estimator.release_curve`.

    Args:
      phases: f32[P, 6] packed phase table.
      tgrid: f32[T].

    Returns:
      f32[2, T]: per-category release curves (row 0 = SD, row 1 = LD).
    """
    phases = jnp.asarray(phases, dtype=jnp.float32)
    tgrid = jnp.asarray(tgrid, dtype=jnp.float32)
    gamma = phases[:, FieldIdx.GAMMA][:, None]
    dps = phases[:, FieldIdx.DPS][:, None]
    c = phases[:, FieldIdx.C][:, None]
    alpha = phases[:, FieldIdx.ALPHA][:, None]
    beta = phases[:, FieldIdx.BETA][:, None]
    cat = phases[:, FieldIdx.CAT][:, None]

    val = phase_release(tgrid[None, :], gamma, dps, c, alpha, beta)  # [P, T]
    sd = jnp.sum(val * (1.0 - cat), axis=0)
    ld = jnp.sum(val * cat, axis=0)
    return jnp.stack([sd, ld])
