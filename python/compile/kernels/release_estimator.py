"""Pallas kernel for the DRESS per-phase resource-release estimator.

Implements the paper's estimation function (Eq. 1-3):

    p_j(t) = ((t - gamma_j) / dps_j) * c_j     for t in [gamma_j, gamma_j + dps_j]
           = 0                                  otherwise
    f_i(t) = sum_j p_j(t)                       for t in [alpha_i, beta_i], else 0
    F_k(t) = sum_{J_i in category k} f_i(t)     k in {SD, LD}

The kernel evaluates a *padded table* of phases (one row per phase of every
running job, zero-padded to PAD_PHASES) over a grid of future time points and
reduces the result per job category.  This is the computation the Layer-3
coordinator runs every scheduling heartbeat; it is AOT-lowered (interpret
mode) into ``artifacts/estimator.hlo.txt`` and executed from Rust via PJRT.

TPU shaping (see DESIGN.md §Hardware-Adaptation): the time grid is blocked
via ``BlockSpec`` so each program instance holds one T-tile in VMEM, while
the full phase table (PAD_PHASES x NUM_FIELDS f32 = 6 KiB) stays resident
across instances.  The inner body is a vectorized masked broadcast over
[P, T_block] — VPU work, no gathers, no MXU requirement.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# -- Artifact-interface constants (mirrored in rust/src/runtime/taskwork.rs) --

#: Number of phase rows the AOT artifact is padded to.
PAD_PHASES = 256
#: Number of future time points evaluated per call.
TIME_GRID = 64
#: Fields per phase row (see :class:`FieldIdx`).
NUM_FIELDS = 6
#: Time-grid block per pallas program instance.
TIME_BLOCK = 32
#: Guard against dps == 0 (a phase whose tasks all started simultaneously
#: releases as a step function; epsilon turns the ramp into ~step).
EPS = 1e-6


class FieldIdx:
    """Column layout of a packed phase row (f32)."""

    GAMMA = 0  #: earliest task finish time in the phase (release ramp start)
    DPS = 1    #: starting-time variation Delta-ps (ramp width)
    C = 2      #: containers occupied by the phase
    ALPHA = 3  #: job start time (phase contributes only inside [alpha, beta])
    BETA = 4   #: job finish horizon
    CAT = 5    #: job category: 0.0 = SD (small demand), 1.0 = LD (large demand)


def _release_kernel(phases_ref, tgrid_ref, out_ref):
    """One program instance: full phase table x one T-tile -> [2, T-tile]."""
    ph = phases_ref[...]          # [P, NUM_FIELDS]
    t = tgrid_ref[...]            # [Tb]

    gamma = ph[:, FieldIdx.GAMMA][:, None]   # [P, 1]
    dps = ph[:, FieldIdx.DPS][:, None]
    c = ph[:, FieldIdx.C][:, None]
    alpha = ph[:, FieldIdx.ALPHA][:, None]
    beta = ph[:, FieldIdx.BETA][:, None]
    cat = ph[:, FieldIdx.CAT][:, None]

    tt = t[None, :]               # [1, Tb]
    # dps == 0 degenerates to a step: all containers release at gamma.
    frac = jnp.where(
        dps <= EPS, 1.0, jnp.clip((tt - gamma) / jnp.maximum(dps, EPS), 0.0, 1.0)
    )
    in_window = (tt >= gamma) & (tt <= gamma + dps)
    in_job = (tt >= alpha) & (tt <= beta)
    val = jnp.where(in_window & in_job, frac * c, 0.0)

    out_ref[0, :] = jnp.sum(val * (1.0 - cat), axis=0)
    out_ref[1, :] = jnp.sum(val * cat, axis=0)


@functools.partial(jax.jit, static_argnames=("time_block",))
def release_curve(phases, tgrid, *, time_block=TIME_BLOCK):
    """Evaluate F_SD(t), F_LD(t) over ``tgrid``.

    Args:
      phases: f32[P, NUM_FIELDS] packed phase table (zero rows are inert:
        c == 0 contributes nothing).
      tgrid: f32[T] future time points; T must be a multiple of time_block.
      time_block: T-tile size per pallas program instance.

    Returns:
      f32[2, T]: row 0 = SD release curve, row 1 = LD release curve.
    """
    p, nf = phases.shape
    (t_len,) = tgrid.shape
    if t_len % time_block != 0:
        raise ValueError(f"T={t_len} not a multiple of time_block={time_block}")
    grid = (t_len // time_block,)
    return pl.pallas_call(
        _release_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, nf), lambda i: (0, 0)),       # phases: VMEM-resident
            pl.BlockSpec((time_block,), lambda i: (i,)),   # tgrid: one tile
        ],
        out_specs=pl.BlockSpec((2, time_block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((2, t_len), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(phases.astype(jnp.float32), tgrid.astype(jnp.float32))


def release_curve_fn(phases, tgrid):
    """AOT entrypoint: tuple-returning wrapper (rust side unwraps tuple1)."""
    return (release_curve(phases, tgrid),)


def pack_phases(rows, pad=PAD_PHASES):
    """Pack a list of (gamma, dps, c, alpha, beta, cat) tuples into the padded
    f32[pad, NUM_FIELDS] table the kernel/artifact expects."""
    if len(rows) > pad:
        raise ValueError(f"{len(rows)} phases exceed pad size {pad}")
    table = jnp.zeros((pad, NUM_FIELDS), dtype=jnp.float32)
    if rows:
        table = table.at[: len(rows), :].set(jnp.asarray(rows, dtype=jnp.float32))
    return table
