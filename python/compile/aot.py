"""AOT bridge: lower the Layer-2 JAX graphs to HLO *text* artifacts.

HLO text (NOT ``lowered.compile().serialize()``) is the interchange format:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
``xla`` crate's bundled xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.

Usage::

    cd python && python -m compile.aot --out ../artifacts/model.hlo.txt

writes every artifact into the directory of ``--out`` (the Makefile keys the
rebuild off ``model.hlo.txt``, which is the estimator module):

* ``model.hlo.txt``      — estimator: (phases[256,6], tgrid[64]) -> (f32[2,64],)
* ``taskwork.hlo.txt``   — task work: (a[64,64], x[64]) -> (f32[64],)
* ``manifest.txt``       — shapes/constants the Rust runtime sanity-checks.
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side always unwraps a tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_estimator() -> str:
    lowered = jax.jit(model.estimator_model).lower(*model.estimator_example_args())
    return to_hlo_text(lowered)


def lower_taskwork() -> str:
    lowered = jax.jit(model.taskwork_model).lower(*model.taskwork_example_args())
    return to_hlo_text(lowered)


def manifest() -> str:
    from .kernels.release_estimator import PAD_PHASES, TIME_GRID, NUM_FIELDS

    lines = [
        "# DRESS AOT artifact manifest (read by rust/src/runtime/)",
        f"pad_phases={PAD_PHASES}",
        f"time_grid={TIME_GRID}",
        f"num_fields={NUM_FIELDS}",
        f"taskwork_dim={model.TASKWORK_DIM}",
        f"taskwork_iters={model.TASKWORK_ITERS}",
        f"jax_version={jax.__version__}",
    ]
    return "\n".join(lines) + "\n"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="path of the estimator artifact; siblings written next to it")
    args = ap.parse_args()

    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(out_dir, exist_ok=True)

    est = lower_estimator()
    with open(args.out, "w") as f:
        f.write(est)
    print(f"wrote estimator HLO: {args.out} ({len(est)} chars)")

    tw_path = os.path.join(out_dir, "taskwork.hlo.txt")
    tw = lower_taskwork()
    with open(tw_path, "w") as f:
        f.write(tw)
    print(f"wrote taskwork HLO: {tw_path} ({len(tw)} chars)")

    man_path = os.path.join(out_dir, "manifest.txt")
    with open(man_path, "w") as f:
        f.write(manifest())
    print(f"wrote manifest: {man_path}")


if __name__ == "__main__":
    main()
