//! Federation acceptance suite.
//!
//! 1. **1-cell bit-identity** — a federation of one cell, under every
//!    router policy, is bit-identical to the plain engine for all five
//!    schedulers, with and without fault plans, with and without the
//!    shadow δ tuner, on scalar and vector demands.  The `Cell` extraction
//!    and the federation driver are pure re-plumbing: same event order,
//!    same RNG draws, same metrics.
//! 2. **Migration conservation** — across randomized cell-failure
//!    scripts, every attempt is accounted for
//!    (`attempts == tasks_recorded + failures + lost_attempts`) and every
//!    job completes exactly once, even when jobs migrate between cells.
//! 3. **Cell-death recovery** — a 3-cell `by-category` federation under a
//!    cell-death plan reports nonzero migrations and a finite
//!    time-to-recover through the merged `RunResult`.
//! 4. **Fingerprints** — federated and single-cell sweep grids (and
//!    different tuner cadences) hash to different fingerprints, so their
//!    shards refuse to merge.

use dress::config::{ExperimentConfig, RouterKind, SchedKind};
use dress::expt::shard::grid_fingerprint;
use dress::expt::sweep::{SweepGrid, SweepWorkload};
use dress::federation::run_federation;
use dress::jobs::{Demand, JobSpec, PhaseKind, PhaseSpec, Platform};
use dress::sim::{run_experiment_with, EngineOptions, FaultPlan, RunResult};
use dress::workload::{congested_burst_vec, generate, WorkloadMix};

const KINDS: [SchedKind; 5] = [
    SchedKind::Fifo,
    SchedKind::Fair,
    SchedKind::Capacity,
    SchedKind::Dress,
    SchedKind::MaxWeight,
];

const ROUTERS: [RouterKind; 3] =
    [RouterKind::RoundRobin, RouterKind::LeastLoad, RouterKind::ByCategory];

/// The simulation fields of a run — everything except the federation
/// metadata (`cells`/`routing`), which legitimately differs between a
/// plain engine run (no routing table) and a 1-cell federation (routing
/// `[n]`).  Bit-identity is judged on this.
fn sim_fingerprint(r: &RunResult) -> (u64, u64, u64, String, Vec<(u64, f64)>, u64, u64, u64, u32, u32, u64) {
    (
        r.system.makespan_ms,
        r.events,
        r.tasks_recorded,
        format!("{:?}", r.jobs),
        r.delta_history.clone(),
        r.util.area_ms,
        r.util.span_ms,
        r.util.samples,
        r.failures,
        r.lost_attempts,
        r.jobs.iter().map(|j| j.waiting_ms).sum(),
    )
}

fn federated_vs_plain(cfg: &ExperimentConfig, specs: Vec<JobSpec>, opts: EngineOptions) {
    let plain = run_experiment_with(cfg, specs.clone(), opts);
    let fed = run_federation(cfg, specs, opts).merged();
    assert_eq!(fed.cells, 1);
    assert_eq!(fed.migrations, 0, "a 1-cell federation cannot migrate");
    assert_eq!(
        sim_fingerprint(&fed),
        sim_fingerprint(&plain),
        "1-cell federation diverged from plain engine ({:?}, {:?})",
        cfg.sched.kind,
        cfg.federation.router,
    );
    assert_eq!(fed.trace.tasks, plain.trace.tasks, "trace drift");
}

#[test]
fn one_cell_federation_bit_identical_all_schedulers_and_routers() {
    let specs = generate(12, WorkloadMix::Mixed, 0.3, 2_000, 42);
    for kind in KINDS {
        for router in ROUTERS {
            let mut cfg = ExperimentConfig::default();
            cfg.sched.kind = kind;
            cfg.federation.cells = 1;
            cfg.federation.router = router;
            federated_vs_plain(&cfg, specs.clone(), EngineOptions::default());
        }
    }
}

#[test]
fn one_cell_federation_bit_identical_under_node_faults() {
    // Node-level fault plans live inside the cell; driving the cell
    // through `advance_to` chunks must pop the identical event sequence.
    let specs = generate(16, WorkloadMix::Mixed, 0.3, 1_500, 11);
    for kind in KINDS {
        let mut cfg = ExperimentConfig::default();
        cfg.sched.kind = kind;
        cfg.faults = FaultPlan::empty().with_outage(30_000, 0, 45_000);
        cfg.federation.cells = 1;
        federated_vs_plain(&cfg, specs.clone(), EngineOptions::default());
    }
}

#[test]
fn one_cell_federation_bit_identical_with_tuner_and_failures() {
    let specs = generate(12, WorkloadMix::Mixed, 0.4, 1_500, 7);
    let tuned = EngineOptions { tune_delta: true, ..Default::default() };
    for kind in [SchedKind::Dress, SchedKind::Capacity] {
        let mut cfg = ExperimentConfig::default();
        cfg.sched.kind = kind;
        cfg.cluster.task_failure_prob = 0.2;
        cfg.federation.cells = 1;
        cfg.federation.router = RouterKind::ByCategory;
        federated_vs_plain(&cfg, specs.clone(), tuned);
    }
}

#[test]
fn one_cell_federation_bit_identical_on_vector_demands() {
    let specs = congested_burst_vec(80, 100, 0xFEED);
    assert!(specs.iter().any(|s| !s.demand.is_uniform()), "preset drew no vector demands");
    for kind in KINDS {
        let mut cfg = ExperimentConfig::default();
        cfg.sched.kind = kind;
        cfg.federation.cells = 1;
        cfg.federation.router = RouterKind::LeastLoad;
        federated_vs_plain(&cfg, specs.clone(), EngineOptions::default());
    }
}

/// Deterministic hand-built workload for the death/recovery tests: SD
/// jobs (demand 2 « θ·capacity = 4) and LD jobs (demand 30), explicit
/// task durations so the timeline is analyzable.
fn split_specs(n_sd: u32, n_ld: u32) -> Vec<JobSpec> {
    let mut specs = Vec::new();
    for i in 0..(n_sd + n_ld) {
        let demand = if i < n_sd { Demand::scalar(2) } else { Demand::scalar(30) };
        let s = JobSpec {
            id: i + 1,
            name: format!("j{}", i + 1),
            platform: Platform::MapReduce,
            submit_ms: i as u64 * 200,
            demand,
            phases: vec![PhaseSpec::new(PhaseKind::Map, &[8_000; 4])],
        };
        s.validate().expect("split specs must be valid");
        specs.push(s);
    }
    specs
}

#[test]
fn three_cell_by_category_death_reports_migrations_and_recovery() {
    // 3 cells: SD group {0, 1}, LD group {2}.  Cell 1 holds every other
    // SD job; it dies at 3s (all jobs already submitted by 2.2s, none can
    // have finished — each needs 2 rounds of 8s tasks) and comes back at
    // 8s, well inside the run (the LD cell works far longer).  Salvaged
    // jobs re-route within the SD group, so migrations are guaranteed.
    let mut cfg = ExperimentConfig::default();
    cfg.sched.kind = SchedKind::Dress;
    cfg.federation.cells = 3;
    cfg.federation.router = RouterKind::ByCategory;
    cfg.federation.cell_faults = FaultPlan::empty().with_outage(3_000, 1, 5_000);
    cfg.validate().expect("config must validate");
    let specs = split_specs(8, 4);
    let res = run_federation(&cfg, specs, EngineOptions::default()).merged();

    assert_eq!(res.cells, 3);
    assert_eq!(res.routing.len(), 3);
    assert_eq!(res.routing.iter().sum::<u32>(), 12, "every job routed exactly once");
    assert_eq!(res.routing[2], 4, "LD group is cell 2 alone");
    assert!(res.migrations > 0, "cell death must migrate the salvaged jobs");

    assert_eq!(res.cell_outages.len(), 1);
    let o = &res.cell_outages[0];
    assert_eq!(o.cell, 1);
    assert!(o.salvaged > 0, "dead cell held jobs; none salvaged");
    let ttr = o
        .time_to_recover_ms()
        .expect("downtime elapses inside the run: recovery must be observed");
    assert!(ttr >= o.down_ms, "cannot fully heal before the cell is back up");

    // Every job completes exactly once, with queueing history intact.
    assert_eq!(res.jobs.len(), 12);
    let mut ids: Vec<u32> = res.jobs.iter().map(|j| j.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 12, "a job completed in more than one cell");

    // The imbalance stream sampled a real ratio at some heartbeat.
    assert!(res.imbalance_max >= 1.0, "imbalance never sampled");
    assert!(res.imbalance_mean > 0.0 && res.imbalance_mean <= res.imbalance_max);
}

#[test]
fn migration_conserves_attempts_across_random_failure_scripts() {
    // Property: under randomized cell-death scripts (different cells,
    // times, downtimes, thresholds, schedulers), the merged attempt
    // ledger balances exactly and no job is lost or duplicated.
    for trial in 0u64..6 {
        let cells = 2 + (trial % 2) as u32; // 2 or 3 cells
        let victim = (trial % cells as u64) as u16;
        let at = 2_000 + (trial * 137) % 3_000;
        let down = 3_000 + (trial * 911) % 4_000;
        let mut cfg = ExperimentConfig::default();
        cfg.sched.kind = KINDS[(trial % 5) as usize];
        cfg.cluster.task_failure_prob = if trial % 2 == 0 { 0.1 } else { 0.0 };
        cfg.federation.cells = cells;
        cfg.federation.router = ROUTERS[(trial % 3) as usize];
        cfg.federation.migrate_threshold = (trial % 3) as u32;
        cfg.federation.cell_faults = FaultPlan::empty().with_outage(at, victim, down);
        cfg.validate().expect("script config must validate");

        let n_jobs = 10 + (trial as u32 % 5);
        let specs = generate(n_jobs, WorkloadMix::Mixed, 0.4, 700, 100 + trial);
        let res = run_federation(&cfg, specs, EngineOptions::default()).merged();

        assert_eq!(
            res.attempts as u64,
            res.tasks_recorded + res.failures as u64 + res.lost_attempts as u64,
            "trial {trial}: attempt ledger out of balance \
             (attempts {}, tasks {}, failures {}, lost {})",
            res.attempts,
            res.tasks_recorded,
            res.failures,
            res.lost_attempts,
        );
        assert_eq!(res.jobs.len(), n_jobs as usize, "trial {trial}: job lost or duplicated");
        let mut ids: Vec<u32> = res.jobs.iter().map(|j| j.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n_jobs as usize, "trial {trial}: duplicate completion");
        assert_eq!(res.routing.iter().sum::<u32>(), n_jobs, "trial {trial}: routing leak");

        // Determinism: the same script replays bit-identically.
        let specs = generate(n_jobs, WorkloadMix::Mixed, 0.4, 700, 100 + trial);
        let again = run_federation(&cfg, specs, EngineOptions::default()).merged();
        assert_eq!(sim_fingerprint(&res), sim_fingerprint(&again), "trial {trial}: non-deterministic");
        assert_eq!(res.migrations, again.migrations, "trial {trial}: migration drift");
    }
}

#[test]
fn federation_changes_the_grid_fingerprint() {
    let grid = |cells: u32, router: RouterKind, tune_every: u32| -> SweepGrid {
        let mut base = ExperimentConfig::default();
        base.federation.cells = cells;
        base.federation.router = router;
        let mut opts = EngineOptions::default();
        opts.tune_every = tune_every;
        SweepGrid {
            base,
            seeds: vec![1, 2],
            scheds: KINDS.to_vec(),
            workloads: vec![SweepWorkload::Generate {
                n: 4,
                mix: WorkloadMix::Mixed,
                small_frac: 0.3,
                arrival_ms: 2_000,
            }],
            opts,
        }
    };
    let single = grid_fingerprint(&grid(1, RouterKind::RoundRobin, 16));
    let fed = grid_fingerprint(&grid(3, RouterKind::RoundRobin, 16));
    assert_ne!(single, fed, "cells count invisible to the fingerprint");
    let by_cat = grid_fingerprint(&grid(3, RouterKind::ByCategory, 16));
    assert_ne!(fed, by_cat, "router policy invisible to the fingerprint");
    let cadence = grid_fingerprint(&grid(1, RouterKind::RoundRobin, 8));
    assert_ne!(single, cadence, "tuner cadence invisible to the fingerprint");
}
