//! Cross-scheduler integration: the paper's qualitative results must hold
//! across seeds, and each scheduler must behave according to its policy.

use dress::config::{ExperimentConfig, SchedKind};
use dress::expt::{mixed_setting, mr20, run_pair, spark20};
use dress::sim::engine::run_experiment;
use dress::workload::{generate, WorkloadMix};

#[test]
fn dress_reduces_small_job_completion_across_seeds() {
    let mut wins = 0;
    for seed in [7u64, 42, 1337] {
        let pair = mixed_setting(0.3, seed);
        if pair.comparison.small_completion_change_pct < 0.0 {
            wins += 1;
        }
    }
    assert!(wins >= 2, "DRESS should win on small jobs in most seeds, won {wins}/3");
}

#[test]
fn spark20_reproduces_paper_shape() {
    let pair = spark20(42);
    let c = &pair.comparison;
    assert!(c.small_completion_change_pct < 0.0, "small jobs faster: {c:?}");
    assert!(c.small_waiting_change_pct < 0.0, "small jobs wait less: {c:?}");
    assert!(c.makespan_change_pct.abs() < 15.0, "makespan stable: {c:?}");
    assert!(!c.small_ids.is_empty());
}

#[test]
fn mr20_reproduces_paper_shape() {
    let pair = mr20(42);
    let c = &pair.comparison;
    assert!(c.small_completion_change_pct < 0.0, "{c:?}");
    assert!(c.large_penalized_mean_pct >= 0.0, "{c:?}");
}

#[test]
fn small_fraction_sweep_always_helps_small_jobs() {
    for frac in [0.1, 0.2, 0.3, 0.4] {
        let pair = mixed_setting(frac, 42);
        assert!(
            pair.comparison.small_completion_change_pct < 0.0,
            "frac {frac}: {:?}",
            pair.comparison
        );
    }
}

#[test]
fn fair_spreads_waiting_more_evenly_than_fifo() {
    let cfg = ExperimentConfig::default();
    let specs = generate(12, WorkloadMix::Mixed, 0.3, 2_000, 9);
    let mut fifo_cfg = cfg.clone();
    fifo_cfg.sched.kind = SchedKind::Fifo;
    let mut fair_cfg = cfg.clone();
    fair_cfg.sched.kind = SchedKind::Fair;
    let fifo = run_experiment(&fifo_cfg, specs.clone());
    let fair = run_experiment(&fair_cfg, specs);
    let spread = |r: &dress::sim::RunResult| {
        let w: Vec<f64> = r.jobs.iter().map(|j| j.waiting_ms as f64).collect();
        dress::util::stats::stddev(&w)
    };
    assert!(
        spread(&fair) <= spread(&fifo) * 1.2,
        "fair spread {} vs fifo {}",
        spread(&fair),
        spread(&fifo)
    );
}

#[test]
fn capacity_two_queue_ablation_unblocks_other_queue() {
    // With two queues and a router splitting odd/even ids, a blocked head
    // in one queue must not delay the other queue's jobs.
    use dress::sched::CapacityScheduler;
    use dress::sim::Engine;
    let mut cfg = ExperimentConfig::default();
    cfg.cluster.nodes = 1;
    cfg.cluster.slots_per_node = 8;
    // Demands must fit the 4-container queue guarantee to gang-start.
    let mut specs = generate(6, WorkloadMix::Mixed, 0.5, 1_000, 5);
    for s in specs.iter_mut() {
        s.demand = s.demand.min_each(dress::jobs::Demand::scalar(3));
    }
    fn route(j: u32) -> usize {
        (j % 2) as usize
    }
    let sched = CapacityScheduler::with_queues(true, vec![0.5, 0.5], route);
    let res = Engine::new(cfg, specs, Box::new(sched)).run();
    assert_eq!(res.jobs.len(), 6);
}

#[test]
fn multi_category_dress_extension_completes_and_helps_small_jobs() {
    // The paper's §IV.C extension: >2 categories. Three buckets on the
    // standard congested mix; small jobs must not regress vs Capacity.
    use dress::sched::dress::MultiDress;
    use dress::sim::Engine;
    let cfg = ExperimentConfig::default();
    let specs = generate(16, WorkloadMix::Mixed, 0.3, 3_000, 13);

    let multi = MultiDress::new(vec![0.1, 0.4], cfg.cluster.total_containers());
    let multi_run = Engine::new(cfg.clone(), specs.clone(), Box::new(multi)).run();

    let mut cap_cfg = cfg;
    cap_cfg.sched.kind = SchedKind::Capacity;
    let cap_run = run_experiment(&cap_cfg, specs);

    let small_wait = |r: &dress::sim::RunResult| {
        let w: Vec<f64> = r
            .jobs
            .iter()
            .filter(|j| j.demand <= 4)
            .map(|j| j.waiting_ms as f64)
            .collect();
        dress::util::stats::mean(&w)
    };
    assert_eq!(multi_run.jobs.len(), 16);
    assert!(
        small_wait(&multi_run) <= small_wait(&cap_run) * 1.1,
        "multi-dress small wait {} vs capacity {}",
        small_wait(&multi_run),
        small_wait(&cap_run)
    );
}

#[test]
fn trace_roundtrip_reproduces_run() {
    // Export a workload as a trace file, reload it, and verify the runs
    // are identical (trace-driven methodology).
    let cfg = ExperimentConfig::default();
    let specs = generate(6, WorkloadMix::Mixed, 0.3, 2_000, 3);
    let text = dress::workload::to_trace(&specs);
    let reloaded = dress::workload::from_trace(&text).unwrap();
    let a = run_experiment(&cfg, specs);
    let b = run_experiment(&cfg, reloaded);
    assert_eq!(a.system.makespan_ms, b.system.makespan_ms);
    for (x, y) in a.jobs.iter().zip(&b.jobs) {
        assert_eq!(x.completion_ms, y.completion_ms);
    }
}

#[test]
fn run_pair_compares_identical_workloads() {
    let cfg = ExperimentConfig::default();
    let specs = generate(8, WorkloadMix::Spark, 0.4, 2_000, 11);
    let pair = run_pair(&cfg, specs, SchedKind::Capacity);
    assert_eq!(pair.dress.jobs.len(), pair.baseline.jobs.len());
    for (d, b) in pair.dress.jobs.iter().zip(&pair.baseline.jobs) {
        assert_eq!(d.id, b.id);
        assert_eq!(d.demand, b.demand);
    }
    assert_eq!(pair.dress.scheduler, "dress");
    assert_eq!(pair.baseline.scheduler, "capacity");
}

#[test]
fn gang_vs_nongang_ablation() {
    // Non-gang Capacity should start the head job earlier (partial grants).
    let specs = generate(10, WorkloadMix::MapReduce, 0.2, 1_000, 21);
    let mut gang = ExperimentConfig::default();
    gang.sched.kind = SchedKind::Capacity;
    gang.sched.gang = true;
    let mut nogang = gang.clone();
    nogang.sched.gang = false;
    let rg = run_experiment(&gang, specs.clone());
    let rn = run_experiment(&nogang, specs);
    assert!(
        rn.system.avg_waiting_ms <= rg.system.avg_waiting_ms * 1.05,
        "non-gang waiting {} should not exceed gang {}",
        rn.system.avg_waiting_ms,
        rg.system.avg_waiting_ms
    );
}
