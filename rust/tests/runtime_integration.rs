//! PJRT runtime integration: the AOT Pallas artifact must agree with the
//! pure-Rust release model, and the taskwork artifact with its CPU
//! reference.  Skipped (with a loud note) when artifacts are missing.

use dress::estimator::accel::PjrtEstimator;
use dress::estimator::{eval_curves, PhaseEstimate};
use dress::runtime::taskwork::reference_unit;
use dress::runtime::{check_manifest, find_artifacts_dir, Runtime, TaskWork, TIME_GRID};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = find_artifacts_dir();
    if dir.is_none() {
        eprintln!("NOTE: artifacts/ missing — run `make artifacts`; skipping PJRT tests");
    }
    dir
}

#[test]
fn manifest_matches_binary_constants() {
    let Some(dir) = artifacts() else { return };
    let text = std::fs::read_to_string(dir.join("manifest.txt")).unwrap();
    check_manifest(&text).expect("manifest/binary mismatch");
}

#[test]
fn pjrt_estimator_matches_rust_model() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let mut est = PjrtEstimator::load(&rt, dir.join("model.hlo.txt").to_str().unwrap())
        .expect("load estimator artifact");

    let phases: Vec<PhaseEstimate> = (0..37)
        .map(|i| PhaseEstimate {
            gamma: 500.0 + i as f64 * 119.0,
            dps: (i % 7) as f64 * 333.0, // includes dps == 0 step case
            c: 1.0 + (i % 9) as f64,
            alpha: 100.0,
            beta: if i % 5 == 0 { f64::MAX } else { 20_000.0 },
            cat: (i % 2) as u8,
        })
        .collect();
    let grid: Vec<f64> = (0..TIME_GRID).map(|i| 400.0 + i as f64 * 77.0).collect();
    let gridf: Vec<f32> = grid.iter().map(|&x| x as f32).collect();

    let (sd_pjrt, ld_pjrt) = est.curves(&phases, &gridf).expect("pjrt exec");
    let [sd_rust, ld_rust] = eval_curves(&phases, &grid);

    for i in 0..TIME_GRID {
        assert!(
            (sd_pjrt[i] as f64 - sd_rust[i]).abs() < 1e-2,
            "SD[{i}]: pjrt {} vs rust {}",
            sd_pjrt[i],
            sd_rust[i]
        );
        assert!(
            (ld_pjrt[i] as f64 - ld_rust[i]).abs() < 1e-2,
            "LD[{i}]: pjrt {} vs rust {}",
            ld_pjrt[i],
            ld_rust[i]
        );
    }
}

#[test]
fn pjrt_estimator_empty_table_is_zero() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut est = PjrtEstimator::load(&rt, dir.join("model.hlo.txt").to_str().unwrap()).unwrap();
    let grid: Vec<f32> = (0..TIME_GRID).map(|i| i as f32).collect();
    let (sd, ld) = est.curves(&[], &grid).unwrap();
    assert!(sd.iter().all(|&x| x == 0.0));
    assert!(ld.iter().all(|&x| x == 0.0));
}

#[test]
fn taskwork_matches_cpu_reference() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let tw = TaskWork::load(&rt, dir.join("taskwork.hlo.txt").to_str().unwrap()).unwrap();
    let (a, x) = TaskWork::make_inputs(42);
    let want = reference_unit(&a, &x);
    // One unit through PJRT:
    let got_sum = tw.run_units(42, 1).unwrap();
    let want_sum: f32 = want.iter().sum();
    assert!(
        (got_sum - want_sum).abs() < 1e-3,
        "pjrt {got_sum} vs reference {want_sum}"
    );
}

#[test]
fn taskwork_deterministic_across_calls() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let tw = TaskWork::load(&rt, dir.join("taskwork.hlo.txt").to_str().unwrap()).unwrap();
    let a = tw.run_units(7, 2).unwrap();
    let b = tw.run_units(7, 2).unwrap();
    assert_eq!(a, b);
}
