//! Property-based invariants over randomized workloads (DESIGN.md §7),
//! run with the in-tree `propcheck` runner.

use dress::config::{ExperimentConfig, SchedKind};
use dress::estimator::{eval_curves, PhaseEstimate};
use dress::sim::engine::run_experiment;
use dress::util::propcheck::forall;
use dress::util::rng::Rng;
use dress::workload::{generate, WorkloadMix};

/// Random small experiment: 4-10 jobs on a 2-4 node cluster.
fn gen_world(rng: &mut Rng) -> (ExperimentConfig, u64, u32) {
    let mut cfg = ExperimentConfig::default();
    cfg.cluster.nodes = 2 + (rng.next_u64() % 3) as u16;
    cfg.cluster.slots_per_node = 4 + (rng.next_u64() % 5) as u32;
    cfg.workload.seed = rng.next_u64();
    let seed = cfg.workload.seed;
    let jobs = 4 + (rng.next_u64() % 7) as u32;
    (cfg, seed, jobs)
}

#[test]
fn every_job_completes_under_every_scheduler() {
    forall(
        "no starvation",
        12,
        |rng| {
            let (cfg, seed, jobs) = gen_world(rng);
            let kind = [SchedKind::Fifo, SchedKind::Fair, SchedKind::Capacity, SchedKind::Dress]
                [(rng.next_u64() % 4) as usize];
            (cfg, seed, jobs, kind)
        },
        |(cfg, seed, jobs, kind)| {
            let mut cfg = cfg.clone();
            cfg.sched.kind = *kind;
            let specs = generate(*jobs, WorkloadMix::Mixed, 0.3, 2_000, *seed);
            let expected_tasks: usize = specs.iter().map(|s| s.total_tasks() as usize).sum();
            // run_experiment asserts all_finished internally.
            let res = run_experiment(&cfg, specs);
            if res.trace.tasks.len() != expected_tasks {
                return Err(format!(
                    "{:?}: ran {} tasks, expected {expected_tasks}",
                    kind,
                    res.trace.tasks.len()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn waiting_below_completion_and_positive_makespan() {
    forall(
        "metric sanity",
        10,
        |rng| gen_world(rng),
        |(cfg, seed, jobs)| {
            let mut cfg = cfg.clone();
            cfg.sched.kind = SchedKind::Dress;
            let res = run_experiment(&cfg, generate(*jobs, WorkloadMix::Mixed, 0.3, 2_000, *seed));
            for j in &res.jobs {
                if j.waiting_ms > j.completion_ms {
                    return Err(format!("J{}: waiting {} > completion {}", j.id, j.waiting_ms, j.completion_ms));
                }
            }
            if res.system.makespan_ms == 0 {
                return Err("zero makespan".into());
            }
            if !(0.0..=1.0).contains(&res.system.mean_utilization) {
                return Err(format!("utilization {}", res.system.mean_utilization));
            }
            Ok(())
        },
    );
}

#[test]
fn dress_delta_always_in_unit_interval() {
    forall(
        "delta in (0,1)",
        10,
        |rng| gen_world(rng),
        |(cfg, seed, jobs)| {
            let mut cfg = cfg.clone();
            cfg.sched.kind = SchedKind::Dress;
            let res = run_experiment(&cfg, generate(*jobs, WorkloadMix::Mixed, 0.4, 1_500, *seed));
            for &(t, d) in &res.delta_history {
                if !(0.0 < d && d < 1.0) {
                    return Err(format!("delta {d} at t={t}"));
                }
            }
            if res.delta_history.is_empty() {
                return Err("no delta history".into());
            }
            Ok(())
        },
    );
}

#[test]
fn fifo_starts_jobs_in_submission_order() {
    forall(
        "fifo ordering",
        10,
        |rng| gen_world(rng),
        |(cfg, seed, jobs)| {
            let mut cfg = cfg.clone();
            cfg.sched.kind = SchedKind::Fifo;
            let res = run_experiment(&cfg, generate(*jobs, WorkloadMix::Mixed, 0.3, 2_000, *seed));
            // first-start times must be non-decreasing in job id (submission order)
            let mut starts: Vec<(u32, u64)> = res
                .jobs
                .iter()
                .map(|j| (j.id, j.submit_ms + j.waiting_ms))
                .collect();
            starts.sort_by_key(|&(id, _)| id);
            for w in starts.windows(2) {
                if w[1].1 + 1 < w[0].1 {
                    // +1 ms tolerance for same-tick grants
                    return Err(format!("J{} started before J{}", w[1].0, w[0].0));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn dress_makespan_within_bound_of_capacity() {
    forall(
        "makespan stability",
        8,
        |rng| gen_world(rng),
        |(cfg, seed, jobs)| {
            let specs = generate(*jobs, WorkloadMix::Mixed, 0.3, 2_000, *seed);
            let mut d = cfg.clone();
            d.sched.kind = SchedKind::Dress;
            let mut c = cfg.clone();
            c.sched.kind = SchedKind::Capacity;
            let rd = run_experiment(&d, specs.clone());
            let rc = run_experiment(&c, specs);
            let ratio = rd.system.makespan_ms as f64 / rc.system.makespan_ms.max(1) as f64;
            // Paper: "maintains a stable overall system performance".
            if ratio > 1.5 {
                return Err(format!("DRESS makespan {ratio:.2}x Capacity"));
            }
            Ok(())
        },
    );
}

#[test]
fn release_curves_nonnegative_and_bounded() {
    forall(
        "eq3 bounds",
        50,
        |rng| {
            let n = (rng.next_u64() % 20) as usize;
            let phases: Vec<PhaseEstimate> = (0..n)
                .map(|_| PhaseEstimate {
                    gamma: rng.range_f64(0.0, 5_000.0),
                    dps: rng.range_f64(0.0, 2_000.0),
                    c: rng.range_f64(0.0, 40.0),
                    alpha: rng.range_f64(0.0, 1_000.0),
                    beta: rng.range_f64(1_000.0, 50_000.0),
                    cat: (rng.next_u64() % 2) as u8,
                })
                .collect();
            let grid: Vec<f64> = (0..64).map(|i| i as f64 * 100.0).collect();
            (phases, grid)
        },
        |(phases, grid)| {
            let [sd, ld] = eval_curves(phases, grid);
            let total_c: f64 = phases.iter().map(|p| p.c).sum();
            for (i, (&s, &l)) in sd.iter().zip(ld.iter()).enumerate() {
                if s < 0.0 || l < 0.0 {
                    return Err(format!("negative release at t[{i}]"));
                }
                if s + l > total_c + 1e-9 {
                    return Err(format!("release {} exceeds total c {total_c}", s + l));
                }
            }
            Ok(())
        },
    );
}
