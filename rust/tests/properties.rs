//! Property-based invariants over randomized workloads (DESIGN.md §7),
//! run with the in-tree `propcheck` runner.

use dress::config::{ExperimentConfig, SchedKind};
use dress::estimator::{eval_curves, PhaseEstimate};
use dress::sim::engine::run_experiment;
use dress::sim::{Event, EventQueue, QueueKind};
use dress::util::propcheck::forall;
use dress::util::rng::Rng;
use dress::workload::{generate, WorkloadMix};

const ALL_KINDS: [SchedKind; 5] = [
    SchedKind::Fifo,
    SchedKind::Fair,
    SchedKind::Capacity,
    SchedKind::Dress,
    SchedKind::MaxWeight,
];

/// Random small experiment: 4-10 jobs on a 2-4 node cluster.
fn gen_world(rng: &mut Rng) -> (ExperimentConfig, u64, u32) {
    let mut cfg = ExperimentConfig::default();
    cfg.cluster.nodes = 2 + (rng.next_u64() % 3) as u16;
    cfg.cluster.slots_per_node = 4 + (rng.next_u64() % 5) as u32;
    cfg.workload.seed = rng.next_u64();
    let seed = cfg.workload.seed;
    let jobs = 4 + (rng.next_u64() % 7) as u32;
    (cfg, seed, jobs)
}

#[test]
fn every_job_completes_under_every_scheduler() {
    forall(
        "no starvation",
        12,
        |rng| {
            let (cfg, seed, jobs) = gen_world(rng);
            let kind = ALL_KINDS[(rng.next_u64() % ALL_KINDS.len() as u64) as usize];
            (cfg, seed, jobs, kind)
        },
        |(cfg, seed, jobs, kind)| {
            let mut cfg = cfg.clone();
            cfg.sched.kind = *kind;
            let specs = generate(*jobs, WorkloadMix::Mixed, 0.3, 2_000, *seed);
            let expected_tasks: usize = specs.iter().map(|s| s.total_tasks() as usize).sum();
            // run_experiment asserts all_finished internally.
            let res = run_experiment(&cfg, specs);
            if res.trace.tasks.len() != expected_tasks {
                return Err(format!(
                    "{:?}: ran {} tasks, expected {expected_tasks}",
                    kind,
                    res.trace.tasks.len()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn waiting_below_completion_and_positive_makespan() {
    forall(
        "metric sanity",
        10,
        |rng| gen_world(rng),
        |(cfg, seed, jobs)| {
            let mut cfg = cfg.clone();
            cfg.sched.kind = SchedKind::Dress;
            let res = run_experiment(&cfg, generate(*jobs, WorkloadMix::Mixed, 0.3, 2_000, *seed));
            for j in &res.jobs {
                if j.waiting_ms > j.completion_ms {
                    return Err(format!("J{}: waiting {} > completion {}", j.id, j.waiting_ms, j.completion_ms));
                }
            }
            if res.system.makespan_ms == 0 {
                return Err("zero makespan".into());
            }
            if !(0.0..=1.0).contains(&res.system.mean_utilization) {
                return Err(format!("utilization {}", res.system.mean_utilization));
            }
            Ok(())
        },
    );
}

#[test]
fn dress_delta_always_in_unit_interval() {
    forall(
        "delta in (0,1)",
        10,
        |rng| gen_world(rng),
        |(cfg, seed, jobs)| {
            let mut cfg = cfg.clone();
            cfg.sched.kind = SchedKind::Dress;
            let res = run_experiment(&cfg, generate(*jobs, WorkloadMix::Mixed, 0.4, 1_500, *seed));
            for &(t, d) in &res.delta_history {
                if !(0.0 < d && d < 1.0) {
                    return Err(format!("delta {d} at t={t}"));
                }
            }
            if res.delta_history.is_empty() {
                return Err("no delta history".into());
            }
            Ok(())
        },
    );
}

#[test]
fn fifo_starts_jobs_in_submission_order() {
    forall(
        "fifo ordering",
        10,
        |rng| gen_world(rng),
        |(cfg, seed, jobs)| {
            let mut cfg = cfg.clone();
            cfg.sched.kind = SchedKind::Fifo;
            let res = run_experiment(&cfg, generate(*jobs, WorkloadMix::Mixed, 0.3, 2_000, *seed));
            // first-start times must be non-decreasing in job id (submission order)
            let mut starts: Vec<(u32, u64)> = res
                .jobs
                .iter()
                .map(|j| (j.id, j.submit_ms + j.waiting_ms))
                .collect();
            starts.sort_by_key(|&(id, _)| id);
            for w in starts.windows(2) {
                if w[1].1 + 1 < w[0].1 {
                    // +1 ms tolerance for same-tick grants
                    return Err(format!("J{} started before J{}", w[1].0, w[0].0));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn dress_makespan_within_bound_of_capacity() {
    forall(
        "makespan stability",
        8,
        |rng| gen_world(rng),
        |(cfg, seed, jobs)| {
            let specs = generate(*jobs, WorkloadMix::Mixed, 0.3, 2_000, *seed);
            let mut d = cfg.clone();
            d.sched.kind = SchedKind::Dress;
            let mut c = cfg.clone();
            c.sched.kind = SchedKind::Capacity;
            let rd = run_experiment(&d, specs.clone());
            let rc = run_experiment(&c, specs);
            let ratio = rd.system.makespan_ms as f64 / rc.system.makespan_ms.max(1) as f64;
            // Paper: "maintains a stable overall system performance".
            if ratio > 1.5 {
                return Err(format!("DRESS makespan {ratio:.2}x Capacity"));
            }
            Ok(())
        },
    );
}

#[test]
fn crashed_tasks_eventually_complete_with_work_conserved() {
    // Random worlds with a random single-node outage: every task still
    // completes exactly once, attempt conservation holds (attempts ==
    // completed + coin-flip failures + crash-killed), the per-outage kill
    // ledger sums to the run total, and recovery timestamps are sane.
    forall(
        "crash recovery + conservation",
        12,
        |rng| {
            let (mut cfg, seed, jobs) = gen_world(rng);
            let kind = ALL_KINDS[(rng.next_u64() % ALL_KINDS.len() as u64) as usize];
            cfg.sched.kind = kind;
            let node = (rng.next_u64() % cfg.cluster.nodes as u64) as u16;
            let at = rng.next_u64() % 60_000;
            let down = 1_000 + rng.next_u64() % 30_000;
            cfg.faults = dress::sim::FaultPlan::empty().with_outage(at, node, down);
            (cfg, seed, jobs)
        },
        |(cfg, seed, jobs)| {
            let specs = generate(*jobs, WorkloadMix::Mixed, 0.3, 2_000, *seed);
            let expected: u32 = specs.iter().map(|s| s.total_tasks()).sum();
            // run_experiment asserts all_finished internally: a crashed
            // task that never re-completes fails the starvation check.
            let res = run_experiment(cfg, specs);
            if res.trace.tasks.len() as u32 != expected {
                return Err(format!("ran {} tasks, expected {expected}", res.trace.tasks.len()));
            }
            if res.attempts != expected + res.failures + res.lost_attempts {
                return Err(format!(
                    "conservation: {} attempts != {expected} done + {} failed + {} lost",
                    res.attempts, res.failures, res.lost_attempts
                ));
            }
            let killed: u32 = res.outages.iter().map(|o| o.killed).sum();
            if killed != res.lost_attempts {
                return Err(format!("outage ledger {killed} != lost total {}", res.lost_attempts));
            }
            if res.lost_work_ms > res.wasted_work_ms {
                return Err(format!(
                    "lost {} ms > wasted {} ms",
                    res.lost_work_ms, res.wasted_work_ms
                ));
            }
            if !(0.0..=1.0).contains(&res.goodput()) {
                return Err(format!("goodput {}", res.goodput()));
            }
            for o in &res.outages {
                if let Some(t) = o.recovered_at {
                    if t < o.at_ms + o.down_ms {
                        return Err(format!(
                            "node {} healed at {t}, before its downtime ended at {}",
                            o.node,
                            o.at_ms + o.down_ms
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// One random op for the queue model: push at a time, or pop.
#[derive(Debug, Clone, Copy)]
enum QueueOp {
    Push(u64, Event),
    Pop,
}

/// Random interleaved push/pop script.  Times are drawn from a narrow
/// range so same-timestamp ties (seq ordering) happen constantly, and
/// pops interleave with pushes so re-insertion after pop — including at
/// already-popped timestamps — is exercised.
fn gen_queue_script(rng: &mut Rng) -> Vec<QueueOp> {
    let len = 50 + (rng.next_u64() % 400) as usize;
    let time_span = 1 + rng.next_u64() % 500; // narrow => heavy tie traffic
    (0..len)
        .map(|_| {
            if rng.chance(0.6) {
                let t = rng.next_u64() % time_span;
                let ev = match rng.next_u64() % 5 {
                    0 => Event::JobSubmit((rng.next_u64() % 32) as u32),
                    1 => Event::SchedTick,
                    2 => Event::ContainerAdvance((rng.next_u64() % 64) as u32),
                    3 => Event::TaskFinish((rng.next_u64() % 64) as u32),
                    _ => Event::TaskFail((rng.next_u64() % 64) as u32),
                };
                QueueOp::Push(t, ev)
            } else {
                QueueOp::Pop
            }
        })
        .collect()
}

/// Apply the script to a queue of `kind`, recording every pop result
/// (including None) and the final drain order.
fn run_queue_script(kind: QueueKind, script: &[QueueOp]) -> Vec<Option<(u64, Event)>> {
    let mut q = EventQueue::with_kind(kind);
    let mut out = Vec::new();
    for op in script {
        match *op {
            QueueOp::Push(t, ev) => q.push(t, ev),
            QueueOp::Pop => out.push(q.pop()),
        }
    }
    while !q.is_empty() {
        out.push(q.pop());
    }
    out
}

#[test]
fn calendar_queue_matches_binary_heap_reference_model() {
    // Both calendar width rules (gap-sampled default and the span/len
    // reference) must drain identically to the heap model.
    forall(
        "calendar == heap on random interleaved push/pop",
        60,
        gen_queue_script,
        |script| {
            let heap = run_queue_script(QueueKind::Heap, script);
            for kind in [QueueKind::Calendar, QueueKind::CalendarSpan] {
                let cal = run_queue_script(kind, script);
                if cal != heap {
                    let first = cal
                        .iter()
                        .zip(&heap)
                        .position(|(a, b)| a != b)
                        .unwrap_or(usize::MAX);
                    return Err(format!(
                        "{kind:?} pop sequences diverge at pop #{first}: {:?} vs heap {:?}",
                        cal.get(first),
                        heap.get(first)
                    ));
                }
            }
            Ok(())
        },
    );
}

/// One random op for the slab model: alloc a payload, or take a live
/// handle (chosen by the embedded index seed so the script is
/// deterministic once generated).
#[derive(Debug, Clone, Copy)]
enum SlabOp {
    Alloc(u64),
    Take(u64),
}

#[test]
fn slab_arena_matches_map_reference_model() {
    use dress::util::slab::Slab;
    use std::collections::HashMap;

    forall(
        "slab == handle map on random alloc/take",
        60,
        |rng| {
            let len = 50 + (rng.next_u64() % 400) as usize;
            (0..len)
                .map(|_| {
                    if rng.chance(0.55) {
                        SlabOp::Alloc(rng.next_u64())
                    } else {
                        SlabOp::Take(rng.next_u64())
                    }
                })
                .collect::<Vec<SlabOp>>()
        },
        |script| {
            let mut slab: Slab<u64> = Slab::new();
            let mut live: Vec<u32> = Vec::new(); // insertion-ordered handles
            let mut model: HashMap<u32, u64> = HashMap::new();
            let mut peak_live = 0usize;
            for op in script {
                match *op {
                    SlabOp::Alloc(v) => {
                        let h = slab.alloc(v);
                        if model.insert(h, v).is_some() {
                            return Err(format!("handle {h} double-allocated while live"));
                        }
                        live.push(h);
                        peak_live = peak_live.max(live.len());
                    }
                    SlabOp::Take(seed) => {
                        if live.is_empty() {
                            continue;
                        }
                        let h = live.swap_remove((seed % live.len() as u64) as usize);
                        let want = model.remove(&h).expect("model tracks every live handle");
                        let got = slab.take(h);
                        if got != want {
                            return Err(format!("handle {h}: payload {got} != {want}"));
                        }
                    }
                }
                if slab.live() != model.len() {
                    return Err(format!("live {} != model {}", slab.live(), model.len()));
                }
            }
            // Freed slots must be reused: the backing store never grows past
            // the peak number of simultaneously live payloads.
            if slab.capacity() > peak_live {
                return Err(format!(
                    "capacity {} exceeds peak live {peak_live} (free list not reused)",
                    slab.capacity()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn batched_estimator_tick_matches_naive_reference() {
    use dress::cluster::{ContainerState, Transition};
    use dress::estimator::{EstimatorBank, EstimatorParams};

    // Random plausible observation streams (each task Running then
    // Completed, times interleaved across jobs) fed to two banks; one
    // ticks only its dirty set, the other ticks every estimator.  All
    // detection state and both release curves must stay bit-identical.
    forall(
        "batched tick == tick_all on random streams",
        40,
        |rng| {
            let jobs = 1 + rng.index(6) as u32;
            let mut stream: Vec<Transition> = Vec::new();
            for job in 1..=jobs {
                let tasks = 1 + rng.index(5);
                for task in 0..tasks {
                    let start = rng.next_u64() % 20_000;
                    let dur = 500 + rng.next_u64() % 40_000;
                    let c = (job * 8 + task as u32) % 64;
                    stream.push(Transition {
                        time: start,
                        container: c,
                        job,
                        task,
                        to: ContainerState::Running,
                    });
                    stream.push(Transition {
                        time: start + dur,
                        container: c,
                        job,
                        task,
                        to: ContainerState::Completed,
                    });
                }
            }
            stream.sort_by_key(|t| t.time);
            let hb = 200 + rng.next_u64() % 2_000;
            (stream, jobs, hb)
        },
        |(stream, jobs, hb)| {
            let mut batched = EstimatorBank::new(EstimatorParams::default());
            let mut naive = EstimatorBank::new(EstimatorParams::default());
            let horizon = stream.last().map_or(0, |t| t.time) + 30_000;
            let mut fed = 0;
            let mut now = *hb;
            while now < horizon {
                let upto = stream[fed..].iter().take_while(|t| t.time < now).count();
                batched.ingest(&stream[fed..fed + upto]);
                naive.ingest(&stream[fed..fed + upto]);
                fed += upto;
                batched.tick(now);
                naive.tick_all(now);
                let (b1, b2) = batched.predicted_release_pair(now, now + hb);
                let (n1, n2) = naive.predicted_release_pair(now, now + hb);
                if b1.to_bits() != n1.to_bits() || b2.to_bits() != n2.to_bits() {
                    return Err(format!("release pair drift at now={now}: ({b1}, {b2}) vs ({n1}, {n2})"));
                }
                now += hb;
            }
            for id in 1..=*jobs {
                let b = format!("{:?}", batched.job(id));
                let n = format!("{:?}", naive.job(id));
                if b != n {
                    return Err(format!("estimator state drift for job {id}: {b} vs {n}"));
                }
            }
            if batched.active_jobs() != 0 {
                return Err(format!(
                    "{} jobs stuck in the dirty set after all work drained",
                    batched.active_jobs()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn queue_pop_order_is_time_then_insertion_seq() {
    // Model-free invariant: popped times are non-decreasing once pushes
    // stop, and among equal times FIFO (insertion) order holds — checked
    // by tagging each push with a unique container id.
    forall(
        "sorted (time, seq) drain",
        40,
        |rng| {
            let n = 20 + (rng.next_u64() % 200) as usize;
            let span = 1 + rng.next_u64() % 50;
            (0..n).map(|i| (rng.next_u64() % span, i as u32)).collect::<Vec<(u64, u32)>>()
        },
        |pushes| {
            let mut q = EventQueue::with_kind(QueueKind::Calendar);
            for &(t, tag) in pushes {
                q.push(t, Event::ContainerAdvance(tag));
            }
            let mut prev: Option<(u64, u32)> = None;
            let mut popped = 0usize;
            while let Some((t, ev)) = q.pop() {
                let tag = match ev {
                    Event::ContainerAdvance(c) => c,
                    other => return Err(format!("unexpected event {other:?}")),
                };
                if let Some((pt, ptag)) = prev {
                    if t < pt {
                        return Err(format!("time went backwards: {pt} -> {t}"));
                    }
                    if t == pt && tag < ptag {
                        return Err(format!("FIFO violated at t={t}: tag {ptag} before {tag}"));
                    }
                }
                prev = Some((t, tag));
                popped += 1;
            }
            if popped != pushes.len() {
                return Err(format!("lost events: {popped}/{}", pushes.len()));
            }
            Ok(())
        },
    );
}

#[test]
fn release_curves_nonnegative_and_bounded() {
    forall(
        "eq3 bounds",
        50,
        |rng| {
            let n = (rng.next_u64() % 20) as usize;
            let phases: Vec<PhaseEstimate> = (0..n)
                .map(|_| PhaseEstimate {
                    gamma: rng.range_f64(0.0, 5_000.0),
                    dps: rng.range_f64(0.0, 2_000.0),
                    c: rng.range_f64(0.0, 40.0),
                    alpha: rng.range_f64(0.0, 1_000.0),
                    beta: rng.range_f64(1_000.0, 50_000.0),
                    cat: (rng.next_u64() % 2) as u8,
                })
                .collect();
            let grid: Vec<f64> = (0..64).map(|i| i as f64 * 100.0).collect();
            (phases, grid)
        },
        |(phases, grid)| {
            let [sd, ld] = eval_curves(phases, grid);
            let total_c: f64 = phases.iter().map(|p| p.c).sum();
            for (i, (&s, &l)) in sd.iter().zip(ld.iter()).enumerate() {
                if s < 0.0 || l < 0.0 {
                    return Err(format!("negative release at t[{i}]"));
                }
                if s + l > total_c + 1e-9 {
                    return Err(format!("release {} exceeds total c {total_c}", s + l));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn probes_never_perturb_engine_state_or_outcome() {
    use dress::sim::{Engine, EngineOptions};

    // Random worlds, random schedulers, probes interleaved with live
    // stepping at a random cadence: every probe must (a) be idempotent,
    // (b) leave the engine's full state fingerprint — job-store lanes,
    // event-queue contents, estimator state, δ history — exactly
    // unchanged, and (c) the probed run must finish bit-identical to an
    // unprobed twin.
    forall(
        "probe purity",
        8,
        |rng| {
            let (cfg, seed, jobs) = gen_world(rng);
            let kind = ALL_KINDS[(rng.next_u64() % ALL_KINDS.len() as u64) as usize];
            let probe_every = 1 + rng.next_u64() % 5;
            let demands: Vec<u32> =
                (0..3).map(|_| 1 + (rng.next_u64() % 9) as u32).collect();
            (cfg, seed, jobs, kind, probe_every, demands)
        },
        |(cfg, seed, jobs, kind, probe_every, demands)| {
            let mut cfg = cfg.clone();
            cfg.sched.kind = *kind;
            let specs = generate(*jobs, WorkloadMix::Mixed, 0.3, 2_000, *seed);
            let total = cfg.cluster.total_containers();
            let fingerprint = |r: &dress::sim::RunResult| {
                (
                    r.system.makespan_ms,
                    r.trace.tasks.clone(),
                    format!("{:?}", r.jobs),
                    r.delta_history.clone(),
                )
            };
            let build = |specs: Vec<dress::jobs::JobSpec>| {
                Engine::with_options(
                    cfg.clone(),
                    specs,
                    dress::sched::build(&cfg.sched, total),
                    EngineOptions::default(),
                )
            };
            let plain = fingerprint(&build(specs.clone()).run());

            let mut eng = build(specs);
            let mut steps = 0u64;
            loop {
                let alive = eng.step();
                steps += 1;
                if steps % probe_every == 0 {
                    let before = eng.state_fingerprint();
                    for &d in demands {
                        let s1 = eng.probe(d);
                        let s2 = eng.probe(d);
                        if s1 != s2 {
                            return Err(format!(
                                "{kind:?} step {steps}: probe({d}) not idempotent: {s1:?} vs {s2:?}"
                            ));
                        }
                        let after = eng.state_fingerprint();
                        if after != before {
                            return Err(format!(
                                "{kind:?} step {steps}: probe({d}) perturbed engine state \
                                 ({before:#x} -> {after:#x})"
                            ));
                        }
                    }
                }
                if !alive {
                    break;
                }
            }
            if fingerprint(&eng.finish()) != plain {
                return Err(format!("{kind:?}: probed run diverged from unprobed twin"));
            }
            Ok(())
        },
    );
}

#[test]
fn admission_reservations_conserve_capacity() {
    use dress::live::{AdmissionConfig, AdmissionCtl, TicketId, TicketState};
    use dress::sched::SchedSnapshot;

    // Random op scripts (probe / reserve / commit / release / degrade /
    // restore) with monotone time: after every op the capacity ledger
    // reconciles — available + reserved + committed == total (available
    // pinned at 0 while an outage leaves total below the held sum) — and
    // the controller's aggregate counters equal the per-ticket sums an
    // external observer keeps.  A deterministic epilogue pins exact-tick
    // expiry: capacity returns at `expires_at`, not one tick before.
    forall(
        "reservation conservation",
        30,
        |rng| {
            let total = 2 + (rng.next_u64() % 30) as u32;
            let timeout = 1 + rng.next_u64() % 4_000;
            let len = 20 + rng.index(80);
            let script: Vec<(u8, u64, u32)> = (0..len)
                .map(|_| {
                    (
                        (rng.next_u64() % 6) as u8,
                        rng.next_u64() % 700,
                        1 + (rng.next_u64() % 12) as u32,
                    )
                })
                .collect();
            (total, timeout, script)
        },
        |(total, timeout, script)| {
            let mut ctl = AdmissionCtl::new(AdmissionConfig::enabled(*timeout), *total);
            let mut now = 0u64;
            // (id, demand) of every ticket ever granted.
            let mut tickets: Vec<(TicketId, u32)> = Vec::new();
            let check = |ctl: &AdmissionCtl, tickets: &[(TicketId, u32)], op: &str| {
                let held = ctl.reserved() as u64 + ctl.committed() as u64;
                if held <= ctl.total() as u64 {
                    if ctl.available() as u64 + held != ctl.total() as u64 {
                        return Err(format!(
                            "{op}: {} avail + {held} held != {} total",
                            ctl.available(),
                            ctl.total()
                        ));
                    }
                } else if ctl.available() != 0 {
                    return Err(format!(
                        "{op}: available {} nonzero while held {held} exceeds degraded total {}",
                        ctl.available(),
                        ctl.total()
                    ));
                }
                let sum_in = |want: TicketState| -> u64 {
                    tickets
                        .iter()
                        .filter(|(id, _)| ctl.ticket_state(*id) == Some(want))
                        .map(|&(_, d)| d as u64)
                        .sum()
                };
                if sum_in(TicketState::Reserved) != ctl.reserved() as u64 {
                    return Err(format!("{op}: reserved counter != per-ticket sum"));
                }
                if sum_in(TicketState::Committed) != ctl.committed() as u64 {
                    return Err(format!("{op}: committed counter != per-ticket sum"));
                }
                if sum_in(TicketState::Expired) != ctl.expired_capacity() {
                    return Err(format!("{op}: expired_capacity != per-ticket sum"));
                }
                Ok(())
            };
            for &(op, dt, demand) in script {
                now += dt;
                match op {
                    0 => {
                        // Probe purity: the controller's Debug state is its
                        // full state; a probe must not move a byte of it.
                        let before = format!("{ctl:?}");
                        let snap = SchedSnapshot::of_view(
                            now,
                            ctl.available(),
                            ctl.total(),
                            &[],
                            0.10,
                            0.10,
                        );
                        let _ = ctl.probe(&snap, demand);
                        if format!("{ctl:?}") != before {
                            return Err("probe mutated the admission controller".into());
                        }
                    }
                    1 => {
                        if let Some(id) = ctl.reserve(now, demand) {
                            if ctl.ticket_state(id) != Some(TicketState::Reserved) {
                                return Err(format!("fresh ticket {id} not Reserved"));
                            }
                            tickets.push((id, demand));
                        }
                    }
                    2 | 3 => {
                        if !tickets.is_empty() {
                            let (id, _) = tickets[(dt as usize) % tickets.len()];
                            if op == 2 {
                                ctl.commit(now, id);
                            } else {
                                ctl.release(now, id);
                            }
                        }
                    }
                    4 => ctl.set_total(total / 2), // outage halves capacity
                    _ => ctl.set_total(*total),    // recovery restores it
                }
                check(&ctl, &tickets, &format!("op {op} at t={now}"))?;
            }

            // Exact-tick expiry: restore capacity, grant one reservation,
            // and watch it flip at precisely `expires_at`.
            ctl.set_total(*total);
            ctl.advance(now);
            if ctl.available() == 0 {
                return Ok(()); // script left everything legitimately held
            }
            let id = ctl
                .reserve(now, 1)
                .ok_or("controller refused a 1-slot reservation with capacity available")?;
            tickets.push((id, 1));
            let expires = ctl.ticket_expires_at(id).expect("granted ticket has a deadline");
            ctl.advance(expires - 1);
            if ctl.ticket_state(id) != Some(TicketState::Reserved) {
                return Err(format!("ticket {id} expired early (t={} < {expires})", expires - 1));
            }
            let avail_before = ctl.available() as u64;
            // Script tickets reserved at this exact `now` share the
            // deadline; the tick must return *all* of them, exactly.
            let due: u64 = tickets
                .iter()
                .filter(|&&(tid, _)| {
                    ctl.ticket_state(tid) == Some(TicketState::Reserved)
                        && ctl.ticket_expires_at(tid) == Some(expires)
                })
                .map(|&(_, d)| d as u64)
                .sum();
            ctl.advance(expires);
            if ctl.ticket_state(id) != Some(TicketState::Expired) {
                return Err(format!("ticket {id} still held at its deadline {expires}"));
            }
            if ctl.available() as u64 != avail_before + due {
                return Err(format!(
                    "expiry returned {} slots, expected {due}",
                    ctl.available() as u64 - avail_before
                ));
            }
            if ctl.commit(expires, id) {
                return Err(format!("commit at the deadline revived expired ticket {id}"));
            }
            check(&ctl, &tickets, "epilogue")?;
            Ok(())
        },
    );
}

#[test]
fn paired_delta_ci_sign_consistent_with_per_seed_deltas() {
    use dress::util::stats;

    forall(
        "paired-delta CI sign-consistent with per-seed deltas",
        300,
        |rng| {
            let n = 2 + rng.index(11); // 2..=12 seeds
            let a: Vec<f64> = (0..n).map(|_| rng.range_f64(-100.0, 100.0)).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.range_f64(-100.0, 100.0)).collect();
            (a, b)
        },
        |(a, b)| {
            let deltas = stats::paired_deltas(a, b);
            let ci = stats::paired_ci95(a, b);
            let mean = stats::mean(&deltas);
            let dmin = deltas.iter().copied().fold(f64::INFINITY, f64::min);
            let dmax = deltas.iter().copied().fold(f64::NEG_INFINITY, f64::max);
            if (ci.mean - mean).abs() > 1e-9 {
                return Err(format!("CI mean {} != delta mean {mean}", ci.mean));
            }
            if !(ci.lo() <= ci.mean && ci.mean <= ci.hi()) {
                return Err(format!("mean outside its own CI [{}, {}]", ci.lo(), ci.hi()));
            }
            if ci.mean < dmin - 1e-9 || ci.mean > dmax + 1e-9 {
                return Err(format!("mean {} outside delta range [{dmin}, {dmax}]", ci.mean));
            }
            // Sign consistency: a CI strictly on one side of zero needs at
            // least one per-seed delta on that side, and an all-one-sign
            // delta set can never yield a CI concluding the opposite sign.
            if ci.lo() > 0.0 && dmax <= 0.0 {
                return Err("CI strictly positive but no positive delta".into());
            }
            if ci.hi() < 0.0 && dmin >= 0.0 {
                return Err("CI strictly negative but no negative delta".into());
            }
            if deltas.iter().all(|d| *d > 0.0) && ci.hi() <= 0.0 {
                return Err("all-positive deltas but CI upper bound <= 0".into());
            }
            if deltas.iter().all(|d| *d < 0.0) && ci.lo() >= 0.0 {
                return Err("all-negative deltas but CI lower bound >= 0".into());
            }
            Ok(())
        },
    );
}

#[test]
fn per_axis_allocation_never_exceeds_capacity() {
    use dress::cluster::{Cluster, ContainerId, ContainerState};

    // Random vector-demand allocate/release/crash/recover scripts driven
    // straight at the cluster substrate: after every operation each node
    // must respect BOTH axes (slots and memory units), a down node must
    // hold nothing, and the cluster-wide ledgers must conserve per axis —
    // including while outages leave the live totals degraded.
    forall(
        "per-axis capacity",
        16,
        |rng| {
            let nodes = 2 + (rng.next_u64() % 3) as u16;
            let slots = 3 + (rng.next_u64() % 6) as u32;
            // (op selector, per-container memory footprint) — footprints
            // deliberately range past a node's capacity so refusal paths
            // are exercised too.
            let script: Vec<(u8, u32)> = (0..120)
                .map(|_| {
                    (
                        (rng.next_u64() % 100) as u8,
                        1 + (rng.next_u64() % (slots as u64 + 2)) as u32,
                    )
                })
                .collect();
            (nodes, slots, script)
        },
        |(nodes, slots, script)| {
            let mut cl = Cluster::new(*nodes, *slots);
            let mut live: Vec<ContainerId> = Vec::new();
            let mut down: Vec<u16> = Vec::new();
            let mut now = 0u64;
            for &(op, mem) in script {
                now += 10;
                match op {
                    0..=59 => {
                        if let Some(cid) = cl.allocate(1, 0, 0, mem, now) {
                            live.push(cid);
                        } else if cl.nodes.iter().any(|n| {
                            n.up && n.free() > 0 && n.mem_free() >= mem
                        }) {
                            return Err(format!(
                                "allocate({mem}) refused although a node fits"
                            ));
                        }
                    }
                    60..=79 => {
                        if let Some(cid) = live.pop() {
                            cl.container_mut(cid).state = ContainerState::Completed;
                            cl.release(cid);
                        }
                    }
                    80..=89 => {
                        if let Some(n) = cl.nodes.iter().position(|n| n.up) {
                            let killed = cl.fail_node(n as u16, now);
                            live.retain(|c| !killed.contains(c));
                            down.push(n as u16);
                        }
                    }
                    _ => {
                        if let Some(n) = down.pop() {
                            cl.recover_node(n);
                        }
                    }
                }
                for n in &cl.nodes {
                    if n.in_use > n.capacity {
                        return Err(format!(
                            "node {}: {} slots in use > capacity {}",
                            n.id, n.in_use, n.capacity
                        ));
                    }
                    if n.mem_in_use > n.mem_capacity {
                        return Err(format!(
                            "node {}: {} mem in use > capacity {}",
                            n.id, n.mem_in_use, n.mem_capacity
                        ));
                    }
                    if !n.up && (n.in_use != 0 || n.mem_in_use != 0) {
                        return Err(format!("down node {} still holds resources", n.id));
                    }
                }
                if !cl.conservation_holds() {
                    return Err(format!("per-axis conservation violated at t={now}"));
                }
                if cl.used() > cl.total() || cl.used_mem() > cl.total_mem() {
                    return Err(format!("cluster-wide axis overflow at t={now}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn vector_workloads_complete_under_degraded_capacity() {
    // End-to-end per-axis safety: random vector-demand bursts, every
    // scheduler (the memory-aware ones and the cpu-axis baselines alike),
    // and a random single-node outage degrading both axes mid-run.  Every
    // task must still complete exactly once with attempt conservation —
    // and the engine's internal debug assertions (per-axis cluster
    // conservation on every tick) run the whole time under `cargo test`.
    forall(
        "vector demands under outage",
        10,
        |rng| {
            let (mut cfg, seed, jobs) = gen_world(rng);
            cfg.sched.kind = ALL_KINDS[(rng.next_u64() % ALL_KINDS.len() as u64) as usize];
            let node = (rng.next_u64() % cfg.cluster.nodes as u64) as u16;
            let at = rng.next_u64() % 40_000;
            let downtime = 1_000 + rng.next_u64() % 20_000;
            cfg.faults = dress::sim::FaultPlan::empty().with_outage(at, node, downtime);
            (cfg, seed, jobs)
        },
        |(cfg, seed, jobs)| {
            let specs = dress::workload::congested_burst_vec(*jobs + 4, 150, *seed);
            if !specs.iter().any(|s| !s.demand.is_uniform()) {
                return Err("burst-vec preset drew no vector demands".into());
            }
            let expected: u32 = specs.iter().map(|s| s.total_tasks()).sum();
            let res = run_experiment(cfg, specs);
            if res.trace.tasks.len() as u32 != expected {
                return Err(format!(
                    "{:?}: ran {} tasks, expected {expected}",
                    cfg.sched.kind,
                    res.trace.tasks.len()
                ));
            }
            if res.attempts != expected + res.failures + res.lost_attempts {
                return Err(format!(
                    "{:?} conservation: {} attempts != {expected} done + {} failed + {} lost",
                    cfg.sched.kind, res.attempts, res.failures, res.lost_attempts
                ));
            }
            Ok(())
        },
    );
}
