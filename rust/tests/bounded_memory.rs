//! Bounded-memory smoke: the "memory flat at any horizon" guarantee.
//!
//! A congested run under `EngineOptions::throughput()` (counting trace
//! AND counting metric sinks) must finish holding O(active jobs +
//! retained-cap) state: zero retained task traces, zero retained
//! heartbeat transitions, zero retained per-tick samples — while every
//! reported statistic, including the exact time-weighted utilization,
//! is bit-identical to the fully-retaining run.
//!
//! The 10k-job variant is `#[ignore]`d by default: debug builds
//! cross-check the incremental scheduler view against ground truth on
//! every tick (O(active) per tick), which makes 10k-job runs take
//! minutes under `cargo test`.  CI runs it in release mode via
//! `cargo test --release -q --test bounded_memory -- --include-ignored`.

use dress::config::{ExperimentConfig, SchedKind};
use dress::sim::{run_experiment_with, EngineOptions, RunResult};
use dress::workload::congested_burst;

const KINDS: [SchedKind; 5] = [
    SchedKind::Fifo,
    SchedKind::Fair,
    SchedKind::Capacity,
    SchedKind::Dress,
    SchedKind::MaxWeight,
];

fn run(kind: SchedKind, n: u32, opts: EngineOptions) -> RunResult {
    let mut cfg = ExperimentConfig::default();
    cfg.sched.kind = kind;
    run_experiment_with(&cfg, congested_burst(n, 50, 0xD8E5), opts)
}

fn assert_flat_and_exact(kind: SchedKind, full: &RunResult, lean: &RunResult) {
    // Zero retention of every per-event and per-tick stream...
    assert!(lean.trace.tasks.is_empty(), "{kind:?}: task traces retained");
    assert_eq!(lean.retained_transitions, 0, "{kind:?}: heartbeat history retained");
    assert!(lean.util_history.is_empty(), "{kind:?}: util samples retained");
    assert!(lean.delta_history.is_empty(), "{kind:?}: delta samples retained");
    // ...same observation counts...
    assert_eq!(lean.tasks_recorded, full.tasks_recorded, "{kind:?}");
    assert_eq!(lean.transitions_recorded, full.transitions_recorded, "{kind:?}");
    assert_eq!(lean.util_recorded, full.util_recorded, "{kind:?}");
    assert_eq!(lean.delta_recorded, full.delta_recorded, "{kind:?}");
    // ...identical simulation...
    assert_eq!(lean.events, full.events, "{kind:?}");
    assert_eq!(lean.system.makespan_ms, full.system.makespan_ms, "{kind:?}");
    assert_eq!(lean.jobs, full.jobs, "{kind:?}: per-job metrics diverged");
    // ...and exact summary statistics: integer math, no tolerance.
    assert_eq!(lean.util, full.util, "{kind:?}: utilization integers diverged");
    assert_eq!(
        lean.system.mean_utilization.to_bits(),
        full.system.mean_utilization.to_bits(),
        "{kind:?}: time-weighted utilization not bit-identical"
    );
    assert_eq!(lean.delta, full.delta, "{kind:?}: delta summary diverged");
    // The full run really did retain O(ticks) state — the term the
    // counting run eliminates.
    assert_eq!(full.util_history.len() as u64, full.util_recorded);
    assert!(full.util_recorded > 0, "{kind:?}: no ticks sampled");
}

#[test]
fn counting_sinks_bound_congested_run_memory() {
    // Always-on shrunk variant: same property at a size debug builds
    // clear quickly.
    for kind in KINDS {
        let full = run(kind, 200, EngineOptions::default());
        let lean = run(kind, 200, EngineOptions::throughput());
        assert_flat_and_exact(kind, &full, &lean);
    }
}

#[test]
#[ignore = "10k-job release-mode CI smoke; debug-build tick cross-checks make it minutes-slow"]
fn counting_sinks_bound_10k_job_congested_run_memory() {
    // The acceptance-criteria scale: 10k heavy-tailed jobs in a Poisson
    // burst, all five schedulers, zero retained per-tick samples, exact
    // time-weighted utilization.
    for kind in KINDS {
        let full = run(kind, 10_000, EngineOptions::default());
        let lean = run(kind, 10_000, EngineOptions::throughput());
        assert_flat_and_exact(kind, &full, &lean);
        assert!(
            lean.util_recorded > 1_000,
            "{kind:?}: expected a long horizon, got {} ticks",
            lean.util_recorded
        );
    }
}
