//! Golden determinism / refactor-equivalence suite for the indexed engine.
//!
//! Two guarantees, for Fifo, Fair, Capacity, Dress and MaxWeight on
//! congested mixed workloads:
//!
//! 1. **Determinism** — the same `(seed, scheduler)` produces the identical
//!    `(makespan_ms, total waiting_ms, trace len, failures, δ history)`
//!    across repeated runs.
//! 2. **Seed equivalence** — the indexed hot path (O(1) job lookup,
//!    finished-jobs counter, incremental view) produces bit-identical
//!    results to the seed engine's rebuild-every-tick reference path
//!    (`EngineOptions::naive_hot_path`), which reconstructs the seed's
//!    exact per-tick `ClusterView` including finished jobs.
//!
//! Together these pin `(seed, scheduler) -> metrics` without hardcoding
//! machine-independent-but-opaque golden numbers: the naive path *is* the
//! golden reference, derived from the same spec the seed implemented.

use dress::config::{ExperimentConfig, SchedKind};
use dress::expt::shard::{
    merge_shards, render_sweep_report, run_shard, shard_from_json, shard_to_json, CellSummary,
    ShardSpec, SweepMeta, SweepMode,
};
use dress::expt::sweep::{paper_grid, run_sweep, SweepGrid, SweepWorkload};
use dress::sim::{run_experiment_with, EngineOptions, QueueKind, RunResult};
use dress::util::json::Json;
use dress::workload::{congested_burst, generate, WorkloadMix};

const KINDS: [SchedKind; 5] = [
    SchedKind::Fifo,
    SchedKind::Fair,
    SchedKind::Capacity,
    SchedKind::Dress,
    SchedKind::MaxWeight,
];

/// The comparable fingerprint of one run.
#[derive(Debug, Clone, PartialEq)]
struct Golden {
    makespan_ms: u64,
    total_waiting_ms: u64,
    total_completion_ms: u64,
    trace_len: usize,
    failures: u32,
    delta_history: Vec<(u64, f64)>,
    /// Time-weighted mean utilization folds every per-tick sample into
    /// one float, so it is a sensitive whole-run fingerprint on its own.
    mean_utilization: f64,
    /// The exact integer terms behind it (area / span / samples).
    util_area_ms: u64,
    util_span_ms: u64,
    util_samples: u64,
}

impl Golden {
    fn of(r: &RunResult) -> Golden {
        Golden {
            makespan_ms: r.system.makespan_ms,
            total_waiting_ms: r.jobs.iter().map(|j| j.waiting_ms).sum(),
            total_completion_ms: r.jobs.iter().map(|j| j.completion_ms).sum(),
            trace_len: r.trace.tasks.len(),
            failures: r.failures,
            delta_history: r.delta_history.clone(),
            mean_utilization: r.system.mean_utilization,
            util_area_ms: r.util.area_ms,
            util_span_ms: r.util.span_ms,
            util_samples: r.util.samples,
        }
    }
}

fn run(kind: SchedKind, specs: Vec<dress::jobs::JobSpec>, naive: bool, failures: f64) -> Golden {
    run_opts(kind, specs, EngineOptions { naive_hot_path: naive, ..Default::default() }, failures)
}

fn run_opts(
    kind: SchedKind,
    specs: Vec<dress::jobs::JobSpec>,
    opts: EngineOptions,
    failures: f64,
) -> Golden {
    let mut cfg = ExperimentConfig::default();
    cfg.sched.kind = kind;
    cfg.cluster.task_failure_prob = failures;
    Golden::of(&run_experiment_with(&cfg, specs, opts))
}

#[test]
fn same_seed_same_metrics_all_schedulers() {
    let specs = generate(24, WorkloadMix::Mixed, 0.3, 2_000, 42);
    for kind in KINDS {
        let a = run(kind, specs.clone(), false, 0.0);
        let b = run(kind, specs.clone(), false, 0.0);
        assert_eq!(a, b, "{kind:?}: non-deterministic run");
        assert!(a.makespan_ms > 0 && a.trace_len > 0, "{kind:?}: empty run");
    }
}

#[test]
fn indexed_engine_reproduces_naive_reference_all_schedulers() {
    let specs = generate(24, WorkloadMix::Mixed, 0.3, 2_000, 42);
    for kind in KINDS {
        let fast = run(kind, specs.clone(), false, 0.0);
        let naive = run(kind, specs.clone(), true, 0.0);
        assert_eq!(fast, naive, "{kind:?}: indexed hot path diverged from seed behavior");
    }
}

#[test]
fn equivalence_holds_under_failure_injection() {
    // Failure injection exercises the TaskFail path and extra RNG draws;
    // the hot-path refactor must not perturb either.
    let specs = generate(12, WorkloadMix::Mixed, 0.4, 1_500, 7);
    for kind in [SchedKind::Capacity, SchedKind::Dress] {
        let fast = run(kind, specs.clone(), false, 0.2);
        let naive = run(kind, specs.clone(), true, 0.2);
        assert_eq!(fast, naive, "{kind:?}: divergence under failures");
        assert!(fast.failures > 0, "{kind:?}: failure injection inert");
    }
}

#[test]
fn equivalence_holds_on_congested_burst() {
    // The at-scale scenario the throughput benches use, shrunk to test
    // size: heavy-tailed demands, Poisson burst arrivals.
    let specs = congested_burst(200, 100, 0xFEED);
    for kind in KINDS {
        let fast = run(kind, specs.clone(), false, 0.0);
        let naive = run(kind, specs.clone(), true, 0.0);
        assert_eq!(fast, naive, "{kind:?}: burst divergence");
    }
}

#[test]
fn calendar_queue_reproduces_heap_reference_all_schedulers() {
    // The calendar-queue event core must preserve the exact (time, seq)
    // total order the BinaryHeap implemented — whole experiments on both
    // queue kinds yield bit-identical goldens, with and without failure
    // injection (extra RNG draws shuffle the event pattern).
    let specs = generate(24, WorkloadMix::Mixed, 0.3, 2_000, 42);
    let heap = EngineOptions { queue: QueueKind::Heap, ..Default::default() };
    for kind in KINDS {
        let cal = run_opts(kind, specs.clone(), EngineOptions::default(), 0.0);
        let href = run_opts(kind, specs.clone(), heap, 0.0);
        assert_eq!(cal, href, "{kind:?}: calendar queue diverged from heap order");
    }
    let specs = generate(12, WorkloadMix::Mixed, 0.4, 1_500, 7);
    for kind in [SchedKind::Capacity, SchedKind::Dress] {
        let cal = run_opts(kind, specs.clone(), EngineOptions::default(), 0.2);
        let href = run_opts(kind, specs.clone(), heap, 0.2);
        assert_eq!(cal, href, "{kind:?}: queue divergence under failures");
    }
}

#[test]
fn calendar_queue_handles_congested_burst() {
    let specs = congested_burst(200, 100, 0xFEED);
    let heap = EngineOptions { queue: QueueKind::Heap, ..Default::default() };
    for kind in KINDS {
        let cal = run_opts(kind, specs.clone(), EngineOptions::default(), 0.0);
        let href = run_opts(kind, specs.clone(), heap, 0.0);
        assert_eq!(cal, href, "{kind:?}: burst queue divergence");
    }
}

/// The whole-run fingerprint of one sweep cell, extended with the raw
/// trace + job metrics so "byte-identical" means the full RunResult.
fn sweep_fingerprint(r: &RunResult) -> (Golden, Vec<dress::sim::TaskTrace>, String) {
    (Golden::of(r), r.trace.tasks.clone(), format!("{:?}", r.jobs))
}

#[test]
fn sweep_parallel_output_identical_to_serial() {
    // run_sweep(jobs=1) and run_sweep(jobs=N) must produce byte-identical
    // RunResult vectors for a 3-seed x 5-scheduler grid: results land by
    // grid index, not completion order, and every cell is deterministic.
    let grid = SweepGrid {
        base: ExperimentConfig::default(),
        seeds: vec![42, 43, 44],
        scheds: KINDS.to_vec(),
        workloads: vec![SweepWorkload::Generate {
            n: 8,
            mix: WorkloadMix::Mixed,
            small_frac: 0.3,
            arrival_ms: 2_000,
        }],
        opts: EngineOptions::default(),
    };
    let serial = run_sweep(&grid, 1);
    assert_eq!(serial.len(), 15);
    for workers in [2, 5] {
        let parallel = run_sweep(&grid, workers);
        assert_eq!(parallel.len(), serial.len());
        for (i, (a, b)) in serial.iter().zip(&parallel).enumerate() {
            assert_eq!(
                sweep_fingerprint(a),
                sweep_fingerprint(b),
                "cell {i}: parallel sweep (workers={workers}) diverged from serial"
            );
        }
    }
}

/// Shard every cell of `grid` into `n` partitions (each run on 2 worker
/// threads), round-trip every shard through its JSON serialization, and
/// merge — returning what a downstream consumer actually sees.
fn shard_roundtrip_merge(grid: &SweepGrid, meta: &SweepMeta, n: usize) -> Vec<CellSummary> {
    let mut files = Vec::new();
    for i in 0..n {
        let spec = ShardSpec { index: i, count: n };
        let cells = run_shard(grid, spec, 2);
        // Serialize + reparse: the merge must survive the actual wire
        // format, not just in-memory structs.
        let text = shard_to_json(meta, spec, &cells).render();
        files.push(shard_from_json(&Json::parse(&text).unwrap()).unwrap());
    }
    let (merged_meta, merged_cells) = merge_shards(files).expect("complete shard set merges");
    assert_eq!(&merged_meta, meta, "merge must preserve grid meta");
    merged_cells
}

#[test]
fn shard_merge_bit_identical_to_unsharded_sweep_all_schedulers() {
    // shard(N) + JSON round-trip + merge must equal the unsharded
    // run_sweep cell-for-cell — per-job metrics included — for N in
    // {2, 3}, on a grid covering all five schedulers; and the rendered
    // report (tables + seed aggregates) must be byte-identical.
    let grid = SweepGrid {
        base: ExperimentConfig::default(),
        seeds: vec![42, 43, 44],
        scheds: KINDS.to_vec(),
        workloads: vec![SweepWorkload::Generate {
            n: 6,
            mix: WorkloadMix::Mixed,
            small_frac: 0.3,
            arrival_ms: 2_000,
        }],
        opts: EngineOptions::default(),
    };
    let meta = SweepMeta::of(&grid, SweepMode::Grid);
    let unsharded: Vec<CellSummary> = run_sweep(&grid, 1)
        .iter()
        .enumerate()
        .map(|(i, r)| CellSummary::of(&grid, i, r))
        .collect();
    assert_eq!(unsharded.len(), 15);
    let reference_report = render_sweep_report(&meta, &unsharded);
    for n in [2, 3] {
        let merged = shard_roundtrip_merge(&grid, &meta, n);
        assert_eq!(merged, unsharded, "shard({n})+merge diverged from unsharded sweep");
        assert_eq!(
            render_sweep_report(&meta, &merged),
            reference_report,
            "shard({n})+merge report not byte-identical"
        );
    }
}

#[test]
fn shard_merge_paper_claim_report_bit_identical() {
    // The paper-claim grid (Figs 7/9 + Table II pairs): sharded execution
    // must reproduce the claim-verification report — mean ± 95% CI rows,
    // CI whisker chart, verdict line — byte-for-byte.
    let grid = paper_grid(&[42, 43]);
    let meta = SweepMeta::of(&grid, SweepMode::Paper);
    let unsharded: Vec<CellSummary> = run_sweep(&grid, 1)
        .iter()
        .enumerate()
        .map(|(i, r)| CellSummary::of(&grid, i, r))
        .collect();
    let reference_report = render_sweep_report(&meta, &unsharded);
    assert!(reference_report.contains("paper claims (pass/fail on the 95% CI bound)"));
    assert!(reference_report.contains("n=2"), "CI rows carry the seed count");
    for n in [2, 3] {
        let merged = shard_roundtrip_merge(&grid, &meta, n);
        assert_eq!(merged, unsharded, "paper shard({n})+merge diverged");
        assert_eq!(
            render_sweep_report(&meta, &merged),
            reference_report,
            "paper shard({n})+merge claim report not byte-identical"
        );
    }
}

#[test]
fn metric_sink_retention_never_changes_reported_statistics() {
    // Full vs Counting metric retention on the same congested burst, all
    // five schedulers: the simulation, the exact utilization integers and
    // the final float must be identical — the Counting run just retains
    // zero per-tick samples.  This is the engine-level face of the
    // "reports are byte-identical under Full, exact under Counting"
    // acceptance bar (the report-bytes half lives in the shard tests,
    // whose summaries carry these same integers over the wire).
    let specs = congested_burst(150, 100, 0xFACE);
    for kind in KINDS {
        let mut cfg = ExperimentConfig::default();
        cfg.sched.kind = kind;
        let full = run_experiment_with(&cfg, specs.clone(), EngineOptions::default());
        let lean = run_experiment_with(
            &cfg,
            specs.clone(),
            EngineOptions {
                metrics: dress::sim::MetricSinkKind::Counting,
                ..Default::default()
            },
        );
        // Retained δ samples are what the counting sink intentionally
        // drops; every other fingerprint component must match exactly.
        let reference = Golden::of(&full);
        let lean_golden = Golden::of(&lean);
        assert!(lean_golden.delta_history.is_empty(), "{kind:?}: δ samples retained");
        assert_eq!(
            Golden { delta_history: Vec::new(), ..reference },
            lean_golden,
            "{kind:?}: statistics drifted"
        );
        assert_eq!(full.delta, lean.delta, "{kind:?}: δ summary must survive counting");
        assert_eq!(
            full.system.mean_utilization.to_bits(),
            lean.system.mean_utilization.to_bits(),
            "{kind:?}: utilization not exact under counting retention"
        );
        assert!(full.util_history.len() as u64 == full.util_recorded && full.util_recorded > 0);
        assert!(lean.util_history.is_empty(), "{kind:?}: counting sink retained samples");
        // Trace retention untouched by the metric flag: Full either way.
        assert_eq!(full.trace.tasks, lean.trace.tasks, "{kind:?}");
    }
}

#[test]
fn full_metric_retention_report_bytes_stable_across_sharding() {
    // Under Full-equivalent metric retention the whole report pipeline —
    // cell summaries, utilization column, seed aggregates — must render
    // byte-identically whether cells come from one process or a shard
    // round-trip (the wire carries the utilization integers, never the
    // derived float).
    let grid = SweepGrid {
        base: ExperimentConfig::default(),
        seeds: vec![42, 43],
        scheds: KINDS.to_vec(),
        workloads: vec![SweepWorkload::Generate {
            n: 6,
            mix: WorkloadMix::Mixed,
            small_frac: 0.3,
            arrival_ms: 2_000,
        }],
        opts: EngineOptions::default(),
    };
    let meta = SweepMeta::of(&grid, SweepMode::Grid);
    let unsharded: Vec<CellSummary> = run_sweep(&grid, 1)
        .iter()
        .enumerate()
        .map(|(i, r)| CellSummary::of(&grid, i, r))
        .collect();
    let reference = render_sweep_report(&meta, &unsharded);
    assert!(reference.contains("Util (%)") && reference.contains("util_pct"));
    let merged = shard_roundtrip_merge(&grid, &meta, 2);
    assert_eq!(
        render_sweep_report(&meta, &merged),
        reference,
        "utilization column not byte-stable across shard+merge"
    );
    // And a Counting-metric grid reports the same utilization numbers:
    // the summary integers are sink-independent.
    let mut counting_grid = grid.clone();
    counting_grid.opts.metrics = dress::sim::MetricSinkKind::Counting;
    let counting: Vec<CellSummary> = run_sweep(&counting_grid, 1)
        .iter()
        .enumerate()
        .map(|(i, r)| CellSummary::of(&counting_grid, i, r))
        .collect();
    for (a, b) in unsharded.iter().zip(&counting) {
        assert_eq!(a, b, "cell summaries must be identical under counting metrics");
    }
}

#[test]
fn inert_fault_plans_are_bit_identical_for_all_schedulers() {
    // Three plans that can never fire an outage inside the run — the
    // explicit empty plan, a stochastic process whose horizon materializes
    // zero crashes, and a fixed crash far beyond the makespan — must leave
    // every scheduler's golden bit-identical to the default config.  The
    // stochastic case is the RNG-isolation proof: its materialization does
    // draw from the dedicated fault stream, and nothing moves.
    use dress::sim::FaultPlan;
    let specs = generate(24, WorkloadMix::Mixed, 0.3, 2_000, 42);
    let run_with = |kind: SchedKind, faults: FaultPlan| {
        let mut cfg = ExperimentConfig::default();
        cfg.sched.kind = kind;
        cfg.faults = faults;
        Golden::of(&run_experiment_with(&cfg, specs.clone(), EngineOptions::default()))
    };
    for kind in KINDS {
        let baseline = run_with(kind, FaultPlan::default());
        assert_eq!(
            baseline,
            run_with(kind, FaultPlan::empty()),
            "{kind:?}: empty fault plan perturbed the run"
        );
        // mtbf >> until: the first up-time draw always overshoots the
        // horizon, so the plan materializes to nothing.
        assert_eq!(
            baseline,
            run_with(kind, FaultPlan::empty().stochastic(1_000_000, 1_000, 1)),
            "{kind:?}: zero-outage stochastic plan leaked into the event RNG"
        );
        // A crash scheduled long after the last job finishes never pops
        // off the queue, so the golden — and the outage ledger — is clean.
        assert_eq!(
            baseline,
            run_with(kind, FaultPlan::at(100_000_000, 0)),
            "{kind:?}: post-makespan outage perturbed the run"
        );
    }
    // Sensitivity: a crash *inside* the run must move the fingerprint,
    // else the three equalities above prove nothing.
    let calm = run_with(SchedKind::Dress, FaultPlan::default());
    let stormy = run_with(SchedKind::Dress, FaultPlan::empty().with_outage(40_000, 0, 60_000));
    assert_ne!(calm, stormy, "golden fingerprint blind to a live outage");
}

#[test]
fn soa_layout_reproduces_aos_reference_all_schedulers() {
    // The SoA job store (perf iter 6) against the original JobRt records,
    // with and without coin-flip failure injection: layout is invisible to
    // the simulation, so every golden must be bit-identical.
    use dress::sim::JobLayout;
    let aos = EngineOptions { jobs: JobLayout::Aos, ..Default::default() };
    let specs = generate(24, WorkloadMix::Mixed, 0.3, 2_000, 42);
    for kind in KINDS {
        let soa = run_opts(kind, specs.clone(), EngineOptions::default(), 0.0);
        let aref = run_opts(kind, specs.clone(), aos, 0.0);
        assert_eq!(soa, aref, "{kind:?}: SoA layout diverged from AoS reference");
    }
    let specs = generate(12, WorkloadMix::Mixed, 0.4, 1_500, 7);
    for kind in KINDS {
        let soa = run_opts(kind, specs.clone(), EngineOptions::default(), 0.2);
        let aref = run_opts(kind, specs.clone(), aos, 0.2);
        assert_eq!(soa, aref, "{kind:?}: SoA divergence under failures");
        assert!(soa.failures > 0, "{kind:?}: failure injection inert");
    }
}

#[test]
fn soa_layout_reproduces_aos_reference_under_fault_plan() {
    // Node outages exercise requeue/lost-work accounting, which the store
    // now owns; the layouts must agree on a crashing cluster too.
    use dress::sim::{FaultPlan, JobLayout};
    let specs = generate(16, WorkloadMix::Mixed, 0.3, 1_500, 11);
    for kind in KINDS {
        let mut cfg = ExperimentConfig::default();
        cfg.sched.kind = kind;
        cfg.faults = FaultPlan::empty().with_outage(30_000, 0, 45_000);
        let soa = run_experiment_with(&cfg, specs.clone(), EngineOptions::default());
        let aref = run_experiment_with(
            &cfg,
            specs.clone(),
            EngineOptions { jobs: JobLayout::Aos, ..Default::default() },
        );
        assert!(soa.lost_attempts > 0, "{kind:?}: outage killed nothing");
        assert_eq!(
            Golden::of(&soa),
            Golden::of(&aref),
            "{kind:?}: SoA divergence under node outage"
        );
        assert_eq!(soa.lost_work_ms, aref.lost_work_ms, "{kind:?}: lost-work drift");
        assert_eq!(soa.trace.tasks, aref.trace.tasks, "{kind:?}: trace drift");
    }
}

#[test]
fn gap_sampled_widths_reproduce_span_rule_all_schedulers() {
    // Bucket width only affects *where* entries sit, never pop order: the
    // gap-sampled default and the span/len reference rule must produce
    // bit-identical experiments, with and without failure injection.
    let span = EngineOptions { queue: QueueKind::CalendarSpan, ..Default::default() };
    let specs = generate(24, WorkloadMix::Mixed, 0.3, 2_000, 42);
    for kind in KINDS {
        let gap = run_opts(kind, specs.clone(), EngineOptions::default(), 0.0);
        let sref = run_opts(kind, specs.clone(), span, 0.0);
        assert_eq!(gap, sref, "{kind:?}: gap-sampled widths diverged from span rule");
    }
    let specs = generate(12, WorkloadMix::Mixed, 0.4, 1_500, 7);
    for kind in [SchedKind::Capacity, SchedKind::Dress] {
        let gap = run_opts(kind, specs.clone(), EngineOptions::default(), 0.2);
        let sref = run_opts(kind, specs.clone(), span, 0.2);
        assert_eq!(gap, sref, "{kind:?}: width-rule divergence under failures");
    }
}

/// Run Dress with an explicitly constructed scheduler so the
/// `naive_estimator_tick` reference flag can be set.
fn run_dress_estimator(specs: Vec<dress::jobs::JobSpec>, naive_tick: bool, failures: f64) -> Golden {
    use dress::sched::DressScheduler;
    use dress::sim::Engine;
    let mut cfg = ExperimentConfig::default();
    cfg.sched.kind = SchedKind::Dress;
    cfg.cluster.task_failure_prob = failures;
    let mut sched = DressScheduler::new(&cfg.sched, cfg.cluster.total_containers());
    sched.naive_estimator_tick = naive_tick;
    Golden::of(&Engine::with_options(cfg, specs, Box::new(sched), EngineOptions::default()).run())
}

#[test]
fn batched_estimator_tick_reproduces_naive_reference() {
    // The dirty-set estimator tick skips exactly the jobs whose tick is a
    // no-op, so δ history — the most estimator-sensitive golden component —
    // must stay bit-identical to ticking every estimator each heartbeat.
    let specs = generate(24, WorkloadMix::Mixed, 0.3, 2_000, 42);
    let batched = run_dress_estimator(specs.clone(), false, 0.0);
    let naive = run_dress_estimator(specs, true, 0.0);
    assert_eq!(batched, naive, "batched estimator tick diverged");
    assert!(!batched.delta_history.is_empty(), "δ history empty; test proves nothing");

    let specs = generate(12, WorkloadMix::Mixed, 0.4, 1_500, 7);
    let batched = run_dress_estimator(specs.clone(), false, 0.2);
    let naive = run_dress_estimator(specs, true, 0.2);
    assert_eq!(batched, naive, "batched estimator divergence under failures");
}

#[test]
fn modern_hot_path_reproduces_full_reference_stack() {
    // Everything at once: the shipped configuration (SoA store, gap-sampled
    // calendar queue, indexed views, batched estimator) against a run with
    // *every* reference path enabled — AoS records, span-rule widths, naive
    // per-tick view rebuilds — on a congested burst and under a fault plan.
    use dress::sim::{FaultPlan, JobLayout};
    let reference = EngineOptions {
        naive_hot_path: true,
        queue: QueueKind::CalendarSpan,
        jobs: JobLayout::Aos,
        ..Default::default()
    };
    let specs = congested_burst(200, 100, 0xFEED);
    for kind in KINDS {
        let modern = run_opts(kind, specs.clone(), EngineOptions::default(), 0.0);
        let refr = run_opts(kind, specs.clone(), reference, 0.0);
        assert_eq!(modern, refr, "{kind:?}: modern stack diverged from full reference");
    }
    let specs = generate(16, WorkloadMix::Mixed, 0.3, 1_500, 11);
    for kind in [SchedKind::Capacity, SchedKind::Dress] {
        let mut cfg = ExperimentConfig::default();
        cfg.sched.kind = kind;
        cfg.cluster.task_failure_prob = 0.1;
        cfg.faults = FaultPlan::empty().with_outage(30_000, 0, 45_000);
        let modern = run_experiment_with(&cfg, specs.clone(), EngineOptions::default());
        let refr = run_experiment_with(&cfg, specs.clone(), reference);
        assert_eq!(
            Golden::of(&modern),
            Golden::of(&refr),
            "{kind:?}: modern stack divergence under faults"
        );
        assert_eq!(modern.trace.tasks, refr.trace.tasks, "{kind:?}: trace drift");
    }
}

#[test]
fn disabled_shadow_tuner_is_bit_identical_for_all_schedulers() {
    // PR 8's face of the PR 5 empty-fault-plan guarantee: with
    // `tune_delta` off (the default) and no admission config in play, the
    // shadow layer must cost zero RNG draws and zero events — the whole
    // run is bit-identical to the pre-shadow engine.  Three claims:
    //
    // 1. Explicit `tune_delta: false` == default options (pins the
    //    default itself).
    // 2. `tune_delta: true` on the *baseline* schedulers == off: the
    //    trait-level no-op means the flag cannot perturb Fifo, Fair or
    //    Capacity even when armed.
    // 3. Both hold under coin-flip failure injection — the RNG-isolation
    //    proof: if the disabled (or no-op-armed) shadow layer drew from
    //    the engine RNG, the failure pattern would shift and the goldens
    //    would diverge.
    let off = EngineOptions { tune_delta: false, ..Default::default() };
    let on = EngineOptions { tune_delta: true, ..Default::default() };
    for failures in [0.0, 0.2] {
        let specs = generate(24, WorkloadMix::Mixed, 0.3, 2_000, 42);
        for kind in KINDS {
            let baseline = run_opts(kind, specs.clone(), EngineOptions::default(), failures);
            assert_eq!(
                baseline,
                run_opts(kind, specs.clone(), off, failures),
                "{kind:?} (failures={failures}): explicit tune_delta=false != default"
            );
            if kind != SchedKind::Dress {
                assert_eq!(
                    baseline,
                    run_opts(kind, specs.clone(), on, failures),
                    "{kind:?} (failures={failures}): armed tuner perturbed a baseline scheduler"
                );
            }
        }
    }
}

#[test]
fn disabled_shadow_tuner_is_bit_identical_under_fault_plans() {
    // Same zero-overhead claim with the deterministic outage machinery
    // live: node crash/recover events, requeues and degraded capacity all
    // flow through the (time, seq) queue the shadow layer must never
    // touch when disabled.
    use dress::sim::FaultPlan;
    let specs = generate(16, WorkloadMix::Mixed, 0.3, 1_500, 11);
    let off = EngineOptions { tune_delta: false, ..Default::default() };
    let on = EngineOptions { tune_delta: true, ..Default::default() };
    for kind in KINDS {
        let mut cfg = ExperimentConfig::default();
        cfg.sched.kind = kind;
        cfg.faults = FaultPlan::empty().with_outage(30_000, 0, 45_000);
        let baseline = Golden::of(&run_experiment_with(&cfg, specs.clone(), EngineOptions::default()));
        assert_eq!(
            baseline,
            Golden::of(&run_experiment_with(&cfg, specs.clone(), off)),
            "{kind:?}: tune_delta=false perturbed a faulted run"
        );
        if kind != SchedKind::Dress {
            assert_eq!(
                baseline,
                Golden::of(&run_experiment_with(&cfg, specs.clone(), on)),
                "{kind:?}: armed tuner perturbed a faulted baseline run"
            );
        }
    }
}

#[test]
fn tuned_dress_runs_are_deterministic_and_in_band() {
    // The armed tuner on DRESS: run-to-run bit-identical (replay draws no
    // randomness, the window is a deterministic function of the event
    // stream), and every δ it ever adopts stays inside the legal band.
    use dress::sched::dress::reserve::{DELTA_MAX, DELTA_MIN};
    let on = EngineOptions { tune_delta: true, ..Default::default() };
    let specs = congested_burst(120, 80, 0xBEEF);
    let a = run_opts(SchedKind::Dress, specs.clone(), on, 0.0);
    let b = run_opts(SchedKind::Dress, specs.clone(), on, 0.0);
    assert_eq!(a, b, "tuned run not deterministic");
    assert!(!a.delta_history.is_empty(), "tuned run recorded no δ samples");
    for &(at, d) in &a.delta_history {
        assert!(
            (DELTA_MIN..=DELTA_MAX).contains(&d),
            "adopted δ {d} at t={at} outside [{DELTA_MIN}, {DELTA_MAX}]"
        );
    }
}

#[test]
fn vector_demand_burst_deterministic_and_reference_equivalent() {
    // The stochastic vector-demand preset through the whole equivalence
    // matrix: every scheduler is run-to-run bit-identical on cpu × mem
    // demands, and the indexed hot path still reproduces the naive
    // per-tick reference exactly.
    let specs = dress::workload::congested_burst_vec(150, 100, 0xFEED);
    assert!(specs.iter().any(|s| !s.demand.is_uniform()), "preset drew no vector demands");
    for kind in KINDS {
        let fast = run(kind, specs.clone(), false, 0.0);
        let again = run(kind, specs.clone(), false, 0.0);
        assert_eq!(fast, again, "{kind:?}: vector-demand run not deterministic");
        let naive = run(kind, specs.clone(), true, 0.0);
        assert_eq!(fast, naive, "{kind:?}: vector-demand hot path diverged from reference");
        assert!(fast.makespan_ms > 0 && fast.trace_len > 0, "{kind:?}: empty vector run");
    }
}

#[test]
fn memory_axis_changes_scheduling_when_fat() {
    // Sensitivity proof for the scalar bit-identity claim: the memory
    // axis must be *live* — a workload whose only difference from its
    // scalar twin is a 4-units-per-container memory footprint has to
    // produce a different golden for every scheduler (the per-node and
    // per-tick memory clamps restrict concurrency).  If this failed, the
    // "scalar runs are unchanged" goldens above would prove nothing.
    use dress::jobs::{Demand, JobSpec, PhaseKind, PhaseSpec, Platform};
    let mk = |demand: Demand| -> Vec<JobSpec> {
        (0..8u32)
            .map(|i| {
                let s = JobSpec {
                    id: i + 1,
                    name: format!("mem{}", i + 1),
                    platform: Platform::MapReduce,
                    submit_ms: i as u64 * 500,
                    demand,
                    phases: vec![
                        PhaseSpec::new(PhaseKind::Map, &[5_000; 4]),
                        PhaseSpec::new(PhaseKind::Reduce, &[5_000; 4]),
                    ],
                };
                s.validate().expect("sensitivity specs must be valid");
                s
            })
            .collect()
    };
    let scalar = mk(Demand::scalar(4));
    let fat = mk(Demand::new(4, 16)); // 4 memory units per container
    for kind in KINDS {
        let thin = run(kind, scalar.clone(), false, 0.0);
        let wide = run(kind, fat.clone(), false, 0.0);
        assert_ne!(thin, wide, "{kind:?}: memory axis invisible to scheduling");
        assert!(
            wide.makespan_ms >= thin.makespan_ms,
            "{kind:?}: fat memory demands somehow finished earlier ({} < {})",
            wide.makespan_ms,
            thin.makespan_ms
        );
    }
}

#[test]
fn cross_seed_runs_differ() {
    // Sanity that the fingerprint is actually sensitive: different seeds
    // must yield different goldens (else the equality tests prove nothing).
    let a = run(SchedKind::Dress, generate(24, WorkloadMix::Mixed, 0.3, 2_000, 42), false, 0.0);
    let b = run(SchedKind::Dress, generate(24, WorkloadMix::Mixed, 0.3, 2_000, 43), false, 0.0);
    assert_ne!(a, b, "fingerprint insensitive to seed");
}
