//! Fault-injection matrix: every scheduler must absorb node crashes.
//!
//! For Fifo, Fair, Capacity and Dress under {empty, single-crash,
//! correlated-outage} plans on a congested mixed workload:
//!
//! * every job still finishes (the engine asserts no starvation),
//! * attempt conservation holds: attempts created == completed tasks +
//!   coin-flip failures + crash-killed attempts,
//! * crash-killed work shows up in the recovery accounting (lost work,
//!   per-outage time-to-recover, goodput < 1), and
//! * DRESS's δ trajectory actually reacts to the capacity loss.

use dress::config::{ExperimentConfig, SchedKind};
use dress::sim::engine::run_experiment;
use dress::sim::{FaultPlan, RunResult};
use dress::workload::{generate, WorkloadMix};

const KINDS: [SchedKind; 5] = [
    SchedKind::Fifo,
    SchedKind::Fair,
    SchedKind::Capacity,
    SchedKind::Dress,
    SchedKind::MaxWeight,
];

/// 24 mixed jobs every 2 s on the default 5x8 cluster: congested from the
/// first minute, so a crash in that window always has victims.
fn faulted(kind: SchedKind, plan: FaultPlan) -> RunResult {
    let mut cfg = ExperimentConfig::default();
    cfg.sched.kind = kind;
    cfg.faults = plan;
    run_experiment(&cfg, generate(24, WorkloadMix::Mixed, 0.3, 2_000, 42))
}

fn expected_tasks() -> u32 {
    generate(24, WorkloadMix::Mixed, 0.3, 2_000, 42).iter().map(|s| s.total_tasks()).sum()
}

/// Shared invariants for any completed run under any plan.
fn check_conservation(kind: SchedKind, r: &RunResult, label: &str) {
    assert_eq!(
        r.trace.tasks.len() as u32,
        expected_tasks(),
        "{kind:?}/{label}: not every task completed"
    );
    assert_eq!(
        r.attempts,
        r.trace.tasks.len() as u32 + r.failures + r.lost_attempts,
        "{kind:?}/{label}: attempt conservation violated"
    );
    assert_eq!(
        r.outages.iter().map(|o| o.killed).sum::<u32>(),
        r.lost_attempts,
        "{kind:?}/{label}: per-outage kills disagree with the run total"
    );
    assert!(
        r.lost_work_ms <= r.wasted_work_ms,
        "{kind:?}/{label}: crash-lost work exceeds total wasted work"
    );
    let g = r.goodput();
    assert!((0.0..=1.0).contains(&g), "{kind:?}/{label}: goodput {g} out of range");
}

#[test]
fn empty_plan_runs_clean_for_all_schedulers() {
    for kind in KINDS {
        let r = faulted(kind, FaultPlan::empty());
        check_conservation(kind, &r, "empty");
        assert!(r.outages.is_empty(), "{kind:?}: phantom outage");
        assert_eq!(r.lost_attempts, 0, "{kind:?}: lost attempts without a fault plan");
        assert_eq!(r.goodput(), 1.0, "{kind:?}: goodput must be perfect without faults");
    }
}

#[test]
fn single_crash_recovers_under_all_schedulers() {
    // Node 0 (8 of 40 slots) dies at t=40 s for 60 s — mid-congestion, so
    // running tasks are killed, requeued, and must all re-complete.
    let plan = FaultPlan::empty().with_outage(40_000, 0, 60_000);
    for kind in KINDS {
        let r = faulted(kind, plan.clone());
        check_conservation(kind, &r, "single-crash");
        assert_eq!(r.outages.len(), 1, "{kind:?}: outage not recorded");
        let o = &r.outages[0];
        assert!(o.killed > 0, "{kind:?}: crash killed nothing on a congested cluster");
        assert!(r.lost_attempts > 0 && r.lost_work_ms > 0, "{kind:?}: no work lost");
        assert!(r.goodput() < 1.0, "{kind:?}: lost work must show up in goodput");
        let ttr = o
            .time_to_recover_ms()
            .unwrap_or_else(|| panic!("{kind:?}: outage never healed"));
        assert!(
            ttr >= o.down_ms,
            "{kind:?}: healed in {ttr} ms, below the {} ms downtime",
            o.down_ms
        );
    }
}

#[test]
fn correlated_outage_recovers_under_all_schedulers() {
    // A rack failure: nodes 1 and 2 (16 of 40 slots) die together at
    // t=45 s for 90 s.  Every scheduler must still drain the workload.
    let plan = FaultPlan::empty().correlated(45_000, &[1, 2], 90_000);
    for kind in KINDS {
        let r = faulted(kind, plan.clone());
        check_conservation(kind, &r, "correlated");
        assert_eq!(r.outages.len(), 2, "{kind:?}: both halves of the outage must record");
        assert!(r.lost_attempts > 0, "{kind:?}: correlated crash killed nothing");
        for o in &r.outages {
            assert_eq!(o.at_ms, 45_000);
            if let Some(t) = o.recovered_at {
                assert!(t >= o.at_ms + o.down_ms, "{kind:?}: healed before the node was up");
            }
        }
    }
}

#[test]
fn dress_delta_trace_reacts_to_capacity_loss() {
    // DRESS re-derives its reservation split from the live total, so a
    // 60 s capacity dip must perturb the δ trajectory (and the schedule).
    let calm = faulted(SchedKind::Dress, FaultPlan::empty());
    let stormy = faulted(SchedKind::Dress, FaultPlan::empty().with_outage(40_000, 0, 60_000));
    assert!(!calm.delta_history.is_empty() && !stormy.delta_history.is_empty());
    assert_ne!(
        calm.delta_history, stormy.delta_history,
        "δ trajectory blind to a 20% capacity loss"
    );
}

#[test]
fn stochastic_plan_is_reproducible_end_to_end() {
    // Same seed, same stochastic plan => bit-identical recovery ledger.
    let plan = FaultPlan::empty().stochastic(120_000, 20_000, 300_000);
    let a = faulted(SchedKind::Capacity, plan.clone());
    let b = faulted(SchedKind::Capacity, plan);
    assert_eq!(a.outages, b.outages, "stochastic outage ledger not seed-stable");
    assert_eq!(a.lost_work_ms, b.lost_work_ms);
    assert_eq!(a.system.makespan_ms, b.system.makespan_ms);
    check_conservation(SchedKind::Capacity, &a, "stochastic");
}
