//! Admission-front lifecycle + shadow-tuner integration (docs/ADMISSION.md).
//!
//! The lifecycle matrix — probe → reserve → commit, probe → reserve →
//! expire, and reserve under outage-degraded capacity — runs against a
//! snapshot taken from each of the five schedulers, and every scenario is
//! seed-stable: repeating it reproduces the controller's full Debug state
//! byte-for-byte.  The tuner smoke pins the adopted δ to the legal band
//! and the tuned trajectory to run-to-run bit-identity.

use dress::config::{ExperimentConfig, SchedKind};
use dress::jobs::Demand;
use dress::live::{AdmissionConfig, AdmissionCtl, ProbeDecision, TicketState};
use dress::sched::dress::reserve::{DELTA_MAX, DELTA_MIN};
use dress::sched::{ClusterView, JobView, SchedSnapshot};
use dress::sim::run_experiment_with;
use dress::sim::EngineOptions;
use dress::workload::{congested_burst, generate, WorkloadMix};

const KINDS: [SchedKind; 5] = [
    SchedKind::Fifo,
    SchedKind::Fair,
    SchedKind::Capacity,
    SchedKind::Dress,
    SchedKind::MaxWeight,
];

const TOTAL: u32 = 8;
const TIMEOUT: u64 = 5_000;

fn jv(id: u32, demand: u32, started: bool) -> JobView {
    JobView {
        id,
        demand: Demand::scalar(demand),
        submit_ms: id as u64 * 500,
        started,
        finished: false,
        pending_tasks: demand,
        occupied: if started { demand } else { 0 },
    }
}

/// Snapshot as the given scheduler would capture it: its own override
/// when it has one (DRESS carries classifier/estimator/δ state), the
/// scheduler-agnostic view otherwise.
fn snapshot_for(kind: SchedKind, jobs: &[JobView], free: u32) -> SchedSnapshot {
    let cfg = ExperimentConfig::default();
    let mut sched_cfg = cfg.sched;
    sched_cfg.kind = kind;
    let sched = dress::sched::build(&sched_cfg, TOTAL);
    let view = ClusterView {
        now: 10_000,
        free,
        total: TOTAL,
        free_mem: free,
        total_mem: TOTAL,
        jobs,
        transitions: &[],
    };
    sched.snapshot(&view).unwrap_or_else(|| {
        SchedSnapshot::of_view(10_000, free, TOTAL, jobs, sched_cfg.delta0, sched_cfg.theta)
    })
}

fn conserved(ctl: &AdmissionCtl) {
    assert_eq!(
        ctl.available() + ctl.reserved() + ctl.committed(),
        ctl.total(),
        "capacity ledger out of balance"
    );
}

/// One full lifecycle pass against `kind`'s snapshot; returns the
/// controller's terminal Debug state for the seed-stability check.
fn lifecycle_pass(kind: SchedKind) -> String {
    let jobs = [jv(1, 3, true), jv(2, 2, false)];
    let mut ctl = AdmissionCtl::new(AdmissionConfig::enabled(TIMEOUT), TOTAL);

    // probe → reserve → commit
    let snap = snapshot_for(kind, &jobs, TOTAL - 3);
    let report = ctl.probe(&snap, 2);
    assert_eq!(report.decision, ProbeDecision::Admit, "{kind:?}: free capacity must admit");
    assert_eq!(report.available, TOTAL, "{kind:?}: probe misreported availability");
    let committed = ctl.reserve(0, 2).expect("reserve after Admit");
    assert_eq!(ctl.ticket_state(committed), Some(TicketState::Reserved));
    conserved(&ctl);
    assert!(ctl.commit(100, committed), "{kind:?}: commit within the timeout failed");
    assert_eq!(ctl.ticket_state(committed), Some(TicketState::Committed));
    conserved(&ctl);

    // probe → reserve → expire: never committed, capacity returns at the
    // deadline and a late commit is refused.
    let expired = ctl.reserve(100, 3).expect("second reservation fits");
    let deadline = ctl.ticket_expires_at(expired).unwrap();
    assert_eq!(deadline, 100 + TIMEOUT);
    ctl.advance(deadline - 1);
    assert_eq!(ctl.ticket_state(expired), Some(TicketState::Reserved), "{kind:?}: expired early");
    ctl.advance(deadline);
    assert_eq!(ctl.ticket_state(expired), Some(TicketState::Expired), "{kind:?}: missed expiry");
    assert!(!ctl.commit(deadline, expired), "{kind:?}: commit revived an expired ticket");
    assert_eq!(ctl.expired_capacity(), 3, "{kind:?}: expiry must return exactly 3 slots");
    conserved(&ctl);

    // reserve under degraded capacity: an outage halves the cluster; the
    // committed 2 slots survive, so only TOTAL/2 - 2 are reservable.
    ctl.set_total(TOTAL / 2);
    assert_eq!(ctl.available(), TOTAL / 2 - 2, "{kind:?}: degraded availability wrong");
    assert!(ctl.reserve(deadline, TOTAL / 2).is_none(), "{kind:?}: overcommit under outage");
    let snap = snapshot_for(kind, &jobs, 1);
    assert_eq!(
        ctl.probe(&snap, TOTAL / 2).decision,
        ProbeDecision::Defer,
        "{kind:?}: probe must defer what reserve would refuse"
    );
    let small = ctl.reserve(deadline, 1).expect("1 slot still fits the degraded cluster");
    // Recovery restores headroom; the held reservations are untouched.
    ctl.set_total(TOTAL);
    assert_eq!(ctl.ticket_state(small), Some(TicketState::Reserved));
    assert_eq!(ctl.available(), TOTAL - 3);
    conserved(&ctl);
    assert!(ctl.release(deadline, committed), "{kind:?}: release of committed ticket failed");
    assert_eq!(ctl.available(), TOTAL - 1);
    conserved(&ctl);

    format!("{ctl:?}")
}

#[test]
fn lifecycle_matrix_all_schedulers_seed_stable() {
    for kind in KINDS {
        let first = lifecycle_pass(kind);
        let second = lifecycle_pass(kind);
        assert_eq!(first, second, "{kind:?}: lifecycle not reproducible");
    }
}

#[test]
fn probe_is_read_only_against_every_schedulers_snapshot() {
    // The what-if itself must not disturb the snapshot it reads: replay
    // clones the classifier, so even a DRESS snapshot (which carries live
    // classifier + estimator state) is byte-identical after N probes.
    let jobs = [jv(1, 6, true), jv(2, 2, false), jv(3, 1, false)];
    for kind in KINDS {
        let snap = snapshot_for(kind, &jobs, 2);
        let ctl = AdmissionCtl::new(AdmissionConfig::enabled(TIMEOUT), TOTAL);
        let before = (format!("{snap:?}"), format!("{ctl:?}"));
        for demand in [0, 1, 4, TOTAL, TOTAL + 5] {
            let a = ctl.probe(&snap, demand);
            let b = ctl.probe(&snap, demand);
            assert_eq!(a.decision, b.decision, "{kind:?}: probe({demand}) not idempotent");
            assert_eq!(a.score, b.score, "{kind:?}: probe({demand}) score drifted");
        }
        assert_eq!(
            (format!("{snap:?}"), format!("{ctl:?}")),
            before,
            "{kind:?}: probing mutated snapshot or controller"
        );
    }
}

#[test]
fn zero_and_oversized_demands_never_admit() {
    let ctl = AdmissionCtl::new(AdmissionConfig::enabled(TIMEOUT), TOTAL);
    let snap = snapshot_for(SchedKind::Dress, &[jv(1, 2, true)], TOTAL - 2);
    assert_eq!(ctl.probe(&snap, 0).decision, ProbeDecision::Defer);
    assert_eq!(ctl.probe(&snap, TOTAL + 1).decision, ProbeDecision::Defer);
    let mut ctl = ctl;
    assert!(ctl.reserve(0, 0).is_none(), "zero-demand reservation granted");
    assert!(ctl.reserve(0, TOTAL + 1).is_none(), "oversized reservation granted");
    // A disabled front refuses reservations outright.
    let mut off = AdmissionCtl::new(AdmissionConfig::default(), TOTAL);
    assert!(off.reserve(0, 1).is_none(), "disabled front granted a ticket");
    assert_eq!(off.expiries_scheduled(), 0, "disabled front scheduled an expiry event");
}

/// Tuned-run fingerprint: everything the tuner can influence.
fn tuned_fingerprint(specs: Vec<dress::jobs::JobSpec>) -> (u64, Vec<(u64, f64)>, String) {
    let mut cfg = ExperimentConfig::default();
    cfg.sched.kind = SchedKind::Dress;
    let res = run_experiment_with(
        &cfg,
        specs,
        EngineOptions { tune_delta: true, ..Default::default() },
    );
    (res.system.makespan_ms, res.delta_history.clone(), format!("{:?}", res.jobs))
}

#[test]
fn shadow_tuner_adopts_in_band_deltas_deterministically() {
    let specs = generate(24, WorkloadMix::Mixed, 0.3, 2_000, 42);
    let a = tuned_fingerprint(specs.clone());
    let b = tuned_fingerprint(specs);
    assert_eq!(a, b, "tuned trajectory not reproducible run-to-run");
    assert!(!a.1.is_empty(), "tuned run recorded no δ history");
    for &(at, d) in &a.1 {
        assert!(
            (DELTA_MIN..=DELTA_MAX).contains(&d),
            "adopted δ {d} at t={at} outside [{DELTA_MIN}, {DELTA_MAX}]"
        );
    }
}

#[test]
#[ignore = "large-window variant: congested burst big enough to wrap the 256-event ring"]
fn shadow_tuner_deterministic_after_window_wraparound() {
    // >256 submit/complete events guarantee the ring buffer evicts — the
    // wrapped iteration order and the eviction path must stay inside the
    // same determinism and band guarantees as the warm-up path.
    let specs = congested_burst(400, 100, 0xD1CE);
    let a = tuned_fingerprint(specs.clone());
    let b = tuned_fingerprint(specs);
    assert_eq!(a, b, "post-wraparound tuned trajectory not reproducible");
    for &(at, d) in &a.1 {
        assert!(
            (DELTA_MIN..=DELTA_MAX).contains(&d),
            "adopted δ {d} at t={at} outside [{DELTA_MIN}, {DELTA_MAX}]"
        );
    }
}
