//! Live-mode integration: a miniature end-to-end run with real PJRT
//! compute per task (skipped if artifacts are missing).

use dress::config::{SchedConfig, SchedKind};
use dress::live::{run_live, LiveConfig};
use dress::runtime::find_artifacts_dir;
use dress::workload::{generate, WorkloadMix};

fn tiny_specs(n: u32, seed: u64) -> Vec<dress::jobs::JobSpec> {
    let mut specs = generate(n, WorkloadMix::Mixed, 0.5, 200, seed);
    for s in specs.iter_mut() {
        s.phases.truncate(1);
        for p in s.phases.iter_mut() {
            p.tasks.truncate(2);
            for t in p.tasks.iter_mut() {
                t.duration_ms = t.duration_ms.min(1_000);
            }
        }
        s.demand = s.demand.min_each(dress::jobs::Demand::scalar(2));
    }
    specs
}

#[test]
fn live_run_completes_with_real_compute() {
    let Some(dir) = find_artifacts_dir() else {
        eprintln!("NOTE: artifacts/ missing — skipping live test");
        return;
    };
    let cfg = LiveConfig {
        workers: 3,
        hb: std::time::Duration::from_millis(20),
        units_per_sec: 1.0,
        max_wall: std::time::Duration::from_secs(120),
        ..Default::default()
    };
    let sched_cfg = SchedConfig { kind: SchedKind::Dress, ..Default::default() };
    let sched = dress::sched::build(&sched_cfg, 3);
    let rep = run_live(
        &cfg,
        &sched_cfg,
        tiny_specs(3, 42),
        sched,
        dir.join("taskwork.hlo.txt").to_str().unwrap(),
    )
    .expect("live run");
    assert_eq!(rep.jobs.len(), 3);
    assert!(rep.tasks_run >= 3, "tasks {}", rep.tasks_run);
    assert!(rep.checksum.is_finite() && rep.checksum != 0.0);
    for j in &rep.jobs {
        assert!(j.completion_ms > 0);
        assert!(j.waiting_ms <= j.completion_ms);
    }
}

#[test]
fn live_capacity_baseline_also_completes() {
    let Some(dir) = find_artifacts_dir() else { return };
    let cfg = LiveConfig {
        workers: 2,
        hb: std::time::Duration::from_millis(20),
        units_per_sec: 1.0,
        max_wall: std::time::Duration::from_secs(120),
        ..Default::default()
    };
    let sched_cfg = SchedConfig { kind: SchedKind::Capacity, ..Default::default() };
    let sched = dress::sched::build(&sched_cfg, 2);
    let rep = run_live(
        &cfg,
        &sched_cfg,
        tiny_specs(2, 7),
        sched,
        dir.join("taskwork.hlo.txt").to_str().unwrap(),
    )
    .expect("live run");
    assert_eq!(rep.scheduler, "capacity");
    assert_eq!(rep.jobs.len(), 2);
    assert!(rep.unfinished.is_empty(), "healthy run left {:?} unfinished", rep.unfinished);
}

#[test]
fn live_run_survives_a_dead_worker() {
    let Some(dir) = find_artifacts_dir() else { return };
    // One of three workers silently dies holding its first task.  The
    // deadline scan must reclaim the lost attempt and the surviving pool
    // must finish every job — no hang, no panic, nothing unfinished.
    let cfg = LiveConfig {
        workers: 3,
        hb: std::time::Duration::from_millis(20),
        units_per_sec: 1.0,
        max_wall: std::time::Duration::from_secs(120),
        task_deadline: std::time::Duration::from_secs(2),
        simulate_worker_deaths: 1,
        ..Default::default()
    };
    let sched_cfg = SchedConfig { kind: SchedKind::Dress, ..Default::default() };
    let sched = dress::sched::build(&sched_cfg, 3);
    let rep = run_live(
        &cfg,
        &sched_cfg,
        tiny_specs(3, 42),
        sched,
        dir.join("taskwork.hlo.txt").to_str().unwrap(),
    )
    .expect("live run with a dead worker");
    assert!(rep.unfinished.is_empty(), "jobs lost to a single dead worker: {:?}", rep.unfinished);
    assert_eq!(rep.jobs.len(), 3);
    assert!(rep.checksum.is_finite());
    // Whether the doomed worker ever won a task is a race; if it did, the
    // requeue path must have fired.
    if rep.requeues > 0 {
        eprintln!("NOTE: dead worker ate a task; {} requeue(s) recovered it", rep.requeues);
    }
}

#[test]
fn all_workers_dead_reports_unfinished_instead_of_hanging() {
    let Some(dir) = find_artifacts_dir() else { return };
    // The entire pool (one worker) dies on its first task.  The run must
    // wind down through the pool-dead path — reporting the jobs as
    // unfinished — rather than spinning until max_wall or panicking on a
    // closed channel.
    let cfg = LiveConfig {
        workers: 1,
        hb: std::time::Duration::from_millis(20),
        units_per_sec: 1.0,
        max_wall: std::time::Duration::from_secs(60),
        task_deadline: std::time::Duration::from_millis(300),
        simulate_worker_deaths: 1,
        ..Default::default()
    };
    let sched_cfg = SchedConfig { kind: SchedKind::Fifo, ..Default::default() };
    let sched = dress::sched::build(&sched_cfg, 1);
    let t0 = std::time::Instant::now();
    let rep = run_live(
        &cfg,
        &sched_cfg,
        tiny_specs(2, 7),
        sched,
        dir.join("taskwork.hlo.txt").to_str().unwrap(),
    )
    .expect("pool death must degrade, not error");
    assert_eq!(rep.unfinished.len(), 2, "all jobs should be unfinished: {rep:?}");
    assert!(rep.jobs.is_empty(), "no job can have finished: {:?}", rep.jobs);
    assert!(
        t0.elapsed() < cfg.max_wall,
        "pool-dead wind-down should beat max_wall, took {:?}",
        t0.elapsed()
    );
}
