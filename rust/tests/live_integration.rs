//! Live-mode integration: a miniature end-to-end run with real PJRT
//! compute per task (skipped if artifacts are missing).

use dress::config::{SchedConfig, SchedKind};
use dress::live::{run_live, LiveConfig};
use dress::runtime::find_artifacts_dir;
use dress::workload::{generate, WorkloadMix};

fn tiny_specs(n: u32, seed: u64) -> Vec<dress::jobs::JobSpec> {
    let mut specs = generate(n, WorkloadMix::Mixed, 0.5, 200, seed);
    for s in specs.iter_mut() {
        s.phases.truncate(1);
        for p in s.phases.iter_mut() {
            p.tasks.truncate(2);
            for t in p.tasks.iter_mut() {
                t.duration_ms = t.duration_ms.min(1_000);
            }
        }
        s.demand = s.demand.min(2);
    }
    specs
}

#[test]
fn live_run_completes_with_real_compute() {
    let Some(dir) = find_artifacts_dir() else {
        eprintln!("NOTE: artifacts/ missing — skipping live test");
        return;
    };
    let cfg = LiveConfig {
        workers: 3,
        hb: std::time::Duration::from_millis(20),
        units_per_sec: 1.0,
        max_wall: std::time::Duration::from_secs(120),
    };
    let sched_cfg = SchedConfig { kind: SchedKind::Dress, ..Default::default() };
    let sched = dress::sched::build(&sched_cfg, 3);
    let rep = run_live(
        &cfg,
        &sched_cfg,
        tiny_specs(3, 42),
        sched,
        dir.join("taskwork.hlo.txt").to_str().unwrap(),
    )
    .expect("live run");
    assert_eq!(rep.jobs.len(), 3);
    assert!(rep.tasks_run >= 3, "tasks {}", rep.tasks_run);
    assert!(rep.checksum.is_finite() && rep.checksum != 0.0);
    for j in &rep.jobs {
        assert!(j.completion_ms > 0);
        assert!(j.waiting_ms <= j.completion_ms);
    }
}

#[test]
fn live_capacity_baseline_also_completes() {
    let Some(dir) = find_artifacts_dir() else { return };
    let cfg = LiveConfig {
        workers: 2,
        hb: std::time::Duration::from_millis(20),
        units_per_sec: 1.0,
        max_wall: std::time::Duration::from_secs(120),
    };
    let sched_cfg = SchedConfig { kind: SchedKind::Capacity, ..Default::default() };
    let sched = dress::sched::build(&sched_cfg, 2);
    let rep = run_live(
        &cfg,
        &sched_cfg,
        tiny_specs(2, 7),
        sched,
        dir.join("taskwork.hlo.txt").to_str().unwrap(),
    )
    .expect("live run");
    assert_eq!(rep.scheduler, "capacity");
    assert_eq!(rep.jobs.len(), 2);
}
