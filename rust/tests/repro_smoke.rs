//! Smoke tests over the experiment registry: every figure/table claim of
//! the paper must hold in its reproduction shape on the default seed.

use dress::expt::{fig1, mixed_setting, mr20, spark20, trace_benchmark};
use dress::jobs::Platform;
use dress::report::comparison_row;
use dress::workload::Benchmark;

fn holds(claim_id: &str, measured: f64) -> bool {
    let (row, ok) = comparison_row(&dress::expt::paper::claim(claim_id), measured);
    if !ok {
        eprintln!("{row}");
    }
    ok
}

#[test]
fn fig1_claims() {
    let r = fig1();
    assert!(holds("FIG1.fcfs-makespan-s", r.fcfs_makespan_s));
    assert!(holds("FIG1.fcfs-avg-wait-s", r.fcfs_avg_wait_s));
    assert!(holds("FIG1.rearranged-makespan-s", r.dress_makespan_s));
    assert!(holds("FIG1.rearranged-avg-wait-s", r.dress_avg_wait_s));
}

#[test]
fn fig2_to_4_trace_shapes() {
    // Fig 2: two phases with measurable starting variation.
    let r = trace_benchmark(Benchmark::WordCount, Platform::MapReduce, 42);
    assert!(r.trace.phase_dps(1, 0).unwrap() > 0);
    // Fig 3: heading task — min map duration well below the max.
    let r = trace_benchmark(Benchmark::PageRank, Platform::MapReduce, 42);
    let durs: Vec<u64> = r
        .trace
        .job_tasks(1)
        .iter()
        .filter(|t| t.phase == 0)
        .map(|t| t.duration())
        .collect();
    let min = *durs.iter().min().unwrap() as f64;
    let max = *durs.iter().max().unwrap() as f64;
    assert!(min < 0.8 * max, "heading task: {durs:?}");
    // Fig 4: trailing task — max stage duration above the second-longest.
    let r = trace_benchmark(Benchmark::PageRank, Platform::Spark, 42);
    let mut durs: Vec<u64> = r
        .trace
        .job_tasks(1)
        .iter()
        .filter(|t| t.phase == 0)
        .map(|t| t.duration())
        .collect();
    durs.sort_unstable();
    assert!(
        durs[durs.len() - 1] as f64 > durs[durs.len() - 2] as f64 * 1.03,
        "trailing task: {durs:?}"
    );
}

#[test]
fn spark20_claims() {
    let pair = spark20(42);
    assert!(holds("FIG6.small-waiting-change-pct", pair.comparison.small_waiting_change_pct));
    assert!(holds("FIG7.small-completion-change-pct", pair.comparison.small_completion_change_pct));
    assert!(holds("FIG7.large-penalized-mean-pct", pair.comparison.large_penalized_mean_pct));
    assert!(holds("TAB2.makespan-change-pct", pair.comparison.makespan_change_pct));
}

#[test]
fn mr20_claims() {
    let pair = mr20(42);
    assert!(holds("FIG8.small-waiting-change-pct", pair.comparison.small_waiting_change_pct));
    assert!(holds("FIG9.small-completion-change-pct", pair.comparison.small_completion_change_pct));
}

#[test]
fn mixed_sweep_claims() {
    for (fig, frac) in [(10, 0.10), (11, 0.20), (12, 0.30), (13, 0.40)] {
        let pair = mixed_setting(frac, 42);
        assert!(
            holds(
                &format!("FIG{fig}.small-completion-change-pct"),
                pair.comparison.small_completion_change_pct
            ),
            "fig{fig}"
        );
    }
}
