//! Estimator vs simulator ground truth: Algorithms 1-2 must recover phase
//! structure (Δps, γ, c) from heartbeat observations alone.

use dress::cluster::ContainerState;
use dress::config::{ExperimentConfig, SchedKind};
use dress::estimator::{EstimatorBank, EstimatorParams};
use dress::expt::trace_benchmark;
use dress::jobs::Platform;
use dress::sim::engine::run_experiment;
use dress::workload::{generate, Benchmark, WorkloadMix};

/// Re-drive an estimator from a finished run's heartbeat history.
fn replay(res: &dress::sim::RunResult, params: EstimatorParams) -> EstimatorBank {
    let mut bank = EstimatorBank::new(params);
    // Synthesize heartbeats at 1 s granularity from the task trace: feed
    // Running/Completed transitions in time order, tick every second.
    let mut events: Vec<(u64, u32, usize, ContainerState)> = Vec::new();
    for t in &res.trace.tasks {
        events.push((t.start, t.job, t.task, ContainerState::Running));
        events.push((t.finish, t.job, t.task, ContainerState::Completed));
    }
    events.sort_by_key(|&(t, ..)| t);
    let end = events.last().map(|&(t, ..)| t).unwrap_or(0);
    let mut ei = 0;
    for now in (0..=end + 30_000).step_by(1_000) {
        let mut batch = Vec::new();
        while ei < events.len() && events[ei].0 <= now {
            let (time, job, task, to) = events[ei];
            bank.register(job, 0);
            batch.push(dress::cluster::Transition { time, container: task as u32, job, task, to });
            ei += 1;
        }
        bank.ingest(&batch);
        bank.tick(now);
    }
    bank
}

#[test]
fn wordcount_phases_detected_with_correct_widths() {
    let res = trace_benchmark(Benchmark::WordCount, Platform::MapReduce, 42);
    let bank = replay(&res, EstimatorParams::default());
    let est = bank.job(1).expect("job observed");
    assert!(
        est.phases.len() >= 2,
        "map + reduce phases expected, got {}",
        est.phases.len()
    );
    // Total containers across detected phases == total tasks run.
    let total_c: u32 = est.phases.iter().map(|p| p.c).sum();
    assert_eq!(total_c as usize, res.trace.tasks.len());
    // First phase should be the wide map phase.
    assert!(est.phases[0].c >= 16, "map phase width {}", est.phases[0].c);
}

#[test]
fn detected_dps_close_to_ground_truth() {
    let res = trace_benchmark(Benchmark::WordCount, Platform::MapReduce, 7);
    let bank = replay(&res, EstimatorParams::default());
    let est = bank.job(1).unwrap();
    let truth = res.trace.phase_dps(1, 0).unwrap() as f64;
    let detected = est.phases[0].dps(0) as f64;
    // Within 50% or 2 s absolute — observation is windowed, truth is exact.
    assert!(
        (detected - truth).abs() <= (0.5 * truth).max(2_000.0),
        "detected Δps {detected} vs truth {truth}"
    );
}

#[test]
fn gamma_detected_after_first_bulk_finish() {
    let res = trace_benchmark(Benchmark::WordCount, Platform::MapReduce, 3);
    let bank = replay(&res, EstimatorParams::default());
    let est = bank.job(1).unwrap();
    let p0 = &est.phases[0];
    let gamma = p0.gamma.expect("gamma detected for map phase") as u64;
    let first_finish = res
        .trace
        .tasks
        .iter()
        .filter(|t| t.phase == 0)
        .map(|t| t.finish)
        .min()
        .unwrap();
    let last_finish = res
        .trace
        .tasks
        .iter()
        .filter(|t| t.phase == 0)
        .map(|t| t.finish)
        .max()
        .unwrap();
    assert!(
        gamma >= first_finish && gamma <= last_finish,
        "gamma {gamma} outside [{first_finish}, {last_finish}]"
    );
}

#[test]
fn beta_set_once_job_drains() {
    let res = trace_benchmark(Benchmark::Scan, Platform::MapReduce, 5);
    let bank = replay(&res, EstimatorParams::default());
    let est = bank.job(1).unwrap();
    let last = res.trace.tasks.iter().map(|t| t.finish).max().unwrap();
    assert_eq!(est.beta, Some(last));
    assert_eq!(est.running, 0);
}

#[test]
fn estimator_inside_dress_produces_nonzero_predictions() {
    // During a congested DRESS run, the estimator must at some point
    // predict a strictly positive release (δ history then moves).
    let mut cfg = ExperimentConfig::default();
    cfg.sched.kind = SchedKind::Dress;
    let res = run_experiment(&cfg, generate(12, WorkloadMix::Mixed, 0.3, 2_000, 42));
    let deltas: Vec<f64> = res.delta_history.iter().map(|&(_, d)| d).collect();
    let min = deltas.iter().copied().fold(f64::INFINITY, f64::min);
    let max = deltas.iter().copied().fold(0.0f64, f64::max);
    assert!(max > min, "δ never moved: [{min}, {max}]");
}
