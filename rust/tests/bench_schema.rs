//! Schema check for the checked-in bench trajectory file.
//!
//! `BENCH_engine.json` is written by two producers (`perf_throughput`
//! owns the top level, `perf_sweep` owns the `sweep` section) and read by
//! humans comparing PRs.  This test pins the contract: the checked-in
//! file must parse with the in-tree JSON parser and carry both sections —
//! whether it holds measured numbers or `status: pending` placeholders
//! (the growth container has no Rust toolchain, so regeneration happens
//! wherever `cargo` is available).

use dress::util::json::Json;

fn bench_file() -> String {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_engine.json");
    std::fs::read_to_string(path).unwrap_or_else(|e| panic!("read {path}: {e}"))
}

#[test]
fn bench_engine_json_parses_and_has_required_sections() {
    let root = Json::parse(&bench_file()).expect("BENCH_engine.json must be valid JSON");
    assert_eq!(
        root.get("bench").and_then(|v| v.as_str()),
        Some("perf_throughput"),
        "top-level `bench` tag"
    );
    assert!(root.get("workload").is_some(), "missing `workload`");
    assert!(
        root.get("metric_sink").is_some(),
        "missing `metric_sink` (the per-tick retention policy the numbers were measured under)"
    );
    assert!(
        root.get("speedup_indexed_vs_naive_1k").is_some(),
        "missing `speedup_indexed_vs_naive_1k`"
    );
    let runs = root
        .get("runs")
        .and_then(|v| v.as_arr())
        .expect("`runs` must be an array");
    assert!(!runs.is_empty(), "`runs` must not be empty");
    for row in runs {
        for key in [
            "jobs",
            "scheduler",
            // Cluster size the row ran on (1M rows use a larger cluster to
            // stay under the livelock guard) and the process RSS high-water
            // mark, so memory trajectories travel with the throughput ones.
            "nodes",
            "peak_rss_bytes",
            "events",
            "wall_ms",
            "events_per_sec",
            "retained_transitions",
            // Metric-sink retention fields (bounded-memory trajectory):
            // retained must stay 0 under the counting preset; the exact
            // utilization integers travel alongside for PR comparison.
            "retained_util_samples",
            "util_samples",
            "util_area_ms",
            "util_span_ms",
            "mean_utilization_pct",
        ] {
            assert!(row.get(key).is_some(), "run row missing `{key}`: {row:?}");
        }
        // Whether pending or measured, the bounded-memory invariants are
        // constants of the counting preset, so the checked-in values can be
        // pinned unconditionally.
        assert_eq!(
            row.get("retained_util_samples").and_then(|v| v.as_f64()),
            Some(0.0),
            "counting-preset bench must retain zero per-tick samples: {row:?}"
        );
        assert_eq!(
            row.get("retained_transitions").and_then(|v| v.as_f64()),
            Some(0.0),
            "counting-preset bench must retain zero transitions: {row:?}"
        );
    }
    // The default matrix reaches 100k jobs (1M rides behind
    // DRESS_BENCH_FULL=1 and is optional in the checked-in file).
    let sizes: Vec<f64> = runs.iter().filter_map(|r| r.get("jobs").and_then(|v| v.as_f64())).collect();
    assert!(
        sizes.contains(&100_000.0),
        "runs must include the 100k-job rows (got sizes {sizes:?})"
    );

    // The sweep section added with the parallel executor, extended by the
    // shard/statistics layer: every worker row carries the wall-time
    // statistics columns (mean ± 95% CI over `passes` repeats), and the
    // section pins the fingerprint of the grid the numbers were measured
    // on.
    let sweep = root.get("sweep").expect("missing `sweep` section");
    assert_eq!(
        sweep.get("bench").and_then(|v| v.as_str()),
        Some("perf_sweep"),
        "`sweep.bench` tag"
    );
    assert!(sweep.get("grid").is_some(), "missing `sweep.grid`");
    assert!(
        sweep.get("grid_fingerprint").is_some(),
        "missing `sweep.grid_fingerprint` (regenerate with `cargo bench --bench perf_sweep`)"
    );
    let sweep_runs = sweep
        .get("runs")
        .and_then(|v| v.as_arr())
        .expect("`sweep.runs` must be an array");
    assert!(!sweep_runs.is_empty(), "`sweep.runs` must not be empty");
    for row in sweep_runs {
        for key in [
            "workers",
            "runs",
            "passes",
            "wall_ms_mean",
            "wall_ms_ci_lo",
            "wall_ms_ci_hi",
            "runs_per_sec",
            "speedup_vs_serial",
        ] {
            assert!(row.get(key).is_some(), "sweep row missing `{key}`: {row:?}");
        }
    }

    let is_pending = |section: &dress::util::json::Json| {
        section
            .get("status")
            .map(|s| s.as_str().map(|t| t.contains("pending")).unwrap_or(false))
            .unwrap_or(false)
    };

    // Placeholder sections must say so; measured sections must hold real
    // numbers AND a fingerprint matching the *current* grid definition —
    // numbers measured on a since-edited grid are silent drift and must
    // fail here until the bench is re-run.
    if !is_pending(&root) {
        for row in runs {
            assert!(
                !row.get("events").unwrap().is_null(),
                "measured file with null events: {row:?}"
            );
        }
    }
    if !is_pending(sweep) {
        for row in sweep_runs {
            assert!(
                !row.get("wall_ms_mean").unwrap().is_null(),
                "measured sweep section with null wall_ms_mean: {row:?}"
            );
        }
        let current = dress::expt::shard::grid_fingerprint(&dress::expt::sweep::bench_grid());
        assert_eq!(
            sweep.get("grid_fingerprint").and_then(|v| v.as_str()),
            Some(current.as_str()),
            "sweep numbers were measured on a different SweepGrid definition than the current \
             `expt::sweep::bench_grid()` — regenerate with `cargo bench --bench perf_sweep`"
        );
    }
}
