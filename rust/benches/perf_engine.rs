//! Perf: DES engine event throughput and full-experiment wall time.
//! Target: >= 10^6 events/s equivalent (DESIGN.md §8).

use dress::bench_harness::{bench, bench_quick, black_box};
use dress::config::{ExperimentConfig, SchedKind};
use dress::sim::engine::run_experiment;
use dress::sim::{run_experiment_with, EngineOptions, Event, EventQueue, QueueKind};
use dress::workload::{congested_burst, generate, WorkloadMix};

fn main() {
    println!("=== perf: DES engine ===");

    // Raw event-queue throughput (push+pop of 10k events per iteration),
    // calendar queue vs the binary-heap reference.
    for kind in [QueueKind::Calendar, QueueKind::Heap] {
        bench(&format!("engine/event-queue/10k-push-pop/{kind:?}"), |i| {
            let mut q = EventQueue::with_kind(kind);
            for k in 0..10_000u64 {
                q.push((i as u64 * 7 + k * 13) % 100_000, Event::SchedTick);
            }
            while let Some(e) = q.pop() {
                black_box(e);
            }
        });
    }

    // Full 20-job experiments per scheduler.
    for kind in [SchedKind::Capacity, SchedKind::Dress] {
        let mut cfg = ExperimentConfig::default();
        cfg.sched.kind = kind;
        bench_quick(&format!("engine/20job-experiment/{}", kind.name()), |i| {
            let specs = generate(20, WorkloadMix::Mixed, 0.3, 5_000, i as u64 + 1);
            black_box(run_experiment(&cfg, specs));
        });
    }

    // Scale: 100-job congested run under DRESS.
    let mut cfg = ExperimentConfig::default();
    cfg.sched.kind = SchedKind::Dress;
    bench_quick("engine/100job-experiment/dress", |i| {
        let specs = generate(100, WorkloadMix::Mixed, 0.3, 2_000, i as u64 + 1);
        black_box(run_experiment(&cfg, specs));
    });

    // Scale: 1k-job heavy-tailed burst, counting sinks (the indexed hot
    // path; see benches/perf_throughput.rs for 5k/10k + events/sec).
    let opts = EngineOptions::throughput();
    bench_quick("engine/1kjob-burst/dress", |i| {
        let specs = congested_burst(1_000, 50, i as u64 + 1);
        black_box(run_experiment_with(&cfg, specs, opts));
    });
}
