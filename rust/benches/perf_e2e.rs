//! Perf: end-to-end PJRT paths — taskwork execution latency and the
//! full live-mode run (real compute per task).

use dress::bench_harness::{bench, bench_quick, black_box};
use dress::runtime::{find_artifacts_dir, Runtime, TaskWork};

fn main() {
    println!("=== perf: end-to-end PJRT ===");
    let Some(dir) = find_artifacts_dir() else {
        println!("(artifacts/ missing — run `make artifacts`)");
        return;
    };
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let tw = TaskWork::load(&rt, dir.join("taskwork.hlo.txt").to_str().unwrap())
        .expect("load taskwork");

    bench("e2e/taskwork-unit (8 power steps, 64x64)", |i| {
        black_box(tw.run_units(i as u64, 1).expect("run"));
    });
    bench_quick("e2e/taskwork-8units", |i| {
        black_box(tw.run_units(i as u64, 8).expect("run"));
    });

    // Live mini-run: 3 jobs, 4 workers, real compute.
    use dress::config::{SchedConfig, SchedKind};
    use dress::live::{run_live, LiveConfig};
    use dress::workload::{generate, WorkloadMix};
    let mut specs = generate(3, WorkloadMix::Mixed, 0.4, 300, 42);
    for s in specs.iter_mut() {
        for p in s.phases.iter_mut() {
            p.tasks.truncate(3);
            for t in p.tasks.iter_mut() {
                t.duration_ms = t.duration_ms.min(1_500);
            }
        }
        s.demand = s.demand.min(3);
        s.phases.truncate(1);
    }
    let cfg = LiveConfig {
        workers: 4,
        hb: std::time::Duration::from_millis(20),
        units_per_sec: 0.5,
        max_wall: std::time::Duration::from_secs(60),
        ..Default::default()
    };
    let sched_cfg = SchedConfig { kind: SchedKind::Dress, ..Default::default() };
    let t0 = std::time::Instant::now();
    let sched = dress::sched::build(&sched_cfg, 4);
    let rep = run_live(&cfg, &sched_cfg, specs, sched, dir.join("taskwork.hlo.txt").to_str().unwrap())
        .expect("live run");
    println!(
        "bench e2e/live-3job-run: {:?} wall, {} tasks, checksum {:.3}",
        t0.elapsed(),
        rep.tasks_run,
        rep.checksum
    );
}
