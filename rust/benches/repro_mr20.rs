//! Bench: regenerate Figs 8-9 (20 MapReduce jobs).

use dress::bench_harness::{bench_quick, black_box};
use dress::expt::mr20;
use dress::report::comparison_row;

fn main() {
    println!("=== repro: Figs 8-9 (Hadoop YARN MapReduce, 20 jobs) ===");
    let pair = mr20(42);
    for (claim, measured) in [
        ("FIG8.small-waiting-change-pct", pair.comparison.small_waiting_change_pct),
        ("FIG9.small-completion-change-pct", pair.comparison.small_completion_change_pct),
    ] {
        let (row, _) = comparison_row(&dress::expt::paper::claim(claim), measured);
        println!("{row}");
    }
    println!(
        "small ids {:?}; best single-job reduction {:+.1}% (paper: Job 9 waiting 189.2s -> 19.98s)",
        pair.comparison.small_ids, pair.comparison.best_small_reduction_pct
    );
    bench_quick("mr20/dress-vs-capacity-pair", |i| {
        black_box(mr20(i as u64 + 1));
    });
}
