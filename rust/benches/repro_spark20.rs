//! Bench: regenerate Figs 6-7 + Table II (20 Spark-on-YARN jobs).

use dress::bench_harness::{bench_quick, black_box};
use dress::expt::spark20;
use dress::metrics::SchedulerSummary;
use dress::report::{self, comparison_row};

fn main() {
    println!("=== repro: Figs 6-7 + Table II (Spark-on-YARN, 20 jobs) ===");
    let pair = spark20(42);
    for (claim, measured) in [
        ("FIG6.small-waiting-change-pct", pair.comparison.small_waiting_change_pct),
        ("FIG7.small-completion-change-pct", pair.comparison.small_completion_change_pct),
        ("FIG7.large-penalized-mean-pct", pair.comparison.large_penalized_mean_pct),
        ("TAB2.makespan-change-pct", pair.comparison.makespan_change_pct),
    ] {
        let (row, _) = comparison_row(&dress::expt::paper::claim(claim), measured);
        println!("{row}");
    }
    println!(
        "{}",
        report::table2(&[
            SchedulerSummary::of("capacity", &pair.baseline.system),
            SchedulerSummary::of("dress", &pair.dress.system),
        ])
    );
    bench_quick("spark20/dress-vs-capacity-pair", |i| {
        black_box(spark20(i as u64 + 1));
    });
}
