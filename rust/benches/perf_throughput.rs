//! Perf: engine throughput at scale — events/sec and sched-ticks/sec on
//! heavy-tailed congested bursts of 1k / 5k / 10k jobs (trace recording
//! off, so the numbers measure scheduling, not trace-vector growth), plus
//! the indexed-vs-naive hot-path speedup against the seed engine's
//! rebuild-every-tick reference path.
//!
//! Emits `BENCH_engine.json` in the working directory for trajectory
//! tracking (schema documented in docs/PERFORMANCE.md):
//!
//!     cargo bench --bench perf_throughput

use dress::bench_harness::black_box;
use dress::config::{ExperimentConfig, SchedKind};
use dress::sim::{run_experiment_with, EngineOptions, RunResult};
use dress::workload::congested_burst;
use std::time::Instant;

const ARRIVAL_MEAN_MS: u64 = 50;
const SEED: u64 = 0xD8E5;

fn timed(cfg: &ExperimentConfig, n: u32, opts: EngineOptions) -> (RunResult, f64) {
    let specs = congested_burst(n, ARRIVAL_MEAN_MS, SEED);
    let t0 = Instant::now();
    let res = run_experiment_with(cfg, specs, opts);
    (res, t0.elapsed().as_secs_f64())
}

fn main() {
    println!("=== perf: engine throughput at scale (congested_burst) ===");
    let opts = EngineOptions { record_trace: false, ..Default::default() };
    let mut runs_json: Vec<String> = Vec::new();

    for n in [1_000u32, 5_000, 10_000] {
        for kind in [SchedKind::Capacity, SchedKind::Dress] {
            let mut cfg = ExperimentConfig::default();
            cfg.sched.kind = kind;
            let (res, wall_s) = timed(&cfg, n, opts);
            let eps = res.events as f64 / wall_s;
            let tps = res.sched_ticks as f64 / wall_s;
            println!(
                "bench engine-throughput/{:<8}/jobs{:<6} {:>12.0} events/s {:>10.0} ticks/s  \
                 ({} events, {} ticks, {:.2} s wall, makespan {:.0} s)",
                kind.name(),
                n,
                eps,
                tps,
                res.events,
                res.sched_ticks,
                wall_s,
                res.system.makespan_ms as f64 / 1000.0
            );
            runs_json.push(format!(
                "    {{\"jobs\": {n}, \"scheduler\": \"{}\", \"events\": {}, \
                 \"sched_ticks\": {}, \"wall_ms\": {:.3}, \"events_per_sec\": {:.1}, \
                 \"ticks_per_sec\": {:.1}, \"makespan_ms\": {}}}",
                kind.name(),
                res.events,
                res.sched_ticks,
                wall_s * 1000.0,
                eps,
                tps,
                res.system.makespan_ms
            ));
            black_box(res);
        }
    }

    // Indexed engine vs the seed's rebuild-every-tick hot path, identical
    // 1k-job workload under DRESS (the naive path is O(jobs) per event, so
    // larger sizes are pointless to wait on).
    let mut cfg = ExperimentConfig::default();
    cfg.sched.kind = SchedKind::Dress;
    let (fast, fast_s) = timed(&cfg, 1_000, opts);
    let (naive, naive_s) =
        timed(&cfg, 1_000, EngineOptions { record_trace: false, naive_hot_path: true });
    assert_eq!(
        fast.system.makespan_ms, naive.system.makespan_ms,
        "hot paths must simulate identically"
    );
    let speedup = naive_s / fast_s;
    println!(
        "bench engine-throughput/indexed-vs-naive/jobs1000: {speedup:.2}x speedup \
         (indexed {fast_s:.2} s vs naive {naive_s:.2} s, identical makespan)"
    );

    let json = format!(
        "{{\n  \"bench\": \"perf_throughput\",\n  \"workload\": \"congested_burst(n, \
         {ARRIVAL_MEAN_MS}, {SEED:#x})\",\n  \"trace_recording\": false,\n  \
         \"speedup_indexed_vs_naive_1k\": {speedup:.2},\n  \"runs\": [\n{}\n  ]\n}}\n",
        runs_json.join(",\n")
    );
    match std::fs::write("BENCH_engine.json", &json) {
        Ok(()) => println!("wrote BENCH_engine.json"),
        Err(e) => eprintln!("could not write BENCH_engine.json: {e}"),
    }
}
