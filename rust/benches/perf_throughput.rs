//! Perf: engine throughput at scale — events/sec and sched-ticks/sec on
//! heavy-tailed congested bursts of 1k / 5k / 10k / 100k jobs (counting
//! trace sinks, so the numbers measure scheduling, not trace-vector
//! growth — and memory stays O(active jobs)), plus the indexed-vs-naive
//! hot-path speedup against the seed engine's rebuild-every-tick
//! reference path.
//!
//! `DRESS_BENCH_FULL=1` adds the 1M-job row.  That run needs a larger
//! cluster (50 nodes): on the default 40 containers a million jobs would
//! take ~170 simulated hours, past the engine's livelock guard; each row
//! records the `nodes` it ran on so trajectories compare like with like.
//!
//! Updates `BENCH_engine.json` in the working directory for trajectory
//! tracking (schema documented in docs/PERFORMANCE.md), preserving the
//! `sweep` section owned by `perf_sweep`:
//!
//!     cargo bench --bench perf_throughput

use dress::bench_harness::black_box;
use dress::config::{ExperimentConfig, SchedKind};
use dress::sim::{run_experiment_with, EngineOptions, RunResult};
use dress::util::json::Json;
use dress::workload::congested_burst;
use std::time::Instant;

const ARRIVAL_MEAN_MS: u64 = 50;
const SEED: u64 = 0xD8E5;

/// The checked-in trajectory file at the repo root — anchored via the
/// manifest dir because `cargo bench` runs with cwd = package root
/// (`rust/`), not the workspace root.
const BENCH_JSON: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_engine.json");

fn timed(cfg: &ExperimentConfig, n: u32, opts: EngineOptions) -> (RunResult, f64) {
    let specs = congested_burst(n, ARRIVAL_MEAN_MS, SEED);
    let t0 = Instant::now();
    let res = run_experiment_with(cfg, specs, opts);
    (res, t0.elapsed().as_secs_f64())
}

/// Process peak resident set (`VmHWM`) in bytes — 0 where /proc is
/// unavailable.  A high-water mark, so later rows inherit earlier rows'
/// peaks; the interesting reading is the largest size's.
fn peak_rss_bytes() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    status
        .lines()
        .find_map(|l| l.strip_prefix("VmHWM:"))
        .and_then(|v| v.trim().trim_end_matches("kB").trim().parse::<u64>().ok())
        .map_or(0, |kb| kb * 1024)
}

fn main() {
    println!("=== perf: engine throughput at scale (congested_burst) ===");
    let opts = EngineOptions::throughput();
    let full = std::env::var("DRESS_BENCH_FULL").is_ok_and(|v| v == "1");
    let mut sizes = vec![1_000u32, 5_000, 10_000, 100_000];
    if full {
        sizes.push(1_000_000);
    } else {
        println!("(set DRESS_BENCH_FULL=1 for the 1M-job row)");
    }
    let mut runs = Vec::new();

    for n in sizes {
        for kind in [SchedKind::Capacity, SchedKind::Dress] {
            let mut cfg = ExperimentConfig::default();
            cfg.sched.kind = kind;
            if n >= 1_000_000 {
                // Keep the simulated horizon under the engine's livelock
                // guard: ~10x the capacity for ~10x the jobs of the 100k row.
                cfg.cluster.nodes = 50;
            }
            let (res, wall_s) = timed(&cfg, n, opts);
            let eps = res.events as f64 / wall_s;
            let tps = res.sched_ticks as f64 / wall_s;
            println!(
                "bench engine-throughput/{:<8}/jobs{:<6} {:>12.0} events/s {:>10.0} ticks/s  \
                 ({} events, {} ticks, {:.2} s wall, makespan {:.0} s)",
                kind.name(),
                n,
                eps,
                tps,
                res.events,
                res.sched_ticks,
                wall_s,
                res.system.makespan_ms as f64 / 1000.0
            );
            let mut row = Json::obj();
            row.set("jobs", Json::Num(n as f64));
            row.set("scheduler", Json::Str(kind.name().to_string()));
            row.set("nodes", Json::Num(cfg.cluster.nodes as f64));
            row.set("peak_rss_bytes", Json::Num(peak_rss_bytes() as f64));
            row.set("events", Json::Num(res.events as f64));
            row.set("sched_ticks", Json::Num(res.sched_ticks as f64));
            row.set("wall_ms", Json::Num((wall_s * 100_000.0).round() / 100.0));
            row.set("events_per_sec", Json::Num(eps.round()));
            row.set("ticks_per_sec", Json::Num(tps.round()));
            row.set("makespan_ms", Json::Num(res.system.makespan_ms as f64));
            row.set(
                "retained_transitions",
                Json::Num(res.retained_transitions as f64),
            );
            // Bounded-memory guarantees under the throughput preset: no
            // per-tick metric samples and no heartbeat transitions retained
            // (the exact time-weighted summaries still report), at every
            // size up to 1M jobs.
            assert_eq!(res.util_history.len(), 0, "counting metric sink retained samples");
            assert_eq!(res.retained_transitions, 0, "throughput preset retained transitions");
            row.set(
                "retained_util_samples",
                Json::Num(res.util_history.len() as f64),
            );
            row.set("util_samples", Json::Num(res.util_recorded as f64));
            row.set("util_area_ms", Json::Num(res.util.area_ms as f64));
            row.set("util_span_ms", Json::Num(res.util.span_ms as f64));
            row.set(
                "mean_utilization_pct",
                Json::Num((res.system.mean_utilization * 1000.0).round() / 10.0),
            );
            runs.push(row);
            black_box(res);
        }
    }

    // Indexed engine vs the seed's rebuild-every-tick hot path, identical
    // 1k-job workload under DRESS (the naive path is O(jobs) per event, so
    // larger sizes are pointless to wait on).
    let mut cfg = ExperimentConfig::default();
    cfg.sched.kind = SchedKind::Dress;
    let (fast, fast_s) = timed(&cfg, 1_000, opts);
    let (naive, naive_s) =
        timed(&cfg, 1_000, EngineOptions { naive_hot_path: true, ..EngineOptions::throughput() });
    assert_eq!(
        fast.system.makespan_ms, naive.system.makespan_ms,
        "hot paths must simulate identically"
    );
    let speedup = naive_s / fast_s;
    println!(
        "bench engine-throughput/indexed-vs-naive/jobs1000: {speedup:.2}x speedup \
         (indexed {fast_s:.2} s vs naive {naive_s:.2} s, identical makespan)"
    );

    // Read-modify-write in place: set our own keys on the parsed root so
    // every foreign section (`sweep` today, anything a future bench adds)
    // survives, then drop the placeholder `status` marker — this file now
    // carries measured numbers.
    let mut root = std::fs::read_to_string(BENCH_JSON)
        .ok()
        .and_then(|t| Json::parse(&t).ok())
        .filter(|v| matches!(v, Json::Obj(_)))
        .unwrap_or_else(Json::obj);
    root.remove("status");
    root.set("bench", Json::Str("perf_throughput".into()));
    root.set(
        "workload",
        Json::Str(format!("congested_burst(n, {ARRIVAL_MEAN_MS}, {SEED:#x})")),
    );
    root.set("trace_sink", Json::Str("counting".into()));
    root.set("metric_sink", Json::Str("counting".into()));
    root.set(
        "speedup_indexed_vs_naive_1k",
        Json::Num((speedup * 100.0).round() / 100.0),
    );
    root.set("runs", Json::Arr(runs));
    match std::fs::write(BENCH_JSON, root.render()) {
        Ok(()) => println!("wrote {BENCH_JSON}"),
        Err(e) => eprintln!("could not write {BENCH_JSON}: {e}"),
    }
}
