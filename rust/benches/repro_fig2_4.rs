//! Bench: regenerate Figs 2-4 (task-execution characteristic traces).

use dress::bench_harness::{bench_quick, black_box};
use dress::expt::trace_benchmark;
use dress::jobs::Platform;
use dress::workload::Benchmark;

fn main() {
    println!("=== repro: Figs 2-4 (task traces) ===");

    // Fig 2: WordCount, 20 map + 4 reduce, visible Δps per phase.
    let r = trace_benchmark(Benchmark::WordCount, Platform::MapReduce, 42);
    let dps0 = r.trace.phase_dps(1, 0).unwrap();
    let dps1 = r.trace.phase_dps(1, 1).unwrap();
    println!("FIG2 wordcount: {} tasks, Δps(map)={}ms Δps(reduce)={}ms", r.trace.tasks.len(), dps0, dps1);
    assert!(r.trace.tasks.len() >= 24);

    // Fig 3: PageRank MR heading task — min map-task duration well under max.
    let r = trace_benchmark(Benchmark::PageRank, Platform::MapReduce, 42);
    let durs: Vec<u64> = r.trace.job_tasks(1).iter().filter(|t| t.phase == 0).map(|t| t.duration()).collect();
    let (min, max) = (*durs.iter().min().unwrap(), *durs.iter().max().unwrap());
    println!("FIG3 pagerank-mr: heading ratio min/max = {:.2} (paper: 1.26s vs 18.25s ≈ 0.07)", min as f64 / max as f64);

    // Fig 4: PageRank Spark trailing task — max stage duration over median.
    let r = trace_benchmark(Benchmark::PageRank, Platform::Spark, 42);
    let mut durs: Vec<u64> = r.trace.job_tasks(1).iter().filter(|t| t.phase == 0).map(|t| t.duration()).collect();
    durs.sort_unstable();
    let trail = durs[durs.len() - 1] as f64 / durs[durs.len() - 2] as f64;
    println!("FIG4 pagerank-spark: trailing/second = {trail:.2} (paper: 1.38)");

    bench_quick("fig2-4/trace-wordcount", |i| {
        black_box(trace_benchmark(Benchmark::WordCount, Platform::MapReduce, i as u64));
    });
}
