//! Bench: regenerate Figs 10-13 (mixed setting, small-fraction sweep).

use dress::bench_harness::{bench_quick, black_box};
use dress::expt::mixed_setting;
use dress::report::comparison_row;

fn main() {
    println!("=== repro: Figs 10-13 (mixed jobs, 10-40% small) ===");
    for (fig, frac) in [(10, 0.10), (11, 0.20), (12, 0.30), (13, 0.40)] {
        let pair = mixed_setting(frac, 42);
        let id = format!("FIG{fig}.small-completion-change-pct");
        let (row, _) = comparison_row(
            &dress::expt::paper::claim(&id),
            pair.comparison.small_completion_change_pct,
        );
        println!("{row}   (makespan change {:+.1}%)", pair.comparison.makespan_change_pct);
    }
    bench_quick("mixed/30pct-pair", |i| {
        black_box(mixed_setting(0.3, i as u64 + 1));
    });
}
