//! Perf: Eq. (1)-(3) evaluation — pure-Rust model vs the PJRT-executed
//! Pallas artifact, across phase-table occupancies.

use dress::bench_harness::{bench, black_box};
use dress::estimator::accel::PjrtEstimator;
use dress::estimator::{eval_curves, predicted_release, PhaseEstimate};
use dress::runtime::{find_artifacts_dir, Runtime, TIME_GRID};

fn phases(n: usize) -> Vec<PhaseEstimate> {
    (0..n)
        .map(|i| PhaseEstimate {
            gamma: 1_000.0 + i as f64 * 37.0,
            dps: 500.0 + (i % 11) as f64 * 90.0,
            c: 1.0 + (i % 8) as f64,
            alpha: 0.0,
            beta: f64::MAX,
            cat: (i % 2) as u8,
        })
        .collect()
}

fn main() {
    println!("=== perf: estimator Eq.(1)-(3) ===");
    let grid: Vec<f64> = (0..TIME_GRID).map(|i| 900.0 + i as f64 * 40.0).collect();
    let gridf: Vec<f32> = grid.iter().map(|&x| x as f32).collect();

    for n in [8usize, 64, 256] {
        let ps = phases(n);
        bench(&format!("estimator/rust-curves/p{n}"), |_| {
            black_box(eval_curves(&ps, &grid));
        });
        bench(&format!("estimator/rust-predict/p{n}"), |_| {
            black_box(predicted_release(&ps, 0, 1_000.0, 2_000.0));
        });
    }

    match find_artifacts_dir() {
        Some(dir) => {
            let rt = Runtime::cpu().expect("PJRT CPU client");
            let path = dir.join("model.hlo.txt");
            let mut est = PjrtEstimator::load(&rt, path.to_str().unwrap()).expect("load artifact");
            for n in [8usize, 64, 256] {
                let ps = phases(n);
                bench(&format!("estimator/pjrt-curves/p{n}"), |_| {
                    black_box(est.curves(&ps, &gridf).expect("pjrt exec"));
                });
            }
        }
        None => println!("(artifacts/ missing — skipping PJRT side; run `make artifacts`)"),
    }
}
