//! Perf: scheduler decision latency per heartbeat (all five schedulers)
//! at 20 and 200 active jobs.  Target: <= 10 µs at 20 jobs (DESIGN.md §8).

use dress::bench_harness::{bench, black_box};
use dress::config::{SchedConfig, SchedKind};
use dress::jobs::Demand;
use dress::sched::{self, ClusterView, JobView};

fn mk_jobs(n: u32) -> Vec<JobView> {
    (0..n)
        .map(|i| JobView {
            id: i + 1,
            demand: Demand::scalar(2 + (i % 24)),
            submit_ms: i as u64 * 5_000,
            started: i % 3 == 0,
            finished: false,
            pending_tasks: 1 + (i % 9),
            occupied: if i % 3 == 0 { 1 + i % 5 } else { 0 },
        })
        .collect()
}

fn main() {
    println!("=== perf: scheduler decision per heartbeat ===");
    for kind in [
        SchedKind::Fifo,
        SchedKind::Fair,
        SchedKind::Capacity,
        SchedKind::Dress,
        SchedKind::MaxWeight,
    ] {
        for njobs in [20u32, 200] {
            let cfg = SchedConfig { kind, ..Default::default() };
            let mut s = sched::build(&cfg, 40);
            let jobs = mk_jobs(njobs);
            bench(&format!("sched/{}/jobs{}", kind.name(), njobs), |i| {
                let view = ClusterView {
                    now: i as u64 * 1_000,
                    free: 12,
                    total: 40,
                    free_mem: 12,
                    total_mem: 40,
                    jobs: &jobs,
                    transitions: &[],
                };
                black_box(s.schedule(&view));
            });
        }
    }
}
