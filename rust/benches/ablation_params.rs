//! Parameter-sensitivity ablation — the analysis the paper omitted "due to
//! the page limit" (§V.A.1): sweep the phase window pw, the thresholds
//! t_s/t_e, the initial reserve δ₀, and the heartbeat period, reporting the
//! small-job completion change and makespan change vs Capacity.

use dress::bench_harness::{bench_quick, black_box};
use dress::config::{ExperimentConfig, SchedKind};
use dress::expt::run_pair;
use dress::util::stats;
use dress::workload::{generate, WorkloadMix};

fn sweep(label: &str, apply: impl Fn(&mut ExperimentConfig, f64), values: &[f64]) {
    println!("-- sweep: {label}");
    for &v in values {
        let mut sc = Vec::new();
        let mut mk = Vec::new();
        for seed in [42u64, 7, 1337] {
            let mut cfg = ExperimentConfig::default();
            apply(&mut cfg, v);
            let specs = generate(20, WorkloadMix::Mixed, 0.3, 5_000, seed);
            let pair = run_pair(&cfg, specs, SchedKind::Capacity);
            sc.push(pair.comparison.small_completion_change_pct);
            mk.push(pair.comparison.makespan_change_pct);
        }
        println!(
            "   {label} = {v:>8}   small-compl {:>7.1}%   makespan {:>6.1}%",
            stats::mean(&sc),
            stats::mean(&mk)
        );
    }
}

fn main() {
    println!("=== ablation: estimator/scheduler parameters (3-seed means) ===");
    sweep("pw_ms", |c, v| c.sched.pw_ms = v as u64, &[2_000.0, 5_000.0, 10_000.0, 20_000.0]);
    sweep("ts_te", |c, v| {
        c.sched.ts = v as u32;
        c.sched.te = v as u32;
    }, &[1.0, 3.0, 5.0, 9.0]);
    sweep("delta0", |c, v| c.sched.delta0 = v, &[0.05, 0.10, 0.25, 0.50]);
    sweep("hb_ms", |c, v| c.cluster.hb_ms = v as u64, &[500.0, 1_000.0, 3_000.0]);
    sweep("failure_prob", |c, v| c.cluster.task_failure_prob = v, &[0.0, 0.05, 0.15]);

    bench_quick("ablation-params/one-pair", |i| {
        let cfg = ExperimentConfig::default();
        let specs = generate(20, WorkloadMix::Mixed, 0.3, 5_000, i as u64);
        black_box(run_pair(&cfg, specs, SchedKind::Capacity));
    });
}
