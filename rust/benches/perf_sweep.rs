//! Perf: parallel sweep scaling — runs/sec for a fixed seed × scheduler ×
//! congested-burst grid as the worker count grows from 1 to all cores.
//!
//! Each cell is an independent deterministic simulation, so the sweep
//! should scale ~linearly until memory bandwidth saturates; the bench
//! asserts the parallel results stay bit-identical to the serial pass
//! while it measures.  Every worker count is timed over `PASSES` repeats
//! and recorded as `wall_ms_mean` ± Student-t 95% CI, so cross-PR
//! comparisons of `BENCH_engine.json` see dispersion, not one sample.
//! The grid definition lives in the library (`expt::sweep::bench_grid`)
//! and its fingerprint is written next to the numbers —
//! `tests/bench_schema.rs` recomputes it and rejects a checked-in file
//! whose numbers were measured on a stale grid.  Updates the `sweep`
//! section of `BENCH_engine.json` (the rest of the file is owned by
//! `perf_throughput`):
//!
//!     cargo bench --bench perf_sweep

use dress::bench_harness::update_bench_json;
use dress::expt::shard::grid_fingerprint;
use dress::expt::sweep::{bench_grid, run_sweep};
use dress::util::json::Json;
use dress::util::stats::Ci95;
use std::time::Instant;

/// Timed repeats per worker count (dispersion for the CI columns).
const PASSES: usize = 3;

/// The checked-in trajectory file at the repo root — anchored via the
/// manifest dir because `cargo bench` runs with cwd = package root
/// (`rust/`), not the workspace root.
const BENCH_JSON: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_engine.json");

fn round2(x: f64) -> f64 {
    (x * 100.0).round() / 100.0
}

fn main() {
    println!("=== perf: parallel sweep scaling (seed x scheduler grid) ===");
    let grid = bench_grid();
    let fingerprint = grid_fingerprint(&grid);
    let total = grid.len();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    // Serial reference pass: the fingerprint every parallel pass must
    // reproduce bit-identically (timed as pass 1 of workers=1).
    let t0 = Instant::now();
    let reference = run_sweep(&grid, 1);
    let serial_first_ms = t0.elapsed().as_secs_f64() * 1e3;

    let mut worker_counts = vec![1usize];
    let mut w = 2;
    while w < cores {
        worker_counts.push(w);
        w *= 2;
    }
    if cores > 1 {
        worker_counts.push(cores);
    }

    let mut serial_mean_ms = serial_first_ms;
    let mut rows = Vec::new();
    for &workers in &worker_counts {
        let mut walls_ms = Vec::with_capacity(PASSES);
        for pass in 0..PASSES {
            if workers == 1 && pass == 0 {
                walls_ms.push(serial_first_ms);
                continue;
            }
            let t0 = Instant::now();
            let results = run_sweep(&grid, workers);
            walls_ms.push(t0.elapsed().as_secs_f64() * 1e3);
            for (a, b) in reference.iter().zip(&results) {
                assert_eq!(a.system.makespan_ms, b.system.makespan_ms, "parallel sweep diverged");
                assert_eq!(a.events, b.events, "parallel sweep diverged");
                assert_eq!(a.delta_history, b.delta_history, "parallel sweep diverged");
                assert_eq!(
                    a.transitions_recorded, b.transitions_recorded,
                    "parallel sweep diverged"
                );
                let (wa, wb): (u64, u64) = (
                    a.jobs.iter().map(|j| j.waiting_ms).sum(),
                    b.jobs.iter().map(|j| j.waiting_ms).sum(),
                );
                assert_eq!(wa, wb, "parallel sweep diverged");
            }
        }
        let ci = Ci95::of(&walls_ms);
        if workers == 1 {
            serial_mean_ms = ci.mean;
        }
        let rps = total as f64 / (ci.mean / 1e3);
        println!(
            "bench sweep-scaling/workers{:<3} {:>7.2} runs/s  ({} runs, {:.1} ± {:.1} ms wall \
             over {PASSES} passes, {:.2}x vs serial)",
            workers,
            rps,
            total,
            ci.mean,
            ci.half,
            serial_mean_ms / ci.mean
        );
        let mut row = Json::obj();
        row.set("workers", Json::Num(workers as f64));
        row.set("runs", Json::Num(total as f64));
        row.set("passes", Json::Num(PASSES as f64));
        row.set("wall_ms_mean", Json::Num(round2(ci.mean)));
        row.set("wall_ms_ci_lo", Json::Num(round2(ci.lo())));
        row.set("wall_ms_ci_hi", Json::Num(round2(ci.hi())));
        row.set("runs_per_sec", Json::Num(round2(rps)));
        row.set("speedup_vs_serial", Json::Num(round2(serial_mean_ms / ci.mean)));
        rows.push(row);
    }

    let mut sweep = Json::obj();
    sweep.set("bench", Json::Str("perf_sweep".into()));
    sweep.set("grid", Json::Str("8 seeds x [capacity, dress] x congested_burst(500, 50)".into()));
    sweep.set("grid_fingerprint", Json::Str(fingerprint.clone()));
    sweep.set("cores", Json::Num(cores as f64));
    sweep.set("trace_sink", Json::Str("counting".into()));
    sweep.set("runs", Json::Arr(rows));
    match update_bench_json(BENCH_JSON, "sweep", sweep) {
        Ok(()) => println!("updated {BENCH_JSON} [sweep] (grid fingerprint {fingerprint})"),
        Err(e) => eprintln!("could not update {BENCH_JSON}: {e}"),
    }
}
