//! Perf: parallel sweep scaling — runs/sec for a fixed seed × scheduler ×
//! congested-burst grid as the worker count grows from 1 to all cores.
//!
//! Each cell is an independent deterministic simulation, so the sweep
//! should scale ~linearly until memory bandwidth saturates; the bench
//! asserts the parallel results stay bit-identical to the serial pass
//! while it measures.  Updates the `sweep` section of `BENCH_engine.json`
//! (the rest of the file is owned by `perf_throughput`):
//!
//!     cargo bench --bench perf_sweep

use dress::bench_harness::update_bench_json;
use dress::config::{ExperimentConfig, SchedKind};
use dress::expt::sweep::{run_sweep, SweepGrid, SweepWorkload};
use dress::sim::EngineOptions;
use dress::util::json::Json;
use std::time::Instant;

const JOBS_PER_RUN: u32 = 500;
const N_SEEDS: u64 = 8;

/// The checked-in trajectory file at the repo root — anchored via the
/// manifest dir because `cargo bench` runs with cwd = package root
/// (`rust/`), not the workspace root.
const BENCH_JSON: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_engine.json");

fn main() {
    println!("=== perf: parallel sweep scaling (seed x scheduler grid) ===");
    let grid = SweepGrid {
        base: ExperimentConfig::default(),
        seeds: (0..N_SEEDS).map(|i| 0xD8E5 + i).collect(),
        scheds: vec![SchedKind::Capacity, SchedKind::Dress],
        workloads: vec![SweepWorkload::CongestedBurst {
            n: JOBS_PER_RUN,
            arrival_mean_ms: 50,
        }],
        opts: EngineOptions::throughput(),
    };
    let total = grid.len();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    // Serial reference pass: both the jobs=1 scaling point and the
    // fingerprint the parallel passes must reproduce bit-identically.
    let t0 = Instant::now();
    let reference = run_sweep(&grid, 1);
    let serial_s = t0.elapsed().as_secs_f64();

    let mut worker_counts = vec![1usize];
    let mut w = 2;
    while w < cores {
        worker_counts.push(w);
        w *= 2;
    }
    if cores > 1 {
        worker_counts.push(cores);
    }

    let mut rows = Vec::new();
    for &workers in &worker_counts {
        let (wall_s, results) = if workers == 1 {
            (serial_s, None)
        } else {
            let t0 = Instant::now();
            let r = run_sweep(&grid, workers);
            (t0.elapsed().as_secs_f64(), Some(r))
        };
        if let Some(results) = results {
            for (a, b) in reference.iter().zip(&results) {
                assert_eq!(a.system.makespan_ms, b.system.makespan_ms, "parallel sweep diverged");
                assert_eq!(a.events, b.events, "parallel sweep diverged");
                assert_eq!(a.delta_history, b.delta_history, "parallel sweep diverged");
                assert_eq!(a.transitions_recorded, b.transitions_recorded, "parallel sweep diverged");
                let (wa, wb): (u64, u64) = (
                    a.jobs.iter().map(|j| j.waiting_ms).sum(),
                    b.jobs.iter().map(|j| j.waiting_ms).sum(),
                );
                assert_eq!(wa, wb, "parallel sweep diverged");
            }
        }
        let rps = total as f64 / wall_s;
        println!(
            "bench sweep-scaling/workers{:<3} {:>7.2} runs/s  ({} runs, {:.2} s wall, {:.2}x vs serial)",
            workers,
            rps,
            total,
            wall_s,
            serial_s / wall_s
        );
        let mut row = Json::obj();
        row.set("workers", Json::Num(workers as f64));
        row.set("runs", Json::Num(total as f64));
        row.set("wall_ms", Json::Num((wall_s * 100_000.0).round() / 100.0));
        row.set("runs_per_sec", Json::Num((rps * 100.0).round() / 100.0));
        row.set("speedup_vs_serial", Json::Num(((serial_s / wall_s) * 100.0).round() / 100.0));
        rows.push(row);
    }

    let mut sweep = Json::obj();
    sweep.set("bench", Json::Str("perf_sweep".into()));
    sweep.set(
        "grid",
        Json::Str(format!(
            "{N_SEEDS} seeds x [capacity, dress] x congested_burst({JOBS_PER_RUN}, 50)"
        )),
    );
    sweep.set("cores", Json::Num(cores as f64));
    sweep.set("trace_sink", Json::Str("counting".into()));
    sweep.set("runs", Json::Arr(rows));
    match update_bench_json(BENCH_JSON, "sweep", sweep) {
        Ok(()) => println!("updated {BENCH_JSON} [sweep]"),
        Err(e) => eprintln!("could not update {BENCH_JSON}: {e}"),
    }
}
