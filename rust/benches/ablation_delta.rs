//! Ablation bench: the two design choices DESIGN.md calls out —
//! dynamic δ (Algorithm 3) and the release estimator (Algorithms 1-2) —
//! each removed in turn, vs the Capacity baseline.

use dress::bench_harness::{bench_quick, black_box};
use dress::expt::{ablation, DressVariant};
use dress::util::stats;

fn main() {
    println!("=== ablation: DRESS design choices (20 mixed jobs vs Capacity) ===");
    println!(
        "{:<14} {:>14} {:>14} {:>14} {:>12}",
        "variant", "small-compl%", "small-wait%", "makespan%", "final-δ"
    );
    for (name, v) in [
        ("full", DressVariant::Full),
        ("static-delta", DressVariant::StaticDelta),
        ("no-estimator", DressVariant::NoEstimator),
    ] {
        // Average over seeds to smooth single-run noise.
        let mut sc = Vec::new();
        let mut sw = Vec::new();
        let mut mk = Vec::new();
        let mut final_delta = 0.0;
        for seed in [42u64, 7, 1337] {
            let pair = ablation(v, seed);
            sc.push(pair.comparison.small_completion_change_pct);
            sw.push(pair.comparison.small_waiting_change_pct);
            mk.push(pair.comparison.makespan_change_pct);
            final_delta = pair
                .dress
                .delta_history
                .last()
                .map(|&(_, d)| d)
                .unwrap_or(f64::NAN);
        }
        println!(
            "{:<14} {:>13.1}% {:>13.1}% {:>13.1}% {:>12.3}",
            name,
            stats::mean(&sc),
            stats::mean(&sw),
            stats::mean(&mk),
            final_delta
        );
    }
    bench_quick("ablation/full-variant-run", |i| {
        black_box(ablation(DressVariant::Full, i as u64 + 1));
    });
}
