//! Bench: regenerate Fig 1 (motivating example) and time it.

use dress::bench_harness::{bench_quick, black_box};
use dress::report::comparison_row;

fn main() {
    println!("=== repro: Fig 1 (motivating example) ===");
    let r = dress::expt::fig1();
    for (claim, measured) in [
        ("FIG1.fcfs-makespan-s", r.fcfs_makespan_s),
        ("FIG1.fcfs-avg-wait-s", r.fcfs_avg_wait_s),
        ("FIG1.rearranged-makespan-s", r.dress_makespan_s),
        ("FIG1.rearranged-avg-wait-s", r.dress_avg_wait_s),
    ] {
        let (row, _) = comparison_row(&dress::expt::paper::claim(claim), measured);
        println!("{row}");
    }
    bench_quick("fig1/full-experiment", |_| {
        black_box(dress::expt::fig1());
    });
}
