//! Criterion-style measurement harness (offline substitute): warmup,
//! adaptive iteration count, and a stats summary per benchmark.  Used by
//! the `rust/benches/*.rs` binaries (`harness = false`).

use crate::util::stats::{self, Summary};
use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    /// Per-iteration wall time in nanoseconds.
    pub summary: Summary,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        self.summary.mean
    }

    /// Human-readable time per iteration.
    pub fn pretty(&self) -> String {
        format!(
            "bench {:<40} {:>12}/iter  (median {:>12}, p95 {:>12}, n={})",
            self.name,
            fmt_ns(self.summary.mean),
            fmt_ns(self.summary.median),
            fmt_ns(self.summary.p95),
            self.iters
        )
    }
}

/// Format nanoseconds with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Measure `f`, auto-scaling iterations to fill `target` wall time
/// (default 1 s via [`bench`]). `f` receives the iteration index.
pub fn bench_with_target(name: &str, target: Duration, mut f: impl FnMut(usize)) -> BenchResult {
    // Warmup: 2 calls (fills caches, triggers lazy init).
    f(0);
    f(1);
    // Estimate a single-iteration cost.
    let t0 = Instant::now();
    f(2);
    let est = t0.elapsed().as_nanos().max(1) as f64;
    let iters = ((target.as_nanos() as f64 / est) as usize).clamp(5, 10_000);

    let mut samples = Vec::with_capacity(iters);
    for i in 0..iters {
        let t = Instant::now();
        f(i + 3);
        samples.push(t.elapsed().as_nanos() as f64);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        summary: stats::Summary::of(&samples),
    }
}

/// Measure with the default 1-second target and print the result.
pub fn bench(name: &str, f: impl FnMut(usize)) -> BenchResult {
    let r = bench_with_target(name, Duration::from_secs(1), f);
    println!("{}", r.pretty());
    r
}

/// Quick variant for expensive end-to-end benches (0.3 s target).
pub fn bench_quick(name: &str, f: impl FnMut(usize)) -> BenchResult {
    let r = bench_with_target(name, Duration::from_millis(300), f);
    println!("{}", r.pretty());
    r
}

/// Prevent the optimizer from discarding a value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Read-modify-write one section of a bench trajectory JSON file
/// (`BENCH_engine.json`): parse the existing file if present, replace
/// `section` with `value`, keep every other key (so `perf_throughput` and
/// `perf_sweep` can own different sections of the same file), and write it
/// back.  A missing or unparseable file starts from an empty object.
pub fn update_bench_json(
    path: &str,
    section: &str,
    value: crate::util::json::Json,
) -> std::io::Result<()> {
    use crate::util::json::Json;
    let mut root = std::fs::read_to_string(path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
        .unwrap_or_else(Json::obj);
    if !matches!(root, Json::Obj(_)) {
        root = Json::obj();
    }
    root.set(section, value);
    std::fs::write(path, root.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut count = 0usize;
        let r = bench_with_target("noop", Duration::from_millis(5), |_| {
            count += 1;
            black_box(count);
        });
        assert!(r.iters >= 5);
        assert_eq!(count, r.iters + 3);
        assert!(r.summary.mean >= 0.0);
        assert!(!r.pretty().is_empty());
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
