//! A small TOML-subset parser sufficient for our config files:
//! `[section]` headers, `key = value` with string / integer / float / bool
//! values, `#` comments, and flat arrays of scalars.  No nested tables,
//! no dotted keys, no datetimes — validated config surface only.

use std::collections::BTreeMap;

/// Parsed scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// `section -> key -> value`; keys before any header land in section "".
pub type Doc = BTreeMap<String, BTreeMap<String, TomlValue>>;

/// Parse a TOML-subset document. Errors carry 1-based line numbers.
pub fn parse(text: &str) -> Result<Doc, String> {
    let mut doc: Doc = BTreeMap::new();
    let mut section = String::new();
    doc.entry(section.clone()).or_default();

    for (ln, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section header", ln + 1))?
                .trim();
            if name.is_empty() {
                return Err(format!("line {}: empty section name", ln + 1));
            }
            section = name.to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| format!("line {}: expected `key = value`", ln + 1))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(format!("line {}: empty key", ln + 1));
        }
        let val = parse_value(line[eq + 1..].trim())
            .map_err(|e| format!("line {}: {e}", ln + 1))?;
        doc.get_mut(&section).unwrap().insert(key.to_string(), val);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("missing value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(Vec::new()));
        }
        let items = split_array_items(inner)?
            .into_iter()
            .map(|it| parse_value(it.trim()))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(TomlValue::Array(items));
    }
    let clean = s.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("unrecognized value `{s}`"))
}

fn split_array_items(s: &str) -> Result<Vec<&str>, String> {
    let mut items = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, ch) in s.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                items.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if in_str {
        return Err("unterminated string in array".into());
    }
    items.push(&s[start..]);
    Ok(items)
}

/// Convenience typed lookups with config-style error messages.
pub fn get_int(doc: &Doc, section: &str, key: &str) -> Option<i64> {
    doc.get(section)?.get(key)?.as_int()
}
pub fn get_float(doc: &Doc, section: &str, key: &str) -> Option<f64> {
    doc.get(section)?.get(key)?.as_float()
}
pub fn get_str<'d>(doc: &'d Doc, section: &str, key: &str) -> Option<&'d str> {
    doc.get(section)?.get(key)?.as_str()
}
pub fn get_bool(doc: &Doc, section: &str, key: &str) -> Option<bool> {
    doc.get(section)?.get(key)?.as_bool()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = parse(
            r#"
# top comment
top = 1
[cluster]
nodes = 5
slots = 8            # trailing comment
hb_ms = 1_000
name = "cloudlab # c220g2"
congested = true
ratio = 0.35
"#,
        )
        .unwrap();
        assert_eq!(get_int(&doc, "", "top"), Some(1));
        assert_eq!(get_int(&doc, "cluster", "nodes"), Some(5));
        assert_eq!(get_int(&doc, "cluster", "hb_ms"), Some(1000));
        assert_eq!(get_str(&doc, "cluster", "name"), Some("cloudlab # c220g2"));
        assert_eq!(get_bool(&doc, "cluster", "congested"), Some(true));
        assert_eq!(get_float(&doc, "cluster", "ratio"), Some(0.35));
        // int readable as float too
        assert_eq!(get_float(&doc, "cluster", "nodes"), Some(5.0));
    }

    #[test]
    fn parses_arrays() {
        let doc = parse("xs = [1, 2, 3]\nys = [\"a\", \"b\"]\nempty = []").unwrap();
        match &doc[""]["xs"] {
            TomlValue::Array(v) => assert_eq!(v.len(), 3),
            other => panic!("{other:?}"),
        }
        match &doc[""]["empty"] {
            TomlValue::Array(v) => assert!(v.is_empty()),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn errors_carry_line_numbers() {
        assert!(parse("[unterminated").unwrap_err().contains("line 1"));
        assert!(parse("\nkey").unwrap_err().contains("line 2"));
        assert!(parse("k = ").unwrap_err().contains("line 1"));
        assert!(parse("k = \"oops").unwrap_err().contains("unterminated"));
        assert!(parse("k = zzz").unwrap_err().contains("unrecognized"));
    }

    #[test]
    fn negative_and_underscore_numbers() {
        let doc = parse("a = -42\nb = 1_000_000\nc = -0.5").unwrap();
        assert_eq!(get_int(&doc, "", "a"), Some(-42));
        assert_eq!(get_int(&doc, "", "b"), Some(1_000_000));
        assert_eq!(get_float(&doc, "", "c"), Some(-0.5));
    }
}
