//! Configuration system: a TOML-subset parser (offline stand-in for
//! `toml`/`serde`) plus the typed experiment schema with validation.

pub mod schema;
pub mod toml;

pub use schema::{
    ClusterConfig, DelayConfig, ExperimentConfig, FederationConfig, RouterKind, SchedConfig,
    SchedKind, WorkloadConfig,
};
pub use toml::{parse, TomlValue};
