//! Configuration system: a TOML-subset parser (offline stand-in for
//! `toml`/`serde`) plus the typed experiment schema with validation.

pub mod schema;
pub mod toml;

pub use schema::{ClusterConfig, DelayConfig, ExperimentConfig, SchedKind, SchedConfig, WorkloadConfig};
pub use toml::{parse, TomlValue};
