//! Typed experiment configuration, loadable from TOML-subset files
//! (`configs/*.toml`) with defaults matching the paper's testbed (§V.A).

use super::toml::{self, Doc};
use crate::sim::fault::FaultPlan;
use crate::util::Time;

/// Scheduler selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedKind {
    Fifo,
    Fair,
    Capacity,
    Dress,
    /// Greedy max-weight-over-configurations baseline (sched/maxweight.rs).
    MaxWeight,
}

impl SchedKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "fifo" => Ok(SchedKind::Fifo),
            "fair" => Ok(SchedKind::Fair),
            "capacity" => Ok(SchedKind::Capacity),
            "dress" => Ok(SchedKind::Dress),
            "maxweight" => Ok(SchedKind::MaxWeight),
            other => {
                Err(format!("unknown scheduler `{other}` (fifo|fair|capacity|dress|maxweight)"))
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SchedKind::Fifo => "fifo",
            SchedKind::Fair => "fair",
            SchedKind::Capacity => "capacity",
            SchedKind::Dress => "dress",
            SchedKind::MaxWeight => "maxweight",
        }
    }
}

/// Container state-transition delay model (medians + multiplicative spread;
/// samples are log-normal, long-tailed like real YARN RPC latencies).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DelayConfig {
    pub new_to_reserved_ms: f64,
    pub reserved_to_allocated_ms: f64,
    pub allocated_to_acquired_ms: f64,
    pub acquired_to_running_ms: f64,
    /// Log-normal sigma shared by all hops.
    pub sigma: f64,
}

impl Default for DelayConfig {
    fn default() -> Self {
        DelayConfig {
            new_to_reserved_ms: 120.0,
            reserved_to_allocated_ms: 180.0,
            allocated_to_acquired_ms: 250.0,
            acquired_to_running_ms: 700.0,
            sigma: 0.45,
        }
    }
}

/// Cluster shape. Paper: 5 nodes, deliberately small to create congestion.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClusterConfig {
    pub nodes: u16,
    pub slots_per_node: u32,
    /// Heartbeat / scheduling-round period.
    pub hb_ms: Time,
    pub delays: DelayConfig,
    /// Probability a Running container fails mid-task (YARN re-attempts
    /// the task; failure injection for robustness tests, default 0).
    pub task_failure_prob: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            nodes: 5,
            slots_per_node: 8,
            hb_ms: 1_000,
            delays: DelayConfig::default(),
            task_failure_prob: 0.0,
        }
    }
}

impl ClusterConfig {
    pub fn total_containers(&self) -> u32 {
        self.nodes as u32 * self.slots_per_node
    }
}

/// Scheduler parameters (paper §V.A.1 values as defaults).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SchedConfig {
    pub kind: SchedKind,
    /// Job-indicator factor θ: demand > θ·A_c at submission => LD.
    pub theta: f64,
    /// Initial reserve ratio δ.
    pub delta0: f64,
    /// Algorithm 1 start threshold t_s (tasks).
    pub ts: u32,
    /// Algorithm 2 completion threshold t_e (tasks, filters heading tasks).
    pub te: u32,
    /// Phase window pw.
    pub pw_ms: Time,
    /// Gang admission: a job starts only when its full demand fits.
    pub gang: bool,
    /// Capacity scheduler queue weights (fraction of cluster per queue).
    pub capacity_queues: [f64; 2],
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            kind: SchedKind::Dress,
            theta: 0.10,
            delta0: 0.10,
            ts: 5,
            te: 5,
            pw_ms: 10_000,
            gang: true,
            capacity_queues: [1.0, 0.0],
        }
    }
}

/// Cross-cell routing policy for federated (multi-cell) runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterKind {
    /// Reference policy: cells in rotation, skipping dead cells.
    RoundRobin,
    /// Route to the alive cell with the least outstanding work.
    LeastLoad,
    /// DRESS classification made topological: SD jobs to one cell group,
    /// LD jobs to the other (docs/FEDERATION.md).
    ByCategory,
}

impl RouterKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "round-robin" => Ok(RouterKind::RoundRobin),
            "least-load" => Ok(RouterKind::LeastLoad),
            "by-category" => Ok(RouterKind::ByCategory),
            other => {
                Err(format!("unknown router `{other}` (round-robin|least-load|by-category)"))
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RouterKind::RoundRobin => "round-robin",
            RouterKind::LeastLoad => "least-load",
            RouterKind::ByCategory => "by-category",
        }
    }
}

/// Federated multi-cell topology.  The default (`cells = 1`) runs the
/// plain single-cell engine; `cells > 1` lock-steps N identical cells on
/// a global clock with cross-cell routing and migration
/// (docs/FEDERATION.md).  Part of the `Debug` representation, so cells,
/// router, threshold and cell-fault plan all enter the sweep-grid
/// fingerprint — federated and single-cell shards refuse to merge.
#[derive(Debug, Clone, PartialEq)]
pub struct FederationConfig {
    /// Number of cells; each is a full copy of `[cluster]`.
    pub cells: u32,
    /// Cross-cell routing policy.
    pub router: RouterKind,
    /// Queue-imbalance migration threshold: at each heartbeat, jobs move
    /// from the longest to the shortest pending queue while the gap
    /// exceeds this many jobs.  0 disables migration.
    pub migrate_threshold: u32,
    /// Cell-level outage plan; same grammar as node fault plans but the
    /// "node" field names a cell index.  A dead cell loses all nodes at
    /// once and its salvageable jobs are re-routed.
    pub cell_faults: FaultPlan,
}

impl Default for FederationConfig {
    fn default() -> Self {
        FederationConfig {
            cells: 1,
            router: RouterKind::RoundRobin,
            migrate_threshold: 4,
            cell_faults: FaultPlan::empty(),
        }
    }
}

/// Workload shape for generated experiments.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadConfig {
    pub jobs: u32,
    /// "mapreduce" | "spark" | "mixed"
    pub platform: String,
    /// Fraction of small-demand jobs targeted by the generator (mixed runs).
    pub small_frac: f64,
    /// Inter-arrival gap (paper: 5 s).
    pub arrival_ms: Time,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            jobs: 20,
            platform: "mixed".into(),
            small_frac: 0.3,
            arrival_ms: 5_000,
            seed: 42,
        }
    }
}

/// Top-level experiment configuration.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ExperimentConfig {
    pub cluster: ClusterConfig,
    pub sched: SchedConfig,
    pub workload: WorkloadConfig,
    /// Node crash/recovery plan (empty by default — no faults).  Part of
    /// the `Debug` representation, so it enters the sweep-grid fingerprint
    /// and shards with different plans refuse to merge.
    pub faults: FaultPlan,
    /// Multi-cell federation topology (default: one cell, plain engine).
    pub federation: FederationConfig,
}

impl ExperimentConfig {
    /// Load from a TOML-subset string; unspecified keys keep defaults.
    pub fn from_toml(text: &str) -> Result<Self, String> {
        let doc = toml::parse(text)?;
        let mut cfg = ExperimentConfig::default();
        cfg.apply(&doc)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn from_file(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {path}: {e}"))?;
        Self::from_toml(&text)
    }

    fn apply(&mut self, doc: &Doc) -> Result<(), String> {
        if let Some(v) = toml::get_int(doc, "cluster", "nodes") {
            self.cluster.nodes = v as u16;
        }
        if let Some(v) = toml::get_int(doc, "cluster", "slots_per_node") {
            self.cluster.slots_per_node = v as u32;
        }
        if let Some(v) = toml::get_int(doc, "cluster", "hb_ms") {
            self.cluster.hb_ms = v as Time;
        }
        if let Some(v) = toml::get_float(doc, "cluster", "delay_sigma") {
            self.cluster.delays.sigma = v;
        }
        if let Some(v) = toml::get_float(doc, "cluster", "acquired_to_running_ms") {
            self.cluster.delays.acquired_to_running_ms = v;
        }
        if let Some(v) = toml::get_float(doc, "cluster", "task_failure_prob") {
            self.cluster.task_failure_prob = v;
        }
        if let Some(s) = toml::get_str(doc, "sched", "kind") {
            self.sched.kind = SchedKind::parse(s)?;
        }
        if let Some(v) = toml::get_float(doc, "sched", "theta") {
            self.sched.theta = v;
        }
        if let Some(v) = toml::get_float(doc, "sched", "delta0") {
            self.sched.delta0 = v;
        }
        if let Some(v) = toml::get_int(doc, "sched", "ts") {
            self.sched.ts = v as u32;
        }
        if let Some(v) = toml::get_int(doc, "sched", "te") {
            self.sched.te = v as u32;
        }
        if let Some(v) = toml::get_int(doc, "sched", "pw_ms") {
            self.sched.pw_ms = v as Time;
        }
        if let Some(v) = toml::get_bool(doc, "sched", "gang") {
            self.sched.gang = v;
        }
        if let Some(v) = toml::get_int(doc, "workload", "jobs") {
            self.workload.jobs = v as u32;
        }
        if let Some(s) = toml::get_str(doc, "workload", "platform") {
            self.workload.platform = s.to_string();
        }
        if let Some(v) = toml::get_float(doc, "workload", "small_frac") {
            self.workload.small_frac = v;
        }
        if let Some(v) = toml::get_int(doc, "workload", "arrival_ms") {
            self.workload.arrival_ms = v as Time;
        }
        if let Some(v) = toml::get_int(doc, "workload", "seed") {
            self.workload.seed = v as u64;
        }
        if let Some(s) = toml::get_str(doc, "faults", "plan") {
            self.faults = FaultPlan::parse(s)?;
        }
        if let Some(v) = toml::get_int(doc, "federation", "cells") {
            self.federation.cells = v as u32;
        }
        if let Some(s) = toml::get_str(doc, "federation", "router") {
            self.federation.router = RouterKind::parse(s)?;
        }
        if let Some(v) = toml::get_int(doc, "federation", "migrate_threshold") {
            self.federation.migrate_threshold = v as u32;
        }
        if let Some(s) = toml::get_str(doc, "federation", "cell_faults") {
            self.federation.cell_faults = FaultPlan::parse(s)?;
        }
        Ok(())
    }

    /// Sanity checks (paper-parameter ranges).
    pub fn validate(&self) -> Result<(), String> {
        if self.cluster.nodes == 0 || self.cluster.slots_per_node == 0 {
            return Err("cluster must have nodes and slots".into());
        }
        if self.cluster.hb_ms == 0 {
            return Err("hb_ms must be > 0".into());
        }
        if !(0.0 < self.sched.theta && self.sched.theta < 1.0) {
            return Err(format!("theta must be in (0,1), got {}", self.sched.theta));
        }
        if !(0.0 < self.sched.delta0 && self.sched.delta0 < 1.0) {
            return Err(format!("delta0 must be in (0,1), got {}", self.sched.delta0));
        }
        if !(0.0..=1.0).contains(&self.workload.small_frac) {
            return Err("small_frac must be in [0,1]".into());
        }
        if !(0.0..0.9).contains(&self.cluster.task_failure_prob) {
            return Err("task_failure_prob must be in [0, 0.9)".into());
        }
        if self.workload.jobs == 0 {
            return Err("workload.jobs must be > 0".into());
        }
        match self.workload.platform.as_str() {
            "mapreduce" | "spark" | "mixed" => {}
            other => return Err(format!("unknown platform `{other}`")),
        }
        // Materialization re-checks node ranges/overlap with stochastic
        // draws included; here it doubles as plan validation.
        self.faults.materialize(self.cluster.nodes, self.workload.seed)?;
        if self.federation.cells == 0 {
            return Err("federation.cells must be >= 1".into());
        }
        if self.federation.cells > u16::MAX as u32 {
            return Err("federation.cells exceeds the cell-index range".into());
        }
        if !self.federation.cell_faults.is_empty() {
            if self.federation.cells < 2 {
                return Err("cell_faults require federation.cells >= 2".into());
            }
            if !self.faults.is_empty() {
                // A node fault firing inside a cell that a cell fault has
                // already killed would double-crash the node; the two
                // plan layers are mutually exclusive.
                return Err("cell_faults cannot be combined with node fault plans".into());
            }
            // Cell plans materialize against the cell count: the plan's
            // "node" field names a cell index.
            self.federation
                .cell_faults
                .materialize(self.federation.cells as u16, self.workload.seed)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = ExperimentConfig::default();
        assert_eq!(c.cluster.nodes, 5);
        assert_eq!(c.sched.theta, 0.10);
        assert_eq!(c.sched.delta0, 0.10);
        assert_eq!(c.sched.ts, 5);
        assert_eq!(c.sched.te, 5);
        assert_eq!(c.sched.pw_ms, 10_000);
        assert_eq!(c.workload.arrival_ms, 5_000);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn toml_overrides() {
        let cfg = ExperimentConfig::from_toml(
            r#"
[cluster]
nodes = 3
slots_per_node = 4
hb_ms = 500
[sched]
kind = "capacity"
theta = 0.2
[workload]
jobs = 8
platform = "spark"
seed = 7
"#,
        )
        .unwrap();
        assert_eq!(cfg.cluster.nodes, 3);
        assert_eq!(cfg.cluster.total_containers(), 12);
        assert_eq!(cfg.sched.kind, SchedKind::Capacity);
        assert_eq!(cfg.sched.theta, 0.2);
        assert_eq!(cfg.workload.jobs, 8);
        assert_eq!(cfg.workload.seed, 7);
    }

    #[test]
    fn rejects_invalid() {
        assert!(ExperimentConfig::from_toml("[sched]\ntheta = 1.5").is_err());
        assert!(ExperimentConfig::from_toml("[sched]\nkind = \"bogus\"").is_err());
        assert!(ExperimentConfig::from_toml("[workload]\njobs = 0").is_err());
        assert!(ExperimentConfig::from_toml("[workload]\nplatform = \"dask\"").is_err());
    }

    #[test]
    fn fault_plan_from_toml() {
        let cfg = ExperimentConfig::from_toml(
            "[faults]\nplan = \"60000:0:30000;120000:1+2:60000\"",
        )
        .unwrap();
        assert_eq!(cfg.faults.fixed.len(), 3);
        assert_eq!(cfg.faults.fixed[0].node, 0);
        // Default is the empty plan.
        assert!(ExperimentConfig::default().faults.is_empty());
        // Plans referencing out-of-range nodes are rejected at validate.
        assert!(ExperimentConfig::from_toml("[faults]\nplan = \"1000:9:500\"").is_err());
        assert!(ExperimentConfig::from_toml("[faults]\nplan = \"garbage\"").is_err());
    }

    #[test]
    fn federation_from_toml() {
        let cfg = ExperimentConfig::from_toml(
            "[federation]\ncells = 3\nrouter = \"by-category\"\nmigrate_threshold = 2\ncell_faults = \"60000:1:30000\"",
        )
        .unwrap();
        assert_eq!(cfg.federation.cells, 3);
        assert_eq!(cfg.federation.router, RouterKind::ByCategory);
        assert_eq!(cfg.federation.migrate_threshold, 2);
        assert_eq!(cfg.federation.cell_faults.fixed.len(), 1);
        // Defaults: single cell, round-robin, no cell faults.
        let d = ExperimentConfig::default();
        assert_eq!(d.federation.cells, 1);
        assert_eq!(d.federation.router, RouterKind::RoundRobin);
        assert!(d.federation.cell_faults.is_empty());
        assert!(d.validate().is_ok());
        // Rejections: zero cells, cell faults without federation, cell
        // faults naming out-of-range cells, mixing fault layers.
        assert!(ExperimentConfig::from_toml("[federation]\ncells = 0").is_err());
        assert!(
            ExperimentConfig::from_toml("[federation]\ncell_faults = \"1000:0:500\"").is_err()
        );
        assert!(ExperimentConfig::from_toml(
            "[federation]\ncells = 2\ncell_faults = \"1000:5:500\""
        )
        .is_err());
        assert!(ExperimentConfig::from_toml(
            "[federation]\ncells = 2\ncell_faults = \"1000:0:500\"\n[faults]\nplan = \"1000:0:500\""
        )
        .is_err());
    }

    #[test]
    fn router_kind_roundtrip() {
        for r in ["round-robin", "least-load", "by-category"] {
            assert_eq!(RouterKind::parse(r).unwrap().name(), r);
        }
        assert!(RouterKind::parse("hash").is_err());
    }

    #[test]
    fn sched_kind_roundtrip() {
        for k in ["fifo", "fair", "capacity", "dress", "maxweight"] {
            assert_eq!(SchedKind::parse(k).unwrap().name(), k);
        }
    }
}
