//! Admission front: the probe → reserve → commit lifecycle
//! (docs/ADMISSION.md).
//!
//! Production multi-user platforms do not drop arriving work straight
//! into the scheduler queue; they *admit* it.  [`AdmissionCtl`] models
//! the three-level lifecycle on top of the shadow layer:
//!
//! 1. [`AdmissionCtl::probe`] — a read-only what-if: shadow-replay the
//!    arrival against a [`SchedSnapshot`] and report whether capacity is
//!    available *right now*.  Takes `&self`; purity is structural and
//!    property-tested (tests/properties.rs).
//! 2. [`AdmissionCtl::reserve`] — hold capacity behind a ticket with a
//!    commit timeout.  The expiry rides the same exact `(time, seq)`
//!    event-queue discipline as the simulator — a *private*
//!    [`EventQueue`] carrying [`Event::ReservationExpire`] — so expiry
//!    order is deterministic and happens at exactly the timeout tick.
//! 3. [`AdmissionCtl::commit`] — convert the held reservation into
//!    admitted capacity (released back when the work retires).
//!
//! Accounting invariant, property-tested over random interleavings:
//! `available() + reserved() + committed() == total()` at every step
//! (with `available` saturating at 0 while an outage has `total` below
//! the held capacity), and a reservation that reaches its timeout
//! un-committed returns its capacity at exactly `expires_at`.
//!
//! The disabled path ([`AdmissionConfig::default`]) is inert by
//! construction: `reserve` refuses, the private queue never sees a push,
//! and no RNG exists anywhere in this module — mirroring the
//! empty-fault-plan and `tune_delta`-off zero-overhead guarantees.

use crate::sched::shadow::{self, SchedSnapshot, ShadowEvent, ShadowScore, ShadowWindow};
use crate::sim::{Event, EventQueue, QueueKind};
use crate::util::Time;

/// Ticket handle returned by [`AdmissionCtl::reserve`].
pub type TicketId = u32;

/// Admission-front knobs.  The default is **disabled** — and the
/// disabled front is inert: no reservations, no events, no allocations.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdmissionConfig {
    /// Master switch; off means every `reserve` is refused and the
    /// lifecycle collapses to the legacy submit-directly path.
    pub enabled: bool,
    /// How long a reservation holds capacity before expiring back.
    pub commit_timeout_ms: Time,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig { enabled: false, commit_timeout_ms: 10_000 }
    }
}

impl AdmissionConfig {
    /// An enabled front with the given commit timeout (clamped ≥ 1 ms so
    /// an expiry can never collide with its own reserve tick).
    pub fn enabled(commit_timeout_ms: Time) -> Self {
        AdmissionConfig { enabled: true, commit_timeout_ms: commit_timeout_ms.max(1) }
    }
}

/// Lifecycle state of one reservation ticket.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TicketState {
    /// Capacity held, commit timeout pending.
    Reserved,
    /// Committed before the timeout: capacity stays held until
    /// [`AdmissionCtl::release`].
    Committed,
    /// The timeout fired first: capacity returned at `expires_at`.
    Expired,
    /// Committed capacity returned (the admitted work retired).
    Released,
}

#[derive(Debug, Clone, Copy)]
struct Ticket {
    demand: u32,
    state: TicketState,
    expires_at: Time,
}

/// Outcome of a read-only probe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProbeDecision {
    /// Capacity is available to reserve right now.
    Admit,
    /// The front is holding too much; retry after a release/expiry.
    Defer,
}

/// What a probe reports: the decision, the shadow what-if score for the
/// hypothetical arrival, and the capacity the front could still reserve.
#[derive(Debug, Clone, Copy)]
pub struct ProbeReport {
    pub decision: ProbeDecision,
    pub score: ShadowScore,
    pub available: u32,
}

/// The admission front.  Owns its own event queue (reservation expiries
/// never enter the simulator's queue — the engine's arm for
/// [`Event::ReservationExpire`] is inert by design) and all capacity
/// accounting.
#[derive(Debug)]
pub struct AdmissionCtl {
    cfg: AdmissionConfig,
    /// Live capacity ceiling; tracks `ClusterView::total` under outages
    /// via [`Self::set_total`].
    total: u32,
    /// Capacity held by un-expired, un-committed reservations.
    reserved: u32,
    /// Capacity held by committed (admitted, not yet released) tickets.
    committed: u32,
    /// Cumulative capacity returned through expiry (diagnostics).
    expired_capacity: u64,
    /// Expiry events ever scheduled — the inertness counter the golden
    /// layer asserts stays 0 while the front is disabled.
    expiries_scheduled: u64,
    tickets: Vec<Ticket>,
    /// Private `(time, seq)` queue of [`Event::ReservationExpire`].
    queue: EventQueue,
    /// Admission clock: the latest `now` any mutating call has seen.
    now: Time,
}

impl AdmissionCtl {
    pub fn new(cfg: AdmissionConfig, total: u32) -> Self {
        AdmissionCtl {
            cfg,
            total,
            reserved: 0,
            committed: 0,
            expired_capacity: 0,
            expiries_scheduled: 0,
            tickets: Vec::new(),
            queue: EventQueue::with_kind(QueueKind::Calendar),
            now: 0,
        }
    }

    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    pub fn total(&self) -> u32 {
        self.total
    }

    pub fn reserved(&self) -> u32 {
        self.reserved
    }

    pub fn committed(&self) -> u32 {
        self.committed
    }

    /// Capacity the front could still reserve.  Saturating: an outage
    /// can pull `total` below what is already held, and the deficit must
    /// read as 0 availability, not wrap.
    pub fn available(&self) -> u32 {
        self.total.saturating_sub(self.reserved + self.committed)
    }

    /// Cumulative capacity returned through expiries.
    pub fn expired_capacity(&self) -> u64 {
        self.expired_capacity
    }

    /// Expiry events ever pushed to the private queue (0 while disabled).
    pub fn expiries_scheduled(&self) -> u64 {
        self.expiries_scheduled
    }

    pub fn ticket_state(&self, id: TicketId) -> Option<TicketState> {
        self.tickets.get(id as usize).map(|t| t.state)
    }

    pub fn ticket_expires_at(&self, id: TicketId) -> Option<Time> {
        self.tickets.get(id as usize).map(|t| t.expires_at)
    }

    /// Track the live capacity ceiling (degraded during an outage,
    /// restored on recovery).  Held reservations are *not* revoked — the
    /// deficit surfaces as zero availability until expiries/releases
    /// drain it, exactly like YARN riding out a node loss.
    pub fn set_total(&mut self, total: u32) {
        self.total = total;
    }

    /// Read-only what-if (level 1): would a `demand`-container arrival
    /// be admitted now, and how would the cluster fare?  `&self` — no
    /// ticket, no held capacity, no event, no RNG; N probes leave every
    /// fingerprint bit untouched (tests/properties.rs).
    pub fn probe(&self, snap: &SchedSnapshot, demand: u32) -> ProbeReport {
        let mut window = ShadowWindow::new(1);
        let next_id = snap.jobs.iter().map(|j| j.id).max().unwrap_or(0) + 1;
        window.push(ShadowEvent::Submit { job: next_id, demand, at: snap.now });
        let score = shadow::replay(snap, &window, snap.delta, shadow::REPLAY_TICKS);
        let available = self.available();
        let decision = if demand > 0 && demand <= available {
            ProbeDecision::Admit
        } else {
            ProbeDecision::Defer
        };
        ProbeReport { decision, score, available }
    }

    /// Hold `demand` containers behind a commit timeout (level 2).
    /// Returns `None` when the front is disabled, the demand is 0, or
    /// not enough capacity is free to hold.
    pub fn reserve(&mut self, now: Time, demand: u32) -> Option<TicketId> {
        self.advance(now);
        if !self.cfg.enabled || demand == 0 || demand > self.available() {
            return None;
        }
        let id = self.tickets.len() as TicketId;
        let expires_at = now + self.cfg.commit_timeout_ms;
        self.tickets.push(Ticket { demand, state: TicketState::Reserved, expires_at });
        self.reserved += demand;
        self.queue.push(expires_at, Event::ReservationExpire(id));
        self.expiries_scheduled += 1;
        Some(id)
    }

    /// Convert a held reservation into admitted capacity (level 3).
    /// Fails (`false`) if the ticket already expired — the timeout is
    /// applied first, so a commit arriving at `expires_at` or later
    /// always loses to the expiry.
    pub fn commit(&mut self, now: Time, id: TicketId) -> bool {
        self.advance(now);
        let Some(t) = self.tickets.get_mut(id as usize) else { return false };
        if t.state != TicketState::Reserved {
            return false;
        }
        t.state = TicketState::Committed;
        self.reserved -= t.demand;
        self.committed += t.demand;
        true
    }

    /// Return a committed ticket's capacity (the admitted work retired).
    pub fn release(&mut self, now: Time, id: TicketId) -> bool {
        self.advance(now);
        let Some(t) = self.tickets.get_mut(id as usize) else { return false };
        if t.state != TicketState::Committed {
            return false;
        }
        t.state = TicketState::Released;
        self.committed -= t.demand;
        true
    }

    /// Apply every expiry due at or before `now`, in exact `(time, seq)`
    /// order.  Expiry of an already-committed ticket is a stale event
    /// (the queue cannot remove entries — same discipline as the
    /// engine's dead-container events) and is ignored.
    pub fn advance(&mut self, now: Time) {
        self.now = self.now.max(now);
        while self.queue.peek_time().is_some_and(|t| t <= now) {
            let (_, ev) = self.queue.pop().expect("peeked");
            let Event::ReservationExpire(id) = ev else {
                unreachable!("admission queue carries only expiries");
            };
            let t = &mut self.tickets[id as usize];
            if t.state == TicketState::Reserved {
                t.state = TicketState::Expired;
                self.reserved -= t.demand;
                self.expired_capacity += t.demand as u64;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::JobView;

    fn snap(free: u32, total: u32) -> SchedSnapshot {
        let jobs: Vec<JobView> = Vec::new();
        SchedSnapshot::of_view(0, free, total, &jobs, 0.10, 0.10)
    }

    fn conserved(c: &AdmissionCtl) {
        assert_eq!(
            c.available() + c.reserved() + c.committed(),
            c.total(),
            "capacity accounting broke"
        );
    }

    #[test]
    fn default_front_is_disabled_and_inert() {
        let mut c = AdmissionCtl::new(AdmissionConfig::default(), 8);
        assert!(!c.config().enabled);
        assert_eq!(c.reserve(0, 2), None, "disabled front must refuse");
        assert_eq!(c.expiries_scheduled(), 0, "disabled front pushed an event");
        // Probing the disabled front is still a pure read.
        let before = format!("{c:?}");
        let s = snap(8, 8);
        for d in [1, 4, 9] {
            c.probe(&s, d);
        }
        assert_eq!(format!("{c:?}"), before, "probe mutated the front");
        conserved(&c);
    }

    #[test]
    fn probe_admits_within_available_and_defers_beyond() {
        let mut c = AdmissionCtl::new(AdmissionConfig::enabled(5_000), 8);
        let s = snap(8, 8);
        assert_eq!(c.probe(&s, 4).decision, ProbeDecision::Admit);
        assert_eq!(c.probe(&s, 9).decision, ProbeDecision::Defer);
        assert_eq!(c.probe(&s, 0).decision, ProbeDecision::Defer);
        let t = c.reserve(0, 6).unwrap();
        assert_eq!(c.probe(&s, 4).decision, ProbeDecision::Defer, "held capacity ignored");
        assert_eq!(c.probe(&s, 2).decision, ProbeDecision::Admit);
        assert!(c.commit(100, t));
        conserved(&c);
    }

    #[test]
    fn commit_before_timeout_holds_capacity_until_release() {
        let mut c = AdmissionCtl::new(AdmissionConfig::enabled(5_000), 8);
        let t = c.reserve(1_000, 3).unwrap();
        assert_eq!(c.reserved(), 3);
        conserved(&c);
        assert!(c.commit(2_000, t));
        assert_eq!((c.reserved(), c.committed()), (0, 3));
        conserved(&c);
        // The stale expiry event at 6 000 must not return committed capacity.
        c.advance(10_000);
        assert_eq!(c.committed(), 3);
        assert_eq!(c.ticket_state(t), Some(TicketState::Committed));
        conserved(&c);
        assert!(c.release(11_000, t));
        assert_eq!(c.available(), 8);
        assert!(!c.release(11_000, t), "double release must fail");
        conserved(&c);
    }

    #[test]
    fn expiry_returns_capacity_at_exactly_the_timeout_tick() {
        let mut c = AdmissionCtl::new(AdmissionConfig::enabled(5_000), 8);
        let t = c.reserve(1_000, 3).unwrap();
        assert_eq!(c.ticket_expires_at(t), Some(6_000));
        c.advance(5_999);
        assert_eq!(c.reserved(), 3, "expired one tick early");
        c.advance(6_000);
        assert_eq!(c.reserved(), 0, "capacity not back at the timeout tick");
        assert_eq!(c.ticket_state(t), Some(TicketState::Expired));
        assert_eq!(c.expired_capacity(), 3);
        assert!(!c.commit(6_000, t), "commit at the timeout tick loses to expiry");
        conserved(&c);
    }

    #[test]
    fn degraded_capacity_saturates_availability() {
        let mut c = AdmissionCtl::new(AdmissionConfig::enabled(5_000), 8);
        let t = c.reserve(0, 6).unwrap();
        c.set_total(4); // outage: total drops below held capacity
        assert_eq!(c.available(), 0, "deficit must read as zero, not wrap");
        assert_eq!(c.reserve(100, 1), None);
        assert!(c.commit(200, t));
        c.set_total(8); // recovery
        assert_eq!(c.available(), 2);
        conserved(&c);
    }

    #[test]
    fn reserve_respects_live_capacity() {
        let mut c = AdmissionCtl::new(AdmissionConfig::enabled(1_000), 4);
        assert!(c.reserve(0, 5).is_none(), "over-capacity reserve accepted");
        let a = c.reserve(0, 3).unwrap();
        assert!(c.reserve(0, 2).is_none(), "second reserve overlaps the first");
        let b = c.reserve(0, 1).unwrap();
        assert_ne!(a, b);
        conserved(&c);
        // Both expire; everything comes back.
        c.advance(1_000);
        assert_eq!((c.reserved(), c.available()), (0, 4));
        assert_eq!(c.expired_capacity(), 4);
        conserved(&c);
    }
}
