//! Live mode: the end-to-end driver proving all three layers compose.
//!
//! Unlike the discrete-event simulator (virtual time), live mode runs in
//! *wall-clock* time with a worker-thread pool in which every task executes
//! a real PJRT computation (the AOT-compiled PageRank power iteration from
//! `artifacts/taskwork.hlo.txt`).  The scheduler — including DRESS with its
//! estimator — makes decisions on real heartbeats; Python is nowhere on
//! this path.
//!
//! Task "duration" maps to compute *work units* (one unit = 8 power-
//! iteration steps on a 64x64 operator), so congestion, waiting and phase
//! barriers are all real.

pub mod admission;

pub use admission::{
    AdmissionConfig, AdmissionCtl, ProbeDecision, ProbeReport, TicketId, TicketState,
};

use crate::bail;
use crate::cluster::{ContainerState, Transition};
use crate::config::SchedConfig;
use crate::jobs::{Demand, JobId, JobSpec};
use crate::metrics::JobMetrics;
use crate::runtime::{Runtime, TaskWork};
use crate::sched::shadow::SchedSnapshot;
use crate::sched::{ClusterView, JobView, Scheduler};
use crate::util::error::Result;
use crate::util::Time;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Live-mode parameters.
#[derive(Debug, Clone)]
pub struct LiveConfig {
    /// Worker threads == container slots.
    pub workers: usize,
    /// Heartbeat period (real time).
    pub hb: Duration,
    /// Work units per simulated task second (compute intensity knob).
    pub units_per_sec: f64,
    /// Hard wall-clock cap.
    pub max_wall: Duration,
    /// Per-attempt task deadline.  A dispatched attempt that has not
    /// reported back within `task_deadline << attempt` (multiplicative
    /// backoff) is presumed lost — dead worker, dropped completion — and
    /// its slot is reclaimed and the task requeued.
    pub task_deadline: Duration,
    /// Attempts beyond the first before a task is abandoned and its job
    /// reported in [`LiveReport::unfinished`].
    pub max_retries: u32,
    /// Fault injection: this many workers die silently on their first
    /// task — they consume the message, report nothing, and exit.  The
    /// deadline/requeue machinery must absorb both the lost task and the
    /// permanently smaller pool.  0 in production.
    pub simulate_worker_deaths: u32,
    /// Admission front (probe → reserve → commit; see live/admission.rs
    /// and docs/ADMISSION.md).  Disabled by default, and the disabled
    /// front is inert — the run is identical to the pre-admission driver.
    pub admission: AdmissionConfig,
}

impl Default for LiveConfig {
    fn default() -> Self {
        LiveConfig {
            workers: 8,
            hb: Duration::from_millis(100),
            units_per_sec: 0.25,
            max_wall: Duration::from_secs(300),
            task_deadline: Duration::from_secs(30),
            max_retries: 2,
            simulate_worker_deaths: 0,
            admission: AdmissionConfig::default(),
        }
    }
}

/// Outcome of a live run.
#[derive(Debug, Clone)]
pub struct LiveReport {
    pub scheduler: String,
    /// Metrics for jobs that *finished*; abandoned jobs are not here.
    pub jobs: Vec<JobMetrics>,
    pub makespan: Duration,
    pub tasks_run: usize,
    /// Sum of all task checksums — proof the PJRT compute really happened.
    pub checksum: f64,
    /// Jobs that did not finish: a task exhausted its retries (or the
    /// whole worker pool died).  Empty on a healthy run.
    pub unfinished: Vec<JobId>,
    /// Task attempts requeued after a deadline expiry or failed attempt.
    pub requeues: usize,
    /// Admission probes performed (0 with the front disabled).
    pub admission_probes: usize,
    /// Capacity returned through reservation expiry (0 when disabled, or
    /// when every reservation committed in time).
    pub admission_expired_capacity: u64,
}

struct TaskMsg {
    job: JobId,
    phase: usize,
    task: usize,
    units: u32,
    seed: u64,
    attempt: u32,
}

struct DoneMsg {
    job: JobId,
    phase: usize,
    task: usize,
    /// Echo of [`TaskMsg::attempt`]: completions from superseded attempts
    /// (the deadline path already requeued the task) are discarded instead
    /// of corrupting the state machine.
    attempt: u32,
    /// False when the compute failed or panicked; triggers a retry.
    ok: bool,
    started: Instant,
    finished: Instant,
    checksum: f32,
}

const PENDING: u8 = 0;
const RUNNING: u8 = 1;
const DONE: u8 = 2;
const ABANDONED: u8 = 3;

#[derive(Clone)]
struct LiveTask {
    units: u32,
    state: u8, // PENDING / RUNNING / DONE / ABANDONED
    /// Attempt counter; incremented on every requeue.  The running
    /// attempt's number rides along in TaskMsg/DoneMsg for stale-completion
    /// detection.
    attempt: u32,
    /// When the current attempt was dispatched (deadline anchor).
    running_since: Option<Time>,
}

struct LiveJob {
    spec: JobSpec,
    cur_phase: usize,
    tasks: Vec<Vec<LiveTask>>,
    submitted: bool,
    first_start: Option<Time>,
    finish: Option<Time>,
    occupied: u32,
    /// A task exhausted its retries: the job can never finish.  Failed
    /// jobs read as `finished` to schedulers and stop dispatching.
    failed: bool,
    /// Admission reservation (None with the front disabled, or before
    /// the job passes probe → reserve).
    ticket: Option<TicketId>,
}

impl LiveJob {
    fn pending_tasks(&self) -> u32 {
        if self.failed || self.cur_phase >= self.tasks.len() {
            return 0;
        }
        self.tasks[self.cur_phase].iter().filter(|t| t.state == PENDING).count() as u32
    }
    fn advance(&mut self) {
        while self.cur_phase < self.tasks.len()
            && self.tasks[self.cur_phase].iter().all(|t| t.state == DONE)
        {
            self.cur_phase += 1;
        }
    }
    fn all_done(&self) -> bool {
        self.tasks.iter().all(|p| p.iter().all(|t| t.state == DONE))
    }
    /// Finished or permanently failed — nothing left to drive.
    fn terminal(&self) -> bool {
        self.finish.is_some() || self.failed
    }
}

/// Deadline for a given attempt: base doubled per retry (backoff gives a
/// slow-but-alive worker a growing grace window before we burn a retry).
fn attempt_deadline_ms(base: Duration, attempt: u32) -> Time {
    (base.as_millis() as Time).saturating_mul(1 << attempt.min(16))
}

/// Run `specs` under `sched` with real PJRT task compute.
pub fn run_live(
    cfg: &LiveConfig,
    sched_cfg: &SchedConfig,
    specs: Vec<JobSpec>,
    mut sched: Box<dyn Scheduler>,
    taskwork_path: &str,
) -> Result<LiveReport> {
    // Sanity-check the artifact on the main thread before spawning workers.
    {
        let rt = Runtime::cpu()?;
        TaskWork::load(&rt, taskwork_path)?;
    }

    let (task_tx, task_rx) = mpsc::channel::<TaskMsg>();
    let task_rx = Arc::new(Mutex::new(task_rx));
    let (done_tx, done_rx) = mpsc::channel::<DoneMsg>();

    // Worker pool. PJRT handles are not Send, so each worker owns its own
    // client + compiled executable (compiled once per thread, reused for
    // every task — still zero Python on the request path).  A worker that
    // fails to initialize, or panics mid-task, must never take the run
    // down with it: init failures exit the thread (the rest of the pool
    // absorbs the load), task panics are caught and reported as failed
    // attempts, and a silently-dead worker is covered by the driver's
    // per-task deadline.
    let mut handles = Vec::new();
    for widx in 0..cfg.workers.max(1) {
        let rx = Arc::clone(&task_rx);
        let tx = done_tx.clone();
        let path = taskwork_path.to_string();
        let lethal = (widx as u32) < cfg.simulate_worker_deaths;
        handles.push(std::thread::spawn(move || {
            let Ok(rt) = Runtime::cpu() else { return };
            let Ok(work) = TaskWork::load(&rt, &path) else { return };
            loop {
                let msg = { rx.lock().unwrap().recv() };
                let Ok(m) = msg else { break };
                if lethal {
                    // Fault injection: die holding the task, reporting
                    // nothing — exactly what a crashed machine looks like.
                    return;
                }
                let started = Instant::now();
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    work.run_units(m.seed, m.units)
                }));
                let (ok, checksum) = match out {
                    Ok(Ok(c)) if c.is_finite() => (true, c),
                    _ => (false, f32::NAN),
                };
                let _ = tx.send(DoneMsg {
                    job: m.job,
                    phase: m.phase,
                    task: m.task,
                    attempt: m.attempt,
                    ok,
                    started,
                    finished: Instant::now(),
                    checksum,
                });
            }
        }));
    }
    drop(done_tx);
    // Drop the driver's receiver handle so `task_tx.send` starts failing
    // the moment every worker has exited — the observable all-dead signal.
    drop(task_rx);

    let epoch = Instant::now();
    let now_ms = |t: Instant| t.duration_since(epoch).as_millis() as Time;

    let mut jobs: Vec<LiveJob> = specs
        .into_iter()
        .map(|spec| {
            let tasks = spec
                .phases
                .iter()
                .map(|p| {
                    p.tasks
                        .iter()
                        .map(|t| LiveTask {
                            units: ((t.duration_ms as f64 / 1000.0 * cfg.units_per_sec).ceil()
                                as u32)
                                .max(1),
                            state: PENDING,
                            attempt: 0,
                            running_since: None,
                        })
                        .collect()
                })
                .collect();
            LiveJob {
                spec,
                cur_phase: 0,
                tasks,
                submitted: false,
                first_start: None,
                finish: None,
                occupied: 0,
                failed: false,
                ticket: None,
            }
        })
        .collect();

    let total = cfg.workers as u32;
    let mut ctl = AdmissionCtl::new(cfg.admission, total);
    let mut admission_probes = 0usize;
    let mut tasks_run = 0usize;
    let mut checksum = 0f64;
    let mut requeues = 0usize;
    let mut transitions: Vec<Transition> = Vec::new();
    let mut cid: u32 = 0;
    let mut pool_dead = false;

    loop {
        let wall = epoch.elapsed();
        if wall > cfg.max_wall {
            bail!("live run exceeded {:?}", cfg.max_wall);
        }
        let now = wall.as_millis() as Time;

        // Drain completions.
        while let Ok(d) = done_rx.try_recv() {
            let Some(ji) = jobs.iter().position(|j| j.spec.id == d.job) else { continue };
            let t = &mut jobs[ji].tasks[d.phase][d.task];
            if t.state != RUNNING || t.attempt != d.attempt {
                // Stale: this attempt was already presumed lost and
                // requeued (its occupied slot was reclaimed then).
                continue;
            }
            if !d.ok {
                // Failed/panicked attempt: reclaim the slot and retry
                // (or abandon once the retry budget is spent).
                t.running_since = None;
                let abandon = t.attempt >= cfg.max_retries;
                if abandon {
                    t.state = ABANDONED;
                } else {
                    t.state = PENDING;
                    t.attempt += 1;
                    requeues += 1;
                }
                jobs[ji].occupied -= 1;
                if abandon {
                    jobs[ji].failed = true;
                }
                continue;
            }
            t.state = DONE;
            t.running_since = None;
            jobs[ji].occupied -= 1;
            let start_ms = now_ms(d.started);
            if jobs[ji].first_start.is_none() {
                jobs[ji].first_start = Some(start_ms);
            }
            jobs[ji].advance();
            if jobs[ji].all_done() && jobs[ji].finish.is_none() {
                jobs[ji].finish = Some(now_ms(d.finished));
            }
            transitions.push(Transition {
                time: now_ms(d.finished),
                container: 0,
                job: d.job,
                task: d.task,
                to: ContainerState::Completed,
            });
            tasks_run += 1;
            checksum += d.checksum as f64;
        }

        // Deadline scan: an attempt running past its backed-off deadline
        // was lost — a dead worker, a dropped completion — so reclaim the
        // slot and requeue.  Should the attempt report after all, the
        // echoed attempt number marks it stale above.
        for j in jobs.iter_mut() {
            if j.terminal() || j.cur_phase >= j.tasks.len() {
                continue;
            }
            let mut failed = false;
            let mut reclaimed = 0u32;
            let phase = j.cur_phase;
            for t in j.tasks[phase].iter_mut() {
                if t.state != RUNNING {
                    continue;
                }
                let Some(since) = t.running_since else { continue };
                if now.saturating_sub(since) <= attempt_deadline_ms(cfg.task_deadline, t.attempt)
                {
                    continue;
                }
                t.running_since = None;
                reclaimed += 1;
                if t.attempt >= cfg.max_retries {
                    t.state = ABANDONED;
                    failed = true;
                } else {
                    t.state = PENDING;
                    t.attempt += 1;
                    requeues += 1;
                }
            }
            j.occupied -= reclaimed;
            if failed {
                j.failed = true;
            }
        }

        // Submissions (arrival times are wall-clock offsets).  With the
        // admission front enabled, an arriving job must pass probe →
        // reserve before the scheduler sees it; the reservation commits
        // at the job's first dispatch and releases when it retires.  A
        // job whose probe defers (or whose reservation expired before it
        // dispatched) simply re-probes on the next heartbeat.
        if ctl.config().enabled {
            ctl.advance(now);
            // Release retired jobs first so their capacity is available
            // to arrivals on this very heartbeat.
            for j in jobs.iter() {
                if j.terminal() {
                    if let Some(t) = j.ticket {
                        if ctl.ticket_state(t) == Some(TicketState::Committed) {
                            ctl.release(now, t);
                        }
                    }
                }
            }
            let occupied_total: u32 = jobs.iter().map(|j| j.occupied).sum();
            let admitted: Vec<JobView> = jobs
                .iter()
                .filter(|j| j.submitted)
                .map(|j| JobView {
                    id: j.spec.id,
                    demand: j.spec.demand.min_each(Demand::scalar(total)),
                    submit_ms: j.spec.submit_ms,
                    started: j.first_start.is_some() || j.occupied > 0,
                    finished: j.terminal(),
                    pending_tasks: j.pending_tasks(),
                    occupied: j.occupied,
                })
                .collect();
            let snap = SchedSnapshot::of_view(
                now,
                total.saturating_sub(occupied_total),
                total,
                &admitted,
                sched_cfg.delta0,
                sched_cfg.theta,
            );
            for j in jobs.iter_mut() {
                if j.submitted || j.spec.submit_ms > now || j.terminal() {
                    continue;
                }
                let demand = j.spec.demand.cpu.min(total).max(1);
                admission_probes += 1;
                if ctl.probe(&snap, demand).decision != ProbeDecision::Admit {
                    continue;
                }
                if let Some(t) = ctl.reserve(now, demand) {
                    j.ticket = Some(t);
                    j.submitted = true;
                }
            }
        } else {
            for j in jobs.iter_mut() {
                if !j.submitted && j.spec.submit_ms <= now {
                    j.submitted = true;
                }
            }
        }

        if pool_dead {
            // Every worker is gone: nothing pending can ever run again.
            for j in jobs.iter_mut() {
                if !j.terminal() {
                    j.failed = true;
                }
            }
        }
        if jobs.iter().all(|j| j.terminal()) {
            break;
        }

        // Heartbeat: build view, schedule, dispatch.
        let occupied_total: u32 = jobs.iter().map(|j| j.occupied).sum();
        let view_jobs: Vec<JobView> = jobs
            .iter()
            .filter(|j| j.submitted)
            .map(|j| JobView {
                id: j.spec.id,
                demand: j.spec.demand.min_each(Demand::scalar(total)),
                submit_ms: j.spec.submit_ms,
                started: j.first_start.is_some() || j.occupied > 0,
                finished: j.terminal(),
                pending_tasks: j.pending_tasks(),
                occupied: j.occupied,
            })
            .collect();
        // Live workers have one memory unit per slot; held containers debit
        // their per-container footprint (exactly 1 for uniform demands, so
        // the mem axis mirrors the slot axis on scalar workloads).
        let mem_occupied: u32 = jobs
            .iter()
            .map(|j| j.occupied * j.spec.demand.mem_per_container().max(1))
            .sum();
        let view = ClusterView {
            now,
            free: total.saturating_sub(occupied_total),
            total,
            free_mem: total.saturating_sub(mem_occupied),
            total_mem: total,
            jobs: &view_jobs,
            transitions: &transitions,
        };
        let allocs = sched.schedule(&view);
        transitions.clear();
        let mut free = total.saturating_sub(occupied_total);
        'dispatch: for a in allocs {
            let Some(ji) = jobs.iter().position(|j| j.spec.id == a.job) else { continue };
            if jobs[ji].terminal() {
                continue;
            }
            for _ in 0..a.n.min(free) {
                let phase = jobs[ji].cur_phase;
                if phase >= jobs[ji].tasks.len() {
                    break;
                }
                let Some(ti) =
                    jobs[ji].tasks[phase].iter().position(|t| t.state == PENDING)
                else {
                    break;
                };
                // Send before mutating: if the whole pool is gone the task
                // stays PENDING (nothing to undo) and the run winds down
                // through the pool-dead path instead of panicking.
                let sent = task_tx.send(TaskMsg {
                    job: a.job,
                    phase,
                    task: ti,
                    units: jobs[ji].tasks[phase][ti].units,
                    seed: (a.job as u64) << 16 | ti as u64,
                    attempt: jobs[ji].tasks[phase][ti].attempt,
                });
                if sent.is_err() {
                    pool_dead = true;
                    break 'dispatch;
                }
                jobs[ji].tasks[phase][ti].state = RUNNING;
                jobs[ji].tasks[phase][ti].running_since = Some(now);
                jobs[ji].occupied += 1;
                // First dispatch commits the admission reservation (a
                // no-op for already-committed or expired tickets, and
                // for the disabled front where no ticket exists).
                if let Some(t) = jobs[ji].ticket {
                    if ctl.ticket_state(t) == Some(TicketState::Reserved) {
                        ctl.commit(now, t);
                    }
                }
                free -= 1;
                cid += 1;
                transitions.push(Transition {
                    time: now,
                    container: cid,
                    job: a.job,
                    task: ti,
                    to: ContainerState::Running,
                });
            }
        }

        std::thread::sleep(cfg.hb);
    }

    drop(task_tx);
    for h in handles {
        let _ = h.join();
    }

    // Metrics only for jobs that actually finished; a job that never
    // started (all attempts lost) or never finished must not panic the
    // report, and wall-clock jitter must not underflow the subtractions.
    let job_metrics: Vec<JobMetrics> = jobs
        .iter()
        .filter_map(|j| {
            let (first, finish) = (j.first_start?, j.finish?);
            let waiting = first.saturating_sub(j.spec.submit_ms);
            let completion = finish.saturating_sub(j.spec.submit_ms);
            Some(JobMetrics {
                id: j.spec.id,
                demand: j.spec.demand.cpu,
                submit_ms: j.spec.submit_ms,
                waiting_ms: waiting,
                completion_ms: completion,
                execution_ms: completion.saturating_sub(waiting),
            })
        })
        .collect();
    let unfinished: Vec<JobId> =
        jobs.iter().filter(|j| j.finish.is_none()).map(|j| j.spec.id).collect();

    Ok(LiveReport {
        scheduler: sched.name().to_string(),
        jobs: job_metrics,
        makespan: epoch.elapsed(),
        tasks_run,
        checksum,
        unfinished,
        requeues,
        admission_probes,
        admission_expired_capacity: ctl.expired_capacity(),
    })
}

#[cfg(test)]
mod tests {
    // Live-mode integration (needs artifacts + threads) is exercised in
    // rust/tests/live_integration.rs and examples/e2e_cluster.rs.
    use super::*;

    #[test]
    fn live_config_defaults_sane() {
        let c = LiveConfig::default();
        assert!(c.workers > 0);
        assert!(c.hb < Duration::from_secs(1));
        assert!(c.task_deadline > c.hb, "deadline shorter than a heartbeat would thrash");
        assert!(c.max_retries >= 1);
        assert_eq!(c.simulate_worker_deaths, 0, "fault injection must be off by default");
        assert!(!c.admission.enabled, "admission front must be off by default");
    }

    #[test]
    fn deadline_backoff_doubles_and_never_overflows() {
        let base = Duration::from_secs(30);
        assert_eq!(attempt_deadline_ms(base, 0), 30_000);
        assert_eq!(attempt_deadline_ms(base, 1), 60_000);
        assert_eq!(attempt_deadline_ms(base, 3), 240_000);
        // The shift is capped, so absurd attempt counts stay finite.
        assert_eq!(attempt_deadline_ms(base, 64), attempt_deadline_ms(base, 16));
    }

    #[test]
    fn failed_job_reports_no_pending_tasks() {
        let mut j = LiveJob {
            spec: JobSpec {
                id: 1,
                name: "t".into(),
                platform: crate::jobs::Platform::MapReduce,
                submit_ms: 0,
                demand: Demand::scalar(2),
                phases: vec![],
            },
            cur_phase: 0,
            tasks: vec![vec![
                LiveTask { units: 1, state: PENDING, attempt: 0, running_since: None },
                LiveTask { units: 1, state: ABANDONED, attempt: 3, running_since: None },
            ]],
            submitted: true,
            first_start: None,
            finish: None,
            occupied: 0,
            failed: true,
            ticket: None,
        };
        assert_eq!(j.pending_tasks(), 0, "failed jobs must not advertise work");
        assert!(j.terminal());
        assert!(!j.all_done());
        j.failed = false;
        assert_eq!(j.pending_tasks(), 1);
    }
}
