//! Live mode: the end-to-end driver proving all three layers compose.
//!
//! Unlike the discrete-event simulator (virtual time), live mode runs in
//! *wall-clock* time with a worker-thread pool in which every task executes
//! a real PJRT computation (the AOT-compiled PageRank power iteration from
//! `artifacts/taskwork.hlo.txt`).  The scheduler — including DRESS with its
//! estimator — makes decisions on real heartbeats; Python is nowhere on
//! this path.
//!
//! Task "duration" maps to compute *work units* (one unit = 8 power-
//! iteration steps on a 64x64 operator), so congestion, waiting and phase
//! barriers are all real.

use crate::bail;
use crate::cluster::{ContainerState, Transition};
use crate::config::SchedConfig;
use crate::jobs::{JobId, JobSpec};
use crate::metrics::JobMetrics;
use crate::runtime::{Runtime, TaskWork};
use crate::sched::{ClusterView, JobView, Scheduler};
use crate::util::error::Result;
use crate::util::Time;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Live-mode parameters.
#[derive(Debug, Clone)]
pub struct LiveConfig {
    /// Worker threads == container slots.
    pub workers: usize,
    /// Heartbeat period (real time).
    pub hb: Duration,
    /// Work units per simulated task second (compute intensity knob).
    pub units_per_sec: f64,
    /// Hard wall-clock cap.
    pub max_wall: Duration,
}

impl Default for LiveConfig {
    fn default() -> Self {
        LiveConfig {
            workers: 8,
            hb: Duration::from_millis(100),
            units_per_sec: 0.25,
            max_wall: Duration::from_secs(300),
        }
    }
}

/// Outcome of a live run.
#[derive(Debug, Clone)]
pub struct LiveReport {
    pub scheduler: String,
    pub jobs: Vec<JobMetrics>,
    pub makespan: Duration,
    pub tasks_run: usize,
    /// Sum of all task checksums — proof the PJRT compute really happened.
    pub checksum: f64,
}

struct TaskMsg {
    job: JobId,
    phase: usize,
    task: usize,
    units: u32,
    seed: u64,
}

struct DoneMsg {
    job: JobId,
    phase: usize,
    task: usize,
    started: Instant,
    finished: Instant,
    checksum: f32,
}

#[derive(Clone)]
struct LiveTask {
    units: u32,
    state: u8, // 0 pending, 1 running, 2 done
}

struct LiveJob {
    spec: JobSpec,
    cur_phase: usize,
    tasks: Vec<Vec<LiveTask>>,
    submitted: bool,
    first_start: Option<Time>,
    finish: Option<Time>,
    occupied: u32,
}

impl LiveJob {
    fn pending_tasks(&self) -> u32 {
        if self.cur_phase >= self.tasks.len() {
            return 0;
        }
        self.tasks[self.cur_phase].iter().filter(|t| t.state == 0).count() as u32
    }
    fn advance(&mut self) {
        while self.cur_phase < self.tasks.len()
            && self.tasks[self.cur_phase].iter().all(|t| t.state == 2)
        {
            self.cur_phase += 1;
        }
    }
    fn all_done(&self) -> bool {
        self.tasks.iter().all(|p| p.iter().all(|t| t.state == 2))
    }
}

/// Run `specs` under `sched` with real PJRT task compute.
pub fn run_live(
    cfg: &LiveConfig,
    sched_cfg: &SchedConfig,
    specs: Vec<JobSpec>,
    mut sched: Box<dyn Scheduler>,
    taskwork_path: &str,
) -> Result<LiveReport> {
    let _ = sched_cfg;
    // Sanity-check the artifact on the main thread before spawning workers.
    {
        let rt = Runtime::cpu()?;
        TaskWork::load(&rt, taskwork_path)?;
    }

    let (task_tx, task_rx) = mpsc::channel::<TaskMsg>();
    let task_rx = Arc::new(Mutex::new(task_rx));
    let (done_tx, done_rx) = mpsc::channel::<DoneMsg>();

    // Worker pool. PJRT handles are not Send, so each worker owns its own
    // client + compiled executable (compiled once per thread, reused for
    // every task — still zero Python on the request path).
    let mut handles = Vec::new();
    for _ in 0..cfg.workers.max(1) {
        let rx = Arc::clone(&task_rx);
        let tx = done_tx.clone();
        let path = taskwork_path.to_string();
        handles.push(std::thread::spawn(move || {
            let rt = Runtime::cpu().expect("worker PJRT client");
            let work = TaskWork::load(&rt, &path).expect("worker taskwork load");
            loop {
                let msg = { rx.lock().unwrap().recv() };
                let Ok(m) = msg else { break };
                let started = Instant::now();
                let checksum = work.run_units(m.seed, m.units).unwrap_or(f32::NAN);
                let _ = tx.send(DoneMsg {
                    job: m.job,
                    phase: m.phase,
                    task: m.task,
                    started,
                    finished: Instant::now(),
                    checksum,
                });
            }
        }));
    }
    drop(done_tx);

    let epoch = Instant::now();
    let now_ms = |t: Instant| t.duration_since(epoch).as_millis() as Time;

    let mut jobs: Vec<LiveJob> = specs
        .into_iter()
        .map(|spec| {
            let tasks = spec
                .phases
                .iter()
                .map(|p| {
                    p.tasks
                        .iter()
                        .map(|t| LiveTask {
                            units: ((t.duration_ms as f64 / 1000.0 * cfg.units_per_sec).ceil()
                                as u32)
                                .max(1),
                            state: 0,
                        })
                        .collect()
                })
                .collect();
            LiveJob {
                spec,
                cur_phase: 0,
                tasks,
                submitted: false,
                first_start: None,
                finish: None,
                occupied: 0,
            }
        })
        .collect();

    let total = cfg.workers as u32;
    let mut tasks_run = 0usize;
    let mut checksum = 0f64;
    let mut transitions: Vec<Transition> = Vec::new();
    let mut cid: u32 = 0;

    loop {
        let wall = epoch.elapsed();
        if wall > cfg.max_wall {
            bail!("live run exceeded {:?}", cfg.max_wall);
        }
        let now = wall.as_millis() as Time;

        // Drain completions.
        while let Ok(d) = done_rx.try_recv() {
            let ji = jobs.iter().position(|j| j.spec.id == d.job).unwrap();
            jobs[ji].tasks[d.phase][d.task].state = 2;
            jobs[ji].occupied -= 1;
            let start_ms = now_ms(d.started);
            if jobs[ji].first_start.is_none() {
                jobs[ji].first_start = Some(start_ms);
            }
            jobs[ji].advance();
            if jobs[ji].all_done() && jobs[ji].finish.is_none() {
                jobs[ji].finish = Some(now_ms(d.finished));
            }
            transitions.push(Transition {
                time: now_ms(d.finished),
                container: 0,
                job: d.job,
                task: d.task,
                to: ContainerState::Completed,
            });
            tasks_run += 1;
            checksum += d.checksum as f64;
        }

        // Submissions (arrival times are wall-clock offsets).
        for j in jobs.iter_mut() {
            if !j.submitted && j.spec.submit_ms <= now {
                j.submitted = true;
            }
        }

        if jobs.iter().all(|j| j.finish.is_some()) {
            break;
        }

        // Heartbeat: build view, schedule, dispatch.
        let occupied_total: u32 = jobs.iter().map(|j| j.occupied).sum();
        let view_jobs: Vec<JobView> = jobs
            .iter()
            .filter(|j| j.submitted)
            .map(|j| JobView {
                id: j.spec.id,
                demand: j.spec.demand.min(total),
                submit_ms: j.spec.submit_ms,
                started: j.first_start.is_some() || j.occupied > 0,
                finished: j.finish.is_some(),
                pending_tasks: j.pending_tasks(),
                occupied: j.occupied,
            })
            .collect();
        let view = ClusterView {
            now,
            free: total.saturating_sub(occupied_total),
            total,
            jobs: &view_jobs,
            transitions: &transitions,
        };
        let allocs = sched.schedule(&view);
        transitions.clear();
        let mut free = total.saturating_sub(occupied_total);
        for a in allocs {
            let ji = jobs.iter().position(|j| j.spec.id == a.job).unwrap();
            for _ in 0..a.n.min(free) {
                let phase = jobs[ji].cur_phase;
                if phase >= jobs[ji].tasks.len() {
                    break;
                }
                let Some(ti) = jobs[ji].tasks[phase].iter().position(|t| t.state == 0) else {
                    break;
                };
                jobs[ji].tasks[phase][ti].state = 1;
                jobs[ji].occupied += 1;
                free -= 1;
                cid += 1;
                transitions.push(Transition {
                    time: now,
                    container: cid,
                    job: a.job,
                    task: ti,
                    to: ContainerState::Running,
                });
                task_tx
                    .send(TaskMsg {
                        job: a.job,
                        phase,
                        task: ti,
                        units: jobs[ji].tasks[phase][ti].units,
                        seed: (a.job as u64) << 16 | ti as u64,
                    })
                    .expect("worker pool alive");
            }
        }

        std::thread::sleep(cfg.hb);
    }

    drop(task_tx);
    for h in handles {
        let _ = h.join();
    }

    let job_metrics: Vec<JobMetrics> = jobs
        .iter()
        .map(|j| {
            let waiting = j.first_start.unwrap().saturating_sub(j.spec.submit_ms);
            let completion = j.finish.unwrap().saturating_sub(j.spec.submit_ms);
            JobMetrics {
                id: j.spec.id,
                demand: j.spec.demand,
                submit_ms: j.spec.submit_ms,
                waiting_ms: waiting,
                completion_ms: completion,
                execution_ms: completion - waiting,
            }
        })
        .collect();

    Ok(LiveReport {
        scheduler: sched.name().to_string(),
        jobs: job_metrics,
        makespan: epoch.elapsed(),
        tasks_run,
        checksum,
    })
}

#[cfg(test)]
mod tests {
    // Live-mode integration (needs artifacts + threads) is exercised in
    // rust/tests/live_integration.rs and examples/e2e_cluster.rs.
    use super::*;

    #[test]
    fn live_config_defaults_sane() {
        let c = LiveConfig::default();
        assert!(c.workers > 0);
        assert!(c.hb < Duration::from_secs(1));
    }
}
