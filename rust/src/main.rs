//! `dress` binary — Layer-3 coordinator CLI.

fn main() {
    let args = match dress::cli::Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    };
    std::process::exit(dress::cli::run_cli(&args));
}
