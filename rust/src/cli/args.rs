//! Minimal argument parser: `dress <subcommand> [positional] [--flag value]
//! [--switch]`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from raw args (without argv[0]).
    pub fn parse(raw: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    return Err("empty flag `--`".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    out.flags.insert(name.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args, String> {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&raw)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{name}: {e}")),
        }
    }

    pub fn flag_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{name}: {e}")),
        }
    }

    pub fn flag_str<'s>(&'s self, name: &str, default: &'s str) -> &'s str {
        self.flag(name).unwrap_or(default)
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        let raw: Vec<String> = s.split_whitespace().map(|x| x.to_string()).collect();
        Args::parse(&raw).unwrap()
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse("repro fig6 extra");
        assert_eq!(a.subcommand.as_deref(), Some("repro"));
        assert_eq!(a.positional, vec!["fig6", "extra"]);
    }

    #[test]
    fn flags_with_values_and_equals() {
        let a = parse("run --sched dress --jobs=20 --seed 7");
        assert_eq!(a.flag("sched"), Some("dress"));
        assert_eq!(a.flag_u64("jobs", 0).unwrap(), 20);
        assert_eq!(a.flag_u64("seed", 0).unwrap(), 7);
        assert_eq!(a.flag_u64("missing", 9).unwrap(), 9);
    }

    #[test]
    fn switches() {
        let a = parse("run --verbose --sched fair");
        assert!(a.switch("verbose"));
        assert!(!a.switch("quiet"));
        assert_eq!(a.flag("sched"), Some("fair"));
    }

    #[test]
    fn trailing_switch() {
        let a = parse("bench --quick");
        assert!(a.switch("quick"));
    }

    #[test]
    fn bad_numeric_flag_errors() {
        let a = parse("run --jobs abc");
        assert!(a.flag_u64("jobs", 0).is_err());
    }
}
