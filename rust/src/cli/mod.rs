//! Command-line interface (offline substitute for `clap`): subcommand +
//! `--key value` flag parsing, and the command implementations.

pub mod args;
pub mod commands;

pub use args::Args;
pub use commands::run_cli;
