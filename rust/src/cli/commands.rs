//! CLI subcommands: `run`, `repro`, `trace`, `live`, `bench`.

use super::args::Args;
use crate::config::{ExperimentConfig, SchedKind};
use crate::expt;
use crate::jobs::Platform;
use crate::metrics::SchedulerSummary;
use crate::report::{self, comparison_row};
use crate::sim::engine::run_experiment;
use crate::workload::{generate, Benchmark, WorkloadMix};

const USAGE: &str = "\
dress — Dynamic RESource-reservation Scheme (paper reproduction)

USAGE:
  dress run   [--config file.toml] [--sched fifo|fair|capacity|dress|maxweight]
              [--jobs N] [--platform mapreduce|spark|mixed]
              [--small-frac F] [--seed S] [--csv out-prefix]
              [--metric-sink full|counting|ring:N|decimate:K]
              [--fault-plan SPEC] [--trace in.trace] [--export-trace out.trace]
              [--tune-delta] [--tune-every K] [--shadow-window W]
              [--cells N] [--router by-category|least-load|round-robin]
              [--migrate-threshold K] [--cell-faults SPEC]
  dress compare [--jobs N] [--platform mapreduce|spark|mixed] [--seed S]
  dress repro <fig1|fig2|fig3|fig4|fig6|fig7|fig8|fig9|fig10|fig11|fig12|fig13|table2|all>
              [--seed S]
  dress trace <wordcount|pagerank-mr|pagerank-spark> [--seed S]
  dress live  [--jobs N] [--workers W] [--sched dress|capacity] [--seed S]
              [--simulate-deaths K] [--admission] [--commit-timeout-ms T]
  dress sweep [--seeds K] [--seed S] [--jobs W | --workers W] [--njobs N]
              [--platform mapreduce|spark|mixed|burst|burst-vec|burst-vec-jitter]
              [--small-frac F] [--trace in.trace]
              [--metric-sink full|counting|ring:N|decimate:K]
              [--fault-plan SPEC] [--tune-delta] [--tune-every K]
              [--shadow-window W] [--cells N] [--router POLICY]
              [--migrate-threshold K] [--cell-faults SPEC]
              [--paper] [--shard i/N]
              [--out shard.json] [--report report.txt] [--csv out-prefix]
  dress sweep-merge <shard.json...> [--partial] [--report report.txt]
              [--csv out-prefix]
  dress bench

`run` simulates one workload under one of the five schedulers (FIFO,
Fair, Capacity, DRESS, MaxWeight), all of which schedule full vector
(cpu x mem) demands.  `sweep` fans a K-seed x 5-scheduler grid across
W worker threads (--jobs 0 = all cores; results are bit-identical to
--jobs 1) with counting trace sinks (O(active) memory).  --platform
burst-vec draws stochastic vector (cpu x mem) demands, and
burst-vec-jitter adds per-task memory jitter on top (a separate preset
so burst-vec runs stay bit-stable); --trace FILE replays a recorded
trace instead of a synthetic preset (the trace text is part of the grid
fingerprint, so trace and synthetic shards refuse to merge).
--paper instead sweeps the
DRESS-vs-Capacity pairs behind Figs 7/9 + Table II and reports each
claim as mean ± 95% CI over seeds, judged on the CI bound.
--metric-sink bounds what the per-tick utilization/δ streams retain
(summary statistics are exact under every policy; the flag is part of
the grid fingerprint, so all shards of a partition must agree on it).
--shard i/N runs only grid cells with index % N == i and writes them to
a JSON shard file (distribute N shards across machines); `sweep-merge`
validates the shards' grid fingerprints, reassembles the full grid and
emits the identical report a single-process sweep would print
(--report writes the deterministic part to a file for byte comparison).
`sweep-merge --partial` accepts an incomplete shard set: it prints a
per-shard coverage report (which grid cells are present/missing) and
renders the report over the surviving cells only.

--fault-plan injects deterministic node crashes (see docs/ROBUSTNESS.md):
segments joined by `;` — `T:N:D` crashes node N at T ms for D ms,
`T:N1+N2:D` is a correlated multi-node outage, and
`mtbf=U,mttr=R,until=H` adds a seeded stochastic crash/recovery process
(isolated RNG stream: `none`/empty leaves every run bit-identical).
The plan is part of the sweep-grid fingerprint.

--tune-delta turns on the online shadow δ auto-tuner (DRESS only — see
docs/ADMISSION.md): the scheduler replays its recent submit/complete
window against candidate δ values every few heartbeats and adopts the
winner, clamped to the reserve band.  --tune-every sets the re-tune
cadence in heartbeats and --shadow-window the replay-window capacity
in events; both default to the historical hard-wired values and both
are part of the sweep-grid fingerprint.  Deterministic given the seed.
`dress live --admission` fronts arriving jobs with the probe → reserve
(commit timeout) → commit lifecycle; --commit-timeout-ms sets the
reservation expiry.

--cells N > 1 federates the run across N lock-stepped simulation cells
(see docs/FEDERATION.md): --router picks the deterministic routing
policy (by-category classifies jobs SD/LD the DRESS way and pins each
class to its own cell group; least-load routes to the cell with the
least outstanding work; round-robin is the reference), and
--migrate-threshold K migrates queued jobs off a cell whenever its
pending queue exceeds the least-loaded cell's by more than K (0
disables rebalancing).  --cell-faults takes the same `T:N:D` grammar
as --fault-plan with *cell indices* in the node field and kills whole
cells: their unfinished jobs are salvaged and re-routed.  A 1-cell
federation is bit-identical to a plain run; cells and router are part
of the sweep-grid fingerprint, so federated and single-cell shards
refuse to merge.
";

/// Entry point used by `main.rs`; returns a process exit code.
pub fn run_cli(args: &Args) -> i32 {
    match dispatch(args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!("{USAGE}");
            1
        }
    }
}

fn dispatch(args: &Args) -> Result<(), String> {
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(args),
        Some("compare") => cmd_compare(args),
        Some("repro") => cmd_repro(args),
        Some("trace") => cmd_trace(args),
        Some("live") => cmd_live(args),
        Some("sweep") => cmd_sweep(args),
        Some("sweep-merge") => cmd_sweep_merge(args),
        Some("bench") => cmd_bench(),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => Err(format!("unknown subcommand `{other}`")),
    }
}

fn load_config(args: &Args) -> Result<ExperimentConfig, String> {
    let mut cfg = match args.flag("config") {
        Some(path) => ExperimentConfig::from_file(path)?,
        None => ExperimentConfig::default(),
    };
    if let Some(s) = args.flag("sched") {
        cfg.sched.kind = SchedKind::parse(s)?;
    }
    cfg.workload.jobs = args.flag_u64("jobs", cfg.workload.jobs as u64)? as u32;
    cfg.workload.seed = args.flag_u64("seed", cfg.workload.seed)?;
    cfg.workload.small_frac = args.flag_f64("small-frac", cfg.workload.small_frac)?;
    if let Some(p) = args.flag("platform") {
        cfg.workload.platform = p.to_string();
    }
    if let Some(s) = args.flag("fault-plan") {
        cfg.faults = crate::sim::FaultPlan::parse(s)?;
    }
    apply_federation_flags(args, &mut cfg)?;
    cfg.validate()?;
    Ok(cfg)
}

/// Fold the federation flags into `cfg.federation` (shared by `run` and
/// `sweep`; validation happens in `ExperimentConfig::validate`).
fn apply_federation_flags(args: &Args, cfg: &mut ExperimentConfig) -> Result<(), String> {
    cfg.federation.cells = args.flag_u64("cells", cfg.federation.cells as u64)? as u32;
    if let Some(s) = args.flag("router") {
        cfg.federation.router = crate::config::RouterKind::parse(s)?;
    }
    cfg.federation.migrate_threshold =
        args.flag_u64("migrate-threshold", cfg.federation.migrate_threshold as u64)? as u32;
    if let Some(s) = args.flag("cell-faults") {
        cfg.federation.cell_faults = crate::sim::FaultPlan::parse(s)?;
    }
    Ok(())
}

/// Fold the δ-tuner cadence flags into `opts` (shared by `run` and
/// `sweep`; both knobs are part of the sweep-grid fingerprint).
fn apply_tuner_flags(args: &Args, opts: &mut crate::sim::EngineOptions) -> Result<(), String> {
    opts.tune_delta = opts.tune_delta || args.switch("tune-delta");
    opts.tune_every = args.flag_u64("tune-every", opts.tune_every as u64)? as u32;
    if opts.tune_every == 0 {
        return Err("--tune-every must be >= 1 heartbeat".into());
    }
    opts.shadow_window = args.flag_u64("shadow-window", opts.shadow_window as u64)? as usize;
    if opts.shadow_window == 0 {
        return Err("--shadow-window must be >= 1 event".into());
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<(), String> {
    let cfg = load_config(args)?;
    let specs = match args.flag("trace") {
        Some(path) => {
            let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
            crate::workload::from_trace(&text)?
        }
        None => {
            let mix = WorkloadMix::parse(&cfg.workload.platform)?;
            generate(
                cfg.workload.jobs,
                mix,
                cfg.workload.small_frac,
                cfg.workload.arrival_ms,
                cfg.workload.seed,
            )
        }
    };
    if let Some(path) = args.flag("export-trace") {
        std::fs::write(path, crate::workload::to_trace(&specs))
            .map_err(|e| format!("write {path}: {e}"))?;
        println!("wrote workload trace to {path}");
    }
    println!(
        "running {} jobs ({}) under `{}` on {}x{} containers, seed {}",
        specs.len(),
        cfg.workload.platform,
        cfg.sched.kind.name(),
        cfg.cluster.nodes,
        cfg.cluster.slots_per_node,
        cfg.workload.seed
    );
    let mut opts = crate::sim::EngineOptions::default();
    if let Some(sink) = args.flag("metric-sink") {
        opts.metrics = crate::sim::MetricSinkKind::parse(sink)?;
    }
    apply_tuner_flags(args, &mut opts)?;
    let res = crate::sim::run_experiment_with(&cfg, specs, opts);
    let header = ["Job", "Demand", "Waiting (s)", "Completion (s)"];
    let rows: Vec<Vec<String>> = res
        .jobs
        .iter()
        .map(|j| {
            vec![
                format!("J{}", j.id),
                j.demand.to_string(),
                format!("{:.1}", j.waiting_ms as f64 / 1000.0),
                format!("{:.1}", j.completion_ms as f64 / 1000.0),
            ]
        })
        .collect();
    println!("{}", report::render_table(&header, &rows));
    let summary = SchedulerSummary::of(&res.scheduler, &res.system);
    println!("{}", report::table2(&[summary]));
    let slow = crate::metrics::slowdowns(&res.jobs);
    let (small, large) = crate::metrics::by_class(&res.jobs, 4);
    println!(
        "fairness (Jain over slowdowns): {:.3} | small n={} avgC {:.1}s | large n={} avgC {:.1}s",
        crate::metrics::jain_index(&slow),
        small.n,
        small.avg_completion_s,
        large.n,
        large.avg_completion_s
    );
    print!("{}", report::fig_utilization("cluster utilization", &res.util_history, &res.util));
    if res.delta_recorded > 0 {
        // min/max/mean always come from the exact online accumulator —
        // under ring/decimating retention the retained subset would
        // understate the trajectory; the sparkline (when samples were
        // kept) shows whatever the sink retained.
        let spark = if res.delta_history.is_empty() {
            String::new()
        } else {
            let ds: Vec<f64> = res.delta_history.iter().map(|&(_, d)| d).collect();
            format!("{}  ", crate::util::ascii_plot::sparkline(&ds))
        };
        println!(
            "δ trajectory: {spark}{} samples (retained {})  min {:.2}, max {:.2}, \
             time-weighted mean {:.2}, final {:.2}",
            res.delta_recorded,
            res.delta_history.len(),
            res.delta.min,
            res.delta.max,
            res.delta.mean(),
            res.delta.last
        );
    }
    if !res.outages.is_empty() {
        println!(
            "faults: {} outage(s) | {} attempt(s) killed | {:.1}s work lost to crashes \
             ({:.1}s wasted overall) | goodput {:.3}",
            res.outages.len(),
            res.lost_attempts,
            res.lost_work_ms as f64 / 1000.0,
            res.wasted_work_ms as f64 / 1000.0,
            res.goodput()
        );
        for o in &res.outages {
            let ttr = match o.time_to_recover_ms() {
                Some(ms) => format!("time-to-recover {:.1}s", ms as f64 / 1000.0),
                None => "unrecovered at run end".into(),
            };
            println!(
                "  node {} down at {:.1}s for {:.1}s: killed {} attempt(s), lost {:.1}s, {ttr}",
                o.node,
                o.at_ms as f64 / 1000.0,
                o.down_ms as f64 / 1000.0,
                o.killed,
                o.lost_work_ms as f64 / 1000.0,
            );
        }
    }
    print!("{}", report::federation_summary(cfg.federation.router.name(), &res));
    if let Some(base) = args.flag("csv") {
        for (suffix, text) in [
            ("jobs", report::jobs_csv(&res)),
            ("trace", report::trace_csv(&res)),
            ("delta", report::delta_csv(&res)),
            ("util", report::util_csv(&res)),
        ] {
            let path = format!("{base}.{suffix}.csv");
            std::fs::write(&path, text).map_err(|e| format!("write {path}: {e}"))?;
            println!("wrote {path}");
        }
    }
    Ok(())
}

/// Run all five schedulers (plus the multi-category DRESS extension) on
/// one identical workload and print Table-II rows + fairness.
fn cmd_compare(args: &Args) -> Result<(), String> {
    let mut cfg = load_config(args)?;
    let mix = WorkloadMix::parse(&cfg.workload.platform)?;
    let specs = generate(
        cfg.workload.jobs,
        mix,
        cfg.workload.small_frac,
        cfg.workload.arrival_ms,
        cfg.workload.seed,
    );
    println!(
        "comparing schedulers on {} {} jobs (seed {}, {} containers)\n",
        specs.len(),
        cfg.workload.platform,
        cfg.workload.seed,
        cfg.cluster.total_containers()
    );
    let mut rows = Vec::new();
    let mut fairness = Vec::new();
    for kind in [
        SchedKind::Fifo,
        SchedKind::Fair,
        SchedKind::Capacity,
        SchedKind::Dress,
        SchedKind::MaxWeight,
    ] {
        cfg.sched.kind = kind;
        let res = run_experiment(&cfg, specs.clone());
        fairness.push((kind.name().to_string(), crate::metrics::jain_index(&crate::metrics::slowdowns(&res.jobs))));
        rows.push(SchedulerSummary::of(kind.name(), &res.system));
    }
    // The paper's multi-category extension as a fifth row.
    let multi = crate::sched::dress::MultiDress::new(vec![0.1, 0.4], cfg.cluster.total_containers());
    let res = crate::sim::Engine::new(cfg.clone(), specs, Box::new(multi)).run();
    fairness.push(("multi-dress".into(), crate::metrics::jain_index(&crate::metrics::slowdowns(&res.jobs))));
    rows.push(SchedulerSummary::of("multi-dress", &res.system));

    println!("{}", report::table2(&rows));
    println!("Jain fairness over per-job slowdowns:");
    for (name, j) in fairness {
        println!("  {name:<12} {j:.3}");
    }
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<(), String> {
    let what = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let seed = args.flag_u64("seed", 42)?;
    let mut all_ok = true;
    let mut check = |row: (String, bool)| {
        println!("{}", row.0);
        all_ok &= row.1;
    };

    let wants = |id: &str| what == "all" || what == id;

    if wants("fig1") {
        let r = expt::fig1();
        println!("Fig 1 — motivating example (6 containers, 4 jobs):");
        check(comparison_row(&expt::paper::claim("FIG1.fcfs-makespan-s"), r.fcfs_makespan_s));
        check(comparison_row(&expt::paper::claim("FIG1.fcfs-avg-wait-s"), r.fcfs_avg_wait_s));
        check(comparison_row(&expt::paper::claim("FIG1.rearranged-makespan-s"), r.dress_makespan_s));
        check(comparison_row(&expt::paper::claim("FIG1.rearranged-avg-wait-s"), r.dress_avg_wait_s));
    }
    if wants("fig2") || wants("fig3") || wants("fig4") {
        for (id, bench, platform, title) in [
            ("fig2", Benchmark::WordCount, Platform::MapReduce, "Fig 2 — WordCount on YARN (starting variation)"),
            ("fig3", Benchmark::PageRank, Platform::MapReduce, "Fig 3 — PageRank MR (heading tasks)"),
            ("fig4", Benchmark::PageRank, Platform::Spark, "Fig 4 — PageRank Spark (trailing tasks)"),
        ] {
            if !wants(id) {
                continue;
            }
            let r = expt::trace_benchmark(bench, platform, seed);
            println!("{}", report::fig_trace(title, &r.trace.job_tasks(1)));
        }
    }
    if wants("fig6") || wants("fig7") || wants("table2") {
        let pair = expt::spark20(seed);
        if wants("fig6") {
            println!("{}", report::fig_waiting_bars("Fig 6 — waiting, 20 Spark jobs", &pair.dress, &pair.baseline));
            check(comparison_row(
                &expt::paper::claim("FIG6.small-waiting-change-pct"),
                pair.comparison.small_waiting_change_pct,
            ));
        }
        if wants("fig7") {
            println!("{}", report::fig_completion_bars("Fig 7 — completion, 20 Spark jobs", &pair.dress, &pair.baseline));
            check(comparison_row(
                &expt::paper::claim("FIG7.small-completion-change-pct"),
                pair.comparison.small_completion_change_pct,
            ));
            check(comparison_row(
                &expt::paper::claim("FIG7.large-penalized-mean-pct"),
                pair.comparison.large_penalized_mean_pct,
            ));
        }
        if wants("table2") {
            let rows = vec![
                SchedulerSummary::of("capacity", &pair.baseline.system),
                SchedulerSummary::of("dress", &pair.dress.system),
            ];
            println!("Table II — overall system performance (Spark-on-YARN run):");
            println!("{}", report::table2(&rows));
            check(comparison_row(
                &expt::paper::claim("TAB2.makespan-change-pct"),
                pair.comparison.makespan_change_pct,
            ));
        }
    }
    if wants("fig8") || wants("fig9") {
        let pair = expt::mr20(seed);
        if wants("fig8") {
            println!("{}", report::fig_waiting_bars("Fig 8 — waiting, 20 MapReduce jobs", &pair.dress, &pair.baseline));
            check(comparison_row(
                &expt::paper::claim("FIG8.small-waiting-change-pct"),
                pair.comparison.small_waiting_change_pct,
            ));
        }
        if wants("fig9") {
            println!("{}", report::fig_completion_bars("Fig 9 — completion, 20 MapReduce jobs", &pair.dress, &pair.baseline));
            check(comparison_row(
                &expt::paper::claim("FIG9.small-completion-change-pct"),
                pair.comparison.small_completion_change_pct,
            ));
        }
    }
    for (id, frac) in [("fig10", 0.10), ("fig11", 0.20), ("fig12", 0.30), ("fig13", 0.40)] {
        if !wants(id) {
            continue;
        }
        let pair = expt::mixed_setting(frac, seed);
        println!(
            "{}",
            report::fig_stacked_bars(
                &format!("Fig {} — mixed setting, {:.0}% small jobs", &id[3..], frac * 100.0),
                &pair.dress,
                &pair.baseline
            )
        );
        check(comparison_row(
            &expt::paper::claim(&format!("{}.small-completion-change-pct", id.to_uppercase())),
            pair.comparison.small_completion_change_pct,
        ));
    }

    println!();
    println!(
        "reproduction shape: {}",
        if all_ok { "ALL CLAIMS HOLD" } else { "SOME CLAIMS MISSED (see rows above)" }
    );
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<(), String> {
    let which = args
        .positional
        .first()
        .ok_or("trace requires a benchmark name")?;
    let seed = args.flag_u64("seed", 42)?;
    let (bench, platform) = match which.as_str() {
        "wordcount" => (Benchmark::WordCount, Platform::MapReduce),
        "pagerank-mr" => (Benchmark::PageRank, Platform::MapReduce),
        "pagerank-spark" => (Benchmark::PageRank, Platform::Spark),
        other => return Err(format!("unknown trace target `{other}`")),
    };
    let r = expt::trace_benchmark(bench, platform, seed);
    println!("{}", report::fig_trace(&format!("trace: {which}"), &r.trace.job_tasks(1)));
    Ok(())
}

fn cmd_live(args: &Args) -> Result<(), String> {
    let jobs = args.flag_u64("jobs", 6)? as u32;
    let workers = args.flag_u64("workers", 8)? as usize;
    let seed = args.flag_u64("seed", 42)?;
    let kind = SchedKind::parse(args.flag_str("sched", "dress"))?;

    let art = crate::runtime::find_artifacts_dir()
        .ok_or("artifacts/ not found — run `make artifacts` first")?;
    let taskwork = art.join("taskwork.hlo.txt");

    let mut specs = generate(jobs, WorkloadMix::Mixed, 0.3, 2_000, seed);
    // Live runs execute real compute: shrink tasks so the demo stays short.
    for s in specs.iter_mut() {
        for p in s.phases.iter_mut() {
            p.tasks.truncate(4);
            for t in p.tasks.iter_mut() {
                t.duration_ms = t.duration_ms.min(4_000);
            }
        }
        s.demand = s.demand.min_each(crate::jobs::Demand::scalar(4));
    }

    let deaths = args.flag_u64("simulate-deaths", 0)? as u32;
    let admission = if args.switch("admission") {
        crate::live::AdmissionConfig::enabled(args.flag_u64("commit-timeout-ms", 10_000)?)
    } else {
        crate::live::AdmissionConfig::default()
    };
    let cfg = crate::live::LiveConfig {
        workers,
        simulate_worker_deaths: deaths,
        admission,
        ..Default::default()
    };
    let sched_cfg = crate::config::SchedConfig { kind, ..Default::default() };
    let sched = crate::sched::build(&sched_cfg, workers as u32);
    let report = crate::live::run_live(&cfg, &sched_cfg, specs, sched, taskwork.to_str().unwrap())
        .map_err(|e| format!("{e:#}"))?;
    println!(
        "live run: {} jobs, {} tasks of real PJRT compute, makespan {:.2?}, checksum {:.4}",
        report.jobs.len(),
        report.tasks_run,
        report.makespan,
        report.checksum
    );
    if report.requeues > 0 || !report.unfinished.is_empty() {
        println!(
            "resilience: {} requeued attempt(s), {} unfinished job(s) {:?}",
            report.requeues, report.unfinished.len(), report.unfinished
        );
    }
    if report.admission_probes > 0 {
        println!(
            "admission: {} probe(s), {} container(s) of reserved capacity expired back",
            report.admission_probes, report.admission_expired_capacity
        );
    }
    for j in &report.jobs {
        println!(
            "  J{:<3} demand {:<3} waiting {:>7.2}s completion {:>7.2}s",
            j.id,
            j.demand,
            j.waiting_ms as f64 / 1000.0,
            j.completion_ms as f64 / 1000.0
        );
    }
    Ok(())
}

/// Parallel seed × scheduler sweep (`expt::sweep`): the many-fast-runs
/// entry point.  `--jobs` here is *worker threads* (0 = all cores);
/// `--njobs` sizes the workload of each run.  `--shard i/N` runs one
/// shard of the grid and writes a mergeable JSON partial instead of the
/// report (see [`cmd_sweep_merge`]).
fn cmd_sweep(args: &Args) -> Result<(), String> {
    use crate::expt::shard::{self, ShardSpec, SweepMeta, SweepMode};
    use crate::expt::sweep::{self, SweepGrid, SweepWorkload};
    use crate::sim::EngineOptions;

    let n_seeds = args.flag_u64("seeds", 3)? as usize;
    if n_seeds == 0 {
        return Err("--seeds must be >= 1".into());
    }
    let base_seed = args.flag_u64("seed", 42)?;
    // `--jobs` is worker threads here (per the sweep contract); `--workers`
    // is accepted as an unambiguous alias since `run`/`compare` use
    // `--jobs` for workload size.
    let workers = args.flag_u64("workers", args.flag_u64("jobs", 0)?)? as usize;
    let njobs = args.flag_u64("njobs", 20)? as u32;
    let small_frac = args.flag_f64("small-frac", 0.3)?;
    let platform = args.flag_str("platform", "mixed");
    let seeds: Vec<u64> = (0..n_seeds as u64).map(|i| base_seed + i).collect();

    let (mut grid, mode) = if args.switch("paper") {
        // Multi-seed claim verification: the Figs 7/9 + Table II pair grid.
        (sweep::paper_grid(&seeds), SweepMode::Paper)
    } else {
        // A recorded trace replaces the synthetic preset entirely; its
        // text rides into the grid fingerprint (content-addressed), so
        // trace shards and synthetic shards can never be merged.
        let workload = if let Some(path) = args.flag("trace") {
            let text =
                std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
            SweepWorkload::trace(path, text)?
        } else {
            match (platform, WorkloadMix::parse(platform)) {
                ("burst", _) => SweepWorkload::CongestedBurst { n: njobs, arrival_mean_ms: 100 },
                ("burst-vec", _) => {
                    SweepWorkload::CongestedBurstVec { n: njobs, arrival_mean_ms: 100 }
                }
                ("burst-vec-jitter", _) => {
                    SweepWorkload::CongestedBurstVecJitter { n: njobs, arrival_mean_ms: 100 }
                }
                (_, Ok(mix)) => {
                    SweepWorkload::Generate { n: njobs, mix, small_frac, arrival_ms: 5_000 }
                }
                (_, Err(e)) => return Err(e),
            }
        };
        let grid = SweepGrid {
            base: ExperimentConfig::default(),
            seeds,
            scheds: vec![
                SchedKind::Fifo,
                SchedKind::Fair,
                SchedKind::Capacity,
                SchedKind::Dress,
                SchedKind::MaxWeight,
            ],
            workloads: vec![workload],
            // Counting sinks: a sweep is a throughput tool, keep memory flat.
            opts: EngineOptions::throughput(),
        };
        (grid, SweepMode::Grid)
    };
    // Per-tick metric retention is part of the grid definition (and so of
    // the fingerprint): shards of one partition must agree on it.
    if let Some(sink) = args.flag("metric-sink") {
        grid.opts.metrics = crate::sim::MetricSinkKind::parse(sink)?;
    }
    // So is the fault plan: every cell of the grid runs under it, and
    // shards swept with different plans must refuse to merge.
    if let Some(spec) = args.flag("fault-plan") {
        grid.base.faults = crate::sim::FaultPlan::parse(spec)?;
    }
    // Federation topology too: a federated sweep and a single-cell sweep
    // are different experiments (the base config is in the fingerprint),
    // and each worker thread runs its whole federation in-process.
    apply_federation_flags(args, &mut grid.base)?;
    grid.base.validate()?;
    // And the shadow tuner: tuned and untuned sweeps are different
    // experiments (EngineOptions is part of the fingerprint).
    apply_tuner_flags(args, &mut grid.opts)?;
    let meta = SweepMeta::of(&grid, mode);

    if let Some(spec) = args.flag("shard") {
        let spec = ShardSpec::parse(spec)?;
        let t0 = std::time::Instant::now();
        let cells = shard::run_shard(&grid, spec, workers);
        let wall = t0.elapsed();
        let path = args
            .flag("out")
            .map(str::to_string)
            .unwrap_or_else(|| format!("dress-sweep-shard-{}-of-{}.json", spec.index, spec.count));
        let text = shard::shard_to_json(&meta, spec, &cells).render();
        std::fs::write(&path, text).map_err(|e| format!("write {path}: {e}"))?;
        println!(
            "shard {}/{}: {} of {} cells in {:.2?} ({} workers, fingerprint {}) -> {path}",
            spec.index,
            spec.count,
            cells.len(),
            grid.len(),
            wall,
            sweep::effective_jobs(workers),
            meta.fingerprint
        );
        return Ok(());
    }

    let t0 = std::time::Instant::now();
    let cells = shard::run_shard(&grid, ShardSpec::full(), workers);
    let wall = t0.elapsed();
    emit_sweep_report(args, &meta, &cells)?;
    println!(
        "{} runs in {:.2?} ({} workers): {:.1} runs/s",
        cells.len(),
        wall,
        sweep::effective_jobs(workers),
        cells.len() as f64 / wall.as_secs_f64().max(1e-9)
    );
    Ok(())
}

/// Merge shard files written by `dress sweep --shard` and emit the final
/// report — byte-identical to a single-process `dress sweep` of the same
/// grid (fingerprints are validated, so mismatched grids are rejected).
fn cmd_sweep_merge(args: &Args) -> Result<(), String> {
    use crate::expt::shard;
    use crate::util::json::Json;

    if args.positional.is_empty() {
        return Err("sweep-merge requires at least one shard file".into());
    }
    let mut files = Vec::with_capacity(args.positional.len());
    for path in &args.positional {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        let json = Json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
        files.push(shard::shard_from_json(&json).map_err(|e| format!("{path}: {e}"))?);
    }
    let n_files = files.len();
    if args.switch("partial") {
        let (meta, cells, cov) = shard::merge_shards_partial(files)?;
        let rendered = shard::render_partial_sweep_report(&meta, &cells, &cov);
        print!("{rendered}");
        if let Some(path) = args.flag("report") {
            std::fs::write(path, &rendered).map_err(|e| format!("write {path}: {e}"))?;
            println!("wrote {path}");
        }
        if let Some(base) = args.flag("csv") {
            let path = format!("{base}.sweep_stats.csv");
            let csv = report::sweep_stats_csv(&shard::sweep_stat_rows(&meta, &cells));
            std::fs::write(&path, csv).map_err(|e| format!("write {path}: {e}"))?;
            println!("wrote {path}");
        }
        println!(
            "partial merge: {n_files} shard file(s), {}/{} shards, {}/{} cells (fingerprint {})",
            cov.shards_present.len(),
            cov.shard_count,
            cov.present_cells(),
            cov.total_cells,
            meta.fingerprint
        );
        return Ok(());
    }
    let (meta, cells) = shard::merge_shards(files)?;
    emit_sweep_report(args, &meta, &cells)?;
    println!(
        "merged {n_files} shard file(s) -> {} cells (fingerprint {})",
        cells.len(),
        meta.fingerprint
    );
    Ok(())
}

/// Print the deterministic sweep report and honor `--report` (write the
/// exact bytes to a file — what the CI sweep matrix `cmp`s) and `--csv`
/// (seed-aggregate statistics, plus claim CIs in paper mode).
fn emit_sweep_report(
    args: &Args,
    meta: &crate::expt::shard::SweepMeta,
    cells: &[crate::expt::shard::CellSummary],
) -> Result<(), String> {
    use crate::expt::shard::{self, SweepMode};

    let rendered = shard::render_sweep_report(meta, cells);
    print!("{rendered}");
    if let Some(path) = args.flag("report") {
        std::fs::write(path, &rendered).map_err(|e| format!("write {path}: {e}"))?;
        println!("wrote {path}");
    }
    if let Some(base) = args.flag("csv") {
        let path = format!("{base}.sweep_stats.csv");
        let csv = report::sweep_stats_csv(&shard::sweep_stat_rows(meta, cells));
        std::fs::write(&path, csv).map_err(|e| format!("write {path}: {e}"))?;
        println!("wrote {path}");
        if meta.mode == SweepMode::Paper {
            let checks = shard::sweep_claim_checks(meta, cells);
            let rows: Vec<_> = checks.iter().map(|c| (&c.claim, c.ci, c.holds)).collect();
            let path = format!("{base}.claims.csv");
            std::fs::write(&path, report::claims_csv(&rows)).map_err(|e| format!("write {path}: {e}"))?;
            println!("wrote {path}");
        }
    }
    Ok(())
}

fn cmd_bench() -> Result<(), String> {
    println!("use `cargo bench` for the full harness; quick in-process sample:");
    let cfg = ExperimentConfig::default();
    let specs = generate(20, WorkloadMix::Mixed, 0.3, 5_000, 42);
    let t = std::time::Instant::now();
    let res = run_experiment(&cfg, specs);
    println!(
        "20-job mixed experiment: {:?} wall, makespan {:.1}s, {} tasks",
        t.elapsed(),
        res.system.makespan_ms as f64 / 1000.0,
        res.trace.tasks.len()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        let raw: Vec<String> = s.split_whitespace().map(|x| x.to_string()).collect();
        Args::parse(&raw).unwrap()
    }

    #[test]
    fn help_and_unknown() {
        assert_eq!(run_cli(&args("help")), 0);
        assert_eq!(run_cli(&args("frobnicate")), 1);
    }

    #[test]
    fn run_small_experiment() {
        assert_eq!(run_cli(&args("run --jobs 4 --sched capacity --seed 3")), 0);
    }

    #[test]
    fn trace_requires_target() {
        assert_eq!(run_cli(&args("trace")), 1);
        assert_eq!(run_cli(&args("trace wordcount --seed 2")), 0);
    }

    #[test]
    fn compare_runs_all_schedulers() {
        assert_eq!(run_cli(&args("compare --jobs 4 --seed 3")), 0);
    }

    #[test]
    fn sweep_runs_parallel_grid() {
        // Tiny grid, 2 workers; cells must land in grid order regardless.
        assert_eq!(run_cli(&args("sweep --seeds 2 --njobs 3 --jobs 2 --seed 5")), 0);
    }

    #[test]
    fn sweep_rejects_zero_seeds() {
        assert_eq!(run_cli(&args("sweep --seeds 0")), 1);
    }

    #[test]
    fn run_accepts_maxweight_scheduler() {
        assert_eq!(run_cli(&args("run --jobs 4 --sched maxweight --seed 3")), 0);
    }

    #[test]
    fn sweep_runs_burst_vec_platform() {
        assert_eq!(run_cli(&args("sweep --seeds 2 --njobs 4 --platform burst-vec --seed 7")), 0);
    }

    /// The checked-in fixture trace (also exercised by the tracefile
    /// parser tests); paths are whitespace-free so `args()` can split.
    const FIXTURE_TRACE: &str =
        concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/workload.trace");

    #[test]
    fn sweep_replays_a_trace_through_shards_and_merge() {
        // A recorded trace flows through the same shard/merge machinery
        // as synthetic presets: two shards merge back to the bytes of a
        // single-process sweep of the same trace.
        let (s0, s1) = (tmp("trace-shard0.json"), tmp("trace-shard1.json"));
        let (merged, full) = (tmp("trace-merged.txt"), tmp("trace-full.txt"));
        let base = format!("sweep --seeds 2 --seed 5 --jobs 2 --trace {FIXTURE_TRACE}");
        assert_eq!(run_cli(&args(&format!("{base} --shard 0/2 --out {s0}"))), 0);
        assert_eq!(run_cli(&args(&format!("{base} --shard 1/2 --out {s1}"))), 0);
        assert_eq!(run_cli(&args(&format!("sweep-merge {s0} {s1} --report {merged}"))), 0);
        assert_eq!(run_cli(&args(&format!("{base} --report {full}"))), 0);
        let merged_text = std::fs::read_to_string(&merged).unwrap();
        assert!(!merged_text.is_empty());
        assert_eq!(
            merged_text,
            std::fs::read_to_string(&full).unwrap(),
            "merged trace report diverged from full run"
        );
    }

    #[test]
    fn sweep_trace_workload_is_part_of_the_fingerprint() {
        // A trace shard and a synthetic shard describe different grids
        // and must refuse to merge.
        let (a, b) = (tmp("trace-src-a.json"), tmp("trace-src-b.json"));
        let base = "sweep --seeds 2 --seed 5";
        assert_eq!(
            run_cli(&args(&format!("{base} --trace {FIXTURE_TRACE} --shard 0/2 --out {a}"))),
            0
        );
        assert_eq!(run_cli(&args(&format!("{base} --njobs 4 --shard 1/2 --out {b}"))), 0);
        assert_eq!(run_cli(&args(&format!("sweep-merge {a} {b}"))), 1);
    }

    #[test]
    fn sweep_rejects_missing_or_invalid_trace() {
        assert_eq!(run_cli(&args("sweep --seeds 1 --trace /no/such/file.trace")), 1);
        let bad = tmp("bad.trace");
        std::fs::write(&bad, "job zero\n").unwrap();
        assert_eq!(run_cli(&args(&format!("sweep --seeds 1 --trace {bad}"))), 1);
    }

    #[test]
    fn run_accepts_metric_sink_flag() {
        assert_eq!(run_cli(&args("run --jobs 4 --sched dress --seed 3 --metric-sink counting")), 0);
        assert_eq!(run_cli(&args("run --jobs 4 --sched dress --seed 3 --metric-sink ring:32")), 0);
        assert_eq!(run_cli(&args("run --jobs 4 --metric-sink bogus")), 1);
    }

    #[test]
    fn sweep_metric_sink_is_part_of_the_fingerprint() {
        // Shards run with different metric retention describe different
        // grid definitions and must refuse to merge.
        let (a, b) = (tmp("msink-a.json"), tmp("msink-b.json"));
        let base = "sweep --seeds 2 --njobs 3";
        assert_eq!(
            run_cli(&args(&format!("{base} --shard 0/2 --out {a} --metric-sink counting"))),
            0
        );
        assert_eq!(
            run_cli(&args(&format!("{base} --shard 1/2 --out {b} --metric-sink full"))),
            0
        );
        assert_eq!(run_cli(&args(&format!("sweep-merge {a} {b}"))), 1);
    }

    #[test]
    fn run_accepts_tune_delta_flag() {
        assert_eq!(run_cli(&args("run --jobs 4 --sched dress --seed 3 --tune-delta")), 0);
        // Harmless on schedulers with no δ to tune.
        assert_eq!(run_cli(&args("run --jobs 4 --sched fifo --seed 3 --tune-delta")), 0);
    }

    #[test]
    fn run_accepts_tuner_cadence_flags() {
        assert_eq!(
            run_cli(&args(
                "run --jobs 4 --sched dress --seed 3 --tune-delta --tune-every 8 --shadow-window 64"
            )),
            0
        );
        assert_eq!(run_cli(&args("run --jobs 4 --sched dress --tune-delta --tune-every 0")), 1);
        assert_eq!(run_cli(&args("run --jobs 4 --sched dress --tune-delta --shadow-window 0")), 1);
    }

    #[test]
    fn run_accepts_federation_flags() {
        for router in ["round-robin", "least-load", "by-category"] {
            assert_eq!(
                run_cli(&args(&format!(
                    "run --jobs 6 --sched dress --seed 3 --cells 3 --router {router}"
                ))),
                0
            );
        }
        assert_eq!(
            run_cli(&args("run --jobs 6 --seed 3 --cells 2 --migrate-threshold 1")),
            0
        );
        assert_eq!(run_cli(&args("run --jobs 4 --cells 0")), 1);
        assert_eq!(run_cli(&args("run --jobs 4 --cells 2 --router bogus")), 1);
    }

    #[test]
    fn run_accepts_cell_fault_plans() {
        // Cell 1 of 3 dies at 4s for 5s: the downtime elapses inside the
        // run, so recovery is observable.
        assert_eq!(
            run_cli(&args("run --jobs 8 --seed 3 --cells 3 --cell-faults 4000:1:5000")),
            0
        );
        // Cell faults need a federation to kill cells of.
        assert_eq!(run_cli(&args("run --jobs 4 --cell-faults 4000:0:5000")), 1);
        // Node-level and cell-level fault layers cannot be combined.
        assert_eq!(
            run_cli(&args(
                "run --jobs 4 --cells 2 --cell-faults 4000:1:5000 --fault-plan 5000:0:2000"
            )),
            1
        );
        // Cell index beyond the federation: rejected by validate.
        assert_eq!(
            run_cli(&args("run --jobs 4 --cells 2 --cell-faults 4000:7:5000")),
            1
        );
    }

    #[test]
    fn sweep_runs_burst_vec_jitter_platform() {
        assert_eq!(
            run_cli(&args("sweep --seeds 2 --njobs 4 --platform burst-vec-jitter --seed 7")),
            0
        );
    }

    #[test]
    fn sweep_federation_is_part_of_the_fingerprint() {
        // A federated shard and a single-cell shard describe different
        // experiments and must refuse to merge.
        let (a, b) = (tmp("fed-a.json"), tmp("fed-b.json"));
        let base = "sweep --seeds 2 --njobs 3";
        assert_eq!(
            run_cli(&args(&format!("{base} --shard 0/2 --out {a} --cells 2 --router least-load"))),
            0
        );
        assert_eq!(run_cli(&args(&format!("{base} --shard 1/2 --out {b}"))), 0);
        assert_eq!(run_cli(&args(&format!("sweep-merge {a} {b}"))), 1);
    }

    #[test]
    fn sweep_tuner_cadence_is_part_of_the_fingerprint() {
        let (a, b) = (tmp("cadence-a.json"), tmp("cadence-b.json"));
        let base = "sweep --seeds 2 --njobs 3 --tune-delta";
        assert_eq!(
            run_cli(&args(&format!("{base} --shard 0/2 --out {a} --tune-every 8"))),
            0
        );
        assert_eq!(run_cli(&args(&format!("{base} --shard 1/2 --out {b}"))), 0);
        assert_eq!(run_cli(&args(&format!("sweep-merge {a} {b}"))), 1);
    }

    #[test]
    fn federated_sweep_shard_merge_matches_full_run() {
        // Per-cell federated configurations ride the existing shard
        // machinery: a sharded federated sweep merges back to the bytes of
        // the unsharded federated sweep.
        let (s0, s1, s2) = (tmp("fshard0.json"), tmp("fshard1.json"), tmp("fshard2.json"));
        let (merged, full) = (tmp("fmerged.txt"), tmp("ffull.txt"));
        let base = "sweep --seeds 2 --njobs 4 --seed 5 --jobs 2 --cells 2 --router by-category";
        assert_eq!(run_cli(&args(&format!("{base} --shard 0/3 --out {s0}"))), 0);
        assert_eq!(run_cli(&args(&format!("{base} --shard 1/3 --out {s1}"))), 0);
        assert_eq!(run_cli(&args(&format!("{base} --shard 2/3 --out {s2}"))), 0);
        assert_eq!(
            run_cli(&args(&format!("sweep-merge {s0} {s1} {s2} --report {merged}"))),
            0
        );
        assert_eq!(run_cli(&args(&format!("{base} --report {full}"))), 0);
        let merged_text = std::fs::read_to_string(&merged).unwrap();
        assert!(!merged_text.is_empty());
        assert_eq!(
            merged_text,
            std::fs::read_to_string(&full).unwrap(),
            "merged federated report diverged from full run"
        );
    }

    #[test]
    fn sweep_tune_delta_is_part_of_the_fingerprint() {
        // A tuned shard and an untuned shard describe different
        // experiments and must refuse to merge.
        let (a, b) = (tmp("tune-a.json"), tmp("tune-b.json"));
        let base = "sweep --seeds 2 --njobs 3";
        assert_eq!(
            run_cli(&args(&format!("{base} --shard 0/2 --out {a} --tune-delta"))),
            0
        );
        assert_eq!(run_cli(&args(&format!("{base} --shard 1/2 --out {b}"))), 0);
        assert_eq!(run_cli(&args(&format!("sweep-merge {a} {b}"))), 1);
    }

    #[test]
    fn run_accepts_fault_plan() {
        assert_eq!(
            run_cli(&args("run --jobs 4 --sched dress --seed 3 --fault-plan 5000:0:20000")),
            0
        );
        assert_eq!(run_cli(&args("run --jobs 4 --sched capacity --fault-plan none")), 0);
        assert_eq!(run_cli(&args("run --jobs 4 --fault-plan garbage")), 1);
        // Node index beyond the default 5-node cluster: rejected by validate.
        assert_eq!(run_cli(&args("run --jobs 4 --fault-plan 5000:99:20000")), 1);
    }

    #[test]
    fn sweep_fault_plan_is_part_of_the_fingerprint() {
        // Shards swept under different fault plans describe different
        // experiments and must refuse to merge.
        let (a, b) = (tmp("fault-a.json"), tmp("fault-b.json"));
        let base = "sweep --seeds 2 --njobs 3";
        assert_eq!(
            run_cli(&args(&format!("{base} --shard 0/2 --out {a} --fault-plan 5000:0:20000"))),
            0
        );
        assert_eq!(run_cli(&args(&format!("{base} --shard 1/2 --out {b}"))), 0);
        assert_eq!(run_cli(&args(&format!("sweep-merge {a} {b}"))), 1);
    }

    #[test]
    fn sweep_rejects_bad_shard_spec() {
        assert_eq!(run_cli(&args("sweep --seeds 2 --njobs 3 --shard 3/3")), 1);
        assert_eq!(run_cli(&args("sweep --seeds 2 --njobs 3 --shard nope")), 1);
    }

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir()
            .join(format!("dress-cli-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_str().unwrap().to_string()
    }

    #[test]
    fn sweep_shard_merge_report_is_byte_identical_to_full_run() {
        // Two shards + merge must reproduce the single-process report
        // byte-for-byte (the property the CI sweep matrix asserts).
        let (s0, s1) = (tmp("shard0.json"), tmp("shard1.json"));
        let (merged, full) = (tmp("merged.txt"), tmp("full.txt"));
        let base = "sweep --seeds 2 --njobs 3 --seed 5 --jobs 2";
        assert_eq!(run_cli(&args(&format!("{base} --shard 0/2 --out {s0}"))), 0);
        assert_eq!(run_cli(&args(&format!("{base} --shard 1/2 --out {s1}"))), 0);
        assert_eq!(run_cli(&args(&format!("sweep-merge {s0} {s1} --report {merged}"))), 0);
        assert_eq!(run_cli(&args(&format!("{base} --report {full}"))), 0);
        let merged_text = std::fs::read_to_string(&merged).unwrap();
        let full_text = std::fs::read_to_string(&full).unwrap();
        assert!(!merged_text.is_empty());
        assert_eq!(merged_text, full_text, "merged report diverged from full run");
    }

    #[test]
    fn sweep_merge_partial_accepts_incomplete_shard_sets() {
        // 2-of-3 shards: plain merge rejects, --partial degrades gracefully
        // with a coverage report whose bytes are argument-order independent.
        let (s0, s2) = (tmp("p-shard0.json"), tmp("p-shard2.json"));
        let (r1, r2) = (tmp("p-merged1.txt"), tmp("p-merged2.txt"));
        let base = "sweep --seeds 2 --njobs 3 --seed 5";
        assert_eq!(run_cli(&args(&format!("{base} --shard 0/3 --out {s0}"))), 0);
        assert_eq!(run_cli(&args(&format!("{base} --shard 2/3 --out {s2}"))), 0);
        assert_eq!(run_cli(&args(&format!("sweep-merge {s0} {s2}"))), 1);
        assert_eq!(
            run_cli(&args(&format!("sweep-merge {s0} {s2} --partial --report {r1}"))),
            0
        );
        assert_eq!(
            run_cli(&args(&format!("sweep-merge {s2} {s0} --partial --report {r2}"))),
            0
        );
        let t1 = std::fs::read_to_string(&r1).unwrap();
        assert!(t1.contains("coverage: 2/3 shards present"), "{t1}");
        assert!(t1.contains("shards missing: [1]"), "{t1}");
        assert_eq!(
            t1,
            std::fs::read_to_string(&r2).unwrap(),
            "partial report must not depend on shard argument order"
        );
    }

    #[test]
    fn sweep_merge_rejects_mismatched_grids() {
        // Shards from different grid definitions (different --njobs) must
        // not merge: the fingerprints differ.
        let (a, b) = (tmp("mismatch-a.json"), tmp("mismatch-b.json"));
        assert_eq!(run_cli(&args(&format!("sweep --seeds 2 --njobs 3 --shard 0/2 --out {a}"))), 0);
        assert_eq!(run_cli(&args(&format!("sweep --seeds 2 --njobs 4 --shard 1/2 --out {b}"))), 0);
        assert_eq!(run_cli(&args(&format!("sweep-merge {a} {b}"))), 1);
        // Incomplete partitions are rejected too.
        assert_eq!(run_cli(&args(&format!("sweep-merge {a}"))), 1);
        assert_eq!(run_cli(&args("sweep-merge")), 1);
    }

    #[test]
    fn config_overrides() {
        let cfg = load_config(&args("run --sched fair --jobs 7 --seed 9 --platform spark")).unwrap();
        assert_eq!(cfg.sched.kind, SchedKind::Fair);
        assert_eq!(cfg.workload.jobs, 7);
        assert_eq!(cfg.workload.platform, "spark");
    }
}
