//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`, produced
//! once by `make artifacts`) and execute them from the Rust hot path.
//! Python is never on the request path — the binary is self-contained once
//! artifacts exist.

pub mod executable;
pub mod taskwork;

pub use executable::{Executable, Runtime};
pub use taskwork::TaskWork;

/// Default artifact locations relative to the repo root.
pub const ESTIMATOR_HLO: &str = "artifacts/model.hlo.txt";
pub const TASKWORK_HLO: &str = "artifacts/taskwork.hlo.txt";
pub const MANIFEST: &str = "artifacts/manifest.txt";

/// Artifact-interface constants (mirrors `python/compile/kernels`).
pub const PAD_PHASES: usize = 256;
pub const NUM_FIELDS: usize = 6;
pub const TIME_GRID: usize = 64;
pub const TASKWORK_DIM: usize = 64;

/// Locate the artifacts directory: walk up from cwd looking for
/// `artifacts/manifest.txt` (lets tests/benches run from any subdir).
pub fn find_artifacts_dir() -> Option<std::path::PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join("artifacts").join("manifest.txt");
        if cand.is_file() {
            return Some(dir.join("artifacts"));
        }
        if !dir.pop() {
            return None;
        }
    }
}

/// Parse `key=value` lines from the manifest and sanity-check the constants
/// this binary was compiled against.
pub fn check_manifest(text: &str) -> Result<(), String> {
    let want = [
        ("pad_phases", PAD_PHASES),
        ("time_grid", TIME_GRID),
        ("num_fields", NUM_FIELDS),
        ("taskwork_dim", TASKWORK_DIM),
    ];
    for (key, expect) in want {
        let found = text
            .lines()
            .find_map(|l| l.strip_prefix(&format!("{key}=")))
            .ok_or_else(|| format!("manifest missing `{key}`"))?;
        let got: usize = found
            .trim()
            .parse()
            .map_err(|e| format!("manifest {key}: {e}"))?;
        if got != expect {
            return Err(format!("manifest {key}={got}, binary expects {expect} — re-run `make artifacts`"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_check_accepts_current() {
        let text = "pad_phases=256\ntime_grid=64\nnum_fields=6\ntaskwork_dim=64\ntaskwork_iters=8\n";
        assert!(check_manifest(text).is_ok());
    }

    #[test]
    fn manifest_check_rejects_mismatch() {
        let text = "pad_phases=128\ntime_grid=64\nnum_fields=6\ntaskwork_dim=64\n";
        let err = check_manifest(text).unwrap_err();
        assert!(err.contains("pad_phases"));
        assert!(check_manifest("time_grid=64").is_err(), "missing keys rejected");
    }
}
