//! Typed wrapper over the PJRT CPU client.
//!
//! Two builds of the same API:
//!
//! * `--features pjrt` — the real path over the `xla` crate (add the
//!   dependency to `rust/Cargo.toml` on a networked machine; it links the
//!   xla_extension C++ library).  Interchange is HLO *text* —
//!   `HloModuleProto::from_text_file` reassigns instruction ids,
//!   sidestepping the 64-bit-id protos jax >= 0.5 emits that
//!   xla_extension 0.5.1 rejects (see /opt/xla-example/README.md).
//! * default — an offline stub: identical types and signatures, but
//!   [`Runtime::cpu`] returns an error.  Everything that needs PJRT
//!   (accel, taskwork, live mode) already degrades gracefully when the
//!   runtime or the artifacts are unavailable, so the crate builds and
//!   tests fully offline.

#[cfg(feature = "pjrt")]
mod backend {
    use crate::format_err;
    use crate::util::error::{Context, Result};

    /// A PJRT client plus compilation entry points.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    impl Runtime {
        /// Create a CPU PJRT client.
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
            Ok(Runtime { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load an HLO-text artifact and compile it for this client.
        pub fn load_hlo_text(&self, path: &str) -> Result<Executable> {
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parse HLO text {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compile {path}"))?;
            Ok(Executable { exe, name: path.to_string() })
        }
    }

    /// One compiled computation with an f32 calling convention.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        name: String,
    }

    impl Executable {
        pub fn name(&self) -> &str {
            &self.name
        }

        /// Execute with f32 inputs of the given shapes; the computation must
        /// return a 1-tuple of an f32 array (jax lowering uses
        /// `return_tuple=True`), which is returned flattened.
        pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|(data, dims)| {
                    let lit = xla::Literal::vec1(data);
                    if dims.len() <= 1 {
                        Ok(lit)
                    } else {
                        lit.reshape(dims)
                            .with_context(|| format!("reshape input to {dims:?} for {}", self.name))
                    }
                })
                .collect::<Result<_>>()?;
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .with_context(|| format!("execute {}", self.name))?;
            let buf = result
                .first()
                .and_then(|d| d.first())
                .ok_or_else(|| format_err!("{}: empty execution result", self.name))?;
            let out = buf
                .to_literal_sync()
                .context("fetch result literal")?
                .to_tuple1()
                .context("unwrap 1-tuple result")?;
            out.to_vec::<f32>().context("result to f32 vec")
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod backend {
    use crate::util::error::{Error, Result};

    const STUB_MSG: &str =
        "PJRT runtime unavailable: built without the `pjrt` feature (offline stub)";

    /// Offline stub of the PJRT client; [`Runtime::cpu`] always errors, so
    /// callers take their artifact-missing / runtime-missing skip paths.
    pub struct Runtime {
        _priv: (),
    }

    impl Runtime {
        pub fn cpu() -> Result<Self> {
            Err(Error::msg(STUB_MSG))
        }

        pub fn platform(&self) -> String {
            "stub".to_string()
        }

        pub fn load_hlo_text(&self, path: &str) -> Result<Executable> {
            let _ = path;
            Err(Error::msg(STUB_MSG))
        }
    }

    /// Offline stub executable (never constructed; the stub
    /// [`Runtime::cpu`] is the only way in and it always errors).
    pub struct Executable {
        _priv: (),
    }

    impl Executable {
        pub fn name(&self) -> &str {
            "stub"
        }

        pub fn run_f32(&self, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
            Err(Error::msg(STUB_MSG))
        }
    }
}

pub use backend::{Executable, Runtime};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn stub_runtime_errors_cleanly() {
        let err = Runtime::cpu().err().expect("stub must not construct");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    // Exercising the real PJRT path needs the AOT artifacts; those tests
    // live in rust/tests/runtime_integration.rs (skipped when artifacts or
    // the `pjrt` feature are absent).
}
