//! The real compute executed per simulated task in the end-to-end example:
//! a PageRank-style power iteration AOT-lowered from JAX (`taskwork.hlo.txt`).

use super::{Executable, Runtime, TASKWORK_DIM};
use crate::util::error::Result;
use crate::util::rng::Rng;

/// A loaded task-work executable plus input synthesis.
pub struct TaskWork {
    exe: Executable,
}

impl TaskWork {
    pub fn load(rt: &Runtime, path: &str) -> Result<Self> {
        Ok(TaskWork { exe: rt.load_hlo_text(path)? })
    }

    /// Build a column-stochastic matrix + uniform rank vector from a seed.
    pub fn make_inputs(seed: u64) -> (Vec<f32>, Vec<f32>) {
        let n = TASKWORK_DIM;
        let mut rng = Rng::new(seed);
        let mut a = vec![0f32; n * n];
        for v in a.iter_mut() {
            *v = rng.next_f64() as f32 + 0.01;
        }
        // Normalize columns so the iteration is a proper PageRank walk.
        for col in 0..n {
            let s: f32 = (0..n).map(|row| a[row * n + col]).sum();
            for row in 0..n {
                a[row * n + col] /= s;
            }
        }
        let x = vec![1.0f32 / n as f32; n];
        (a, x)
    }

    /// Run `units` power-iteration work units; returns a checksum of the
    /// final rank vector (proof the compute actually ran).
    pub fn run_units(&self, seed: u64, units: u32) -> Result<f32> {
        let (a, mut x) = Self::make_inputs(seed);
        let n = TASKWORK_DIM as i64;
        for _ in 0..units.max(1) {
            x = self.exe.run_f32(&[(&a, &[n, n]), (&x, &[n])])?;
        }
        Ok(x.iter().sum())
    }
}

/// CPU reference of one work unit (8 power-iteration steps), for validating
/// the PJRT path in integration tests.
pub fn reference_unit(a: &[f32], x0: &[f32]) -> Vec<f32> {
    let n = TASKWORK_DIM;
    let mut x = x0.to_vec();
    for _ in 0..8 {
        let mut y = vec![0f32; n];
        for row in 0..n {
            let mut acc = 0f32;
            for col in 0..n {
                acc += a[row * n + col] * x[col];
            }
            y[row] = acc;
        }
        let norm: f32 = y.iter().map(|v| v.abs()).sum::<f32>() + 1e-9;
        for v in y.iter_mut() {
            *v /= norm;
        }
        x = y;
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inputs_are_column_stochastic() {
        let (a, x) = TaskWork::make_inputs(7);
        let n = TASKWORK_DIM;
        assert_eq!(a.len(), n * n);
        assert_eq!(x.len(), n);
        for col in 0..n {
            let s: f32 = (0..n).map(|row| a[row * n + col]).sum();
            assert!((s - 1.0).abs() < 1e-4, "col {col} sums to {s}");
        }
        let xs: f32 = x.iter().sum();
        assert!((xs - 1.0).abs() < 1e-5);
    }

    #[test]
    fn inputs_deterministic_per_seed() {
        let (a1, _) = TaskWork::make_inputs(3);
        let (a2, _) = TaskWork::make_inputs(3);
        let (a3, _) = TaskWork::make_inputs(4);
        assert_eq!(a1, a2);
        assert_ne!(a1, a3);
    }

    #[test]
    fn reference_unit_preserves_l1_norm() {
        let (a, x) = TaskWork::make_inputs(5);
        let out = reference_unit(&a, &x);
        let norm: f32 = out.iter().map(|v| v.abs()).sum();
        assert!((norm - 1.0).abs() < 1e-3, "norm {norm}");
    }
}
