//! Shadow schedules: cheaply-cloneable scheduler snapshots and a bounded
//! what-if replay executor (docs/ADMISSION.md).
//!
//! A [`SchedSnapshot`] freezes everything a what-if evaluation needs —
//! the observable job table, the live capacity totals, and (for DRESS)
//! the classifier + estimator-bank state and the current δ — behind a
//! plain `Clone`.  A [`ShadowWindow`] ring-buffers the recent
//! submit/complete stream.  [`replay`] runs a coarse deterministic
//! admission model of that window against a snapshot under one candidate
//! δ and scores it; [`tune_delta`] ranks a candidate ladder and returns
//! the winner, clamped to `reserve::DELTA_MIN..=DELTA_MAX`.
//!
//! Everything here is pure with respect to live state: replay clones the
//! snapshot's classifier, never touches the caller's, and draws **zero**
//! random numbers — the same inputs always produce the same tuned δ
//! (pinned by `tests/admission_integration.rs`).

use super::dress::reserve::{DELTA_MAX, DELTA_MIN};
use super::dress::{Category, Classifier};
use super::JobView;
use crate::estimator::EstimatorBank;
use crate::jobs::{Demand, JobId};
use crate::util::Time;

/// Default ring capacity for the recent-event window.
pub const DEFAULT_WINDOW: usize = 256;
/// Default tuner cadence: re-tune every K heartbeats.
pub const DEFAULT_TUNE_EVERY: u32 = 16;
/// Synthetic heartbeats one replay simulates.
pub const REPLAY_TICKS: u32 = 32;

/// One observed scheduling-stream event, as ring-buffered by the tuner.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShadowEvent {
    /// A job entered the scheduler's view.
    Submit { job: JobId, demand: u32, at: Time },
    /// A job left the view (finished or retired).
    Complete { job: JobId, at: Time },
}

impl ShadowEvent {
    pub fn at(&self) -> Time {
        match *self {
            ShadowEvent::Submit { at, .. } | ShadowEvent::Complete { at, .. } => at,
        }
    }
}

/// Fixed-capacity ring buffer over [`ShadowEvent`]s: pushes never
/// allocate once warm, and the oldest entry is overwritten when full.
#[derive(Debug, Clone)]
pub struct ShadowWindow {
    cap: usize,
    buf: Vec<ShadowEvent>,
    /// Next write position (== oldest entry once the ring has wrapped).
    head: usize,
}

impl ShadowWindow {
    pub fn new(cap: usize) -> Self {
        // The backing Vec grows lazily up to `cap`: a window that is never
        // pushed to (tuner disabled) costs no heap allocation at all.
        ShadowWindow { cap: cap.max(1), buf: Vec::new(), head: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn push(&mut self, e: ShadowEvent) {
        if self.buf.len() < self.cap {
            self.buf.push(e);
        } else {
            self.buf[self.head] = e;
            self.head = (self.head + 1) % self.cap;
        }
    }

    /// Events oldest → newest.
    pub fn iter(&self) -> impl Iterator<Item = &ShadowEvent> {
        let (wrapped, recent) = if self.buf.len() < self.cap {
            (&self.buf[..0], &self.buf[..])
        } else {
            (&self.buf[self.head..], &self.buf[..self.head])
        };
        wrapped.iter().chain(recent.iter())
    }
}

/// A frozen, cheaply-cloneable picture of scheduler + cluster state.
///
/// Cloned parts: the job table (`Vec<JobView>`, `Copy` rows), the DRESS
/// classifier (one `Vec<Option<Category>>`) and the estimator bank.
/// Shared/derived parts: capacity totals are plain integers; nothing
/// borrows from the live engine, so a snapshot outlives any view.
#[derive(Debug, Clone)]
pub struct SchedSnapshot {
    pub now: Time,
    pub free: u32,
    pub total: u32,
    /// Active + tombstoned jobs, in submission order (a copy of the
    /// engine's `ClusterView::jobs` slice).
    pub jobs: Vec<JobView>,
    /// DRESS reserve ratio at capture time (δ₀ default for non-DRESS).
    pub delta: f64,
    pub classifier: Classifier,
    pub estimator: EstimatorBank,
}

impl SchedSnapshot {
    /// Scheduler-agnostic snapshot: capacity + job table from a view,
    /// neutral classifier/estimator state.  `delta` is whatever the live
    /// scheduler reports (`reserve_ratio()`), or a caller-chosen default.
    pub fn of_view(
        now: Time,
        free: u32,
        total: u32,
        jobs: &[JobView],
        delta: f64,
        theta: f64,
    ) -> SchedSnapshot {
        SchedSnapshot {
            now,
            free,
            total,
            jobs: jobs.to_vec(),
            delta,
            classifier: Classifier::new(theta),
            estimator: EstimatorBank::default(),
        }
    }

    /// Containers demanded by jobs that have not started yet — the
    /// backlog a probe weighs against free capacity.
    pub fn waiting_demand(&self) -> u64 {
        self.jobs
            .iter()
            .filter(|j| !j.finished && !j.started)
            .map(|j| j.demand.cpu as u64)
            .sum()
    }
}

/// Per-candidate outcome of one shadow replay.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShadowScore {
    pub delta: f64,
    /// Jobs that drained all task-units within the replay horizon.
    pub completed: u32,
    /// Slot-ticks of useful service performed (goodput proxy).
    pub goodput: u64,
}

impl ShadowScore {
    /// Strictly-better ordering: completions first, then goodput.
    fn beats(&self, other: &ShadowScore) -> bool {
        (self.completed, self.goodput) > (other.completed, other.goodput)
    }
}

/// One simulated job inside a replay.
struct ShadowJob {
    demand: u32,
    /// Task-units still to serve (pending + in-flight at capture).
    remaining: u32,
    /// Slots held this synthetic tick.
    occupied: u32,
    cat: Category,
    /// Synthetic tick at which the job becomes visible.
    arrive: u32,
    done: bool,
}

/// Replay the snapshot + recent window under one candidate δ.
///
/// The service model is deliberately coarse — every granted slot serves
/// one task-unit per synthetic heartbeat — because the score is only
/// ever *compared between candidates under the same model*.  What the
/// model does preserve exactly is the DRESS admission discipline: the
/// δ split (`round(δ·total)` clamped to leave both pools ≥ 1), per-pool
/// FCFS admission in submission order, and leftover free slots flowing
/// to the smallest blocked jobs.  No RNG, no live-state access.
pub fn replay(
    snap: &SchedSnapshot,
    window: &ShadowWindow,
    delta: f64,
    ticks: u32,
) -> ShadowScore {
    let total = snap.total;
    if total < 2 || ticks == 0 {
        return ShadowScore { delta, completed: 0, goodput: 0 };
    }
    // Replay classifies synthetic arrivals against a *clone* — probe
    // purity: the caller's classifier is untouched.
    let mut classifier = snap.classifier.clone();

    // Live jobs at capture: visible from tick 0.
    let mut jobs: Vec<ShadowJob> = snap
        .jobs
        .iter()
        .filter(|j| !j.finished)
        .map(|j| ShadowJob {
            demand: j.demand.cpu.max(1),
            remaining: j.pending_tasks + j.occupied,
            occupied: 0,
            cat: classifier.classify(
                j.id,
                j.demand,
                Demand::scalar(snap.free),
                Demand::scalar(total),
            ),
            arrive: 0,
            done: false,
        })
        .collect();

    // Recent window replayed as synthetic arrivals spread over the
    // horizon: each Submit re-arrives at a tick proportional to its age
    // (oldest → tick 0, newest → last tick).  Completes carry no load.
    let submits: Vec<(JobId, u32, Time)> = window
        .iter()
        .filter_map(|e| match *e {
            ShadowEvent::Submit { job, demand, at } => Some((job, demand, at)),
            ShadowEvent::Complete { .. } => None,
        })
        .collect();
    if let (Some(oldest), Some(newest)) =
        (submits.first().map(|s| s.2), submits.last().map(|s| s.2))
    {
        let span = newest.saturating_sub(oldest).max(1);
        for &(job, demand, at) in &submits {
            let arrive = ((at - oldest) * (ticks as u64 - 1) / span) as u32;
            jobs.push(ShadowJob {
                demand: demand.max(1),
                remaining: demand.max(1),
                occupied: 0,
                // Re-arrivals keep their real id: the sticky classifier
                // reuses the live category when the job was already seen.
                // The window records axis-0 (container) demand only, so
                // replayed arrivals classify as uniform vectors.
                cat: classifier.classify(
                    job,
                    Demand::scalar(demand),
                    Demand::scalar(snap.free),
                    Demand::scalar(total),
                ),
                arrive,
                done: false,
            });
        }
    }

    let sd_quota = ((delta * total as f64).round() as u32).clamp(1, total - 1);
    let ld_quota = total - sd_quota;
    let mut completed = 0u32;
    let mut goodput = 0u64;

    for t in 0..ticks {
        // Service: every held slot completes one task-unit, then frees.
        for j in jobs.iter_mut() {
            if j.occupied > 0 {
                goodput += j.occupied as u64;
                j.remaining -= j.occupied.min(j.remaining);
                j.occupied = 0;
            }
            if !j.done && j.arrive <= t && j.remaining == 0 {
                j.done = true;
                completed += 1;
            }
        }
        // Admission under the candidate split: per-pool FCFS in
        // submission order, then leftovers to the smallest blocked jobs.
        let mut free = total;
        let (mut sd_free, mut ld_free) = (sd_quota, ld_quota);
        let mut blocked: Vec<usize> = Vec::new();
        for (i, j) in jobs.iter_mut().enumerate() {
            if j.done || j.arrive > t || j.remaining == 0 {
                continue;
            }
            let want = j.remaining.min(j.demand);
            let pool = match j.cat {
                Category::Sd => &mut sd_free,
                Category::Ld => &mut ld_free,
            };
            let n = want.min(*pool).min(free);
            if n > 0 {
                j.occupied = n;
                *pool -= n;
                free -= n;
            }
            if j.occupied < want {
                blocked.push(i);
            }
        }
        if free > 0 && !blocked.is_empty() {
            blocked.sort_by_key(|&i| (jobs[i].demand, i));
            for i in blocked {
                if free == 0 {
                    break;
                }
                let j = &mut jobs[i];
                let extra = (j.remaining.min(j.demand) - j.occupied).min(free);
                j.occupied += extra;
                free -= extra;
            }
        }
    }
    ShadowScore { delta, completed, goodput }
}

/// Rank a deterministic candidate ladder around `current` by shadow
/// replay and return the winning δ, clamped to the legal band.  The
/// current value is evaluated first and wins all ties, so an
/// uninformative window (empty, or scores all equal) never moves δ.
pub fn tune_delta(
    snap: &SchedSnapshot,
    window: &ShadowWindow,
    current: f64,
    ticks: u32,
) -> f64 {
    let current = current.clamp(DELTA_MIN, DELTA_MAX);
    if snap.total < 2 {
        return current;
    }
    let ladder = [current, current - 0.05, current + 0.05, current - 0.10, current + 0.10];
    let mut best: Option<ShadowScore> = None;
    for cand in ladder {
        let cand = cand.clamp(DELTA_MIN, DELTA_MAX);
        if best.as_ref().is_some_and(|b| b.delta.to_bits() == cand.to_bits()) {
            continue;
        }
        let score = replay(snap, window, cand, ticks);
        match &best {
            Some(b) if !score.beats(b) => {}
            _ => best = Some(score),
        }
    }
    best.map_or(current, |b| b.delta.clamp(DELTA_MIN, DELTA_MAX))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::JobView;

    fn jv(id: JobId, demand: u32, pending: u32, started: bool) -> JobView {
        JobView {
            id,
            demand: Demand::scalar(demand),
            submit_ms: id as Time * 500,
            started,
            finished: false,
            pending_tasks: pending,
            occupied: 0,
        }
    }

    fn snap(free: u32, total: u32, jobs: Vec<JobView>) -> SchedSnapshot {
        SchedSnapshot::of_view(10_000, free, total, &jobs, 0.10, 0.10)
    }

    #[test]
    fn window_ring_overwrites_oldest() {
        let mut w = ShadowWindow::new(3);
        for i in 0..5u32 {
            w.push(ShadowEvent::Complete { job: i, at: i as Time });
        }
        assert_eq!(w.len(), 3);
        let ats: Vec<Time> = w.iter().map(|e| e.at()).collect();
        assert_eq!(ats, vec![2, 3, 4], "oldest events evicted, order preserved");
    }

    #[test]
    fn empty_window_never_allocates() {
        let w = ShadowWindow::new(DEFAULT_WINDOW);
        assert_eq!(w.buf.capacity(), 0, "idle window must not pre-allocate");
    }

    #[test]
    fn replay_is_deterministic() {
        let s = snap(20, 40, vec![jv(1, 4, 4, false), jv(2, 30, 30, false)]);
        let mut w = ShadowWindow::new(16);
        w.push(ShadowEvent::Submit { job: 3, demand: 6, at: 9_000 });
        w.push(ShadowEvent::Submit { job: 4, demand: 2, at: 9_500 });
        let a = replay(&s, &w, 0.2, REPLAY_TICKS);
        let b = replay(&s, &w, 0.2, REPLAY_TICKS);
        assert_eq!(a, b);
        assert!(a.completed > 0 && a.goodput > 0);
    }

    #[test]
    fn replay_never_mutates_the_snapshot() {
        let s = snap(10, 40, vec![jv(1, 4, 4, false), jv(7, 30, 30, true)]);
        let before = format!("{s:?}");
        let w = ShadowWindow::new(8);
        for d in [0.02, 0.5, 0.95] {
            replay(&s, &w, d, 16);
        }
        assert_eq!(format!("{s:?}"), before, "replay touched the snapshot");
    }

    #[test]
    fn tuned_delta_stays_in_band_and_keeps_current_on_empty_window() {
        let s = snap(40, 40, vec![]);
        let w = ShadowWindow::new(8);
        for d in [0.0, 0.02, 0.10, 0.5, 0.95, 1.5] {
            let tuned = tune_delta(&s, &w, d, REPLAY_TICKS);
            assert!((DELTA_MIN..=DELTA_MAX).contains(&tuned), "tuned {tuned} out of band");
            let clamped = d.clamp(DELTA_MIN, DELTA_MAX);
            assert_eq!(
                tuned.to_bits(),
                clamped.to_bits(),
                "uninformative window moved δ {clamped} -> {tuned}"
            );
        }
    }

    #[test]
    fn degraded_capacity_replay_is_a_noop() {
        let s = snap(1, 1, vec![jv(1, 3, 3, false)]);
        let w = ShadowWindow::new(8);
        assert_eq!(replay(&s, &w, 0.5, 16), ShadowScore { delta: 0.5, completed: 0, goodput: 0 });
        assert_eq!(tune_delta(&s, &w, 0.10, 16).to_bits(), 0.10f64.to_bits());
    }

    #[test]
    fn congested_window_prefers_a_working_split() {
        // A stream of small jobs against a big running backlog: some
        // candidate must complete at least as much as every other, and
        // the chosen δ is one of the ladder values.
        let mut jobs = vec![jv(1, 36, 36, true)];
        for id in 2..10u32 {
            jobs.push(jv(id, 2, 2, false));
        }
        let s = snap(4, 40, jobs);
        let mut w = ShadowWindow::new(32);
        for id in 10..20u32 {
            w.push(ShadowEvent::Submit { job: id, demand: 2, at: 9_000 + id as Time * 50 });
        }
        let tuned = tune_delta(&s, &w, 0.10, REPLAY_TICKS);
        assert!((DELTA_MIN..=DELTA_MAX).contains(&tuned));
        let chosen = replay(&s, &w, tuned, REPLAY_TICKS);
        for cand in [0.05, 0.10, 0.15, 0.20] {
            let other = replay(&s, &w, cand, REPLAY_TICKS);
            assert!(
                !other.beats(&chosen),
                "candidate {cand} beats adopted δ {tuned}: {other:?} > {chosen:?}"
            );
        }
    }
}
