//! Scheduler interface and implementations.
//!
//! The engine invokes the scheduler once per heartbeat with a [`ClusterView`]
//! (observable state only: free containers, job queue, and the heartbeat
//! transition batch).  The scheduler returns [`Allocation`]s; the engine
//! enforces feasibility (never more than free capacity, never more than a
//! job's pending tasks).

pub mod capacity;
pub mod dress;
pub mod fair;
pub mod fifo;
pub mod maxweight;
pub mod shadow;

pub use capacity::CapacityScheduler;
pub use dress::DressScheduler;
pub use fair::FairScheduler;
pub use fifo::FifoScheduler;
pub use maxweight::MaxWeightScheduler;
pub use shadow::{SchedSnapshot, ShadowEvent, ShadowScore, ShadowWindow};

use crate::cluster::Transition;
use crate::config::{SchedConfig, SchedKind};
use crate::jobs::{Demand, JobId};
use crate::util::Time;

/// What the scheduler can see about one job (observable via YARN requests
/// and heartbeats — no ground-truth task durations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JobView {
    pub id: JobId,
    /// Resource vector requested at submission.  Axis 0 (`demand.cpu`) is
    /// the paper's `r_i` — the container-count grant currency every
    /// scheduler reasons in; axis 1 (`demand.mem`) is job-level memory.
    /// `demand.mem_per_container()` is the per-grant memory footprint
    /// (exactly 1 for scalar demands).
    pub demand: Demand,
    pub submit_ms: Time,
    /// Job has at least one task past Pending.
    pub started: bool,
    pub finished: bool,
    /// Tasks of the current runnable phase still waiting for containers.
    pub pending_tasks: u32,
    /// Containers currently held.
    pub occupied: u32,
}

/// Observable cluster state at a heartbeat.
///
/// `jobs` is borrowed from the engine's incrementally-maintained active-job
/// list (perf iter 4): the engine retires finished jobs on completion and
/// hands schedulers a slice instead of rebuilding a vector every tick, so
/// per-tick view cost is O(1) and per-event maintenance is O(1).
#[derive(Debug, Clone)]
pub struct ClusterView<'a> {
    pub now: Time,
    /// Free containers (the paper's `A_c`).
    pub free: u32,
    /// Total containers (the paper's `Tot_R`).  **Time-varying** under a
    /// fault plan: crashed nodes drop out of this figure until they
    /// recover, so schedulers must re-derive any capacity split from the
    /// view every heartbeat rather than caching a construction-time total.
    /// May be 0 while every node is down.
    pub total: u32,
    /// Free memory units (axis 1).  In scalar runs every container has a
    /// one-unit footprint, so `free_mem == free` invariantly.
    pub free_mem: u32,
    /// Total memory units across live nodes — time-varying under a fault
    /// plan exactly like `total`.
    pub total_mem: u32,
    /// Submitted jobs in submission order.  May include already-finished
    /// entries with `finished = true` — the engine tombstones completed
    /// jobs until its next compaction, and live mode plus the engine's
    /// naive reference path expose finished jobs indefinitely — so every
    /// scheduler MUST keep filtering on `!finished` (see
    /// tests/golden_determinism.rs for the equivalence contract).
    pub jobs: &'a [JobView],
    /// Container transitions observed since the previous heartbeat.
    pub transitions: &'a [Transition],
}

impl ClusterView<'_> {
    pub fn active_jobs(&self) -> impl Iterator<Item = &JobView> {
        self.jobs.iter().filter(|j| !j.finished)
    }

    /// Free capacity as a resource vector (cpu slots, memory units).
    pub fn free_vec(&self) -> Demand {
        Demand::new(self.free, self.free_mem)
    }

    /// Total capacity as a resource vector.
    pub fn total_vec(&self) -> Demand {
        Demand::new(self.total, self.total_mem)
    }
}

/// A grant of `n` containers to a job this round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Allocation {
    pub job: JobId,
    pub n: u32,
}

/// The scheduler interface.
///
/// Two required methods drive simulation; everything else is the
/// **SchedIntrospect** surface below — optional hooks with no-op defaults,
/// so a new scheduler implements exactly `name` + `schedule` and inherits
/// correct (empty) introspection for free.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Called once per heartbeat. Must return feasible allocations; the
    /// engine additionally clamps to free capacity and pending tasks, and
    /// enforces per-node memory feasibility at allocation time.
    fn schedule(&mut self, view: &ClusterView) -> Vec<Allocation>;

    // ------------------------------------------------------------------
    // SchedIntrospect: optional observation & tuning hooks.
    //
    // Contract: every method here has a default impl that reports
    // "nothing to see" and changes no behavior.  Reports, the CLI, and
    // the admission front call these on `dyn Scheduler` without knowing
    // the concrete type; only DRESS-family schedulers override them.
    // Do NOT copy-paste no-op bodies into new schedulers — the defaults
    // are the no-ops.
    // ------------------------------------------------------------------

    /// Introspection for reports: DRESS's current reserve ratio δ.
    /// `None` for schedulers without a reservation split.
    fn reserve_ratio(&self) -> Option<f64> {
        None
    }

    /// Opt-in online shadow tuner (`EngineOptions::tune_delta`).  Default
    /// is a no-op: only DRESS has a δ to tune, and with the flag off the
    /// tuner path must cost nothing (see docs/ADMISSION.md).
    fn set_tune_delta(&mut self, on: bool) {
        let _ = on;
    }

    /// Configure the shadow tuner's re-tune cadence (heartbeats) and
    /// window capacity (events) — `EngineOptions::{tune_every,
    /// shadow_window}`.  Default is a no-op for schedulers with no tuner;
    /// inert for DRESS too unless `set_tune_delta(true)` arms it.
    fn set_tune_params(&mut self, every: u32, window: usize) {
        let _ = (every, window);
    }

    /// Freeze the scheduler's tunable state into a [`shadow::SchedSnapshot`]
    /// for what-if evaluation.  `None` for schedulers with no hidden state
    /// (callers fall back to [`shadow::SchedSnapshot::of_view`]).
    fn snapshot(&self, view: &ClusterView) -> Option<shadow::SchedSnapshot> {
        let _ = view;
        None
    }
}

/// Construct a scheduler from config. `total` is the *provisioned* cluster
/// container count; schedulers treat it as a hint only and follow the live
/// `ClusterView::total` for capacity splits.
pub fn build(cfg: &SchedConfig, total: u32) -> Box<dyn Scheduler> {
    match cfg.kind {
        SchedKind::Fifo => Box::new(FifoScheduler::new(cfg.gang)),
        SchedKind::Fair => Box::new(FairScheduler::new()),
        SchedKind::Capacity => Box::new(CapacityScheduler::new(cfg.gang)),
        SchedKind::Dress => Box::new(DressScheduler::new(cfg, total)),
        SchedKind::MaxWeight => Box::new(MaxWeightScheduler::new()),
    }
}

/// Shared helper: refill already-started, unfinished jobs up to their demand
/// (YARN keeps feeding an admitted application's outstanding requests).
/// Returns allocations and the remaining free count.
pub(crate) fn refill_started(view: &ClusterView, mut free: u32) -> (Vec<Allocation>, u32) {
    let mut out = Vec::new();
    for j in view.jobs.iter().filter(|j| j.started && !j.finished) {
        if free == 0 {
            break;
        }
        let budget = j.demand.cpu.saturating_sub(j.occupied);
        let want = budget.min(j.pending_tasks).min(free);
        if want > 0 {
            out.push(Allocation { job: j.id, n: want });
            free -= want;
        }
    }
    (out, free)
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;

    /// Build a ClusterView for scheduler unit tests.  The job list is
    /// leaked to get a `'static` borrow — fine for test-sized inputs.
    pub fn view(free: u32, total: u32, jobs: Vec<JobView>) -> ClusterView<'static> {
        ClusterView {
            now: 0,
            free,
            total,
            free_mem: free,
            total_mem: total,
            jobs: Box::leak(jobs.into_boxed_slice()),
            transitions: &[],
        }
    }

    /// A test view where the memory axis differs from the cpu axis.
    pub fn view_mem(
        free: u32,
        total: u32,
        free_mem: u32,
        total_mem: u32,
        jobs: Vec<JobView>,
    ) -> ClusterView<'static> {
        ClusterView {
            now: 0,
            free,
            total,
            free_mem,
            total_mem,
            jobs: Box::leak(jobs.into_boxed_slice()),
            transitions: &[],
        }
    }

    pub fn jv(id: JobId, demand: u32, pending: u32) -> JobView {
        JobView {
            id,
            demand: Demand::scalar(demand),
            submit_ms: id as Time * 1_000,
            started: false,
            finished: false,
            pending_tasks: pending,
            occupied: 0,
        }
    }

    /// A job view with a true vector demand.
    pub fn jv_vec(id: JobId, demand: Demand, pending: u32) -> JobView {
        JobView { demand, ..jv(id, demand.cpu, pending) }
    }

    pub fn started(mut j: JobView, occupied: u32) -> JobView {
        j.started = true;
        j.occupied = occupied;
        j
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::*;
    use super::*;
    use crate::config::SchedConfig;

    #[test]
    fn build_all_kinds() {
        for kind in [
            SchedKind::Fifo,
            SchedKind::Fair,
            SchedKind::Capacity,
            SchedKind::Dress,
            SchedKind::MaxWeight,
        ] {
            let cfg = SchedConfig { kind, ..SchedConfig::default() };
            let s = build(&cfg, 40);
            assert_eq!(s.name(), kind.name());
        }
    }

    #[test]
    fn introspect_defaults_report_nothing() {
        // The SchedIntrospect surface must be inherited, not copy-pasted:
        // schedulers without hidden state get None/no-op from the trait.
        for kind in [SchedKind::Fifo, SchedKind::Fair, SchedKind::Capacity, SchedKind::MaxWeight] {
            let cfg = SchedConfig { kind, ..SchedConfig::default() };
            let mut s = build(&cfg, 40);
            assert_eq!(s.reserve_ratio(), None, "{}", s.name());
            s.set_tune_delta(true); // must be a harmless no-op
            let v = view(4, 40, vec![jv(1, 2, 2)]);
            assert!(s.snapshot(&v).is_none(), "{}", s.name());
        }
        let cfg = SchedConfig { kind: SchedKind::Dress, ..SchedConfig::default() };
        let s = build(&cfg, 40);
        assert!(s.reserve_ratio().is_some(), "dress overrides the introspect surface");
    }

    #[test]
    fn refill_prioritizes_started_jobs() {
        let jobs = vec![
            started(jv(1, 4, 2), 2), // wants 2 more
            jv(2, 10, 10),           // not started: ignored by refill
            started(jv(3, 6, 9), 3), // budget 3, pending 9 -> 3
        ];
        let v = view(4, 40, jobs);
        let (allocs, free) = refill_started(&v, v.free);
        assert_eq!(allocs, vec![Allocation { job: 1, n: 2 }, Allocation { job: 3, n: 2 }]);
        assert_eq!(free, 0);
    }

    #[test]
    fn refill_respects_demand_cap() {
        let jobs = vec![started(jv(1, 4, 10), 4)]; // at demand: no refill
        let v = view(8, 40, jobs);
        let (allocs, free) = refill_started(&v, v.free);
        assert!(allocs.is_empty());
        assert_eq!(free, 8);
    }
}
