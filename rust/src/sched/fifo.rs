//! Strict first-come-first-serve scheduler — the "FCFS manner" of the
//! paper's motivating example (Fig. 1): the head-of-line job blocks
//! everything behind it until it can start.

use super::{refill_started, Allocation, ClusterView, Scheduler};

/// FIFO with optional gang admission.
///
/// * `gang = true` (paper's Fig. 1 semantics): an unstarted job launches
///   only when its *full* demand fits in the free pool.
/// * `gang = false`: the head job may start with partial resources.
///
/// In both modes, jobs behind an unstartable head wait (no skipping).
///
/// `strict` additionally freezes the queue behind any job that was ever
/// delayed, until that job *finishes* — the paper's idealized Fig. 1 FCFS
/// narrative (J3/J4 wait for J2's completion even though containers are
/// free).  Real YARN backfills; strict mode exists to reproduce the
/// motivating example's exact arithmetic.
#[derive(Debug, Clone)]
pub struct FifoScheduler {
    gang: bool,
    strict: bool,
    delayed: std::collections::BTreeSet<crate::jobs::JobId>,
}

impl FifoScheduler {
    pub fn new(gang: bool) -> Self {
        FifoScheduler { gang, strict: false, delayed: Default::default() }
    }

    /// The paper's Fig. 1 FCFS (gang + frozen queue behind delayed jobs).
    pub fn strict() -> Self {
        FifoScheduler { gang: true, strict: true, delayed: Default::default() }
    }
}

impl Scheduler for FifoScheduler {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn schedule(&mut self, view: &ClusterView) -> Vec<Allocation> {
        // 1. Keep feeding already-admitted jobs.
        let (mut allocs, mut free) = refill_started(view, view.free);
        // Strict mode: a once-delayed job freezes the queue until it ends.
        if self.strict
            && view
                .jobs
                .iter()
                .any(|j| j.started && !j.finished && self.delayed.contains(&j.id))
        {
            return allocs;
        }
        // 2. Admit unstarted jobs strictly in submission order.
        for j in view.jobs.iter().filter(|j| !j.started && !j.finished) {
            if free == 0 {
                break;
            }
            let want = j.demand.cpu.min(j.pending_tasks);
            if want == 0 {
                continue;
            }
            if self.gang && want > free {
                self.delayed.insert(j.id);
                break; // head-of-line blocks the queue
            }
            let n = want.min(free);
            allocs.push(Allocation { job: j.id, n });
            free -= n;
            if self.strict && self.delayed.contains(&j.id) {
                break; // a once-delayed job freezes the queue as it starts
            }
            if !self.gang && free == 0 {
                break;
            }
        }
        allocs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::testutil::*;

    #[test]
    fn gang_head_of_line_blocks() {
        // Fig 1: 6 containers; J1 (R3) running, J2 (R4) can't fit, so J3
        // (R2) and J4 (R2) must wait even though they would fit.
        let jobs = vec![
            started(jv(1, 3, 0), 3),
            jv(2, 4, 4),
            jv(3, 2, 2),
            jv(4, 2, 2),
        ];
        let mut s = FifoScheduler::new(true);
        let allocs = s.schedule(&view(3, 6, jobs));
        assert!(allocs.is_empty(), "J2 blocks: {allocs:?}");
    }

    #[test]
    fn gang_admits_in_order_when_fits() {
        let jobs = vec![jv(1, 3, 3), jv(2, 2, 2), jv(3, 4, 4)];
        let mut s = FifoScheduler::new(true);
        let allocs = s.schedule(&view(6, 6, jobs));
        // J1 (3) + J2 (2) fit; J3 (4) blocks at 1 free.
        assert_eq!(allocs, vec![Allocation { job: 1, n: 3 }, Allocation { job: 2, n: 2 }]);
    }

    #[test]
    fn non_gang_takes_partial() {
        let jobs = vec![jv(1, 8, 8)];
        let mut s = FifoScheduler::new(false);
        let allocs = s.schedule(&view(3, 6, jobs));
        assert_eq!(allocs, vec![Allocation { job: 1, n: 3 }]);
    }

    #[test]
    fn demand_caps_even_with_more_pending() {
        // Job pending tasks 10 but demand 4: only 4 granted.
        let jobs = vec![jv(1, 4, 10)];
        let mut s = FifoScheduler::new(true);
        let allocs = s.schedule(&view(10, 10, jobs));
        assert_eq!(allocs, vec![Allocation { job: 1, n: 4 }]);
    }

    #[test]
    fn strict_mode_freezes_queue_behind_delayed_job() {
        let mut s = FifoScheduler::strict();
        // Round 1: J2 (R4) blocks with 3 free -> marked delayed.
        let jobs = vec![started(jv(1, 3, 0), 3), jv(2, 4, 4), jv(3, 2, 2)];
        assert!(s.schedule(&view(3, 6, jobs)).is_empty());
        // Round 2: J1 done; J2 admitted; J3 must NOT backfill while the
        // once-delayed J2 runs, even with 2 containers free.
        let jobs = vec![jv(2, 4, 4), jv(3, 2, 2)];
        let allocs = s.schedule(&view(6, 6, jobs));
        assert_eq!(allocs, vec![Allocation { job: 2, n: 4 }]);
        // Round 3: J2 running (started, delayed) -> queue frozen.
        let jobs = vec![started(jv(2, 4, 0), 4), jv(3, 2, 2)];
        assert!(s.schedule(&view(2, 6, jobs)).is_empty());
        // Round 4: J2 finished -> J3 finally admitted.
        let mut f = jv(2, 4, 0);
        f.finished = true;
        f.started = true;
        let jobs = vec![f, jv(3, 2, 2)];
        let allocs = s.schedule(&view(6, 6, jobs));
        assert_eq!(allocs, vec![Allocation { job: 3, n: 2 }]);
    }

    #[test]
    fn finished_jobs_are_skipped() {
        let mut f = jv(1, 4, 0);
        f.finished = true;
        let jobs = vec![f, jv(2, 2, 2)];
        let mut s = FifoScheduler::new(true);
        let allocs = s.schedule(&view(6, 6, jobs));
        assert_eq!(allocs, vec![Allocation { job: 2, n: 2 }]);
    }
}
