//! Fair scheduler baseline: "assigning resources to jobs such that all jobs
//! get, on average, an equal share of resources over time" (paper §I).
//!
//! Max-min fairness over containers, no preemption: each heartbeat the free
//! containers are granted to the active jobs furthest below their fair
//! share (water-filling), capped by demand and pending tasks.

use super::{Allocation, ClusterView, Scheduler};

#[derive(Debug, Clone, Default)]
pub struct FairScheduler;

impl FairScheduler {
    pub fn new() -> Self {
        FairScheduler
    }
}

impl Scheduler for FairScheduler {
    fn name(&self) -> &'static str {
        "fair"
    }

    fn schedule(&mut self, view: &ClusterView) -> Vec<Allocation> {
        // Jobs that can absorb containers now.
        let mut eligible: Vec<(u32, u32, u32)> = view // (idx, occupied, cap)
            .jobs
            .iter()
            .enumerate()
            .filter(|(_, j)| !j.finished && j.pending_tasks > 0 && j.occupied < j.demand.cpu)
            .map(|(i, j)| {
                let cap =
                    j.occupied + j.demand.cpu.saturating_sub(j.occupied).min(j.pending_tasks);
                (i as u32, j.occupied, cap)
            })
            .collect();
        if eligible.is_empty() {
            return Vec::new();
        }

        // Water-filling: repeatedly grant one container to the eligible job
        // with the lowest current occupancy (FIFO tie-break by index).
        let mut grants = vec![0u32; view.jobs.len()];
        let mut free = view.free;
        while free > 0 {
            let Some(best) = eligible
                .iter_mut()
                .filter(|(_, occ, cap)| *occ < *cap)
                .min_by_key(|(idx, occ, _)| (*occ, *idx))
            else {
                break;
            };
            best.1 += 1;
            grants[best.0 as usize] += 1;
            free -= 1;
        }

        grants
            .iter()
            .enumerate()
            .filter(|(_, &n)| n > 0)
            .map(|(i, &n)| Allocation { job: view.jobs[i].id, n })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::testutil::*;

    #[test]
    fn equal_split_between_equal_jobs() {
        let jobs = vec![jv(1, 6, 6), jv(2, 6, 6)];
        let mut s = FairScheduler::new();
        let allocs = s.schedule(&view(6, 6, jobs));
        assert_eq!(allocs, vec![Allocation { job: 1, n: 3 }, Allocation { job: 2, n: 3 }]);
    }

    #[test]
    fn waterfill_favors_underfilled() {
        // J1 already holds 4; J2 holds 0. 4 free -> J2 gets all 4.
        let jobs = vec![started(jv(1, 8, 4), 4), jv(2, 8, 8)];
        let mut s = FairScheduler::new();
        let allocs = s.schedule(&view(4, 8, jobs));
        assert_eq!(allocs, vec![Allocation { job: 2, n: 4 }]);
    }

    #[test]
    fn demand_and_pending_cap_shares() {
        // J1 can take at most 2 (demand), J2 at most 1 (pending).
        let jobs = vec![jv(1, 2, 5), jv(2, 8, 1)];
        let mut s = FairScheduler::new();
        let allocs = s.schedule(&view(8, 8, jobs));
        assert_eq!(allocs, vec![Allocation { job: 1, n: 2 }, Allocation { job: 2, n: 1 }]);
    }

    #[test]
    fn no_eligible_jobs_no_allocs() {
        let jobs = vec![started(jv(1, 2, 0), 2)];
        let mut s = FairScheduler::new();
        assert!(s.schedule(&view(6, 8, jobs)).is_empty());
    }

    #[test]
    fn leftover_when_all_capped() {
        let jobs = vec![jv(1, 1, 1), jv(2, 1, 1)];
        let mut s = FairScheduler::new();
        let allocs = s.schedule(&view(8, 8, jobs));
        let total: u32 = allocs.iter().map(|a| a.n).sum();
        assert_eq!(total, 2);
    }
}
