//! Max-weight-over-configurations baseline (arXiv 1901.05998, Psychas &
//! Ghaderi: "Randomized Algorithms for Scheduling Multi-Resource Jobs in
//! the Cloud").
//!
//! The exact max-weight policy picks, each scheduling instant, the
//! feasible *configuration* (a packing of queued jobs onto the residual
//! capacity vector) with the largest total weight, where a job's weight
//! is its queue backlog.  Solving that packing exactly is NP-hard for
//! vector demands, so — following the paper's greedy approximation — we
//! build the configuration incrementally: visit jobs in descending
//! backlog order and grant each as many containers as its demand, its
//! backlog, and the residual capacity on *every* axis allow.
//!
//! Properties relied on elsewhere:
//! - **Deterministic, zero-RNG.** Ties break by (submit time, job id),
//!   so the same view always yields the same allocation sequence —
//!   goldens and shard/merge byte-identity hold for this scheduler too.
//! - **Fully vector-aware.** Unlike fifo/fair/capacity (cpu-axis only,
//!   with the engine enforcing per-node memory feasibility), max-weight
//!   clamps its grants by the free-memory axis directly, so its
//!   configurations are feasible in aggregate by construction.
//! - **No introspection.** Only `name`/`schedule` are implemented; the
//!   `SchedIntrospect` defaults (no reserve ratio, no tuning, no
//!   snapshot) apply as-is.

use super::{Allocation, ClusterView, Scheduler};

#[derive(Debug, Clone, Default)]
pub struct MaxWeightScheduler;

impl MaxWeightScheduler {
    pub fn new() -> Self {
        MaxWeightScheduler
    }
}

impl Scheduler for MaxWeightScheduler {
    fn name(&self) -> &'static str {
        "maxweight"
    }

    fn schedule(&mut self, view: &ClusterView) -> Vec<Allocation> {
        // Candidate jobs with positive backlog, heaviest first.  There is
        // no started/waiting distinction: refills and admissions compete
        // on backlog alone, as in the max-weight formulation.
        let mut order: Vec<&super::JobView> = view
            .jobs
            .iter()
            .filter(|j| !j.finished && j.pending_tasks > 0 && j.occupied < j.demand.cpu)
            .collect();
        order.sort_by_key(|j| (core::cmp::Reverse(j.pending_tasks), j.submit_ms, j.id));

        let mut free = view.free;
        let mut free_mem = view.free_mem;
        let mut allocs = Vec::new();
        for j in order {
            if free == 0 {
                break;
            }
            let mpt = j.demand.mem_per_container().max(1);
            let budget = j.demand.cpu.saturating_sub(j.occupied).min(j.pending_tasks);
            let n = budget.min(free).min(free_mem / mpt);
            if n > 0 {
                allocs.push(Allocation { job: j.id, n });
                free -= n;
                free_mem -= n * mpt;
            }
        }
        allocs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobs::Demand;
    use crate::sched::testutil::*;

    #[test]
    fn heaviest_backlog_first() {
        // J2 has the larger backlog and is served first despite arriving
        // later; J1 takes the leftovers.
        let jobs = vec![jv(1, 4, 2), jv(2, 4, 4)];
        let mut s = MaxWeightScheduler::new();
        let allocs = s.schedule(&view(5, 8, jobs));
        assert_eq!(allocs, vec![Allocation { job: 2, n: 4 }, Allocation { job: 1, n: 1 }]);
    }

    #[test]
    fn backlog_ties_break_by_submit_order() {
        let jobs = vec![jv(1, 4, 3), jv(2, 4, 3)];
        let mut s = MaxWeightScheduler::new();
        let allocs = s.schedule(&view(4, 8, jobs));
        assert_eq!(allocs, vec![Allocation { job: 1, n: 3 }, Allocation { job: 2, n: 1 }]);
    }

    #[test]
    fn refills_compete_on_backlog_capped_by_demand() {
        // Started J1 (occupies 2 of its 4) only takes 2 more even though
        // its backlog is 6.
        let jobs = vec![started(jv(1, 4, 6), 2), jv(2, 8, 3)];
        let mut s = MaxWeightScheduler::new();
        let allocs = s.schedule(&view(8, 8, jobs));
        assert_eq!(allocs, vec![Allocation { job: 1, n: 2 }, Allocation { job: 2, n: 3 }]);
    }

    #[test]
    fn memory_axis_limits_the_configuration() {
        // 10 free slots but only 8 memory units: the fat job (2 units per
        // container) fits 4 containers, and the drained memory axis then
        // starves the thin job even though slots remain.
        let jobs = vec![jv_vec(1, Demand::new(10, 20), 10), jv_vec(2, Demand::new(6, 6), 3)];
        let mut s = MaxWeightScheduler::new();
        let allocs = s.schedule(&view_mem(10, 40, 8, 40, jobs));
        assert_eq!(allocs, vec![Allocation { job: 1, n: 4 }]);
    }

    #[test]
    fn deterministic_across_calls() {
        let jobs = vec![jv(1, 6, 6), jv(2, 3, 3), jv(3, 6, 5)];
        let mut s = MaxWeightScheduler::new();
        let a = s.schedule(&view(9, 12, jobs.clone()));
        let b = s.schedule(&view(9, 12, jobs));
        assert_eq!(a, b);
    }
}
