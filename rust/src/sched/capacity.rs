//! Capacity scheduler baseline — the paper's primary comparator.
//!
//! YARN's CapacityScheduler shares a cluster between queues with guaranteed
//! capacities; *within* a queue, applications are admitted
//! first-come-first-serve (paper §I: "both of them add jobs to the queues
//! following a first-come-first-serve manner").  The paper's experiments
//! use the stock single-queue setup, which this reproduces by default; the
//! two-queue configuration is exercised in tests/ablations.

use super::{refill_started, Allocation, ClusterView, Scheduler};
use crate::jobs::JobId;

#[derive(Debug, Clone)]
pub struct CapacityScheduler {
    gang: bool,
    /// Guaranteed fraction of the cluster per queue (must sum to <= 1).
    queue_caps: Vec<f64>,
    /// Routing: job -> queue (default: everything to queue 0).
    route: fn(JobId) -> usize,
}

fn route_all_to_default(_j: JobId) -> usize {
    0
}

impl CapacityScheduler {
    /// Stock single-queue Capacity scheduler (the paper's baseline).
    pub fn new(gang: bool) -> Self {
        CapacityScheduler { gang, queue_caps: vec![1.0], route: route_all_to_default }
    }

    /// Multi-queue variant for ablations.
    pub fn with_queues(gang: bool, caps: Vec<f64>, route: fn(JobId) -> usize) -> Self {
        assert!(!caps.is_empty());
        let sum: f64 = caps.iter().sum();
        assert!(sum <= 1.0 + 1e-9, "queue capacities exceed cluster: {sum}");
        CapacityScheduler { gang, queue_caps: caps, route }
    }
}

impl Scheduler for CapacityScheduler {
    fn name(&self) -> &'static str {
        "capacity"
    }

    fn schedule(&mut self, view: &ClusterView) -> Vec<Allocation> {
        // Refill admitted jobs first (YARN serves outstanding requests of
        // running apps before admitting new ones).
        let (mut allocs, mut free) = refill_started(view, view.free);

        // Per-queue occupancy (running jobs count against their queue).
        let nq = self.queue_caps.len();
        let mut used = vec![0u32; nq];
        for j in view.jobs.iter().filter(|j| !j.finished) {
            used[(self.route)(j.id).min(nq - 1)] += j.occupied;
        }
        for a in &allocs {
            used[(self.route)(a.job).min(nq - 1)] += a.n;
        }

        // FCFS admission within each queue, respecting queue guarantees.
        let mut blocked = vec![false; nq];
        for j in view.jobs.iter().filter(|j| !j.started && !j.finished) {
            if free == 0 {
                break;
            }
            let q = (self.route)(j.id).min(nq - 1);
            if blocked[q] {
                continue; // FIFO within queue: head blocks its own queue only
            }
            let cap = (self.queue_caps[q] * view.total as f64).round() as u32;
            let head_room = cap.saturating_sub(used[q]).min(free);
            let want = j.demand.cpu.min(j.pending_tasks);
            if want == 0 {
                continue;
            }
            if self.gang && want > head_room {
                blocked[q] = true;
                continue;
            }
            let n = want.min(head_room);
            if n == 0 {
                blocked[q] = true;
                continue;
            }
            allocs.push(Allocation { job: j.id, n });
            used[q] += n;
            free -= n;
        }
        allocs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::testutil::*;

    #[test]
    fn single_queue_behaves_fcfs_gang() {
        let jobs = vec![jv(1, 3, 3), jv(2, 4, 4), jv(3, 2, 2)];
        let mut s = CapacityScheduler::new(true);
        let allocs = s.schedule(&view(6, 6, jobs));
        // J1 admitted (3), J2 needs 4 > 3 free -> queue blocks; J3 waits.
        assert_eq!(allocs, vec![Allocation { job: 1, n: 3 }]);
    }

    #[test]
    fn refill_before_admission() {
        // 8-container queue: J1 (occupies 2, wants 2 more), J2 gang-needs 4.
        let jobs = vec![started(jv(1, 4, 2), 2), jv(2, 4, 4)];
        let mut s = CapacityScheduler::new(true);
        let allocs = s.schedule(&view(6, 8, jobs));
        assert_eq!(
            allocs,
            vec![Allocation { job: 1, n: 2 }, Allocation { job: 2, n: 4 }]
        );
    }

    fn route_even_odd(j: JobId) -> usize {
        (j % 2) as usize
    }

    #[test]
    fn queues_isolate_head_of_line_blocking() {
        // Queue 0 (even ids) capacity 0.5, queue 1 (odd) 0.5 of 8 = 4 each.
        // J1 (odd, demand 6) blocks queue 1; J2 (even, demand 3) admitted.
        let jobs = vec![jv(1, 6, 6), jv(2, 3, 3)];
        let mut s = CapacityScheduler::with_queues(true, vec![0.5, 0.5], route_even_odd);
        let allocs = s.schedule(&view(8, 8, jobs));
        assert_eq!(allocs, vec![Allocation { job: 2, n: 3 }]);
    }

    #[test]
    fn queue_cap_limits_admission() {
        // Queue 0 cap = 25% of 8 = 2: J2 (even, demand 3) cannot gang-start.
        let jobs = vec![jv(2, 3, 3)];
        let mut s = CapacityScheduler::with_queues(true, vec![0.25, 0.75], route_even_odd);
        assert!(s.schedule(&view(8, 8, jobs)).is_empty());
        // Non-gang: partial admission up to the queue cap.
        let jobs = vec![jv(2, 3, 3)];
        let mut s = CapacityScheduler::with_queues(false, vec![0.25, 0.75], route_even_odd);
        assert_eq!(s.schedule(&view(8, 8, jobs)), vec![Allocation { job: 2, n: 2 }]);
    }

    #[test]
    #[should_panic(expected = "queue capacities exceed")]
    fn overcommitted_queues_rejected() {
        CapacityScheduler::with_queues(true, vec![0.7, 0.7], route_even_odd);
    }
}
