//! DRESS — the paper's contribution: two category pools with a dynamically
//! adjusted reserve ratio δ, driven by the release estimator.
//!
//! Per heartbeat:
//! 1. classify newly submitted jobs (θ rule, [`categories`]),
//! 2. feed heartbeat transitions to the estimator (Algorithms 1-2),
//! 3. adjust δ (Algorithm 3, [`reserve`]) using F₁/F₂(t+1),
//! 4. allocate: refill running jobs from their category pool, admit
//!    waiting jobs FCFS-within-category against the pool quota, and move
//!    LD leftovers to SD jobs (ascending demand) when both pools are
//!    congested.

pub mod categories;
pub mod multi;
pub mod reserve;

pub use categories::{Category, Classifier};
pub use multi::MultiDress;
pub use reserve::{adjust, ReserveInputs};

use super::shadow::{self, SchedSnapshot, ShadowEvent, ShadowWindow};
use super::{Allocation, ClusterView, JobView, Scheduler};
use crate::config::SchedConfig;
use crate::estimator::{EstimatorBank, EstimatorParams};
use crate::jobs::JobId;
use crate::util::Time;
use std::collections::{BTreeSet, HashSet};

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DressStats {
    pub delta: f64,
    pub sd_jobs: u32,
    pub ld_jobs: u32,
}

pub struct DressScheduler {
    classifier: Classifier,
    estimator: EstimatorBank,
    delta: f64,
    hb_ms: Time,
    gang: bool,
    /// Ablation: freeze δ at its initial value (disables Algorithm 3).
    pub freeze_delta: bool,
    /// Ablation: ignore the release estimator (F₁ = F₂ = 0 in Algorithm 3).
    pub disable_estimator: bool,
    /// Reference path (perf iter 6): tick every estimator per heartbeat
    /// instead of only the dirty set.  Bit-identical by construction; kept
    /// for equivalence goldens.
    pub naive_estimator_tick: bool,
    /// Opt-in online δ auto-tuner (`EngineOptions::tune_delta`): every
    /// [`shadow::DEFAULT_TUNE_EVERY`] heartbeats, replay the recent
    /// submit/complete window against a snapshot under a candidate ladder
    /// and adopt the winner.  Off by default; when off, none of the tuner
    /// state below is ever touched (zero-overhead disabled path — pinned
    /// by the golden inertness test).
    pub tune_delta: bool,
    /// Tuner cadence K, in heartbeats.
    pub tune_every: u32,
    /// Heartbeats since the last re-tune.
    tune_ticks: u32,
    /// Ring buffer of recent submit/complete observations.
    window: ShadowWindow,
    /// Active jobs currently tracked by the observer (BTreeSet: completion
    /// events must enter the window in deterministic ascending-id order).
    tracked: BTreeSet<JobId>,
}

impl DressScheduler {
    /// `_total` is the provisioned capacity; DRESS re-derives its split
    /// from the *live* `ClusterView::total` each heartbeat (time-varying
    /// under a fault plan), so construction keeps no capacity state.
    pub fn new(cfg: &SchedConfig, _total: u32) -> Self {
        DressScheduler {
            classifier: Classifier::new(cfg.theta),
            estimator: EstimatorBank::new(EstimatorParams {
                ts: cfg.ts,
                te: cfg.te,
                pw_ms: cfg.pw_ms,
            }),
            delta: cfg.delta0,
            hb_ms: 1_000,
            gang: cfg.gang,
            freeze_delta: false,
            disable_estimator: false,
            naive_estimator_tick: false,
            tune_delta: false,
            tune_every: shadow::DEFAULT_TUNE_EVERY,
            tune_ticks: 0,
            window: ShadowWindow::new(shadow::DEFAULT_WINDOW),
            tracked: BTreeSet::new(),
        }
    }

    pub fn delta(&self) -> f64 {
        self.delta
    }

    /// Freeze classifier + estimator + δ + the observable view into a
    /// cheaply-cloneable [`SchedSnapshot`] (docs/ADMISSION.md).
    pub fn snapshot(&self, view: &ClusterView) -> SchedSnapshot {
        SchedSnapshot {
            now: view.now,
            free: view.free,
            total: view.total,
            jobs: view.jobs.to_vec(),
            delta: self.delta,
            classifier: self.classifier.clone(),
            estimator: self.estimator.clone(),
        }
    }

    /// Restore tunable state from a snapshot — the inverse of
    /// [`Self::snapshot`] for shadow executors that borrow the live
    /// scheduler, run a what-if, and put it back.
    pub fn restore(&mut self, snap: &SchedSnapshot) {
        self.classifier = snap.classifier.clone();
        self.estimator = snap.estimator.clone();
        self.delta = snap.delta;
    }

    /// Record this heartbeat's submit/complete deltas into the shadow
    /// window.  Only called while the tuner is on.
    fn observe(&mut self, view: &ClusterView) {
        let now = view.now;
        let mut present: HashSet<JobId> = HashSet::with_capacity(view.jobs.len());
        for j in view.jobs.iter().filter(|j| !j.finished) {
            present.insert(j.id);
            if self.tracked.insert(j.id) {
                self.window.push(ShadowEvent::Submit {
                    job: j.id,
                    demand: j.demand.cpu,
                    at: now,
                });
            }
        }
        // Jobs that left the view (finished, then tombstoned or compacted
        // away) complete in ascending-id order — deterministic window
        // contents regardless of hash-set iteration order.
        let gone: Vec<JobId> =
            self.tracked.iter().copied().filter(|id| !present.contains(id)).collect();
        for id in gone {
            self.tracked.remove(&id);
            self.window.push(ShadowEvent::Complete { job: id, at: now });
        }
    }

    pub fn stats(&self, view: &ClusterView) -> DressStats {
        let (mut sd, mut ld) = (0, 0);
        for j in view.active_jobs() {
            match self.classifier.get(j.id) {
                Some(Category::Sd) => sd += 1,
                Some(Category::Ld) => ld += 1,
                None => {}
            }
        }
        DressStats { delta: self.delta, sd_jobs: sd, ld_jobs: ld }
    }

    fn category(&self, job: JobId) -> Category {
        self.classifier.get(job).unwrap_or(Category::Sd)
    }

    /// Pool quotas over the *live* capacity: SD gets round(δ·Tot), LD the
    /// rest.  `total` must be >= 2 (both pools need at least one slot).
    fn quotas(&self, total: u32) -> (u32, u32) {
        let sd = ((self.delta * total as f64).round() as u32).clamp(1, total - 1);
        (sd, total - sd)
    }

    /// FCFS-with-ascending-fallback admission inside one category.
    ///
    /// `borrow` is extra headroom lent by the *other* category's idle pool
    /// (used when LD admits while no SD job is waiting — without it, a job
    /// demanding more than the LD quota could starve forever even on an
    /// idle cluster).  Deducted only after the own pool is exhausted.
    ///
    /// `free_mem` is the memory-axis headroom: each grant of `n` containers
    /// to a job with per-container footprint `m` consumes `n·m` units, and
    /// grants are clamped so the footprint always fits.  In scalar runs
    /// `m == 1` and `free_mem` starts equal to `free` and is debited
    /// identically, so the clamp is a provable no-op (see
    /// docs/RESOURCES.md).
    fn admit_category(
        &self,
        waiting: &[&JobView],
        pool_free: &mut u32,
        borrow: &mut u32,
        free: &mut u32,
        free_mem: &mut u32,
        allocs: &mut Vec<Allocation>,
    ) {
        let mut grant = |j: &JobView,
                         pool_free: &mut u32,
                         borrow: &mut u32,
                         free: &mut u32,
                         free_mem: &mut u32|
         -> Option<u32> {
            let mpt = j.demand.mem_per_container().max(1);
            let want = j.demand.cpu.min(j.pending_tasks);
            if want == 0 {
                return Some(0);
            }
            let room = (*pool_free + *borrow).min(*free).min(*free_mem / mpt);
            if self.gang && want > room {
                return None;
            }
            let n = want.min(room);
            if n == 0 {
                return None;
            }
            let own = n.min(*pool_free);
            *pool_free -= own;
            *borrow -= n - own;
            *free -= n;
            *free_mem -= n * mpt;
            Some(n)
        };
        // First pass: FCFS gang.
        let mut blocked: Vec<&JobView> = Vec::new();
        for j in waiting {
            match grant(j, pool_free, borrow, free, free_mem) {
                Some(n) if n > 0 => {
                    allocs.push(Allocation { job: j.id, n });
                }
                Some(_) => {}
                None => blocked.push(j),
            }
        }
        // Second pass (Algorithm 3 lines 12-20): ascending-demand packing of
        // the blocked jobs — small requests squeeze into the remainder.
        // Demand order is the cpu axis (the grant currency); for uniform
        // demands this is exactly the pre-vector scalar order.
        blocked.sort_by_key(|j| (j.demand.cpu, j.submit_ms));
        for j in blocked {
            if let Some(n) = grant(j, pool_free, borrow, free, free_mem) {
                if n > 0 {
                    allocs.push(Allocation { job: j.id, n });
                }
            }
        }
    }
}

impl Scheduler for DressScheduler {
    fn name(&self) -> &'static str {
        "dress"
    }

    fn reserve_ratio(&self) -> Option<f64> {
        Some(self.delta)
    }

    fn set_tune_delta(&mut self, on: bool) {
        self.tune_delta = on;
    }

    fn set_tune_params(&mut self, every: u32, window: usize) {
        self.tune_every = every.max(1);
        self.window = ShadowWindow::new(window.max(1));
    }

    fn snapshot(&self, view: &ClusterView) -> Option<SchedSnapshot> {
        Some(DressScheduler::snapshot(self, view))
    }

    fn schedule(&mut self, view: &ClusterView) -> Vec<Allocation> {
        // (1) classify new arrivals against observed A_c.
        for j in view.jobs {
            if self.classifier.get(j.id).is_none() {
                let cat =
                    self.classifier.classify(j.id, j.demand, view.free_vec(), view.total_vec());
                self.estimator.register(j.id, cat.index());
            }
        }

        // (1b) opt-in shadow tuner: observe the stream, and every K
        // heartbeats replay the window under a candidate ladder and adopt
        // the winning δ (clamped inside `shadow::tune_delta`).  The whole
        // block is behind the flag: disabled runs touch no tuner state,
        // push no events and draw no randomness (replay uses none) — the
        // golden inertness test holds them bit-identical to the pre-tuner
        // engine.
        if self.tune_delta {
            self.observe(view);
            self.tune_ticks += 1;
            if self.tune_ticks >= self.tune_every.max(1) && view.total >= 2 {
                self.tune_ticks = 0;
                let snap = DressScheduler::snapshot(self, view);
                self.delta = shadow::tune_delta(&snap, &self.window, self.delta, shadow::REPLAY_TICKS);
            }
        }

        // (2) estimator ingest + tick (Algorithms 1-2).
        self.estimator.ingest(view.transitions);
        if self.naive_estimator_tick {
            self.estimator.tick_all(view.now);
        } else {
            self.estimator.tick(view.now);
        }

        // Degraded capacity (fault plan): the split is re-derived from the
        // live total every heartbeat.  Below two slots there is no way to
        // give each pool its mandatory minimum, so grant nothing and wait
        // for recovery — classification and estimator state stay warm above.
        let total = view.total;
        if total < 2 {
            return Vec::new();
        }

        // One fused pass over the view (perf iter 4): per-category
        // occupancy plus the running / waiting partitions, all in
        // submission order.  The seed re-derived each of these with its own
        // full scan (and computed occupancy twice); the view is a snapshot,
        // so one pass yields identical values.
        let (mut occ_sd, mut occ_ld) = (0u32, 0u32);
        let mut running: Vec<&JobView> = Vec::new();
        let mut sd_wait: Vec<&JobView> = Vec::new();
        let mut ld_wait: Vec<&JobView> = Vec::new();
        for j in view.jobs.iter().filter(|j| !j.finished) {
            let cat = self.category(j.id);
            match cat {
                Category::Sd => occ_sd += j.occupied,
                Category::Ld => occ_ld += j.occupied,
            }
            if j.started {
                running.push(j);
            } else {
                match cat {
                    Category::Sd => sd_wait.push(j),
                    Category::Ld => ld_wait.push(j),
                }
            }
        }

        // (3) Algorithm 3: adjust δ with F(t+1) over the next heartbeat.
        let horizon = view.now + self.hb_ms;
        let (f1, f2) = if self.disable_estimator {
            (0.0, 0.0)
        } else {
            self.estimator.predicted_release_pair(view.now, horizon)
        };
        let (sd_quota, ld_quota) = self.quotas(total);
        // Free containers attributable per pool: quota minus occupancy,
        // bounded by what is globally free.
        let ac1 = sd_quota.saturating_sub(occ_sd).min(view.free) as f64;
        let ac2 = ld_quota
            .saturating_sub(occ_ld)
            .min(view.free.saturating_sub(ac1 as u32)) as f64;
        // Reserve arithmetic stays on the cpu axis — δ splits the grant
        // currency; the mem axis is enforced as a feasibility clamp below.
        let mut sd_demands: Vec<u32> = sd_wait.iter().map(|j| j.demand.cpu).collect();
        let mut ld_demands: Vec<u32> = ld_wait.iter().map(|j| j.demand.cpu).collect();
        sd_demands.sort_unstable();
        ld_demands.sort_unstable();
        if !self.freeze_delta {
            self.delta = adjust(
                self.delta,
                &ReserveInputs {
                    total,
                    ac1,
                    ac2,
                    f1,
                    f2,
                    sd_demands,
                    ld_demands,
                },
            );
        }
        // δ is exposed per tick via `reserve_ratio()`; the engine's
        // metric sink owns its history (the scheduler used to keep a
        // duplicate unbounded Vec here — an O(ticks) memory term the
        // bounded-metric runs could never turn off).

        // (4) allocation against the adjusted quotas.  Occupancy is
        // unchanged since the fused pass (the view is immutable), so the
        // counters are reused instead of rescanned.
        let (sd_quota, ld_quota) = self.quotas(total);
        let mut sd_free = sd_quota.saturating_sub(occ_sd);
        let mut ld_free = ld_quota.saturating_sub(occ_ld);
        let mut free = view.free;
        let mut free_mem = view.free_mem;
        let mut allocs: Vec<Allocation> = Vec::new();

        // 4a. refill running jobs from their own pools (mem clamp is a
        // no-op for scalar demands: mpt == 1 and free_mem tracks free).
        for j in &running {
            if free == 0 {
                break;
            }
            let budget = j.demand.cpu.saturating_sub(j.occupied).min(j.pending_tasks);
            if budget == 0 {
                continue;
            }
            let mpt = j.demand.mem_per_container().max(1);
            let pool = match self.category(j.id) {
                Category::Sd => &mut sd_free,
                Category::Ld => &mut ld_free,
            };
            let n = budget.min(*pool).min(free).min(free_mem / mpt);
            if n > 0 {
                allocs.push(Allocation { job: j.id, n });
                *pool -= n;
                free -= n;
                free_mem -= n * mpt;
            }
        }

        // 4b. admit waiting jobs per category.
        let mut no_borrow = 0u32;
        self.admit_category(
            &sd_wait,
            &mut sd_free,
            &mut no_borrow,
            &mut free,
            &mut free_mem,
            &mut allocs,
        );
        // LD may borrow the idle SD reserve when no SD job is waiting for it.
        let mut sd_idle = if sd_wait.is_empty() { sd_free } else { 0 };
        self.admit_category(
            &ld_wait,
            &mut ld_free,
            &mut sd_idle,
            &mut free,
            &mut free_mem,
            &mut allocs,
        );
        if sd_wait.is_empty() {
            sd_free = sd_idle;
        }

        // 4c. LD leftovers flow to SD jobs (ascending demand), lines 21-24.
        // Membership is an O(1) hash probe (the seed's `Vec::contains` made
        // this pass quadratic in waiting jobs under congestion); each job
        // appears in `rest` at most once, so no inserts are needed inside
        // the loop.
        if free > 0 && ld_free > 0 {
            let granted: HashSet<JobId> = allocs.iter().map(|a| a.job).collect();
            let mut rest: Vec<&JobView> = sd_wait
                .iter()
                .filter(|j| !granted.contains(&j.id))
                .copied()
                .collect();
            rest.sort_by_key(|j| (j.demand.cpu, j.submit_ms));
            for j in rest {
                let mpt = j.demand.mem_per_container().max(1);
                let want = j.demand.cpu.min(j.pending_tasks);
                let room = (sd_free + ld_free).min(free).min(free_mem / mpt);
                if want == 0 || want > room {
                    continue;
                }
                allocs.push(Allocation { job: j.id, n: want });
                let from_sd = want.min(sd_free);
                sd_free -= from_sd;
                ld_free -= want - from_sd;
                free -= want;
                free_mem -= want * mpt;
                // δ grows with each migrated reservation (line 23).
                if !self.freeze_delta {
                    self.delta = (self.delta + want as f64 / total as f64)
                        .clamp(reserve::DELTA_MIN, reserve::DELTA_MAX);
                }
            }
        }

        allocs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchedConfig;
    use crate::sched::testutil::*;

    fn dress(total: u32) -> DressScheduler {
        DressScheduler::new(&SchedConfig::default(), total)
    }

    #[test]
    fn small_job_bypasses_large_head_of_line() {
        // 40-container cluster. J1 (LD, 30) running with 30; J2 (LD, 20)
        // blocked; J3 (SD, 3) must still get in via the SD reserve.
        let jobs = vec![
            started(jv(1, 30, 0), 30),
            jv(2, 20, 20),
            jv(3, 3, 3),
        ];
        let mut s = dress(40);
        let allocs = s.schedule(&view(10, 40, jobs));
        assert!(
            allocs.iter().any(|a| a.job == 3 && a.n == 3),
            "SD job admitted: {allocs:?}"
        );
        assert!(!allocs.iter().any(|a| a.job == 2), "LD J2 stays blocked");
    }

    #[test]
    fn classification_happens_on_first_view() {
        let jobs = vec![jv(1, 3, 3), jv(2, 30, 30)];
        let mut s = dress(40);
        s.schedule(&view(40, 40, jobs));
        assert_eq!(s.classifier.get(1), Some(Category::Sd));
        assert_eq!(s.classifier.get(2), Some(Category::Ld));
    }

    #[test]
    fn delta_exposed_every_tick_via_reserve_ratio() {
        // The engine samples δ through `reserve_ratio()` on every tick;
        // the scheduler itself retains no history (bounded memory).
        let mut s = dress(40);
        for t in 0..5u64 {
            let v = ClusterView {
                now: t * 1_000,
                free: 40,
                total: 40,
                free_mem: 40,
                total_mem: 40,
                jobs: &[],
                transitions: &[],
            };
            s.schedule(&v);
            assert_eq!(s.reserve_ratio(), Some(s.delta()));
        }
    }

    #[test]
    fn ld_leftover_serves_small_jobs() {
        // Mostly idle: SD quota tiny (δ=0.1 -> 4), LD huge. An SD job with
        // demand 6 exceeds its pool but fits with LD leftovers.
        let jobs = vec![jv(1, 4, 4)]; // SD (4 <= 0.1*40)
        let mut s = dress(40);
        let allocs = s.schedule(&view(40, 40, jobs.clone()));
        assert!(allocs.iter().any(|a| a.job == 1 && a.n == 4), "{allocs:?}");
    }

    #[test]
    fn split_tracks_live_total_under_degraded_capacity() {
        // Built against 40 slots but observing a 20-slot cluster (node
        // down): grants must respect the live capacity, and a <2-slot view
        // grants nothing at all (no room for both mandatory pool minimums).
        let jobs = vec![jv(1, 18, 18)];
        let mut s = dress(40);
        let allocs = s.schedule(&view(20, 20, jobs.clone()));
        let granted: u32 = allocs.iter().map(|a| a.n).sum();
        assert!(granted <= 20, "over-allocated on degraded cluster: {allocs:?}");
        assert!(allocs.iter().any(|a| a.job == 1), "{allocs:?}");
        let mut s2 = dress(40);
        assert!(s2.schedule(&view(1, 1, jobs)).is_empty());
    }

    #[test]
    fn respects_global_free_limit() {
        let jobs = vec![jv(1, 4, 4), jv(2, 30, 30)];
        let mut s = dress(40);
        let allocs = s.schedule(&view(5, 40, jobs));
        let total: u32 = allocs.iter().map(|a| a.n).sum();
        assert!(total <= 5, "over-allocated: {allocs:?}");
    }

    #[test]
    fn memory_axis_clamps_vector_grants() {
        // 40 slots but only 8 memory units free.  A vector job wanting 10
        // containers at 2 units each can place at most 4 — the cpu pools
        // alone would have granted all 10.
        use crate::jobs::Demand;
        let jobs = vec![jv_vec(1, Demand::new(10, 20), 10)];
        let mut s = dress(40);
        let allocs = s.schedule(&view_mem(40, 40, 8, 40, jobs));
        let granted: u32 = allocs.iter().filter(|a| a.job == 1).map(|a| a.n).sum();
        assert!(granted <= 4, "memory axis must clamp the grant: {allocs:?}");
    }
}
