//! Algorithm 3: dynamic adjustment of the reserve ratio δ.
//!
//! δ·Tot_R containers are reserved for SD jobs, (1-δ)·Tot_R for LD.  Each
//! heartbeat the scheduler recomputes δ from (a) the estimated release
//! curves F₁/F₂(t+1), (b) per-category free containers A_c1/A_c2, and
//! (c) pending demands P₁/P₂.

/// Inputs to one Algorithm-3 adjustment round.
#[derive(Debug, Clone, PartialEq)]
pub struct ReserveInputs {
    /// Total containers in the system (Tot_R).
    pub total: u32,
    /// Free containers currently attributable to SD / LD pools.
    pub ac1: f64,
    pub ac2: f64,
    /// Estimated releases into each pool by the next heartbeat, F_k(t+1).
    pub f1: f64,
    pub f2: f64,
    /// Pending demands per category, ascending-sorted (r_i of waiting jobs).
    pub sd_demands: Vec<u32>,
    pub ld_demands: Vec<u32>,
}

/// δ is kept inside (0,1) with a numeric guard band; the paper leaves the
/// bound implicit ("δ ∈ (0,1)").
pub const DELTA_MIN: f64 = 0.02;
pub const DELTA_MAX: f64 = 0.95;

/// One Algorithm-3 round: returns the new δ.
pub fn adjust(delta: f64, inp: &ReserveInputs) -> f64 {
    let tot = inp.total.max(1) as f64;
    let p1: f64 = inp.sd_demands.iter().map(|&d| d as f64).sum();
    let p2: f64 = inp.ld_demands.iter().map(|&d| d as f64).sum();
    let avail1 = inp.ac1 + inp.f1;
    let avail2 = inp.ac2 + inp.f2;

    let mut delta = delta;
    if avail1 >= p1 {
        // Lines 7-8: SD has surplus — return it to LD.
        delta -= (avail1 - p1) / tot;
    } else if avail2 >= p2 {
        // Lines 9-11: SD starved but LD has surplus — enlarge the reserve.
        delta += (avail2 - p2) / tot;
    } else {
        // Lines 12-24: both starved. Greedy-pack ascending demands within
        // each category, then move LD leftovers to the next SD jobs.
        let mut a1 = avail1;
        for &r in &inp.sd_demands {
            let r = r as f64;
            if a1 - r > 0.0 {
                a1 -= r;
            }
        }
        let mut a2 = avail2;
        let mut unserved_sd: Vec<f64> = Vec::new();
        {
            // Jobs SD could not serve, in ascending order (lines 21-24 walk
            // "from the request of J_{i+1}").
            let mut a1_probe = avail1;
            for &r in &inp.sd_demands {
                let r = r as f64;
                if a1_probe - r > 0.0 {
                    a1_probe -= r;
                } else {
                    unserved_sd.push(r);
                }
            }
        }
        for &r in &inp.ld_demands {
            let r = r as f64;
            if a2 - r > 0.0 {
                a2 -= r;
            }
        }
        // Combined leftovers serve further SD jobs; each such migration
        // grows the SD reserve (line 23: δ = δ + r_i / Tot_R).
        for r in unserved_sd {
            if r < a1 + a2 {
                let take_from_ld = (r - a1).max(0.0);
                a1 = (a1 - r).max(0.0);
                a2 -= take_from_ld;
                delta += r / tot;
            } else {
                break;
            }
        }
    }
    delta.clamp(DELTA_MIN, DELTA_MAX)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> ReserveInputs {
        ReserveInputs {
            total: 40,
            ac1: 0.0,
            ac2: 0.0,
            f1: 0.0,
            f2: 0.0,
            sd_demands: vec![],
            ld_demands: vec![],
        }
    }

    #[test]
    fn surplus_sd_shrinks_delta() {
        let mut inp = base();
        inp.ac1 = 8.0; // SD pool free
        inp.sd_demands = vec![2]; // pending needs only 2
        let d = adjust(0.30, &inp);
        // surplus 6 / 40 = 0.15 returned to LD
        assert!((d - 0.15).abs() < 1e-9, "{d}");
    }

    #[test]
    fn starved_sd_with_ld_surplus_grows_delta() {
        let mut inp = base();
        inp.sd_demands = vec![4, 4]; // P1 = 8, avail1 = 0
        inp.ac2 = 10.0;
        inp.ld_demands = vec![5]; // P2 = 5, surplus 5
        let d = adjust(0.10, &inp);
        assert!((d - 0.225).abs() < 1e-9, "{d}"); // +5/40
    }

    #[test]
    fn both_starved_migrates_leftovers_to_sd() {
        let mut inp = base();
        // SD: 3 free, jobs [2, 4] -> serves 2 (leftover ~1), job 4 unserved.
        inp.ac1 = 3.0;
        inp.sd_demands = vec![2, 4];
        // LD: 9 free, jobs [5, 30] -> serves 5 (leftover 4), job 30 unserved.
        inp.ac2 = 9.0;
        inp.ld_demands = vec![5, 30];
        // leftovers 1 + 4 = 5 > 4 -> SD job 4 served, δ += 4/40.
        let d = adjust(0.10, &inp);
        assert!((d - 0.20).abs() < 1e-9, "{d}");
    }

    #[test]
    fn estimated_release_counts_toward_pools() {
        let mut inp = base();
        inp.f1 = 6.0; // releases land in SD pool next tick
        inp.sd_demands = vec![2];
        let d = adjust(0.5, &inp);
        assert!((d - 0.4).abs() < 1e-9, "{d}"); // surplus 4/40 returned
    }

    #[test]
    fn delta_stays_in_bounds() {
        let mut inp = base();
        inp.ac1 = 40.0; // giant SD surplus
        assert!(adjust(0.05, &inp) >= DELTA_MIN);
        inp.ac1 = 0.0;
        inp.ac2 = 40.0;
        inp.sd_demands = vec![40];
        assert!(adjust(0.90, &inp) <= DELTA_MAX);
    }

    #[test]
    fn idle_system_drifts_down_to_min() {
        // No pending demands, no frees: SD branch (0 >= 0) with 0 surplus.
        let inp = base();
        let mut d = 0.10;
        for _ in 0..100 {
            d = adjust(d, &inp);
        }
        assert!((DELTA_MIN..=0.10).contains(&d));
    }
}
