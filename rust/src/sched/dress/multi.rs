//! N-category DRESS — the paper's stated extension (§IV.C: "It's easy to
//! classify incoming jobs into more categories by applying a similar
//! strategy").
//!
//! Jobs are bucketed by demand against a ladder of thresholds
//! θ₁ < θ₂ < … (fractions of cluster capacity); each bucket owns a reserve
//! share, renormalized each heartbeat by pending demand (the Algorithm-3
//! surplus/deficit idea applied pairwise down the ladder).  Idle shares are
//! borrowable by larger buckets, so the scheduler is livelock-free.

use super::super::{Allocation, ClusterView, JobView, Scheduler};
use crate::jobs::{Demand, JobId};

/// N-category DRESS scheduler.
pub struct MultiDress {
    /// Ascending demand thresholds as fractions of total; bucket k holds
    /// jobs with demand <= thresholds[k] * total, last bucket the rest.
    thresholds: Vec<f64>,
    /// Current reserve share per bucket (sums to 1).
    shares: Vec<f64>,
    cats: Vec<Option<usize>>, // job id -> bucket, sticky
}

impl MultiDress {
    /// `thresholds` must be ascending, in (0,1). Buckets = len + 1.
    /// `_total` is the provisioned capacity; pools are sized from the
    /// *live* `ClusterView::total` each heartbeat (time-varying under a
    /// fault plan), so construction keeps no capacity state.
    pub fn new(thresholds: Vec<f64>, _total: u32) -> Self {
        assert!(!thresholds.is_empty());
        assert!(thresholds.windows(2).all(|w| w[0] < w[1]));
        assert!(thresholds.iter().all(|&t| 0.0 < t && t < 1.0));
        let n = thresholds.len() + 1;
        MultiDress {
            thresholds,
            shares: vec![1.0 / n as f64; n],
            cats: Vec::new(),
        }
    }

    pub fn buckets(&self) -> usize {
        self.thresholds.len() + 1
    }

    pub fn shares(&self) -> &[f64] {
        &self.shares
    }

    /// Sticky bucket assignment against the capacity observed at arrival.
    ///
    /// Vector generalization: the ladder is applied on the job's dominant
    /// resource axis (largest share of `total`, ties to cpu), in the same
    /// multiplicative form as the scalar rule — so uniform demands bucket
    /// on bit-identical arithmetic to the pre-vector scheme.
    fn classify(&mut self, job: JobId, demand: Demand, total: Demand) -> usize {
        let idx = job as usize;
        if idx >= self.cats.len() {
            self.cats.resize(idx + 1, None);
        }
        if let Some(b) = self.cats[idx] {
            return b;
        }
        let axis = demand.dominant_axis(total);
        let b = self
            .thresholds
            .iter()
            .position(|&t| (demand.axis(axis) as f64) <= t * total.axis(axis) as f64)
            .unwrap_or(self.thresholds.len());
        self.cats[idx] = Some(b);
        b
    }

    fn bucket_of(&self, job: JobId) -> usize {
        self.cats
            .get(job as usize)
            .copied()
            .flatten()
            .unwrap_or(self.buckets() - 1)
    }

    /// Renormalize shares toward pending demand per bucket (EWMA so the
    /// reservation has the paper's "dynamic" character without thrash).
    /// Each bucket with pending work gets a floor large enough for its
    /// smallest waiting job, so no bucket starves on share arithmetic.
    fn adjust_shares(&mut self, pending: &[f64], min_pending_demand: &[u32], cap: u32) {
        let total: f64 = pending.iter().sum();
        let n = self.buckets();
        let mut target: Vec<f64> = if total <= 0.0 {
            vec![1.0 / n as f64; n]
        } else {
            pending.iter().map(|&p| (p / total).max(0.02)).collect()
        };
        for (k, t) in target.iter_mut().enumerate() {
            if min_pending_demand[k] > 0 {
                let floor = (min_pending_demand[k] as f64 + 1.0) / cap as f64;
                *t = t.max(floor);
            }
        }
        let norm: f64 = target.iter().sum();
        for (s, t) in self.shares.iter_mut().zip(&target) {
            *s = 0.7 * *s + 0.3 * (t / norm);
        }
        let sum: f64 = self.shares.iter().sum();
        for s in self.shares.iter_mut() {
            *s /= sum;
        }
    }
}

impl Scheduler for MultiDress {
    fn name(&self) -> &'static str {
        "multi-dress"
    }

    fn schedule(&mut self, view: &ClusterView) -> Vec<Allocation> {
        let n = self.buckets();
        // Live capacity (time-varying under a fault plan); pools, floors
        // and demand clamps are all derived from it.  A fully-crashed
        // cluster has nothing to hand out — and would divide by zero in
        // the share floor — so bail early while keeping buckets sticky.
        let total = view.total;
        for j in view.jobs {
            self.classify(j.id, j.demand, view.total_vec());
        }
        if total == 0 {
            return Vec::new();
        }

        // Pending demand per bucket -> share adjustment.
        let mut pending = vec![0.0; n];
        let mut min_pending = vec![0u32; n];
        for j in view.jobs.iter().filter(|j| !j.started && !j.finished) {
            let b = self.bucket_of(j.id);
            pending[b] += j.demand.cpu as f64;
            let d = j.demand.cpu.min(total);
            min_pending[b] = if min_pending[b] == 0 { d } else { min_pending[b].min(d) };
        }
        self.adjust_shares(&pending, &min_pending, total);

        // Pool accounting.
        let mut occupied = vec![0u32; n];
        for j in view.jobs.iter().filter(|j| !j.finished) {
            occupied[self.bucket_of(j.id)] += j.occupied;
        }
        let mut pool: Vec<u32> = self
            .shares
            .iter()
            .zip(&occupied)
            .map(|(&s, &occ)| ((s * total as f64).round() as u32).saturating_sub(occ))
            .collect();

        let mut free = view.free;
        let mut free_mem = view.free_mem;
        let mut allocs = Vec::new();

        // Refill running jobs from their pools (the memory clamp is a
        // no-op for scalar demands: footprint 1, free_mem tracks free).
        for j in view.jobs.iter().filter(|j| j.started && !j.finished) {
            if free == 0 {
                break;
            }
            let b = self.bucket_of(j.id);
            let mpt = j.demand.mem_per_container().max(1);
            let budget = j.demand.cpu.saturating_sub(j.occupied).min(j.pending_tasks);
            let m = budget.min(pool[b]).min(free).min(free_mem / mpt);
            if m > 0 {
                allocs.push(Allocation { job: j.id, n: m });
                pool[b] -= m;
                free -= m;
                free_mem -= m * mpt;
            }
        }

        // Admit FCFS within bucket, smallest bucket first; idle pools of
        // smaller buckets are borrowable by larger ones.
        for b in 0..n {
            let waiting: Vec<&JobView> = view
                .jobs
                .iter()
                .filter(|j| !j.started && !j.finished && self.bucket_of(j.id) == b)
                .collect();
            for j in waiting {
                let mpt = j.demand.mem_per_container().max(1);
                let want = j.demand.cpu.min(j.pending_tasks).min(total);
                if want == 0 || free == 0 {
                    continue;
                }
                // Own pool plus pools of smaller, currently idle buckets.
                let idle_smaller: u32 = (0..b)
                    .filter(|&k| pending[k] == 0.0)
                    .map(|k| pool[k])
                    .sum();
                let room = (pool[b] + idle_smaller).min(free).min(free_mem / mpt);
                if want > room {
                    continue; // ascending-demand: later (smaller) jobs may fit
                }
                allocs.push(Allocation { job: j.id, n: want });
                let own = want.min(pool[b]);
                pool[b] -= own;
                let mut borrow = want - own;
                for k in 0..b {
                    if borrow == 0 {
                        break;
                    }
                    if pending[k] == 0.0 {
                        let take = borrow.min(pool[k]);
                        pool[k] -= take;
                        borrow -= take;
                    }
                }
                free -= want;
                free_mem -= want * mpt;
            }
        }

        // Progress guarantee: on an idle cluster with nothing granted this
        // round, admit the smallest waiting job directly — share EWMA must
        // never deadlock the system.
        if allocs.is_empty() && view.free == view.total {
            if let Some(j) = view
                .jobs
                .iter()
                .filter(|j| !j.started && !j.finished && j.pending_tasks > 0)
                .min_by_key(|j| (j.demand.cpu, j.submit_ms))
            {
                let mpt = j.demand.mem_per_container().max(1);
                let want =
                    j.demand.cpu.min(j.pending_tasks).min(view.free).min(view.free_mem / mpt);
                if want > 0 {
                    allocs.push(Allocation { job: j.id, n: want });
                }
            }
        }
        allocs
    }

    fn reserve_ratio(&self) -> Option<f64> {
        Some(self.shares[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::testutil::*;

    fn md() -> MultiDress {
        // Buckets: <=10% (4), <=40% (16), rest — on a 40-container cluster.
        MultiDress::new(vec![0.1, 0.4], 40)
    }

    fn s(n: u32) -> Demand {
        Demand::scalar(n)
    }

    #[test]
    fn classification_ladder() {
        let mut m = md();
        assert_eq!(m.classify(1, s(3), s(40)), 0);
        assert_eq!(m.classify(2, s(10), s(40)), 1);
        assert_eq!(m.classify(3, s(30), s(40)), 2);
        // sticky: re-seen jobs keep their bucket even as demand/total move
        assert_eq!(m.classify(1, s(30), s(40)), 0);
        assert_eq!(m.classify(2, s(10), s(20)), 1);
    }

    #[test]
    fn vector_jobs_bucket_on_dominant_axis() {
        let mut m = md();
        // 3 containers but 20/40 of memory: mem share 0.5 -> top bucket.
        assert_eq!(m.classify(1, Demand::new(3, 20), s(40)), 2);
        // Memory-light vector job keeps its cpu-axis bucket.
        assert_eq!(m.classify(2, Demand::new(3, 4), s(40)), 0);
    }

    #[test]
    fn degraded_total_shrinks_pools() {
        let mut m = md();
        // On a half-capacity view the pools must be sized from the live
        // total: a job wanting 18 of the 20 surviving slots still starts
        // (borrowing idle smaller pools), and a zero-capacity view is a
        // no-op rather than a divide-by-zero in the share floor.
        let jobs = vec![jv(1, 18, 18)];
        let mut started_ok = false;
        for _ in 0..20 {
            let allocs = m.schedule(&view(20, 20, jobs.clone()));
            let granted: u32 = allocs.iter().map(|a| a.n).sum();
            assert!(granted <= 20, "over-allocated on degraded cluster: {allocs:?}");
            if allocs.iter().any(|a| a.job == 1 && a.n == 18) {
                started_ok = true;
                break;
            }
        }
        assert!(started_ok, "job starved on degraded cluster");
        assert!(m.schedule(&view(0, 0, jobs)).is_empty());
    }

    #[test]
    fn small_jobs_not_blocked_by_large_head() {
        let mut m = md();
        let jobs = vec![
            started(jv(1, 30, 0), 30), // bucket 2, running
            jv(2, 25, 25),             // bucket 2, blocked
            jv(3, 3, 3),               // bucket 0, should fit
        ];
        let allocs = m.schedule(&view(10, 40, jobs));
        assert!(allocs.iter().any(|a| a.job == 3), "{allocs:?}");
        assert!(!allocs.iter().any(|a| a.job == 2));
    }

    #[test]
    fn shares_track_pending_demand() {
        let mut m = md();
        // Only bucket-0 demand pending: its share must grow.
        let jobs = vec![jv(1, 3, 3), jv(2, 4, 4), jv(3, 3, 3)];
        let before = m.shares()[0];
        for _ in 0..10 {
            m.schedule(&view(0, 40, jobs.clone()));
        }
        assert!(m.shares()[0] > before, "share {} !> {}", m.shares()[0], before);
        let sum: f64 = m.shares().iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn borrowing_prevents_livelock() {
        let mut m = md();
        // A bucket-2 job demanding 38 of 40: needs to borrow idle pools.
        let jobs = vec![jv(1, 38, 38)];
        let mut started_ok = false;
        for _ in 0..20 {
            let allocs = m.schedule(&view(40, 40, jobs.clone()));
            if allocs.iter().any(|a| a.job == 1 && a.n == 38) {
                started_ok = true;
                break;
            }
        }
        assert!(started_ok, "large job starved by reserves");
    }

    #[test]
    #[should_panic]
    fn rejects_non_ascending_thresholds() {
        MultiDress::new(vec![0.4, 0.1], 40);
    }
}
