//! SD / LD job classification (paper §IV.C).
//!
//! "We denote θ ∈ (0,1) as a preset indicator factor such that if the
//! resource request is larger than A_c × θ, the job will be classified to
//! 'large demand' (LD), otherwise it will join 'small demand' (SD)."
//!
//! Classification happens once, at submission, against the *available*
//! containers observed at that moment — so the same demand can land in
//! different categories under different congestion, exactly as on YARN.

use crate::jobs::{Demand, JobId};

/// Job category.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Category {
    /// Small demand — the reserved-pool beneficiaries.
    Sd,
    /// Large demand.
    Ld,
}

impl Category {
    pub fn index(self) -> u8 {
        match self {
            Category::Sd => 0,
            Category::Ld => 1,
        }
    }
}

/// Sticky classifier: classifies on first sight, remembers forever.
///
/// Perf (EXPERIMENTS.md §Perf iter 2): the scheduler queries the category
/// of every job on every heartbeat — lookups are O(1) against a dense
/// Vec indexed by job id (ids are sequential in this system).
#[derive(Debug, Clone)]
pub struct Classifier {
    theta: f64,
    assigned: Vec<Option<Category>>,
}

impl Classifier {
    pub fn new(theta: f64) -> Self {
        assert!(0.0 < theta && theta < 1.0, "theta must be in (0,1)");
        Classifier { theta, assigned: Vec::new() }
    }

    /// Classify `job` with a `demand` vector against the `available` (A_c)
    /// and `total` capacity vectors — but use the total as a floor
    /// reference when the cluster is drained (A_c = 0 would otherwise make
    /// every job LD).
    ///
    /// Vector generalization (docs/RESOURCES.md): the θ rule is applied on
    /// the job's *dominant* resource axis — the axis where it claims the
    /// largest share of the reference capacity — with ties breaking to the
    /// cpu axis.  Every uniform (scalar) demand ties, so scalar runs
    /// classify on exactly the pre-vector cpu-axis arithmetic.
    pub fn classify(
        &mut self,
        job: JobId,
        demand: Demand,
        available: Demand,
        total: Demand,
    ) -> Category {
        if let Some(c) = self.get(job) {
            return c;
        }
        // Paper uses A_c ("larger than A_c × θ"), but in its own experiments
        // the realized rule is "more than 10 containers" on a mostly-full
        // cluster — i.e. θ of the *capacity*. Raw A_c degenerates under
        // congestion (A_c -> 0 makes every job LD), so we take the larger of
        // the two references: idle cluster => identical to the paper's rule,
        // congested => stable. Recorded as a substitution in DESIGN.md.
        let reference = Demand::new(
            available.cpu.max(total.cpu).max(1),
            available.mem.max(total.mem).max(1),
        );
        let axis = demand.dominant_axis(reference);
        let cat = if (demand.axis(axis) as f64) > self.theta * reference.axis(axis) as f64 {
            Category::Ld
        } else {
            Category::Sd
        };
        let idx = job as usize;
        if idx >= self.assigned.len() {
            self.assigned.resize(idx + 1, None);
        }
        self.assigned[idx] = Some(cat);
        cat
    }

    /// Category of an already-classified job.
    pub fn get(&self, job: JobId) -> Option<Category> {
        self.assigned.get(job as usize).copied().flatten()
    }

    pub fn theta(&self) -> f64 {
        self.theta
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(n: u32) -> Demand {
        Demand::scalar(n)
    }

    #[test]
    fn small_vs_large_at_idle_cluster() {
        let mut c = Classifier::new(0.10);
        // Idle 40-container cluster: threshold = 4 containers.
        assert_eq!(c.classify(1, s(3), s(40), s(40)), Category::Sd);
        assert_eq!(c.classify(2, s(4), s(40), s(40)), Category::Sd);
        assert_eq!(c.classify(3, s(5), s(40), s(40)), Category::Ld);
        assert_eq!(c.classify(4, s(30), s(40), s(40)), Category::Ld);
    }

    #[test]
    fn classification_is_sticky() {
        let mut c = Classifier::new(0.10);
        assert_eq!(c.classify(1, s(3), s(40), s(40)), Category::Sd);
        // Same job re-observed under drained cluster: unchanged.
        assert_eq!(c.classify(1, s(3), s(0), s(40)), Category::Sd);
        assert_eq!(c.get(1), Some(Category::Sd));
        assert_eq!(c.get(99), None);
    }

    #[test]
    fn drained_cluster_uses_capacity_reference() {
        let mut c = Classifier::new(0.10);
        // A_c = 0 on a 40-container cluster: threshold stays 4, so a
        // 3-container job is still SD (raw A_c would make everything LD).
        assert_eq!(c.classify(1, s(3), s(0), s(40)), Category::Sd);
        assert_eq!(c.classify(2, s(5), s(0), s(40)), Category::Ld);
    }

    #[test]
    fn dominant_axis_drives_vector_classification() {
        let mut c = Classifier::new(0.10);
        // 3 containers (SD-sized on cpu) but 20/40 of the memory: the mem
        // axis dominates and pushes the job into LD.
        assert_eq!(c.classify(1, Demand::new(3, 20), s(40), s(40)), Category::Ld);
        // Memory-light vector job stays governed by the cpu axis.
        assert_eq!(c.classify(2, Demand::new(3, 4), s(40), s(40)), Category::Sd);
        // cpu-dominant wide job is LD by the scalar rule regardless of mem.
        assert_eq!(c.classify(3, Demand::new(30, 30), s(40), s(40)), Category::Ld);
    }

    #[test]
    #[should_panic(expected = "theta")]
    fn rejects_bad_theta() {
        Classifier::new(1.0);
    }
}
