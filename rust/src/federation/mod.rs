//! Federated multi-cell simulation: N independent [`Cell`]s lock-stepped
//! on a global virtual clock, with deterministic cross-cell job routing,
//! queue-imbalance migration, and cell-level failure injection
//! (docs/FEDERATION.md).
//!
//! The paper's DRESS scheduler manages one congested cluster; this layer
//! scales the reproduction out: each cell is a full single-cluster
//! simulation (the exact engine core, bit-identical when `cells = 1` —
//! pinned by tests/federation_integration.rs), and the federation only
//! talks to cells through their public membership API ([`Cell::accept`],
//! [`Cell::withdraw_one_queued`], [`Cell::withdraw_unfinished`],
//! [`Cell::fail_cell`]) and the [`CellOutput`] stream.
//!
//! ## Determinism
//!
//! Everything here is deterministic by construction: cells advance in
//! index order at every breakpoint, routers are pure functions of
//! `(spec, cell status)` with explicit tie-breaks, cell outages come from
//! the same seeded [`FaultPlan`](crate::sim::fault::FaultPlan) grammar as
//! node faults, and no wall-clock or hash-iteration order is consulted.
//! Double runs byte-compare in CI.
//!
//! ## Migration semantics
//!
//! A migrated job is withdrawn from its current cell (containers must be
//! idle — only cold queued jobs or salvaged jobs move) and re-submitted
//! to the destination through an ordinary `JobSubmit` event, keeping its
//! original `submit_ms` so queueing history is never erased.  Each cell
//! tracks job execution in its own store, so a job that ran partially in
//! a now-dead cell re-runs its tasks in the destination; the work already
//! burned is accounted in the dead cell's `useful`/`wasted` tallies and
//! only the finishing cell reports the job's metrics — exactly one
//! [`CellOutput::JobDone`] fires per job globally.

use crate::config::{ExperimentConfig, RouterKind};
use crate::jobs::{Demand, JobId, JobSpec};
use crate::metrics::{DeltaSummary, JobMetrics, SystemMetrics, UtilSummary};
use crate::sched::dress::Classifier;
use crate::sim::engine::{EngineOptions, RunResult};
use crate::sim::fault::CellOutageRecord;
use crate::sim::{Cell, CellOutput, TraceRecorder};
use crate::util::Time;
use std::collections::HashMap;

/// What a router may observe about one cell when placing a job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CellStatus {
    /// False while the cell is dead (cell-level fault).  Routers must
    /// never place a job on a dead cell.
    pub alive: bool,
    /// Jobs routed here at construction (static routing).
    pub routed_jobs: u32,
    /// Total remaining work (ms of task run-time) of unfinished jobs
    /// currently placed here — the `least-load` signal.
    pub outstanding_work_ms: u64,
    /// Pending queue length at the last heartbeat (jobs holding zero
    /// containers) — the imbalance signal.
    pub queued: u32,
}

/// A deterministic cross-cell placement policy.  Called once per job at
/// construction (static routing) and again for every salvage/park
/// re-placement; implementations must be pure in `(spec, cells)` plus
/// their own explicit cursor state, and must return an alive cell.
pub trait Router {
    fn name(&self) -> &'static str;

    /// Pick a cell for `spec`.  At least one entry of `cells` is alive.
    fn route(&mut self, spec: &JobSpec, cells: &[CellStatus]) -> usize;
}

/// Reference policy: cells in rotation, skipping dead ones.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl Router for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn route(&mut self, _spec: &JobSpec, cells: &[CellStatus]) -> usize {
        let n = cells.len();
        for off in 0..n {
            let i = (self.next + off) % n;
            if cells[i].alive {
                self.next = (i + 1) % n;
                return i;
            }
        }
        unreachable!("route called with no alive cell");
    }
}

/// Route to the alive cell with the least outstanding work; lowest index
/// wins ties, so placement is independent of map iteration order.
#[derive(Debug, Default)]
pub struct LeastLoad;

impl Router for LeastLoad {
    fn name(&self) -> &'static str {
        "least-load"
    }

    fn route(&mut self, _spec: &JobSpec, cells: &[CellStatus]) -> usize {
        cells
            .iter()
            .enumerate()
            .filter(|(_, c)| c.alive)
            .min_by_key(|(i, c)| (c.outstanding_work_ms, *i))
            .map(|(i, _)| i)
            .expect("route called with no alive cell")
    }
}

/// DRESS's SD/LD job classification made topological: small-demand jobs
/// go to the first `ceil(n/2)` cells, large-demand jobs to the rest, with
/// per-group rotation.  This is the paper's reservation split applied at
/// cluster granularity — LD jobs can never congest the SD cells' queues.
#[derive(Debug)]
pub struct ByCategory {
    classifier: Classifier,
    /// Static per-cell capacity vector the classifier measures against
    /// (every cell is provisioned identically).
    capacity: Demand,
    /// First LD cell; cells `[0, sd_cells)` serve SD jobs.
    sd_cells: usize,
    /// Per-group rotation cursors, indexed by `Category::index()`.
    cursor: [usize; 2],
}

impl ByCategory {
    pub fn new(theta: f64, cells: usize, capacity: Demand) -> Self {
        ByCategory {
            classifier: Classifier::new(theta),
            capacity,
            sd_cells: cells.div_ceil(2),
            cursor: [0, 0],
        }
    }
}

impl Router for ByCategory {
    fn name(&self) -> &'static str {
        "by-category"
    }

    fn route(&mut self, spec: &JobSpec, cells: &[CellStatus]) -> usize {
        // Classification is sticky (same as the in-cell classifier), so a
        // salvaged job re-routes to its original group.  Capacity is the
        // static provisioned vector: routing happens before admission, so
        // the live A_c of any one cell is not the right reference.
        let cat =
            self.classifier.classify(spec.id, spec.demand, self.capacity, self.capacity);
        let g = cat.index() as usize;
        let (lo, hi) = if self.sd_cells == 0 || self.sd_cells == cells.len() {
            (0, cells.len()) // degenerate split (n = 1): one shared group
        } else if g == 0 {
            (0, self.sd_cells)
        } else {
            (self.sd_cells, cells.len())
        };
        let span = hi - lo;
        for off in 0..span {
            let i = lo + (self.cursor[g] + off) % span;
            if cells[i].alive {
                self.cursor[g] = (i - lo + 1) % span;
                return i;
            }
        }
        // Whole group dead: first alive cell anywhere keeps jobs flowing.
        cells
            .iter()
            .position(|c| c.alive)
            .expect("route called with no alive cell")
    }
}

/// Build the configured router for an `n`-cell federation.
pub fn build_router(cfg: &ExperimentConfig, n: usize) -> Box<dyn Router> {
    match cfg.federation.router {
        RouterKind::RoundRobin => Box::new(RoundRobin::default()),
        RouterKind::LeastLoad => Box::new(LeastLoad),
        RouterKind::ByCategory => {
            let tc = cfg.cluster.total_containers();
            // One memory unit per slot (cluster/node.rs), so the static
            // capacity vector is square.
            Box::new(ByCategory::new(cfg.sched.theta, n, Demand::new(tc, tc)))
        }
    }
}

/// Outcome of a federated run: per-cell results plus federation-level
/// metrics.  [`Self::merged`] folds it into one [`RunResult`] so sweeps,
/// shards, and reports consume federated runs unchanged.
#[derive(Debug)]
pub struct FederationResult {
    /// Per-cell results, indexed by cell.
    pub cells: Vec<RunResult>,
    /// Jobs initially routed to each cell.
    pub routing: Vec<u32>,
    /// Cross-cell migrations (threshold rebalancing + death salvage).
    pub migrations: u32,
    /// Peak per-heartbeat `max(queued) / mean(queued)` over alive cells.
    pub imbalance_max: f64,
    /// Mean of the same ratio over sampled heartbeats.
    pub imbalance_mean: f64,
    /// Cell-outage accounting in injection order (fired outages only).
    pub cell_outages: Vec<CellOutageRecord>,
    /// Federation-level utilization: used containers across all cells
    /// against the summed provisioned capacity, sampled every heartbeat.
    pub util: UtilSummary,
    /// Router policy name.
    pub router: &'static str,
}

impl FederationResult {
    /// Fold into a single [`RunResult`].  For one cell the simulation
    /// fields pass through untouched (the bit-identity contract); for N
    /// cells, per-job metrics concatenate (sorted by submission for
    /// stable reports), counters sum, and system metrics derive from the
    /// federation-level utilization stream.
    pub fn merged(mut self) -> RunResult {
        let routing = std::mem::take(&mut self.routing);
        if self.cells.len() == 1 {
            let mut r = self.cells.remove(0);
            r.cells = 1;
            r.routing = routing;
            r.migrations = self.migrations;
            r.imbalance_max = self.imbalance_max;
            r.imbalance_mean = self.imbalance_mean;
            r.cell_outages = self.cell_outages;
            return r;
        }
        let n = self.cells.len() as u32;
        let mut jobs: Vec<JobMetrics> =
            self.cells.iter().flat_map(|c| c.jobs.iter().copied()).collect();
        jobs.sort_by_key(|j| (j.submit_ms, j.id));
        let system = SystemMetrics::of(&jobs, &self.util);
        let mut trace = TraceRecorder::default();
        let mut delta = DeltaSummary::default();
        for c in &self.cells {
            trace.tasks.extend(c.trace.tasks.iter().copied());
            delta.merge(&c.delta);
        }
        let sum = |f: fn(&RunResult) -> u64| self.cells.iter().map(f).sum::<u64>();
        let sum32 = |f: fn(&RunResult) -> u32| self.cells.iter().map(f).sum::<u32>();
        RunResult {
            scheduler: self.cells[0].scheduler.clone(),
            jobs,
            system,
            trace,
            // Per-sample histories stay per-cell (they would interleave
            // meaninglessly); the exact accumulators merge instead.
            delta_history: Vec::new(),
            util_history: Vec::new(),
            util: self.util,
            delta,
            util_recorded: self.util.samples,
            delta_recorded: sum(|c| c.delta_recorded),
            failures: sum32(|c| c.failures),
            lost_attempts: sum32(|c| c.lost_attempts),
            lost_work_ms: sum(|c| c.lost_work_ms),
            useful_work_ms: sum(|c| c.useful_work_ms),
            wasted_work_ms: sum(|c| c.wasted_work_ms),
            attempts: sum32(|c| c.attempts),
            outages: self.cells.iter().flat_map(|c| c.outages.iter().copied()).collect(),
            events: sum(|c| c.events),
            sched_ticks: sum(|c| c.sched_ticks),
            tasks_recorded: sum(|c| c.tasks_recorded),
            transitions_recorded: sum(|c| c.transitions_recorded),
            retained_transitions: self.cells.iter().map(|c| c.retained_transitions).sum(),
            cells: n,
            migrations: self.migrations,
            routing,
            imbalance_max: self.imbalance_max,
            imbalance_mean: self.imbalance_mean,
            cell_outages: self.cell_outages,
        }
    }
}

/// One planned cell outage's live bookkeeping.
struct CellOutage {
    rec: CellOutageRecord,
    /// The cell is back up (recovery transition applied).
    back: bool,
    /// Salvaged jobs not yet completed anywhere.
    waiting: u32,
}

/// A scheduled cell state change; recoveries sort before deaths at equal
/// times so a back-to-back plan never sees zero alive cells spuriously.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct CellTransition {
    at: Time,
    is_death: bool,
    outage: usize,
}

/// N cells on a global clock. Construct with [`Federation::new`], run to
/// completion with [`Federation::run`].
pub struct Federation {
    cfg: ExperimentConfig,
    cells: Vec<Cell>,
    status: Vec<CellStatus>,
    router: Box<dyn Router>,
    specs: Vec<JobSpec>,
    /// `JobId -> spec slot` (iteration order never consulted).
    slot_of: HashMap<JobId, usize>,
    /// Remaining-work estimate per spec slot (total task run-time).
    work: Vec<u64>,
    routing: Vec<u32>,
    outages: Vec<CellOutage>,
    transitions: Vec<CellTransition>,
    /// Outage a salvaged job is healing (iteration order never consulted).
    salvage_of: HashMap<JobId, usize>,
    /// Jobs with no alive cell to run on, waiting for a recovery.
    parked: Vec<JobId>,
    migrations: u32,
    finished: usize,
    util: UtilSummary,
    imb_max: f64,
    imb_sum: f64,
    imb_samples: u64,
}

impl Federation {
    pub fn new(cfg: &ExperimentConfig, specs: Vec<JobSpec>, opts: EngineOptions) -> Self {
        let n = cfg.federation.cells as usize;
        assert!(n >= 1, "federation needs at least one cell");
        let mut router = build_router(cfg, n);
        let mut status = vec![
            CellStatus { alive: true, routed_jobs: 0, outstanding_work_ms: 0, queued: 0 };
            n
        ];
        // Static routing: place every job before simulation starts, in
        // submission (spec) order.  With one cell every policy routes
        // everything to cell 0 — the bit-identity case.
        let mut masks = vec![vec![false; specs.len()]; n];
        let mut routing = vec![0u32; n];
        let mut slot_of = HashMap::with_capacity(specs.len());
        let mut work = Vec::with_capacity(specs.len());
        for (slot, s) in specs.iter().enumerate() {
            let dst = router.route(s, &status);
            assert!(status[dst].alive);
            masks[dst][slot] = true;
            routing[dst] += 1;
            status[dst].routed_jobs += 1;
            let w = s.work_ms() as u64;
            status[dst].outstanding_work_ms += w;
            slot_of.insert(s.id, slot);
            work.push(w);
        }
        let cells: Vec<Cell> = masks
            .iter()
            .map(|mask| {
                let sched = crate::sched::build(&cfg.sched, cfg.cluster.total_containers());
                let mut cell = Cell::with_assignment(
                    cfg.clone(),
                    specs.clone(),
                    Some(mask.as_slice()),
                    sched,
                    opts,
                );
                cell.collect_outputs(true);
                cell
            })
            .collect();
        // Cell outages share the node-fault grammar and seed stream, with
        // cell indices in the node field (validated in config/schema.rs).
        let planned = cfg
            .federation
            .cell_faults
            .materialize(cfg.federation.cells as u16, cfg.workload.seed)
            .unwrap_or_else(|e| panic!("invalid cell fault plan: {e}"));
        let mut outages = Vec::with_capacity(planned.len());
        let mut transitions = Vec::with_capacity(planned.len() * 2);
        for (i, o) in planned.iter().enumerate() {
            transitions.push(CellTransition { at: o.at_ms, is_death: true, outage: i });
            transitions.push(CellTransition {
                at: o.at_ms + o.down_ms,
                is_death: false,
                outage: i,
            });
            outages.push(CellOutage {
                rec: CellOutageRecord {
                    cell: o.node as u32,
                    at_ms: o.at_ms,
                    down_ms: o.down_ms,
                    salvaged: 0,
                    recovered_at: None,
                },
                back: false,
                waiting: 0,
            });
        }
        // `is_death: false < true` puts recoveries first at equal times.
        transitions.sort();
        let total = cfg.cluster.total_containers() * n as u32;
        Federation {
            cfg: cfg.clone(),
            cells,
            status,
            router,
            specs,
            slot_of,
            work,
            routing,
            outages,
            transitions,
            salvage_of: HashMap::new(),
            parked: Vec::new(),
            migrations: 0,
            finished: 0,
            util: UtilSummary::new(total),
            imb_max: 0.0,
            imb_sum: 0.0,
            imb_samples: 0,
        }
    }

    /// Lock-step all cells to completion and produce the result bundle.
    pub fn run(mut self) -> FederationResult {
        let hb = self.cfg.cluster.hb_ms;
        let max_ms: Time = 40 * 3_600 * 1_000; // same livelock guard as Cell
        let total_jobs = self.specs.len();
        let mut trans_i = 0usize;
        let mut t: Time = 0;
        loop {
            // 1. Advance every cell to the breakpoint (index order) and
            //    react to what they emitted.
            for i in 0..self.cells.len() {
                let outs = self.cells[i].advance_to(t);
                for out in outs {
                    self.on_output(i, out);
                }
            }
            // 2. Apply cell deaths/recoveries scheduled exactly here.
            while trans_i < self.transitions.len() && self.transitions[trans_i].at == t {
                let tr = self.transitions[trans_i];
                trans_i += 1;
                if tr.is_death {
                    self.on_cell_death(tr.outage, t);
                } else {
                    self.on_cell_recovery(tr.outage, t);
                }
            }
            // 3. Heartbeat-boundary bookkeeping: utilization + imbalance
            //    sampling, then threshold migration.
            if t % hb == 0 {
                let used: u32 = self.cells.iter().map(|c| c.used()).sum();
                self.util.push(t, used);
                self.sample_imbalance();
                self.rebalance(t);
            }
            if self.finished == total_jobs {
                break;
            }
            let next_hb = (t / hb + 1) * hb;
            let next = match self.transitions.get(trans_i) {
                Some(tr) => tr.at.min(next_hb),
                None => next_hb,
            };
            assert!(next > t);
            t = next;
            assert!(
                t <= max_ms,
                "federation livelock: {} of {total_jobs} jobs finished by t={t}ms",
                self.finished
            );
        }
        let outages: Vec<CellOutageRecord> = self
            .outages
            .iter()
            .filter(|o| o.rec.at_ms <= t)
            .map(|o| o.rec)
            .collect();
        FederationResult {
            routing: self.routing,
            migrations: self.migrations,
            imbalance_max: self.imb_max,
            imbalance_mean: if self.imb_samples == 0 {
                0.0
            } else {
                self.imb_sum / self.imb_samples as f64
            },
            cell_outages: outages,
            util: self.util,
            router: self.router.name(),
            cells: self.cells.into_iter().map(Cell::finish).collect(),
        }
    }

    fn on_output(&mut self, cell: usize, out: CellOutput) {
        match out {
            CellOutput::JobDone { job, at } => {
                self.finished += 1;
                let slot = self.slot_of[&job];
                self.status[cell].outstanding_work_ms =
                    self.status[cell].outstanding_work_ms.saturating_sub(self.work[slot]);
                if let Some(oi) = self.salvage_of.remove(&job) {
                    self.outages[oi].waiting -= 1;
                    self.try_heal(oi, at);
                }
            }
            CellOutput::Release { .. } | CellOutput::Heartbeat { .. } => {}
        }
    }

    /// An outage heals when the cell is back up AND every job salvaged
    /// from it has completed somewhere; `recovered_at` is the moment the
    /// later condition became true.
    fn try_heal(&mut self, oi: usize, at: Time) {
        let o = &mut self.outages[oi];
        if o.back && o.waiting == 0 && o.rec.recovered_at.is_none() {
            o.rec.recovered_at = Some(at);
        }
    }

    fn on_cell_death(&mut self, oi: usize, t: Time) {
        let ci = self.outages[oi].rec.cell as usize;
        assert!(self.status[ci].alive, "cell fault plan double-kills cell {ci}");
        self.status[ci].alive = false;
        self.cells[ci].fail_cell(t);
        let salvaged = self.cells[ci].withdraw_unfinished();
        self.outages[oi].rec.salvaged = salvaged.len() as u32;
        for id in salvaged {
            let slot = self.slot_of[&id];
            self.status[ci].outstanding_work_ms =
                self.status[ci].outstanding_work_ms.saturating_sub(self.work[slot]);
            // A job can be salvaged twice (its rescue cell died too); it
            // then heals the newest outage only.
            if let Some(old) = self.salvage_of.remove(&id) {
                self.outages[old].waiting -= 1;
                self.try_heal(old, t);
            }
            self.salvage_of.insert(id, oi);
            self.outages[oi].waiting += 1;
            self.place(id, t);
        }
    }

    fn on_cell_recovery(&mut self, oi: usize, t: Time) {
        let ci = self.outages[oi].rec.cell as usize;
        assert!(!self.status[ci].alive, "cell fault plan double-recovers cell {ci}");
        self.cells[ci].recover_cell(t);
        self.status[ci].alive = true;
        self.outages[oi].back = true;
        self.try_heal(oi, t);
        // Jobs that had nowhere to go can flow again.
        let parked = std::mem::take(&mut self.parked);
        for id in parked {
            self.place(id, t);
        }
    }

    /// Route `id` to an alive cell (or park it until a recovery), keeping
    /// the outstanding-work ledger and the migration counter in step.
    fn place(&mut self, id: JobId, t: Time) {
        if !self.status.iter().any(|s| s.alive) {
            self.parked.push(id);
            return;
        }
        let slot = self.slot_of[&id];
        let dst = self.router.route(&self.specs[slot], &self.status);
        assert!(self.status[dst].alive, "router placed a job on a dead cell");
        self.cells[dst].accept(id, t);
        self.status[dst].outstanding_work_ms += self.work[slot];
        self.migrations += 1;
    }

    /// Sample the cross-cell queue-imbalance ratio `max/mean` over alive
    /// cells.  Heartbeats where every alive queue is empty are skipped
    /// (the ratio is undefined, not balanced); single-cell federations
    /// never sample (the ratio is identically 1).
    fn sample_imbalance(&mut self) {
        for (i, c) in self.cells.iter().enumerate() {
            self.status[i].queued = if self.status[i].alive { c.queued_jobs() } else { 0 };
        }
        if self.cells.len() < 2 {
            return;
        }
        let alive: Vec<u32> = self
            .status
            .iter()
            .filter(|s| s.alive)
            .map(|s| s.queued)
            .collect();
        if alive.is_empty() {
            return;
        }
        let sum: u32 = alive.iter().sum();
        if sum == 0 {
            return;
        }
        let mean = sum as f64 / alive.len() as f64;
        let ratio = *alive.iter().max().unwrap() as f64 / mean;
        self.imb_max = self.imb_max.max(ratio);
        self.imb_sum += ratio;
        self.imb_samples += 1;
    }

    /// Threshold migration: while the alive max/min pending-queue gap
    /// exceeds `migrate_threshold`, move one cold queued job from the
    /// longest to the shortest queue.  Local counters track the moves —
    /// the destination's submit event has not fired yet, so asking the
    /// cell again would re-count.  Ties break to the lowest index.
    fn rebalance(&mut self, t: Time) {
        let k = self.cfg.federation.migrate_threshold;
        if k == 0 || self.status.iter().filter(|s| s.alive).count() < 2 {
            return;
        }
        let mut queued: Vec<u32> =
            self.status.iter().map(|s| if s.alive { s.queued } else { 0 }).collect();
        loop {
            let (mut src, mut dst) = (usize::MAX, usize::MAX);
            for (i, s) in self.status.iter().enumerate() {
                if !s.alive {
                    continue;
                }
                if src == usize::MAX || queued[i] > queued[src] {
                    src = i;
                }
                if dst == usize::MAX || queued[i] < queued[dst] {
                    dst = i;
                }
            }
            if src == dst || queued[src] - queued[dst] <= k {
                return;
            }
            let Some(id) = self.cells[src].withdraw_one_queued() else {
                return; // queue is all warm (started) jobs — nothing cold to move
            };
            let slot = self.slot_of[&id];
            self.cells[dst].accept(id, t);
            self.status[src].outstanding_work_ms =
                self.status[src].outstanding_work_ms.saturating_sub(self.work[slot]);
            self.status[dst].outstanding_work_ms += self.work[slot];
            self.migrations += 1;
            queued[src] -= 1;
            queued[dst] += 1;
            self.status[src].queued = queued[src];
            self.status[dst].queued = queued[dst];
        }
    }
}

/// Build and run a federation per `cfg.federation` (the
/// [`run_experiment_with`](crate::sim::engine::run_experiment_with) entry
/// point for `cells > 1`).
pub fn run_federation(
    cfg: &ExperimentConfig,
    specs: Vec<JobSpec>,
    opts: EngineOptions,
) -> FederationResult {
    Federation::new(cfg, specs, opts).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchedKind;
    use crate::jobs::{PhaseKind, PhaseSpec, Platform};
    use crate::sim::fault::FaultPlan;

    fn job(id: u32, submit: Time, demand: u32, durs: &[Time]) -> JobSpec {
        JobSpec {
            id,
            name: format!("job{id}"),
            platform: Platform::MapReduce,
            submit_ms: submit,
            demand: Demand::scalar(demand),
            phases: vec![PhaseSpec::new(PhaseKind::Map, durs)],
        }
    }

    fn status(n: usize) -> Vec<CellStatus> {
        vec![CellStatus { alive: true, routed_jobs: 0, outstanding_work_ms: 0, queued: 0 }; n]
    }

    #[test]
    fn round_robin_rotates_and_skips_dead() {
        let mut r = RoundRobin::default();
        let mut cells = status(3);
        let s = job(1, 0, 1, &[1_000]);
        assert_eq!(r.route(&s, &cells), 0);
        assert_eq!(r.route(&s, &cells), 1);
        assert_eq!(r.route(&s, &cells), 2);
        assert_eq!(r.route(&s, &cells), 0);
        cells[1].alive = false;
        assert_eq!(r.route(&s, &cells), 2, "dead cell skipped");
        assert_eq!(r.route(&s, &cells), 0);
    }

    #[test]
    fn least_load_prefers_lowest_work_then_lowest_index() {
        let mut r = LeastLoad;
        let mut cells = status(3);
        cells[0].outstanding_work_ms = 500;
        cells[1].outstanding_work_ms = 100;
        cells[2].outstanding_work_ms = 100;
        let s = job(1, 0, 1, &[1_000]);
        assert_eq!(r.route(&s, &cells), 1, "tie breaks to the lowest index");
        cells[1].alive = false;
        assert_eq!(r.route(&s, &cells), 2);
    }

    #[test]
    fn by_category_splits_sd_and_ld() {
        // 4 cells, capacity 40: theta 0.1 puts demand <= 4 in SD.
        let mut r = ByCategory::new(0.1, 4, Demand::new(40, 40));
        let cells = status(4);
        let sd = job(1, 0, 2, &[1_000]);
        let ld = job(2, 0, 30, &[1_000]);
        let a = r.route(&sd, &cells);
        let b = r.route(&ld, &cells);
        assert!(a < 2, "SD group is the first half, got {a}");
        assert!(b >= 2, "LD group is the second half, got {b}");
        // Rotation within the group, stickiness per job id.
        let sd2 = job(3, 0, 2, &[1_000]);
        assert_eq!(r.route(&sd2, &cells), 1);
        let mut dead = cells;
        dead[2].alive = false;
        dead[3].alive = false;
        assert!(r.route(&ld, &dead) < 2, "dead group falls back to any alive cell");
    }

    #[test]
    fn single_cell_federation_matches_plain_engine() {
        // Quick in-module check; the full scheduler/router matrix lives in
        // tests/federation_integration.rs.
        let mut cfg = ExperimentConfig::default();
        cfg.cluster.nodes = 2;
        cfg.cluster.slots_per_node = 3;
        cfg.sched.kind = SchedKind::Dress;
        let specs = vec![
            job(1, 0, 4, &[8_000, 8_000, 9_000, 9_000]),
            job(2, 1_000, 2, &[3_000, 3_000]),
            job(3, 2_000, 2, &[4_000, 4_000]),
        ];
        let plain = crate::sim::engine::run_experiment(&cfg, specs.clone());
        let fed =
            run_federation(&cfg, specs, EngineOptions::default()).merged();
        assert_eq!(fed.cells, 1);
        assert_eq!(fed.migrations, 0);
        assert_eq!(fed.routing, vec![3]);
        assert_eq!(plain.system.makespan_ms, fed.system.makespan_ms);
        assert_eq!(plain.events, fed.events);
        assert_eq!(plain.trace.tasks, fed.trace.tasks);
        assert_eq!(plain.jobs, fed.jobs);
        assert_eq!(plain.delta_history, fed.delta_history);
    }

    #[test]
    fn cell_death_salvages_and_recovers() {
        let mut cfg = ExperimentConfig::default();
        cfg.cluster.nodes = 2;
        cfg.cluster.slots_per_node = 4;
        cfg.federation.cells = 3;
        cfg.federation.router = RouterKind::RoundRobin;
        cfg.federation.migrate_threshold = 0; // isolate death salvage
        // Short downtime: the cell must come back *within* the run for
        // time-to-recover to be finite (same semantics as node outages).
        cfg.federation.cell_faults = FaultPlan::empty().with_outage(4_000, 1, 5_000);
        let specs: Vec<JobSpec> = (0..9)
            .map(|i| job(i + 1, i as Time * 500, 2, &[6_000, 6_000]))
            .collect();
        let res = run_federation(&cfg, specs, EngineOptions::default());
        assert_eq!(res.cells.len(), 3);
        assert_eq!(res.cell_outages.len(), 1);
        let o = &res.cell_outages[0];
        assert_eq!(o.cell, 1);
        assert!(o.salvaged > 0, "cell 1 held unfinished jobs at t=4s");
        assert!(res.migrations >= o.salvaged, "every salvaged job migrated");
        assert!(o.recovered_at.is_some(), "salvaged jobs finish elsewhere");
        assert!(o.time_to_recover_ms().unwrap() > 0);
        let merged = res.merged();
        assert_eq!(merged.jobs.len(), 9, "every job completed exactly once");
        assert_eq!(merged.cells, 3);
        // Attempt conservation survives the merge.
        assert_eq!(
            merged.attempts as u64,
            merged.tasks_recorded + merged.failures as u64 + merged.lost_attempts as u64
        );
    }

    #[test]
    fn threshold_migration_drains_hot_cell() {
        // All jobs routed to cell 0 by a biased initial state: use
        // round-robin with 2 cells but submit everything at once so cell 0
        // and 1 split evenly — then check the no-threshold run migrates
        // nothing and a tight threshold moves jobs.
        let mut cfg = ExperimentConfig::default();
        cfg.cluster.nodes = 1;
        cfg.cluster.slots_per_node = 2;
        cfg.federation.cells = 2;
        cfg.federation.router = RouterKind::LeastLoad;
        // least-load sends every job to the emptier cell; make job 1 huge
        // so jobs 2..n pile onto cell 1, then imbalance pulls them back.
        let mut specs = vec![job(1, 0, 2, &[30_000, 30_000])];
        for i in 2..=8 {
            specs.push(job(i, 100, 1, &[5_000]));
        }
        cfg.federation.migrate_threshold = 1;
        let moved = run_federation(&cfg, specs.clone(), EngineOptions::default());
        cfg.federation.migrate_threshold = 0;
        let frozen = run_federation(&cfg, specs, EngineOptions::default());
        assert_eq!(frozen.migrations, 0, "threshold 0 disables migration");
        assert!(moved.migrations > 0, "gap of 6 queued jobs exceeds threshold 1");
        let m = moved.merged();
        assert_eq!(m.jobs.len(), 8);
        assert_eq!(m.migrations, moved.migrations);
    }
}
