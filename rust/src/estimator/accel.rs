//! PJRT-accelerated evaluation of Eq. (1)-(3): packs [`PhaseEstimate`]s into
//! the padded table the AOT Pallas kernel expects and executes
//! `artifacts/model.hlo.txt`.  Must agree with [`super::release_model`] —
//! cross-validated in `rust/tests/runtime_integration.rs`.

use super::release_model::PhaseEstimate;
use crate::bail;
use crate::runtime::{Executable, Runtime, NUM_FIELDS, PAD_PHASES, TIME_GRID};
use crate::util::error::Result;

/// The estimator artifact, loaded and compiled once.
pub struct PjrtEstimator {
    exe: Executable,
    /// Reused input buffer (hot path: no per-call allocation of the table).
    table: Vec<f32>,
}

impl PjrtEstimator {
    pub fn load(rt: &Runtime, path: &str) -> Result<Self> {
        Ok(PjrtEstimator {
            exe: rt.load_hlo_text(path)?,
            table: vec![0f32; PAD_PHASES * NUM_FIELDS],
        })
    }

    /// Evaluate the per-category release curves over `tgrid`
    /// (len == TIME_GRID).  Returns (SD curve, LD curve).
    pub fn curves(
        &mut self,
        phases: &[PhaseEstimate],
        tgrid: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        if phases.len() > PAD_PHASES {
            bail!("{} phases exceed artifact pad {}", phases.len(), PAD_PHASES);
        }
        if tgrid.len() != TIME_GRID {
            bail!("tgrid len {} != artifact TIME_GRID {}", tgrid.len(), TIME_GRID);
        }
        self.table.fill(0.0);
        for (i, p) in phases.iter().enumerate() {
            self.table[i * NUM_FIELDS..(i + 1) * NUM_FIELDS].copy_from_slice(&p.to_row());
        }
        let out = self.exe.run_f32(&[
            (&self.table, &[PAD_PHASES as i64, NUM_FIELDS as i64]),
            (tgrid, &[TIME_GRID as i64]),
        ])?;
        if out.len() != 2 * TIME_GRID {
            bail!("artifact returned {} values, expected {}", out.len(), 2 * TIME_GRID);
        }
        Ok((out[..TIME_GRID].to_vec(), out[TIME_GRID..].to_vec()))
    }

    /// Build a uniform grid of TIME_GRID points over (now, horizon].
    pub fn grid(now: f64, horizon: f64) -> Vec<f32> {
        let span = (horizon - now).max(1.0);
        (0..TIME_GRID)
            .map(|i| (now + span * (i + 1) as f64 / TIME_GRID as f64) as f32)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_covers_interval() {
        let g = PjrtEstimator::grid(1_000.0, 2_000.0);
        assert_eq!(g.len(), TIME_GRID);
        assert!(g[0] > 1_000.0);
        assert!((g[TIME_GRID - 1] - 2_000.0).abs() < 1e-3);
        assert!(g.windows(2).all(|w| w[0] < w[1]));
    }
}
