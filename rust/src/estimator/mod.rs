//! Resource-release estimation (paper §III.B, §IV).
//!
//! The estimator watches container state transitions arriving in heartbeat
//! batches — never simulator ground truth — and maintains, per running job,
//! the detected phases with their parameters:
//!
//! * `Δps_j` — starting-time variation of phase j (Algorithm 1),
//! * `γ_j`   — earliest "bulk" finish time, heading tasks filtered (Algorithm 2),
//! * `c_j`   — containers occupied by the phase.
//!
//! [`release_model`] then evaluates Eq. (1)-(3) to predict per-category
//! container availability F₁(t), F₂(t); [`accel`] offloads the same
//! evaluation to the AOT-compiled Pallas kernel via PJRT.

pub mod accel;
pub mod phase_detect;
pub mod release_model;

pub use phase_detect::JobEstimator;
pub use release_model::{eval_curves, eval_phase, predicted_release, PhaseEstimate};

use crate::cluster::Transition;
use crate::jobs::JobId;
use crate::util::idmap::IdMap;
use crate::util::Time;

/// Estimator configuration (paper §V.A.1: t_s = t_e = 5, pw = 10 s).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EstimatorParams {
    pub ts: u32,
    pub te: u32,
    pub pw_ms: Time,
}

impl Default for EstimatorParams {
    fn default() -> Self {
        EstimatorParams { ts: 5, te: 5, pw_ms: 10_000 }
    }
}

/// Per-cluster estimator: one [`JobEstimator`] per observed job.
///
/// Perf (perf iter 4): both maps are dense id-indexed vectors ([`IdMap`]) —
/// job ids are small sequential integers, so lookup on the per-transition
/// hot path is an array index instead of a `BTreeMap` walk.  Iteration
/// order stays ascending-by-id, keeping float accumulation in
/// [`Self::predicted_release_pair`] bit-identical to the tree it replaced.
#[derive(Debug, Clone, Default)]
pub struct EstimatorBank {
    params: EstimatorParams,
    jobs: IdMap<JobEstimator>,
    /// Category per job (0 = SD, 1 = LD), registered by the scheduler.
    cats: IdMap<u8>,
    /// Dirty set for the batched tick (perf iter 6): job ids whose
    /// estimator may still mutate on a tick.  Jobs enter on ingest and
    /// leave once [`JobEstimator::tick_pending`] reports quiescence, so
    /// idle jobs cost nothing per heartbeat.
    active: Vec<JobId>,
    /// id -> currently in `active` (dense, like the id maps).
    active_mark: Vec<bool>,
}

impl EstimatorBank {
    pub fn new(params: EstimatorParams) -> Self {
        EstimatorBank {
            params,
            jobs: IdMap::new(),
            cats: IdMap::new(),
            active: Vec::new(),
            active_mark: Vec::new(),
        }
    }

    /// Register a job's category at submission (θ classification).
    pub fn register(&mut self, job: JobId, cat: u8) {
        self.cats.insert(job, cat);
    }

    /// Ingest a heartbeat transition batch.
    pub fn ingest(&mut self, transitions: &[Transition]) {
        for tr in transitions {
            let params = self.params;
            let cat = self.cats.get(tr.job).copied().unwrap_or(0);
            self.jobs
                .get_or_insert_with(tr.job, || JobEstimator::new(tr.job, cat, params))
                .on_transition(tr);
            self.mark_active(tr.job);
        }
    }

    fn mark_active(&mut self, job: JobId) {
        let i = job as usize;
        if i >= self.active_mark.len() {
            self.active_mark.resize(i + 1, false);
        }
        if !self.active_mark[i] {
            self.active_mark[i] = true;
            self.active.push(job);
        }
    }

    /// Advance window-based detection to `now` (each heartbeat): one
    /// batched pass over the dirty jobs only, retaining those whose
    /// detection state can still move without new observations.  Skipped
    /// jobs are exactly the ones whose `tick` would be a no-op (see
    /// [`JobEstimator::tick_pending`]), and per-job ticks are independent,
    /// so results are bit-identical to [`Self::tick_all`].
    pub fn tick(&mut self, now: Time) {
        let mut w = 0;
        for r in 0..self.active.len() {
            let id = self.active[r];
            let est = self.jobs.get_mut(id).expect("active job has an estimator");
            est.tick(now);
            if est.tick_pending() {
                self.active[w] = id;
                w += 1;
            } else {
                self.active_mark[id as usize] = false;
            }
        }
        self.active.truncate(w);
    }

    /// The pre-batching reference pass: tick every known estimator,
    /// dormant or not.  Kept for equivalence tests
    /// (`DressScheduler::naive_estimator_tick`).
    pub fn tick_all(&mut self, now: Time) {
        for est in self.jobs.values_mut() {
            est.tick(now);
        }
    }

    /// Jobs currently in the batched tick's dirty set (instrumentation).
    pub fn active_jobs(&self) -> usize {
        self.active.len()
    }

    /// Snapshot all live phase estimates (input to Eq. 1-3 / the kernel).
    pub fn snapshot(&self) -> Vec<PhaseEstimate> {
        self.jobs.values().flat_map(|j| j.estimates()).collect()
    }

    /// Predicted containers released by category `cat` in (now, horizon].
    pub fn predicted_release(&self, cat: u8, now: Time, horizon: Time) -> f64 {
        let (f1, f2) = self.predicted_release_pair(now, horizon);
        if cat == 0 {
            f1
        } else {
            f2
        }
    }

    /// Both categories in one allocation-free pass (the DRESS hot path).
    pub fn predicted_release_pair(&self, now: Time, horizon: Time) -> (f64, f64) {
        let (now, horizon) = (now as f64, horizon as f64);
        let (mut f1, mut f2) = (0.0, 0.0);
        for est in self.jobs.values() {
            est.for_each_estimate(|p| {
                let d = release_model::phase_release_delta(&p, now, horizon);
                if p.cat == 0 {
                    f1 += d;
                } else {
                    f2 += d;
                }
            });
        }
        (f1, f2)
    }

    pub fn job(&self, id: JobId) -> Option<&JobEstimator> {
        self.jobs.get(id)
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ContainerState;

    fn tr(time: Time, job: JobId, task: usize, to: ContainerState) -> Transition {
        Transition { time, container: task as u32, job, task, to }
    }

    #[test]
    fn bank_tracks_jobs_independently() {
        let mut bank = EstimatorBank::new(EstimatorParams::default());
        bank.register(1, 0);
        bank.register(2, 1);
        bank.ingest(&[
            tr(1_000, 1, 0, ContainerState::Running),
            tr(1_200, 2, 0, ContainerState::Running),
        ]);
        assert_eq!(bank.len(), 2);
        bank.tick(2_000);
        assert!(bank.job(1).is_some());
        assert!(bank.job(2).is_some());
    }

    #[test]
    fn empty_bank_predicts_zero() {
        let bank = EstimatorBank::new(EstimatorParams::default());
        assert_eq!(bank.predicted_release(0, 0, 1_000), 0.0);
        assert!(bank.snapshot().is_empty());
    }

    #[test]
    fn batched_tick_matches_tick_all() {
        // Identical observation streams; one bank ticks the dirty set, the
        // other ticks everything.  Detection state must agree exactly
        // (tests/properties.rs fuzzes this over random interleavings).
        let mut batched = EstimatorBank::new(EstimatorParams::default());
        let mut naive = EstimatorBank::new(EstimatorParams::default());
        let stream = [
            tr(1_000, 1, 0, ContainerState::Running),
            tr(1_100, 1, 1, ContainerState::Running),
            tr(1_300, 2, 0, ContainerState::Running),
            tr(9_000, 1, 0, ContainerState::Completed),
            tr(9_200, 1, 1, ContainerState::Completed),
            tr(30_000, 2, 0, ContainerState::Completed),
        ];
        let mut fed = 0;
        for now in (2_000..60_000).step_by(1_000) {
            while fed < stream.len() && stream[fed].time < now {
                batched.ingest(&stream[fed..fed + 1]);
                naive.ingest(&stream[fed..fed + 1]);
                fed += 1;
            }
            batched.tick(now);
            naive.tick_all(now);
        }
        for id in [1, 2] {
            assert_eq!(
                format!("{:?}", batched.job(id)),
                format!("{:?}", naive.job(id)),
                "estimator state drift for job {id}"
            );
        }
        let (b1, b2) = batched.predicted_release_pair(40_000, 60_000);
        let (n1, n2) = naive.predicted_release_pair(40_000, 60_000);
        assert_eq!(b1.to_bits(), n1.to_bits());
        assert_eq!(b2.to_bits(), n2.to_bits());
        assert_eq!(batched.active_jobs(), 0, "drained jobs must leave the dirty set");
    }
}
