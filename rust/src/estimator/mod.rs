//! Resource-release estimation (paper §III.B, §IV).
//!
//! The estimator watches container state transitions arriving in heartbeat
//! batches — never simulator ground truth — and maintains, per running job,
//! the detected phases with their parameters:
//!
//! * `Δps_j` — starting-time variation of phase j (Algorithm 1),
//! * `γ_j`   — earliest "bulk" finish time, heading tasks filtered (Algorithm 2),
//! * `c_j`   — containers occupied by the phase.
//!
//! [`release_model`] then evaluates Eq. (1)-(3) to predict per-category
//! container availability F₁(t), F₂(t); [`accel`] offloads the same
//! evaluation to the AOT-compiled Pallas kernel via PJRT.

pub mod accel;
pub mod phase_detect;
pub mod release_model;

pub use phase_detect::JobEstimator;
pub use release_model::{eval_curves, eval_phase, predicted_release, PhaseEstimate};

use crate::cluster::Transition;
use crate::jobs::JobId;
use crate::util::idmap::IdMap;
use crate::util::Time;

/// Estimator configuration (paper §V.A.1: t_s = t_e = 5, pw = 10 s).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EstimatorParams {
    pub ts: u32,
    pub te: u32,
    pub pw_ms: Time,
}

impl Default for EstimatorParams {
    fn default() -> Self {
        EstimatorParams { ts: 5, te: 5, pw_ms: 10_000 }
    }
}

/// Per-cluster estimator: one [`JobEstimator`] per observed job.
///
/// Perf (perf iter 4): both maps are dense id-indexed vectors ([`IdMap`]) —
/// job ids are small sequential integers, so lookup on the per-transition
/// hot path is an array index instead of a `BTreeMap` walk.  Iteration
/// order stays ascending-by-id, keeping float accumulation in
/// [`Self::predicted_release_pair`] bit-identical to the tree it replaced.
#[derive(Debug, Default)]
pub struct EstimatorBank {
    params: EstimatorParams,
    jobs: IdMap<JobEstimator>,
    /// Category per job (0 = SD, 1 = LD), registered by the scheduler.
    cats: IdMap<u8>,
}

impl EstimatorBank {
    pub fn new(params: EstimatorParams) -> Self {
        EstimatorBank { params, jobs: IdMap::new(), cats: IdMap::new() }
    }

    /// Register a job's category at submission (θ classification).
    pub fn register(&mut self, job: JobId, cat: u8) {
        self.cats.insert(job, cat);
    }

    /// Ingest a heartbeat transition batch.
    pub fn ingest(&mut self, transitions: &[Transition]) {
        for tr in transitions {
            let params = self.params;
            let cat = self.cats.get(tr.job).copied().unwrap_or(0);
            self.jobs
                .get_or_insert_with(tr.job, || JobEstimator::new(tr.job, cat, params))
                .on_transition(tr);
        }
    }

    /// Advance window-based detection to `now` (each heartbeat).
    pub fn tick(&mut self, now: Time) {
        for est in self.jobs.values_mut() {
            est.tick(now);
        }
    }

    /// Snapshot all live phase estimates (input to Eq. 1-3 / the kernel).
    pub fn snapshot(&self) -> Vec<PhaseEstimate> {
        self.jobs.values().flat_map(|j| j.estimates()).collect()
    }

    /// Predicted containers released by category `cat` in (now, horizon].
    pub fn predicted_release(&self, cat: u8, now: Time, horizon: Time) -> f64 {
        let (f1, f2) = self.predicted_release_pair(now, horizon);
        if cat == 0 {
            f1
        } else {
            f2
        }
    }

    /// Both categories in one allocation-free pass (the DRESS hot path).
    pub fn predicted_release_pair(&self, now: Time, horizon: Time) -> (f64, f64) {
        let (now, horizon) = (now as f64, horizon as f64);
        let (mut f1, mut f2) = (0.0, 0.0);
        for est in self.jobs.values() {
            est.for_each_estimate(|p| {
                let d = release_model::phase_release_delta(&p, now, horizon);
                if p.cat == 0 {
                    f1 += d;
                } else {
                    f2 += d;
                }
            });
        }
        (f1, f2)
    }

    pub fn job(&self, id: JobId) -> Option<&JobEstimator> {
        self.jobs.get(id)
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ContainerState;

    fn tr(time: Time, job: JobId, task: usize, to: ContainerState) -> Transition {
        Transition { time, container: task as u32, job, task, to }
    }

    #[test]
    fn bank_tracks_jobs_independently() {
        let mut bank = EstimatorBank::new(EstimatorParams::default());
        bank.register(1, 0);
        bank.register(2, 1);
        bank.ingest(&[
            tr(1_000, 1, 0, ContainerState::Running),
            tr(1_200, 2, 0, ContainerState::Running),
        ]);
        assert_eq!(bank.len(), 2);
        bank.tick(2_000);
        assert!(bank.job(1).is_some());
        assert!(bank.job(2).is_some());
    }

    #[test]
    fn empty_bank_predicts_zero() {
        let bank = EstimatorBank::new(EstimatorParams::default());
        assert_eq!(bank.predicted_release(0, 0, 1_000), 0.0);
        assert!(bank.snapshot().is_empty());
    }
}
