//! Algorithms 1 & 2: per-job phase detection from heartbeat observations.
//!
//! Algorithm 1 (starting variation): tasks whose containers enter Running
//! are grouped into phases by watching the running count inside a sliding
//! window `pw`; a burst of more than `t_s` new starts opens a phase, a
//! window with no new starts closes its start ramp and fixes
//! `Δps = ps_last - ps_first`.
//!
//! Algorithm 2 (start-release time): a burst of more than `t_e` completions
//! inside `pw` marks the phase's release start `γ` (taking the minimum
//! finish *within the triggering window*, which filters heading tasks that
//! completed abnormally early); a completion stall with tasks still running
//! marks those as trailing tasks, counted into the next phase.
//!
//! Adaptation (documented, paper is ambiguous here): the paper sets
//! t_s = t_e = 5 for 5-node HiBench jobs, but small jobs can have phases
//! with fewer than 5 tasks which would then never be detected.  We apply
//! the paper's thresholds for burst detection but additionally open/close
//! on *stability*: an unassigned start/finish older than a full window is
//! folded in even if the burst threshold was never crossed.

use super::release_model::PhaseEstimate;
use super::EstimatorParams;
use crate::cluster::{ContainerState, Transition};
use crate::jobs::JobId;
use crate::util::Time;

/// One detected phase (observation side of the paper's `p_j`).
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseObs {
    /// Start of the first task (`ps_jf`).
    pub ps_first: Time,
    /// Start of the last task (`ps_jl`), once the ramp closed.
    pub ps_last: Option<Time>,
    /// Containers assigned to this phase (`c_pj`), including trailing
    /// carry-over from the previous phase.
    pub c: u32,
    /// Release start (`γ_j`), once detected.
    pub gamma: Option<Time>,
    /// Completions attributed to this phase so far.
    pub completed: u32,
    /// Phase considered fully drained (no more release expected).
    pub closed: bool,
}

impl PhaseObs {
    /// Δps; while the ramp is still open, the provisional spread so far.
    pub fn dps(&self, latest_start: Time) -> Time {
        self.ps_last.unwrap_or(latest_start).saturating_sub(self.ps_first)
    }
}

/// Per-job online estimator (Algorithms 1 + 2 fused over one event stream).
#[derive(Debug, Clone)]
pub struct JobEstimator {
    pub job: JobId,
    pub cat: u8,
    params: EstimatorParams,
    /// Job start `α_i`: first Running observed.
    pub alpha: Option<Time>,
    /// Job end `β_i`: set when running drops to zero with no pending ramp.
    pub beta: Option<Time>,
    /// Start times not yet assigned to a phase.
    unassigned_starts: Vec<Time>,
    /// Finish times not yet attributed to a phase's release.
    unassigned_finishes: Vec<Time>,
    /// Currently running containers.
    pub running: u32,
    /// Detected phases in order.
    pub phases: Vec<PhaseObs>,
    /// Index of the phase whose start ramp is currently open.
    open_phase: Option<usize>,
    /// Trailing tasks carried into the next phase (Algorithm 2 line 12).
    carry_c: u32,
    latest_start: Time,
    /// Latest Completed transition observed (for β).
    last_finish: Option<Time>,
}

impl JobEstimator {
    pub fn new(job: JobId, cat: u8, params: EstimatorParams) -> Self {
        JobEstimator {
            job,
            cat,
            params,
            alpha: None,
            beta: None,
            unassigned_starts: Vec::new(),
            unassigned_finishes: Vec::new(),
            running: 0,
            phases: Vec::new(),
            open_phase: None,
            carry_c: 0,
            latest_start: 0,
            last_finish: None,
        }
    }

    /// Feed one observed transition (only Running / Completed matter).
    pub fn on_transition(&mut self, tr: &Transition) {
        debug_assert_eq!(tr.job, self.job);
        match tr.to {
            ContainerState::Running => {
                self.alpha = Some(self.alpha.map_or(tr.time, |a| a.min(tr.time)));
                self.latest_start = self.latest_start.max(tr.time);
                self.unassigned_starts.push(tr.time);
                self.running += 1;
            }
            ContainerState::Completed => {
                self.unassigned_finishes.push(tr.time);
                self.last_finish = Some(self.last_finish.map_or(tr.time, |f| f.max(tr.time)));
                self.running = self.running.saturating_sub(1);
            }
            _ => {}
        }
    }

    /// Sliding-window pass (call at each heartbeat with the current time).
    pub fn tick(&mut self, now: Time) {
        self.detect_phase_starts(now);
        self.detect_release(now);
        if self.running == 0
            && self.unassigned_starts.is_empty()
            && self.open_phase.is_none()
            && self.alpha.is_some()
            && self.phases.iter().all(|p| p.closed)
        {
            // All observed work drained: β_i = latest finish (Algo 2 line 14).
            if let Some(last) = self.last_finish {
                self.beta = Some(self.beta.map_or(last, |b| b.max(last)));
            }
        }
    }

    /// Whether a future [`Self::tick`] could still mutate this estimator
    /// without new transitions arriving.  Derived from the exact mutation
    /// conditions of `detect_phase_starts`, `detect_release`, and the β
    /// block; when this returns `false`, `tick(now)` is a no-op for
    /// *every* `now`, so the bank's batched pass can skip the job until
    /// its next ingested transition (`EstimatorBank::tick`).  Proven
    /// equivalent to unconditional ticking by the property test in
    /// tests/properties.rs and by whole-run goldens.
    pub fn tick_pending(&self) -> bool {
        // Algorithm 1 can open a phase (stability fallback fires once the
        // oldest start ages past pw) or close an open ramp as time passes.
        if !self.unassigned_starts.is_empty() || self.open_phase.is_some() {
            return true;
        }
        // Algorithm 2 operates on the earliest unclosed phase.
        if let Some(p) = self.phases.iter().find(|p| !p.closed) {
            // Pending finishes can fix γ or be attributed to the phase.
            if !self.unassigned_finishes.is_empty() {
                return true;
            }
            // With γ known and no finishes in flight, the close conditions
            // (`completed >= c`, or a stall with tasks still running) are
            // time-independent: if one holds, the very next tick mutates.
            if p.gamma.is_some() && (p.completed >= p.c || self.running > 0) {
                return true;
            }
            // γ still unknown and nothing to observe: dormant until the
            // next transition re-marks the job.
            return false;
        }
        // All phases closed: β catches up to the latest finish once the
        // job is drained.
        if self.running == 0 && self.alpha.is_some() {
            if let Some(last) = self.last_finish {
                if self.beta.is_none_or(|b| b < last) {
                    return true;
                }
            }
        }
        false
    }

    // --- Algorithm 1 ---------------------------------------------------
    fn detect_phase_starts(&mut self, now: Time) {
        let pw = self.params.pw_ms;
        let win_lo = now.saturating_sub(pw);
        let in_window =
            self.unassigned_starts.iter().filter(|&&t| t > win_lo).count() as u32;

        if self.open_phase.is_none() && !self.unassigned_starts.is_empty() {
            let oldest = *self.unassigned_starts.iter().min().unwrap();
            // Burst (line 11) or stability fallback for narrow phases.
            if in_window > self.params.ts || oldest <= win_lo {
                let ps_first = oldest;
                self.phases.push(PhaseObs {
                    ps_first,
                    ps_last: None,
                    c: self.carry_c,
                    gamma: None,
                    completed: 0,
                    closed: false,
                });
                self.carry_c = 0;
                self.open_phase = Some(self.phases.len() - 1);
            }
        }

        if let Some(pi) = self.open_phase {
            // Absorb all observed starts into the open phase.
            let n = self.unassigned_starts.len() as u32;
            if n > 0 {
                self.phases[pi].c += n;
                let last = *self.unassigned_starts.iter().max().unwrap();
                self.phases[pi].ps_last =
                    Some(self.phases[pi].ps_last.map_or(last, |l| l.max(last)));
                self.unassigned_starts.clear();
            }
            // Ramp closes when a full window passes with no new starts
            // (lines 14-16): ps_last is final, Δps fixed.
            let last = self.phases[pi].ps_last.unwrap_or(self.phases[pi].ps_first);
            if now.saturating_sub(last) >= pw {
                self.open_phase = None;
            }
        }
    }

    // --- Algorithm 2 ---------------------------------------------------
    fn detect_release(&mut self, now: Time) {
        let pw = self.params.pw_ms;
        let win_lo = now.saturating_sub(pw);

        // Find the earliest phase that has started but not closed: releases
        // are attributed oldest-phase-first (phases are barriers).
        let Some(pi) = self.phases.iter().position(|p| !p.closed) else {
            return;
        };

        let in_window: Vec<Time> = self
            .unassigned_finishes
            .iter()
            .copied()
            .filter(|&t| t > win_lo)
            .collect();

        if self.phases[pi].gamma.is_none() && !self.unassigned_finishes.is_empty() {
            let oldest = *self.unassigned_finishes.iter().min().unwrap();
            if in_window.len() as u32 > self.params.te {
                // Burst: γ = min finish inside the window — heading tasks
                // that completed before the bulk are filtered out (line 8-10).
                self.phases[pi].gamma = in_window.iter().copied().min();
            } else if self.phases[pi].c <= self.params.te
                && oldest <= win_lo
                && in_window.is_empty()
            {
                // Stability fallback ONLY for phases narrower than t_e —
                // a wide phase must wait for its completion burst, otherwise
                // an isolated heading task would masquerade as γ and the
                // stalled bulk would be misread as trailing tasks.
                self.phases[pi].gamma = Some(oldest);
            }
        }

        if self.phases[pi].gamma.is_some() {
            // Attribute all drained finishes to this phase.
            let n = self.unassigned_finishes.len() as u32;
            self.phases[pi].completed += n;
            self.unassigned_finishes.clear();

            let done = self.phases[pi].completed >= self.phases[pi].c;
            let latest_finish_stalled = in_window.is_empty();
            if done {
                self.phases[pi].closed = true;
            } else if latest_finish_stalled && self.running > 0 {
                // Completion stall with tasks still running: trailing tasks —
                // count them into the next phase (lines 11-12) and close.
                let remaining = self.phases[pi].c - self.phases[pi].completed;
                self.carry_c += remaining;
                self.phases[pi].c = self.phases[pi].completed;
                self.phases[pi].closed = true;
            }
        }
    }

    /// Live phase estimates for Eq. (1)-(3): phases with a known γ that have
    /// not fully drained contribute a release ramp.
    pub fn estimates(&self) -> Vec<PhaseEstimate> {
        let mut out = Vec::new();
        self.for_each_estimate(|p| out.push(p));
        out
    }

    /// Allocation-free visitor over live phase estimates (perf iter 3: the
    /// DRESS heartbeat calls this once per tick instead of materializing
    /// snapshot vectors per category).
    pub fn for_each_estimate(&self, mut f: impl FnMut(PhaseEstimate)) {
        let Some(alpha) = self.alpha else { return };
        let alpha = alpha as f64;
        let beta = self.beta.map_or(f64::MAX, |b| b as f64);
        for p in &self.phases {
            let Some(gamma) = p.gamma else { continue };
            f(PhaseEstimate {
                gamma: gamma as f64,
                dps: p.dps(self.latest_start) as f64,
                c: p.c as f64,
                alpha,
                beta,
                cat: self.cat,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tr(time: Time, task: usize, to: ContainerState) -> Transition {
        Transition { time, container: task as u32, job: 1, task, to }
    }

    fn est() -> JobEstimator {
        JobEstimator::new(1, 0, EstimatorParams { ts: 5, te: 5, pw_ms: 10_000 })
    }

    /// Drive a wave of `n` task starts around `t0` spaced `gap` apart,
    /// then finishes around `f0`.
    fn wave(e: &mut JobEstimator, n: usize, t0: Time, gap: Time) {
        for i in 0..n {
            e.on_transition(&tr(t0 + i as Time * gap, i, ContainerState::Running));
        }
    }

    #[test]
    fn burst_opens_phase_and_measures_dps() {
        let mut e = est();
        wave(&mut e, 8, 5_000, 500); // starts 5000..8500 (Δps = 3500)
        e.tick(9_000); // 8 starts within window > ts=5 -> phase opens
        assert_eq!(e.phases.len(), 1);
        assert_eq!(e.phases[0].c, 8);
        assert_eq!(e.phases[0].ps_first, 5_000);
        // ramp closes after a quiet window
        e.tick(20_000);
        assert_eq!(e.phases[0].ps_last, Some(8_500));
        assert_eq!(e.phases[0].dps(0), 3_500);
        assert_eq!(e.alpha, Some(5_000));
    }

    #[test]
    fn small_phase_detected_by_stability() {
        let mut e = est();
        wave(&mut e, 2, 1_000, 300); // only 2 tasks, below ts
        e.tick(2_000);
        assert!(e.phases.is_empty(), "burst threshold not crossed yet");
        e.tick(12_000); // oldest start now outside window -> stability open
        assert_eq!(e.phases.len(), 1);
        assert_eq!(e.phases[0].c, 2);
    }

    #[test]
    fn gamma_from_completion_burst_filters_heading() {
        let mut e = est();
        wave(&mut e, 9, 0, 200);
        e.tick(3_000);
        assert_eq!(e.phases.len(), 1);
        // Heading task finishes abnormally early (paper Fig 3: 1.26 s vs 18 s).
        e.on_transition(&tr(2_000, 0, ContainerState::Completed));
        e.tick(4_000);
        // Bulk completes much later, within one window.
        for i in 1..8 {
            e.on_transition(&tr(20_000 + i as Time * 300, i, ContainerState::Completed));
        }
        e.tick(24_000);
        let gamma = e.phases[0].gamma.expect("gamma detected");
        // γ is min finish in the *triggering window*: 20_300, not the
        // heading task's 2_000.
        assert_eq!(gamma, 20_300);
    }

    #[test]
    fn trailing_tasks_carry_to_next_phase() {
        let mut e = est();
        wave(&mut e, 8, 0, 100);
        e.tick(1_000);
        assert_eq!(e.phases[0].c, 8);
        // 7 finish promptly; 1 trails (data skew).
        for i in 0..7 {
            e.on_transition(&tr(10_000 + i as Time * 200, i, ContainerState::Completed));
        }
        e.tick(12_000);
        assert!(e.phases[0].gamma.is_some());
        // Long stall while the trailing task still runs.
        e.tick(30_000);
        assert!(e.phases[0].closed);
        assert_eq!(e.phases[0].c, 7, "trailing task excluded");
        // Next wave: trailing carry lands in phase 2's count.
        wave(&mut e, 4, 31_000, 100); // tasks 8..11? reuse indices: fine
        e.tick(45_000);
        assert_eq!(e.phases.len(), 2);
        assert_eq!(e.phases[1].c, 4 + 1, "carry_c included");
    }

    #[test]
    fn beta_set_when_drained() {
        let mut e = est();
        wave(&mut e, 6, 0, 100);
        e.tick(1_000);
        for i in 0..6 {
            e.on_transition(&tr(5_000 + i as Time * 100, i, ContainerState::Completed));
        }
        // Heartbeats arrive every second in reality: the completion burst is
        // observed inside a pw window (6 > t_e), fixing γ and closing the phase.
        e.tick(6_000);
        e.tick(16_000);
        e.tick(17_000);
        assert_eq!(e.running, 0);
        assert_eq!(e.beta, Some(5_500));
    }

    #[test]
    fn estimates_empty_before_any_start() {
        let e = est();
        assert!(e.estimates().is_empty());
    }

    #[test]
    fn estimates_expose_release_ramp() {
        let mut e = est();
        wave(&mut e, 8, 0, 500);
        e.tick(5_000);
        for i in 0..8 {
            e.on_transition(&tr(15_000 + i as Time * 400, i, ContainerState::Completed));
        }
        e.tick(19_000);
        let ests = e.estimates();
        assert_eq!(ests.len(), 1);
        let p = &ests[0];
        assert_eq!(p.c, 8.0);
        assert_eq!(p.gamma, 15_000.0);
        assert_eq!(p.alpha, 0.0);
        assert!(p.dps > 0.0);
    }
}
