//! Eq. (1)-(3): the resource-release model, evaluated in pure Rust.
//!
//! This is the authoritative CPU implementation; the Pallas kernel
//! (`python/compile/kernels/release_estimator.py`) and the PJRT-executed
//! artifact must agree with it bit-closely (see `rust/tests/` and
//! `python/tests/test_kernel.py` — all three share the same EPS and the
//! same dps == 0 step semantics).

/// Mirror of the kernel's EPS guard.
pub const EPS: f64 = 1e-6;

/// One phase's release parameters (the kernel's packed row layout:
/// gamma, dps, c, alpha, beta, cat).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PhaseEstimate {
    /// Earliest bulk finish time γ (ms).
    pub gamma: f64,
    /// Starting variation Δps (ms).
    pub dps: f64,
    /// Containers occupied by the phase.
    pub c: f64,
    /// Job start α (ms).
    pub alpha: f64,
    /// Job end β (ms; f64::MAX while the job runs).
    pub beta: f64,
    /// 0 = SD, 1 = LD.
    pub cat: u8,
}

impl PhaseEstimate {
    /// Packed f32 row for the AOT artifact.
    pub fn to_row(&self) -> [f32; 6] {
        // f64::MAX would overflow f32; saturate to a large finite sentinel.
        let beta = if self.beta > 1e30 { 3.0e38 } else { self.beta };
        [
            self.gamma as f32,
            self.dps as f32,
            self.c as f32,
            self.alpha as f32,
            beta as f32,
            self.cat as f32,
        ]
    }
}

/// Eq. (3): containers released by one phase at absolute time `t`, gated by
/// the job interval (Eq. 2).  `dps <= EPS` degenerates to a step at γ.
pub fn eval_phase(p: &PhaseEstimate, t: f64) -> f64 {
    let in_window = t >= p.gamma && t <= p.gamma + p.dps;
    let in_job = t >= p.alpha && t <= p.beta;
    if !(in_window && in_job) {
        return 0.0;
    }
    let frac = if p.dps <= EPS {
        1.0
    } else {
        ((t - p.gamma) / p.dps).clamp(0.0, 1.0)
    };
    frac * p.c
}

/// Eq. (1): per-category curves over a time grid — the Rust mirror of the
/// Pallas kernel (used to cross-validate the PJRT artifact).
///
/// Perf (EXPERIMENTS.md §Perf iter 1): for ascending grids — the scheduler
/// always evaluates ascending horizons — each phase touches only the grid
/// indices inside its release window (binary search), instead of testing
/// every (phase, t) pair.  Unsorted grids fall back to the naive product.
pub fn eval_curves(phases: &[PhaseEstimate], tgrid: &[f64]) -> [Vec<f64>; 2] {
    let mut sd = vec![0.0; tgrid.len()];
    let mut ld = vec![0.0; tgrid.len()];
    let sorted = tgrid.windows(2).all(|w| w[0] <= w[1]);
    for p in phases {
        let out = if p.cat == 0 { &mut sd } else { &mut ld };
        if !sorted {
            for (i, &t) in tgrid.iter().enumerate() {
                out[i] += eval_phase(p, t);
            }
            continue;
        }
        // Active interval = release window ∩ job interval.
        let lo_t = p.gamma.max(p.alpha);
        let hi_t = (p.gamma + p.dps).min(p.beta);
        if hi_t < lo_t {
            continue;
        }
        let lo = tgrid.partition_point(|&t| t < lo_t);
        let hi = tgrid.partition_point(|&t| t <= hi_t);
        if p.dps <= EPS {
            for v in &mut out[lo..hi] {
                *v += p.c;
            }
        } else {
            let inv = p.c / p.dps;
            for (i, v) in out[lo..hi].iter_mut().enumerate() {
                let frac = (tgrid[lo + i] - p.gamma).clamp(0.0, p.dps);
                *v += frac * inv;
            }
        }
    }
    [sd, ld]
}

/// Eq. (3) treated as *cumulative*: a phase past its window has fully
/// released, so the curve saturates at `c` instead of dropping to zero.
/// This is the form the delta prediction needs.
pub fn saturating_eval(p: &PhaseEstimate, t: f64) -> f64 {
    if t > p.gamma + p.dps && t >= p.alpha && p.gamma + p.dps <= p.beta {
        p.c
    } else {
        eval_phase(p, t)
    }
}

/// Containers one phase is predicted to release in (now, horizon]:
/// max(0, p(horizon) - p(now)) in saturating form — the delta avoids
/// double-counting containers already returned to A_c before `now`.
pub fn phase_release_delta(p: &PhaseEstimate, now: f64, horizon: f64) -> f64 {
    (saturating_eval(p, horizon) - saturating_eval(p, now)).max(0.0)
}

/// Containers category `cat` is predicted to release in (now, horizon].
pub fn predicted_release(phases: &[PhaseEstimate], cat: u8, now: f64, horizon: f64) -> f64 {
    phases
        .iter()
        .filter(|p| p.cat == cat)
        .map(|p| phase_release_delta(p, now, horizon))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ph(gamma: f64, dps: f64, c: f64, cat: u8) -> PhaseEstimate {
        PhaseEstimate { gamma, dps, c, alpha: 0.0, beta: f64::MAX, cat }
    }

    #[test]
    fn ramp_endpoints() {
        let p = ph(10.0, 20.0, 8.0, 0);
        assert_eq!(eval_phase(&p, 9.9), 0.0);
        assert_eq!(eval_phase(&p, 10.0), 0.0);
        assert!((eval_phase(&p, 20.0) - 4.0).abs() < 1e-12);
        assert!((eval_phase(&p, 30.0) - 8.0).abs() < 1e-12);
        assert_eq!(eval_phase(&p, 30.1), 0.0, "eq3: zero after the window");
    }

    #[test]
    fn step_when_dps_zero() {
        let p = ph(10.0, 0.0, 5.0, 0);
        assert_eq!(eval_phase(&p, 9.0), 0.0);
        assert_eq!(eval_phase(&p, 10.0), 5.0);
        assert_eq!(eval_phase(&p, 10.5), 0.0);
    }

    #[test]
    fn job_interval_gates() {
        let mut p = ph(10.0, 20.0, 8.0, 0);
        p.beta = 15.0;
        assert!(eval_phase(&p, 12.0) > 0.0);
        assert_eq!(eval_phase(&p, 16.0), 0.0);
        p.alpha = 11.0;
        assert_eq!(eval_phase(&p, 10.5), 0.0);
    }

    #[test]
    fn curves_split_categories() {
        let phases = [ph(0.0, 10.0, 4.0, 0), ph(0.0, 10.0, 6.0, 1)];
        let grid = [0.0, 5.0, 10.0];
        let [sd, ld] = eval_curves(&phases, &grid);
        assert_eq!(sd, vec![0.0, 2.0, 4.0]);
        assert_eq!(ld, vec![0.0, 3.0, 6.0]);
    }

    #[test]
    fn predicted_release_delta_form() {
        let phases = [ph(100.0, 100.0, 10.0, 0)];
        // Mid-ramp to later mid-ramp: the delta, not the absolute value.
        let d = predicted_release(&phases, 0, 150.0, 175.0);
        assert!((d - 2.5).abs() < 1e-12, "{d}");
        // Before the ramp to after it: everything.
        assert!((predicted_release(&phases, 0, 0.0, 1e6) - 10.0).abs() < 1e-12);
        // After the window: nothing left.
        assert_eq!(predicted_release(&phases, 0, 300.0, 400.0), 0.0);
        // Wrong category: nothing.
        assert_eq!(predicted_release(&phases, 1, 150.0, 175.0), 0.0);
    }

    /// The window-clipped fast path must agree with per-point eval_phase on
    /// both sorted and unsorted grids (perf iter 1 regression guard).
    #[test]
    fn fast_path_matches_naive() {
        let mut rng = crate::util::rng::Rng::new(0xC0FFEE);
        for case in 0..200 {
            let n = (rng.next_u64() % 12) as usize;
            let phases: Vec<PhaseEstimate> = (0..n)
                .map(|i| PhaseEstimate {
                    gamma: rng.range_f64(0.0, 2_000.0),
                    dps: if i % 4 == 0 { 0.0 } else { rng.range_f64(0.0, 800.0) },
                    c: rng.range_f64(0.0, 20.0),
                    alpha: rng.range_f64(0.0, 500.0),
                    beta: if i % 3 == 0 { f64::MAX } else { rng.range_f64(500.0, 4_000.0) },
                    cat: (i % 2) as u8,
                })
                .collect();
            let mut grid: Vec<f64> = (0..33).map(|_| rng.range_f64(0.0, 4_000.0)).collect();
            if case % 2 == 0 {
                grid.sort_by(|a, b| a.partial_cmp(b).unwrap());
            }
            let [sd, ld] = eval_curves(&phases, &grid);
            for (i, &t) in grid.iter().enumerate() {
                let want_sd: f64 = phases.iter().filter(|p| p.cat == 0).map(|p| eval_phase(p, t)).sum();
                let want_ld: f64 = phases.iter().filter(|p| p.cat == 1).map(|p| eval_phase(p, t)).sum();
                assert!((sd[i] - want_sd).abs() < 1e-9, "case {case} sd[{i}]");
                assert!((ld[i] - want_ld).abs() < 1e-9, "case {case} ld[{i}]");
            }
        }
    }

    #[test]
    fn to_row_saturates_beta() {
        let p = ph(1.0, 2.0, 3.0, 1);
        let row = p.to_row();
        assert_eq!(row[0], 1.0);
        assert_eq!(row[5], 1.0);
        assert!(row[4].is_finite());
    }
}
