//! # dress — Dynamic RESource-reservation Scheme
//!
//! A full reproduction of *DRESS: Dynamic RESource-reservation Scheme for
//! Congested Data-intensive Computing Platforms* (Mao et al., 2018) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the coordinator: a YARN-fidelity cluster
//!   simulator, the DRESS scheduler with its release estimator
//!   (Algorithms 1-3), the Fair/Capacity/FIFO baselines, workload
//!   generation, metrics, and the experiment registry reproducing every
//!   figure and table of the paper's evaluation.
//! * **Layer 2** — `python/compile/model.py`: JAX compute graphs, AOT-lowered
//!   once to HLO text.
//! * **Layer 1** — `python/compile/kernels/release_estimator.py`: the Pallas
//!   kernel evaluating Eq. (1)-(3), executed from Rust via PJRT
//!   ([`runtime`], [`estimator::accel`]).
//!
//! Quickstart (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use dress::config::ExperimentConfig;
//! use dress::sim::engine::run_experiment;
//! use dress::workload::{generate, WorkloadMix};
//!
//! let mut cfg = ExperimentConfig::default();
//! cfg.sched.kind = dress::config::SchedKind::Dress;
//! let jobs = generate(20, WorkloadMix::Mixed, 0.3, 5_000, 42);
//! let result = run_experiment(&cfg, jobs);
//! println!("makespan: {} ms", result.system.makespan_ms);
//! ```

pub mod bench_harness;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod estimator;
pub mod expt;
pub mod federation;
pub mod jobs;
pub mod live;
pub mod metrics;
pub mod report;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod util;
pub mod workload;
