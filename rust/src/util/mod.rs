//! Foundation utilities built in-tree (the environment is offline; see
//! DESIGN.md §2): deterministic RNG + distributions, descriptive statistics,
//! ASCII plotting for figure reproduction, and a tiny property-test runner.

pub mod ascii_plot;
pub mod error;
pub mod idmap;
pub mod json;
pub mod propcheck;
pub mod rng;
pub mod slab;
pub mod stats;

/// Simulation time in milliseconds.
pub type Time = u64;

/// Convert milliseconds to fractional seconds (reporting only).
pub fn ms_to_s(ms: Time) -> f64 {
    ms as f64 / 1000.0
}

/// Convert fractional seconds to milliseconds (config ingestion).
pub fn s_to_ms(s: f64) -> Time {
    (s * 1000.0).round() as Time
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ms_s_roundtrip() {
        assert_eq!(s_to_ms(1.5), 1500);
        assert!((ms_to_s(2500) - 2.5).abs() < 1e-12);
        assert_eq!(s_to_ms(ms_to_s(123_456)), 123_456);
    }
}
