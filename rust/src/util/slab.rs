//! Generic slab arena: dense `Vec<T>` storage behind stable `u32` handles
//! with free-list reuse.
//!
//! The calendar queue keeps its fat `Event` payloads here so bucket inserts
//! and resizes move 24-byte `(time, seq, handle)` keys instead of the full
//! entry (see docs/PERFORMANCE.md §"Memory layout & batching").  The slab is
//! deliberately minimal — `alloc` hands out the most recently freed slot
//! (LIFO reuse keeps hot slots cache-resident), `take` reads a slot and
//! frees it in one step.  There is no occupancy tagging: callers own the
//! discipline that a handle is taken at most once per alloc.  Debug builds
//! check double-frees; `tests/properties.rs` model-checks random
//! alloc/take interleavings against a reference map.

#[derive(Debug)]
pub struct Slab<T> {
    slots: Vec<T>,
    free: Vec<u32>,
}

impl<T> Default for Slab<T> {
    fn default() -> Self {
        Slab::new()
    }
}

impl<T> Slab<T> {
    pub fn new() -> Self {
        Slab { slots: Vec::new(), free: Vec::new() }
    }

    /// Store `v`, reusing the most recently freed slot if any.
    pub fn alloc(&mut self, v: T) -> u32 {
        match self.free.pop() {
            Some(h) => {
                self.slots[h as usize] = v;
                h
            }
            None => {
                let h = self.slots.len();
                assert!(h < u32::MAX as usize, "slab handle space exhausted");
                self.slots.push(v);
                h as u32
            }
        }
    }

    /// Live (allocated, not yet taken) entry count.
    pub fn live(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    /// High-water slot count — total slots ever allocated (live + free).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }
}

impl<T: Copy> Slab<T> {
    /// Read the value at `h` and free the slot for reuse.
    pub fn take(&mut self, h: u32) -> T {
        debug_assert!(
            !self.free.contains(&h),
            "double free of slab handle {h}"
        );
        let v = self.slots[h as usize];
        self.free.push(h);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_take_roundtrip() {
        let mut s = Slab::new();
        let a = s.alloc(10u64);
        let b = s.alloc(20);
        assert_eq!(s.live(), 2);
        assert_eq!(s.take(a), 10);
        assert_eq!(s.live(), 1);
        // LIFO reuse: the freed slot is handed back first.
        let c = s.alloc(30);
        assert_eq!(c, a);
        assert_eq!(s.take(b), 20);
        assert_eq!(s.take(c), 30);
        assert_eq!(s.live(), 0);
        assert_eq!(s.capacity(), 2);
    }

    #[test]
    fn capacity_tracks_high_water_not_live() {
        let mut s = Slab::new();
        let hs: Vec<u32> = (0..8u64).map(|i| s.alloc(i)).collect();
        for &h in &hs {
            s.take(h);
        }
        assert_eq!(s.live(), 0);
        assert_eq!(s.capacity(), 8);
        // Churn within the freed pool never grows the slot vector.
        for i in 0..100u64 {
            let h = s.alloc(i);
            assert_eq!(s.take(h), i);
        }
        assert_eq!(s.capacity(), 8);
    }
}
