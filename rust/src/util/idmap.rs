//! Dense id-indexed map: O(1) lookup for the small sequential u32 ids this
//! system uses everywhere (job ids, container ids).  Replaces the
//! `BTreeMap<JobId, _>` on the estimator hot path — iteration stays in
//! ascending-id order, so float accumulation order (and therefore results)
//! is bit-identical to the tree it replaced.

/// A map from `u32` ids to `V`, backed by a dense `Vec<Option<V>>`.
#[derive(Debug, Clone)]
pub struct IdMap<V> {
    slots: Vec<Option<V>>,
    len: usize,
}

// Manual impl: the derived one would demand `V: Default` it never needs.
impl<V> Default for IdMap<V> {
    fn default() -> Self {
        IdMap::new()
    }
}

impl<V> IdMap<V> {
    pub fn new() -> Self {
        IdMap { slots: Vec::new(), len: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn contains(&self, id: u32) -> bool {
        self.get(id).is_some()
    }

    pub fn get(&self, id: u32) -> Option<&V> {
        self.slots.get(id as usize).and_then(|s| s.as_ref())
    }

    pub fn get_mut(&mut self, id: u32) -> Option<&mut V> {
        self.slots.get_mut(id as usize).and_then(|s| s.as_mut())
    }

    /// Insert, returning the previous value if any.
    pub fn insert(&mut self, id: u32, v: V) -> Option<V> {
        let idx = id as usize;
        if idx >= self.slots.len() {
            self.slots.resize_with(idx + 1, || None);
        }
        let prev = self.slots[idx].replace(v);
        if prev.is_none() {
            self.len += 1;
        }
        prev
    }

    /// Get the value for `id`, inserting `make()` first if absent.
    pub fn get_or_insert_with(&mut self, id: u32, make: impl FnOnce() -> V) -> &mut V {
        let idx = id as usize;
        if idx >= self.slots.len() {
            self.slots.resize_with(idx + 1, || None);
        }
        if self.slots[idx].is_none() {
            self.slots[idx] = Some(make());
            self.len += 1;
        }
        self.slots[idx].as_mut().expect("just filled")
    }

    /// Values in ascending-id order.
    pub fn values(&self) -> impl Iterator<Item = &V> {
        self.slots.iter().filter_map(|s| s.as_ref())
    }

    /// Mutable values in ascending-id order.
    pub fn values_mut(&mut self) -> impl Iterator<Item = &mut V> {
        self.slots.iter_mut().filter_map(|s| s.as_mut())
    }

    /// (id, value) pairs in ascending-id order.
    pub fn iter(&self) -> impl Iterator<Item = (u32, &V)> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|v| (i as u32, v)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_len() {
        let mut m: IdMap<&str> = IdMap::new();
        assert!(m.is_empty());
        assert_eq!(m.insert(5, "five"), None);
        assert_eq!(m.insert(1, "one"), None);
        assert_eq!(m.insert(5, "FIVE"), Some("five"));
        assert_eq!(m.len(), 2);
        assert_eq!(m.get(5), Some(&"FIVE"));
        assert_eq!(m.get(2), None);
        assert!(m.contains(1) && !m.contains(0));
    }

    #[test]
    fn iteration_ascending_by_id() {
        let mut m: IdMap<u32> = IdMap::new();
        for id in [9u32, 3, 7, 1] {
            m.insert(id, id * 10);
        }
        let ids: Vec<u32> = m.iter().map(|(i, _)| i).collect();
        assert_eq!(ids, vec![1, 3, 7, 9]);
        let vals: Vec<u32> = m.values().copied().collect();
        assert_eq!(vals, vec![10, 30, 70, 90]);
    }

    #[test]
    fn get_or_insert_with_inserts_once() {
        let mut m: IdMap<Vec<u32>> = IdMap::new();
        m.get_or_insert_with(3, Vec::new).push(1);
        m.get_or_insert_with(3, || panic!("must not rebuild")).push(2);
        assert_eq!(m.get(3), Some(&vec![1, 2]));
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn values_mut_updates() {
        let mut m: IdMap<u32> = IdMap::new();
        m.insert(2, 1);
        m.insert(4, 2);
        for v in m.values_mut() {
            *v += 10;
        }
        assert_eq!(m.get(2), Some(&11));
        assert_eq!(m.get(4), Some(&12));
    }
}
