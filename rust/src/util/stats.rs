//! Descriptive statistics for metrics summaries and the bench harness.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for fewer than two samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (average of middle two for even length); 0.0 for empty.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Linear-interpolated percentile in [0, 100]; 0.0 for empty input.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in percentile input"));
    let rank = (p.clamp(0.0, 100.0) / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Minimum; 0.0 for empty.
pub fn min(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().copied().fold(f64::INFINITY, f64::min)
    }
}

/// Maximum; 0.0 for empty.
pub fn max(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }
}

/// Relative change (b - a) / a as a percentage; 0 when a == 0.
pub fn pct_change(a: f64, b: f64) -> f64 {
    if a == 0.0 {
        0.0
    } else {
        (b - a) / a * 100.0
    }
}

/// Summary bundle used by reports and the bench harness.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p95: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        Summary {
            n: xs.len(),
            mean: mean(xs),
            median: median(xs),
            stddev: stddev(xs),
            min: min(xs),
            max: max(xs),
            p95: percentile(xs, 95.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_inputs_are_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(min(&[]), 0.0);
        assert_eq!(max(&[]), 0.0);
    }

    #[test]
    fn mean_median_simple() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[1.0, 2.0, 9.0]), 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 5.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
        assert_eq!(percentile(&xs, 25.0), 2.5);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [5.0, 1.0, 9.0, 3.0, 7.0];
        assert_eq!(median(&xs), 5.0);
        assert_eq!(percentile(&xs, 100.0), 9.0);
    }

    #[test]
    fn stddev_known_value() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn pct_change_signs() {
        assert_eq!(pct_change(100.0, 50.0), -50.0);
        assert_eq!(pct_change(50.0, 100.0), 100.0);
        assert_eq!(pct_change(0.0, 5.0), 0.0);
    }

    #[test]
    fn summary_bundles() {
        let s = Summary::of(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
    }
}
