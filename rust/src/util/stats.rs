//! Descriptive statistics for metrics summaries, the bench harness, and
//! the sweep layer's claim verification: sample dispersion, Student-t 95%
//! confidence intervals (table-interpolated critical values, zero deps),
//! and paired per-seed deltas.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for fewer than two samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (average of middle two for even length); 0.0 for empty.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Linear-interpolated percentile in [0, 100]; 0.0 for empty input.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in percentile input"));
    let rank = (p.clamp(0.0, 100.0) / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Minimum; 0.0 for empty.
pub fn min(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().copied().fold(f64::INFINITY, f64::min)
    }
}

/// Maximum; 0.0 for empty.
pub fn max(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }
}

/// Sample (n-1 denominator) standard deviation; 0.0 for fewer than two
/// samples.  This is the dispersion estimate confidence intervals need —
/// [`stddev`] above is the population form used by the bench summaries.
pub fn sample_stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Standard error of the mean (sample stddev / sqrt(n)); 0.0 for n < 2.
pub fn stderr(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    sample_stddev(xs) / (xs.len() as f64).sqrt()
}

/// Two-sided 95% Student-t critical value for `df` degrees of freedom.
///
/// Exact table for df 1..=30; linear interpolation in 1/df between the
/// standard anchors (30, 40, 60, 120, ∞) above that — max error vs the
/// true inverse CDF is 3e-4 over df 31..500, far below the precision any
/// claim check needs.  Panics on df == 0 (a CI over one sample has no
/// dispersion estimate; [`Ci95::of`] short-circuits that case).
pub fn t_critical_95(df: usize) -> f64 {
    assert!(df >= 1, "t_critical_95: df must be >= 1");
    const TABLE: [f64; 30] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228, 2.201, 2.179,
        2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086, 2.080, 2.074, 2.069, 2.064,
        2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
    ];
    if df <= TABLE.len() {
        return TABLE[df - 1];
    }
    const ANCHORS: [(f64, f64); 5] =
        [(30.0, 2.042), (40.0, 2.021), (60.0, 2.000), (120.0, 1.980), (f64::INFINITY, 1.960)];
    let x = 1.0 / df as f64;
    for w in ANCHORS.windows(2) {
        let (d0, t0) = w[0];
        let (d1, t1) = w[1];
        let x0 = 1.0 / d0;
        let x1 = if d1.is_finite() { 1.0 / d1 } else { 0.0 };
        if (x1..=x0).contains(&x) {
            return t1 + (x - x1) / (x0 - x1) * (t0 - t1);
        }
    }
    1.960
}

/// Two-sided 95% Student-t confidence interval for a sample mean.
///
/// Degenerate inputs degrade to a zero-width interval at the point
/// estimate: n < 2 has no dispersion estimate, and zero variance yields
/// zero half-width naturally.  A zero-width interval makes CI-bound claim
/// checks equivalent to point-estimate checks, which is the honest
/// fallback for a single seed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Ci95 {
    pub n: usize,
    pub mean: f64,
    /// Half-width `t_{0.975, n-1} * stderr`; 0.0 when n < 2.
    pub half: f64,
}

impl Ci95 {
    pub fn of(xs: &[f64]) -> Ci95 {
        let n = xs.len();
        let half = if n < 2 { 0.0 } else { t_critical_95(n - 1) * stderr(xs) };
        Ci95 { n, mean: mean(xs), half }
    }

    pub fn lo(&self) -> f64 {
        self.mean - self.half
    }

    pub fn hi(&self) -> f64 {
        self.mean + self.half
    }

    pub fn contains(&self, x: f64) -> bool {
        self.lo() <= x && x <= self.hi()
    }
}

/// Per-seed paired deltas `a[i] - b[i]` (e.g. DRESS minus baseline on the
/// identical seed).  Panics on length mismatch — pairing is positional.
pub fn paired_deltas(a: &[f64], b: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), b.len(), "paired_deltas: length mismatch {} vs {}", a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// 95% CI of the mean paired delta `a[i] - b[i]` — the statistic behind
/// "DRESS improves metric M by mean ± CI over seeds".
pub fn paired_ci95(a: &[f64], b: &[f64]) -> Ci95 {
    Ci95::of(&paired_deltas(a, b))
}

/// Relative change (b - a) / a as a percentage; 0 when a == 0.
pub fn pct_change(a: f64, b: f64) -> f64 {
    if a == 0.0 {
        0.0
    } else {
        (b - a) / a * 100.0
    }
}

/// Summary bundle used by reports and the bench harness.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p95: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        Summary {
            n: xs.len(),
            mean: mean(xs),
            median: median(xs),
            stddev: stddev(xs),
            min: min(xs),
            max: max(xs),
            p95: percentile(xs, 95.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_inputs_are_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(min(&[]), 0.0);
        assert_eq!(max(&[]), 0.0);
    }

    #[test]
    fn mean_median_simple() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[1.0, 2.0, 9.0]), 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 5.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
        assert_eq!(percentile(&xs, 25.0), 2.5);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [5.0, 1.0, 9.0, 3.0, 7.0];
        assert_eq!(median(&xs), 5.0);
        assert_eq!(percentile(&xs, 100.0), 9.0);
    }

    #[test]
    fn stddev_known_value() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn pct_change_signs() {
        assert_eq!(pct_change(100.0, 50.0), -50.0);
        assert_eq!(pct_change(50.0, 100.0), 100.0);
        assert_eq!(pct_change(0.0, 5.0), 0.0);
    }

    #[test]
    fn summary_bundles() {
        let s = Summary::of(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
    }

    #[test]
    fn sample_stddev_uses_n_minus_1() {
        // Sum of squared deviations for [2,4,4,4,5,5,7,9] is 32; population
        // variance 4 (tested above), sample variance 32/7.
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((sample_stddev(&xs) - (32.0_f64 / 7.0).sqrt()).abs() < 1e-12);
        assert_eq!(sample_stddev(&[5.0]), 0.0);
        assert_eq!(sample_stddev(&[]), 0.0);
        assert!((stderr(&xs) - (32.0_f64 / 7.0).sqrt() / 8.0_f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn t_table_exact_rows() {
        assert_eq!(t_critical_95(1), 12.706);
        assert_eq!(t_critical_95(2), 4.303);
        assert_eq!(t_critical_95(10), 2.228);
        assert_eq!(t_critical_95(29), 2.045);
        assert_eq!(t_critical_95(30), 2.042);
    }

    #[test]
    fn t_interpolation_is_monotone_and_bounded() {
        let mut prev = t_critical_95(30);
        for df in 31..500 {
            let t = t_critical_95(df);
            assert!(t <= prev + 1e-12, "df {df}: t {t} > prev {prev}");
            assert!((1.960..=2.042).contains(&t), "df {df}: t {t} out of band");
            prev = t;
        }
        // Standard-table anchors are reproduced exactly.
        assert!((t_critical_95(40) - 2.021).abs() < 1e-12);
        assert!((t_critical_95(60) - 2.000).abs() < 1e-12);
        assert!((t_critical_95(120) - 1.980).abs() < 1e-12);
        assert!((t_critical_95(1_000_000) - 1.960).abs() < 1e-3);
    }

    #[test]
    fn ci_width_matches_closed_form_for_consecutive_integers() {
        // For xs = [0, 1, .., n-1] the sample variance is n(n+1)/12, so
        // half = t(n-1) * sqrt((n+1)/12).  Checked for every n in 2..=30.
        for n in 2..=30usize {
            let xs: Vec<f64> = (0..n).map(|i| i as f64).collect();
            let ci = Ci95::of(&xs);
            let expect = t_critical_95(n - 1) * ((n as f64 + 1.0) / 12.0).sqrt();
            assert!(
                (ci.half - expect).abs() < 1e-9,
                "n={n}: half {} != closed form {expect}",
                ci.half
            );
            assert_eq!(ci.n, n);
            assert!((ci.mean - (n as f64 - 1.0) / 2.0).abs() < 1e-12);
            assert!(ci.contains(ci.mean));
        }
    }

    #[test]
    fn ci_known_value_n2() {
        // xs = [0, 2]: mean 1, sample stddev sqrt(2), stderr 1 => half = t(1).
        let ci = Ci95::of(&[0.0, 2.0]);
        assert!((ci.half - 12.706).abs() < 1e-9);
        assert_eq!(ci.mean, 1.0);
        assert!((ci.lo() - (1.0 - 12.706)).abs() < 1e-9);
        assert!((ci.hi() - (1.0 + 12.706)).abs() < 1e-9);
    }

    #[test]
    fn ci_degenerate_inputs_collapse_to_point() {
        // n = 1: no dispersion estimate — zero-width interval at the point.
        let one = Ci95::of(&[7.5]);
        assert_eq!((one.n, one.mean, one.half), (1, 7.5, 0.0));
        assert_eq!(one.lo(), one.hi());
        // Zero variance: zero-width regardless of n.
        let flat = Ci95::of(&[3.0; 12]);
        assert_eq!((flat.mean, flat.half), (3.0, 0.0));
        // Empty: zero everything (matches the other empty-input conventions).
        let empty = Ci95::of(&[]);
        assert_eq!((empty.n, empty.mean, empty.half), (0, 0.0, 0.0));
    }

    #[test]
    fn paired_deltas_and_ci() {
        let dress = [10.0, 12.0, 11.0];
        let base = [14.0, 15.0, 16.0];
        let d = paired_deltas(&dress, &base);
        assert_eq!(d, vec![-4.0, -3.0, -5.0]);
        let ci = paired_ci95(&dress, &base);
        assert_eq!(ci.n, 3);
        assert!((ci.mean + 4.0).abs() < 1e-12);
        // sample stddev of [-4,-3,-5] is 1, stderr 1/sqrt(3), t(2)=4.303.
        assert!((ci.half - 4.303 / 3.0_f64.sqrt()).abs() < 1e-9);
        assert!(ci.hi() < 0.0, "all-negative deltas with small spread stay negative");
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn paired_deltas_reject_mismatch() {
        paired_deltas(&[1.0], &[1.0, 2.0]);
    }
}
