//! Minimal zero-dependency JSON: parse + render, enough for the bench
//! trajectory files (`BENCH_engine.json`).
//!
//! Two producers write sections of the same file (`perf_throughput` owns
//! the top-level run list, `perf_sweep` owns the `sweep` section), so each
//! must read-modify-write instead of clobbering the other; and the test
//! suite schema-checks the checked-in file.  Objects preserve insertion
//! order so rendering is deterministic.  Numbers are f64 (fine below
//! 2^53 — event counts at 10^6+ events are far inside that).

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object as an ordered key → value list (insertion order preserved).
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ---------------------------------------------------------- accessors

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Insert or replace `key` in an object (panics on non-objects).
    pub fn set(&mut self, key: &str, value: Json) {
        match self {
            Json::Obj(kv) => {
                if let Some(slot) = kv.iter_mut().find(|(k, _)| k == key) {
                    slot.1 = value;
                } else {
                    kv.push((key.to_string(), value));
                }
            }
            other => panic!("Json::set on non-object {other:?}"),
        }
    }

    /// Remove `key` from an object if present (no-op otherwise).
    pub fn remove(&mut self, key: &str) {
        if let Json::Obj(kv) = self {
            kv.retain(|(k, _)| k != key);
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    // ------------------------------------------------------------ parsing

    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    // ---------------------------------------------------------- rendering

    /// Render with 2-space indentation and a trailing newline.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => out.push_str(&render_num(*n)),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    item.write(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Obj(kv) => {
                if kv.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
        }
    }
}

fn push_indent(out: &mut String, n: usize) {
    for _ in 0..n {
        out.push_str("  ");
    }
}

fn render_num(n: f64) -> String {
    if !n.is_finite() {
        return "null".to_string(); // JSON has no NaN/inf
    }
    if n.fract() == 0.0 && n.abs() < 9e15 {
        format!("{}", n as i64)
    } else {
        format!("{n}")
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected `{}` at byte {}", c as char, self.i))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            None => Err("unexpected end of input".into()),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected `{}` at byte {}", c as char, self.i)),
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected `,` or `]` at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected `,` or `}}` at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| format!("bad \\u escape `{hex}`"))?;
                            self.i += 4;
                            // Surrogate pairs unsupported (bench files are
                            // ASCII); map lone surrogates to U+FFFD.
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => return Err(format!("bad escape `\\{}`", other as char)),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8 in string")?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number `{text}` at byte {start}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrips_scalars() {
        for (text, v) in [
            ("null", Json::Null),
            ("true", Json::Bool(true)),
            ("false", Json::Bool(false)),
            ("42", Json::Num(42.0)),
            ("-3.5", Json::Num(-3.5)),
            ("\"hi\"", Json::Str("hi".into())),
        ] {
            assert_eq!(Json::parse(text).unwrap(), v, "{text}");
        }
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny", "d": 1e3}"#).unwrap();
        assert_eq!(v.get("d").unwrap().as_f64(), Some(1000.0));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert!(arr[2].get("b").unwrap().is_null());
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn render_parse_roundtrip_preserves_order() {
        let mut o = Json::obj();
        o.set("z", Json::Num(1.0));
        o.set("a", Json::Arr(vec![Json::Bool(true), Json::Str("s\"q".into())]));
        o.set("z", Json::Num(2.0)); // replace, stays first
        let text = o.render();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, o);
        assert!(text.find("\"z\"").unwrap() < text.find("\"a\"").unwrap());
    }

    #[test]
    fn set_and_remove_edit_objects_in_place() {
        let mut o = Json::parse(r#"{"a": 1, "status": "pending", "b": 2}"#).unwrap();
        o.remove("status");
        o.remove("missing"); // no-op
        o.set("a", Json::Num(9.0));
        assert_eq!(o.get("status"), None);
        assert_eq!(o.get("a").unwrap().as_f64(), Some(9.0));
        assert_eq!(o.get("b").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn integers_render_without_decimal_point() {
        assert_eq!(render_num(1234567.0), "1234567");
        assert_eq!(render_num(1.5), "1.5");
        assert_eq!(render_num(f64::NAN), "null");
    }

    #[test]
    fn parses_checked_in_bench_schema_shape() {
        let text = r#"{
  "bench": "perf_throughput",
  "status": "pending",
  "speedup_indexed_vs_naive_1k": null,
  "runs": [
    {"jobs": 1000, "events": null}
  ]
}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("bench").unwrap().as_str(), Some("perf_throughput"));
        assert!(v.get("speedup_indexed_vs_naive_1k").unwrap().is_null());
        assert_eq!(v.get("runs").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1 2]").is_err());
        assert!(Json::parse("\"open").is_err());
        assert!(Json::parse("12 34").is_err());
    }
}
