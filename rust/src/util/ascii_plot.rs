//! Terminal rendering for the paper's figures: grouped bar charts (Figs
//! 6-13) and per-task Gantt-ish traces (Figs 2-4).  Pure text, so figure
//! reproduction works in CI logs and EXPERIMENTS.md.

/// Render a horizontal grouped bar chart. `series` are (label, values);
/// all series must share `cats.len()` values. Values are scaled to `width`.
pub fn grouped_bars(title: &str, cats: &[String], series: &[(&str, Vec<f64>)], width: usize) -> String {
    let mut out = String::new();
    out.push_str(&format!("── {title}\n"));
    let max = series
        .iter()
        .flat_map(|(_, v)| v.iter().copied())
        .fold(0.0_f64, f64::max)
        .max(1e-9);
    let cat_w = cats.iter().map(|c| c.len()).max().unwrap_or(0).max(4);
    for (i, cat) in cats.iter().enumerate() {
        for (si, (name, vals)) in series.iter().enumerate() {
            let v = vals.get(i).copied().unwrap_or(0.0);
            let n = ((v / max) * width as f64).round() as usize;
            let glyph = ["█", "░", "▒", "▓"][si % 4];
            let label = if si == 0 { cat.clone() } else { String::new() };
            out.push_str(&format!(
                "{label:>cat_w$} {glyph_bar:<width$} {v:>9.1} {name}\n",
                glyph_bar = glyph.repeat(n),
            ));
        }
    }
    out
}

/// Render a task trace (one line per task): `rows` are (task_label, start,
/// duration) in seconds; the timeline is scaled to `width` columns.
pub fn task_trace(title: &str, rows: &[(String, f64, f64)], width: usize) -> String {
    let mut out = String::new();
    out.push_str(&format!("── {title}\n"));
    let end = rows
        .iter()
        .map(|(_, s, d)| s + d)
        .fold(0.0_f64, f64::max)
        .max(1e-9);
    let lab_w = rows.iter().map(|(l, _, _)| l.len()).max().unwrap_or(0).max(4);
    for (label, start, dur) in rows {
        let pre = ((start / end) * width as f64).round() as usize;
        let len = (((dur / end) * width as f64).round() as usize).max(1);
        out.push_str(&format!(
            "{label:>lab_w$} |{}{} {start:>7.2}s +{dur:.2}s\n",
            " ".repeat(pre.min(width)),
            "▇".repeat(len.min(width.saturating_sub(pre))),
        ));
    }
    out.push_str(&format!("{:>lab_w$} 0s {:>w$.1}s\n", "", end, w = width));
    out
}

/// A simple sparkline for utilization curves.
pub fn sparkline(values: &[f64]) -> String {
    const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let max = values.iter().copied().fold(0.0_f64, f64::max).max(1e-9);
    values
        .iter()
        .map(|v| GLYPHS[(((v / max) * 7.0).round() as usize).min(7)])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bars_include_labels_and_values() {
        let cats = vec!["J1".to_string(), "J2".to_string()];
        let s = grouped_bars(
            "fig",
            &cats,
            &[("DRESS", vec![10.0, 20.0]), ("Capacity", vec![15.0, 5.0])],
            20,
        );
        assert!(s.contains("J1") && s.contains("J2"));
        assert!(s.contains("DRESS") && s.contains("Capacity"));
        assert!(s.contains("20.0"));
    }

    #[test]
    fn bars_handle_empty_and_zero() {
        let s = grouped_bars("empty", &[], &[], 10);
        assert!(s.contains("empty"));
        let cats = vec!["a".to_string()];
        let s = grouped_bars("z", &cats, &[("x", vec![0.0])], 10);
        assert!(s.contains("0.0"));
    }

    #[test]
    fn trace_scales_to_width() {
        let rows = vec![
            ("t0".to_string(), 0.0, 5.0),
            ("t1".to_string(), 5.0, 5.0),
        ];
        let s = task_trace("trace", &rows, 40);
        assert!(s.lines().count() >= 3);
        assert!(s.contains("t0") && s.contains("t1"));
    }

    #[test]
    fn sparkline_monotone() {
        let s = sparkline(&[0.0, 0.5, 1.0]);
        assert_eq!(s.chars().count(), 3);
        assert!(s.ends_with('█'));
    }
}
