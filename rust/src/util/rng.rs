//! Deterministic pseudo-random number generation and the distributions the
//! simulator needs (uniform, log-normal-ish latency jitter, Zipf skew).
//!
//! SplitMix64 core: tiny, fast, passes BigCrush for our purposes, and —
//! critically for reproduction — every experiment is seeded, so any figure
//! in EXPERIMENTS.md can be regenerated bit-for-bit.

/// SplitMix64 PRNG (Steele, Lea, Flood 2014).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. Two generators with the same seed
    /// produce identical streams.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Derive an independent substream (for per-job / per-node generators).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0xBF58_476D_1CE4_E5B9))
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi] (inclusive). Panics if lo > hi.
    ///
    /// Debiased modulo (OpenBSD `arc4random_uniform` style): a plain
    /// `next_u64() % span` over-weights the first `2^64 mod span` residues
    /// — invisible for small spans, but a span of `3·2^62` maps half of
    /// all draws onto the bottom third of the range.  Rejecting the draws
    /// below `2^64 mod span` leaves exactly `floor(2^64 / span)` raw
    /// values per residue.  Rejection-modulo is deliberately used instead
    /// of Lemire multiply-shift: for every accepted draw the returned
    /// value equals the old `lo + x % span`, so existing seeded workload
    /// streams are unchanged except with probability `< span / 2^64` per
    /// draw (≈ 0 for the small spans the generators use).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "range_u64: lo {lo} > hi {hi}");
        let span = hi.wrapping_sub(lo).wrapping_add(1);
        if span == 0 {
            // Full 2^64 range: every raw value is already uniform.
            return self.next_u64();
        }
        // span.wrapping_neg() == 2^64 - span ≡ 2^64 (mod span).
        let reject_below = span.wrapping_neg() % span;
        loop {
            let x = self.next_u64();
            if x >= reject_below {
                return lo + x % span;
            }
        }
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Uniform choice of an index < n. Panics if n == 0.
    /// Debiased the same way as [`Self::range_u64`].
    pub fn index(&mut self, n: usize) -> usize {
        assert!(n > 0, "index: empty domain");
        self.range_u64(0, n as u64 - 1) as usize
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Log-normal sample with the given *linear-space* median and sigma
    /// (multiplicative spread). Used for container state-transition delays
    /// and task-duration jitter — long-tailed like real YARN latencies.
    pub fn lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        median * (sigma * self.normal()).exp()
    }

    /// Zipf-distributed rank in [1, n] with exponent `s`.  One-shot
    /// convenience around [`ZipfSampler`]; callers drawing many ranks from
    /// the same `(n, s)` (workload generators, partition skew) should hoist
    /// a sampler instead of paying the O(n) weight-table build per draw.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        ZipfSampler::new(n, s).draw(self)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Zipf inverse-CDF sampler with the per-rank weight table and its
/// normalization precomputed once.  The seed implementation recomputed the
/// O(n) `Σ 1/k^s` normalization (n `powf` calls) on *every* draw; building
/// the table up front makes a draw O(expected rank) with no `powf` at all.
///
/// The draw performs the exact float operations of the original inline
/// scan (`u = next_f64()·norm`, then sequential subtraction of the same
/// `1/k^s` values), so for a fixed seed the rank stream is bit-identical
/// to the pre-sampler code — asserted by `zipf_sampler_stream_matches_reference`.
#[derive(Debug, Clone)]
pub struct ZipfSampler {
    /// `weights[k-1] = 1 / k^s` for ranks 1..=n.
    weights: Vec<f64>,
    /// `Σ weights`, summed in rank order (same order as the seed code).
    norm: f64,
}

impl ZipfSampler {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0, "zipf: empty domain");
        let weights: Vec<f64> = (1..=n).map(|k| 1.0 / (k as f64).powf(s)).collect();
        let norm = weights.iter().sum();
        ZipfSampler { weights, norm }
    }

    /// Number of ranks.
    pub fn len(&self) -> usize {
        self.weights.len()
    }

    pub fn is_empty(&self) -> bool {
        false // new() rejects n == 0
    }

    /// Draw a rank in [1, n]; consumes exactly one `next_f64`.
    pub fn draw(&self, rng: &mut Rng) -> usize {
        let mut u = rng.next_f64() * self.norm;
        for (i, w) in self.weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i + 1;
            }
        }
        self.weights.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let m: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn range_respects_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.range_u64(10, 20);
            assert!((10..=20).contains(&v));
            let f = r.range_f64(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&f));
        }
    }

    #[test]
    fn range_u64_large_span_is_unbiased() {
        // Bias-sensitive test: span = 3·2^62.  The old `next_u64() % span`
        // mapped every raw value in [0, 2^62) twice, so P(v < 2^62) was
        // 1/2 instead of the uniform 1/3 — a 50% relative error that no
        // tolerance could excuse.  30k draws put the sample σ at ~0.0027,
        // so the 0.02 band is a >7σ test of the fix while still being
        // deterministic for the fixed seed.
        let span = 3u64 << 62;
        let mut r = Rng::new(0xB1A5);
        let n = 30_000;
        let below = (0..n)
            .filter(|_| r.range_u64(0, span - 1) < (1u64 << 62))
            .count();
        let frac = below as f64 / n as f64;
        assert!(
            (frac - 1.0 / 3.0).abs() < 0.02,
            "biased large-span draw: frac {frac} (modulo bias gives 0.5)"
        );
    }

    #[test]
    fn range_u64_full_domain_does_not_panic() {
        // lo = 0, hi = u64::MAX wraps span to 0; the old code computed
        // `% 0` here.  The full domain needs no debiasing at all.
        let mut r = Rng::new(17);
        let mut distinct = std::collections::HashSet::new();
        for _ in 0..64 {
            distinct.insert(r.range_u64(0, u64::MAX));
        }
        assert!(distinct.len() > 60, "full-domain draws suspiciously collided");
    }

    #[test]
    fn small_span_stream_unchanged_by_debiasing() {
        // For spans ≪ 2^64 the rejection zone is never hit in practice, so
        // the debiased draw must return exactly `lo + next_u64() % span` —
        // the property that keeps every seeded workload bit-stable.
        let mut a = Rng::new(99);
        let mut b = Rng::new(99);
        for _ in 0..2_000 {
            let raw = b.next_u64();
            assert_eq!(a.range_u64(5, 35), 5 + raw % 31);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            let k = r.zipf(10, 1.2);
            assert!((1..=10).contains(&k));
            counts[k - 1] += 1;
        }
        assert!(counts[0] > counts[4] && counts[4] > counts[9]);
    }

    /// The pre-sampler inline implementation, kept verbatim as the
    /// reference: normalization recomputed per draw, subtraction scan over
    /// freshly computed `1/k^s` terms.
    fn zipf_reference(rng: &mut Rng, n: usize, s: f64) -> usize {
        let norm: f64 = (1..=n).map(|k| 1.0 / (k as f64).powf(s)).sum();
        let mut u = rng.next_f64() * norm;
        for k in 1..=n {
            u -= 1.0 / (k as f64).powf(s);
            if u <= 0.0 {
                return k;
            }
        }
        n
    }

    #[test]
    fn zipf_sampler_stream_matches_reference() {
        // The precomputed-table sampler must reproduce the seed
        // implementation's rank stream bit-for-bit — same float values
        // subtracted in the same order — so fixed-seed workloads
        // (congested_burst demands, partition skew) are unchanged.
        for (n, s, seed) in [(30, 1.1, 42u64), (10, 1.2, 5), (64, 1.6, 0xFEED), (1, 0.7, 9)] {
            let sampler = ZipfSampler::new(n, s);
            assert_eq!(sampler.len(), n);
            let mut a = Rng::new(seed);
            let mut b = Rng::new(seed);
            for i in 0..2_000 {
                let fast = sampler.draw(&mut a);
                let refr = zipf_reference(&mut b, n, s);
                assert_eq!(fast, refr, "draw {i} diverged for n={n} s={s} seed={seed}");
            }
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(1234);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
