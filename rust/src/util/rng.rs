//! Deterministic pseudo-random number generation and the distributions the
//! simulator needs (uniform, log-normal-ish latency jitter, Zipf skew).
//!
//! SplitMix64 core: tiny, fast, passes BigCrush for our purposes, and —
//! critically for reproduction — every experiment is seeded, so any figure
//! in EXPERIMENTS.md can be regenerated bit-for-bit.

/// SplitMix64 PRNG (Steele, Lea, Flood 2014).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. Two generators with the same seed
    /// produce identical streams.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Derive an independent substream (for per-job / per-node generators).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0xBF58_476D_1CE4_E5B9))
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi] (inclusive). Panics if lo > hi.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "range_u64: lo {lo} > hi {hi}");
        let span = hi - lo + 1;
        lo + self.next_u64() % span
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Uniform choice of an index < n. Panics if n == 0.
    pub fn index(&mut self, n: usize) -> usize {
        assert!(n > 0, "index: empty domain");
        (self.next_u64() % n as u64) as usize
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Log-normal sample with the given *linear-space* median and sigma
    /// (multiplicative spread). Used for container state-transition delays
    /// and task-duration jitter — long-tailed like real YARN latencies.
    pub fn lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        median * (sigma * self.normal()).exp()
    }

    /// Zipf-distributed rank in [1, n] with exponent `s` (inverse-CDF via
    /// linear scan over precomputable weights; n is small in our use).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        assert!(n > 0);
        let norm: f64 = (1..=n).map(|k| 1.0 / (k as f64).powf(s)).sum();
        let mut u = self.next_f64() * norm;
        for k in 1..=n {
            u -= 1.0 / (k as f64).powf(s);
            if u <= 0.0 {
                return k;
            }
        }
        n
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let m: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn range_respects_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.range_u64(10, 20);
            assert!((10..=20).contains(&v));
            let f = r.range_f64(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&f));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            let k = r.zipf(10, 1.2);
            assert!((1..=10).contains(&k));
            counts[k - 1] += 1;
        }
        assert!(counts[0] > counts[4] && counts[4] > counts[9]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(1234);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
