//! Minimal error plumbing (offline stand-in for `anyhow`; see DESIGN.md §2
//! for the no-external-deps rule).  Provides the small surface the runtime
//! and live layers need: a string-backed [`Error`], a [`Result`] alias, the
//! [`Context`] extension trait, and `bail!` / `format_err!` macros.

use std::fmt;

/// A string-backed error with an optional context chain.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer (rendered `context: cause`).
    pub fn context(self, c: impl fmt::Display) -> Self {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error { msg: s }
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error { msg: s.to_string() }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error { msg: e.to_string() }
    }
}

/// Crate-wide result alias (mirror of `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context attachment for `Result` and `Option` (mirror of
/// `anyhow::Context`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Early-return with a formatted [`Error`] (mirror of `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

/// Build an [`Error`] from format args (mirror of `anyhow::anyhow!`).
#[macro_export]
macro_rules! format_err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        bail!("broke at {}", 7)
    }

    #[test]
    fn bail_formats() {
        assert_eq!(fails().unwrap_err().to_string(), "broke at 7");
    }

    #[test]
    fn context_chains() {
        let e: Result<()> = Err(Error::msg("inner"));
        let e = e.context("outer");
        assert_eq!(e.unwrap_err().to_string(), "outer: inner");
        let n: Option<u32> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
        let o: Option<u32> = Some(3);
        assert_eq!(o.with_context(|| "unused").unwrap(), 3);
    }

    #[test]
    fn io_error_converts() {
        fn read() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/path")?)
        }
        assert!(read().is_err());
    }
}
