//! Minimal property-based testing runner (offline stand-in for `proptest`).
//!
//! Usage pattern (in `#[cfg(test)]` or `rust/tests/`):
//!
//! ```ignore
//! propcheck::forall("delta stays in (0,1)", 200, |rng| gen_world(rng), |w| {
//!     check(w).map_err(|e| format!("{e}"))
//! });
//! ```
//!
//! On failure the runner re-reports the failing case index and the seed so
//! the exact input can be regenerated deterministically.

use super::rng::Rng;

/// Run `cases` random trials of `prop` over inputs drawn by `gen`.
/// Panics (test failure) on the first counterexample, printing the base
/// seed, the case index, and the property's error message.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    forall_seeded(name, 0xD12E55, cases, &mut gen, &mut prop);
}

/// Seeded variant, for reproducing a failure printed by [`forall`].
pub fn forall_seeded<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    gen: &mut impl FnMut(&mut Rng) -> T,
    prop: &mut impl FnMut(&T) -> Result<(), String>,
) {
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let mut case_rng = root.fork(case as u64);
        let input = gen(&mut case_rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("u64 parity total", 50, |r| r.next_u64(), |_| {
            Ok(())
        });
        forall("count side effect", 10, |r| r.next_u64() % 7, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_context() {
        forall("always fails", 5, |r| r.next_u64(), |_| Err("nope".into()));
    }

    #[test]
    fn same_seed_same_inputs() {
        let mut first: Vec<u64> = Vec::new();
        forall_seeded("collect", 99, 20, &mut |r| r.next_u64(), &mut |x| {
            first.push(*x);
            Ok(())
        });
        let mut second: Vec<u64> = Vec::new();
        forall_seeded("collect2", 99, 20, &mut |r| r.next_u64(), &mut |x| {
            second.push(*x);
            Ok(())
        });
        assert_eq!(first, second);
    }
}
