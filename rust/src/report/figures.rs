//! Per-figure renderers: turn [`RunResult`]s into the paper's plots, plus
//! the sweep layer's confidence-interval whisker chart.

use crate::metrics::{JobMetrics, UtilSummary};
use crate::sim::{RunResult, TaskTrace};
use crate::util::ascii_plot;
use crate::util::stats::Ci95;
use crate::util::Time;

fn job_labels(jobs: &[JobMetrics]) -> Vec<String> {
    jobs.iter().map(|j| format!("J{}", j.id)).collect()
}

/// Figs 6 / 8: per-job waiting times, DRESS vs baseline.
pub fn fig_waiting_bars(title: &str, dress: &RunResult, baseline: &RunResult) -> String {
    let cats = job_labels(&dress.jobs);
    let d: Vec<f64> = dress.jobs.iter().map(|j| j.waiting_ms as f64 / 1000.0).collect();
    let b: Vec<f64> = baseline.jobs.iter().map(|j| j.waiting_ms as f64 / 1000.0).collect();
    ascii_plot::grouped_bars(title, &cats, &[("DRESS", d), ("Capacity", b)], 46)
}

/// Figs 7 / 9: per-job completion times.
pub fn fig_completion_bars(title: &str, dress: &RunResult, baseline: &RunResult) -> String {
    let cats = job_labels(&dress.jobs);
    let d: Vec<f64> = dress.jobs.iter().map(|j| j.completion_ms as f64 / 1000.0).collect();
    let b: Vec<f64> = baseline.jobs.iter().map(|j| j.completion_ms as f64 / 1000.0).collect();
    ascii_plot::grouped_bars(title, &cats, &[("DRESS", d), ("Capacity", b)], 46)
}

/// Figs 10-13: stacked wait+exec per job (two bars per job id).
pub fn fig_stacked_bars(title: &str, dress: &RunResult, baseline: &RunResult) -> String {
    let mut out = format!("── {title}\n");
    out.push_str("    (per job: waiting ░ + execution █; left bar DRESS, right bar Capacity)\n");
    let max_c = dress
        .jobs
        .iter()
        .chain(&baseline.jobs)
        .map(|j| j.completion_ms)
        .max()
        .unwrap_or(1)
        .max(1) as f64;
    let width = 44.0;
    for (d, b) in dress.jobs.iter().zip(&baseline.jobs) {
        for (tag, j) in [("D", d), ("C", b)] {
            let wait = (j.waiting_ms as f64 / max_c * width).round() as usize;
            let exec = (j.execution_ms as f64 / max_c * width).round() as usize;
            out.push_str(&format!(
                "J{:<3}{tag} {}{} {:>7.1}s (w {:.1}s)\n",
                j.id,
                "░".repeat(wait),
                "█".repeat(exec.max(1)),
                j.completion_ms as f64 / 1000.0,
                j.waiting_ms as f64 / 1000.0,
            ));
        }
    }
    out
}

/// Sweep aggregates: one whisker lane per labeled statistic — the 95% CI
/// span (`─`), the mean (`*`), and the zero axis (`|`, `+` when inside
/// the span).  All lanes share one scale that always includes zero, so
/// "does the interval cross zero" is readable at a glance.
pub fn fig_ci_bars(title: &str, rows: &[(String, Ci95)], width: usize) -> String {
    let mut out = format!("── {title}\n");
    if rows.is_empty() {
        return out;
    }
    let mut lo = 0.0_f64;
    let mut hi = 0.0_f64;
    for (_, ci) in rows {
        lo = lo.min(ci.lo());
        hi = hi.max(ci.hi());
    }
    if hi - lo < 1e-9 {
        hi = lo + 1.0;
    }
    let w = width.max(10);
    let label_w = rows.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    let col = |x: f64| (((x - lo) / (hi - lo)) * (w - 1) as f64).round() as usize;
    for (label, ci) in rows {
        let mut lane = vec![' '; w];
        let (a, b) = (col(ci.lo()).min(w - 1), col(ci.hi()).min(w - 1));
        for c in lane.iter_mut().take(b + 1).skip(a) {
            *c = '─';
        }
        let zero = col(0.0).min(w - 1);
        lane[zero] = if lane[zero] == '─' { '+' } else { '|' };
        lane[col(ci.mean).min(w - 1)] = '*';
        let lane: String = lane.into_iter().collect();
        out.push_str(&format!(
            "{label:<label_w$} {lane}  {:.1} ± {:.1} (n={})\n",
            ci.mean, ci.half, ci.n
        ));
    }
    out
}

/// Cluster utilization over time: sparkline of the retained per-tick
/// samples plus the exact summary line.  Under `Ring`/`Decimate` metric
/// retention the sparkline shows the downsampled stream while the summary
/// numbers stay exact (they come from the online accumulator); under
/// `Full` both views describe the complete stream; under `Counting` only
/// the summary line renders.
pub fn fig_utilization(title: &str, samples: &[(Time, u32)], util: &UtilSummary) -> String {
    let mut out = format!("── {title}\n");
    if !samples.is_empty() {
        let fracs: Vec<f64> = samples
            .iter()
            .map(|&(_, used)| used as f64 / util.total.max(1) as f64)
            .collect();
        out.push_str(&format!(
            "    {}  ({} of {} samples retained)\n",
            ascii_plot::sparkline(&fracs),
            samples.len(),
            util.samples,
        ));
    }
    out.push_str(&format!(
        "    time-weighted mean {:.1}% | peak {}/{} containers | span {:.1}s ({} ticks)\n",
        100.0 * util.mean_utilization(),
        util.peak_used,
        util.total,
        util.span_ms as f64 / 1000.0,
        util.samples,
    ));
    out
}

/// Figs 2-4: per-task trace of one job.
pub fn fig_trace(title: &str, tasks: &[TaskTrace]) -> String {
    let rows: Vec<(String, f64, f64)> = tasks
        .iter()
        .map(|t| {
            (
                format!("p{}-t{}", t.phase, t.task),
                t.start as f64 / 1000.0,
                t.duration() as f64 / 1000.0,
            )
        })
        .collect();
    ascii_plot::task_trace(title, &rows, 56)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::SystemMetrics;
    use crate::sim::TraceRecorder;

    fn run(waits: &[u64], comps: &[u64]) -> RunResult {
        let jobs: Vec<JobMetrics> = waits
            .iter()
            .zip(comps)
            .enumerate()
            .map(|(i, (&w, &c))| JobMetrics {
                id: i as u32 + 1,
                demand: 4,
                submit_ms: 0,
                waiting_ms: w,
                completion_ms: c,
                execution_ms: c - w,
            })
            .collect();
        let system = SystemMetrics::of(&jobs, &UtilSummary::from_samples(&[], 10));
        RunResult {
            scheduler: "x".into(),
            jobs,
            system,
            trace: TraceRecorder::new(),
            delta_history: vec![],
            util_history: vec![],
            util: UtilSummary::from_samples(&[], 10),
            delta: Default::default(),
            util_recorded: 0,
            delta_recorded: 0,
            failures: 0,
            lost_attempts: 0,
            lost_work_ms: 0,
            useful_work_ms: 0,
            wasted_work_ms: 0,
            attempts: 0,
            outages: vec![],
            events: 0,
            sched_ticks: 0,
            tasks_recorded: 0,
            transitions_recorded: 0,
            retained_transitions: 0,
            cells: 1,
            migrations: 0,
            routing: vec![],
            imbalance_max: 0.0,
            imbalance_mean: 0.0,
            cell_outages: vec![],
        }
    }

    #[test]
    fn waiting_bars_render_both_series() {
        let d = run(&[1_000, 2_000], &[5_000, 9_000]);
        let c = run(&[3_000, 4_000], &[6_000, 8_000]);
        let s = fig_waiting_bars("Fig 6", &d, &c);
        assert!(s.contains("DRESS") && s.contains("Capacity"));
        assert!(s.contains("J1") && s.contains("J2"));
    }

    #[test]
    fn stacked_bars_contain_all_jobs() {
        let d = run(&[1_000], &[5_000]);
        let c = run(&[2_000], &[6_000]);
        let s = fig_stacked_bars("Fig 10", &d, &c);
        assert!(s.contains("J1  D") && s.contains("J1  C"));
    }

    #[test]
    fn ci_bars_render_span_mean_and_zero_axis() {
        let rows = vec![
            ("FIG7".to_string(), Ci95 { n: 4, mean: -20.0, half: 5.0 }),
            ("TAB2".to_string(), Ci95 { n: 4, mean: 1.0, half: 3.0 }),
        ];
        let s = fig_ci_bars("claim CIs", &rows, 40);
        assert!(s.contains("FIG7") && s.contains("TAB2"));
        assert!(s.contains('*') && s.contains('─'));
        // TAB2's interval crosses zero, so its lane marks the axis inside
        // the span; FIG7's lane keeps the bare axis marker.
        assert!(s.contains('+') && s.contains('|'), "zero axis rendered:\n{s}");
        assert!(s.contains("-20.0 ± 5.0 (n=4)"));
        // Degenerate interval still renders (single-point span).
        let s = fig_ci_bars("flat", &[("x".into(), Ci95 { n: 1, mean: 0.0, half: 0.0 })], 40);
        assert!(s.contains('*'));
    }

    #[test]
    fn utilization_figure_renders_sparkline_and_exact_summary() {
        let samples = [(0u64, 2u32), (1_000, 8), (2_000, 10), (3_000, 4)];
        let util = UtilSummary::from_samples(&samples, 10);
        let s = fig_utilization("utilization", &samples, &util);
        assert!(s.contains("4 of 4 samples retained"));
        assert!(s.contains("peak 10/10"));
        // area = 2·1000 + 8·1000 + 10·1000 = 20000; span 3000 → 66.7%.
        assert!(s.contains("66.7%"), "summary line:\n{s}");
        // Counting retention: no retained samples — summary line only.
        let empty = fig_utilization("utilization", &[], &util);
        assert!(!empty.contains("retained") && empty.contains("66.7%"));
    }

    #[test]
    fn trace_renders_tasks() {
        let tasks = vec![
            TaskTrace { job: 1, phase: 0, task: 0, granted: 0, start: 1_000, finish: 5_000 },
            TaskTrace { job: 1, phase: 1, task: 0, granted: 0, start: 6_000, finish: 8_000 },
        ];
        let s = fig_trace("Fig 2", &tasks);
        assert!(s.contains("p0-t0") && s.contains("p1-t0"));
    }
}
