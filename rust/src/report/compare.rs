//! Paper-vs-measured comparison rows: each experiment declares the paper's
//! claim (a qualitative *shape*: who wins, by roughly what factor) and the
//! harness prints both side by side for EXPERIMENTS.md.

/// One claim from the paper, checked against a measured value.
#[derive(Debug, Clone)]
pub struct PaperClaim {
    /// e.g. "FIG7.small-completion-reduction".
    pub id: String,
    pub description: String,
    /// The paper's number (percent or seconds, see description).
    pub paper: f64,
    /// The direction that must hold for the shape to reproduce:
    /// -1 => measured should be negative/below zero (a reduction),
    /// +1 => positive, 0 => "close to paper value" (|measured-paper| small),
    ///  2 => measured should be <= the paper value (not worse than),
    ///  3 => stability: |measured| small in absolute terms (<= 10).
    pub direction: i8,
}

/// Render one comparison row and evaluate whether the shape holds.
pub fn comparison_row(claim: &PaperClaim, measured: f64) -> (String, bool) {
    let holds = match claim.direction {
        -1 => measured < 0.0,
        1 => measured > 0.0,
        2 => measured <= claim.paper * 1.05,
        3 => measured.abs() <= 10.0,
        _ => {
            let denom = claim.paper.abs().max(1e-9);
            (measured - claim.paper).abs() / denom < 0.35
        }
    };
    let marker = if holds { "OK " } else { "MISS" };
    (
        format!(
            "[{marker}] {:<44} paper {:>9.1}  measured {:>9.1}",
            claim.id, claim.paper, measured
        ),
        holds,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn claim(direction: i8, paper: f64) -> PaperClaim {
        PaperClaim {
            id: "TEST.x".into(),
            description: "test".into(),
            paper,
            direction,
        }
    }

    #[test]
    fn reduction_claims_need_negative_measured() {
        let (row, ok) = comparison_row(&claim(-1, -76.1), -40.0);
        assert!(ok && row.contains("OK"));
        let (_, bad) = comparison_row(&claim(-1, -76.1), 5.0);
        assert!(!bad);
    }

    #[test]
    fn closeness_claims_use_relative_band() {
        let (_, ok) = comparison_row(&claim(0, 100.0), 110.0);
        assert!(ok);
        let (_, bad) = comparison_row(&claim(0, 100.0), 200.0);
        assert!(!bad);
    }

    #[test]
    fn positive_claims() {
        let (_, ok) = comparison_row(&claim(1, 10.0), 0.5);
        assert!(ok);
        let (_, bad) = comparison_row(&claim(1, 10.0), -0.5);
        assert!(!bad);
    }
}
