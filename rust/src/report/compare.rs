//! Paper-vs-measured comparison rows: each experiment declares the paper's
//! claim (a qualitative *shape*: who wins, by roughly what factor) and the
//! harness prints both side by side for EXPERIMENTS.md.
//!
//! Single-run checks use [`comparison_row`] (point estimate); multi-seed
//! sweeps use [`comparison_row_ci`], which judges the claim on the 95%
//! confidence bound — the *whole interval* must satisfy the shape, so one
//! lucky seed can no longer carry a claim.

use crate::util::stats::Ci95;

/// One claim from the paper, checked against a measured value.
#[derive(Debug, Clone)]
pub struct PaperClaim {
    /// e.g. "FIG7.small-completion-reduction".
    pub id: String,
    pub description: String,
    /// The paper's number (percent or seconds, see description).
    pub paper: f64,
    /// The direction that must hold for the shape to reproduce:
    /// -1 => measured should be negative/below zero (a reduction),
    /// +1 => positive, 0 => "close to paper value" (|measured-paper| small),
    ///  2 => measured should be <= the paper value (not worse than),
    ///  3 => stability: |measured| small in absolute terms (<= 10).
    pub direction: i8,
}

/// Render one comparison row and evaluate whether the shape holds.
pub fn comparison_row(claim: &PaperClaim, measured: f64) -> (String, bool) {
    let holds = match claim.direction {
        -1 => measured < 0.0,
        1 => measured > 0.0,
        2 => measured <= claim.paper * 1.05,
        3 => measured.abs() <= 10.0,
        _ => {
            let denom = claim.paper.abs().max(1e-9);
            (measured - claim.paper).abs() / denom < 0.35
        }
    };
    let marker = if holds { "OK " } else { "MISS" };
    (
        format!(
            "[{marker}] {:<44} paper {:>9.1}  measured {:>9.1}",
            claim.id, claim.paper, measured
        ),
        holds,
    )
}

/// Does the claim's shape hold over the *entire* confidence interval?
///
/// Each direction is judged on its adverse CI bound: a reduction claim
/// must keep even `ci.hi()` below zero, a stability claim must bound the
/// worst |endpoint|, and so on.  A zero-width interval (n < 2 seeds)
/// degrades to exactly the point-estimate rule of [`comparison_row`].
pub fn ci_holds(claim: &PaperClaim, ci: &Ci95) -> bool {
    match claim.direction {
        -1 => ci.hi() < 0.0,
        1 => ci.lo() > 0.0,
        2 => ci.hi() <= claim.paper * 1.05,
        3 => ci.lo().abs().max(ci.hi().abs()) <= 10.0,
        _ => {
            let denom = claim.paper.abs().max(1e-9);
            let worst = (ci.lo() - claim.paper).abs().max((ci.hi() - claim.paper).abs());
            worst / denom < 0.35
        }
    }
}

/// Render one multi-seed comparison row (`mean ± CI [lo, hi] n=K`) and
/// evaluate the claim on the CI bound via [`ci_holds`].
pub fn comparison_row_ci(claim: &PaperClaim, ci: &Ci95) -> (String, bool) {
    let holds = ci_holds(claim, ci);
    let marker = if holds { "OK " } else { "MISS" };
    (
        format!(
            "[{marker}] {:<44} paper {:>8.1}  measured {:>8.1} ± {:>6.1}  [{:>8.1}, {:>8.1}]  n={}",
            claim.id,
            claim.paper,
            ci.mean,
            ci.half,
            ci.lo(),
            ci.hi(),
            ci.n
        ),
        holds,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn claim(direction: i8, paper: f64) -> PaperClaim {
        PaperClaim {
            id: "TEST.x".into(),
            description: "test".into(),
            paper,
            direction,
        }
    }

    #[test]
    fn reduction_claims_need_negative_measured() {
        let (row, ok) = comparison_row(&claim(-1, -76.1), -40.0);
        assert!(ok && row.contains("OK"));
        let (_, bad) = comparison_row(&claim(-1, -76.1), 5.0);
        assert!(!bad);
    }

    #[test]
    fn closeness_claims_use_relative_band() {
        let (_, ok) = comparison_row(&claim(0, 100.0), 110.0);
        assert!(ok);
        let (_, bad) = comparison_row(&claim(0, 100.0), 200.0);
        assert!(!bad);
    }

    #[test]
    fn positive_claims() {
        let (_, ok) = comparison_row(&claim(1, 10.0), 0.5);
        assert!(ok);
        let (_, bad) = comparison_row(&claim(1, 10.0), -0.5);
        assert!(!bad);
    }

    #[test]
    fn ci_bound_rejects_what_the_point_estimate_passes() {
        // Mean is negative (point check would pass) but the interval
        // crosses zero — the CI-bound reduction check must reject it.
        let c = claim(-1, -27.6);
        let crossing = Ci95 { n: 3, mean: -5.0, half: 8.0 };
        assert!(!ci_holds(&c, &crossing));
        let (row, ok) = comparison_row_ci(&c, &crossing);
        assert!(!ok && row.contains("MISS") && row.contains("n=3"));
        let solid = Ci95 { n: 5, mean: -20.0, half: 6.0 };
        assert!(ci_holds(&c, &solid));
        let (row, ok) = comparison_row_ci(&c, &solid);
        assert!(ok && row.contains("OK"));
    }

    #[test]
    fn ci_stability_uses_worst_endpoint() {
        let c = claim(3, 0.64);
        assert!(ci_holds(&c, &Ci95 { n: 4, mean: 1.0, half: 5.0 }));
        assert!(!ci_holds(&c, &Ci95 { n: 4, mean: 1.0, half: 12.0 }));
        assert!(!ci_holds(&c, &Ci95 { n: 4, mean: -8.0, half: 3.0 }));
    }

    #[test]
    fn zero_width_ci_degrades_to_point_check() {
        // n=1 (or zero-variance) intervals must agree with comparison_row.
        for (dir, paper, measured) in
            [(-1, -27.6, -3.0), (-1, -27.6, 3.0), (1, 16.1, 2.0), (3, 0.64, 9.0), (0, 100.0, 110.0)]
        {
            let c = claim(dir, paper);
            let point = Ci95 { n: 1, mean: measured, half: 0.0 };
            assert_eq!(
                ci_holds(&c, &point),
                comparison_row(&c, measured).1,
                "direction {dir} measured {measured}"
            );
        }
    }
}
