//! Report rendering: ASCII tables for Table II, grouped bars for Figs 6-13,
//! task traces for Figs 2-4, and paper-vs-measured comparison rows.

pub mod compare;
pub mod csv;
pub mod federation;
pub mod figures;
pub mod table;

pub use compare::{ci_holds, comparison_row, comparison_row_ci, PaperClaim};
pub use federation::federation_summary;
pub use csv::{claims_csv, delta_csv, jobs_csv, sweep_stats_csv, trace_csv, util_csv};
pub use figures::{
    fig_ci_bars, fig_completion_bars, fig_stacked_bars, fig_trace, fig_utilization,
    fig_waiting_bars,
};
pub use table::{render_table, stats_table, table2, StatsRow};
