//! Report rendering: ASCII tables for Table II, grouped bars for Figs 6-13,
//! task traces for Figs 2-4, and paper-vs-measured comparison rows.

pub mod compare;
pub mod csv;
pub mod figures;
pub mod table;

pub use compare::{comparison_row, PaperClaim};
pub use csv::{delta_csv, jobs_csv, trace_csv};
pub use figures::{fig_completion_bars, fig_stacked_bars, fig_trace, fig_waiting_bars};
pub use table::{render_table, table2};
