//! CSV export of run results — for plotting the figures outside the
//! terminal (gnuplot/matplotlib), and for EXPERIMENTS.md appendices.

use crate::sim::RunResult;

/// Per-job metrics CSV (header + one row per job).
pub fn jobs_csv(run: &RunResult) -> String {
    let mut out =
        String::from("job_id,demand,submit_s,waiting_s,completion_s,execution_s\n");
    for j in &run.jobs {
        out.push_str(&format!(
            "{},{},{:.3},{:.3},{:.3},{:.3}\n",
            j.id,
            j.demand,
            j.submit_ms as f64 / 1000.0,
            j.waiting_ms as f64 / 1000.0,
            j.completion_ms as f64 / 1000.0,
            j.execution_ms as f64 / 1000.0,
        ));
    }
    out
}

/// Task trace CSV (Figs 2-4 raw data).
pub fn trace_csv(run: &RunResult) -> String {
    let mut out = String::from("job_id,phase,task,start_s,finish_s,duration_s\n");
    for t in &run.trace.tasks {
        out.push_str(&format!(
            "{},{},{},{:.3},{:.3},{:.3}\n",
            t.job,
            t.phase,
            t.task,
            t.start as f64 / 1000.0,
            t.finish as f64 / 1000.0,
            t.duration() as f64 / 1000.0,
        ));
    }
    out
}

/// δ trajectory CSV (DRESS only; empty body for baselines).
pub fn delta_csv(run: &RunResult) -> String {
    let mut out = String::from("time_s,delta\n");
    for &(t, d) in &run.delta_history {
        out.push_str(&format!("{:.3},{:.6}\n", t as f64 / 1000.0, d));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{JobMetrics, SystemMetrics};
    use crate::sim::{TaskTrace, TraceRecorder};

    fn run() -> RunResult {
        let jobs = vec![JobMetrics {
            id: 1,
            demand: 4,
            submit_ms: 1_000,
            waiting_ms: 500,
            completion_ms: 2_500,
            execution_ms: 2_000,
        }];
        let system = SystemMetrics::of(&jobs, &[], 10);
        let mut trace = TraceRecorder::new();
        trace.record(TaskTrace { job: 1, phase: 0, task: 0, granted: 900, start: 1_500, finish: 3_500 });
        RunResult {
            scheduler: "dress".into(),
            jobs,
            system,
            trace,
            delta_history: vec![(0, 0.1), (1_000, 0.15)],
            failures: 0,
            events: 0,
            sched_ticks: 0,
            tasks_recorded: 1,
            transitions_recorded: 0,
            retained_transitions: 0,
        }
    }

    #[test]
    fn jobs_csv_shape() {
        let csv = jobs_csv(&run());
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("job_id,"));
        assert!(lines[1].starts_with("1,4,1.000,0.500,2.500,2.000"));
    }

    #[test]
    fn trace_csv_shape() {
        let csv = trace_csv(&run());
        assert!(csv.contains("1,0,0,1.500,3.500,2.000"));
    }

    #[test]
    fn delta_csv_shape() {
        let csv = delta_csv(&run());
        assert!(csv.contains("0.000,0.100000"));
        assert!(csv.contains("1.000,0.150000"));
    }
}
