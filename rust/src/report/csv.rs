//! CSV export of run results — for plotting the figures outside the
//! terminal (gnuplot/matplotlib), and for EXPERIMENTS.md appendices.

use super::compare::PaperClaim;
use super::table::StatsRow;
use crate::sim::RunResult;
use crate::util::stats::Ci95;

/// Per-job metrics CSV (header + one row per job).
pub fn jobs_csv(run: &RunResult) -> String {
    let mut out =
        String::from("job_id,demand,submit_s,waiting_s,completion_s,execution_s\n");
    for j in &run.jobs {
        out.push_str(&format!(
            "{},{},{:.3},{:.3},{:.3},{:.3}\n",
            j.id,
            j.demand,
            j.submit_ms as f64 / 1000.0,
            j.waiting_ms as f64 / 1000.0,
            j.completion_ms as f64 / 1000.0,
            j.execution_ms as f64 / 1000.0,
        ));
    }
    out
}

/// Task trace CSV (Figs 2-4 raw data).
pub fn trace_csv(run: &RunResult) -> String {
    let mut out = String::from("job_id,phase,task,start_s,finish_s,duration_s\n");
    for t in &run.trace.tasks {
        out.push_str(&format!(
            "{},{},{},{:.3},{:.3},{:.3}\n",
            t.job,
            t.phase,
            t.task,
            t.start as f64 / 1000.0,
            t.finish as f64 / 1000.0,
            t.duration() as f64 / 1000.0,
        ));
    }
    out
}

/// δ trajectory CSV (DRESS only; empty body for baselines).  Rows cover
/// the *retained* samples — downsampled under ring/decimating metric
/// sinks, complete under full.
pub fn delta_csv(run: &RunResult) -> String {
    let mut out = String::from("time_s,delta\n");
    for &(t, d) in &run.delta_history {
        out.push_str(&format!("{:.3},{:.6}\n", t as f64 / 1000.0, d));
    }
    out
}

/// Per-tick utilization CSV over the retained samples (downsampled under
/// ring/decimating metric sinks; empty body under counting — use the
/// exact `RunResult::util` summary instead).
pub fn util_csv(run: &RunResult) -> String {
    let total = run.util.total.max(1);
    let mut out = String::from("time_s,used,total,busy_frac\n");
    for &(t, used) in &run.util_history {
        out.push_str(&format!(
            "{:.3},{},{},{:.6}\n",
            t as f64 / 1000.0,
            used,
            total,
            used as f64 / total as f64,
        ));
    }
    out
}

/// Seed-aggregate statistics CSV: one row per (group, metric) with the
/// sweep layer's canonical columns.
pub fn sweep_stats_csv(rows: &[StatsRow]) -> String {
    let mut out = String::from("group,metric,n_seeds,mean,ci_lo,ci_hi\n");
    for r in rows {
        out.push_str(&format!(
            "{},{},{},{:.6},{:.6},{:.6}\n",
            r.group,
            r.metric,
            r.ci.n,
            r.ci.mean,
            r.ci.lo(),
            r.ci.hi(),
        ));
    }
    out
}

/// Multi-seed claim-verification CSV: paper target vs measured `mean ± CI`
/// and the CI-bound verdict.
pub fn claims_csv(rows: &[(&PaperClaim, Ci95, bool)]) -> String {
    let mut out = String::from("claim_id,paper,n_seeds,mean,ci_lo,ci_hi,holds\n");
    for (claim, ci, holds) in rows {
        out.push_str(&format!(
            "{},{:.3},{},{:.6},{:.6},{:.6},{}\n",
            claim.id,
            claim.paper,
            ci.n,
            ci.mean,
            ci.lo(),
            ci.hi(),
            holds,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{JobMetrics, SystemMetrics, UtilSummary};
    use crate::sim::{TaskTrace, TraceRecorder};

    fn run() -> RunResult {
        let jobs = vec![JobMetrics {
            id: 1,
            demand: 4,
            submit_ms: 1_000,
            waiting_ms: 500,
            completion_ms: 2_500,
            execution_ms: 2_000,
        }];
        let util_history = vec![(0u64, 5u32), (1_000, 10)];
        let util = UtilSummary::from_samples(&util_history, 10);
        let system = SystemMetrics::of(&jobs, &util);
        let mut trace = TraceRecorder::new();
        trace.record(TaskTrace { job: 1, phase: 0, task: 0, granted: 900, start: 1_500, finish: 3_500 });
        RunResult {
            scheduler: "dress".into(),
            jobs,
            system,
            trace,
            delta_history: vec![(0, 0.1), (1_000, 0.15)],
            util_history,
            util,
            delta: Default::default(),
            util_recorded: 2,
            delta_recorded: 2,
            failures: 0,
            lost_attempts: 0,
            lost_work_ms: 0,
            useful_work_ms: 0,
            wasted_work_ms: 0,
            attempts: 0,
            outages: vec![],
            events: 0,
            sched_ticks: 0,
            tasks_recorded: 1,
            transitions_recorded: 0,
            retained_transitions: 0,
            cells: 1,
            migrations: 0,
            routing: vec![],
            imbalance_max: 0.0,
            imbalance_mean: 0.0,
            cell_outages: vec![],
        }
    }

    #[test]
    fn jobs_csv_shape() {
        let csv = jobs_csv(&run());
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("job_id,"));
        assert!(lines[1].starts_with("1,4,1.000,0.500,2.500,2.000"));
    }

    #[test]
    fn trace_csv_shape() {
        let csv = trace_csv(&run());
        assert!(csv.contains("1,0,0,1.500,3.500,2.000"));
    }

    #[test]
    fn delta_csv_shape() {
        let csv = delta_csv(&run());
        assert!(csv.contains("0.000,0.100000"));
        assert!(csv.contains("1.000,0.150000"));
    }

    #[test]
    fn util_csv_shape() {
        let csv = util_csv(&run());
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "time_s,used,total,busy_frac");
        assert_eq!(lines[1], "0.000,5,10,0.500000");
        assert_eq!(lines[2], "1.000,10,10,1.000000");
    }

    #[test]
    fn sweep_stats_csv_shape() {
        let rows = vec![StatsRow {
            group: "w0/dress".into(),
            metric: "avg_wait_s".into(),
            ci: Ci95 { n: 3, mean: 2.5, half: 0.5 },
        }];
        let csv = sweep_stats_csv(&rows);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "group,metric,n_seeds,mean,ci_lo,ci_hi");
        assert_eq!(lines[1], "w0/dress,avg_wait_s,3,2.500000,2.000000,3.000000");
    }

    #[test]
    fn claims_csv_shape() {
        let claim = PaperClaim {
            id: "FIG7.small-completion-change-pct".into(),
            description: "test".into(),
            paper: -27.6,
            direction: -1,
        };
        let csv = claims_csv(&[(&claim, Ci95 { n: 4, mean: -20.0, half: 5.0 }, true)]);
        assert!(csv.starts_with("claim_id,paper,n_seeds,mean,ci_lo,ci_hi,holds\n"));
        assert!(csv.contains("FIG7.small-completion-change-pct,-27.600,4,-20.000000,-25.000000,-15.000000,true"));
    }
}
