//! Federation report block: routing distribution, migration counters,
//! cross-cell imbalance, and per-cell outage recovery (docs/FEDERATION.md
//! defines each metric).  Pure function of the merged [`RunResult`], so
//! `dress run --cells N` output is deterministic byte-for-byte.

use crate::sim::RunResult;

/// Render the federation section of a `dress run` report.  Empty for
/// single-cell results so callers can `print!` unconditionally.
pub fn federation_summary(router: &str, res: &RunResult) -> String {
    if res.cells <= 1 {
        return String::new();
    }
    let mut out = format!(
        "federation: {} cells via `{router}` | routed {:?} | {} migration(s) | \
         imbalance max {:.2} mean {:.2}\n",
        res.cells, res.routing, res.migrations, res.imbalance_max, res.imbalance_mean
    );
    for o in &res.cell_outages {
        let ttr = match o.time_to_recover_ms() {
            Some(ms) => format!("time-to-recover {:.1}s", ms as f64 / 1000.0),
            None => "unrecovered at run end".into(),
        };
        out.push_str(&format!(
            "  cell {} down at {:.1}s for {:.1}s: salvaged {} job(s), {ttr}\n",
            o.cell,
            o.at_ms as f64 / 1000.0,
            o.down_ms as f64 / 1000.0,
            o.salvaged,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::sim::CellOutageRecord;
    use crate::workload::{generate, WorkloadMix};

    #[test]
    fn single_cell_results_render_nothing() {
        let cfg = ExperimentConfig::default();
        let specs = generate(3, WorkloadMix::Mixed, 0.3, 2_000, 7);
        let res = crate::sim::engine::run_experiment(&cfg, specs);
        assert_eq!(res.cells, 1);
        assert_eq!(federation_summary("round-robin", &res), "");
    }

    #[test]
    fn federated_results_render_counters_and_outages() {
        let mut cfg = ExperimentConfig::default();
        cfg.federation.cells = 2;
        let specs = generate(4, WorkloadMix::Mixed, 0.3, 2_000, 7);
        let mut res = crate::sim::run_experiment_with(
            &cfg,
            specs,
            crate::sim::EngineOptions::default(),
        );
        assert_eq!(res.cells, 2);
        res.cell_outages.push(CellOutageRecord {
            cell: 1,
            at_ms: 4_000,
            down_ms: 5_000,
            salvaged: 3,
            recovered_at: Some(11_000),
        });
        let s = federation_summary("least-load", &res);
        assert!(s.contains("2 cells via `least-load`"), "{s}");
        assert!(s.contains("cell 1 down at 4.0s"), "{s}");
        assert!(s.contains("salvaged 3 job(s), time-to-recover 7.0s"), "{s}");
    }
}
