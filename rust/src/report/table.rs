//! ASCII table rendering + the paper's Table II.

use crate::metrics::SchedulerSummary;

/// Render rows as an aligned ASCII table. `header` defines column count.
pub fn render_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        assert_eq!(row.len(), ncol, "row arity mismatch");
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let mut line = String::new();
    for (i, h) in header.iter().enumerate() {
        line.push_str(&format!("| {:<w$} ", h, w = widths[i]));
    }
    line.push('|');
    out.push_str(&line);
    out.push('\n');
    out.push_str(&"-".repeat(line.len()));
    out.push('\n');
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            out.push_str(&format!("| {:<w$} ", cell, w = widths[i]));
        }
        out.push_str("|\n");
    }
    out
}

/// Table II: overall system performance, one row per scheduler.
pub fn table2(rows: &[SchedulerSummary]) -> String {
    let header = ["Scheduler", "Makespan", "Avg. W.", "Median W.", "Avg. C.", "Median C."];
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|s| {
            vec![
                s.scheduler.clone(),
                format!("{:.1}", s.makespan_s),
                format!("{:.1}", s.avg_waiting_s),
                format!("{:.1}", s.median_waiting_s),
                format!("{:.1}", s.avg_completion_s),
                format!("{:.1}", s.median_completion_s),
            ]
        })
        .collect();
    render_table(&header, &body)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_aligns_columns() {
        let t = render_table(
            &["a", "long-header"],
            &[vec!["x".into(), "y".into()], vec!["wide-cell".into(), "z".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert!(lines.len() >= 4);
        assert_eq!(lines[0].len(), lines[2].len());
        assert!(lines[0].contains("long-header"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_mismatch_panics() {
        render_table(&["a", "b"], &[vec!["only-one".into()]]);
    }

    #[test]
    fn table2_contains_schedulers() {
        let rows = vec![
            SchedulerSummary {
                scheduler: "capacity".into(),
                makespan_s: 1028.6,
                avg_waiting_s: 310.1,
                median_waiting_s: 381.0,
                avg_completion_s: 570.1,
                median_completion_s: 542.8,
            },
            SchedulerSummary {
                scheduler: "dress".into(),
                makespan_s: 1035.2,
                avg_waiting_s: 264.5,
                median_waiting_s: 190.3,
                avg_completion_s: 532.2,
                median_completion_s: 325.1,
            },
        ];
        let t = table2(&rows);
        assert!(t.contains("capacity") && t.contains("dress"));
        assert!(t.contains("1028.6") && t.contains("325.1"));
    }
}
