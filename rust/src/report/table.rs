//! ASCII table rendering + the paper's Table II + seed-aggregate
//! statistics tables (`mean / ci_lo / ci_hi / n_seeds`).

use crate::metrics::SchedulerSummary;
use crate::util::stats::Ci95;

/// Render rows as an aligned ASCII table. `header` defines column count.
pub fn render_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        assert_eq!(row.len(), ncol, "row arity mismatch");
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let mut line = String::new();
    for (i, h) in header.iter().enumerate() {
        line.push_str(&format!("| {:<w$} ", h, w = widths[i]));
    }
    line.push('|');
    out.push_str(&line);
    out.push('\n');
    out.push_str(&"-".repeat(line.len()));
    out.push('\n');
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            out.push_str(&format!("| {:<w$} ", cell, w = widths[i]));
        }
        out.push_str("|\n");
    }
    out
}

/// Table II: overall system performance, one row per scheduler.
pub fn table2(rows: &[SchedulerSummary]) -> String {
    let header = ["Scheduler", "Makespan", "Avg. W.", "Median W.", "Avg. C.", "Median C."];
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|s| {
            vec![
                s.scheduler.clone(),
                format!("{:.1}", s.makespan_s),
                format!("{:.1}", s.avg_waiting_s),
                format!("{:.1}", s.median_waiting_s),
                format!("{:.1}", s.avg_completion_s),
                format!("{:.1}", s.median_completion_s),
            ]
        })
        .collect();
    render_table(&header, &body)
}

/// One row of a seed-aggregate statistics table: a metric for a group
/// (e.g. scheduler × workload) summarized across seeds as a 95% CI.
#[derive(Debug, Clone, PartialEq)]
pub struct StatsRow {
    pub group: String,
    pub metric: String,
    pub ci: Ci95,
}

/// Render seed aggregates as an aligned table with the sweep layer's
/// canonical statistics columns (`n_seeds`, `mean`, `ci_lo`, `ci_hi`).
pub fn stats_table(rows: &[StatsRow]) -> String {
    let header = ["Group", "Metric", "n_seeds", "mean", "ci_lo", "ci_hi"];
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.group.clone(),
                r.metric.clone(),
                r.ci.n.to_string(),
                format!("{:.3}", r.ci.mean),
                format!("{:.3}", r.ci.lo()),
                format!("{:.3}", r.ci.hi()),
            ]
        })
        .collect();
    render_table(&header, &body)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_aligns_columns() {
        let t = render_table(
            &["a", "long-header"],
            &[vec!["x".into(), "y".into()], vec!["wide-cell".into(), "z".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert!(lines.len() >= 4);
        assert_eq!(lines[0].len(), lines[2].len());
        assert!(lines[0].contains("long-header"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_mismatch_panics() {
        render_table(&["a", "b"], &[vec!["only-one".into()]]);
    }

    #[test]
    fn table2_contains_schedulers() {
        let rows = vec![
            SchedulerSummary {
                scheduler: "capacity".into(),
                makespan_s: 1028.6,
                avg_waiting_s: 310.1,
                median_waiting_s: 381.0,
                avg_completion_s: 570.1,
                median_completion_s: 542.8,
            },
            SchedulerSummary {
                scheduler: "dress".into(),
                makespan_s: 1035.2,
                avg_waiting_s: 264.5,
                median_waiting_s: 190.3,
                avg_completion_s: 532.2,
                median_completion_s: 325.1,
            },
        ];
        let t = table2(&rows);
        assert!(t.contains("capacity") && t.contains("dress"));
        assert!(t.contains("1028.6") && t.contains("325.1"));
    }

    #[test]
    fn stats_table_carries_ci_columns() {
        let rows = vec![
            StatsRow {
                group: "spark/dress".into(),
                metric: "makespan_s".into(),
                ci: Ci95 { n: 5, mean: 120.5, half: 3.25 },
            },
            StatsRow {
                group: "spark/capacity".into(),
                metric: "makespan_s".into(),
                ci: Ci95 { n: 5, mean: 119.75, half: 2.0 },
            },
        ];
        let t = stats_table(&rows);
        assert!(t.contains("n_seeds") && t.contains("ci_lo") && t.contains("ci_hi"));
        assert!(t.contains("117.250") && t.contains("123.750"), "lo/hi rendered:\n{t}");
        assert!(t.contains("spark/dress") && t.contains("| 5 "));
    }
}
