//! Job / phase / task domain model.
//!
//! A job (paper notation `J_i`) is a DAG-flattened sequence of *phases*
//! (`p_j`), each a set of *tasks* (`t_i`) that run in parallel, one task per
//! container.  Phases are barriers: phase `j+1` cannot launch until every
//! task of phase `j` completed (MapReduce map->reduce, Spark stage
//! boundaries).  A job's *resource demand* `r_i` is the number of containers
//! it requests from the scheduler.

pub mod demand;
pub mod job;
pub mod spec;
pub mod store;

pub use demand::{Demand, DEMAND_AXES, DEMAND_AXIS_NAMES};
pub use job::{JobRt, TaskRt, TaskState};
pub use spec::{JobId, JobSpec, PhaseKind, PhaseSpec, Platform, TaskSpec};
pub use store::{JobLayout, JobStore};
