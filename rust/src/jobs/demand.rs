//! Vector resource demands: CPU containers × memory units.
//!
//! The paper's `r_i` is a scalar container count.  Real congested platforms
//! (and the max-weight setting of Psychas & Ghaderi, arXiv 1901.05998)
//! schedule over resource *vectors*.  `Demand` generalizes `r_i` to a
//! fixed-2-axis vector while keeping the scalar world as a strict special
//! case: `Demand::scalar(n)` puts `n` on both axes, and every scheduler
//! decision on a uniform demand reduces to exactly the old scalar
//! arithmetic on axis 0 (see docs/RESOURCES.md for the proof obligations).
//!
//! Axis semantics:
//! - axis 0 (`cpu`): containers requested — the grant currency, identical
//!   to the old scalar `demand`.  One task occupies one container.
//! - axis 1 (`mem`): job-level memory units.  Each launched container
//!   carries a footprint of `mem_per_container()` units on its node.

use std::fmt;

/// Number of resource axes (fixed: CPU containers and memory units).
pub const DEMAND_AXES: usize = 2;

/// Human-readable axis names, indexed by axis number.  Used by
/// `JobSpec::validate` errors and the docs so messages name the axis.
pub const DEMAND_AXIS_NAMES: [&str; DEMAND_AXES] = ["cpu", "mem"];

/// A per-job resource demand vector.
///
/// Ordering is lexicographic (cpu, then mem); for uniform demands this is
/// identical to ordering by the old scalar value, which keeps pre-refactor
/// sort orders intact.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Demand {
    /// Containers requested (the paper's `r_i`, the SD/LD key on axis 0).
    pub cpu: u32,
    /// Job-level memory units spread across the granted containers.
    pub mem: u32,
}

impl Demand {
    /// A true vector demand.
    pub const fn new(cpu: u32, mem: u32) -> Self {
        Demand { cpu, mem }
    }

    /// Compatibility constructor: the scalar world.  `scalar(n)` demands
    /// `n` containers each carrying exactly one memory unit, so memory
    /// never binds and every per-axis check degenerates to the cpu axis.
    pub const fn scalar(n: u32) -> Self {
        Demand { cpu: n, mem: n }
    }

    /// True for demands produced by `scalar` — both axes equal.
    pub const fn is_uniform(self) -> bool {
        self.cpu == self.mem
    }

    /// Axis accessor, `a < DEMAND_AXES`.
    pub fn axis(self, a: usize) -> u32 {
        match a {
            0 => self.cpu,
            1 => self.mem,
            _ => panic!("demand axis {a} out of range"),
        }
    }

    /// Memory footprint of one launched container: the job-level memory
    /// demand split evenly over its containers, rounded up.  Exactly 1 for
    /// uniform demands, so scalar runs consume one memory unit per slot.
    pub fn mem_per_container(self) -> u32 {
        self.mem.div_ceil(self.cpu.max(1))
    }

    /// Per-axis minimum (used for demand caps: both axes are clamped, so a
    /// uniform demand stays uniform).
    pub fn min_each(self, other: Demand) -> Demand {
        Demand { cpu: self.cpu.min(other.cpu), mem: self.mem.min(other.mem) }
    }

    /// Dominant-resource axis against a capacity vector: the axis where
    /// this demand claims the largest share of `total`.  Ties break toward
    /// axis 0, so uniform demands against uniform capacity always pick the
    /// cpu axis — the pre-refactor classification key.
    pub fn dominant_axis(self, total: Demand) -> usize {
        let share0 = self.cpu as f64 / total.cpu.max(1) as f64;
        let share1 = self.mem as f64 / total.mem.max(1) as f64;
        if share1 > share0 { 1 } else { 0 }
    }

    /// Parse a tracefile demand token: `"4"` (uniform) or `"4x8"`
    /// (cpu x mem).  Errors mention "demand" so tracefile diagnostics
    /// keep naming the offending column.
    pub fn parse(token: &str) -> Result<Demand, String> {
        match token.split_once('x') {
            None => {
                let n: u32 =
                    token.parse().map_err(|e| format!("demand {token:?}: {e}"))?;
                Ok(Demand::scalar(n))
            }
            Some((c, m)) => {
                let cpu: u32 =
                    c.parse().map_err(|e| format!("demand cpu axis {c:?}: {e}"))?;
                let mem: u32 =
                    m.parse().map_err(|e| format!("demand mem axis {m:?}: {e}"))?;
                Ok(Demand { cpu, mem })
            }
        }
    }
}

/// Renders uniform demands as the bare scalar (`4`) and vector demands as
/// `cpu x mem` (`4x8`) — the tracefile token format.  `parse ∘ render` is
/// the identity, which the tracefile fixed-point property pins.
impl fmt::Display for Demand {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_uniform() {
            write!(f, "{}", self.cpu)
        } else {
            write!(f, "{}x{}", self.cpu, self.mem)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_is_uniform_and_unit_footprint() {
        let d = Demand::scalar(7);
        assert_eq!(d.cpu, 7);
        assert_eq!(d.mem, 7);
        assert!(d.is_uniform());
        assert_eq!(d.mem_per_container(), 1);
    }

    #[test]
    fn vector_footprint_rounds_up() {
        assert_eq!(Demand::new(4, 8).mem_per_container(), 2);
        assert_eq!(Demand::new(4, 9).mem_per_container(), 3);
        assert_eq!(Demand::new(3, 1).mem_per_container(), 1);
        // Degenerate zero-cpu demand must not divide by zero (validate
        // rejects it before any scheduler sees it).
        assert_eq!(Demand::new(0, 5).mem_per_container(), 5);
    }

    #[test]
    fn dominant_axis_ties_to_cpu() {
        let total = Demand::scalar(40);
        assert_eq!(Demand::scalar(10).dominant_axis(total), 0);
        assert_eq!(Demand::new(4, 20).dominant_axis(total), 1);
        assert_eq!(Demand::new(20, 4).dominant_axis(total), 0);
        // Equal shares on a non-uniform demand still pick axis 0.
        assert_eq!(Demand::new(10, 20).dominant_axis(Demand::new(40, 80)), 0);
    }

    #[test]
    fn display_parse_roundtrip() {
        for d in [Demand::scalar(1), Demand::scalar(30), Demand::new(4, 8), Demand::new(2, 17)] {
            assert_eq!(Demand::parse(&d.to_string()).unwrap(), d);
        }
        assert_eq!(Demand::parse("4").unwrap(), Demand::scalar(4));
        assert_eq!(Demand::parse("4x8").unwrap(), Demand::new(4, 8));
    }

    #[test]
    fn parse_errors_name_the_demand_column() {
        for bad in ["lots", "4xfoo", "x8", ""] {
            let err = Demand::parse(bad).unwrap_err();
            assert!(err.contains("demand"), "error should mention demand: {err}");
        }
    }

    #[test]
    fn ordering_matches_scalar_for_uniform() {
        let mut v = vec![Demand::scalar(9), Demand::scalar(2), Demand::scalar(5)];
        v.sort();
        assert_eq!(v, vec![Demand::scalar(2), Demand::scalar(5), Demand::scalar(9)]);
    }

    #[test]
    fn min_each_clamps_per_axis() {
        assert_eq!(Demand::new(10, 40).min_each(Demand::scalar(8)), Demand::new(8, 8));
        assert_eq!(Demand::scalar(3).min_each(Demand::scalar(8)), Demand::scalar(3));
    }
}
