//! Runtime job state tracked by the simulation engine.

use super::spec::{JobId, JobSpec};
use crate::cluster::ContainerId;
use crate::util::Time;

/// Lifecycle of one task.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskState {
    /// Waiting for a container grant.
    Pending,
    /// Granted; its container is working through the YARN state machine.
    Launching(ContainerId),
    /// Executing.
    Running { container: ContainerId, start: Time },
    /// Finished.
    Done { start: Time, finish: Time },
}

/// Runtime task record.
#[derive(Debug, Clone)]
pub struct TaskRt {
    pub duration_ms: Time,
    pub state: TaskState,
}

/// Runtime job record: spec + mutable execution state.
#[derive(Debug, Clone)]
pub struct JobRt {
    pub spec: JobSpec,
    /// Index of the phase currently eligible to launch tasks.
    pub cur_phase: usize,
    /// Per-phase task states, mirroring `spec.phases`.
    pub tasks: Vec<Vec<TaskRt>>,
    /// Set once the job has been observed by the scheduler (submission).
    pub submitted: bool,
    /// Time the first task entered Running (defines waiting time).
    pub first_start: Option<Time>,
    /// Time the last task completed (defines completion time).
    pub finish: Option<Time>,
    /// Containers currently held (Launching + Running tasks).
    pub occupied: u32,
}

impl JobRt {
    pub fn new(spec: JobSpec) -> Self {
        let tasks = spec
            .phases
            .iter()
            .map(|p| {
                p.tasks
                    .iter()
                    .map(|t| TaskRt { duration_ms: t.duration_ms, state: TaskState::Pending })
                    .collect()
            })
            .collect();
        JobRt {
            spec,
            cur_phase: 0,
            tasks,
            submitted: false,
            first_start: None,
            finish: None,
            occupied: 0,
        }
    }

    pub fn id(&self) -> JobId {
        self.spec.id
    }

    pub fn started(&self) -> bool {
        self.first_start.is_some()
    }

    pub fn finished(&self) -> bool {
        self.finish.is_some()
    }

    /// Number of tasks in the current phase still waiting for a container.
    pub fn pending_tasks(&self) -> u32 {
        if self.finished() || self.cur_phase >= self.tasks.len() {
            return 0;
        }
        self.tasks[self.cur_phase]
            .iter()
            .filter(|t| t.state == TaskState::Pending)
            .count() as u32
    }

    /// Pick the next pending task in the current phase (engine side).
    pub fn next_pending(&self) -> Option<(usize, usize)> {
        if self.cur_phase >= self.tasks.len() {
            return None;
        }
        self.tasks[self.cur_phase]
            .iter()
            .position(|t| t.state == TaskState::Pending)
            .map(|i| (self.cur_phase, i))
    }

    /// True when every task of `phase` is Done.
    pub fn phase_complete(&self, phase: usize) -> bool {
        self.tasks[phase]
            .iter()
            .all(|t| matches!(t.state, TaskState::Done { .. }))
    }

    /// Advance the phase cursor past completed phases (barrier semantics).
    pub fn advance_phase(&mut self) {
        while self.cur_phase < self.tasks.len() && self.phase_complete(self.cur_phase) {
            self.cur_phase += 1;
        }
    }

    /// True when all tasks in all phases are done.
    pub fn all_done(&self) -> bool {
        self.tasks.iter().all(|p| {
            p.iter().all(|t| matches!(t.state, TaskState::Done { .. }))
        })
    }

    /// Waiting time (submission -> first task running), once known.
    pub fn waiting_ms(&self) -> Option<Time> {
        self.first_start.map(|s| s.saturating_sub(self.spec.submit_ms))
    }

    /// Completion time (submission -> last task finished), once known.
    pub fn completion_ms(&self) -> Option<Time> {
        self.finish.map(|f| f.saturating_sub(self.spec.submit_ms))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobs::demand::Demand;
    use crate::jobs::spec::{PhaseKind, PhaseSpec, Platform};

    fn rt() -> JobRt {
        JobRt::new(JobSpec {
            id: 3,
            name: "sort".into(),
            platform: Platform::MapReduce,
            submit_ms: 1_000,
            demand: Demand::scalar(2),
            phases: vec![
                PhaseSpec::new(PhaseKind::Map, &[5_000, 6_000]),
                PhaseSpec::new(PhaseKind::Reduce, &[4_000]),
            ],
        })
    }

    #[test]
    fn initial_state() {
        let j = rt();
        assert_eq!(j.pending_tasks(), 2);
        assert!(!j.started() && !j.finished());
        assert_eq!(j.next_pending(), Some((0, 0)));
    }

    #[test]
    fn barrier_blocks_next_phase() {
        let mut j = rt();
        j.tasks[0][0].state = TaskState::Done { start: 0, finish: 5_000 };
        j.advance_phase();
        assert_eq!(j.cur_phase, 0, "phase 0 not fully done yet");
        assert_eq!(j.pending_tasks(), 1);
        j.tasks[0][1].state = TaskState::Done { start: 0, finish: 6_000 };
        j.advance_phase();
        assert_eq!(j.cur_phase, 1);
        assert_eq!(j.pending_tasks(), 1);
    }

    #[test]
    fn completion_metrics() {
        let mut j = rt();
        j.first_start = Some(3_000);
        j.finish = Some(15_000);
        assert_eq!(j.waiting_ms(), Some(2_000));
        assert_eq!(j.completion_ms(), Some(14_000));
    }

    #[test]
    fn all_done_detects_end() {
        let mut j = rt();
        for p in 0..j.tasks.len() {
            for t in 0..j.tasks[p].len() {
                j.tasks[p][t].state = TaskState::Done { start: 0, finish: 1 };
            }
        }
        assert!(j.all_done());
        j.advance_phase();
        assert_eq!(j.pending_tasks(), 0);
    }
}
