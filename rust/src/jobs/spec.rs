//! Immutable job specifications (what the workload generator produces and
//! the simulator consumes).

use crate::jobs::demand::{Demand, DEMAND_AXIS_NAMES};
use crate::util::Time;

/// Job identifier (index into the experiment's job list, 1-based in reports
/// to match the paper's figures).
pub type JobId = u32;

/// Which platform the job runs on (paper §V.A.2 runs both).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Platform {
    /// Classic MapReduce on YARN: distinct Map / Reduce phases.
    MapReduce,
    /// Spark-on-YARN: stages without a Map/Reduce split, data-skew prone.
    Spark,
}

impl std::fmt::Display for Platform {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Platform::MapReduce => write!(f, "mapreduce"),
            Platform::Spark => write!(f, "spark"),
        }
    }
}

/// Phase flavor — informs trace labels and figure rendering only; the
/// scheduler treats all phases uniformly (as YARN does).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PhaseKind {
    Map,
    Reduce,
    SparkStage,
}

/// One task: nominal execution length once its container reaches Running.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TaskSpec {
    pub duration_ms: Time,
}

/// One phase: a parallel wave of tasks behind a barrier.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseSpec {
    pub kind: PhaseKind,
    pub tasks: Vec<TaskSpec>,
}

impl PhaseSpec {
    pub fn new(kind: PhaseKind, durations_ms: &[Time]) -> Self {
        PhaseSpec {
            kind,
            tasks: durations_ms.iter().map(|&d| TaskSpec { duration_ms: d }).collect(),
        }
    }

    pub fn width(&self) -> u32 {
        self.tasks.len() as u32
    }
}

/// A complete job specification.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    pub id: JobId,
    /// Benchmark name, e.g. "wordcount", "pagerank" (HiBench-style).
    pub name: String,
    pub platform: Platform,
    /// Submission time (ms since experiment start).
    pub submit_ms: Time,
    /// Resource demand vector.  Axis 0 (cpu) is the paper's `r_i` — the
    /// containers requested and the SD/LD classification key; axis 1 (mem)
    /// is the job-level memory footprint.  `Demand::scalar(n)` reproduces
    /// the pre-vector scalar world exactly.
    pub demand: Demand,
    pub phases: Vec<PhaseSpec>,
}

impl JobSpec {
    /// Total number of tasks across phases.
    pub fn total_tasks(&self) -> u32 {
        self.phases.iter().map(|p| p.width()).sum()
    }

    /// Widest phase — a lower bound sanity check against `demand`.
    pub fn max_phase_width(&self) -> u32 {
        self.phases.iter().map(|p| p.width()).max().unwrap_or(0)
    }

    /// Total serial work if run with unlimited containers (critical path).
    pub fn critical_path_ms(&self) -> Time {
        self.phases
            .iter()
            .map(|p| p.tasks.iter().map(|t| t.duration_ms).max().unwrap_or(0))
            .sum()
    }

    /// Total container-milliseconds of work.
    pub fn work_ms(&self) -> Time {
        self.phases
            .iter()
            .flat_map(|p| p.tasks.iter().map(|t| t.duration_ms))
            .sum()
    }

    /// Structural validity: at least one phase, no empty phase, a nonzero
    /// demand on every axis, no zero-length task.
    ///
    /// For *vector* (non-uniform) demands the widest phase must also fit
    /// inside the per-axis demand: a phase wider than the cpu axis could
    /// never reach full parallelism on the requested containers, and a
    /// phase wider than the mem axis would imply sub-unit per-container
    /// memory.  Uniform (scalar-compatibility) demands keep the historical
    /// wave semantics — generated workloads legitimately cap `demand`
    /// below the widest phase and run it in multiple waves.
    pub fn validate(&self) -> Result<(), String> {
        if self.phases.is_empty() {
            return Err(format!("job {} has no phases", self.id));
        }
        if self.phases.iter().any(|p| p.tasks.is_empty()) {
            return Err(format!("job {} has an empty phase", self.id));
        }
        if self.demand.cpu == 0 {
            return Err(format!(
                "job {} demands 0 containers on the {} axis",
                self.id, DEMAND_AXIS_NAMES[0]
            ));
        }
        if self.demand.mem == 0 {
            return Err(format!(
                "job {} demands 0 memory units on the {} axis",
                self.id, DEMAND_AXIS_NAMES[1]
            ));
        }
        if !self.demand.is_uniform() {
            let width = self.max_phase_width();
            if width > self.demand.cpu {
                return Err(format!(
                    "job {} widest phase ({} tasks) exceeds its {}-axis demand {}",
                    self.id, width, DEMAND_AXIS_NAMES[0], self.demand.cpu
                ));
            }
            if width > self.demand.mem {
                return Err(format!(
                    "job {} widest phase ({} tasks) exceeds its {}-axis demand {}",
                    self.id, width, DEMAND_AXIS_NAMES[1], self.demand.mem
                ));
            }
        }
        if self.phases.iter().any(|p| p.tasks.iter().any(|t| t.duration_ms == 0)) {
            return Err(format!("job {} has a zero-length task", self.id));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> JobSpec {
        JobSpec {
            id: 1,
            name: "wordcount".into(),
            platform: Platform::MapReduce,
            submit_ms: 0,
            demand: Demand::scalar(4),
            phases: vec![
                PhaseSpec::new(PhaseKind::Map, &[10_000, 12_000, 11_000]),
                PhaseSpec::new(PhaseKind::Reduce, &[8_000]),
            ],
        }
    }

    #[test]
    fn totals() {
        let s = spec();
        assert_eq!(s.total_tasks(), 4);
        assert_eq!(s.max_phase_width(), 3);
        assert_eq!(s.critical_path_ms(), 20_000);
        assert_eq!(s.work_ms(), 41_000);
        assert!(s.validate().is_ok());
    }

    #[test]
    fn validation_rejects_bad_specs() {
        let mut s = spec();
        s.demand = Demand::scalar(0);
        assert!(s.validate().is_err());

        let mut s = spec();
        s.phases.clear();
        assert!(s.validate().is_err());

        let mut s = spec();
        s.phases[0].tasks.clear();
        assert!(s.validate().is_err());

        let mut s = spec();
        s.phases[1].tasks[0].duration_ms = 0;
        assert!(s.validate().is_err());
    }

    #[test]
    fn validation_names_the_zero_axis() {
        let mut s = spec();
        s.demand = Demand::new(4, 0);
        let err = s.validate().unwrap_err();
        assert!(err.contains("mem"), "should name the mem axis: {err}");

        let mut s = spec();
        s.demand = Demand::new(0, 4);
        let err = s.validate().unwrap_err();
        assert!(err.contains("cpu"), "should name the cpu axis: {err}");
    }

    #[test]
    fn vector_demand_rejects_phase_wider_than_axis() {
        // Widest phase is 3 tasks; a vector demand of 2 containers can
        // never run it at full width, and the error names the cpu axis.
        let mut s = spec();
        s.demand = Demand::new(2, 8);
        let err = s.validate().unwrap_err();
        assert!(err.contains("cpu"), "should name the cpu axis: {err}");

        // A vector demand wide enough on both axes is fine.
        let mut s = spec();
        s.demand = Demand::new(3, 9);
        assert!(s.validate().is_ok());
    }

    #[test]
    fn uniform_demand_keeps_wave_semantics() {
        // Scalar-compatibility demands may sit below the widest phase —
        // generated workloads cap demand and run wide phases in waves.
        let mut s = spec();
        s.demand = Demand::scalar(2);
        assert!(s.validate().is_ok(), "uniform demand below phase width must stay legal");
    }
}
