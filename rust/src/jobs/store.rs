//! Job-state storage layouts behind one engine-facing API.
//!
//! The engine tracks per-job execution state (phase cursor, task states,
//! occupancy, completion times) keyed by dense job *slots* (see the
//! engine's `JobIndex`).  Two layouts implement the same contract:
//!
//! * [`JobLayout::Soa`] (default) — struct-of-arrays: hot per-job fields
//!   (remaining tasks, demand, phase cursor, occupancy, timestamps) live in
//!   parallel dense vectors indexed by slot, and all task states across all
//!   jobs share two flat arrays addressed through per-job offset tables.
//!   The per-event state machine then touches a handful of adjacent `u32`/
//!   `u64` lanes instead of walking `Vec<Vec<TaskRt>>` pointer forests, and
//!   cold data (the full [`JobSpec`] — name, platform, phase specs) sits in
//!   a side arena read only at init and metrics time.
//! * [`JobLayout::Aos`] — the original array-of-structs layout
//!   ([`JobRt`] records), kept as the reference path: the golden-
//!   determinism suite runs whole experiments on both layouts and requires
//!   bit-identical results.
//!
//! Every mutator mirrors `JobRt` semantics exactly (same scan orders, same
//! barrier rules), so layout choice can never change simulation output —
//! only memory traffic.  See docs/PERFORMANCE.md §"Memory layout &
//! batching".

use super::demand::Demand;
use super::job::{JobRt, TaskState};
use super::spec::{JobId, JobSpec};
use crate::cluster::ContainerId;
use crate::metrics::JobMetrics;
use crate::util::Time;

/// Which job-state layout the engine uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum JobLayout {
    /// Struct-of-arrays hot layout (default).
    #[default]
    Soa,
    /// Array-of-structs reference layout (the pre-SoA `JobRt` records).
    Aos,
}

/// Outcome of completing one task (see [`JobStore::finish_task`]).
#[derive(Debug, Clone, Copy)]
pub struct TaskFinish {
    /// When the completed attempt started running.
    pub start: Time,
    /// The phase cursor moved (a barrier was crossed).
    pub phase_advanced: bool,
    /// This completion finished the whole job (its finish time was set).
    pub finished_job: bool,
}

/// Sentinel for "timestamp not yet set" in the SoA timestamp lanes.
const NO_TIME: Time = Time::MAX;

/// Engine-facing job-state store; see the module docs for the layouts.
#[derive(Debug, Clone)]
pub enum JobStore {
    Aos(AosStore),
    Soa(SoaStore),
}

impl JobStore {
    pub fn new(specs: Vec<JobSpec>, layout: JobLayout) -> JobStore {
        match layout {
            JobLayout::Aos => JobStore::Aos(AosStore::new(specs)),
            JobLayout::Soa => JobStore::Soa(SoaStore::new(specs)),
        }
    }

    pub fn layout(&self) -> JobLayout {
        match self {
            JobStore::Aos(_) => JobLayout::Aos,
            JobStore::Soa(_) => JobLayout::Soa,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            JobStore::Aos(s) => s.jobs.len(),
            JobStore::Soa(s) => s.specs.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn id(&self, slot: usize) -> JobId {
        match self {
            JobStore::Aos(s) => s.jobs[slot].id(),
            JobStore::Soa(s) => s.specs[slot].id,
        }
    }

    /// Raw requested demand vector (axis 0 is the paper's `r_i`),
    /// unclamped — view construction clamps per axis.
    pub fn demand(&self, slot: usize) -> Demand {
        match self {
            JobStore::Aos(s) => s.jobs[slot].spec.demand,
            JobStore::Soa(s) => s.demand[slot],
        }
    }

    pub fn submit_ms(&self, slot: usize) -> Time {
        match self {
            JobStore::Aos(s) => s.jobs[slot].spec.submit_ms,
            JobStore::Soa(s) => s.submit_ms[slot],
        }
    }

    pub fn submitted(&self, slot: usize) -> bool {
        match self {
            JobStore::Aos(s) => s.jobs[slot].submitted,
            JobStore::Soa(s) => s.submitted[slot],
        }
    }

    pub fn mark_submitted(&mut self, slot: usize) {
        match self {
            JobStore::Aos(s) => s.jobs[slot].submitted = true,
            JobStore::Soa(s) => s.submitted[slot] = true,
        }
    }

    /// Undo a submission (federation withdraws a queued job to migrate it
    /// to another cell).  Task state is left as-is: a job returning to
    /// this cell resumes exactly where it stopped, and its first-start /
    /// finish timestamps stay attached to wherever it actually ran.
    pub fn mark_withdrawn(&mut self, slot: usize) {
        match self {
            JobStore::Aos(s) => s.jobs[slot].submitted = false,
            JobStore::Soa(s) => s.submitted[slot] = false,
        }
    }

    pub fn started(&self, slot: usize) -> bool {
        match self {
            JobStore::Aos(s) => s.jobs[slot].started(),
            JobStore::Soa(s) => s.first_start[slot] != NO_TIME,
        }
    }

    pub fn finished(&self, slot: usize) -> bool {
        match self {
            JobStore::Aos(s) => s.jobs[slot].finished(),
            JobStore::Soa(s) => s.finish[slot] != NO_TIME,
        }
    }

    pub fn occupied(&self, slot: usize) -> u32 {
        match self {
            JobStore::Aos(s) => s.jobs[slot].occupied,
            JobStore::Soa(s) => s.occupied[slot],
        }
    }

    /// Not-yet-Done tasks; 0 == job complete.
    pub fn remaining_tasks(&self, slot: usize) -> u32 {
        match self {
            JobStore::Aos(s) => s.remaining[slot],
            JobStore::Soa(s) => s.remaining[slot],
        }
    }

    /// Tasks of the current phase still waiting for a container — exactly
    /// [`JobRt::pending_tasks`] semantics under both layouts.
    pub fn pending_tasks(&self, slot: usize) -> u32 {
        match self {
            JobStore::Aos(s) => s.jobs[slot].pending_tasks(),
            JobStore::Soa(s) => s.pending_tasks(slot),
        }
    }

    /// Next pending task of the current phase, in task order.
    pub fn next_pending(&self, slot: usize) -> Option<(usize, usize)> {
        match self {
            JobStore::Aos(s) => s.jobs[slot].next_pending(),
            JobStore::Soa(s) => s.next_pending(slot),
        }
    }

    /// Pending -> Launching; the job now holds the container.
    pub fn begin_launch(&mut self, slot: usize, phase: usize, task: usize, cid: ContainerId) {
        match self {
            JobStore::Aos(s) => {
                s.jobs[slot].tasks[phase][task].state = TaskState::Launching(cid);
                s.jobs[slot].occupied += 1;
            }
            JobStore::Soa(s) => {
                let gi = s.task_index(slot, phase, task);
                debug_assert_eq!(s.task_state[gi], TaskState::Pending);
                s.task_state[gi] = TaskState::Launching(cid);
                s.occupied[slot] += 1;
            }
        }
    }

    /// Launching -> Running at `now`; sets the job's first-start timestamp
    /// if unset.  Returns the task's duration (for finish scheduling).
    pub fn begin_run(
        &mut self,
        slot: usize,
        phase: usize,
        task: usize,
        cid: ContainerId,
        now: Time,
    ) -> Time {
        match self {
            JobStore::Aos(s) => {
                let j = &mut s.jobs[slot];
                j.tasks[phase][task].state = TaskState::Running { container: cid, start: now };
                if j.first_start.is_none() {
                    j.first_start = Some(now);
                }
                j.tasks[phase][task].duration_ms
            }
            JobStore::Soa(s) => {
                let gi = s.task_index(slot, phase, task);
                s.task_state[gi] = TaskState::Running { container: cid, start: now };
                if s.first_start[slot] == NO_TIME {
                    s.first_start[slot] = now;
                }
                s.task_dur[gi]
            }
        }
    }

    /// Running -> Done at `now`: releases the container from the job,
    /// decrements the remaining-task counter, advances the phase cursor
    /// past completed barriers, and sets the job finish time when the last
    /// task lands.  Panics on a non-Running task (engine invariant).
    pub fn finish_task(&mut self, slot: usize, phase: usize, task: usize, now: Time) -> TaskFinish {
        match self {
            JobStore::Aos(s) => {
                let start = match s.jobs[slot].tasks[phase][task].state {
                    TaskState::Running { start, .. } => start,
                    other => panic!("finish of non-running task: {other:?}"),
                };
                s.jobs[slot].tasks[phase][task].state =
                    TaskState::Done { start, finish: now };
                s.jobs[slot].occupied -= 1;
                s.remaining[slot] -= 1;
                let before = s.jobs[slot].cur_phase;
                s.jobs[slot].advance_phase();
                let mut finished_job = false;
                if s.remaining[slot] == 0 {
                    debug_assert!(s.jobs[slot].all_done());
                    if s.jobs[slot].finish.is_none() {
                        s.jobs[slot].finish = Some(now);
                        finished_job = true;
                    }
                }
                TaskFinish {
                    start,
                    phase_advanced: s.jobs[slot].cur_phase != before,
                    finished_job,
                }
            }
            JobStore::Soa(s) => {
                let gi = s.task_index(slot, phase, task);
                let start = match s.task_state[gi] {
                    TaskState::Running { start, .. } => start,
                    other => panic!("finish of non-running task: {other:?}"),
                };
                s.task_state[gi] = TaskState::Done { start, finish: now };
                s.occupied[slot] -= 1;
                s.remaining[slot] -= 1;
                let before = s.cur_phase[slot];
                s.advance_phase(slot);
                let mut finished_job = false;
                if s.remaining[slot] == 0 {
                    debug_assert!(s.all_done(slot));
                    if s.finish[slot] == NO_TIME {
                        s.finish[slot] = now;
                        finished_job = true;
                    }
                }
                TaskFinish {
                    start,
                    phase_advanced: s.cur_phase[slot] != before,
                    finished_job,
                }
            }
        }
    }

    /// Kill an attempt (coin-flip failure or node crash): the task drops
    /// back to Pending for a fresh grant and the container is released from
    /// the job.  Returns the run start if the attempt was Running (crash
    /// accounting), `None` if it was still Launching.
    pub fn requeue_task(&mut self, slot: usize, phase: usize, task: usize) -> Option<Time> {
        match self {
            JobStore::Aos(s) => {
                let was = s.jobs[slot].tasks[phase][task].state;
                s.jobs[slot].tasks[phase][task].state = TaskState::Pending;
                s.jobs[slot].occupied -= 1;
                match was {
                    TaskState::Running { start, .. } => Some(start),
                    _ => None,
                }
            }
            JobStore::Soa(s) => {
                let gi = s.task_index(slot, phase, task);
                let was = s.task_state[gi];
                s.task_state[gi] = TaskState::Pending;
                s.occupied[slot] -= 1;
                match was {
                    TaskState::Running { start, .. } => Some(start),
                    _ => None,
                }
            }
        }
    }

    /// Final per-job metrics, in slot order.  Panics if any job never
    /// started or never finished (the engine asserts completion first).
    pub fn metrics(&self) -> Vec<JobMetrics> {
        match self {
            JobStore::Aos(s) => s.jobs.iter().map(JobMetrics::of).collect(),
            JobStore::Soa(s) => (0..s.specs.len()).map(|slot| s.metrics(slot)).collect(),
        }
    }

    /// Final metrics of one job (federation cells report only the jobs
    /// they finished).  Panics if the job never started or never finished.
    pub fn metrics_of(&self, slot: usize) -> JobMetrics {
        match self {
            JobStore::Aos(s) => JobMetrics::of(&s.jobs[slot]),
            JobStore::Soa(s) => s.metrics(slot),
        }
    }
}

/// Array-of-structs reference layout: one [`JobRt`] per slot plus the
/// remaining-task counters the indexed engine always kept.
#[derive(Debug, Clone)]
pub struct AosStore {
    jobs: Vec<JobRt>,
    remaining: Vec<u32>,
}

impl AosStore {
    fn new(specs: Vec<JobSpec>) -> AosStore {
        let remaining = specs.iter().map(|s| s.total_tasks()).collect();
        AosStore { jobs: specs.into_iter().map(JobRt::new).collect(), remaining }
    }
}

/// Struct-of-arrays hot layout; all vectors are slot-parallel except the
/// flat task lanes, which are addressed through `task_off`/`phase_off`.
#[derive(Debug, Clone)]
pub struct SoaStore {
    // Hot per-job lanes (slot-parallel).  The demand lane is the full
    // vector (8 bytes/slot) — axis 0 stays the grant currency.
    demand: Vec<Demand>,
    submit_ms: Vec<Time>,
    submitted: Vec<bool>,
    cur_phase: Vec<u32>,
    occupied: Vec<u32>,
    remaining: Vec<u32>,
    /// `NO_TIME` until the first task enters Running.
    first_start: Vec<Time>,
    /// `NO_TIME` until the last task completes.
    finish: Vec<Time>,
    // Flat task lanes shared by all jobs.
    task_state: Vec<TaskState>,
    task_dur: Vec<Time>,
    /// `n + 1` prefix offsets: job `slot`'s tasks occupy
    /// `task_off[slot]..task_off[slot + 1]` of the task lanes.
    task_off: Vec<u32>,
    /// `n + 1` prefix offsets into `phase_end`.
    phase_off: Vec<u32>,
    /// Per-phase *cumulative* task counts within each job: phase `p` of
    /// job `slot` covers local task indices
    /// `phase_end[phase_off[slot] + p - 1]..phase_end[phase_off[slot] + p]`
    /// (0-based lower bound for `p == 0`).
    phase_end: Vec<u32>,
    /// Cold side arena: full specs, read at init and metrics time only.
    specs: Vec<JobSpec>,
}

impl SoaStore {
    fn new(specs: Vec<JobSpec>) -> SoaStore {
        let n = specs.len();
        let mut task_off = Vec::with_capacity(n + 1);
        let mut phase_off = Vec::with_capacity(n + 1);
        let mut phase_end = Vec::new();
        let mut task_state = Vec::new();
        let mut task_dur = Vec::new();
        task_off.push(0u32);
        phase_off.push(0u32);
        for s in &specs {
            let mut cum = 0u32;
            for p in &s.phases {
                for t in &p.tasks {
                    task_state.push(TaskState::Pending);
                    task_dur.push(t.duration_ms);
                }
                cum += p.tasks.len() as u32;
                phase_end.push(cum);
            }
            task_off.push(task_state.len() as u32);
            phase_off.push(phase_end.len() as u32);
        }
        SoaStore {
            demand: specs.iter().map(|s| s.demand).collect(),
            submit_ms: specs.iter().map(|s| s.submit_ms).collect(),
            submitted: vec![false; n],
            cur_phase: vec![0; n],
            occupied: vec![0; n],
            remaining: specs.iter().map(|s| s.total_tasks()).collect(),
            first_start: vec![NO_TIME; n],
            finish: vec![NO_TIME; n],
            task_state,
            task_dur,
            task_off,
            phase_off,
            phase_end,
            specs,
        }
    }

    fn nphases(&self, slot: usize) -> usize {
        (self.phase_off[slot + 1] - self.phase_off[slot]) as usize
    }

    /// Global task-lane range of `phase` within `slot`.
    fn task_range(&self, slot: usize, phase: usize) -> (usize, usize) {
        let pbase = self.phase_off[slot] as usize;
        let tbase = self.task_off[slot] as usize;
        let lo = if phase == 0 { 0 } else { self.phase_end[pbase + phase - 1] as usize };
        let hi = self.phase_end[pbase + phase] as usize;
        (tbase + lo, tbase + hi)
    }

    fn task_index(&self, slot: usize, phase: usize, task: usize) -> usize {
        let (lo, hi) = self.task_range(slot, phase);
        debug_assert!(lo + task < hi, "task index out of phase range");
        lo + task
    }

    fn pending_tasks(&self, slot: usize) -> u32 {
        let cur = self.cur_phase[slot] as usize;
        if self.finish[slot] != NO_TIME || cur >= self.nphases(slot) {
            return 0;
        }
        let (lo, hi) = self.task_range(slot, cur);
        self.task_state[lo..hi]
            .iter()
            .filter(|&&t| t == TaskState::Pending)
            .count() as u32
    }

    fn next_pending(&self, slot: usize) -> Option<(usize, usize)> {
        let cur = self.cur_phase[slot] as usize;
        if cur >= self.nphases(slot) {
            return None;
        }
        let (lo, hi) = self.task_range(slot, cur);
        self.task_state[lo..hi]
            .iter()
            .position(|&t| t == TaskState::Pending)
            .map(|i| (cur, i))
    }

    fn phase_complete(&self, slot: usize, phase: usize) -> bool {
        let (lo, hi) = self.task_range(slot, phase);
        self.task_state[lo..hi]
            .iter()
            .all(|t| matches!(t, TaskState::Done { .. }))
    }

    fn advance_phase(&mut self, slot: usize) {
        while (self.cur_phase[slot] as usize) < self.nphases(slot)
            && self.phase_complete(slot, self.cur_phase[slot] as usize)
        {
            self.cur_phase[slot] += 1;
        }
    }

    fn all_done(&self, slot: usize) -> bool {
        let (lo, hi) = (self.task_off[slot] as usize, self.task_off[slot + 1] as usize);
        self.task_state[lo..hi]
            .iter()
            .all(|t| matches!(t, TaskState::Done { .. }))
    }

    fn metrics(&self, slot: usize) -> JobMetrics {
        assert!(self.first_start[slot] != NO_TIME, "job never started");
        assert!(self.finish[slot] != NO_TIME, "job never finished");
        let submit = self.submit_ms[slot];
        let waiting = self.first_start[slot].saturating_sub(submit);
        let completion = self.finish[slot].saturating_sub(submit);
        JobMetrics {
            id: self.specs[slot].id,
            demand: self.demand[slot].cpu,
            submit_ms: submit,
            waiting_ms: waiting,
            completion_ms: completion,
            execution_ms: completion - waiting,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobs::spec::{PhaseKind, PhaseSpec, Platform};

    fn spec(id: u32, phases: &[&[Time]]) -> JobSpec {
        JobSpec {
            id,
            name: format!("j{id}"),
            platform: Platform::MapReduce,
            submit_ms: id as Time * 1_000,
            demand: Demand::scalar(2),
            phases: phases
                .iter()
                .map(|durs| PhaseSpec::new(PhaseKind::Map, durs))
                .collect(),
        }
    }

    fn both() -> [JobStore; 2] {
        let specs = vec![spec(1, &[&[5_000, 6_000], &[4_000]]), spec(2, &[&[3_000]])];
        [
            JobStore::new(specs.clone(), JobLayout::Aos),
            JobStore::new(specs, JobLayout::Soa),
        ]
    }

    #[test]
    fn layouts_agree_on_initial_state() {
        for st in both() {
            let l = st.layout();
            assert_eq!(st.len(), 2, "{l:?}");
            assert_eq!(st.id(0), 1, "{l:?}");
            assert_eq!(st.demand(1), Demand::scalar(2), "{l:?}");
            assert_eq!(st.submit_ms(1), 2_000, "{l:?}");
            assert_eq!(st.pending_tasks(0), 2, "{l:?}");
            assert_eq!(st.remaining_tasks(0), 3, "{l:?}");
            assert_eq!(st.next_pending(0), Some((0, 0)), "{l:?}");
            assert!(!st.started(0) && !st.finished(0), "{l:?}");
        }
    }

    #[test]
    fn layouts_agree_on_full_lifecycle() {
        for mut st in both() {
            let l = st.layout();
            st.mark_submitted(0);
            // Launch + run both phase-0 tasks of job 0.
            st.begin_launch(0, 0, 0, 7);
            st.begin_launch(0, 0, 1, 8);
            assert_eq!(st.occupied(0), 2, "{l:?}");
            assert_eq!(st.pending_tasks(0), 0, "{l:?}");
            assert_eq!(st.begin_run(0, 0, 0, 7, 100), 5_000, "{l:?}");
            assert_eq!(st.begin_run(0, 0, 1, 8, 150), 6_000, "{l:?}");
            assert!(st.started(0), "{l:?}");
            // First finish: barrier not crossed yet.
            let f = st.finish_task(0, 0, 0, 5_100);
            assert_eq!(f.start, 100, "{l:?}");
            assert!(!f.phase_advanced && !f.finished_job, "{l:?}");
            assert_eq!(st.remaining_tasks(0), 2, "{l:?}");
            // Second finish crosses the barrier into phase 1.
            let f = st.finish_task(0, 0, 1, 6_150);
            assert!(f.phase_advanced && !f.finished_job, "{l:?}");
            assert_eq!(st.pending_tasks(0), 1, "{l:?}");
            assert_eq!(st.next_pending(0), Some((1, 0)), "{l:?}");
            // Phase 1: fail once (requeue), then complete.
            st.begin_launch(0, 1, 0, 9);
            assert_eq!(st.requeue_task(0, 1, 0), None, "{l:?}: killed while Launching");
            assert_eq!(st.pending_tasks(0), 1, "{l:?}");
            st.begin_launch(0, 1, 0, 10);
            st.begin_run(0, 1, 0, 10, 7_000);
            assert_eq!(st.requeue_task(0, 1, 0), Some(7_000), "{l:?}: killed while Running");
            st.begin_launch(0, 1, 0, 11);
            st.begin_run(0, 1, 0, 11, 8_000);
            let f = st.finish_task(0, 1, 0, 12_000);
            assert!(f.finished_job && f.phase_advanced, "{l:?}");
            assert!(st.finished(0), "{l:?}");
            assert_eq!(st.occupied(0), 0, "{l:?}");
            assert_eq!(st.pending_tasks(0), 0, "{l:?}");
        }
    }

    #[test]
    fn layouts_agree_on_metrics() {
        let mut results = Vec::new();
        for mut st in both() {
            for slot in 0..st.len() {
                st.mark_submitted(slot);
                while let Some((phase, task)) = st.next_pending(slot) {
                    st.begin_launch(slot, phase, task, 1);
                    let d = st.begin_run(slot, phase, task, 1, 10_000);
                    st.finish_task(slot, phase, task, 10_000 + d);
                }
            }
            results.push(st.metrics());
        }
        assert_eq!(results[0], results[1], "AoS and SoA metrics must agree");
    }
}
