//! Discrete-event simulation core: event queue (calendar or heap), engine,
//! pluggable trace + per-tick metric sinks, trace recording.

pub mod engine;
pub mod event;
pub mod fault;
pub mod metric;
pub mod sink;
pub mod trace;

pub use engine::{run_experiment, run_experiment_with, Engine, EngineOptions, RunResult};
pub use event::{Event, EventQueue, QueueKind};
pub use crate::jobs::JobLayout;
pub use fault::{FaultPlan, Outage, OutageRecord, StochasticFaults};
pub use metric::{MetricSink, MetricSinkKind};
pub use sink::{SinkKind, TraceSink};
pub use trace::{TaskTrace, TraceRecorder};
