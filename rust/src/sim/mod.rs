//! Discrete-event simulation core: event queue, engine, trace recording.

pub mod engine;
pub mod event;
pub mod trace;

pub use engine::{run_experiment, run_experiment_with, Engine, EngineOptions, RunResult};
pub use event::{Event, EventQueue};
pub use trace::{TaskTrace, TraceRecorder};
