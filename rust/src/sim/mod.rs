//! Discrete-event simulation core: the reusable [`cell::Cell`] (event
//! queue, scheduler, cluster, job store, fault plan, metric sinks), the
//! single-cell [`Engine`] wrapper, and the pluggable trace + per-tick
//! metric sinks.  `federation/` composes N cells on top of this module.

pub mod cell;
pub mod engine;
pub mod event;
pub mod fault;
pub mod metric;
pub mod sink;
pub mod trace;

pub use cell::{Cell, CellOutput};
pub use engine::{run_experiment, run_experiment_with, Engine, EngineOptions, RunResult};
pub use event::{Event, EventQueue, QueueKind};
pub use crate::jobs::JobLayout;
pub use fault::{CellOutageRecord, FaultPlan, Outage, OutageRecord, StochasticFaults};
pub use metric::{MetricSink, MetricSinkKind};
pub use sink::{SinkKind, TraceSink};
pub use trace::{TaskTrace, TraceRecorder};
