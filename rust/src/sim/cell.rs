//! The self-contained simulation cell — the discrete-event core extracted
//! from `sim/engine.rs` (see that module for the hot-path design notes).
//!
//! A [`Cell`] owns everything one cluster needs to simulate itself: event
//! queue, scheduler, cluster state, job store, fault plan, RNG stream, and
//! metric sinks.  Two driving modes share the exact same event loop:
//!
//! * **Engine mode** — `sim/engine.rs` wraps a single cell and drives
//!   [`Cell::step`] to completion, exactly as the pre-split engine did.
//!   The golden suite (tests/golden_determinism.rs and the federation
//!   goldens) proves the split bit-identical for all five schedulers,
//!   with and without fault plans and the δ tuner.
//! * **Federation mode** — `federation/` lock-steps N cells on a global
//!   clock via [`Cell::advance_to`], which processes every event up to a
//!   deadline and surfaces job completions, container releases, and
//!   heartbeat summaries as [`CellOutput`] data instead of terminal state.
//!
//! Federation support is strictly additive: the output buffer is only
//! populated when [`Cell::collect_outputs`] is armed, and the membership
//! APIs ([`Cell::accept`], [`Cell::withdraw_unfinished`],
//! [`Cell::fail_cell`]) are never called on a single-cell run, so the
//! wrapped engine's event sequence — and therefore its RNG stream — is
//! untouched by the refactor.

use super::engine::{EngineOptions, RunResult};
use super::event::{Event, EventQueue};
use super::fault::OutageRecord;
use super::metric::MetricSink;
use super::sink::TraceSink;
use super::trace::{TaskTrace, TraceRecorder};
use crate::cluster::{Cluster, ContainerState, HeartbeatLog, Transition};
use crate::config::ExperimentConfig;
use crate::jobs::{Demand, JobId, JobSpec, JobStore};
use crate::metrics::{DeltaSummary, JobMetrics, SystemMetrics, UtilSummary};
use crate::sched::shadow::{self, SchedSnapshot, ShadowEvent, ShadowWindow};
use crate::sched::{Allocation, ClusterView, JobView, Scheduler};
use crate::util::rng::Rng;
use crate::util::Time;

/// Observable output of one cell, surfaced by [`Cell::advance_to`] so a
/// federation can react to completions without reaching into cell state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellOutput {
    /// A job completed its last task.
    JobDone { job: JobId, at: Time },
    /// A container was released back to the cell (task completed or
    /// coin-flip failed; crash kills release nothing — the node vanished).
    Release { job: JobId, at: Time },
    /// One scheduler heartbeat executed.
    Heartbeat { at: Time, used: u32, free: u32, active_jobs: u32 },
}

/// O(1) `JobId -> slot` lookup.  Job ids in this system are small
/// sequential integers, so a dense table is the common case; a sorted
/// pair list covers pathologically sparse id spaces without blowing up
/// memory.
#[derive(Debug)]
enum JobIndex {
    Dense(Vec<u32>),
    Sorted(Vec<(u32, u32)>),
}

impl JobIndex {
    fn build(specs: &[JobSpec]) -> Self {
        let max_id = specs.iter().map(|s| s.id).max().unwrap_or(0) as usize;
        if max_id <= 8 * specs.len() + 1024 {
            let mut dense = vec![u32::MAX; max_id + 1];
            for (slot, s) in specs.iter().enumerate() {
                assert_eq!(dense[s.id as usize], u32::MAX, "duplicate job id {}", s.id);
                dense[s.id as usize] = slot as u32;
            }
            JobIndex::Dense(dense)
        } else {
            let mut pairs: Vec<(u32, u32)> = specs
                .iter()
                .enumerate()
                .map(|(slot, s)| (s.id, slot as u32))
                .collect();
            pairs.sort_unstable();
            for w in pairs.windows(2) {
                assert_ne!(w[0].0, w[1].0, "duplicate job id {}", w[0].0);
            }
            JobIndex::Sorted(pairs)
        }
    }

    fn lookup(&self, id: u32) -> usize {
        let slot = match self {
            JobIndex::Dense(v) => v.get(id as usize).copied().unwrap_or(u32::MAX),
            JobIndex::Sorted(v) => v
                .binary_search_by_key(&id, |&(i, _)| i)
                .map(|i| v[i].1)
                .unwrap_or(u32::MAX),
        };
        if slot == u32::MAX {
            panic!("unknown job {id}");
        }
        slot as usize
    }
}

/// Cell-side state of one planned node outage.
#[derive(Debug)]
struct OutageState {
    rec: OutageRecord,
    /// Whether the crash event has fired (outages scheduled past the end
    /// of the run never do and are excluded from results).
    fired: bool,
    /// When the node came back up (None while still down).
    node_back_at: Option<Time>,
    /// Killed tasks `(job slot, phase, task)` not yet re-completed.
    waiting: Vec<(usize, usize, usize)>,
}

/// One self-contained simulation cell. Owns everything for one cluster.
pub struct Cell {
    cfg: ExperimentConfig,
    cluster: Cluster,
    /// Per-job execution state, SoA or AoS per `opts.jobs`.
    store: JobStore,
    queue: EventQueue,
    heartbeats: HeartbeatLog,
    sched: Box<dyn Scheduler>,
    rng: Rng,
    now: Time,
    sink: TraceSink,
    /// Per-tick utilization retention (policy: `opts.metrics`).
    util_sink: MetricSink<u32>,
    /// Per-tick δ retention (schedulers without a reserve ratio yield no
    /// samples).
    delta_sink: MetricSink<f64>,
    /// Exact online utilization accumulator — fed on every tick
    /// regardless of sink policy.
    util_accum: UtilSummary,
    /// Exact online δ accumulator.
    delta_accum: DeltaSummary,
    failures: u32,
    /// Provisioned capacity (crash-independent), for demand clamping:
    /// a transient outage must not permanently truncate a job's request.
    nominal_total: u32,
    /// Materialized fault plan, indexed by `Event::NodeFail/NodeRecover`
    /// payloads.
    outages: Vec<OutageState>,
    /// Outages that have crashed but not fully healed — gates the
    /// per-finish recovery bookkeeping so an empty plan pays nothing.
    open_outages: usize,
    lost_attempts: u32,
    lost_work_ms: Time,
    useful_work_ms: Time,
    wasted_work_ms: Time,
    /// Safety valve against pathological schedules.
    max_ms: Time,
    opts: EngineOptions,
    /// JobId -> slot in the store (replaces the seed's linear scan).
    index: JobIndex,
    /// Jobs this cell is responsible for completing.  Equal to the store
    /// length for single-cell runs; a federation assigns a subset and may
    /// move membership at runtime ([`Self::accept`] / withdraw).
    assigned: usize,
    /// Jobs with `finish` set (replaces the seed's all-jobs scan).
    finished_jobs: usize,
    /// Submitted-and-unfinished jobs currently resident in this cell.
    submitted_active: usize,
    /// Whether a SchedTick is queued or self-rechaining.  Only consulted
    /// by [`Self::accept`] to revive the heartbeat chain after the cell
    /// drained; inert bookkeeping for single-cell runs.
    tick_armed: bool,
    /// Populate the [`CellOutput`] buffer (federation mode only).
    collect: bool,
    outputs: Vec<CellOutput>,
    /// Incrementally-maintained scheduler view: submitted jobs in
    /// submission order.  Completion tombstones the entry (`finished =
    /// true`, exactly what the seed exposed; schedulers filter) and the
    /// vector is compacted once tombstones outnumber live entries, so
    /// retirement is O(1) amortized instead of an O(active) `Vec::remove`.
    view_jobs: Vec<JobView>,
    /// Slot of each `view_jobs` entry (parallel vector).
    view_slots: Vec<usize>,
    /// slot -> position in `view_jobs` (usize::MAX when absent/retired).
    view_pos: Vec<usize>,
    /// Tombstoned (finished but not yet compacted) entries in `view_jobs`.
    view_tombstones: usize,
    events: u64,
    ticks: u64,
    /// Debug-build view cross-check cadence in ticks (1 = every tick).
    #[cfg(debug_assertions)]
    view_check_every: u64,
    #[cfg(debug_assertions)]
    ticks_since_check: u64,
}

impl Cell {
    /// Build a cell owning every job in `specs` — the single-cell engine
    /// configuration.
    pub fn with_options(
        cfg: ExperimentConfig,
        specs: Vec<JobSpec>,
        sched: Box<dyn Scheduler>,
        opts: EngineOptions,
    ) -> Self {
        Cell::with_assignment(cfg, specs, None, sched, opts)
    }

    /// Build a cell that knows every spec but only *owns* the jobs whose
    /// mask entry is true (None = all).  Unowned jobs get no submit event
    /// and never surface in the scheduler view; a federation routes them
    /// to other cells and may later [`Self::accept`] them here.
    pub fn with_assignment(
        cfg: ExperimentConfig,
        specs: Vec<JobSpec>,
        assigned: Option<&[bool]>,
        mut sched: Box<dyn Scheduler>,
        opts: EngineOptions,
    ) -> Self {
        // Arm the opt-in shadow tuner before the first heartbeat; with the
        // flag off this is a no-op for every scheduler (default trait impl)
        // and the run stays bit-identical (tests/golden_determinism.rs).
        sched.set_tune_delta(opts.tune_delta);
        sched.set_tune_params(opts.tune_every, opts.shadow_window);
        if let Some(mask) = assigned {
            assert_eq!(mask.len(), specs.len(), "assignment mask length");
        }
        for s in &specs {
            s.validate().unwrap_or_else(|e| panic!("invalid job spec: {e}"));
        }
        let cluster = Cluster::new(cfg.cluster.nodes, cfg.cluster.slots_per_node);
        let seed = cfg.workload.seed ^ 0xD8E5_5000;
        let mut queue = EventQueue::with_kind(opts.queue);
        let mut owned = 0usize;
        for (slot, s) in specs.iter().enumerate() {
            if assigned.is_none_or(|m| m[slot]) {
                queue.push(s.submit_ms, Event::JobSubmit(s.id));
                owned += 1;
            }
        }
        queue.push(0, Event::SchedTick);
        // Fault events go in last so an empty plan leaves the sequence
        // numbers of every pre-existing event untouched (bit-identity).
        // Stochastic draws use the dedicated fault stream, never `rng`.
        let planned = cfg
            .faults
            .materialize(cfg.cluster.nodes, cfg.workload.seed)
            .unwrap_or_else(|e| panic!("invalid fault plan: {e}"));
        let mut outages = Vec::with_capacity(planned.len());
        for (i, o) in planned.iter().enumerate() {
            queue.push(o.at_ms, Event::NodeFail(i as u32));
            queue.push(o.at_ms + o.down_ms, Event::NodeRecover(i as u32));
            outages.push(OutageState {
                rec: OutageRecord {
                    node: o.node,
                    at_ms: o.at_ms,
                    down_ms: o.down_ms,
                    killed: 0,
                    lost_work_ms: 0,
                    recovered_at: None,
                },
                fired: false,
                node_back_at: None,
                waiting: Vec::new(),
            });
        }
        let index = JobIndex::build(&specs);
        let n = specs.len();
        let total = cluster.total();
        // Debug-build view-check cadence: every tick for test-sized runs
        // (the historical behavior the small goldens exercise), sampled at
        // 64 for big scenarios so debug `cargo test` survives 100k-job
        // horizons.  `DRESS_VIEW_CHECK_EVERY` overrides either default.
        #[cfg(debug_assertions)]
        let view_check_every = match std::env::var("DRESS_VIEW_CHECK_EVERY")
            .ok()
            .and_then(|s| s.parse::<u64>().ok())
        {
            Some(k) => k.max(1),
            None if n <= 1_024 => 1,
            None => 64,
        };
        Cell {
            cfg,
            cluster,
            store: JobStore::new(specs, opts.jobs),
            queue,
            heartbeats: HeartbeatLog::with_retention(opts.trace),
            sched,
            rng: Rng::new(seed),
            now: 0,
            sink: TraceSink::new(opts.trace),
            util_sink: MetricSink::new(opts.metrics),
            delta_sink: MetricSink::new(opts.metrics),
            util_accum: UtilSummary::new(total),
            delta_accum: DeltaSummary::default(),
            failures: 0,
            nominal_total: total,
            outages,
            open_outages: 0,
            lost_attempts: 0,
            lost_work_ms: 0,
            useful_work_ms: 0,
            wasted_work_ms: 0,
            max_ms: 40 * 3_600 * 1_000, // 40 simulated hours
            opts,
            index,
            assigned: owned,
            finished_jobs: 0,
            submitted_active: 0,
            tick_armed: true,
            collect: false,
            outputs: Vec::new(),
            view_jobs: Vec::new(),
            view_slots: Vec::new(),
            view_pos: vec![usize::MAX; n],
            view_tombstones: 0,
            events: 0,
            ticks: 0,
            #[cfg(debug_assertions)]
            view_check_every,
            #[cfg(debug_assertions)]
            ticks_since_check: 0,
        }
    }

    /// Arm (or disarm) the [`CellOutput`] buffer.  Off by default so the
    /// single-cell engine never pays the push.
    pub fn collect_outputs(&mut self, on: bool) {
        self.collect = on;
    }

    fn job_index(&self, id: u32) -> usize {
        self.index.lookup(id)
    }

    /// Every job this cell owns has finished.
    pub fn all_finished(&self) -> bool {
        self.finished_jobs == self.assigned
    }

    /// Current simulated time (last processed event).
    pub fn now(&self) -> Time {
        self.now
    }

    /// Containers currently busy.
    pub fn used(&self) -> u32 {
        self.cluster.used()
    }

    /// Provisioned (crash-independent) container capacity.
    pub fn nominal_total(&self) -> u32 {
        self.nominal_total
    }

    /// Provisioned capacity as a demand vector (router reference).
    pub fn capacity(&self) -> Demand {
        Demand::new(self.nominal_total, self.cluster.nominal_total_mem())
    }

    /// Submitted-and-unfinished jobs resident in this cell.
    pub fn active_jobs(&self) -> u32 {
        self.submitted_active as u32
    }

    /// Active jobs holding zero containers — the cell's pending queue,
    /// the imbalance signal federations migrate on.
    pub fn queued_jobs(&self) -> u32 {
        self.view_jobs
            .iter()
            .filter(|v| !v.finished && v.occupied == 0)
            .count() as u32
    }

    // --- incremental view maintenance -----------------------------------

    /// A job's demand as the cell honors it.  Two clamps, both no-ops
    /// for uniform (scalar) demands:
    ///
    /// * per axis to the *nominal* cluster totals — a demand above cluster
    ///   capacity can never gang-start, and nominal (not live) capacity
    ///   means a transient outage does not truncate the request forever;
    /// * on the memory axis to `cpu × max_node_mem` — a per-container
    ///   footprint wider than the fattest node fits nowhere, so an
    ///   unclamped value would starve the job (and hang the run).
    fn effective_demand(&self, slot: usize) -> Demand {
        let d = self.store.demand(slot).min_each(Demand::new(
            self.nominal_total,
            self.cluster.nominal_total_mem(),
        ));
        let fit = d.cpu.max(1).saturating_mul(self.cluster.max_node_mem().max(1));
        Demand::new(d.cpu, d.mem.min(fit))
    }

    /// Admit `slot` into the scheduler view at its submission-order
    /// position.  Submissions arrive in event-time order, which for every
    /// workload in this repo is also slot order, so the common case is an
    /// O(1) push; an out-of-order submit time falls back to a sorted
    /// insert.
    fn view_insert(&mut self, slot: usize) {
        let jv = JobView {
            id: self.store.id(slot),
            demand: self.effective_demand(slot),
            submit_ms: self.store.submit_ms(slot),
            started: self.store.started(slot),
            finished: false,
            pending_tasks: self.store.pending_tasks(slot),
            occupied: self.store.occupied(slot),
        };
        if self.view_slots.last().is_none_or(|&s| s < slot) {
            self.view_pos[slot] = self.view_jobs.len();
            self.view_jobs.push(jv);
            self.view_slots.push(slot);
            return;
        }
        let pos = self.view_slots.partition_point(|&s| s < slot);
        self.view_jobs.insert(pos, jv);
        self.view_slots.insert(pos, slot);
        for &s in &self.view_slots[pos + 1..] {
            if self.view_pos[s] != usize::MAX {
                self.view_pos[s] += 1;
            }
        }
        self.view_pos[slot] = pos;
    }

    /// Retire a completed (or withdrawn) job from the view: tombstone the
    /// entry (`finished = true` — the seed exposed exactly this and every
    /// scheduler filters it) and compact once tombstones outnumber live
    /// entries, so retirement is O(1) amortized.
    fn view_retire(&mut self, slot: usize) {
        let pos = self.view_pos[slot];
        debug_assert_ne!(pos, usize::MAX, "retire of job not in view");
        self.view_jobs[pos].finished = true;
        self.view_pos[slot] = usize::MAX;
        self.view_tombstones += 1;
        if self.view_tombstones * 2 > self.view_jobs.len() {
            self.view_compact();
        }
    }

    /// Drop tombstoned entries, preserving order (O(len), amortized O(1)
    /// per retirement by the doubling rule in [`Self::view_retire`]).
    fn view_compact(&mut self) {
        let mut w = 0;
        for r in 0..self.view_jobs.len() {
            if !self.view_jobs[r].finished {
                let slot = self.view_slots[r];
                self.view_jobs[w] = self.view_jobs[r];
                self.view_slots[w] = slot;
                self.view_pos[slot] = w;
                w += 1;
            }
        }
        self.view_jobs.truncate(w);
        self.view_slots.truncate(w);
        self.view_tombstones = 0;
    }

    /// The view entry of an active job (O(1)).
    fn view_entry(&mut self, slot: usize) -> &mut JobView {
        let pos = self.view_pos[slot];
        debug_assert_ne!(pos, usize::MAX, "view entry of inactive job");
        &mut self.view_jobs[pos]
    }

    /// Seed-identical per-tick view rebuild: every submitted job, finished
    /// ones included with `finished = true` (schedulers filter them).
    /// Reference path for `EngineOptions::naive_hot_path`.
    fn naive_view_jobs(&self) -> Vec<JobView> {
        (0..self.store.len())
            .filter(|&slot| self.store.submitted(slot))
            .map(|slot| JobView {
                id: self.store.id(slot),
                demand: self.effective_demand(slot),
                submit_ms: self.store.submit_ms(slot),
                started: self.store.started(slot),
                finished: self.store.finished(slot),
                pending_tasks: self.store.pending_tasks(slot),
                occupied: self.store.occupied(slot),
            })
            .collect()
    }

    /// Debug-build cross-check: the incremental view must equal ground
    /// truth derived from the job store (runs every
    /// `view_check_every`-th tick under `cargo test`, so the whole suite
    /// exercises the equivalence).
    #[cfg(debug_assertions)]
    fn assert_view_consistent(&self) {
        let mut live = 0;
        for slot in 0..self.store.len() {
            let id = self.store.id(slot);
            if self.store.submitted(slot) && !self.store.finished(slot) {
                let pos = self.view_pos[slot];
                assert_ne!(pos, usize::MAX, "active job {id} missing from view");
                let v = &self.view_jobs[pos];
                assert_eq!(v.id, id);
                assert!(!v.finished, "J{id} live entry tombstoned");
                assert_eq!(v.started, self.store.started(slot), "J{id} started drift");
                assert_eq!(
                    v.pending_tasks,
                    self.store.pending_tasks(slot),
                    "J{id} pending drift"
                );
                assert_eq!(v.occupied, self.store.occupied(slot), "J{id} occupied drift");
                live += 1;
            } else {
                assert_eq!(self.view_pos[slot], usize::MAX, "inactive job indexed in view");
            }
        }
        assert_eq!(self.view_jobs.iter().filter(|v| !v.finished).count(), live);
        assert_eq!(
            self.view_jobs.iter().filter(|v| v.finished).count(),
            self.view_tombstones
        );
    }

    // --- event handlers --------------------------------------------------

    /// Apply one feasible allocation: create containers in the YARN state
    /// machine for up to `n` pending tasks of the job.
    fn apply_allocation(&mut self, alloc: Allocation) {
        let ji = self.job_index(alloc.job);
        let mem = self.effective_demand(ji).mem_per_container().max(1);
        for _ in 0..alloc.n {
            if self.cluster.free() == 0 {
                break;
            }
            let Some((phase, task)) = self.store.next_pending(ji) else {
                break;
            };
            // With vector demands a slot-feasible grant can still fail
            // node-level memory packing (fragmentation); for uniform
            // demands `mem == 1` and free slots always admit, as before.
            let Some(cid) = self.cluster.allocate(alloc.job, phase, task, mem, self.now)
            else {
                break;
            };
            self.store.begin_launch(ji, phase, task, cid);
            let v = self.view_entry(ji);
            v.occupied += 1;
            v.pending_tasks -= 1;
            self.record_transition(cid, ContainerState::New);
            self.schedule_advance(cid);
        }
    }

    fn record_transition(&mut self, cid: u32, to: ContainerState) {
        let c = self.cluster.container(cid);
        self.heartbeats.record(Transition {
            time: self.now,
            container: cid,
            job: c.job,
            task: c.task,
            to,
        });
    }

    /// Sample the delay for the container's next state hop and enqueue it.
    fn schedule_advance(&mut self, cid: u32) {
        let state = self.cluster.container(cid).state;
        let d = &self.cfg.cluster.delays;
        let median = match state {
            ContainerState::New => d.new_to_reserved_ms,
            ContainerState::Reserved => d.reserved_to_allocated_ms,
            ContainerState::Allocated => d.allocated_to_acquired_ms,
            ContainerState::Acquired => d.acquired_to_running_ms,
            _ => return,
        };
        let delay = self.rng.lognormal(median, d.sigma).max(1.0) as Time;
        self.queue.push(self.now + delay, Event::ContainerAdvance(cid));
    }

    fn on_container_advance(&mut self, cid: u32) {
        // The queue cannot remove entries, so events for containers killed
        // by a node crash still fire — and must be ignored.
        if self.cluster.container(cid).dead {
            return;
        }
        let new_state = self.cluster.container_mut(cid).advance(self.now);
        self.record_transition(cid, new_state);
        let (job, phase, task) = {
            let c = self.cluster.container(cid);
            (c.job, c.phase, c.task)
        };
        if new_state == ContainerState::Running {
            let ji = self.job_index(job);
            let dur = self.store.begin_run(ji, phase, task, cid, self.now);
            self.view_entry(ji).started = true;
            // Failure injection: the container may die mid-task; the task
            // is then re-attempted in a fresh container (YARN AM behavior).
            let pf = self.cfg.cluster.task_failure_prob;
            if pf > 0.0 && self.rng.chance(pf) {
                let at = self.now + (dur as f64 * self.rng.range_f64(0.1, 0.9)) as Time;
                self.queue.push(at.max(self.now + 1), Event::TaskFail(cid));
            } else {
                self.queue.push(self.now + dur, Event::TaskFinish(cid));
            }
        } else {
            self.schedule_advance(cid);
        }
    }

    fn on_task_finish(&mut self, cid: u32) {
        if self.cluster.container(cid).dead {
            return;
        }
        let new_state = self.cluster.container_mut(cid).advance(self.now);
        debug_assert_eq!(new_state, ContainerState::Completed);
        self.record_transition(cid, ContainerState::Completed);
        let (job, phase, task, run_start) = {
            let c = self.cluster.container(cid);
            (c.job, c.phase, c.task, c.run_start)
        };
        self.cluster.release(cid);

        let ji = self.job_index(job);
        let fin = self.store.finish_task(ji, phase, task, self.now);
        debug_assert_eq!(fin.start, run_start);
        self.view_entry(ji).occupied -= 1;
        self.useful_work_ms += self.now - fin.start;
        if self.open_outages > 0 {
            self.note_recompletion(ji, phase, task);
        }
        self.sink.record(TaskTrace {
            job,
            phase,
            task,
            granted: run_start, // grant time folded into startup elsewhere
            start: fin.start,
            finish: self.now,
        });
        if self.collect {
            self.outputs.push(CellOutput::Release { job, at: self.now });
        }
        if fin.finished_job {
            self.finished_jobs += 1;
            self.submitted_active -= 1;
            self.view_retire(ji);
            if self.collect {
                self.outputs.push(CellOutput::JobDone { job, at: self.now });
            }
        } else if fin.phase_advanced {
            // Barrier crossed: the newly-runnable phase is all-Pending.
            let pending = self.store.pending_tasks(ji);
            self.view_entry(ji).pending_tasks = pending;
        }
    }

    /// Container dies mid-task: release the slot, reset the task to
    /// Pending so the scheduler re-grants it.
    fn on_task_fail(&mut self, cid: u32) {
        if self.cluster.container(cid).dead {
            return;
        }
        let new_state = self.cluster.container_mut(cid).advance(self.now);
        debug_assert_eq!(new_state, ContainerState::Completed);
        self.record_transition(cid, ContainerState::Completed);
        let (job, phase, task, run_start) = {
            let c = self.cluster.container(cid);
            (c.job, c.phase, c.task, c.run_start)
        };
        self.cluster.release(cid);
        self.wasted_work_ms += self.now - run_start;
        let ji = self.job_index(job);
        let was_running = self.store.requeue_task(ji, phase, task);
        debug_assert!(was_running.is_some(), "coin-flip fail of non-running task");
        let v = self.view_entry(ji);
        v.occupied -= 1;
        v.pending_tasks += 1;
        self.failures += 1;
        if self.collect {
            self.outputs.push(CellOutput::Release { job, at: self.now });
        }
    }

    /// A node crashes: its capacity leaves `total`, every container on it
    /// dies, and the killed tasks requeue as Pending (with their accrued
    /// run-time counted as lost).  No Completed heartbeat transition is
    /// recorded for killed containers — the node vanished, it did not
    /// report.
    fn on_node_fail(&mut self, oidx: u32) {
        let oidx = oidx as usize;
        let node = self.outages[oidx].rec.node;
        let killed = self.cluster.fail_node(node, self.now);
        let mut lost: Time = 0;
        for &cid in &killed {
            let (job, phase, task) = {
                let c = self.cluster.container(cid);
                (c.job, c.phase, c.task)
            };
            let ji = self.job_index(job);
            if let Some(start) = self.store.requeue_task(ji, phase, task) {
                lost += self.now - start;
            }
            let v = self.view_entry(ji);
            v.occupied -= 1;
            v.pending_tasks += 1;
            self.outages[oidx].waiting.push((ji, phase, task));
        }
        self.lost_attempts += killed.len() as u32;
        self.lost_work_ms += lost;
        self.wasted_work_ms += lost;
        let o = &mut self.outages[oidx];
        o.fired = true;
        o.rec.killed = killed.len() as u32;
        o.rec.lost_work_ms = lost;
        self.open_outages += 1;
    }

    /// The node comes back: its (empty) slots rejoin capacity.  The outage
    /// is healed once the node is up AND every task it killed re-completed.
    fn on_node_recover(&mut self, oidx: u32) {
        let oidx = oidx as usize;
        let node = self.outages[oidx].rec.node;
        self.cluster.recover_node(node);
        let o = &mut self.outages[oidx];
        o.node_back_at = Some(self.now);
        if o.waiting.is_empty() && o.rec.recovered_at.is_none() {
            o.rec.recovered_at = Some(self.now);
            self.open_outages -= 1;
        }
    }

    /// A task just completed; clear it from every open outage still
    /// waiting on it (a task can appear in several if re-killed).  Only
    /// called while an outage is open, so the empty-plan fast path never
    /// touches this.
    fn note_recompletion(&mut self, ji: usize, phase: usize, task: usize) {
        let now = self.now;
        let mut healed = 0;
        for o in self.outages.iter_mut() {
            if !o.fired || o.rec.recovered_at.is_some() {
                continue;
            }
            if let Some(p) = o.waiting.iter().position(|&w| w == (ji, phase, task)) {
                o.waiting.swap_remove(p);
                if o.waiting.is_empty() && o.node_back_at.is_some() {
                    o.rec.recovered_at = Some(now);
                    healed += 1;
                }
            }
        }
        self.open_outages -= healed;
    }

    fn on_sched_tick(&mut self) {
        self.ticks += 1;
        let transitions = self.heartbeats.drain();
        #[cfg(debug_assertions)]
        {
            self.ticks_since_check += 1;
            if self.ticks_since_check >= self.view_check_every {
                self.ticks_since_check = 0;
                self.assert_view_consistent();
            }
        }
        // Indexed path: borrow the maintained active-job slice — O(1).
        // Naive path: rebuild from scratch like the seed engine did.
        let scratch: Vec<JobView>;
        let view_jobs: &[JobView] = if self.opts.naive_hot_path {
            scratch = self.naive_view_jobs();
            &scratch
        } else {
            &self.view_jobs
        };
        let view = ClusterView {
            now: self.now,
            free: self.cluster.free(),
            total: self.cluster.total(),
            free_mem: self.cluster.free_mem(),
            total_mem: self.cluster.total_mem(),
            jobs: view_jobs,
            transitions: &transitions,
        };
        let allocs = self.sched.schedule(&view);
        // Feasibility enforcement: total grants bounded by free capacity
        // on every axis (the memory clamp is a no-op for uniform demands,
        // where footprint is 1 and free_mem tracks free exactly).
        let mut free = self.cluster.free();
        let mut free_mem = self.cluster.free_mem();
        for a in allocs {
            let ji = self.job_index(a.job);
            let pending = self.store.pending_tasks(ji);
            let mem = self.effective_demand(ji).mem_per_container().max(1);
            let n = a.n.min(pending).min(free).min(free_mem / mem);
            if n == 0 {
                continue;
            }
            free -= n;
            free_mem -= n * mem;
            self.apply_allocation(Allocation { job: a.job, n });
        }
        let used = self.cluster.used();
        self.util_sink.record(self.now, used);
        self.util_accum.push(self.now, used);
        if let Some(delta) = self.sched.reserve_ratio() {
            self.delta_sink.record(self.now, delta);
            self.delta_accum.push(self.now, delta);
        }
        if self.collect {
            self.outputs.push(CellOutput::Heartbeat {
                at: self.now,
                used,
                free: self.cluster.free(),
                active_jobs: self.submitted_active as u32,
            });
        }
        debug_assert!(self.cluster.conservation_holds());
        if !self.all_finished() {
            self.queue
                .push(self.now + self.cfg.cluster.hb_ms, Event::SchedTick);
            self.tick_armed = true;
        } else {
            self.tick_armed = false;
        }
    }

    /// Advance the simulation by exactly one event.  Returns `false` once
    /// the run is over (every owned job finished, or the queue drained).
    ///
    /// `Engine::run()` is just `while self.step() {}` + [`Self::finish`];
    /// the stepping form exists so tests can interleave read-only
    /// [`Self::probe`]s with live execution and fingerprint the state
    /// between events (tests/properties.rs probe-purity property).
    pub fn step(&mut self) -> bool {
        if self.all_finished() {
            return false;
        }
        let Some((t, ev)) = self.queue.pop() else {
            return false;
        };
        assert!(t >= self.now, "time went backwards");
        self.now = t;
        if self.now > self.max_ms {
            panic!("simulation exceeded {} ms — livelocked schedule?", self.max_ms);
        }
        self.events += 1;
        match ev {
            Event::JobSubmit(id) => {
                let ji = self.job_index(id);
                self.store.mark_submitted(ji);
                self.submitted_active += 1;
                self.view_insert(ji);
            }
            Event::SchedTick => self.on_sched_tick(),
            Event::ContainerAdvance(cid) => self.on_container_advance(cid),
            Event::TaskFinish(cid) => self.on_task_finish(cid),
            Event::TaskFail(cid) => self.on_task_fail(cid),
            Event::NodeFail(o) => self.on_node_fail(o),
            Event::NodeRecover(o) => self.on_node_recover(o),
            // Reservation timeouts live in the admission layer's private
            // queue (live/admission.rs), never in the cell's; the arm
            // exists only for exhaustiveness and is inert by design.
            Event::ReservationExpire(_) => {}
        }
        !self.all_finished()
    }

    /// Process every queued event with `time <= t`, stopping early when
    /// all owned jobs are done, and drain the [`CellOutput`] buffer.
    /// Completion stops the heartbeat chain exactly as in engine mode, so
    /// chunked driving (`advance_to(hb)`, `advance_to(2·hb)`, …) pops the
    /// identical event sequence `Engine::run` does — the federation
    /// goldens pin this.
    pub fn advance_to(&mut self, t: Time) -> Vec<CellOutput> {
        while !self.all_finished() {
            match self.queue.peek_time() {
                Some(next) if next <= t => {
                    self.step();
                }
                _ => break,
            }
        }
        std::mem::take(&mut self.outputs)
    }

    // --- federation membership -------------------------------------------

    /// Hand this cell a job it does not currently own (migration /
    /// salvage).  The job surfaces through a normal `JobSubmit` event at
    /// `at`, so schedulers observe an ordinary arrival; its original
    /// `submit_ms` keeps feeding waiting-time metrics, so migration never
    /// erases queueing history.  Revives the heartbeat chain if this cell
    /// had drained.
    pub fn accept(&mut self, id: JobId, at: Time) {
        let slot = self.job_index(id);
        assert!(at >= self.now, "accept in the past");
        assert!(
            !self.store.submitted(slot) && !self.store.finished(slot),
            "accept of a job this cell already holds"
        );
        self.assigned += 1;
        self.queue.push(at, Event::JobSubmit(id));
        if !self.tick_armed {
            let hb = self.cfg.cluster.hb_ms;
            let next_tick = at.div_ceil(hb) * hb;
            self.queue.push(next_tick.max(at), Event::SchedTick);
            self.tick_armed = true;
        }
    }

    /// Withdraw one cold queued job (never started, zero containers) for
    /// threshold migration — the youngest first, so long-waiting jobs keep
    /// their place.  Returns `None` when nothing is migratable.
    pub fn withdraw_one_queued(&mut self) -> Option<JobId> {
        for pos in (0..self.view_jobs.len()).rev() {
            let v = &self.view_jobs[pos];
            if !v.finished && !v.started && v.occupied == 0 {
                let slot = self.view_slots[pos];
                let id = self.store.id(slot);
                self.withdraw_slot(slot);
                return Some(id);
            }
        }
        None
    }

    /// Withdraw every submitted-but-unfinished job (cell-death salvage).
    /// Callers must have killed the cell's containers first
    /// ([`Self::fail_cell`]) — withdrawing a job holding containers is a
    /// logic error.  Jobs routed here whose submit event has not fired yet
    /// stay owned: they arrive during the outage and wait it out, exactly
    /// like jobs submitted to a down YARN cluster.
    pub fn withdraw_unfinished(&mut self) -> Vec<JobId> {
        let mut out = Vec::new();
        for slot in 0..self.store.len() {
            if self.store.submitted(slot) && !self.store.finished(slot) {
                assert_eq!(self.store.occupied(slot), 0, "withdraw of a running job");
                out.push(self.store.id(slot));
                self.withdraw_slot(slot);
            }
        }
        out
    }

    fn withdraw_slot(&mut self, slot: usize) {
        debug_assert!(self.store.submitted(slot) && !self.store.finished(slot));
        debug_assert_eq!(self.store.occupied(slot), 0);
        self.store.mark_withdrawn(slot);
        self.view_retire(slot);
        self.submitted_active -= 1;
        self.assigned -= 1;
    }

    /// Cell-level failure at `at`: every up node crashes at once, killing
    /// all containers and requeueing their tasks with full lost-work
    /// accounting (the node-level crash machinery applied cluster-wide).
    /// The federation then salvages survivors via
    /// [`Self::withdraw_unfinished`] and re-routes them.
    pub fn fail_cell(&mut self, at: Time) {
        // `now` stays at the last processed event: a dormant cell may hold
        // a stale queued SchedTick older than `at`, and fast-forwarding
        // `now` would break the pop-monotonicity assert when the cell is
        // later revived by an accept.
        assert!(at >= self.now, "cell death in the past");
        let mut killed_total = 0u32;
        let mut lost: Time = 0;
        for node in 0..self.cfg.cluster.nodes {
            if !self.cluster.node_up(node) {
                continue;
            }
            for cid in self.cluster.fail_node(node, at) {
                let (job, phase, task) = {
                    let c = self.cluster.container(cid);
                    (c.job, c.phase, c.task)
                };
                let ji = self.job_index(job);
                if let Some(start) = self.store.requeue_task(ji, phase, task) {
                    lost += at - start;
                }
                let v = self.view_entry(ji);
                v.occupied -= 1;
                v.pending_tasks += 1;
                killed_total += 1;
            }
        }
        self.lost_attempts += killed_total;
        self.lost_work_ms += lost;
        self.wasted_work_ms += lost;
    }

    /// Bring a dead cell back at `at`: every down node rejoins capacity
    /// empty.  The heartbeat chain revives on the next [`Self::accept`].
    pub fn recover_cell(&mut self, at: Time) {
        assert!(at >= self.now, "cell recovery in the past");
        for node in 0..self.cfg.cluster.nodes {
            if !self.cluster.node_up(node) {
                self.cluster.recover_node(node);
            }
        }
    }

    // --- probes & results -------------------------------------------------

    /// Read-only admission probe against the live cell: snapshot the
    /// scheduler's tunable state (or a neutral view-only snapshot for
    /// baselines), overlay one hypothetical `demand`-container arrival,
    /// and shadow-replay it.  Purity is structural — `&self`, no RNG
    /// stream access, no event pushes — and is property-tested: N probes
    /// leave [`Self::state_fingerprint`] exactly unchanged.
    pub fn probe(&self, demand: u32) -> shadow::ShadowScore {
        let jobs = self.naive_view_jobs();
        let view = ClusterView {
            now: self.now,
            free: self.cluster.free(),
            total: self.cluster.total(),
            free_mem: self.cluster.free_mem(),
            total_mem: self.cluster.total_mem(),
            jobs: &jobs,
            transitions: &[],
        };
        let snap = self.sched.snapshot(&view).unwrap_or_else(|| {
            SchedSnapshot::of_view(
                view.now,
                view.free,
                view.total,
                view.jobs,
                self.sched.reserve_ratio().unwrap_or(self.cfg.sched.delta0),
                self.cfg.sched.theta,
            )
        });
        let mut window = ShadowWindow::new(1);
        let next_id = jobs.iter().map(|j| j.id).max().unwrap_or(0) + 1;
        window.push(ShadowEvent::Submit { job: next_id, demand, at: self.now });
        shadow::replay(&snap, &window, snap.delta, shadow::REPLAY_TICKS)
    }

    /// FNV-1a-64 digest of the full observable simulation state: job-store
    /// lanes, event-queue shape, the scheduler view, classifier/estimator
    /// state and δ (via the scheduler snapshot), the exact metric
    /// accumulators, and every progress counter.  Equal fingerprints mean
    /// the two cells are in identical simulation states; the probe-purity
    /// property (tests/properties.rs) pins that probes never move it.
    pub fn state_fingerprint(&self) -> u64 {
        let jobs = self.naive_view_jobs();
        let view = ClusterView {
            now: self.now,
            free: self.cluster.free(),
            total: self.cluster.total(),
            free_mem: self.cluster.free_mem(),
            total_mem: self.cluster.total_mem(),
            jobs: &jobs,
            transitions: &[],
        };
        let snap = self.sched.snapshot(&view);
        let repr = format!(
            "{}|{}|{}|{}|{:?}|{}|{}|{:?}|{:?}|{}|{}|{:?}|{:?}|{:?}|{:?}",
            self.now,
            self.events,
            self.ticks,
            self.queue.len(),
            self.queue.peek_time(),
            self.cluster.free(),
            self.cluster.total(),
            self.sched.reserve_ratio(),
            snap,
            self.finished_jobs,
            self.failures,
            jobs,
            self.store,
            self.util_accum,
            self.delta_accum,
        );
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in repr.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        h
    }

    /// Consume a completed cell into its [`RunResult`].  Panics if owned
    /// jobs remain unfinished (starvation) — callers drive [`Self::step`]
    /// or [`Self::advance_to`] until done first.  Jobs withdrawn by a
    /// federation are excluded: they complete (and report) elsewhere.
    pub fn finish(self) -> RunResult {
        assert!(self.all_finished(), "run ended with unfinished jobs (starvation)");

        let jobs: Vec<JobMetrics> = (0..self.store.len())
            .filter(|&slot| self.store.finished(slot))
            .map(|slot| self.store.metrics_of(slot))
            .collect();
        // Utilization comes from the online accumulator, never from the
        // retained samples — exact under every metric-sink policy.
        let system = SystemMetrics::of(&jobs, &self.util_accum);
        let (trace, tasks_recorded) = self.sink.finish();
        let (util_history, util_recorded) = self.util_sink.finish();
        let (delta_history, delta_recorded) = self.delta_sink.finish();
        RunResult {
            scheduler: self.sched.name().to_string(),
            jobs,
            system,
            trace,
            delta_history,
            util_history,
            util: self.util_accum,
            delta: self.delta_accum,
            util_recorded,
            delta_recorded,
            failures: self.failures,
            lost_attempts: self.lost_attempts,
            lost_work_ms: self.lost_work_ms,
            useful_work_ms: self.useful_work_ms,
            wasted_work_ms: self.wasted_work_ms,
            attempts: self.cluster.containers.len() as u32,
            outages: self
                .outages
                .iter()
                .filter(|o| o.fired)
                .map(|o| o.rec)
                .collect(),
            events: self.events,
            sched_ticks: self.ticks,
            tasks_recorded,
            transitions_recorded: self.heartbeats.recorded(),
            retained_transitions: self.heartbeats.history_len(),
            cells: 1,
            migrations: 0,
            routing: Vec::new(),
            imbalance_max: 0.0,
            imbalance_mean: 0.0,
            cell_outages: Vec::new(),
        }
    }
}

/// The trace recorder type re-exported for federation result merging.
pub type CellTrace = TraceRecorder;
