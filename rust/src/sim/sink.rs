//! Pluggable trace sinks — bounded-memory observability for at-scale runs.
//!
//! The seed engine retained every task trace and every heartbeat
//! transition for the whole run, so a 100k-job congested run held
//! O(total transitions) memory — the dominant RSS term at that scale.
//! [`SinkKind`] picks the retention policy for *both* streams (task traces
//! in the engine, transition history in
//! [`HeartbeatLog`](crate::cluster::HeartbeatLog)):
//!
//! | kind | retains | use for |
//! |---|---|---|
//! | `Full` | everything | figures, paper repro, validation |
//! | `Counting` | counts only | throughput benches, 100k-job sweeps |
//! | `Ring(cap)` | last `cap` records + counts | debugging tails of big runs |
//!
//! Counting and ring sinks never change simulation results — only what is
//! kept in memory (asserted by the engine's sink tests).

use super::trace::{TaskTrace, TraceRecorder};

/// Retention policy for task traces and heartbeat history.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SinkKind {
    /// Keep the complete history (the seed behavior).
    #[default]
    Full,
    /// Keep nothing; count records as they pass through.
    Counting,
    /// Keep the most recent `cap` records plus a total count.
    Ring(usize),
}

/// A task-trace sink with [`SinkKind`] retention.
#[derive(Debug, Clone)]
pub enum TraceSink {
    Full(TraceRecorder),
    Counting { recorded: u64 },
    Ring { cap: usize, buf: Vec<TaskTrace>, head: usize, recorded: u64 },
}

impl TraceSink {
    pub fn new(kind: SinkKind) -> Self {
        match kind {
            SinkKind::Full => TraceSink::Full(TraceRecorder::new()),
            SinkKind::Counting | SinkKind::Ring(0) => TraceSink::Counting { recorded: 0 },
            SinkKind::Ring(cap) => {
                TraceSink::Ring { cap, buf: Vec::with_capacity(cap), head: 0, recorded: 0 }
            }
        }
    }

    pub fn record(&mut self, t: TaskTrace) {
        match self {
            TraceSink::Full(rec) => rec.record(t),
            TraceSink::Counting { recorded } => *recorded += 1,
            TraceSink::Ring { cap, buf, head, recorded } => {
                if buf.len() < *cap {
                    buf.push(t);
                } else {
                    buf[*head] = t;
                    *head = (*head + 1) % *cap;
                }
                *recorded += 1;
            }
        }
    }

    /// Total records seen, independent of retention.
    pub fn recorded(&self) -> u64 {
        match self {
            TraceSink::Full(rec) => rec.tasks.len() as u64,
            TraceSink::Counting { recorded } => *recorded,
            TraceSink::Ring { recorded, .. } => *recorded,
        }
    }

    /// Records currently held in memory.
    pub fn retained(&self) -> usize {
        match self {
            TraceSink::Full(rec) => rec.tasks.len(),
            TraceSink::Counting { .. } => 0,
            TraceSink::Ring { buf, .. } => buf.len(),
        }
    }

    /// Consume into `(retained traces in record order, total recorded)`.
    pub fn finish(self) -> (TraceRecorder, u64) {
        match self {
            TraceSink::Full(rec) => {
                let n = rec.tasks.len() as u64;
                (rec, n)
            }
            TraceSink::Counting { recorded } => (TraceRecorder::new(), recorded),
            TraceSink::Ring { buf, head, recorded, .. } => {
                let mut tasks = Vec::with_capacity(buf.len());
                tasks.extend_from_slice(&buf[head..]);
                tasks.extend_from_slice(&buf[..head]);
                (TraceRecorder { tasks }, recorded)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tt(i: usize) -> TaskTrace {
        TaskTrace {
            job: 1,
            phase: 0,
            task: i,
            granted: i as u64 * 10,
            start: i as u64 * 10 + 5,
            finish: i as u64 * 10 + 9,
        }
    }

    #[test]
    fn full_sink_keeps_everything() {
        let mut s = TraceSink::new(SinkKind::Full);
        for i in 0..5 {
            s.record(tt(i));
        }
        assert_eq!(s.recorded(), 5);
        assert_eq!(s.retained(), 5);
        let (rec, n) = s.finish();
        assert_eq!(n, 5);
        assert_eq!(rec.tasks.len(), 5);
        assert_eq!(rec.tasks[2].task, 2);
    }

    #[test]
    fn counting_sink_counts_without_retaining() {
        let mut s = TraceSink::new(SinkKind::Counting);
        for i in 0..1000 {
            s.record(tt(i));
        }
        assert_eq!(s.recorded(), 1000);
        assert_eq!(s.retained(), 0);
        let (rec, n) = s.finish();
        assert!(rec.tasks.is_empty());
        assert_eq!(n, 1000);
    }

    #[test]
    fn ring_sink_keeps_last_cap_in_order() {
        let mut s = TraceSink::new(SinkKind::Ring(3));
        for i in 0..8 {
            s.record(tt(i));
        }
        assert_eq!(s.recorded(), 8);
        assert_eq!(s.retained(), 3);
        let (rec, n) = s.finish();
        assert_eq!(n, 8);
        let kept: Vec<usize> = rec.tasks.iter().map(|t| t.task).collect();
        assert_eq!(kept, vec![5, 6, 7]);
    }

    #[test]
    fn ring_zero_degenerates_to_counting() {
        let mut s = TraceSink::new(SinkKind::Ring(0));
        s.record(tt(0));
        assert_eq!(s.recorded(), 1);
        assert_eq!(s.retained(), 0);
    }

    #[test]
    fn ring_below_capacity_keeps_all() {
        let mut s = TraceSink::new(SinkKind::Ring(10));
        for i in 0..4 {
            s.record(tt(i));
        }
        let (rec, n) = s.finish();
        assert_eq!(n, 4);
        let kept: Vec<usize> = rec.tasks.iter().map(|t| t.task).collect();
        assert_eq!(kept, vec![0, 1, 2, 3]);
    }
}
