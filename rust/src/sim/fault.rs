//! Deterministic node-level fault injection.
//!
//! A [`FaultPlan`] describes when whole nodes crash and recover.  The
//! engine materializes the plan into concrete [`Outage`]s at start-up and
//! injects them through the ordinary event queue (`Event::NodeFail` /
//! `Event::NodeRecover`), so fault handling obeys the same exact
//! (time, seq) total order as everything else and runs are bit-for-bit
//! reproducible.
//!
//! Two guarantees matter for the golden-determinism suite:
//!
//! * **Empty plan ⇒ zero perturbation.**  An empty plan materializes to no
//!   outages, pushes no events, and draws nothing from any RNG — existing
//!   seeded runs are untouched byte-for-byte.
//! * **Dedicated RNG stream.**  Stochastic plans (MTBF/MTTR renewal per
//!   node) draw from `Rng::new(workload_seed ^ FAULT_SEED_SALT)` — an
//!   independent SplitMix64 stream, never the engine's event RNG — so
//!   adding or removing stochastic faults cannot shift task-duration or
//!   failure-coin draws.

use crate::cluster::NodeId;
use crate::util::rng::Rng;
use crate::util::Time;

/// Salt XORed into the workload seed to derive the fault stream.  Distinct
/// from the engine's event-stream salt (`0xD8E5_5000`) by construction.
pub const FAULT_SEED_SALT: u64 = 0xFA17_0000_5EED_0001;

/// Downtime used by the [`FaultPlan::at`] shorthand (one minute).
pub const DEFAULT_DOWN_MS: Time = 60_000;

/// One planned crash of one node.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Outage {
    /// Crash time.
    pub at_ms: Time,
    /// Node that goes down.
    pub node: NodeId,
    /// Downtime; the node recovers at `at_ms + down_ms`.
    pub down_ms: Time,
}

/// Parameters of a per-node alternating-renewal fault process: each node
/// independently alternates exponential up-times (mean `mtbf_ms`) and
/// exponential down-times (mean `mttr_ms`), with crashes drawn only
/// before `until_ms`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StochasticFaults {
    pub mtbf_ms: Time,
    pub mttr_ms: Time,
    pub until_ms: Time,
}

/// A declarative fault plan: explicit outages plus an optional stochastic
/// process.  `Debug` formatting feeds the sweep-grid fingerprint, so two
/// shards with different plans refuse to merge.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    pub fixed: Vec<Outage>,
    pub stochastic: Option<StochasticFaults>,
}

impl FaultPlan {
    /// The no-fault plan (also `Default`).
    pub fn empty() -> FaultPlan {
        FaultPlan::default()
    }

    /// Shorthand: crash `node` at `at_ms` for [`DEFAULT_DOWN_MS`].
    pub fn at(at_ms: Time, node: NodeId) -> FaultPlan {
        FaultPlan::default().with_outage(at_ms, node, DEFAULT_DOWN_MS)
    }

    /// Add one explicit outage.
    pub fn with_outage(mut self, at_ms: Time, node: NodeId, down_ms: Time) -> FaultPlan {
        self.fixed.push(Outage { at_ms, node, down_ms });
        self
    }

    /// Add a correlated outage: every listed node crashes at the same
    /// instant for the same downtime (rack/switch failure).
    pub fn correlated(mut self, at_ms: Time, nodes: &[NodeId], down_ms: Time) -> FaultPlan {
        for &n in nodes {
            self.fixed.push(Outage { at_ms, node: n, down_ms });
        }
        self
    }

    /// Attach a stochastic MTBF/MTTR process.
    pub fn stochastic(mut self, mtbf_ms: Time, mttr_ms: Time, until_ms: Time) -> FaultPlan {
        self.stochastic = Some(StochasticFaults { mtbf_ms, mttr_ms, until_ms });
        self
    }

    /// True when the plan can never produce an outage.
    pub fn is_empty(&self) -> bool {
        self.fixed.is_empty() && self.stochastic.is_none()
    }

    /// Parse the CLI/TOML spec string.  Grammar (segments joined by `;`):
    ///
    /// * `T:N:D` — crash node `N` at time `T` ms for `D` ms.
    /// * `T:N1+N2+…:D` — correlated outage of several nodes.
    /// * `mtbf=U,mttr=R,until=H` — stochastic process (all ms).
    /// * `none` / empty — the empty plan.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let spec = spec.trim();
        if spec.is_empty() || spec == "none" {
            return Ok(FaultPlan::default());
        }
        let mut plan = FaultPlan::default();
        for seg in spec.split(';').map(str::trim).filter(|s| !s.is_empty()) {
            if seg.contains('=') {
                if plan.stochastic.is_some() {
                    return Err(format!("fault plan `{spec}`: multiple stochastic segments"));
                }
                let (mut mtbf, mut mttr, mut until) = (None, None, None);
                for kv in seg.split(',') {
                    let (k, v) = kv
                        .split_once('=')
                        .ok_or_else(|| format!("fault segment `{seg}`: expected key=value"))?;
                    let v: Time = v
                        .trim()
                        .parse()
                        .map_err(|e| format!("fault segment `{seg}`: {e}"))?;
                    match k.trim() {
                        "mtbf" => mtbf = Some(v),
                        "mttr" => mttr = Some(v),
                        "until" => until = Some(v),
                        other => {
                            return Err(format!("fault segment `{seg}`: unknown key `{other}`"))
                        }
                    }
                }
                plan.stochastic = Some(StochasticFaults {
                    mtbf_ms: mtbf.ok_or_else(|| format!("fault segment `{seg}`: missing mtbf"))?,
                    mttr_ms: mttr.ok_or_else(|| format!("fault segment `{seg}`: missing mttr"))?,
                    until_ms: until
                        .ok_or_else(|| format!("fault segment `{seg}`: missing until"))?,
                });
            } else {
                let parts: Vec<&str> = seg.split(':').collect();
                if parts.len() != 3 {
                    return Err(format!(
                        "fault segment `{seg}`: expected T:NODE[+NODE…]:DOWN_MS"
                    ));
                }
                let at: Time = parts[0]
                    .trim()
                    .parse()
                    .map_err(|e| format!("fault segment `{seg}`: bad time: {e}"))?;
                let down: Time = parts[2]
                    .trim()
                    .parse()
                    .map_err(|e| format!("fault segment `{seg}`: bad downtime: {e}"))?;
                for n in parts[1].split('+') {
                    let node: NodeId = n
                        .trim()
                        .parse()
                        .map_err(|e| format!("fault segment `{seg}`: bad node: {e}"))?;
                    plan.fixed.push(Outage { at_ms: at, node, down_ms: down });
                }
            }
        }
        Ok(plan)
    }

    /// Canonical spec string — parses back to an equal plan.
    pub fn to_spec(&self) -> String {
        if self.is_empty() {
            return "none".into();
        }
        let mut segs: Vec<String> = self
            .fixed
            .iter()
            .map(|o| format!("{}:{}:{}", o.at_ms, o.node, o.down_ms))
            .collect();
        if let Some(s) = self.stochastic {
            segs.push(format!("mtbf={},mttr={},until={}", s.mtbf_ms, s.mttr_ms, s.until_ms));
        }
        segs.join(";")
    }

    /// Expand the plan into a concrete, validated outage list for a
    /// cluster of `nodes` nodes.  Stochastic draws come exclusively from
    /// the dedicated fault stream derived from `seed` (one per-node fork),
    /// so an empty plan performs **zero** RNG work.  The result is sorted
    /// by `(at_ms, node)` and checked for per-node overlap: a node must
    /// be back up before its next scheduled crash (touching intervals are
    /// allowed — recovery events sort before same-time crash events).
    pub fn materialize(&self, nodes: u16, seed: u64) -> Result<Vec<Outage>, String> {
        let mut out = self.fixed.clone();
        if let Some(s) = self.stochastic {
            if s.mtbf_ms == 0 || s.mttr_ms == 0 {
                return Err("fault plan: mtbf and mttr must be > 0".into());
            }
            let mut root = Rng::new(seed ^ FAULT_SEED_SALT);
            for node in 0..nodes {
                let mut r = root.fork(node as u64);
                let mut t: Time = 0;
                loop {
                    t = t.saturating_add(exp_ms(&mut r, s.mtbf_ms));
                    if t >= s.until_ms {
                        break;
                    }
                    let down = exp_ms(&mut r, s.mttr_ms);
                    out.push(Outage { at_ms: t, node, down_ms: down });
                    t = t.saturating_add(down);
                }
            }
        }
        for o in &out {
            if o.node as usize >= nodes as usize {
                return Err(format!(
                    "fault plan: node {} out of range (cluster has {nodes} nodes)",
                    o.node
                ));
            }
            if o.down_ms == 0 {
                return Err(format!("fault plan: zero downtime for node {} at {}", o.node, o.at_ms));
            }
        }
        out.sort_unstable();
        // Overlap is a per-node notion, so check with same-node entries
        // adjacent (the (time, node) sort interleaves nodes).
        let mut by_node = out.clone();
        by_node.sort_unstable_by_key(|o| (o.node, o.at_ms));
        for w in by_node.windows(2) {
            let (a, b) = (w[0], w[1]);
            if a.node == b.node && b.at_ms < a.at_ms + a.down_ms {
                return Err(format!(
                    "fault plan: overlapping outages for node {} at {} and {}",
                    a.node, a.at_ms, b.at_ms
                ));
            }
        }
        Ok(out)
    }
}

impl std::fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.to_spec())
    }
}

/// Exponential draw with the given mean, floored to 1 ms so renewal
/// processes always make progress.
fn exp_ms(r: &mut Rng, mean_ms: Time) -> Time {
    let u = r.next_f64(); // [0, 1)
    let x = -(mean_ms as f64) * (1.0 - u).ln();
    (x as Time).max(1)
}

/// What one outage did to the run — filled in by the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OutageRecord {
    pub node: NodeId,
    pub at_ms: Time,
    pub down_ms: Time,
    /// Task attempts killed by the crash.
    pub killed: u32,
    /// Run-time thrown away: `Σ (crash − run_start)` over killed Running
    /// tasks (Launching attempts die with zero accrued work).
    pub lost_work_ms: Time,
    /// When the outage was fully healed: the node is back up AND every
    /// task it killed has re-completed.  `None` when the run finished
    /// before the node's downtime elapsed (the outage outlived the run).
    pub recovered_at: Option<Time>,
}

impl OutageRecord {
    /// Crash → fully-healed latency.
    pub fn time_to_recover_ms(&self) -> Option<Time> {
        self.recovered_at.map(|t| t - self.at_ms)
    }
}

/// What one *cell-level* outage did to a federated run — filled in by
/// `federation::Federation`.  A cell outage reuses the [`FaultPlan`]
/// grammar with cell indices in place of node ids: every node of the cell
/// crashes at `at_ms` and recovers at `at_ms + down_ms`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CellOutageRecord {
    /// Index of the cell that died.
    pub cell: u32,
    pub at_ms: Time,
    pub down_ms: Time,
    /// Submitted-but-unfinished jobs salvaged from the dead cell and
    /// re-routed to surviving cells.
    pub salvaged: u32,
    /// When the outage was fully healed: the cell is back up AND every
    /// job salvaged from it has completed somewhere in the federation.
    /// `None` when the federation finished before the downtime elapsed.
    pub recovered_at: Option<Time>,
}

impl CellOutageRecord {
    /// Cell death → fully-healed latency.
    pub fn time_to_recover_ms(&self) -> Option<Time> {
        self.recovered_at.map(|t| t - self.at_ms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_materializes_to_nothing() {
        let plan = FaultPlan::empty();
        assert!(plan.is_empty());
        assert_eq!(plan.materialize(5, 42).unwrap(), vec![]);
        assert_eq!(plan.to_spec(), "none");
    }

    #[test]
    fn at_shorthand_and_builder() {
        let plan = FaultPlan::at(60_000, 2);
        let out = plan.materialize(5, 1).unwrap();
        assert_eq!(out, vec![Outage { at_ms: 60_000, node: 2, down_ms: DEFAULT_DOWN_MS }]);
        let plan = FaultPlan::empty()
            .with_outage(10, 0, 5)
            .correlated(100, &[1, 3], 50);
        let out = plan.materialize(5, 1).unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(out[1], Outage { at_ms: 100, node: 1, down_ms: 50 });
        assert_eq!(out[2], Outage { at_ms: 100, node: 3, down_ms: 50 });
    }

    #[test]
    fn parse_fixed_correlated_and_stochastic() {
        let plan = FaultPlan::parse("60000:0:30000; 120000:1+2:60000").unwrap();
        assert_eq!(plan.fixed.len(), 3);
        assert_eq!(plan.fixed[0], Outage { at_ms: 60_000, node: 0, down_ms: 30_000 });
        assert_eq!(plan.fixed[1], Outage { at_ms: 120_000, node: 1, down_ms: 60_000 });
        assert_eq!(plan.fixed[2], Outage { at_ms: 120_000, node: 2, down_ms: 60_000 });
        assert!(plan.stochastic.is_none());

        let plan = FaultPlan::parse("mtbf=600000,mttr=30000,until=3600000").unwrap();
        assert_eq!(
            plan.stochastic,
            Some(StochasticFaults { mtbf_ms: 600_000, mttr_ms: 30_000, until_ms: 3_600_000 })
        );
        assert!(FaultPlan::parse("none").unwrap().is_empty());
        assert!(FaultPlan::parse("  ").unwrap().is_empty());
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "60000:0",              // missing downtime
            "abc:0:1",              // bad time
            "1:zz:1",               // bad node
            "mtbf=1,mttr=2",        // missing until
            "mtbf=1,bogus=2,until=3",
            "mtbf=1,mttr=2,until=3;mtbf=4,mttr=5,until=6", // two stochastic segs
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted `{bad}`");
        }
    }

    #[test]
    fn spec_roundtrips() {
        for spec in [
            "60000:0:30000;120000:1:60000",
            "1:4:2;mtbf=10,mttr=20,until=30",
            "none",
        ] {
            let plan = FaultPlan::parse(spec).unwrap();
            assert_eq!(FaultPlan::parse(&plan.to_spec()).unwrap(), plan, "{spec}");
        }
    }

    #[test]
    fn materialize_validates() {
        // Node out of range.
        assert!(FaultPlan::at(1, 9).materialize(5, 0).is_err());
        // Zero downtime.
        assert!(FaultPlan::empty().with_outage(1, 0, 0).materialize(5, 0).is_err());
        // Same-node overlap rejected; touching intervals allowed.
        let overlap = FaultPlan::empty().with_outage(100, 0, 50).with_outage(120, 0, 10);
        assert!(overlap.materialize(5, 0).is_err());
        let touching = FaultPlan::empty().with_outage(100, 0, 50).with_outage(150, 0, 10);
        assert_eq!(touching.materialize(5, 0).unwrap().len(), 2);
        // Different nodes may overlap freely (that's a correlated outage).
        let cross = FaultPlan::empty().correlated(100, &[0, 1], 500);
        assert_eq!(cross.materialize(5, 0).unwrap().len(), 2);
    }

    #[test]
    fn stochastic_is_seed_stable_and_non_overlapping() {
        let plan = FaultPlan::empty().stochastic(50_000, 10_000, 1_000_000);
        let a = plan.materialize(4, 42).unwrap();
        let b = plan.materialize(4, 42).unwrap();
        assert_eq!(a, b, "same seed, same outages");
        assert!(!a.is_empty(), "a 1000 s horizon at 50 s MTBF should crash something");
        let c = plan.materialize(4, 43).unwrap();
        assert_ne!(a, c, "different seed, different outages");
        for o in &a {
            assert!(o.at_ms < 1_000_000 && o.down_ms >= 1);
            assert!(o.node < 4);
        }
        // Per-node renewal structure: alternating up/down can't overlap.
        let mut by_node = a.clone();
        by_node.sort_unstable_by_key(|o| (o.node, o.at_ms));
        for w in by_node.windows(2) {
            if w[0].node == w[1].node {
                assert!(w[1].at_ms >= w[0].at_ms + w[0].down_ms);
            }
        }
    }

    #[test]
    fn stochastic_stream_is_isolated_from_engine_salt() {
        // The fault stream must not collide with the engine's event
        // stream for the same workload seed.
        let seed = 7u64;
        let mut fault = Rng::new(seed ^ FAULT_SEED_SALT);
        let mut engine = Rng::new(seed ^ 0xD8E5_5000);
        let same = (0..64).filter(|_| fault.next_u64() == engine.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn outage_record_recovery_latency() {
        let mut rec = OutageRecord {
            node: 1,
            at_ms: 1_000,
            down_ms: 500,
            killed: 3,
            lost_work_ms: 900,
            recovered_at: None,
        };
        assert_eq!(rec.time_to_recover_ms(), None);
        rec.recovered_at = Some(2_500);
        assert_eq!(rec.time_to_recover_ms(), Some(1_500));
    }
}
