//! The single-cell simulation engine: a thin wrapper over [`Cell`]
//! (sim/cell.rs), which owns the discrete-event core — jobs, containers,
//! scheduler heartbeats, feasibility enforcement, metrics and traces.
//!
//! Historically this module *was* the core (~1.5k lines).  The federation
//! refactor extracted it into `sim/cell.rs` so N cells can run side by
//! side under `federation/`; the split is proven bit-identical for all
//! five schedulers (± fault plans, ± tuner, scalar and vector demands) by
//! tests/golden_determinism.rs and the 1-cell federation goldens, exactly
//! as the SoA/AoS and calendar/heap refactors were.  This file keeps the
//! public surface: [`Engine`], [`EngineOptions`], [`RunResult`], and the
//! `run_experiment*` helpers.
//!
//! Hot-path design notes (the indexed O(1)-per-event engine, the SoA job
//! store, the incremental scheduler view) live at the top of sim/cell.rs
//! with the code they describe.

pub use super::cell::{Cell, CellOutput};
use super::event::QueueKind;
use super::fault::{CellOutageRecord, OutageRecord};
use super::metric::MetricSinkKind;
use super::sink::SinkKind;
use super::trace::TraceRecorder;
use crate::config::ExperimentConfig;
use crate::jobs::{JobLayout, JobSpec};
use crate::metrics::{DeltaSummary, JobMetrics, SystemMetrics, UtilSummary};
use crate::sched::shadow;
use crate::sched::Scheduler;
use crate::util::Time;

/// Outcome of one simulated experiment (one cell, or a merged federation).
#[derive(Debug, Clone)]
pub struct RunResult {
    pub scheduler: String,
    pub jobs: Vec<JobMetrics>,
    pub system: SystemMetrics,
    pub trace: TraceRecorder,
    /// Retained DRESS δ samples — empty for baselines, and bounded /
    /// downsampled by [`EngineOptions::metrics`] (use [`Self::delta`] for
    /// exact summary statistics under any retention).
    pub delta_history: Vec<(Time, f64)>,
    /// Retained per-tick `(time, used containers)` samples, bounded /
    /// downsampled by [`EngineOptions::metrics`] (use [`Self::util`] for
    /// exact summary statistics under any retention).
    pub util_history: Vec<(Time, u32)>,
    /// Exact time-weighted utilization summary, accumulated online —
    /// identical under every metric sink.
    pub util: UtilSummary,
    /// Exact δ-stream summary (min/max/last/time-weighted mean),
    /// accumulated online — identical under every metric sink.
    pub delta: DeltaSummary,
    /// Utilization samples observed, independent of retention
    /// (`util_history.len()` holds only what the sink kept).
    pub util_recorded: u64,
    /// δ samples observed, independent of retention.
    pub delta_recorded: u64,
    /// Injected container failures survived (task re-attempts).
    pub failures: u32,
    /// Task attempts killed by node crashes (fault plan); each was
    /// requeued and eventually re-ran to completion.
    pub lost_attempts: u32,
    /// Run-time destroyed by node crashes: `Σ (crash − run_start)` over
    /// killed Running tasks.
    pub lost_work_ms: Time,
    /// Run-time that ended in a successful completion (`Σ finish − start`
    /// over completed attempts) — the goodput numerator.
    pub useful_work_ms: Time,
    /// Run-time thrown away for any reason: crash-killed work plus the
    /// partial work of coin-flip container failures.
    pub wasted_work_ms: Time,
    /// Container attempts created over the run (completed + coin-flip
    /// failures + crash-killed; conservation is property-tested).
    pub attempts: u32,
    /// Per-outage accounting, in injection order.  Only outages whose
    /// crash actually fired during the run appear.
    pub outages: Vec<OutageRecord>,
    /// Total simulation events processed (throughput accounting).
    pub events: u64,
    /// Scheduler heartbeat rounds executed.
    pub sched_ticks: u64,
    /// Task traces observed, independent of sink retention (`trace.tasks`
    /// holds only what the sink kept).
    pub tasks_recorded: u64,
    /// Heartbeat transitions observed over the run.
    pub transitions_recorded: u64,
    /// Heartbeat transitions still held in memory at run end — bounded by
    /// the sink policy (0 for counting, `cap` for ring, all for full).
    pub retained_transitions: usize,
    /// Cells that produced this result (1 for a plain engine run).
    pub cells: u32,
    /// Cross-cell job migrations (threshold rebalancing + death salvage).
    /// Always 0 for a single-cell run.
    pub migrations: u32,
    /// Jobs initially routed to each cell, indexed by cell (empty for a
    /// single-cell run).
    pub routing: Vec<u32>,
    /// Peak cross-cell imbalance: max over heartbeats of
    /// `max(queued) / mean(queued)` across alive cells (0.0 when never
    /// sampled — single cell, or no heartbeat saw a nonempty queue).
    pub imbalance_max: f64,
    /// Time-mean of the same per-heartbeat imbalance ratio.
    pub imbalance_mean: f64,
    /// Cell-level outage accounting (federation only), in injection order.
    pub cell_outages: Vec<CellOutageRecord>,
}

impl RunResult {
    /// Goodput: the fraction of executed run-time that ended in a
    /// successful completion, `useful / (useful + wasted)`.  1.0 when no
    /// work was wasted (including the degenerate no-work case).
    pub fn goodput(&self) -> f64 {
        let total = self.useful_work_ms + self.wasted_work_ms;
        if total == 0 {
            return 1.0;
        }
        self.useful_work_ms as f64 / total as f64
    }
}

/// Engine knobs beyond the experiment config.
#[derive(Debug, Clone, Copy)]
pub struct EngineOptions {
    /// Retention policy for task traces *and* heartbeat history (see
    /// [`SinkKind`]).  Full for figures/tests; counting for throughput
    /// runs so 100k-job sweeps hold O(active) memory instead of
    /// O(total transitions); ring to keep just the tail of a big run.
    pub trace: SinkKind,
    /// Retention policy for the per-tick metric streams (utilization, δ —
    /// see [`MetricSinkKind`]).  Summary statistics (`RunResult::util`,
    /// `RunResult::delta`, `SystemMetrics::mean_utilization`) come from
    /// exact online accumulators and are identical under every policy;
    /// this only bounds what is retained for per-sample rendering.
    pub metrics: MetricSinkKind,
    /// Event-queue implementation ([`QueueKind`]).  Calendar by default;
    /// the binary-heap reference kind exists for equivalence tests.
    pub queue: QueueKind,
    /// Rebuild the scheduler view from scratch every tick (the seed
    /// engine's behavior).  Reference path for equivalence tests and
    /// speedup baselines; simulation results are identical either way.
    pub naive_hot_path: bool,
    /// Job-state storage layout ([`JobLayout`]).  Struct-of-arrays by
    /// default; the array-of-structs reference layout exists for
    /// equivalence tests.  Simulation results are identical either way.
    pub jobs: JobLayout,
    /// Opt-in online δ auto-tuner: the DRESS scheduler shadow-replays its
    /// recent submit/complete window against candidate δ values every K
    /// heartbeats and adopts the winner (see [`crate::sched::shadow`] and
    /// docs/ADMISSION.md).  Off by default — and proven *bit-identical*
    /// off by tests/golden_determinism.rs: zero RNG draws, zero events,
    /// zero allocations.  No-op for the baseline schedulers.
    pub tune_delta: bool,
    /// δ auto-tuner re-tune cadence in heartbeats (CLI `--tune-every`).
    /// Ignored unless `tune_delta` is on; the default matches the
    /// historical hard-wired cadence, so existing goldens are bit-stable.
    pub tune_every: u32,
    /// δ auto-tuner shadow-window capacity in events (CLI
    /// `--shadow-window`).  Ignored unless `tune_delta` is on; the default
    /// matches the historical hard-wired size.
    pub shadow_window: usize,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            trace: SinkKind::Full,
            metrics: MetricSinkKind::Full,
            queue: QueueKind::Calendar,
            naive_hot_path: false,
            jobs: JobLayout::Soa,
            tune_delta: false,
            tune_every: shadow::DEFAULT_TUNE_EVERY,
            shadow_window: shadow::DEFAULT_WINDOW,
        }
    }
}

impl EngineOptions {
    /// The configuration throughput benches and big parallel sweeps use:
    /// counting sinks for events *and* per-tick metrics, so memory is
    /// O(active jobs) at any horizon; default queue and hot path.
    pub fn throughput() -> Self {
        EngineOptions {
            trace: SinkKind::Counting,
            metrics: MetricSinkKind::Counting,
            ..Default::default()
        }
    }
}

/// The single-cell engine: one [`Cell`] driven to completion.  All
/// simulation state and logic live in the cell; this wrapper only fixes
/// the membership mode (every job owned, no output collection) so the
/// historical engine surface keeps working unchanged.
pub struct Engine {
    cell: Cell,
}

impl Engine {
    pub fn new(cfg: ExperimentConfig, specs: Vec<JobSpec>, sched: Box<dyn Scheduler>) -> Self {
        Engine::with_options(cfg, specs, sched, EngineOptions::default())
    }

    pub fn with_options(
        cfg: ExperimentConfig,
        specs: Vec<JobSpec>,
        sched: Box<dyn Scheduler>,
        opts: EngineOptions,
    ) -> Self {
        Engine { cell: Cell::with_options(cfg, specs, sched, opts) }
    }

    /// Advance the simulation by exactly one event.  Returns `false` once
    /// the run is over.  See [`Cell::step`].
    pub fn step(&mut self) -> bool {
        self.cell.step()
    }

    /// Read-only admission probe against the live engine.  See
    /// [`Cell::probe`].
    pub fn probe(&self, demand: u32) -> shadow::ShadowScore {
        self.cell.probe(demand)
    }

    /// FNV-1a-64 digest of the full observable simulation state.  See
    /// [`Cell::state_fingerprint`].
    pub fn state_fingerprint(&self) -> u64 {
        self.cell.state_fingerprint()
    }

    /// Run to completion and produce the result bundle.
    pub fn run(mut self) -> RunResult {
        while self.step() {}
        self.finish()
    }

    /// Consume a completed engine into its [`RunResult`].  Panics if jobs
    /// remain unfinished (starvation) — callers drive [`Self::step`] to
    /// `false` first.
    pub fn finish(self) -> RunResult {
        self.cell.finish()
    }
}

/// Convenience: build + run one experiment with the configured scheduler.
pub fn run_experiment(cfg: &ExperimentConfig, specs: Vec<JobSpec>) -> RunResult {
    run_experiment_with(cfg, specs, EngineOptions::default())
}

/// `run_experiment` with explicit [`EngineOptions`] (benches use this for
/// trace opt-out and for the naive-path speedup baseline).  A config with
/// `federation.cells > 1` runs the full federation and returns the merged
/// result, so sweeps and shards parallelize federated configurations on
/// the existing infrastructure with no further plumbing.
pub fn run_experiment_with(
    cfg: &ExperimentConfig,
    specs: Vec<JobSpec>,
    opts: EngineOptions,
) -> RunResult {
    if cfg.federation.cells > 1 {
        return crate::federation::run_federation(cfg, specs, opts).merged();
    }
    let sched = crate::sched::build(&cfg.sched, cfg.cluster.total_containers());
    Engine::with_options(cfg.clone(), specs, sched, opts).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchedKind;
    use crate::jobs::{Demand, PhaseKind, PhaseSpec, Platform};
    use crate::sched::dress::reserve::{DELTA_MAX, DELTA_MIN};

    fn tiny_job(id: u32, submit: Time, demand: u32, durs: &[Time]) -> JobSpec {
        JobSpec {
            id,
            name: format!("job{id}"),
            platform: Platform::MapReduce,
            submit_ms: submit,
            demand: Demand::scalar(demand),
            phases: vec![PhaseSpec::new(PhaseKind::Map, durs)],
        }
    }

    fn cfg(kind: SchedKind) -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.cluster.nodes = 2;
        c.cluster.slots_per_node = 3;
        c.sched.kind = kind;
        c
    }

    #[test]
    fn single_job_completes() {
        let res = run_experiment(&cfg(SchedKind::Fifo), vec![tiny_job(1, 0, 2, &[5_000, 5_000])]);
        assert_eq!(res.jobs.len(), 1);
        let j = &res.jobs[0];
        assert!(j.waiting_ms > 0, "startup delays imply nonzero waiting");
        assert!(j.completion_ms >= 5_000);
        assert_eq!(res.trace.tasks.len(), 2);
        assert!(res.events > 0 && res.sched_ticks > 0, "throughput counters populated");
    }

    #[test]
    fn all_schedulers_complete_congested_mix() {
        let specs = vec![
            tiny_job(1, 0, 4, &[8_000, 8_000, 9_000, 9_000]),
            tiny_job(2, 1_000, 4, &[7_000, 7_000, 7_000, 7_000]),
            tiny_job(3, 2_000, 2, &[3_000, 3_000]),
            tiny_job(4, 3_000, 2, &[4_000, 4_000]),
        ];
        for kind in [
            SchedKind::Fifo,
            SchedKind::Fair,
            SchedKind::Capacity,
            SchedKind::Dress,
            SchedKind::MaxWeight,
        ] {
            let res = run_experiment(&cfg(kind), specs.clone());
            assert_eq!(res.jobs.len(), 4, "{kind:?}");
            assert!(res.system.makespan_ms > 0);
            assert_eq!(res.trace.tasks.len(), 12, "{kind:?}: every task ran");
        }
    }

    #[test]
    fn dress_records_delta_history() {
        let res = run_experiment(&cfg(SchedKind::Dress), vec![tiny_job(1, 0, 2, &[2_000, 2_000])]);
        assert!(!res.delta_history.is_empty());
        // δ is clamped into the documented reserve band (Algorithm 3);
        // asserted with the same inclusive range everywhere.
        assert!(res
            .delta_history
            .iter()
            .all(|&(_, d)| (DELTA_MIN..=DELTA_MAX).contains(&d)));
        let fifo = run_experiment(&cfg(SchedKind::Fifo), vec![tiny_job(1, 0, 2, &[2_000, 2_000])]);
        assert!(fifo.delta_history.is_empty());
    }

    #[test]
    fn multi_phase_barrier_ordering() {
        let spec = JobSpec {
            id: 1,
            name: "two-phase".into(),
            platform: Platform::MapReduce,
            submit_ms: 0,
            demand: Demand::scalar(3),
            phases: vec![
                PhaseSpec::new(PhaseKind::Map, &[4_000, 4_500, 5_000]),
                PhaseSpec::new(PhaseKind::Reduce, &[3_000]),
            ],
        };
        let res = run_experiment(&cfg(SchedKind::Capacity), vec![spec]);
        let map_finish = res
            .trace
            .tasks
            .iter()
            .filter(|t| t.phase == 0)
            .map(|t| t.finish)
            .max()
            .unwrap();
        let reduce_start = res
            .trace
            .tasks
            .iter()
            .find(|t| t.phase == 1)
            .map(|t| t.start)
            .unwrap();
        assert!(
            reduce_start >= map_finish,
            "reduce started {reduce_start} before last map finished {map_finish}"
        );
    }

    #[test]
    fn failure_injection_retries_until_done() {
        let mut c = cfg(SchedKind::Capacity);
        c.cluster.task_failure_prob = 0.3;
        let specs = vec![
            tiny_job(1, 0, 3, &[4_000, 4_000, 4_000]),
            tiny_job(2, 1_000, 2, &[3_000, 3_000]),
        ];
        let res = run_experiment(&c, specs);
        // All tasks eventually completed despite failures; failed attempts
        // do not appear in the trace (only successful runs do).
        assert_eq!(res.trace.tasks.len(), 5);
        assert!(res.failures > 0, "with p=0.3 over 5+ attempts, expect failures");
        // Failures lengthen the run vs the failure-free baseline.
        let mut clean = cfg(SchedKind::Capacity);
        clean.cluster.task_failure_prob = 0.0;
        let base = run_experiment(&clean, vec![
            tiny_job(1, 0, 3, &[4_000, 4_000, 4_000]),
            tiny_job(2, 1_000, 2, &[3_000, 3_000]),
        ]);
        assert_eq!(base.failures, 0);
        assert!(res.system.makespan_ms >= base.system.makespan_ms);
    }

    #[test]
    fn dress_survives_failures_under_congestion() {
        let mut c = cfg(SchedKind::Dress);
        c.cluster.task_failure_prob = 0.15;
        let specs = crate::workload::generate(
            8,
            crate::workload::WorkloadMix::Mixed,
            0.3,
            2_000,
            11,
        );
        let expected: usize = specs.iter().map(|s| s.total_tasks() as usize).sum();
        let res = run_experiment(&c, specs);
        assert_eq!(res.trace.tasks.len(), expected);
        // Same clamp band as dress_records_delta_history (inclusive).
        assert!(res
            .delta_history
            .iter()
            .all(|&(_, d)| (DELTA_MIN..=DELTA_MAX).contains(&d)));
    }

    #[test]
    fn node_crash_requeues_and_recovers() {
        let mut c = cfg(SchedKind::Capacity);
        c.faults = crate::sim::fault::FaultPlan::empty().with_outage(6_000, 0, 20_000);
        let specs = vec![
            tiny_job(1, 0, 4, &[8_000, 8_000, 9_000, 9_000]),
            tiny_job(2, 1_000, 2, &[7_000, 7_000]),
        ];
        let res = run_experiment(&c, specs.clone());
        assert_eq!(res.trace.tasks.len(), 6, "every task completed despite the crash");
        assert_eq!(res.outages.len(), 1);
        let o = &res.outages[0];
        assert!(o.killed > 0, "node 0 held running containers at t=6 s");
        assert_eq!(res.lost_attempts, o.killed);
        assert!(res.lost_work_ms > 0 && o.lost_work_ms == res.lost_work_ms);
        assert!(o.recovered_at.is_some(), "short downtime heals within the run");
        assert!(o.time_to_recover_ms().unwrap() >= 20_000, "downtime bounds recovery");
        assert!(res.goodput() < 1.0, "killed work must dent goodput");
        assert!(res.wasted_work_ms >= res.lost_work_ms);
        // Conservation: every attempt completed, coin-failed, or was killed.
        assert_eq!(
            res.attempts as usize,
            res.trace.tasks.len() + res.failures as usize + res.lost_attempts as usize
        );
        // The no-fault baseline is untouched and no slower.
        let base = run_experiment(&cfg(SchedKind::Capacity), specs);
        assert!(base.outages.is_empty() && base.lost_attempts == 0);
        assert_eq!(base.goodput(), 1.0);
        assert!(res.system.makespan_ms >= base.system.makespan_ms);
    }

    #[test]
    fn crash_of_idle_node_heals_at_recovery_time() {
        // Nothing runs on the crashed node: killed == 0, recovery is
        // exactly the configured downtime.
        let mut c = cfg(SchedKind::Fifo);
        c.cluster.nodes = 3;
        c.faults = crate::sim::fault::FaultPlan::empty().with_outage(1, 2, 5_000);
        let res = run_experiment(&c, vec![tiny_job(1, 0, 1, &[2_000])]);
        assert_eq!(res.outages.len(), 1);
        let o = &res.outages[0];
        assert!(res.jobs[0].completion_ms > 0);
        if o.killed == 0 {
            assert_eq!(o.lost_work_ms, 0);
            // Healing may still require the run to outlive the downtime.
            if let Some(t) = o.time_to_recover_ms() {
                assert_eq!(t, 5_000);
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let specs = vec![tiny_job(1, 0, 3, &[4_000, 5_000, 6_000])];
        let a = run_experiment(&cfg(SchedKind::Capacity), specs.clone());
        let b = run_experiment(&cfg(SchedKind::Capacity), specs);
        assert_eq!(a.system.makespan_ms, b.system.makespan_ms);
        assert_eq!(a.jobs[0].waiting_ms, b.jobs[0].waiting_ms);
    }

    #[test]
    fn counting_sink_skips_retention_without_changing_results() {
        let c = cfg(SchedKind::Capacity);
        let specs = vec![
            tiny_job(1, 0, 2, &[3_000, 3_000]),
            tiny_job(2, 1_000, 2, &[2_000, 2_000]),
        ];
        let on = run_experiment(&c, specs.clone());
        let off = run_experiment_with(
            &c,
            specs,
            EngineOptions { trace: SinkKind::Counting, ..Default::default() },
        );
        assert_eq!(on.trace.tasks.len(), 4);
        assert!(off.trace.tasks.is_empty(), "counting sink must not retain traces");
        assert_eq!(off.tasks_recorded, 4, "counting sink still counts every task");
        assert_eq!(on.system.makespan_ms, off.system.makespan_ms);
        assert_eq!(on.events, off.events, "recording must not alter the simulation");
    }

    #[test]
    fn counting_sink_bounds_heartbeat_and_trace_memory() {
        // The at-scale memory guarantee, shrunk to test size: a congested
        // burst under the counting sink retains NO history while observing
        // exactly what the full sink observes.
        let mut c = ExperimentConfig::default();
        c.sched.kind = SchedKind::Dress;
        let specs = crate::workload::congested_burst(150, 100, 0xBEEF);
        let full = run_experiment_with(&c, specs.clone(), EngineOptions::default());
        let lean = run_experiment_with(&c, specs, EngineOptions::throughput());
        // Identical simulation...
        assert_eq!(full.system.makespan_ms, lean.system.makespan_ms);
        assert_eq!(full.events, lean.events);
        // ...identical observation counts...
        assert_eq!(full.tasks_recorded, lean.tasks_recorded);
        assert_eq!(full.transitions_recorded, lean.transitions_recorded);
        assert!(lean.transitions_recorded > 0);
        // ...but O(1) retention instead of O(total transitions).
        assert_eq!(lean.retained_transitions, 0, "counting sink retained history");
        assert!(lean.trace.tasks.is_empty());
        assert_eq!(full.retained_transitions as u64, full.transitions_recorded);
        // Per-tick metric streams are bounded the same way: zero retained
        // samples, yet the exact accumulators agree bit-for-bit.
        assert!(lean.util_history.is_empty() && lean.delta_history.is_empty());
        assert_eq!(lean.util_recorded, full.util_recorded);
        assert_eq!(lean.delta_recorded, full.delta_recorded);
        assert!(lean.util_recorded > 0 && lean.delta_recorded > 0, "dress streams populated");
        assert_eq!(lean.util, full.util, "utilization summary must not depend on retention");
        assert_eq!(lean.delta, full.delta);
        assert_eq!(
            lean.system.mean_utilization.to_bits(),
            full.system.mean_utilization.to_bits(),
            "time-weighted utilization must be exact under counting retention"
        );
        assert_eq!(full.util_history.len() as u64, full.util_recorded);
        assert_eq!(full.delta_history.len() as u64, full.delta_recorded);
    }

    #[test]
    fn metric_ring_and_decimate_bound_per_tick_retention() {
        let mut c = ExperimentConfig::default();
        c.sched.kind = SchedKind::Dress;
        let specs = crate::workload::congested_burst(80, 100, 0xD1CE);
        let full = run_experiment_with(&c, specs.clone(), EngineOptions::default());
        assert!(full.util_recorded > 32, "workload too small to exercise metric ring");

        let ring = run_experiment_with(
            &c,
            specs.clone(),
            EngineOptions { metrics: MetricSinkKind::Ring(16), ..Default::default() },
        );
        assert_eq!(ring.util_history.len(), 16);
        assert!(ring.delta_history.len() <= 16);
        // Chronological tail: the retained samples are the last 16 ticks.
        assert!(ring.util_history.windows(2).all(|w| w[0].0 <= w[1].0));
        assert_eq!(
            ring.util_history,
            full.util_history[full.util_history.len() - 16..].to_vec()
        );
        assert_eq!(ring.util, full.util, "ring retention must not perturb the summary");

        let deci = run_experiment_with(
            &c,
            specs,
            EngineOptions { metrics: MetricSinkKind::Decimate(8), ..Default::default() },
        );
        let kept: Vec<(Time, u32)> =
            full.util_history.iter().copied().step_by(8).collect();
        assert_eq!(deci.util_history, kept, "decimation must keep every 8th sample");
        assert_eq!(deci.util, full.util);
        assert_eq!(
            deci.system.mean_utilization.to_bits(),
            full.system.mean_utilization.to_bits()
        );
    }

    #[test]
    fn ring_sink_retains_bounded_tail() {
        let mut c = ExperimentConfig::default();
        c.sched.kind = SchedKind::Capacity;
        let specs = crate::workload::congested_burst(60, 100, 0xCAFE);
        let cap = 16;
        let res = run_experiment_with(
            &c,
            specs,
            EngineOptions { trace: SinkKind::Ring(cap), ..Default::default() },
        );
        assert!(res.tasks_recorded as usize > cap, "workload too small to exercise ring");
        assert_eq!(res.trace.tasks.len(), cap);
        assert!(res.retained_transitions <= cap);
        // The ring keeps the *latest* records: the last retained trace is
        // the final task completion of the whole run.
        let max_finish = res.trace.tasks.iter().map(|t| t.finish).max().unwrap();
        let first_submit = res.jobs.iter().map(|j| j.submit_ms).min().unwrap();
        assert_eq!(max_finish, first_submit + res.system.makespan_ms);
    }

    #[test]
    fn heap_queue_kind_matches_calendar_default() {
        let c = cfg(SchedKind::Dress);
        let specs = crate::workload::generate(
            6,
            crate::workload::WorkloadMix::Mixed,
            0.4,
            1_500,
            9,
        );
        let cal = run_experiment(&c, specs.clone());
        let heap = run_experiment_with(
            &c,
            specs,
            EngineOptions { queue: QueueKind::Heap, ..Default::default() },
        );
        assert_eq!(cal.system.makespan_ms, heap.system.makespan_ms);
        assert_eq!(cal.events, heap.events);
        assert_eq!(cal.delta_history, heap.delta_history);
        assert_eq!(cal.trace.tasks, heap.trace.tasks);
    }

    #[test]
    fn aos_layout_matches_soa_default() {
        // Quick in-module check; the full 4-scheduler matrix (plus fault
        // plans) lives in tests/golden_determinism.rs.
        let mut c = cfg(SchedKind::Dress);
        c.cluster.task_failure_prob = 0.2;
        let specs = crate::workload::generate(
            8,
            crate::workload::WorkloadMix::Mixed,
            0.4,
            1_500,
            21,
        );
        let soa = run_experiment(&c, specs.clone());
        let aos = run_experiment_with(
            &c,
            specs,
            EngineOptions { jobs: JobLayout::Aos, ..Default::default() },
        );
        assert_eq!(soa.system.makespan_ms, aos.system.makespan_ms);
        assert_eq!(soa.events, aos.events);
        assert_eq!(soa.failures, aos.failures);
        assert_eq!(soa.jobs, aos.jobs, "per-job metrics must be layout-independent");
        assert_eq!(soa.trace.tasks, aos.trace.tasks);
    }

    #[test]
    fn calendar_span_width_rule_matches_default() {
        let c = cfg(SchedKind::Dress);
        let specs = crate::workload::generate(
            6,
            crate::workload::WorkloadMix::Mixed,
            0.4,
            1_500,
            13,
        );
        let gap = run_experiment(&c, specs.clone());
        let span = run_experiment_with(
            &c,
            specs,
            EngineOptions { queue: QueueKind::CalendarSpan, ..Default::default() },
        );
        assert_eq!(gap.system.makespan_ms, span.system.makespan_ms);
        assert_eq!(gap.events, span.events);
        assert_eq!(gap.delta_history, span.delta_history);
        assert_eq!(gap.trace.tasks, span.trace.tasks);
    }

    #[test]
    fn view_check_cadence_env_override_accepted() {
        // Any cadence is semantics-preserving (the check is an assertion,
        // not behavior); this pins that the env knob parses and the run
        // still completes with a sampled cross-check.
        std::env::set_var("DRESS_VIEW_CHECK_EVERY", "7");
        let res = run_experiment(
            &cfg(SchedKind::Capacity),
            vec![tiny_job(1, 0, 2, &[2_000, 2_000])],
        );
        std::env::remove_var("DRESS_VIEW_CHECK_EVERY");
        assert_eq!(res.jobs.len(), 1);
    }

    #[test]
    fn naive_reference_path_matches_indexed_engine() {
        // Quick in-module check; the full 4-scheduler matrix (plus failure
        // injection) lives in tests/golden_determinism.rs.
        let c = cfg(SchedKind::Dress);
        let specs = crate::workload::generate(
            6,
            crate::workload::WorkloadMix::Mixed,
            0.4,
            1_500,
            5,
        );
        let fast = run_experiment(&c, specs.clone());
        let naive = run_experiment_with(
            &c,
            specs,
            EngineOptions { naive_hot_path: true, ..Default::default() },
        );
        assert_eq!(fast.system.makespan_ms, naive.system.makespan_ms);
        assert_eq!(fast.trace.tasks.len(), naive.trace.tasks.len());
        assert_eq!(fast.delta_history, naive.delta_history);
    }

    #[test]
    #[should_panic(expected = "duplicate job id")]
    fn duplicate_job_ids_rejected() {
        let specs = vec![tiny_job(1, 0, 1, &[1_000]), tiny_job(1, 500, 1, &[1_000])];
        let c = cfg(SchedKind::Fifo);
        let sched = crate::sched::build(&c.sched, c.cluster.total_containers());
        Engine::new(c, specs, sched);
    }

    #[test]
    fn sparse_job_ids_still_resolve() {
        // Ids far apart force the sorted fallback index.
        let specs = vec![
            tiny_job(7, 0, 1, &[1_000]),
            tiny_job(1_000_000, 500, 1, &[1_000]),
            tiny_job(900_000_000, 900, 1, &[1_000]),
        ];
        let res = run_experiment(&cfg(SchedKind::Capacity), specs);
        assert_eq!(res.jobs.len(), 3);
        assert_eq!(res.trace.tasks.len(), 3);
    }
}
