//! The discrete-event simulation engine: drives jobs, containers, and the
//! scheduler through heartbeat rounds, enforcing feasibility and recording
//! metrics + traces.
//!
//! Hot-path design (perf iter 4 — the indexed engine): the seed engine paid
//! an O(jobs) scan on every event (`job_index`), a second O(jobs) scan after
//! every event (`all_finished`), and rebuilt the scheduler's `ClusterView`
//! from scratch every heartbeat, so congested runs degraded quadratically
//! with job count.  This engine is O(1) per event in the job count:
//!
//! * `JobId -> slot` lookups go through a dense index ([`JobIndex`]);
//! * completion is a counter (`finished_jobs`), not a scan;
//! * the active-job view (`view_jobs`) is maintained incrementally at the
//!   event sites that change it (submit / grant / run / finish / fail) and
//!   handed to the scheduler as a borrowed slice; finished jobs are
//!   tombstoned on completion and compacted away once they outnumber live
//!   entries (O(1) amortized).
//!
//! `EngineOptions::naive_hot_path` keeps the seed's rebuild-every-tick
//! reference path alive for equivalence tests (tests/golden_determinism.rs)
//! and for the speedup measurement in benches/perf_throughput.rs.  Debug
//! builds additionally cross-check the incremental view against ground
//! truth — every tick for test-sized runs, sampled every
//! `DRESS_VIEW_CHECK_EVERY` ticks (default 64) at scale.
//!
//! Job state lives behind [`JobStore`] (perf iter 6): the default
//! struct-of-arrays layout keeps hot per-job lanes dense and all task
//! states in flat arrays, while `EngineOptions::jobs = JobLayout::Aos`
//! selects the original `JobRt` record layout as the reference path — the
//! golden suite proves both bit-identical.

use super::event::{Event, EventQueue, QueueKind};
use super::fault::OutageRecord;
use super::metric::{MetricSink, MetricSinkKind};
use super::sink::{SinkKind, TraceSink};
use super::trace::{TaskTrace, TraceRecorder};
use crate::cluster::{Cluster, ContainerState, HeartbeatLog, Transition};
use crate::config::ExperimentConfig;
use crate::jobs::{Demand, JobLayout, JobSpec, JobStore};
use crate::metrics::{DeltaSummary, JobMetrics, SystemMetrics, UtilSummary};
use crate::sched::shadow::{self, SchedSnapshot, ShadowEvent, ShadowWindow};
use crate::sched::{Allocation, ClusterView, JobView, Scheduler};
use crate::util::rng::Rng;
use crate::util::Time;

/// Outcome of one simulated experiment.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub scheduler: String,
    pub jobs: Vec<JobMetrics>,
    pub system: SystemMetrics,
    pub trace: TraceRecorder,
    /// Retained DRESS δ samples — empty for baselines, and bounded /
    /// downsampled by [`EngineOptions::metrics`] (use [`Self::delta`] for
    /// exact summary statistics under any retention).
    pub delta_history: Vec<(Time, f64)>,
    /// Retained per-tick `(time, used containers)` samples, bounded /
    /// downsampled by [`EngineOptions::metrics`] (use [`Self::util`] for
    /// exact summary statistics under any retention).
    pub util_history: Vec<(Time, u32)>,
    /// Exact time-weighted utilization summary, accumulated online —
    /// identical under every metric sink.
    pub util: UtilSummary,
    /// Exact δ-stream summary (min/max/last/time-weighted mean),
    /// accumulated online — identical under every metric sink.
    pub delta: DeltaSummary,
    /// Utilization samples observed, independent of retention
    /// (`util_history.len()` holds only what the sink kept).
    pub util_recorded: u64,
    /// δ samples observed, independent of retention.
    pub delta_recorded: u64,
    /// Injected container failures survived (task re-attempts).
    pub failures: u32,
    /// Task attempts killed by node crashes (fault plan); each was
    /// requeued and eventually re-ran to completion.
    pub lost_attempts: u32,
    /// Run-time destroyed by node crashes: `Σ (crash − run_start)` over
    /// killed Running tasks.
    pub lost_work_ms: Time,
    /// Run-time that ended in a successful completion (`Σ finish − start`
    /// over completed attempts) — the goodput numerator.
    pub useful_work_ms: Time,
    /// Run-time thrown away for any reason: crash-killed work plus the
    /// partial work of coin-flip container failures.
    pub wasted_work_ms: Time,
    /// Container attempts created over the run (completed + coin-flip
    /// failures + crash-killed; conservation is property-tested).
    pub attempts: u32,
    /// Per-outage accounting, in injection order.  Only outages whose
    /// crash actually fired during the run appear.
    pub outages: Vec<OutageRecord>,
    /// Total simulation events processed (throughput accounting).
    pub events: u64,
    /// Scheduler heartbeat rounds executed.
    pub sched_ticks: u64,
    /// Task traces observed, independent of sink retention (`trace.tasks`
    /// holds only what the sink kept).
    pub tasks_recorded: u64,
    /// Heartbeat transitions observed over the run.
    pub transitions_recorded: u64,
    /// Heartbeat transitions still held in memory at run end — bounded by
    /// the sink policy (0 for counting, `cap` for ring, all for full).
    pub retained_transitions: usize,
}

impl RunResult {
    /// Goodput: the fraction of executed run-time that ended in a
    /// successful completion, `useful / (useful + wasted)`.  1.0 when no
    /// work was wasted (including the degenerate no-work case).
    pub fn goodput(&self) -> f64 {
        let total = self.useful_work_ms + self.wasted_work_ms;
        if total == 0 {
            return 1.0;
        }
        self.useful_work_ms as f64 / total as f64
    }
}

/// Engine knobs beyond the experiment config.
#[derive(Debug, Clone, Copy)]
pub struct EngineOptions {
    /// Retention policy for task traces *and* heartbeat history (see
    /// [`SinkKind`]).  Full for figures/tests; counting for throughput
    /// runs so 100k-job sweeps hold O(active) memory instead of
    /// O(total transitions); ring to keep just the tail of a big run.
    pub trace: SinkKind,
    /// Retention policy for the per-tick metric streams (utilization, δ —
    /// see [`MetricSinkKind`]).  Summary statistics (`RunResult::util`,
    /// `RunResult::delta`, `SystemMetrics::mean_utilization`) come from
    /// exact online accumulators and are identical under every policy;
    /// this only bounds what is retained for per-sample rendering.
    pub metrics: MetricSinkKind,
    /// Event-queue implementation ([`QueueKind`]).  Calendar by default;
    /// the binary-heap reference kind exists for equivalence tests.
    pub queue: QueueKind,
    /// Rebuild the scheduler view from scratch every tick (the seed
    /// engine's behavior).  Reference path for equivalence tests and
    /// speedup baselines; simulation results are identical either way.
    pub naive_hot_path: bool,
    /// Job-state storage layout ([`JobLayout`]).  Struct-of-arrays by
    /// default; the array-of-structs reference layout exists for
    /// equivalence tests.  Simulation results are identical either way.
    pub jobs: JobLayout,
    /// Opt-in online δ auto-tuner: the DRESS scheduler shadow-replays its
    /// recent submit/complete window against candidate δ values every K
    /// heartbeats and adopts the winner (see [`crate::sched::shadow`] and
    /// docs/ADMISSION.md).  Off by default — and proven *bit-identical*
    /// off by tests/golden_determinism.rs: zero RNG draws, zero events,
    /// zero allocations.  No-op for the baseline schedulers.
    pub tune_delta: bool,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            trace: SinkKind::Full,
            metrics: MetricSinkKind::Full,
            queue: QueueKind::Calendar,
            naive_hot_path: false,
            jobs: JobLayout::Soa,
            tune_delta: false,
        }
    }
}

impl EngineOptions {
    /// The configuration throughput benches and big parallel sweeps use:
    /// counting sinks for events *and* per-tick metrics, so memory is
    /// O(active jobs) at any horizon; default queue and hot path.
    pub fn throughput() -> Self {
        EngineOptions {
            trace: SinkKind::Counting,
            metrics: MetricSinkKind::Counting,
            ..Default::default()
        }
    }
}

/// O(1) `JobId -> slot` lookup.  Job ids in this system are small
/// sequential integers, so a dense table is the common case; a sorted
/// pair list covers pathologically sparse id spaces without blowing up
/// memory.
#[derive(Debug)]
enum JobIndex {
    Dense(Vec<u32>),
    Sorted(Vec<(u32, u32)>),
}

impl JobIndex {
    fn build(specs: &[JobSpec]) -> Self {
        let max_id = specs.iter().map(|s| s.id).max().unwrap_or(0) as usize;
        if max_id <= 8 * specs.len() + 1024 {
            let mut dense = vec![u32::MAX; max_id + 1];
            for (slot, s) in specs.iter().enumerate() {
                assert_eq!(dense[s.id as usize], u32::MAX, "duplicate job id {}", s.id);
                dense[s.id as usize] = slot as u32;
            }
            JobIndex::Dense(dense)
        } else {
            let mut pairs: Vec<(u32, u32)> = specs
                .iter()
                .enumerate()
                .map(|(slot, s)| (s.id, slot as u32))
                .collect();
            pairs.sort_unstable();
            for w in pairs.windows(2) {
                assert_ne!(w[0].0, w[1].0, "duplicate job id {}", w[0].0);
            }
            JobIndex::Sorted(pairs)
        }
    }

    fn lookup(&self, id: u32) -> usize {
        let slot = match self {
            JobIndex::Dense(v) => v.get(id as usize).copied().unwrap_or(u32::MAX),
            JobIndex::Sorted(v) => v
                .binary_search_by_key(&id, |&(i, _)| i)
                .map(|i| v[i].1)
                .unwrap_or(u32::MAX),
        };
        if slot == u32::MAX {
            panic!("unknown job {id}");
        }
        slot as usize
    }
}

/// Engine-side state of one planned outage.
#[derive(Debug)]
struct OutageState {
    rec: OutageRecord,
    /// Whether the crash event has fired (outages scheduled past the end
    /// of the run never do and are excluded from results).
    fired: bool,
    /// When the node came back up (None while still down).
    node_back_at: Option<Time>,
    /// Killed tasks `(job slot, phase, task)` not yet re-completed.
    waiting: Vec<(usize, usize, usize)>,
}

/// The engine. Owns everything for one run.
pub struct Engine {
    cfg: ExperimentConfig,
    cluster: Cluster,
    /// Per-job execution state, SoA or AoS per `opts.jobs`.
    store: JobStore,
    queue: EventQueue,
    heartbeats: HeartbeatLog,
    sched: Box<dyn Scheduler>,
    rng: Rng,
    now: Time,
    sink: TraceSink,
    /// Per-tick utilization retention (policy: `opts.metrics`).
    util_sink: MetricSink<u32>,
    /// Per-tick δ retention (schedulers without a reserve ratio yield no
    /// samples).
    delta_sink: MetricSink<f64>,
    /// Exact online utilization accumulator — fed on every tick
    /// regardless of sink policy.
    util_accum: UtilSummary,
    /// Exact online δ accumulator.
    delta_accum: DeltaSummary,
    failures: u32,
    /// Provisioned capacity (crash-independent), for demand clamping:
    /// a transient outage must not permanently truncate a job's request.
    nominal_total: u32,
    /// Materialized fault plan, indexed by `Event::NodeFail/NodeRecover`
    /// payloads.
    outages: Vec<OutageState>,
    /// Outages that have crashed but not fully healed — gates the
    /// per-finish recovery bookkeeping so an empty plan pays nothing.
    open_outages: usize,
    lost_attempts: u32,
    lost_work_ms: Time,
    useful_work_ms: Time,
    wasted_work_ms: Time,
    /// Safety valve against pathological schedules.
    max_ms: Time,
    opts: EngineOptions,
    /// JobId -> slot in `jobs` (replaces the seed's linear scan).
    index: JobIndex,
    /// Jobs with `finish` set (replaces the seed's all-jobs scan).
    finished_jobs: usize,
    /// Incrementally-maintained scheduler view: submitted jobs in
    /// submission order.  Completion tombstones the entry (`finished =
    /// true`, exactly what the seed exposed; schedulers filter) and the
    /// vector is compacted once tombstones outnumber live entries, so
    /// retirement is O(1) amortized instead of an O(active) `Vec::remove`.
    view_jobs: Vec<JobView>,
    /// Slot of each `view_jobs` entry (parallel vector).
    view_slots: Vec<usize>,
    /// slot -> position in `view_jobs` (usize::MAX when absent/retired).
    view_pos: Vec<usize>,
    /// Tombstoned (finished but not yet compacted) entries in `view_jobs`.
    view_tombstones: usize,
    events: u64,
    ticks: u64,
    /// Debug-build view cross-check cadence in ticks (1 = every tick).
    #[cfg(debug_assertions)]
    view_check_every: u64,
    #[cfg(debug_assertions)]
    ticks_since_check: u64,
}

impl Engine {
    pub fn new(cfg: ExperimentConfig, specs: Vec<JobSpec>, sched: Box<dyn Scheduler>) -> Self {
        Engine::with_options(cfg, specs, sched, EngineOptions::default())
    }

    pub fn with_options(
        cfg: ExperimentConfig,
        specs: Vec<JobSpec>,
        mut sched: Box<dyn Scheduler>,
        opts: EngineOptions,
    ) -> Self {
        // Arm the opt-in shadow tuner before the first heartbeat; with the
        // flag off this is a no-op for every scheduler (default trait impl)
        // and the run stays bit-identical (tests/golden_determinism.rs).
        sched.set_tune_delta(opts.tune_delta);
        for s in &specs {
            s.validate().unwrap_or_else(|e| panic!("invalid job spec: {e}"));
        }
        let cluster = Cluster::new(cfg.cluster.nodes, cfg.cluster.slots_per_node);
        let seed = cfg.workload.seed ^ 0xD8E5_5000;
        let mut queue = EventQueue::with_kind(opts.queue);
        for s in &specs {
            queue.push(s.submit_ms, Event::JobSubmit(s.id));
        }
        queue.push(0, Event::SchedTick);
        // Fault events go in last so an empty plan leaves the sequence
        // numbers of every pre-existing event untouched (bit-identity).
        // Stochastic draws use the dedicated fault stream, never `rng`.
        let planned = cfg
            .faults
            .materialize(cfg.cluster.nodes, cfg.workload.seed)
            .unwrap_or_else(|e| panic!("invalid fault plan: {e}"));
        let mut outages = Vec::with_capacity(planned.len());
        for (i, o) in planned.iter().enumerate() {
            queue.push(o.at_ms, Event::NodeFail(i as u32));
            queue.push(o.at_ms + o.down_ms, Event::NodeRecover(i as u32));
            outages.push(OutageState {
                rec: OutageRecord {
                    node: o.node,
                    at_ms: o.at_ms,
                    down_ms: o.down_ms,
                    killed: 0,
                    lost_work_ms: 0,
                    recovered_at: None,
                },
                fired: false,
                node_back_at: None,
                waiting: Vec::new(),
            });
        }
        let index = JobIndex::build(&specs);
        let n = specs.len();
        let total = cluster.total();
        // Debug-build view-check cadence: every tick for test-sized runs
        // (the historical behavior the small goldens exercise), sampled at
        // 64 for big scenarios so debug `cargo test` survives 100k-job
        // horizons.  `DRESS_VIEW_CHECK_EVERY` overrides either default.
        #[cfg(debug_assertions)]
        let view_check_every = match std::env::var("DRESS_VIEW_CHECK_EVERY")
            .ok()
            .and_then(|s| s.parse::<u64>().ok())
        {
            Some(k) => k.max(1),
            None if n <= 1_024 => 1,
            None => 64,
        };
        Engine {
            cfg,
            cluster,
            store: JobStore::new(specs, opts.jobs),
            queue,
            heartbeats: HeartbeatLog::with_retention(opts.trace),
            sched,
            rng: Rng::new(seed),
            now: 0,
            sink: TraceSink::new(opts.trace),
            util_sink: MetricSink::new(opts.metrics),
            delta_sink: MetricSink::new(opts.metrics),
            util_accum: UtilSummary::new(total),
            delta_accum: DeltaSummary::default(),
            failures: 0,
            nominal_total: total,
            outages,
            open_outages: 0,
            lost_attempts: 0,
            lost_work_ms: 0,
            useful_work_ms: 0,
            wasted_work_ms: 0,
            max_ms: 40 * 3_600 * 1_000, // 40 simulated hours
            opts,
            index,
            finished_jobs: 0,
            view_jobs: Vec::new(),
            view_slots: Vec::new(),
            view_pos: vec![usize::MAX; n],
            view_tombstones: 0,
            events: 0,
            ticks: 0,
            #[cfg(debug_assertions)]
            view_check_every,
            #[cfg(debug_assertions)]
            ticks_since_check: 0,
        }
    }

    fn job_index(&self, id: u32) -> usize {
        self.index.lookup(id)
    }

    fn all_finished(&self) -> bool {
        self.finished_jobs == self.store.len()
    }

    // --- incremental view maintenance -----------------------------------

    /// A job's demand as the engine honors it.  Two clamps, both no-ops
    /// for uniform (scalar) demands:
    ///
    /// * per axis to the *nominal* cluster totals — a demand above cluster
    ///   capacity can never gang-start, and nominal (not live) capacity
    ///   means a transient outage does not truncate the request forever;
    /// * on the memory axis to `cpu × max_node_mem` — a per-container
    ///   footprint wider than the fattest node fits nowhere, so an
    ///   unclamped value would starve the job (and hang the run).
    fn effective_demand(&self, slot: usize) -> Demand {
        let d = self.store.demand(slot).min_each(Demand::new(
            self.nominal_total,
            self.cluster.nominal_total_mem(),
        ));
        let fit = d.cpu.max(1).saturating_mul(self.cluster.max_node_mem().max(1));
        Demand::new(d.cpu, d.mem.min(fit))
    }

    /// Admit `slot` into the scheduler view at its submission-order
    /// position.  Submissions arrive in event-time order, which for every
    /// workload in this repo is also slot order, so the common case is an
    /// O(1) push; an out-of-order submit time falls back to a sorted
    /// insert.
    fn view_insert(&mut self, slot: usize) {
        let jv = JobView {
            id: self.store.id(slot),
            demand: self.effective_demand(slot),
            submit_ms: self.store.submit_ms(slot),
            started: self.store.started(slot),
            finished: false,
            pending_tasks: self.store.pending_tasks(slot),
            occupied: self.store.occupied(slot),
        };
        if self.view_slots.last().is_none_or(|&s| s < slot) {
            self.view_pos[slot] = self.view_jobs.len();
            self.view_jobs.push(jv);
            self.view_slots.push(slot);
            return;
        }
        let pos = self.view_slots.partition_point(|&s| s < slot);
        self.view_jobs.insert(pos, jv);
        self.view_slots.insert(pos, slot);
        for &s in &self.view_slots[pos + 1..] {
            if self.view_pos[s] != usize::MAX {
                self.view_pos[s] += 1;
            }
        }
        self.view_pos[slot] = pos;
    }

    /// Retire a completed job from the view: tombstone the entry
    /// (`finished = true` — the seed exposed exactly this and every
    /// scheduler filters it) and compact once tombstones outnumber live
    /// entries, so retirement is O(1) amortized.
    fn view_retire(&mut self, slot: usize) {
        let pos = self.view_pos[slot];
        debug_assert_ne!(pos, usize::MAX, "retire of job not in view");
        self.view_jobs[pos].finished = true;
        self.view_pos[slot] = usize::MAX;
        self.view_tombstones += 1;
        if self.view_tombstones * 2 > self.view_jobs.len() {
            self.view_compact();
        }
    }

    /// Drop tombstoned entries, preserving order (O(len), amortized O(1)
    /// per retirement by the doubling rule in [`Self::view_retire`]).
    fn view_compact(&mut self) {
        let mut w = 0;
        for r in 0..self.view_jobs.len() {
            if !self.view_jobs[r].finished {
                let slot = self.view_slots[r];
                self.view_jobs[w] = self.view_jobs[r];
                self.view_slots[w] = slot;
                self.view_pos[slot] = w;
                w += 1;
            }
        }
        self.view_jobs.truncate(w);
        self.view_slots.truncate(w);
        self.view_tombstones = 0;
    }

    /// The view entry of an active job (O(1)).
    fn view_entry(&mut self, slot: usize) -> &mut JobView {
        let pos = self.view_pos[slot];
        debug_assert_ne!(pos, usize::MAX, "view entry of inactive job");
        &mut self.view_jobs[pos]
    }

    /// Seed-identical per-tick view rebuild: every submitted job, finished
    /// ones included with `finished = true` (schedulers filter them).
    /// Reference path for `EngineOptions::naive_hot_path`.
    fn naive_view_jobs(&self) -> Vec<JobView> {
        (0..self.store.len())
            .filter(|&slot| self.store.submitted(slot))
            .map(|slot| JobView {
                id: self.store.id(slot),
                demand: self.effective_demand(slot),
                submit_ms: self.store.submit_ms(slot),
                started: self.store.started(slot),
                finished: self.store.finished(slot),
                pending_tasks: self.store.pending_tasks(slot),
                occupied: self.store.occupied(slot),
            })
            .collect()
    }

    /// Debug-build cross-check: the incremental view must equal ground
    /// truth derived from the job store (runs every
    /// `view_check_every`-th tick under `cargo test`, so the whole suite
    /// exercises the equivalence).
    #[cfg(debug_assertions)]
    fn assert_view_consistent(&self) {
        let mut live = 0;
        for slot in 0..self.store.len() {
            let id = self.store.id(slot);
            if self.store.submitted(slot) && !self.store.finished(slot) {
                let pos = self.view_pos[slot];
                assert_ne!(pos, usize::MAX, "active job {id} missing from view");
                let v = &self.view_jobs[pos];
                assert_eq!(v.id, id);
                assert!(!v.finished, "J{id} live entry tombstoned");
                assert_eq!(v.started, self.store.started(slot), "J{id} started drift");
                assert_eq!(
                    v.pending_tasks,
                    self.store.pending_tasks(slot),
                    "J{id} pending drift"
                );
                assert_eq!(v.occupied, self.store.occupied(slot), "J{id} occupied drift");
                live += 1;
            } else {
                assert_eq!(self.view_pos[slot], usize::MAX, "inactive job indexed in view");
            }
        }
        assert_eq!(self.view_jobs.iter().filter(|v| !v.finished).count(), live);
        assert_eq!(
            self.view_jobs.iter().filter(|v| v.finished).count(),
            self.view_tombstones
        );
    }

    // --- event handlers --------------------------------------------------

    /// Apply one feasible allocation: create containers in the YARN state
    /// machine for up to `n` pending tasks of the job.
    fn apply_allocation(&mut self, alloc: Allocation) {
        let ji = self.job_index(alloc.job);
        let mem = self.effective_demand(ji).mem_per_container().max(1);
        for _ in 0..alloc.n {
            if self.cluster.free() == 0 {
                break;
            }
            let Some((phase, task)) = self.store.next_pending(ji) else {
                break;
            };
            // With vector demands a slot-feasible grant can still fail
            // node-level memory packing (fragmentation); for uniform
            // demands `mem == 1` and free slots always admit, as before.
            let Some(cid) = self.cluster.allocate(alloc.job, phase, task, mem, self.now)
            else {
                break;
            };
            self.store.begin_launch(ji, phase, task, cid);
            let v = self.view_entry(ji);
            v.occupied += 1;
            v.pending_tasks -= 1;
            self.record_transition(cid, ContainerState::New);
            self.schedule_advance(cid);
        }
    }

    fn record_transition(&mut self, cid: u32, to: ContainerState) {
        let c = self.cluster.container(cid);
        self.heartbeats.record(Transition {
            time: self.now,
            container: cid,
            job: c.job,
            task: c.task,
            to,
        });
    }

    /// Sample the delay for the container's next state hop and enqueue it.
    fn schedule_advance(&mut self, cid: u32) {
        let state = self.cluster.container(cid).state;
        let d = &self.cfg.cluster.delays;
        let median = match state {
            ContainerState::New => d.new_to_reserved_ms,
            ContainerState::Reserved => d.reserved_to_allocated_ms,
            ContainerState::Allocated => d.allocated_to_acquired_ms,
            ContainerState::Acquired => d.acquired_to_running_ms,
            _ => return,
        };
        let delay = self.rng.lognormal(median, d.sigma).max(1.0) as Time;
        self.queue.push(self.now + delay, Event::ContainerAdvance(cid));
    }

    fn on_container_advance(&mut self, cid: u32) {
        // The queue cannot remove entries, so events for containers killed
        // by a node crash still fire — and must be ignored.
        if self.cluster.container(cid).dead {
            return;
        }
        let new_state = self.cluster.container_mut(cid).advance(self.now);
        self.record_transition(cid, new_state);
        let (job, phase, task) = {
            let c = self.cluster.container(cid);
            (c.job, c.phase, c.task)
        };
        if new_state == ContainerState::Running {
            let ji = self.job_index(job);
            let dur = self.store.begin_run(ji, phase, task, cid, self.now);
            self.view_entry(ji).started = true;
            // Failure injection: the container may die mid-task; the task
            // is then re-attempted in a fresh container (YARN AM behavior).
            let pf = self.cfg.cluster.task_failure_prob;
            if pf > 0.0 && self.rng.chance(pf) {
                let at = self.now + (dur as f64 * self.rng.range_f64(0.1, 0.9)) as Time;
                self.queue.push(at.max(self.now + 1), Event::TaskFail(cid));
            } else {
                self.queue.push(self.now + dur, Event::TaskFinish(cid));
            }
        } else {
            self.schedule_advance(cid);
        }
    }

    fn on_task_finish(&mut self, cid: u32) {
        if self.cluster.container(cid).dead {
            return;
        }
        let new_state = self.cluster.container_mut(cid).advance(self.now);
        debug_assert_eq!(new_state, ContainerState::Completed);
        self.record_transition(cid, ContainerState::Completed);
        let (job, phase, task, run_start) = {
            let c = self.cluster.container(cid);
            (c.job, c.phase, c.task, c.run_start)
        };
        self.cluster.release(cid);

        let ji = self.job_index(job);
        let fin = self.store.finish_task(ji, phase, task, self.now);
        debug_assert_eq!(fin.start, run_start);
        self.view_entry(ji).occupied -= 1;
        self.useful_work_ms += self.now - fin.start;
        if self.open_outages > 0 {
            self.note_recompletion(ji, phase, task);
        }
        self.sink.record(TaskTrace {
            job,
            phase,
            task,
            granted: run_start, // grant time folded into startup elsewhere
            start: fin.start,
            finish: self.now,
        });
        if fin.finished_job {
            self.finished_jobs += 1;
            self.view_retire(ji);
        } else if fin.phase_advanced {
            // Barrier crossed: the newly-runnable phase is all-Pending.
            let pending = self.store.pending_tasks(ji);
            self.view_entry(ji).pending_tasks = pending;
        }
    }

    /// Container dies mid-task: release the slot, reset the task to
    /// Pending so the scheduler re-grants it.
    fn on_task_fail(&mut self, cid: u32) {
        if self.cluster.container(cid).dead {
            return;
        }
        let new_state = self.cluster.container_mut(cid).advance(self.now);
        debug_assert_eq!(new_state, ContainerState::Completed);
        self.record_transition(cid, ContainerState::Completed);
        let (job, phase, task, run_start) = {
            let c = self.cluster.container(cid);
            (c.job, c.phase, c.task, c.run_start)
        };
        self.cluster.release(cid);
        self.wasted_work_ms += self.now - run_start;
        let ji = self.job_index(job);
        let was_running = self.store.requeue_task(ji, phase, task);
        debug_assert!(was_running.is_some(), "coin-flip fail of non-running task");
        let v = self.view_entry(ji);
        v.occupied -= 1;
        v.pending_tasks += 1;
        self.failures += 1;
    }

    /// A node crashes: its capacity leaves `total`, every container on it
    /// dies, and the killed tasks requeue as Pending (with their accrued
    /// run-time counted as lost).  No Completed heartbeat transition is
    /// recorded for killed containers — the node vanished, it did not
    /// report.
    fn on_node_fail(&mut self, oidx: u32) {
        let oidx = oidx as usize;
        let node = self.outages[oidx].rec.node;
        let killed = self.cluster.fail_node(node, self.now);
        let mut lost: Time = 0;
        for &cid in &killed {
            let (job, phase, task) = {
                let c = self.cluster.container(cid);
                (c.job, c.phase, c.task)
            };
            let ji = self.job_index(job);
            if let Some(start) = self.store.requeue_task(ji, phase, task) {
                lost += self.now - start;
            }
            let v = self.view_entry(ji);
            v.occupied -= 1;
            v.pending_tasks += 1;
            self.outages[oidx].waiting.push((ji, phase, task));
        }
        self.lost_attempts += killed.len() as u32;
        self.lost_work_ms += lost;
        self.wasted_work_ms += lost;
        let o = &mut self.outages[oidx];
        o.fired = true;
        o.rec.killed = killed.len() as u32;
        o.rec.lost_work_ms = lost;
        self.open_outages += 1;
    }

    /// The node comes back: its (empty) slots rejoin capacity.  The outage
    /// is healed once the node is up AND every task it killed re-completed.
    fn on_node_recover(&mut self, oidx: u32) {
        let oidx = oidx as usize;
        let node = self.outages[oidx].rec.node;
        self.cluster.recover_node(node);
        let o = &mut self.outages[oidx];
        o.node_back_at = Some(self.now);
        if o.waiting.is_empty() && o.rec.recovered_at.is_none() {
            o.rec.recovered_at = Some(self.now);
            self.open_outages -= 1;
        }
    }

    /// A task just completed; clear it from every open outage still
    /// waiting on it (a task can appear in several if re-killed).  Only
    /// called while an outage is open, so the empty-plan fast path never
    /// touches this.
    fn note_recompletion(&mut self, ji: usize, phase: usize, task: usize) {
        let now = self.now;
        let mut healed = 0;
        for o in self.outages.iter_mut() {
            if !o.fired || o.rec.recovered_at.is_some() {
                continue;
            }
            if let Some(p) = o.waiting.iter().position(|&w| w == (ji, phase, task)) {
                o.waiting.swap_remove(p);
                if o.waiting.is_empty() && o.node_back_at.is_some() {
                    o.rec.recovered_at = Some(now);
                    healed += 1;
                }
            }
        }
        self.open_outages -= healed;
    }

    fn on_sched_tick(&mut self) {
        self.ticks += 1;
        let transitions = self.heartbeats.drain();
        #[cfg(debug_assertions)]
        {
            self.ticks_since_check += 1;
            if self.ticks_since_check >= self.view_check_every {
                self.ticks_since_check = 0;
                self.assert_view_consistent();
            }
        }
        // Indexed path: borrow the maintained active-job slice — O(1).
        // Naive path: rebuild from scratch like the seed engine did.
        let scratch: Vec<JobView>;
        let view_jobs: &[JobView] = if self.opts.naive_hot_path {
            scratch = self.naive_view_jobs();
            &scratch
        } else {
            &self.view_jobs
        };
        let view = ClusterView {
            now: self.now,
            free: self.cluster.free(),
            total: self.cluster.total(),
            free_mem: self.cluster.free_mem(),
            total_mem: self.cluster.total_mem(),
            jobs: view_jobs,
            transitions: &transitions,
        };
        let allocs = self.sched.schedule(&view);
        // Feasibility enforcement: total grants bounded by free capacity
        // on every axis (the memory clamp is a no-op for uniform demands,
        // where footprint is 1 and free_mem tracks free exactly).
        let mut free = self.cluster.free();
        let mut free_mem = self.cluster.free_mem();
        for a in allocs {
            let ji = self.job_index(a.job);
            let pending = self.store.pending_tasks(ji);
            let mem = self.effective_demand(ji).mem_per_container().max(1);
            let n = a.n.min(pending).min(free).min(free_mem / mem);
            if n == 0 {
                continue;
            }
            free -= n;
            free_mem -= n * mem;
            self.apply_allocation(Allocation { job: a.job, n });
        }
        let used = self.cluster.used();
        self.util_sink.record(self.now, used);
        self.util_accum.push(self.now, used);
        if let Some(delta) = self.sched.reserve_ratio() {
            self.delta_sink.record(self.now, delta);
            self.delta_accum.push(self.now, delta);
        }
        debug_assert!(self.cluster.conservation_holds());
        if !self.all_finished() {
            self.queue
                .push(self.now + self.cfg.cluster.hb_ms, Event::SchedTick);
        }
    }

    /// Advance the simulation by exactly one event.  Returns `false` once
    /// the run is over (every job finished, or the queue drained).
    ///
    /// `run()` is just `while self.step() {}` + [`Self::finish`]; the
    /// stepping form exists so tests can interleave read-only
    /// [`Self::probe`]s with live execution and fingerprint the state
    /// between events (tests/properties.rs probe-purity property).
    pub fn step(&mut self) -> bool {
        if self.all_finished() {
            return false;
        }
        let Some((t, ev)) = self.queue.pop() else {
            return false;
        };
        assert!(t >= self.now, "time went backwards");
        self.now = t;
        if self.now > self.max_ms {
            panic!("simulation exceeded {} ms — livelocked schedule?", self.max_ms);
        }
        self.events += 1;
        match ev {
            Event::JobSubmit(id) => {
                let ji = self.job_index(id);
                self.store.mark_submitted(ji);
                self.view_insert(ji);
            }
            Event::SchedTick => self.on_sched_tick(),
            Event::ContainerAdvance(cid) => self.on_container_advance(cid),
            Event::TaskFinish(cid) => self.on_task_finish(cid),
            Event::TaskFail(cid) => self.on_task_fail(cid),
            Event::NodeFail(o) => self.on_node_fail(o),
            Event::NodeRecover(o) => self.on_node_recover(o),
            // Reservation timeouts live in the admission layer's private
            // queue (live/admission.rs), never in the engine's; the arm
            // exists only for exhaustiveness and is inert by design.
            Event::ReservationExpire(_) => {}
        }
        !self.all_finished()
    }

    /// Read-only admission probe against the live engine: snapshot the
    /// scheduler's tunable state (or a neutral view-only snapshot for
    /// baselines), overlay one hypothetical `demand`-container arrival,
    /// and shadow-replay it.  Purity is structural — `&self`, no RNG
    /// stream access, no event pushes — and is property-tested: N probes
    /// leave [`Self::state_fingerprint`] exactly unchanged.
    pub fn probe(&self, demand: u32) -> shadow::ShadowScore {
        let jobs = self.naive_view_jobs();
        let view = ClusterView {
            now: self.now,
            free: self.cluster.free(),
            total: self.cluster.total(),
            free_mem: self.cluster.free_mem(),
            total_mem: self.cluster.total_mem(),
            jobs: &jobs,
            transitions: &[],
        };
        let snap = self.sched.snapshot(&view).unwrap_or_else(|| {
            SchedSnapshot::of_view(
                view.now,
                view.free,
                view.total,
                view.jobs,
                self.sched.reserve_ratio().unwrap_or(self.cfg.sched.delta0),
                self.cfg.sched.theta,
            )
        });
        let mut window = ShadowWindow::new(1);
        let next_id = jobs.iter().map(|j| j.id).max().unwrap_or(0) + 1;
        window.push(ShadowEvent::Submit { job: next_id, demand, at: self.now });
        shadow::replay(&snap, &window, snap.delta, shadow::REPLAY_TICKS)
    }

    /// FNV-1a-64 digest of the full observable simulation state: job-store
    /// lanes, event-queue shape, the scheduler view, classifier/estimator
    /// state and δ (via the scheduler snapshot), the exact metric
    /// accumulators, and every progress counter.  Equal fingerprints mean
    /// the two engines are in identical simulation states; the probe-purity
    /// property (tests/properties.rs) pins that probes never move it.
    pub fn state_fingerprint(&self) -> u64 {
        let jobs = self.naive_view_jobs();
        let view = ClusterView {
            now: self.now,
            free: self.cluster.free(),
            total: self.cluster.total(),
            free_mem: self.cluster.free_mem(),
            total_mem: self.cluster.total_mem(),
            jobs: &jobs,
            transitions: &[],
        };
        let snap = self.sched.snapshot(&view);
        let repr = format!(
            "{}|{}|{}|{}|{:?}|{}|{}|{:?}|{:?}|{}|{}|{:?}|{:?}|{:?}|{:?}",
            self.now,
            self.events,
            self.ticks,
            self.queue.len(),
            self.queue.peek_time(),
            self.cluster.free(),
            self.cluster.total(),
            self.sched.reserve_ratio(),
            snap,
            self.finished_jobs,
            self.failures,
            jobs,
            self.store,
            self.util_accum,
            self.delta_accum,
        );
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in repr.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        h
    }

    /// Run to completion and produce the result bundle.
    pub fn run(mut self) -> RunResult {
        while self.step() {}
        self.finish()
    }

    /// Consume a completed engine into its [`RunResult`].  Panics if jobs
    /// remain unfinished (starvation) — callers drive [`Self::step`] to
    /// `false` first.
    pub fn finish(self) -> RunResult {
        assert!(self.all_finished(), "run ended with unfinished jobs (starvation)");

        let jobs: Vec<JobMetrics> = self.store.metrics();
        // Utilization comes from the online accumulator, never from the
        // retained samples — exact under every metric-sink policy.
        let system = SystemMetrics::of(&jobs, &self.util_accum);
        let (trace, tasks_recorded) = self.sink.finish();
        let (util_history, util_recorded) = self.util_sink.finish();
        let (delta_history, delta_recorded) = self.delta_sink.finish();
        RunResult {
            scheduler: self.sched.name().to_string(),
            jobs,
            system,
            trace,
            delta_history,
            util_history,
            util: self.util_accum,
            delta: self.delta_accum,
            util_recorded,
            delta_recorded,
            failures: self.failures,
            lost_attempts: self.lost_attempts,
            lost_work_ms: self.lost_work_ms,
            useful_work_ms: self.useful_work_ms,
            wasted_work_ms: self.wasted_work_ms,
            attempts: self.cluster.containers.len() as u32,
            outages: self
                .outages
                .iter()
                .filter(|o| o.fired)
                .map(|o| o.rec)
                .collect(),
            events: self.events,
            sched_ticks: self.ticks,
            tasks_recorded,
            transitions_recorded: self.heartbeats.recorded(),
            retained_transitions: self.heartbeats.history_len(),
        }
    }
}

/// Convenience: build + run one experiment with the configured scheduler.
pub fn run_experiment(cfg: &ExperimentConfig, specs: Vec<JobSpec>) -> RunResult {
    let sched = crate::sched::build(&cfg.sched, cfg.cluster.total_containers());
    Engine::new(cfg.clone(), specs, sched).run()
}

/// `run_experiment` with explicit [`EngineOptions`] (benches use this for
/// trace opt-out and for the naive-path speedup baseline).
pub fn run_experiment_with(
    cfg: &ExperimentConfig,
    specs: Vec<JobSpec>,
    opts: EngineOptions,
) -> RunResult {
    let sched = crate::sched::build(&cfg.sched, cfg.cluster.total_containers());
    Engine::with_options(cfg.clone(), specs, sched, opts).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchedKind;
    use crate::jobs::{PhaseKind, PhaseSpec, Platform};
    use crate::sched::dress::reserve::{DELTA_MAX, DELTA_MIN};

    fn tiny_job(id: u32, submit: Time, demand: u32, durs: &[Time]) -> JobSpec {
        JobSpec {
            id,
            name: format!("job{id}"),
            platform: Platform::MapReduce,
            submit_ms: submit,
            demand: Demand::scalar(demand),
            phases: vec![PhaseSpec::new(PhaseKind::Map, durs)],
        }
    }

    fn cfg(kind: SchedKind) -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.cluster.nodes = 2;
        c.cluster.slots_per_node = 3;
        c.sched.kind = kind;
        c
    }

    #[test]
    fn single_job_completes() {
        let res = run_experiment(&cfg(SchedKind::Fifo), vec![tiny_job(1, 0, 2, &[5_000, 5_000])]);
        assert_eq!(res.jobs.len(), 1);
        let j = &res.jobs[0];
        assert!(j.waiting_ms > 0, "startup delays imply nonzero waiting");
        assert!(j.completion_ms >= 5_000);
        assert_eq!(res.trace.tasks.len(), 2);
        assert!(res.events > 0 && res.sched_ticks > 0, "throughput counters populated");
    }

    #[test]
    fn all_schedulers_complete_congested_mix() {
        let specs = vec![
            tiny_job(1, 0, 4, &[8_000, 8_000, 9_000, 9_000]),
            tiny_job(2, 1_000, 4, &[7_000, 7_000, 7_000, 7_000]),
            tiny_job(3, 2_000, 2, &[3_000, 3_000]),
            tiny_job(4, 3_000, 2, &[4_000, 4_000]),
        ];
        for kind in [
            SchedKind::Fifo,
            SchedKind::Fair,
            SchedKind::Capacity,
            SchedKind::Dress,
            SchedKind::MaxWeight,
        ] {
            let res = run_experiment(&cfg(kind), specs.clone());
            assert_eq!(res.jobs.len(), 4, "{kind:?}");
            assert!(res.system.makespan_ms > 0);
            assert_eq!(res.trace.tasks.len(), 12, "{kind:?}: every task ran");
        }
    }

    #[test]
    fn dress_records_delta_history() {
        let res = run_experiment(&cfg(SchedKind::Dress), vec![tiny_job(1, 0, 2, &[2_000, 2_000])]);
        assert!(!res.delta_history.is_empty());
        // δ is clamped into the documented reserve band (Algorithm 3);
        // asserted with the same inclusive range everywhere.
        assert!(res
            .delta_history
            .iter()
            .all(|&(_, d)| (DELTA_MIN..=DELTA_MAX).contains(&d)));
        let fifo = run_experiment(&cfg(SchedKind::Fifo), vec![tiny_job(1, 0, 2, &[2_000, 2_000])]);
        assert!(fifo.delta_history.is_empty());
    }

    #[test]
    fn multi_phase_barrier_ordering() {
        let spec = JobSpec {
            id: 1,
            name: "two-phase".into(),
            platform: Platform::MapReduce,
            submit_ms: 0,
            demand: Demand::scalar(3),
            phases: vec![
                PhaseSpec::new(PhaseKind::Map, &[4_000, 4_500, 5_000]),
                PhaseSpec::new(PhaseKind::Reduce, &[3_000]),
            ],
        };
        let res = run_experiment(&cfg(SchedKind::Capacity), vec![spec]);
        let map_finish = res
            .trace
            .tasks
            .iter()
            .filter(|t| t.phase == 0)
            .map(|t| t.finish)
            .max()
            .unwrap();
        let reduce_start = res
            .trace
            .tasks
            .iter()
            .find(|t| t.phase == 1)
            .map(|t| t.start)
            .unwrap();
        assert!(
            reduce_start >= map_finish,
            "reduce started {reduce_start} before last map finished {map_finish}"
        );
    }

    #[test]
    fn failure_injection_retries_until_done() {
        let mut c = cfg(SchedKind::Capacity);
        c.cluster.task_failure_prob = 0.3;
        let specs = vec![
            tiny_job(1, 0, 3, &[4_000, 4_000, 4_000]),
            tiny_job(2, 1_000, 2, &[3_000, 3_000]),
        ];
        let res = run_experiment(&c, specs);
        // All tasks eventually completed despite failures; failed attempts
        // do not appear in the trace (only successful runs do).
        assert_eq!(res.trace.tasks.len(), 5);
        assert!(res.failures > 0, "with p=0.3 over 5+ attempts, expect failures");
        // Failures lengthen the run vs the failure-free baseline.
        let mut clean = cfg(SchedKind::Capacity);
        clean.cluster.task_failure_prob = 0.0;
        let base = run_experiment(&clean, vec![
            tiny_job(1, 0, 3, &[4_000, 4_000, 4_000]),
            tiny_job(2, 1_000, 2, &[3_000, 3_000]),
        ]);
        assert_eq!(base.failures, 0);
        assert!(res.system.makespan_ms >= base.system.makespan_ms);
    }

    #[test]
    fn dress_survives_failures_under_congestion() {
        let mut c = cfg(SchedKind::Dress);
        c.cluster.task_failure_prob = 0.15;
        let specs = crate::workload::generate(
            8,
            crate::workload::WorkloadMix::Mixed,
            0.3,
            2_000,
            11,
        );
        let expected: usize = specs.iter().map(|s| s.total_tasks() as usize).sum();
        let res = run_experiment(&c, specs);
        assert_eq!(res.trace.tasks.len(), expected);
        // Same clamp band as dress_records_delta_history (inclusive).
        assert!(res
            .delta_history
            .iter()
            .all(|&(_, d)| (DELTA_MIN..=DELTA_MAX).contains(&d)));
    }

    #[test]
    fn node_crash_requeues_and_recovers() {
        let mut c = cfg(SchedKind::Capacity);
        c.faults = crate::sim::fault::FaultPlan::empty().with_outage(6_000, 0, 20_000);
        let specs = vec![
            tiny_job(1, 0, 4, &[8_000, 8_000, 9_000, 9_000]),
            tiny_job(2, 1_000, 2, &[7_000, 7_000]),
        ];
        let res = run_experiment(&c, specs.clone());
        assert_eq!(res.trace.tasks.len(), 6, "every task completed despite the crash");
        assert_eq!(res.outages.len(), 1);
        let o = &res.outages[0];
        assert!(o.killed > 0, "node 0 held running containers at t=6 s");
        assert_eq!(res.lost_attempts, o.killed);
        assert!(res.lost_work_ms > 0 && o.lost_work_ms == res.lost_work_ms);
        assert!(o.recovered_at.is_some(), "short downtime heals within the run");
        assert!(o.time_to_recover_ms().unwrap() >= 20_000, "downtime bounds recovery");
        assert!(res.goodput() < 1.0, "killed work must dent goodput");
        assert!(res.wasted_work_ms >= res.lost_work_ms);
        // Conservation: every attempt completed, coin-failed, or was killed.
        assert_eq!(
            res.attempts as usize,
            res.trace.tasks.len() + res.failures as usize + res.lost_attempts as usize
        );
        // The no-fault baseline is untouched and no slower.
        let base = run_experiment(&cfg(SchedKind::Capacity), specs);
        assert!(base.outages.is_empty() && base.lost_attempts == 0);
        assert_eq!(base.goodput(), 1.0);
        assert!(res.system.makespan_ms >= base.system.makespan_ms);
    }

    #[test]
    fn crash_of_idle_node_heals_at_recovery_time() {
        // Nothing runs on the crashed node: killed == 0, recovery is
        // exactly the configured downtime.
        let mut c = cfg(SchedKind::Fifo);
        c.cluster.nodes = 3;
        c.faults = crate::sim::fault::FaultPlan::empty().with_outage(1, 2, 5_000);
        let res = run_experiment(&c, vec![tiny_job(1, 0, 1, &[2_000])]);
        assert_eq!(res.outages.len(), 1);
        let o = &res.outages[0];
        assert!(res.jobs[0].completion_ms > 0);
        if o.killed == 0 {
            assert_eq!(o.lost_work_ms, 0);
            // Healing may still require the run to outlive the downtime.
            if let Some(t) = o.time_to_recover_ms() {
                assert_eq!(t, 5_000);
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let specs = vec![tiny_job(1, 0, 3, &[4_000, 5_000, 6_000])];
        let a = run_experiment(&cfg(SchedKind::Capacity), specs.clone());
        let b = run_experiment(&cfg(SchedKind::Capacity), specs);
        assert_eq!(a.system.makespan_ms, b.system.makespan_ms);
        assert_eq!(a.jobs[0].waiting_ms, b.jobs[0].waiting_ms);
    }

    #[test]
    fn counting_sink_skips_retention_without_changing_results() {
        let c = cfg(SchedKind::Capacity);
        let specs = vec![
            tiny_job(1, 0, 2, &[3_000, 3_000]),
            tiny_job(2, 1_000, 2, &[2_000, 2_000]),
        ];
        let on = run_experiment(&c, specs.clone());
        let off = run_experiment_with(
            &c,
            specs,
            EngineOptions { trace: SinkKind::Counting, ..Default::default() },
        );
        assert_eq!(on.trace.tasks.len(), 4);
        assert!(off.trace.tasks.is_empty(), "counting sink must not retain traces");
        assert_eq!(off.tasks_recorded, 4, "counting sink still counts every task");
        assert_eq!(on.system.makespan_ms, off.system.makespan_ms);
        assert_eq!(on.events, off.events, "recording must not alter the simulation");
    }

    #[test]
    fn counting_sink_bounds_heartbeat_and_trace_memory() {
        // The at-scale memory guarantee, shrunk to test size: a congested
        // burst under the counting sink retains NO history while observing
        // exactly what the full sink observes.
        let mut c = ExperimentConfig::default();
        c.sched.kind = SchedKind::Dress;
        let specs = crate::workload::congested_burst(150, 100, 0xBEEF);
        let full = run_experiment_with(&c, specs.clone(), EngineOptions::default());
        let lean = run_experiment_with(&c, specs, EngineOptions::throughput());
        // Identical simulation...
        assert_eq!(full.system.makespan_ms, lean.system.makespan_ms);
        assert_eq!(full.events, lean.events);
        // ...identical observation counts...
        assert_eq!(full.tasks_recorded, lean.tasks_recorded);
        assert_eq!(full.transitions_recorded, lean.transitions_recorded);
        assert!(lean.transitions_recorded > 0);
        // ...but O(1) retention instead of O(total transitions).
        assert_eq!(lean.retained_transitions, 0, "counting sink retained history");
        assert!(lean.trace.tasks.is_empty());
        assert_eq!(full.retained_transitions as u64, full.transitions_recorded);
        // Per-tick metric streams are bounded the same way: zero retained
        // samples, yet the exact accumulators agree bit-for-bit.
        assert!(lean.util_history.is_empty() && lean.delta_history.is_empty());
        assert_eq!(lean.util_recorded, full.util_recorded);
        assert_eq!(lean.delta_recorded, full.delta_recorded);
        assert!(lean.util_recorded > 0 && lean.delta_recorded > 0, "dress streams populated");
        assert_eq!(lean.util, full.util, "utilization summary must not depend on retention");
        assert_eq!(lean.delta, full.delta);
        assert_eq!(
            lean.system.mean_utilization.to_bits(),
            full.system.mean_utilization.to_bits(),
            "time-weighted utilization must be exact under counting retention"
        );
        assert_eq!(full.util_history.len() as u64, full.util_recorded);
        assert_eq!(full.delta_history.len() as u64, full.delta_recorded);
    }

    #[test]
    fn metric_ring_and_decimate_bound_per_tick_retention() {
        let mut c = ExperimentConfig::default();
        c.sched.kind = SchedKind::Dress;
        let specs = crate::workload::congested_burst(80, 100, 0xD1CE);
        let full = run_experiment_with(&c, specs.clone(), EngineOptions::default());
        assert!(full.util_recorded > 32, "workload too small to exercise metric ring");

        let ring = run_experiment_with(
            &c,
            specs.clone(),
            EngineOptions { metrics: MetricSinkKind::Ring(16), ..Default::default() },
        );
        assert_eq!(ring.util_history.len(), 16);
        assert!(ring.delta_history.len() <= 16);
        // Chronological tail: the retained samples are the last 16 ticks.
        assert!(ring.util_history.windows(2).all(|w| w[0].0 <= w[1].0));
        assert_eq!(
            ring.util_history,
            full.util_history[full.util_history.len() - 16..].to_vec()
        );
        assert_eq!(ring.util, full.util, "ring retention must not perturb the summary");

        let deci = run_experiment_with(
            &c,
            specs,
            EngineOptions { metrics: MetricSinkKind::Decimate(8), ..Default::default() },
        );
        let kept: Vec<(Time, u32)> =
            full.util_history.iter().copied().step_by(8).collect();
        assert_eq!(deci.util_history, kept, "decimation must keep every 8th sample");
        assert_eq!(deci.util, full.util);
        assert_eq!(
            deci.system.mean_utilization.to_bits(),
            full.system.mean_utilization.to_bits()
        );
    }

    #[test]
    fn ring_sink_retains_bounded_tail() {
        let mut c = ExperimentConfig::default();
        c.sched.kind = SchedKind::Capacity;
        let specs = crate::workload::congested_burst(60, 100, 0xCAFE);
        let cap = 16;
        let res = run_experiment_with(
            &c,
            specs,
            EngineOptions { trace: SinkKind::Ring(cap), ..Default::default() },
        );
        assert!(res.tasks_recorded as usize > cap, "workload too small to exercise ring");
        assert_eq!(res.trace.tasks.len(), cap);
        assert!(res.retained_transitions <= cap);
        // The ring keeps the *latest* records: the last retained trace is
        // the final task completion of the whole run.
        let max_finish = res.trace.tasks.iter().map(|t| t.finish).max().unwrap();
        let first_submit = res.jobs.iter().map(|j| j.submit_ms).min().unwrap();
        assert_eq!(max_finish, first_submit + res.system.makespan_ms);
    }

    #[test]
    fn heap_queue_kind_matches_calendar_default() {
        let c = cfg(SchedKind::Dress);
        let specs = crate::workload::generate(
            6,
            crate::workload::WorkloadMix::Mixed,
            0.4,
            1_500,
            9,
        );
        let cal = run_experiment(&c, specs.clone());
        let heap = run_experiment_with(
            &c,
            specs,
            EngineOptions { queue: QueueKind::Heap, ..Default::default() },
        );
        assert_eq!(cal.system.makespan_ms, heap.system.makespan_ms);
        assert_eq!(cal.events, heap.events);
        assert_eq!(cal.delta_history, heap.delta_history);
        assert_eq!(cal.trace.tasks, heap.trace.tasks);
    }

    #[test]
    fn aos_layout_matches_soa_default() {
        // Quick in-module check; the full 4-scheduler matrix (plus fault
        // plans) lives in tests/golden_determinism.rs.
        let mut c = cfg(SchedKind::Dress);
        c.cluster.task_failure_prob = 0.2;
        let specs = crate::workload::generate(
            8,
            crate::workload::WorkloadMix::Mixed,
            0.4,
            1_500,
            21,
        );
        let soa = run_experiment(&c, specs.clone());
        let aos = run_experiment_with(
            &c,
            specs,
            EngineOptions { jobs: JobLayout::Aos, ..Default::default() },
        );
        assert_eq!(soa.system.makespan_ms, aos.system.makespan_ms);
        assert_eq!(soa.events, aos.events);
        assert_eq!(soa.failures, aos.failures);
        assert_eq!(soa.jobs, aos.jobs, "per-job metrics must be layout-independent");
        assert_eq!(soa.trace.tasks, aos.trace.tasks);
    }

    #[test]
    fn calendar_span_width_rule_matches_default() {
        let c = cfg(SchedKind::Dress);
        let specs = crate::workload::generate(
            6,
            crate::workload::WorkloadMix::Mixed,
            0.4,
            1_500,
            13,
        );
        let gap = run_experiment(&c, specs.clone());
        let span = run_experiment_with(
            &c,
            specs,
            EngineOptions { queue: QueueKind::CalendarSpan, ..Default::default() },
        );
        assert_eq!(gap.system.makespan_ms, span.system.makespan_ms);
        assert_eq!(gap.events, span.events);
        assert_eq!(gap.delta_history, span.delta_history);
        assert_eq!(gap.trace.tasks, span.trace.tasks);
    }

    #[test]
    fn view_check_cadence_env_override_accepted() {
        // Any cadence is semantics-preserving (the check is an assertion,
        // not behavior); this pins that the env knob parses and the run
        // still completes with a sampled cross-check.
        std::env::set_var("DRESS_VIEW_CHECK_EVERY", "7");
        let res = run_experiment(
            &cfg(SchedKind::Capacity),
            vec![tiny_job(1, 0, 2, &[2_000, 2_000])],
        );
        std::env::remove_var("DRESS_VIEW_CHECK_EVERY");
        assert_eq!(res.jobs.len(), 1);
    }

    #[test]
    fn naive_reference_path_matches_indexed_engine() {
        // Quick in-module check; the full 4-scheduler matrix (plus failure
        // injection) lives in tests/golden_determinism.rs.
        let c = cfg(SchedKind::Dress);
        let specs = crate::workload::generate(
            6,
            crate::workload::WorkloadMix::Mixed,
            0.4,
            1_500,
            5,
        );
        let fast = run_experiment(&c, specs.clone());
        let naive = run_experiment_with(
            &c,
            specs,
            EngineOptions { naive_hot_path: true, ..Default::default() },
        );
        assert_eq!(fast.system.makespan_ms, naive.system.makespan_ms);
        assert_eq!(fast.trace.tasks.len(), naive.trace.tasks.len());
        assert_eq!(fast.delta_history, naive.delta_history);
    }

    #[test]
    #[should_panic(expected = "duplicate job id")]
    fn duplicate_job_ids_rejected() {
        let specs = vec![tiny_job(1, 0, 1, &[1_000]), tiny_job(1, 500, 1, &[1_000])];
        let c = cfg(SchedKind::Fifo);
        let sched = crate::sched::build(&c.sched, c.cluster.total_containers());
        Engine::new(c, specs, sched);
    }

    #[test]
    fn sparse_job_ids_still_resolve() {
        // Ids far apart force the sorted fallback index.
        let specs = vec![
            tiny_job(7, 0, 1, &[1_000]),
            tiny_job(1_000_000, 500, 1, &[1_000]),
            tiny_job(900_000_000, 900, 1, &[1_000]),
        ];
        let res = run_experiment(&cfg(SchedKind::Capacity), specs);
        assert_eq!(res.jobs.len(), 3);
        assert_eq!(res.trace.tasks.len(), 3);
    }
}
