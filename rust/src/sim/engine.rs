//! The discrete-event simulation engine: drives jobs, containers, and the
//! scheduler through heartbeat rounds, enforcing feasibility and recording
//! metrics + traces.

use super::event::{Event, EventQueue};
use super::trace::{TaskTrace, TraceRecorder};
use crate::cluster::{Cluster, ContainerState, HeartbeatLog, Transition};
use crate::config::ExperimentConfig;
use crate::jobs::{JobRt, JobSpec, TaskState};
use crate::metrics::{JobMetrics, SystemMetrics};
use crate::sched::{Allocation, ClusterView, JobView, Scheduler};
use crate::util::rng::Rng;
use crate::util::Time;

/// Outcome of one simulated experiment.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub scheduler: String,
    pub jobs: Vec<JobMetrics>,
    pub system: SystemMetrics,
    pub trace: TraceRecorder,
    /// DRESS δ history, empty for baselines.
    pub delta_history: Vec<(Time, f64)>,
    /// Injected container failures survived (task re-attempts).
    pub failures: u32,
}

/// The engine. Owns everything for one run.
pub struct Engine {
    cfg: ExperimentConfig,
    cluster: Cluster,
    jobs: Vec<JobRt>,
    queue: EventQueue,
    heartbeats: HeartbeatLog,
    sched: Box<dyn Scheduler>,
    rng: Rng,
    now: Time,
    trace: TraceRecorder,
    /// Utilization samples (time, used containers) at each tick.
    pub util: Vec<(Time, u32)>,
    /// δ samples per tick (schedulers without a reserve ratio yield none).
    delta_trace: Vec<(Time, f64)>,
    failures: u32,
    /// Safety valve against pathological schedules.
    max_ms: Time,
}

impl Engine {
    pub fn new(cfg: ExperimentConfig, specs: Vec<JobSpec>, sched: Box<dyn Scheduler>) -> Self {
        for s in &specs {
            s.validate().unwrap_or_else(|e| panic!("invalid job spec: {e}"));
        }
        let cluster = Cluster::new(cfg.cluster.nodes, cfg.cluster.slots_per_node);
        let seed = cfg.workload.seed ^ 0xD8E5_5000;
        let mut queue = EventQueue::new();
        for s in &specs {
            queue.push(s.submit_ms, Event::JobSubmit(s.id));
        }
        queue.push(0, Event::SchedTick);
        Engine {
            cfg,
            cluster,
            jobs: specs.into_iter().map(JobRt::new).collect(),
            queue,
            heartbeats: HeartbeatLog::new(),
            sched,
            rng: Rng::new(seed),
            now: 0,
            trace: TraceRecorder::new(),
            util: Vec::new(),
            delta_trace: Vec::new(),
            failures: 0,
            max_ms: 40 * 3_600 * 1_000, // 40 simulated hours
        }
    }

    fn job_index(&self, id: u32) -> usize {
        self.jobs
            .iter()
            .position(|j| j.id() == id)
            .unwrap_or_else(|| panic!("unknown job {id}"))
    }

    fn all_finished(&self) -> bool {
        self.jobs.iter().all(|j| j.finished())
    }

    fn build_view<'a>(&self, transitions: &'a [Transition]) -> ClusterView<'a> {
        // A demand above cluster capacity can never gang-start; YARN callers
        // are granted at most the cluster, so the view clamps (prevents
        // head-of-line livelock for oversized requests).
        let total = self.cluster.total();
        let jobs = self
            .jobs
            .iter()
            .filter(|j| j.submitted)
            .map(|j| JobView {
                id: j.id(),
                demand: j.spec.demand.min(total),
                submit_ms: j.spec.submit_ms,
                started: j.started(),
                finished: j.finished(),
                pending_tasks: j.pending_tasks(),
                occupied: j.occupied,
            })
            .collect();
        ClusterView {
            now: self.now,
            free: self.cluster.free(),
            total: self.cluster.total(),
            jobs,
            transitions,
        }
    }

    /// Apply one feasible allocation: create containers in the YARN state
    /// machine for up to `n` pending tasks of the job.
    fn apply_allocation(&mut self, alloc: Allocation) {
        let ji = self.job_index(alloc.job);
        for _ in 0..alloc.n {
            if self.cluster.free() == 0 {
                break;
            }
            let Some((phase, task)) = self.jobs[ji].next_pending() else {
                break;
            };
            let cid = self
                .cluster
                .allocate(alloc.job, phase, task, self.now)
                .expect("free checked above");
            self.jobs[ji].tasks[phase][task].state = TaskState::Launching(cid);
            self.jobs[ji].occupied += 1;
            self.record_transition(cid, ContainerState::New);
            self.schedule_advance(cid);
        }
    }

    fn record_transition(&mut self, cid: u32, to: ContainerState) {
        let c = self.cluster.container(cid);
        self.heartbeats.record(Transition {
            time: self.now,
            container: cid,
            job: c.job,
            task: c.task,
            to,
        });
    }

    /// Sample the delay for the container's next state hop and enqueue it.
    fn schedule_advance(&mut self, cid: u32) {
        let state = self.cluster.container(cid).state;
        let d = &self.cfg.cluster.delays;
        let median = match state {
            ContainerState::New => d.new_to_reserved_ms,
            ContainerState::Reserved => d.reserved_to_allocated_ms,
            ContainerState::Allocated => d.allocated_to_acquired_ms,
            ContainerState::Acquired => d.acquired_to_running_ms,
            _ => return,
        };
        let delay = self.rng.lognormal(median, d.sigma).max(1.0) as Time;
        self.queue.push(self.now + delay, Event::ContainerAdvance(cid));
    }

    fn on_container_advance(&mut self, cid: u32) {
        let new_state = self.cluster.container_mut(cid).advance(self.now);
        self.record_transition(cid, new_state);
        let (job, phase, task) = {
            let c = self.cluster.container(cid);
            (c.job, c.phase, c.task)
        };
        if new_state == ContainerState::Running {
            let ji = self.job_index(job);
            self.jobs[ji].tasks[phase][task].state =
                TaskState::Running { container: cid, start: self.now };
            if self.jobs[ji].first_start.is_none() {
                self.jobs[ji].first_start = Some(self.now);
            }
            let dur = self.jobs[ji].tasks[phase][task].duration_ms;
            // Failure injection: the container may die mid-task; the task
            // is then re-attempted in a fresh container (YARN AM behavior).
            let pf = self.cfg.cluster.task_failure_prob;
            if pf > 0.0 && self.rng.chance(pf) {
                let at = self.now + (dur as f64 * self.rng.range_f64(0.1, 0.9)) as Time;
                self.queue.push(at.max(self.now + 1), Event::TaskFail(cid));
            } else {
                self.queue.push(self.now + dur, Event::TaskFinish(cid));
            }
        } else {
            self.schedule_advance(cid);
        }
    }

    fn on_task_finish(&mut self, cid: u32) {
        let new_state = self.cluster.container_mut(cid).advance(self.now);
        debug_assert_eq!(new_state, ContainerState::Completed);
        self.record_transition(cid, ContainerState::Completed);
        let (job, phase, task, granted, run_start) = {
            let c = self.cluster.container(cid);
            (c.job, c.phase, c.task, c.state_since, c.run_start)
        };
        let _ = granted;
        self.cluster.release(cid);

        let ji = self.job_index(job);
        let start = match self.jobs[ji].tasks[phase][task].state {
            TaskState::Running { start, .. } => start,
            other => panic!("finish of non-running task: {other:?}"),
        };
        debug_assert_eq!(start, run_start);
        self.jobs[ji].tasks[phase][task].state = TaskState::Done { start, finish: self.now };
        self.jobs[ji].occupied -= 1;
        self.trace.record(TaskTrace {
            job,
            phase,
            task,
            granted: run_start, // grant time folded into startup elsewhere
            start,
            finish: self.now,
        });
        self.jobs[ji].advance_phase();
        if self.jobs[ji].all_done() && self.jobs[ji].finish.is_none() {
            self.jobs[ji].finish = Some(self.now);
        }
    }

    /// Container dies mid-task: release the slot, reset the task to
    /// Pending so the scheduler re-grants it.
    fn on_task_fail(&mut self, cid: u32) {
        let new_state = self.cluster.container_mut(cid).advance(self.now);
        debug_assert_eq!(new_state, ContainerState::Completed);
        self.record_transition(cid, ContainerState::Completed);
        let (job, phase, task) = {
            let c = self.cluster.container(cid);
            (c.job, c.phase, c.task)
        };
        self.cluster.release(cid);
        let ji = self.job_index(job);
        debug_assert!(matches!(
            self.jobs[ji].tasks[phase][task].state,
            TaskState::Running { .. }
        ));
        self.jobs[ji].tasks[phase][task].state = TaskState::Pending;
        self.jobs[ji].occupied -= 1;
        self.failures += 1;
    }

    fn on_sched_tick(&mut self) {
        let transitions = self.heartbeats.drain();
        let view = self.build_view(&transitions);
        let allocs = self.sched.schedule(&view);
        // Feasibility enforcement: total grants bounded by free capacity.
        let mut free = self.cluster.free();
        for a in allocs {
            let ji = self.job_index(a.job);
            let pending = self.jobs[ji].pending_tasks();
            let n = a.n.min(pending).min(free);
            if n == 0 {
                continue;
            }
            free -= n;
            self.apply_allocation(Allocation { job: a.job, n });
        }
        self.util.push((self.now, self.cluster.used()));
        if let Some(delta) = self.sched.reserve_ratio() {
            self.delta_trace.push((self.now, delta));
        }
        debug_assert!(self.cluster.conservation_holds());
        if !self.all_finished() {
            self.queue
                .push(self.now + self.cfg.cluster.hb_ms, Event::SchedTick);
        }
    }

    /// Run to completion and produce the result bundle.
    pub fn run(mut self) -> RunResult {
        while let Some((t, ev)) = self.queue.pop() {
            assert!(t >= self.now, "time went backwards");
            self.now = t;
            if self.now > self.max_ms {
                panic!("simulation exceeded {} ms — livelocked schedule?", self.max_ms);
            }
            match ev {
                Event::JobSubmit(id) => {
                    let ji = self.job_index(id);
                    self.jobs[ji].submitted = true;
                }
                Event::SchedTick => self.on_sched_tick(),
                Event::ContainerAdvance(cid) => self.on_container_advance(cid),
                Event::TaskFinish(cid) => self.on_task_finish(cid),
                Event::TaskFail(cid) => self.on_task_fail(cid),
            }
            if self.all_finished() {
                break;
            }
        }
        assert!(self.all_finished(), "run ended with unfinished jobs (starvation)");

        let jobs: Vec<JobMetrics> = self.jobs.iter().map(JobMetrics::of).collect();
        let system = SystemMetrics::of(&jobs, &self.util, self.cluster.total());
        RunResult {
            scheduler: self.sched.name().to_string(),
            jobs,
            system,
            trace: self.trace,
            delta_history: self.delta_trace,
            failures: self.failures,
        }
    }
}

/// Convenience: build + run one experiment with the configured scheduler.
pub fn run_experiment(cfg: &ExperimentConfig, specs: Vec<JobSpec>) -> RunResult {
    let sched = crate::sched::build(&cfg.sched, cfg.cluster.total_containers());
    Engine::new(cfg.clone(), specs, sched).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchedKind;
    use crate::jobs::{PhaseKind, PhaseSpec, Platform};

    fn tiny_job(id: u32, submit: Time, demand: u32, durs: &[Time]) -> JobSpec {
        JobSpec {
            id,
            name: format!("job{id}"),
            platform: Platform::MapReduce,
            submit_ms: submit,
            demand,
            phases: vec![PhaseSpec::new(PhaseKind::Map, durs)],
        }
    }

    fn cfg(kind: SchedKind) -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.cluster.nodes = 2;
        c.cluster.slots_per_node = 3;
        c.sched.kind = kind;
        c
    }

    #[test]
    fn single_job_completes() {
        let res = run_experiment(&cfg(SchedKind::Fifo), vec![tiny_job(1, 0, 2, &[5_000, 5_000])]);
        assert_eq!(res.jobs.len(), 1);
        let j = &res.jobs[0];
        assert!(j.waiting_ms > 0, "startup delays imply nonzero waiting");
        assert!(j.completion_ms >= 5_000);
        assert_eq!(res.trace.tasks.len(), 2);
    }

    #[test]
    fn all_schedulers_complete_congested_mix() {
        let specs = vec![
            tiny_job(1, 0, 4, &[8_000, 8_000, 9_000, 9_000]),
            tiny_job(2, 1_000, 4, &[7_000, 7_000, 7_000, 7_000]),
            tiny_job(3, 2_000, 2, &[3_000, 3_000]),
            tiny_job(4, 3_000, 2, &[4_000, 4_000]),
        ];
        for kind in [SchedKind::Fifo, SchedKind::Fair, SchedKind::Capacity, SchedKind::Dress] {
            let res = run_experiment(&cfg(kind), specs.clone());
            assert_eq!(res.jobs.len(), 4, "{kind:?}");
            assert!(res.system.makespan_ms > 0);
            assert_eq!(res.trace.tasks.len(), 12, "{kind:?}: every task ran");
        }
    }

    #[test]
    fn dress_records_delta_history() {
        let res = run_experiment(&cfg(SchedKind::Dress), vec![tiny_job(1, 0, 2, &[2_000, 2_000])]);
        assert!(!res.delta_history.is_empty());
        assert!(res.delta_history.iter().all(|&(_, d)| (0.0..=1.0).contains(&d)));
        let fifo = run_experiment(&cfg(SchedKind::Fifo), vec![tiny_job(1, 0, 2, &[2_000, 2_000])]);
        assert!(fifo.delta_history.is_empty());
    }

    #[test]
    fn multi_phase_barrier_ordering() {
        let spec = JobSpec {
            id: 1,
            name: "two-phase".into(),
            platform: Platform::MapReduce,
            submit_ms: 0,
            demand: 3,
            phases: vec![
                PhaseSpec::new(PhaseKind::Map, &[4_000, 4_500, 5_000]),
                PhaseSpec::new(PhaseKind::Reduce, &[3_000]),
            ],
        };
        let res = run_experiment(&cfg(SchedKind::Capacity), vec![spec]);
        let map_finish = res
            .trace
            .tasks
            .iter()
            .filter(|t| t.phase == 0)
            .map(|t| t.finish)
            .max()
            .unwrap();
        let reduce_start = res
            .trace
            .tasks
            .iter()
            .find(|t| t.phase == 1)
            .map(|t| t.start)
            .unwrap();
        assert!(
            reduce_start >= map_finish,
            "reduce started {reduce_start} before last map finished {map_finish}"
        );
    }

    #[test]
    fn failure_injection_retries_until_done() {
        let mut c = cfg(SchedKind::Capacity);
        c.cluster.task_failure_prob = 0.3;
        let specs = vec![
            tiny_job(1, 0, 3, &[4_000, 4_000, 4_000]),
            tiny_job(2, 1_000, 2, &[3_000, 3_000]),
        ];
        let res = run_experiment(&c, specs);
        // All tasks eventually completed despite failures; failed attempts
        // do not appear in the trace (only successful runs do).
        assert_eq!(res.trace.tasks.len(), 5);
        assert!(res.failures > 0, "with p=0.3 over 5+ attempts, expect failures");
        // Failures lengthen the run vs the failure-free baseline.
        let mut clean = cfg(SchedKind::Capacity);
        clean.cluster.task_failure_prob = 0.0;
        let base = run_experiment(&clean, vec![
            tiny_job(1, 0, 3, &[4_000, 4_000, 4_000]),
            tiny_job(2, 1_000, 2, &[3_000, 3_000]),
        ]);
        assert_eq!(base.failures, 0);
        assert!(res.system.makespan_ms >= base.system.makespan_ms);
    }

    #[test]
    fn dress_survives_failures_under_congestion() {
        let mut c = cfg(SchedKind::Dress);
        c.cluster.task_failure_prob = 0.15;
        let specs = crate::workload::generate(
            8,
            crate::workload::WorkloadMix::Mixed,
            0.3,
            2_000,
            11,
        );
        let expected: usize = specs.iter().map(|s| s.total_tasks() as usize).sum();
        let res = run_experiment(&c, specs);
        assert_eq!(res.trace.tasks.len(), expected);
        assert!(res.delta_history.iter().all(|&(_, d)| (0.0..1.0).contains(&d)));
    }

    #[test]
    fn deterministic_given_seed() {
        let specs = vec![tiny_job(1, 0, 3, &[4_000, 5_000, 6_000])];
        let a = run_experiment(&cfg(SchedKind::Capacity), specs.clone());
        let b = run_experiment(&cfg(SchedKind::Capacity), specs);
        assert_eq!(a.system.makespan_ms, b.system.makespan_ms);
        assert_eq!(a.jobs[0].waiting_ms, b.jobs[0].waiting_ms);
    }
}
