//! Per-task execution traces — the raw material for Figs. 2-4 (starting
//! variation, heading tasks, trailing tasks) and for estimator validation.

use crate::jobs::JobId;
use crate::util::Time;

/// One task's observed lifetime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TaskTrace {
    pub job: JobId,
    pub phase: usize,
    pub task: usize,
    /// Container grant time.
    pub granted: Time,
    /// Execution start (container reached Running).
    pub start: Time,
    pub finish: Time,
}

impl TaskTrace {
    pub fn duration(&self) -> Time {
        self.finish - self.start
    }

    /// Startup latency: grant -> running (the paper's transition delay).
    pub fn startup(&self) -> Time {
        self.start - self.granted
    }
}

/// Collects task traces during a run.
#[derive(Debug, Default, Clone)]
pub struct TraceRecorder {
    pub tasks: Vec<TaskTrace>,
}

impl TraceRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, t: TaskTrace) {
        self.tasks.push(t);
    }

    /// Tasks of one job, ordered by start time.
    pub fn job_tasks(&self, job: JobId) -> Vec<TaskTrace> {
        let mut v: Vec<TaskTrace> =
            self.tasks.iter().copied().filter(|t| t.job == job).collect();
        v.sort_by_key(|t| (t.start, t.task));
        v
    }

    /// Ground-truth starting variation of (job, phase): max(start)-min(start).
    pub fn phase_dps(&self, job: JobId, phase: usize) -> Option<Time> {
        let starts: Vec<Time> = self
            .tasks
            .iter()
            .filter(|t| t.job == job && t.phase == phase)
            .map(|t| t.start)
            .collect();
        if starts.is_empty() {
            return None;
        }
        Some(starts.iter().max().unwrap() - starts.iter().min().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tt(job: JobId, phase: usize, task: usize, start: Time, finish: Time) -> TaskTrace {
        TaskTrace { job, phase, task, granted: start.saturating_sub(500), start, finish }
    }

    #[test]
    fn durations_and_startup() {
        let t = tt(1, 0, 0, 1_000, 4_000);
        assert_eq!(t.duration(), 3_000);
        assert_eq!(t.startup(), 500);
    }

    #[test]
    fn job_tasks_sorted_by_start() {
        let mut r = TraceRecorder::new();
        r.record(tt(1, 0, 1, 2_000, 3_000));
        r.record(tt(1, 0, 0, 1_000, 3_000));
        r.record(tt(2, 0, 0, 500, 900));
        let ts = r.job_tasks(1);
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].task, 0);
        assert_eq!(ts[1].task, 1);
    }

    #[test]
    fn phase_dps_ground_truth() {
        let mut r = TraceRecorder::new();
        r.record(tt(1, 0, 0, 1_000, 5_000));
        r.record(tt(1, 0, 1, 2_500, 6_000));
        r.record(tt(1, 1, 0, 7_000, 9_000));
        assert_eq!(r.phase_dps(1, 0), Some(1_500));
        assert_eq!(r.phase_dps(1, 1), Some(0));
        assert_eq!(r.phase_dps(1, 2), None);
    }
}
