//! Event types and the time-ordered event queue.

use crate::cluster::ContainerId;
use crate::jobs::JobId;
use crate::util::Time;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Simulation events.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// A job arrives in the cluster.
    JobSubmit(JobId),
    /// Scheduling round (heartbeat aggregation + scheduler invocation).
    SchedTick,
    /// A container moves to its next lifecycle state.
    ContainerAdvance(ContainerId),
    /// A running task completes.
    TaskFinish(ContainerId),
    /// A running container dies mid-task (failure injection); the task is
    /// re-attempted in a fresh container, as on YARN.
    TaskFail(ContainerId),
}

/// Min-heap event queue ordered by (time, insertion sequence) — FIFO among
/// simultaneous events, which keeps runs deterministic.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<(Time, u64, EventEntry)>>,
    seq: u64,
}

/// Wrapper to give Event a total order for the heap (by discriminant; the
/// (time, seq) prefix dominates in practice).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct EventEntry(u8, u32, u32);

impl EventEntry {
    fn pack(e: Event) -> Self {
        match e {
            Event::JobSubmit(j) => EventEntry(0, j, 0),
            Event::SchedTick => EventEntry(1, 0, 0),
            Event::ContainerAdvance(c) => EventEntry(2, c, 0),
            Event::TaskFinish(c) => EventEntry(3, c, 0),
            Event::TaskFail(c) => EventEntry(4, c, 0),
        }
    }

    fn unpack(self) -> Event {
        match self.0 {
            0 => Event::JobSubmit(self.1),
            1 => Event::SchedTick,
            2 => Event::ContainerAdvance(self.1),
            3 => Event::TaskFinish(self.1),
            _ => Event::TaskFail(self.1),
        }
    }
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, time: Time, event: Event) {
        self.heap.push(Reverse((time, self.seq, EventEntry::pack(event))));
        self.seq += 1;
    }

    pub fn pop(&mut self) -> Option<(Time, Event)> {
        self.heap
            .pop()
            .map(|Reverse((t, _, e))| (t, e.unpack()))
    }

    pub fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|Reverse((t, _, _))| *t)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30, Event::SchedTick);
        q.push(10, Event::JobSubmit(1));
        q.push(20, Event::TaskFinish(5));
        assert_eq!(q.pop(), Some((10, Event::JobSubmit(1))));
        assert_eq!(q.pop(), Some((20, Event::TaskFinish(5))));
        assert_eq!(q.pop(), Some((30, Event::SchedTick)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn fifo_among_simultaneous() {
        let mut q = EventQueue::new();
        q.push(5, Event::JobSubmit(1));
        q.push(5, Event::JobSubmit(2));
        q.push(5, Event::SchedTick);
        assert_eq!(q.pop(), Some((5, Event::JobSubmit(1))));
        assert_eq!(q.pop(), Some((5, Event::JobSubmit(2))));
        assert_eq!(q.pop(), Some((5, Event::SchedTick)));
    }

    #[test]
    fn roundtrips_all_event_kinds() {
        let events = [
            Event::JobSubmit(7),
            Event::SchedTick,
            Event::ContainerAdvance(9),
            Event::TaskFinish(11),
            Event::TaskFail(13),
        ];
        let mut q = EventQueue::new();
        for (i, e) in events.iter().enumerate() {
            q.push(i as Time, *e);
        }
        for e in events {
            assert_eq!(q.pop().unwrap().1, e);
        }
    }

    #[test]
    fn peek_time_matches_next_pop() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_time(), None);
        q.push(42, Event::SchedTick);
        q.push(7, Event::SchedTick);
        assert_eq!(q.peek_time(), Some(7));
        q.pop();
        assert_eq!(q.peek_time(), Some(42));
    }
}
