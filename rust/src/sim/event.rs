//! Event types and the time-ordered event queue.
//!
//! Three interchangeable queue implementations sit behind the same
//! [`EventQueue`] API, all honoring the exact (time, insertion-sequence)
//! total order that keeps runs deterministic:
//!
//! * [`QueueKind::Calendar`] (default) — a calendar queue (bucketed timing
//!   wheel, Brown 1988): events hash into `time / width mod nbuckets`
//!   buckets; pop scans the current "day" window, so in the steady state
//!   push and pop are O(1) amortized instead of the binary heap's
//!   O(log n).  Event payloads live in a slab arena behind `u32` handles,
//!   so bucket inserts and resizes move 24-byte keys, not fat enums.  The
//!   bucket count doubles/halves with occupancy (with hysteresis — see
//!   [`CalendarQueue::maybe_shrink`]) and the bucket width re-derives on
//!   every resize from a reservoir of recently observed inter-pop gaps
//!   (Brown's sampled-gap rule; see docs/PERFORMANCE.md for sizing notes).
//! * [`QueueKind::CalendarSpan`] — the same wheel with the pre-gap-sampling
//!   width heuristic (`span * 3 / len` over the live events).  Kept as the
//!   reference path for the width rule: bucket width affects only *where*
//!   events sit, never pop order, and the golden-determinism suite proves
//!   whole runs bit-identical across all three kinds.
//! * [`QueueKind::Heap`] — the seed's `BinaryHeap` ordered by
//!   `(time, seq)`.  Kept as the reference model: the golden-determinism
//!   suite runs whole experiments on every kind and requires bit-identical
//!   results, and `tests/properties.rs` drives random interleaved
//!   push/pop sequences against it.

use crate::cluster::ContainerId;
use crate::jobs::JobId;
use crate::util::slab::Slab;
use crate::util::Time;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Simulation events.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// A job arrives in the cluster.
    JobSubmit(JobId),
    /// Scheduling round (heartbeat aggregation + scheduler invocation).
    SchedTick,
    /// A container moves to its next lifecycle state.
    ContainerAdvance(ContainerId),
    /// A running task completes.
    TaskFinish(ContainerId),
    /// A running container dies mid-task (failure injection); the task is
    /// re-attempted in a fresh container, as on YARN.
    TaskFail(ContainerId),
    /// A whole node crashes (fault plan); the payload indexes the engine's
    /// outage table, not a node id — one outage may span several nodes.
    NodeFail(u32),
    /// A crashed node comes back after its configured downtime.
    NodeRecover(u32),
    /// An admission reservation's commit timeout fired (payload: the
    /// reservation ticket id).  Scheduled by the admission front's
    /// *private* queue (live/admission.rs) — the engine's own queue never
    /// carries one, so the disabled admission path pushes zero events.
    ReservationExpire(u32),
}

/// Which queue implementation an [`EventQueue`] uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QueueKind {
    /// Bucketed calendar queue — O(1) amortized push/pop, widths from
    /// sampled inter-pop gaps.
    #[default]
    Calendar,
    /// Calendar queue with the older `span/len` width heuristic — the
    /// reference path for the gap-sampled rule.
    CalendarSpan,
    /// `BinaryHeap` reference implementation — O(log n) per op.
    Heap,
}

/// Wrapper to give Event a total order for the heap (by discriminant; the
/// (time, seq) prefix dominates in practice).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct EventEntry(u8, u32, u32);

impl EventEntry {
    fn pack(e: Event) -> Self {
        match e {
            Event::JobSubmit(j) => EventEntry(0, j, 0),
            Event::SchedTick => EventEntry(1, 0, 0),
            Event::ContainerAdvance(c) => EventEntry(2, c, 0),
            Event::TaskFinish(c) => EventEntry(3, c, 0),
            Event::TaskFail(c) => EventEntry(4, c, 0),
            Event::NodeFail(o) => EventEntry(5, o, 0),
            Event::NodeRecover(o) => EventEntry(6, o, 0),
            Event::ReservationExpire(r) => EventEntry(7, r, 0),
        }
    }

    fn unpack(self) -> Event {
        match self.0 {
            0 => Event::JobSubmit(self.1),
            1 => Event::SchedTick,
            2 => Event::ContainerAdvance(self.1),
            3 => Event::TaskFinish(self.1),
            4 => Event::TaskFail(self.1),
            5 => Event::NodeFail(self.1),
            6 => Event::NodeRecover(self.1),
            _ => Event::ReservationExpire(self.1),
        }
    }
}

/// Reservoir size for the sampled inter-pop gap rule.  32 recent gaps is
/// enough to track regime shifts (burst → drain) within a few dozen events
/// while staying a single cache line of `u64`s to average on resize.
const GAP_SAMPLES: usize = 32;

/// Calendar queue: `nbuckets` (a power of two) buckets of `width` ms each.
/// An event at time `t` lives in bucket `(t / width) % nbuckets`; buckets
/// are kept sorted descending by `(time, seq)` so the bucket minimum is a
/// O(1) `Vec::pop` from the tail.  Pop walks day windows from the current
/// bucket; a full empty year falls back to a direct min search (rare — it
/// only happens when the queue is sparse relative to its span).
///
/// Bucket elements are `(time, seq, handle)` triples: the comparison key
/// stays inline (no pointer chase during the sorted insert) while the fat
/// [`Event`] payload sits in `arena` and never moves on insert or resize.
#[derive(Debug)]
struct CalendarQueue {
    /// Each bucket sorted descending by (time, seq): last element = min.
    buckets: Vec<Vec<(Time, u64, u32)>>,
    /// Event payloads behind the `u32` handles stored in `buckets`.
    arena: Slab<EventEntry>,
    /// `buckets.len() - 1`; bucket count is always a power of two.
    mask: usize,
    /// Bucket width in ms (>= 1).
    width: Time,
    /// Current bucket index.
    cur: usize,
    /// Exclusive upper bound of the current bucket's day window.
    cur_top: Time,
    len: usize,
    /// Use the sampled-gap width rule (false = span/len reference rule).
    gap_sampled: bool,
    /// Ring of recent nonzero inter-pop gaps (ms); only `gap_len` valid.
    gaps: [Time; GAP_SAMPLES],
    gap_len: usize,
    gap_pos: usize,
    /// Timestamp of the most recent pop, once any pop has happened.
    last_pop: Option<Time>,
    /// Total resizes (grow + shrink) — hysteresis regression counter.
    resizes: u64,
}

const INIT_BUCKETS: usize = 16;
const INIT_WIDTH: Time = 1024;
const MAX_BUCKETS: usize = 1 << 20;

impl CalendarQueue {
    fn new(gap_sampled: bool) -> Self {
        CalendarQueue {
            buckets: (0..INIT_BUCKETS).map(|_| Vec::new()).collect(),
            arena: Slab::new(),
            mask: INIT_BUCKETS - 1,
            width: INIT_WIDTH,
            cur: 0,
            cur_top: INIT_WIDTH,
            len: 0,
            gap_sampled,
            gaps: [0; GAP_SAMPLES],
            gap_len: 0,
            gap_pos: 0,
            last_pop: None,
            resizes: 0,
        }
    }

    /// Point the scan cursor at the day containing `time`.
    fn seek(&mut self, time: Time) {
        let day = time / self.width;
        self.cur = (day as usize) & self.mask;
        self.cur_top = (day + 1) * self.width;
    }

    fn push(&mut self, time: Time, seq: u64, entry: EventEntry) {
        // The scan invariant is "no event earlier than the current day".
        // An empty queue re-anchors for free; a push into the past (legal
        // for generic callers, never done by the engine) rewinds the
        // cursor so the new event cannot be skipped.
        if self.len == 0 || time < self.cur_top.saturating_sub(self.width) {
            self.seek(time);
        }
        let handle = self.arena.alloc(entry);
        let idx = ((time / self.width) as usize) & self.mask;
        let bucket = &mut self.buckets[idx];
        // Descending order; seq is unique so there are no equal keys.
        let pos = bucket.partition_point(|&(t, s, _)| (t, s) > (time, seq));
        bucket.insert(pos, (time, seq, handle));
        self.len += 1;
        if self.len > 4 * self.buckets.len() && self.buckets.len() < MAX_BUCKETS {
            self.resize(self.buckets.len() * 2);
        }
    }

    fn pop(&mut self) -> Option<(Time, u64, EventEntry)> {
        if self.len == 0 {
            return None;
        }
        // Scan at most one full year of day windows from the cursor.
        for _ in 0..=self.mask {
            let bucket = &mut self.buckets[self.cur];
            if let Some(&(t, _, _)) = bucket.last() {
                if t < self.cur_top {
                    let (t, s, h) = bucket.pop().unwrap();
                    self.len -= 1;
                    let entry = self.arena.take(h);
                    self.note_pop(t);
                    self.maybe_shrink();
                    return Some((t, s, entry));
                }
            }
            self.cur = (self.cur + 1) & self.mask;
            self.cur_top += self.width;
        }
        // Sparse queue: nothing within a year of the cursor.  Jump straight
        // to the globally minimal event (each bucket's min is its tail).
        let (t, _, _) = self.min_entry().expect("len > 0");
        self.seek(t);
        let (t, s, h) = self.buckets[self.cur].pop().unwrap();
        self.len -= 1;
        let entry = self.arena.take(h);
        self.note_pop(t);
        self.maybe_shrink();
        Some((t, s, entry))
    }

    /// Globally minimal (time, seq, handle) entry, by scanning bucket tails.
    fn min_entry(&self) -> Option<(Time, u64, u32)> {
        self.buckets
            .iter()
            .filter_map(|b| b.last().copied())
            .min_by_key(|&(t, s, _)| (t, s))
    }

    /// Record the gap between consecutive pops into the reservoir.  Zero
    /// gaps (simultaneous events) and backwards pops (possible after a
    /// push into the past) carry no width information and are skipped.
    fn note_pop(&mut self, t: Time) {
        if let Some(prev) = self.last_pop {
            let gap = t.saturating_sub(prev);
            if gap > 0 {
                self.gaps[self.gap_pos] = gap;
                self.gap_pos = (self.gap_pos + 1) % GAP_SAMPLES;
                self.gap_len = (self.gap_len + 1).min(GAP_SAMPLES);
            }
        }
        self.last_pop = Some(t);
    }

    /// Width from the sampled gaps: 3× the mean recent inter-pop gap, i.e.
    /// ≈3 events per bucket in the steady state (Brown's rule).  `None`
    /// when sampling is off or no gap has been observed yet.
    fn sampled_width(&self) -> Option<Time> {
        if !self.gap_sampled || self.gap_len == 0 {
            return None;
        }
        let sum: Time = self.gaps[..self.gap_len].iter().sum();
        Some((3 * sum / self.gap_len as u64).max(1))
    }

    /// Shrink with hysteresis: only below ⅛ occupancy (`len * 8 < nbuckets`,
    /// strictly inside the "< ¼" band) while growth triggers above 4×.  The
    /// 32× dead band between the two thresholds means a ±1 len oscillation
    /// at either boundary can trigger at most one resize — see the
    /// `calendar_resize_hysteresis_no_thrash` regression test.
    fn maybe_shrink(&mut self) {
        if self.buckets.len() > INIT_BUCKETS && self.len * 8 < self.buckets.len() {
            self.resize(self.buckets.len() / 2);
        }
    }

    /// Rebuild with `nbuckets` buckets and a re-derived width: 3× the mean
    /// sampled inter-pop gap when available, else 3× the live-span mean gap
    /// (`span * 3 / len`) as the cold-start / reference rule.  The sampled
    /// rule is robust to bursty arrivals — one far-future outlier inflates
    /// the span (collapsing occupancy to one bucket) but barely moves the
    /// mean of 32 recent gaps.
    fn resize(&mut self, nbuckets: usize) {
        self.resizes += 1;
        let all: Vec<(Time, u64, u32)> =
            self.buckets.iter_mut().flat_map(std::mem::take).collect();
        debug_assert_eq!(all.len(), self.len);
        if let Some(w) = self.sampled_width() {
            self.width = w;
        } else if let (Some(min_t), Some(max_t)) = (
            all.iter().map(|&(t, _, _)| t).min(),
            all.iter().map(|&(t, _, _)| t).max(),
        ) {
            let span = max_t - min_t;
            self.width = (span * 3 / all.len().max(1) as u64).max(1);
        }
        self.buckets = (0..nbuckets).map(|_| Vec::new()).collect();
        self.mask = nbuckets - 1;
        for &(t, s, h) in &all {
            let idx = ((t / self.width) as usize) & self.mask;
            self.buckets[idx].push((t, s, h));
        }
        for bucket in self.buckets.iter_mut() {
            bucket.sort_unstable_by(|x, y| (y.0, y.1).cmp(&(x.0, x.1)));
        }
        // Re-anchor the cursor at the earliest live event.
        if let Some((t, _, _)) = self.min_entry() {
            self.seek(t);
        }
    }
}

/// Min-queue of events ordered by (time, insertion sequence) — FIFO among
/// simultaneous events, which keeps runs deterministic.  Backed by a
/// calendar queue by default; see [`QueueKind`].
#[derive(Debug)]
pub struct EventQueue {
    imp: Imp,
    seq: u64,
}

#[derive(Debug)]
enum Imp {
    Calendar(CalendarQueue),
    Heap(BinaryHeap<Reverse<(Time, u64, EventEntry)>>),
}

impl Default for EventQueue {
    fn default() -> Self {
        EventQueue::with_kind(QueueKind::default())
    }
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_kind(kind: QueueKind) -> Self {
        let imp = match kind {
            QueueKind::Calendar => Imp::Calendar(CalendarQueue::new(true)),
            QueueKind::CalendarSpan => Imp::Calendar(CalendarQueue::new(false)),
            QueueKind::Heap => Imp::Heap(BinaryHeap::new()),
        };
        EventQueue { imp, seq: 0 }
    }

    pub fn kind(&self) -> QueueKind {
        match &self.imp {
            Imp::Calendar(c) if c.gap_sampled => QueueKind::Calendar,
            Imp::Calendar(_) => QueueKind::CalendarSpan,
            Imp::Heap(_) => QueueKind::Heap,
        }
    }

    pub fn push(&mut self, time: Time, event: Event) {
        let entry = EventEntry::pack(event);
        match &mut self.imp {
            Imp::Calendar(c) => c.push(time, self.seq, entry),
            Imp::Heap(h) => h.push(Reverse((time, self.seq, entry))),
        }
        self.seq += 1;
    }

    pub fn pop(&mut self) -> Option<(Time, Event)> {
        match &mut self.imp {
            Imp::Calendar(c) => c.pop().map(|(t, _, e)| (t, e.unpack())),
            Imp::Heap(h) => h.pop().map(|Reverse((t, _, e))| (t, e.unpack())),
        }
    }

    /// Time of the next event.  O(1) on the heap kind; O(nbuckets) on the
    /// calendar kinds (a full bucket-tail scan) — fine for occasional
    /// inspection, but don't call it per event on hot paths.
    pub fn peek_time(&self) -> Option<Time> {
        match &self.imp {
            Imp::Calendar(c) => c.min_entry().map(|(t, _, _)| t),
            Imp::Heap(h) => h.peek().map(|Reverse((t, _, _))| *t),
        }
    }

    pub fn len(&self) -> usize {
        match &self.imp {
            Imp::Calendar(c) => c.len,
            Imp::Heap(h) => h.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total bucket-table resizes so far (always 0 on the heap kind) —
    /// instrumentation for the resize-hysteresis regression test.
    pub fn resizes(&self) -> u64 {
        match &self.imp {
            Imp::Calendar(c) => c.resizes,
            Imp::Heap(_) => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const KINDS: [QueueKind; 3] =
        [QueueKind::Calendar, QueueKind::CalendarSpan, QueueKind::Heap];

    #[test]
    fn pops_in_time_order() {
        for kind in KINDS {
            let mut q = EventQueue::with_kind(kind);
            q.push(30, Event::SchedTick);
            q.push(10, Event::JobSubmit(1));
            q.push(20, Event::TaskFinish(5));
            assert_eq!(q.pop(), Some((10, Event::JobSubmit(1))), "{kind:?}");
            assert_eq!(q.pop(), Some((20, Event::TaskFinish(5))), "{kind:?}");
            assert_eq!(q.pop(), Some((30, Event::SchedTick)), "{kind:?}");
            assert_eq!(q.pop(), None, "{kind:?}");
        }
    }

    #[test]
    fn fifo_among_simultaneous() {
        for kind in KINDS {
            let mut q = EventQueue::with_kind(kind);
            q.push(5, Event::JobSubmit(1));
            q.push(5, Event::JobSubmit(2));
            q.push(5, Event::SchedTick);
            assert_eq!(q.pop(), Some((5, Event::JobSubmit(1))), "{kind:?}");
            assert_eq!(q.pop(), Some((5, Event::JobSubmit(2))), "{kind:?}");
            assert_eq!(q.pop(), Some((5, Event::SchedTick)), "{kind:?}");
        }
    }

    #[test]
    fn kind_roundtrips() {
        for kind in KINDS {
            assert_eq!(EventQueue::with_kind(kind).kind(), kind);
        }
    }

    #[test]
    fn roundtrips_all_event_kinds() {
        let events = [
            Event::JobSubmit(7),
            Event::SchedTick,
            Event::ContainerAdvance(9),
            Event::TaskFinish(11),
            Event::TaskFail(13),
            Event::NodeFail(2),
            Event::NodeRecover(2),
            Event::ReservationExpire(5),
        ];
        for kind in KINDS {
            let mut q = EventQueue::with_kind(kind);
            for (i, e) in events.iter().enumerate() {
                q.push(i as Time, *e);
            }
            for e in events {
                assert_eq!(q.pop().unwrap().1, e, "{kind:?}");
            }
        }
    }

    #[test]
    fn peek_time_matches_next_pop() {
        for kind in KINDS {
            let mut q = EventQueue::with_kind(kind);
            assert_eq!(q.peek_time(), None, "{kind:?}");
            q.push(42, Event::SchedTick);
            q.push(7, Event::SchedTick);
            assert_eq!(q.peek_time(), Some(7), "{kind:?}");
            q.pop();
            assert_eq!(q.peek_time(), Some(42), "{kind:?}");
        }
    }

    #[test]
    fn calendar_survives_resize_and_sparse_times() {
        // Push enough events to force several grow cycles, over a time
        // span wide enough to wrap the wheel many times, then drain and
        // check total (time, push-order) sorting — under both width rules.
        for kind in [QueueKind::Calendar, QueueKind::CalendarSpan] {
            let mut q = EventQueue::with_kind(kind);
            let mut expect: Vec<(Time, u64)> = Vec::new();
            let mut x = 0x1234_5678_9abc_def0u64;
            for i in 0..5_000u64 {
                // xorshift: deterministic scatter across ~10^8 ms.
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                let t = x % 100_000_000;
                q.push(t, Event::ContainerAdvance((i % 1000) as u32));
                expect.push((t, i));
            }
            expect.sort_unstable();
            let mut got = Vec::new();
            while let Some((t, _)) = q.pop() {
                got.push(t);
            }
            assert_eq!(got.len(), expect.len(), "{kind:?}");
            for (g, (e, _)) in got.iter().zip(&expect) {
                assert_eq!(g, e, "{kind:?}");
            }
        }
    }

    #[test]
    fn calendar_handles_push_into_the_past() {
        // Generic callers may push a time below the last popped one; the
        // cursor must rewind rather than skip the event.
        for kind in [QueueKind::Calendar, QueueKind::CalendarSpan] {
            let mut q = EventQueue::with_kind(kind);
            q.push(1_000_000, Event::SchedTick);
            assert_eq!(q.pop(), Some((1_000_000, Event::SchedTick)), "{kind:?}");
            q.push(3, Event::JobSubmit(1));
            q.push(2_000_000, Event::SchedTick);
            assert_eq!(q.pop(), Some((3, Event::JobSubmit(1))), "{kind:?}");
            assert_eq!(q.pop(), Some((2_000_000, Event::SchedTick)), "{kind:?}");
            assert!(q.is_empty(), "{kind:?}");
        }
    }

    #[test]
    fn same_time_reinsertion_keeps_fifo_order() {
        for kind in KINDS {
            let mut q = EventQueue::with_kind(kind);
            q.push(9, Event::JobSubmit(1));
            assert_eq!(q.pop(), Some((9, Event::JobSubmit(1))), "{kind:?}");
            // Re-insert at the already-popped timestamp: still delivered,
            // and after it a later same-time pair keeps push order.
            q.push(9, Event::JobSubmit(2));
            q.push(9, Event::JobSubmit(3));
            assert_eq!(q.pop(), Some((9, Event::JobSubmit(2))), "{kind:?}");
            assert_eq!(q.pop(), Some((9, Event::JobSubmit(3))), "{kind:?}");
        }
    }

    #[test]
    fn calendar_resize_hysteresis_no_thrash() {
        // Ping-pong the length across the grow boundary (INIT_BUCKETS=16,
        // grow when len > 64) and then across the shrink boundary: each
        // crossing may trigger at most one resize, never an oscillation.
        for kind in [QueueKind::Calendar, QueueKind::CalendarSpan] {
            let mut q = EventQueue::with_kind(kind);
            let mut t: Time = 0;
            for _ in 0..65 {
                t += 10;
                q.push(t, Event::SchedTick);
            }
            let after_grow = q.resizes();
            assert_eq!(after_grow, 1, "{kind:?}: one grow at >4x occupancy");
            // Oscillate ±1 around the grow boundary (len 64 <-> 65).
            for _ in 0..200 {
                q.pop();
                t += 10;
                q.push(t, Event::SchedTick);
            }
            assert_eq!(
                q.resizes(),
                after_grow,
                "{kind:?}: ping-pong at the grow boundary must not resize"
            );
            // Drain toward the shrink boundary (32 buckets: shrink only
            // once len*8 < 32, i.e. len <= 3) ...
            while q.len() > 3 {
                q.pop();
            }
            let after_shrink = q.resizes();
            assert!(
                after_shrink <= after_grow + 1,
                "{kind:?}: at most one shrink crossing the boundary"
            );
            // ... and oscillate ±1 there too (len 3 <-> 4).
            for _ in 0..200 {
                t += 10;
                q.push(t, Event::SchedTick);
                q.pop();
            }
            assert_eq!(
                q.resizes(),
                after_shrink,
                "{kind:?}: ping-pong at the shrink boundary must not resize"
            );
        }
    }

    #[test]
    fn arena_reuses_slots_under_churn() {
        // Steady-state push/pop churn must recycle arena slots instead of
        // growing the payload store without bound.
        let mut q = EventQueue::with_kind(QueueKind::Calendar);
        let mut t: Time = 0;
        for _ in 0..32 {
            t += 7;
            q.push(t, Event::SchedTick);
        }
        for _ in 0..10_000 {
            q.pop();
            t += 7;
            q.push(t, Event::TaskFinish(1));
        }
        let arena_slots = match &q.imp {
            Imp::Calendar(c) => c.arena.capacity(),
            Imp::Heap(_) => unreachable!(),
        };
        assert!(
            arena_slots <= 33,
            "arena grew to {arena_slots} slots for 32 live events"
        );
    }
}
