//! Per-tick metric sinks — the metric-stream counterpart of the event
//! [`TraceSink`](super::sink::TraceSink).
//!
//! The engine samples two per-tick streams: cluster utilization
//! `(time, used containers)` and the DRESS reserve ratio `(time, δ)`.
//! The seed retained both as unbounded `Vec`s — the last O(ticks) memory
//! term after PR 2 bounded the event streams, and the one that dominates
//! multi-day simulated horizons (a 40-hour run at a 1 s heartbeat is
//! 144k samples per stream *per cell* of a sweep).
//!
//! [`MetricSinkKind`] picks the retention policy; summary statistics are
//! *never* computed from the retained samples — the engine feeds exact
//! online accumulators ([`UtilSummary`](crate::metrics::UtilSummary),
//! [`DeltaSummary`](crate::metrics::DeltaSummary)) alongside every sink,
//! so `mean_utilization` is identical under every policy:
//!
//! | kind | retains | use for |
//! |---|---|---|
//! | `Full` | every sample | figures, paper repro, CSV export |
//! | `Counting` | nothing (count only) | throughput benches, 100k-job sweeps |
//! | `Ring(cap)` | last `cap` samples | tail inspection of big runs |
//! | `Decimate(k)` | every k-th sample | figures over long horizons (O(ticks/k)) |
//!
//! Sinks never change simulation results, and — because summaries come
//! from the accumulators — never change reported statistics either; only
//! what is available for per-sample rendering.

use crate::util::Time;

/// Retention policy for per-tick metric streams (utilization, δ).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MetricSinkKind {
    /// Keep every sample (the seed behavior).
    #[default]
    Full,
    /// Keep nothing; count samples as they pass through.
    Counting,
    /// Keep the most recent `cap` samples plus a total count.
    Ring(usize),
    /// Keep every `k`-th sample (stride downsampling): bounded-density
    /// retention for figures over horizons where `Full` is too big and
    /// `Ring` forgets the head.  `Decimate(1)` degenerates to `Full`.
    Decimate(usize),
}

impl MetricSinkKind {
    /// Parse the CLI form: `full`, `counting`, `ring:N`, `decimate:K`.
    pub fn parse(s: &str) -> Result<MetricSinkKind, String> {
        match s {
            "full" => return Ok(MetricSinkKind::Full),
            "counting" => return Ok(MetricSinkKind::Counting),
            _ => {}
        }
        if let Some(n) = s.strip_prefix("ring:") {
            let cap: usize = n
                .parse()
                .map_err(|e| format!("metric sink `ring:{n}`: {e}"))?;
            if cap == 0 {
                // Ring(0) would behave as Counting but fingerprint as a
                // different grid — reject the degenerate spelling so two
                // behaviorally identical shards can't refuse to merge.
                return Err("metric sink `ring:0` (use `counting`)".into());
            }
            return Ok(MetricSinkKind::Ring(cap));
        }
        if let Some(k) = s.strip_prefix("decimate:") {
            let stride: usize = k
                .parse()
                .map_err(|e| format!("metric sink `decimate:{k}`: {e}"))?;
            if stride == 0 {
                return Err("metric sink `decimate:0` (stride must be >= 2)".into());
            }
            if stride == 1 {
                // Decimate(1) would behave as Full but fingerprint as a
                // different grid (same hole as `ring:0` vs `counting`).
                return Err("metric sink `decimate:1` (use `full`)".into());
            }
            return Ok(MetricSinkKind::Decimate(stride));
        }
        Err(format!(
            "unknown metric sink `{s}` (expected full | counting | ring:N | decimate:K)"
        ))
    }
}

/// A per-tick metric sink with [`MetricSinkKind`] retention.  Generic over
/// the sample value (`u32` for utilization, `f64` for δ).
#[derive(Debug, Clone)]
pub enum MetricSink<V> {
    Full(Vec<(Time, V)>),
    Counting { recorded: u64 },
    Ring { cap: usize, buf: Vec<(Time, V)>, head: usize, recorded: u64 },
    Decimate { stride: u64, buf: Vec<(Time, V)>, recorded: u64 },
}

impl<V: Copy> MetricSink<V> {
    pub fn new(kind: MetricSinkKind) -> Self {
        match kind {
            MetricSinkKind::Full | MetricSinkKind::Decimate(1) => MetricSink::Full(Vec::new()),
            MetricSinkKind::Counting | MetricSinkKind::Ring(0) => {
                MetricSink::Counting { recorded: 0 }
            }
            MetricSinkKind::Ring(cap) => {
                MetricSink::Ring { cap, buf: Vec::with_capacity(cap), head: 0, recorded: 0 }
            }
            // Degenerate stride 0 keeps the first sample only — treat it
            // like 1 (Full) instead; parse() already rejects it at the CLI.
            MetricSinkKind::Decimate(0) => MetricSink::Full(Vec::new()),
            MetricSinkKind::Decimate(stride) => {
                MetricSink::Decimate { stride: stride as u64, buf: Vec::new(), recorded: 0 }
            }
        }
    }

    pub fn record(&mut self, t: Time, v: V) {
        match self {
            MetricSink::Full(samples) => samples.push((t, v)),
            MetricSink::Counting { recorded } => *recorded += 1,
            MetricSink::Ring { cap, buf, head, recorded } => {
                if buf.len() < *cap {
                    buf.push((t, v));
                } else {
                    buf[*head] = (t, v);
                    *head = (*head + 1) % *cap;
                }
                *recorded += 1;
            }
            MetricSink::Decimate { stride, buf, recorded } => {
                if *recorded % *stride == 0 {
                    buf.push((t, v));
                }
                *recorded += 1;
            }
        }
    }

    /// Total samples seen, independent of retention.
    pub fn recorded(&self) -> u64 {
        match self {
            MetricSink::Full(samples) => samples.len() as u64,
            MetricSink::Counting { recorded }
            | MetricSink::Ring { recorded, .. }
            | MetricSink::Decimate { recorded, .. } => *recorded,
        }
    }

    /// Samples currently held in memory.
    pub fn retained(&self) -> usize {
        match self {
            MetricSink::Full(samples) => samples.len(),
            MetricSink::Counting { .. } => 0,
            MetricSink::Ring { buf, .. } | MetricSink::Decimate { buf, .. } => buf.len(),
        }
    }

    /// Consume into `(retained samples in chronological order, total recorded)`.
    pub fn finish(self) -> (Vec<(Time, V)>, u64) {
        match self {
            MetricSink::Full(samples) => {
                let n = samples.len() as u64;
                (samples, n)
            }
            MetricSink::Counting { recorded } => (Vec::new(), recorded),
            MetricSink::Ring { buf, head, recorded, .. } => {
                let mut samples = Vec::with_capacity(buf.len());
                samples.extend_from_slice(&buf[head..]);
                samples.extend_from_slice(&buf[..head]);
                (samples, recorded)
            }
            MetricSink::Decimate { buf, recorded, .. } => (buf, recorded),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(kind: MetricSinkKind, n: u64) -> MetricSink<u32> {
        let mut s = MetricSink::new(kind);
        for i in 0..n {
            s.record(i * 1_000, i as u32);
        }
        s
    }

    #[test]
    fn full_sink_keeps_everything() {
        let s = fill(MetricSinkKind::Full, 5);
        assert_eq!(s.recorded(), 5);
        assert_eq!(s.retained(), 5);
        let (samples, n) = s.finish();
        assert_eq!(n, 5);
        assert_eq!(samples, vec![(0, 0), (1_000, 1), (2_000, 2), (3_000, 3), (4_000, 4)]);
    }

    #[test]
    fn counting_sink_counts_without_retaining() {
        let s = fill(MetricSinkKind::Counting, 1_000);
        assert_eq!(s.recorded(), 1_000);
        assert_eq!(s.retained(), 0);
        let (samples, n) = s.finish();
        assert!(samples.is_empty());
        assert_eq!(n, 1_000);
    }

    #[test]
    fn ring_sink_keeps_last_cap_chronologically() {
        let s = fill(MetricSinkKind::Ring(3), 8);
        assert_eq!(s.recorded(), 8);
        assert_eq!(s.retained(), 3);
        let (samples, n) = s.finish();
        assert_eq!(n, 8);
        assert_eq!(samples, vec![(5_000, 5), (6_000, 6), (7_000, 7)]);
    }

    #[test]
    fn ring_zero_degenerates_to_counting() {
        let s = fill(MetricSinkKind::Ring(0), 4);
        assert_eq!(s.recorded(), 4);
        assert_eq!(s.retained(), 0);
    }

    #[test]
    fn decimate_keeps_every_kth_sample() {
        let s = fill(MetricSinkKind::Decimate(3), 10);
        assert_eq!(s.recorded(), 10);
        let (samples, n) = s.finish();
        assert_eq!(n, 10);
        // First sample always kept, then every third.
        assert_eq!(samples, vec![(0, 0), (3_000, 3), (6_000, 6), (9_000, 9)]);
    }

    #[test]
    fn decimate_one_is_full() {
        let s = fill(MetricSinkKind::Decimate(1), 6);
        assert_eq!(s.retained(), 6);
        let (samples, _) = s.finish();
        assert_eq!(samples.len(), 6);
    }

    #[test]
    fn parse_cli_forms() {
        assert_eq!(MetricSinkKind::parse("full").unwrap(), MetricSinkKind::Full);
        assert_eq!(MetricSinkKind::parse("counting").unwrap(), MetricSinkKind::Counting);
        assert_eq!(MetricSinkKind::parse("ring:64").unwrap(), MetricSinkKind::Ring(64));
        assert_eq!(MetricSinkKind::parse("decimate:10").unwrap(), MetricSinkKind::Decimate(10));
        for bad in
            ["ringo", "ring:", "ring:x", "ring:0", "decimate:0", "decimate:1", "decimate:y", ""]
        {
            assert!(MetricSinkKind::parse(bad).is_err(), "`{bad}` accepted");
        }
    }
}
