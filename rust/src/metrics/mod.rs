//! Evaluation metrics (paper §V.A.3): per-job waiting time and completion
//! time, system makespan, plus the Table-II style summaries.

pub mod fairness;
pub mod summary;

pub use fairness::{by_class, jain_index, slowdowns, ClassAggregate};
pub use summary::{compare_small_large, SchedulerSummary, SmallLargeComparison};

use crate::jobs::{JobId, JobRt};
use crate::util::stats;
use crate::util::Time;

/// Final per-job metrics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JobMetrics {
    pub id: JobId,
    /// Container (cpu-axis) demand — the grant currency and the SD/LD
    /// reporting key.  Kept `u32` so shard wire records and claim CSVs
    /// are unchanged by the vector-demand redesign.
    pub demand: u32,
    pub submit_ms: Time,
    /// Submission -> first task Running.
    pub waiting_ms: Time,
    /// Submission -> last task Completed.
    pub completion_ms: Time,
    /// Completion - waiting = in-cluster execution span.
    pub execution_ms: Time,
}

impl JobMetrics {
    pub fn of(job: &JobRt) -> JobMetrics {
        let waiting = job.waiting_ms().expect("job never started");
        let completion = job.completion_ms().expect("job never finished");
        JobMetrics {
            id: job.id(),
            demand: job.spec.demand.cpu,
            submit_ms: job.spec.submit_ms,
            waiting_ms: waiting,
            completion_ms: completion,
            execution_ms: completion - waiting,
        }
    }
}

/// Exact integer summary of the per-tick utilization stream, accumulated
/// online so the engine never has to retain the `(time, used)` samples.
///
/// `mean_utilization` is **time-weighted integration** over sample
/// intervals: `Σ usedᵢ·(tᵢ₊₁ − tᵢ) / (total · (t_last − t_first))` — each
/// sample's occupancy held until the next sample, the step-function
/// integral of what the cluster actually did.  The seed computed an
/// unweighted mean over tick samples instead, which over-weights whatever
/// regime happens to be sampled densely (uneven tick spacing arises
/// whenever the final tick lands early).  All terms are integers; the
/// single final division is the only float op, so Full and Counting
/// retention produce bit-identical results by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct UtilSummary {
    /// Cluster capacity the fractions are relative to.
    pub total: u32,
    /// Samples observed (independent of sink retention).
    pub samples: u64,
    /// `t_last − t_first` over the sample stream.
    pub span_ms: u64,
    /// `Σ usedᵢ · (tᵢ₊₁ − tᵢ)` — container-milliseconds of occupancy.
    pub area_ms: u64,
    /// `Σ usedᵢ` — for the unweighted fallback when `span_ms == 0`.
    pub sum_used: u64,
    /// Max `usedᵢ` observed.
    pub peak_used: u32,
    /// Accumulation state: (first sample time, last sample value).
    first_last: (Time, u64),
}

impl UtilSummary {
    /// Start an empty accumulator for a cluster of `total` containers.
    pub fn new(total: u32) -> UtilSummary {
        UtilSummary { total, ..Default::default() }
    }

    /// Rebuild a summary from its serialized integer fields (the shard
    /// wire format).  The accumulation state is not carried — a rebuilt
    /// summary answers [`Self::mean_utilization`] but cannot be pushed to.
    pub fn from_parts(
        total: u32,
        samples: u64,
        span_ms: u64,
        area_ms: u64,
        sum_used: u64,
        peak_used: u32,
    ) -> UtilSummary {
        UtilSummary { total, samples, span_ms, area_ms, sum_used, peak_used, first_last: (0, 0) }
    }

    /// Feed one per-tick sample.  Times must be non-decreasing — enforced
    /// with a hard assert: in release builds an out-of-order push would
    /// otherwise wrap `t − t_last` and silently corrupt the exact
    /// integral this type exists to guarantee.
    pub fn push(&mut self, t: Time, used: u32) {
        if self.samples > 0 {
            let t0 = self.first_ms();
            assert!(t >= t0 + self.span_ms, "utilization samples out of order");
            let dt = t - (t0 + self.span_ms);
            self.area_ms += self.last_used() as u64 * dt;
            self.span_ms = t - t0;
        } else {
            self.first_last = (t, 0);
        }
        self.first_last.1 = used as u64;
        self.samples += 1;
        self.sum_used += used as u64;
        self.peak_used = self.peak_used.max(used);
    }

    /// Summarize a retained sample slice in one pass (tests, reports).
    pub fn from_samples(samples: &[(Time, u32)], total: u32) -> UtilSummary {
        let mut acc = UtilSummary::new(total);
        for &(t, used) in samples {
            acc.push(t, used);
        }
        acc
    }

    /// Time of the first sample (0 when empty).
    pub fn first_ms(&self) -> Time {
        self.first_last.0
    }

    /// Most recent sample value (0 when empty).
    pub fn last_used(&self) -> u32 {
        self.first_last.1 as u32
    }

    /// Time-weighted mean busy fraction in [0, 1].  A single sample (or a
    /// zero-length span) has no interval to weight, so it degrades to the
    /// unweighted mean; an empty stream is 0.
    pub fn mean_utilization(&self) -> f64 {
        if self.samples == 0 || self.total == 0 {
            return 0.0;
        }
        if self.span_ms == 0 {
            return self.sum_used as f64 / (self.samples as f64 * self.total as f64);
        }
        self.area_ms as f64 / (self.span_ms as f64 * self.total as f64)
    }
}

/// Exact online summary of the DRESS δ stream: min/max/last plus a
/// time-weighted mean, accumulated the same way as [`UtilSummary`] so the
/// CLI and reports can describe the δ trajectory without any retained
/// samples.  δ is a float, but the accumulation order is identical under
/// every sink, so Full and Counting runs report bit-identical values.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct DeltaSummary {
    pub samples: u64,
    pub span_ms: u64,
    /// `Σ δᵢ · (tᵢ₊₁ − tᵢ)`.
    area: f64,
    /// `Σ δᵢ` (unweighted fallback).
    sum: f64,
    pub min: f64,
    pub max: f64,
    pub last: f64,
    first_ms: Time,
}

impl DeltaSummary {
    /// Feed one per-tick δ sample.  Times must be non-decreasing (hard
    /// assert — see [`UtilSummary::push`]).
    pub fn push(&mut self, t: Time, delta: f64) {
        if self.samples > 0 {
            assert!(t >= self.first_ms + self.span_ms, "delta samples out of order");
            let dt = t - (self.first_ms + self.span_ms);
            self.area += self.last * dt as f64;
            self.span_ms = t - self.first_ms;
            self.min = self.min.min(delta);
            self.max = self.max.max(delta);
        } else {
            self.first_ms = t;
            self.min = delta;
            self.max = delta;
        }
        self.last = delta;
        self.samples += 1;
        self.sum += delta;
    }

    /// Pool another δ stream into this one (federation result merging:
    /// one summary per cell, combined into the run-level view).  Counters
    /// and integrals add, extrema combine, and `last` takes the other
    /// stream's tail, so `mean()` becomes the span-weighted average of the
    /// per-stream means.  Merge order is fixed (cell index), so the result
    /// is deterministic.
    pub fn merge(&mut self, other: &DeltaSummary) {
        if other.samples == 0 {
            return;
        }
        if self.samples == 0 {
            *self = *other;
            return;
        }
        self.samples += other.samples;
        self.span_ms += other.span_ms;
        self.area += other.area;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.last = other.last;
    }

    /// Time-weighted mean δ (unweighted for a zero-length span; 0 empty).
    pub fn mean(&self) -> f64 {
        if self.samples == 0 {
            return 0.0;
        }
        if self.span_ms == 0 {
            return self.sum / self.samples as f64;
        }
        self.area / self.span_ms as f64
    }
}

/// System-level metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemMetrics {
    /// First submission -> last completion (paper: "total execution time
    /// for all jobs").
    pub makespan_ms: Time,
    pub avg_waiting_ms: f64,
    pub median_waiting_ms: f64,
    pub avg_completion_ms: f64,
    pub median_completion_ms: f64,
    /// Time-weighted fraction of containers busy over the tick-sample
    /// span (see [`UtilSummary::mean_utilization`]).
    pub mean_utilization: f64,
}

impl SystemMetrics {
    pub fn of(jobs: &[JobMetrics], util: &UtilSummary) -> SystemMetrics {
        let first_submit = jobs.iter().map(|j| j.submit_ms).min().unwrap_or(0);
        let last_finish = jobs
            .iter()
            .map(|j| j.submit_ms + j.completion_ms)
            .max()
            .unwrap_or(0);
        let w: Vec<f64> = jobs.iter().map(|j| j.waiting_ms as f64).collect();
        let c: Vec<f64> = jobs.iter().map(|j| j.completion_ms as f64).collect();
        SystemMetrics {
            makespan_ms: last_finish - first_submit,
            avg_waiting_ms: stats::mean(&w),
            median_waiting_ms: stats::median(&w),
            avg_completion_ms: stats::mean(&c),
            median_completion_ms: stats::median(&c),
            mean_utilization: util.mean_utilization(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn jm(id: JobId, submit: Time, wait: Time, completion: Time) -> JobMetrics {
        JobMetrics {
            id,
            demand: 4,
            submit_ms: submit,
            waiting_ms: wait,
            completion_ms: completion,
            execution_ms: completion - wait,
        }
    }

    #[test]
    fn makespan_spans_first_submit_to_last_finish() {
        let jobs = [jm(1, 0, 1_000, 10_000), jm(2, 5_000, 2_000, 20_000)];
        let m = SystemMetrics::of(&jobs, &UtilSummary::from_samples(&[], 10));
        assert_eq!(m.makespan_ms, 25_000);
        assert_eq!(m.avg_waiting_ms, 1_500.0);
        assert_eq!(m.avg_completion_ms, 15_000.0);
    }

    #[test]
    fn utilization_is_time_weighted() {
        // Even 1 s intervals: 5 busy for [0, 1s), 10 busy for [1s, 2s) —
        // the step-function integral is (5·1000 + 10·1000) / (10·2000).
        let jobs = [jm(1, 0, 0, 1_000)];
        let util = UtilSummary::from_samples(&[(0, 5), (1_000, 10), (2_000, 0)], 10);
        assert_eq!(util.area_ms, 15_000);
        assert_eq!(util.span_ms, 2_000);
        assert_eq!(util.peak_used, 10);
        let m = SystemMetrics::of(&jobs, &util);
        assert!((m.mean_utilization - 0.75).abs() < 1e-12);
    }

    #[test]
    fn uneven_intervals_diverge_from_unweighted_mean() {
        // The satellite-bug witness: 10 busy for a short 100 ms burst,
        // idle for the following 900 ms.  The unweighted per-sample mean
        // said (1.0 + 0.0 + 0.0) / 3 ≈ 0.333 — counting the idle tail
        // once despite it lasting 9× the busy head.  The time-weighted
        // integral is 10·100 / (10·1000) = 0.1.
        let samples = [(0, 10), (100, 0), (1_000, 0)];
        let util = UtilSummary::from_samples(&samples, 10);
        assert_eq!(util.area_ms, 1_000);
        assert_eq!(util.span_ms, 1_000);
        assert!((util.mean_utilization() - 0.1).abs() < 1e-12);
        let unweighted: f64 = samples.iter().map(|&(_, u)| u as f64 / 10.0).sum::<f64>() / 3.0;
        assert!((unweighted - 1.0 / 3.0).abs() < 1e-12);
        assert!((util.mean_utilization() - unweighted).abs() > 0.2, "fix is observable");
    }

    #[test]
    fn util_summary_incremental_equals_batch_and_degenerates() {
        let samples = [(500, 3), (1_500, 7), (1_700, 2), (9_000, 0)];
        let mut inc = UtilSummary::new(8);
        for &(t, u) in &samples {
            inc.push(t, u);
        }
        assert_eq!(inc, UtilSummary::from_samples(&samples, 8));
        assert_eq!(inc.samples, 4);
        assert_eq!(inc.sum_used, 12);
        assert_eq!(inc.last_used(), 0);
        assert_eq!(inc.first_ms(), 500);
        // Single sample: no interval to weight — unweighted fallback.
        let one = UtilSummary::from_samples(&[(42, 4)], 8);
        assert_eq!(one.span_ms, 0);
        assert!((one.mean_utilization() - 0.5).abs() < 1e-12);
        // Empty stream.
        assert_eq!(UtilSummary::from_samples(&[], 8).mean_utilization(), 0.0);
        // Wire-format roundtrip answers the same mean.
        let wire = UtilSummary::from_parts(
            inc.total, inc.samples, inc.span_ms, inc.area_ms, inc.sum_used, inc.peak_used,
        );
        assert_eq!(wire.mean_utilization(), inc.mean_utilization());
    }

    #[test]
    fn delta_summary_tracks_stream_shape() {
        let mut d = DeltaSummary::default();
        assert_eq!(d.mean(), 0.0);
        d.push(0, 0.10);
        d.push(1_000, 0.30);
        d.push(3_000, 0.20);
        assert_eq!(d.samples, 3);
        assert_eq!(d.span_ms, 3_000);
        assert!((d.min - 0.10).abs() < 1e-12 && (d.max - 0.30).abs() < 1e-12);
        assert!((d.last - 0.20).abs() < 1e-12);
        // Time-weighted: 0.10 for 1 s, 0.30 for 2 s over a 3 s span.
        assert!((d.mean() - (0.10 * 1_000.0 + 0.30 * 2_000.0) / 3_000.0).abs() < 1e-12);
    }

    #[test]
    fn empty_jobs_zero_metrics() {
        let m = SystemMetrics::of(&[], &UtilSummary::from_samples(&[], 10));
        assert_eq!(m.makespan_ms, 0);
        assert_eq!(m.avg_waiting_ms, 0.0);
    }
}
