//! Evaluation metrics (paper §V.A.3): per-job waiting time and completion
//! time, system makespan, plus the Table-II style summaries.

pub mod fairness;
pub mod summary;

pub use fairness::{by_class, jain_index, slowdowns, ClassAggregate};
pub use summary::{compare_small_large, SchedulerSummary, SmallLargeComparison};

use crate::jobs::{JobId, JobRt};
use crate::util::stats;
use crate::util::Time;

/// Final per-job metrics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JobMetrics {
    pub id: JobId,
    pub demand: u32,
    pub submit_ms: Time,
    /// Submission -> first task Running.
    pub waiting_ms: Time,
    /// Submission -> last task Completed.
    pub completion_ms: Time,
    /// Completion - waiting = in-cluster execution span.
    pub execution_ms: Time,
}

impl JobMetrics {
    pub fn of(job: &JobRt) -> JobMetrics {
        let waiting = job.waiting_ms().expect("job never started");
        let completion = job.completion_ms().expect("job never finished");
        JobMetrics {
            id: job.id(),
            demand: job.spec.demand,
            submit_ms: job.spec.submit_ms,
            waiting_ms: waiting,
            completion_ms: completion,
            execution_ms: completion - waiting,
        }
    }
}

/// System-level metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemMetrics {
    /// First submission -> last completion (paper: "total execution time
    /// for all jobs").
    pub makespan_ms: Time,
    pub avg_waiting_ms: f64,
    pub median_waiting_ms: f64,
    pub avg_completion_ms: f64,
    pub median_completion_ms: f64,
    /// Mean fraction of containers busy across tick samples.
    pub mean_utilization: f64,
}

impl SystemMetrics {
    pub fn of(jobs: &[JobMetrics], util: &[(Time, u32)], total_containers: u32) -> SystemMetrics {
        let first_submit = jobs.iter().map(|j| j.submit_ms).min().unwrap_or(0);
        let last_finish = jobs
            .iter()
            .map(|j| j.submit_ms + j.completion_ms)
            .max()
            .unwrap_or(0);
        let w: Vec<f64> = jobs.iter().map(|j| j.waiting_ms as f64).collect();
        let c: Vec<f64> = jobs.iter().map(|j| j.completion_ms as f64).collect();
        let u: Vec<f64> = util
            .iter()
            .map(|&(_, used)| used as f64 / total_containers.max(1) as f64)
            .collect();
        SystemMetrics {
            makespan_ms: last_finish - first_submit,
            avg_waiting_ms: stats::mean(&w),
            median_waiting_ms: stats::median(&w),
            avg_completion_ms: stats::mean(&c),
            median_completion_ms: stats::median(&c),
            mean_utilization: stats::mean(&u),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn jm(id: JobId, submit: Time, wait: Time, completion: Time) -> JobMetrics {
        JobMetrics {
            id,
            demand: 4,
            submit_ms: submit,
            waiting_ms: wait,
            completion_ms: completion,
            execution_ms: completion - wait,
        }
    }

    #[test]
    fn makespan_spans_first_submit_to_last_finish() {
        let jobs = [jm(1, 0, 1_000, 10_000), jm(2, 5_000, 2_000, 20_000)];
        let m = SystemMetrics::of(&jobs, &[], 10);
        assert_eq!(m.makespan_ms, 25_000);
        assert_eq!(m.avg_waiting_ms, 1_500.0);
        assert_eq!(m.avg_completion_ms, 15_000.0);
    }

    #[test]
    fn utilization_mean() {
        let jobs = [jm(1, 0, 0, 1_000)];
        let util = [(0, 5), (1_000, 10), (2_000, 0)];
        let m = SystemMetrics::of(&jobs, &util, 10);
        assert!((m.mean_utilization - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_jobs_zero_metrics() {
        let m = SystemMetrics::of(&[], &[], 10);
        assert_eq!(m.makespan_ms, 0);
        assert_eq!(m.avg_waiting_ms, 0.0);
    }
}
