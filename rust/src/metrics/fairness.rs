//! Fairness and per-category breakdowns: Jain's fairness index over
//! per-job slowdowns, and SD/LD aggregate views — used by reports and by
//! the Fair-scheduler validation tests.

use super::JobMetrics;
use crate::util::stats;

/// Jain's fairness index over a set of nonnegative values:
/// (Σx)² / (n·Σx²); 1.0 = perfectly fair, 1/n = maximally unfair.
pub fn jain_index(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if sq == 0.0 {
        return 1.0;
    }
    (sum * sum) / (xs.len() as f64 * sq)
}

/// Per-job slowdown: completion time normalized by in-cluster execution
/// time (1.0 = no queueing at all).
pub fn slowdowns(jobs: &[JobMetrics]) -> Vec<f64> {
    jobs.iter()
        .map(|j| j.completion_ms as f64 / j.execution_ms.max(1) as f64)
        .collect()
}

/// Aggregate metrics of one demand class.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassAggregate {
    pub n: usize,
    pub avg_waiting_s: f64,
    pub avg_completion_s: f64,
    pub avg_slowdown: f64,
}

/// Split jobs at `small_threshold` demand and aggregate each side.
pub fn by_class(jobs: &[JobMetrics], small_threshold: u32) -> (ClassAggregate, ClassAggregate) {
    let agg = |sel: Vec<&JobMetrics>| {
        let w: Vec<f64> = sel.iter().map(|j| j.waiting_ms as f64 / 1000.0).collect();
        let c: Vec<f64> = sel.iter().map(|j| j.completion_ms as f64 / 1000.0).collect();
        let s: Vec<f64> = sel
            .iter()
            .map(|j| j.completion_ms as f64 / j.execution_ms.max(1) as f64)
            .collect();
        ClassAggregate {
            n: sel.len(),
            avg_waiting_s: stats::mean(&w),
            avg_completion_s: stats::mean(&c),
            avg_slowdown: stats::mean(&s),
        }
    };
    (
        agg(jobs.iter().filter(|j| j.demand <= small_threshold).collect()),
        agg(jobs.iter().filter(|j| j.demand > small_threshold).collect()),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn jm(id: u32, demand: u32, wait: u64, completion: u64) -> JobMetrics {
        JobMetrics {
            id,
            demand,
            submit_ms: 0,
            waiting_ms: wait,
            completion_ms: completion,
            execution_ms: completion - wait,
        }
    }

    #[test]
    fn jain_bounds() {
        assert_eq!(jain_index(&[]), 1.0);
        assert!((jain_index(&[5.0, 5.0, 5.0]) - 1.0).abs() < 1e-12);
        let unfair = jain_index(&[10.0, 0.0, 0.0, 0.0]);
        assert!((unfair - 0.25).abs() < 1e-12);
        let mid = jain_index(&[1.0, 2.0, 3.0]);
        assert!(0.25 < mid && mid < 1.0);
    }

    #[test]
    fn slowdown_of_unqueued_job_is_one() {
        let s = slowdowns(&[jm(1, 2, 0, 10_000)]);
        assert!((s[0] - 1.0).abs() < 1e-12);
        let s = slowdowns(&[jm(2, 2, 10_000, 20_000)]);
        assert!((s[0] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn by_class_splits_at_threshold() {
        let jobs = [jm(1, 2, 1_000, 3_000), jm(2, 20, 4_000, 10_000), jm(3, 4, 0, 2_000)];
        let (small, large) = by_class(&jobs, 4);
        assert_eq!(small.n, 2);
        assert_eq!(large.n, 1);
        assert!((small.avg_completion_s - 2.5).abs() < 1e-12);
        assert!((large.avg_waiting_s - 4.0).abs() < 1e-12);
    }
}
