//! Table-II style scheduler summaries and small-vs-large breakdowns
//! (the numbers quoted throughout paper §V.B).

use super::JobMetrics;
use crate::util::stats;

/// One row of the paper's Table II.
#[derive(Debug, Clone, PartialEq)]
pub struct SchedulerSummary {
    pub scheduler: String,
    pub makespan_s: f64,
    pub avg_waiting_s: f64,
    pub median_waiting_s: f64,
    pub avg_completion_s: f64,
    pub median_completion_s: f64,
}

impl SchedulerSummary {
    pub fn of(scheduler: &str, sys: &crate::metrics::SystemMetrics) -> Self {
        SchedulerSummary {
            scheduler: scheduler.to_string(),
            makespan_s: sys.makespan_ms as f64 / 1000.0,
            avg_waiting_s: sys.avg_waiting_ms / 1000.0,
            median_waiting_s: sys.median_waiting_ms / 1000.0,
            avg_completion_s: sys.avg_completion_ms / 1000.0,
            median_completion_s: sys.median_completion_ms / 1000.0,
        }
    }
}

/// Small-vs-large job comparison between DRESS and a baseline
/// (the "%-reduction for small jobs" headline numbers).
#[derive(Debug, Clone, PartialEq)]
pub struct SmallLargeComparison {
    /// IDs classified small (demand <= threshold).
    pub small_ids: Vec<u32>,
    /// Mean completion-time change for small jobs, % (negative = faster).
    pub small_completion_change_pct: f64,
    /// Mean completion-time change for large jobs, %.
    pub large_completion_change_pct: f64,
    /// Mean completion-time *increase* among the large jobs that got slower
    /// (the paper's "+16.1% on average" is over affected jobs only).
    pub large_penalized_mean_pct: f64,
    /// Mean waiting-time change for small jobs, %.
    pub small_waiting_change_pct: f64,
    /// Max single-job completion reduction among small jobs, %.
    pub best_small_reduction_pct: f64,
    /// Makespan change, %.
    pub makespan_change_pct: f64,
}

/// Compare DRESS vs a baseline on the same workload. `small_threshold` is
/// the demand cutoff used for reporting (the paper uses "< 10 containers"
/// for the Spark set; we use the θ rule's realized cutoff).
pub fn compare_small_large(
    dress: &[JobMetrics],
    baseline: &[JobMetrics],
    dress_makespan_ms: u64,
    baseline_makespan_ms: u64,
    small_threshold: u32,
) -> SmallLargeComparison {
    assert_eq!(dress.len(), baseline.len(), "same workload required");
    let mut small_ids = Vec::new();
    let mut small_c = Vec::new();
    let mut large_c = Vec::new();
    let mut large_pen = Vec::new();
    let mut small_w = Vec::new();
    let mut best = 0.0_f64;
    for (d, b) in dress.iter().zip(baseline) {
        assert_eq!(d.id, b.id, "job order must match");
        let dc = stats::pct_change(b.completion_ms as f64, d.completion_ms as f64);
        let dw = stats::pct_change(b.waiting_ms.max(1) as f64, d.waiting_ms.max(1) as f64);
        if d.demand <= small_threshold {
            small_ids.push(d.id);
            small_c.push(dc);
            small_w.push(dw);
            best = best.min(dc);
        } else {
            large_c.push(dc);
            if dc > 0.0 {
                large_pen.push(dc);
            }
        }
    }
    SmallLargeComparison {
        small_ids,
        small_completion_change_pct: stats::mean(&small_c),
        large_completion_change_pct: stats::mean(&large_c),
        large_penalized_mean_pct: stats::mean(&large_pen),
        small_waiting_change_pct: stats::mean(&small_w),
        best_small_reduction_pct: best,
        makespan_change_pct: stats::pct_change(baseline_makespan_ms as f64, dress_makespan_ms as f64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::SystemMetrics;

    fn jm(id: u32, demand: u32, wait: u64, completion: u64) -> JobMetrics {
        JobMetrics {
            id,
            demand,
            submit_ms: 0,
            waiting_ms: wait,
            completion_ms: completion,
            execution_ms: completion - wait,
        }
    }

    #[test]
    fn comparison_classifies_by_demand() {
        let dress = [jm(1, 2, 100, 1_000), jm(2, 20, 500, 6_000)];
        let base = [jm(1, 2, 400, 2_000), jm(2, 20, 400, 5_000)];
        let cmp = compare_small_large(&dress, &base, 10_000, 10_000, 4);
        assert_eq!(cmp.small_ids, vec![1]);
        assert!((cmp.small_completion_change_pct + 50.0).abs() < 1e-9);
        assert!((cmp.large_completion_change_pct - 20.0).abs() < 1e-9);
        assert!((cmp.large_penalized_mean_pct - 20.0).abs() < 1e-9);
        assert!((cmp.best_small_reduction_pct + 50.0).abs() < 1e-9);
        assert_eq!(cmp.makespan_change_pct, 0.0);
    }

    #[test]
    fn summary_converts_to_seconds() {
        let jobs = [jm(1, 2, 1_000, 3_000)];
        let sys = SystemMetrics::of(&jobs, &crate::metrics::UtilSummary::from_samples(&[], 10));
        let s = SchedulerSummary::of("dress", &sys);
        assert_eq!(s.avg_waiting_s, 1.0);
        assert_eq!(s.avg_completion_s, 3.0);
        assert_eq!(s.scheduler, "dress");
    }

    #[test]
    #[should_panic(expected = "same workload")]
    fn mismatched_lengths_panic() {
        compare_small_large(&[], &[jm(1, 1, 1, 1)], 0, 0, 4);
    }
}
