//! Heartbeat observation channel.
//!
//! On YARN, slave nodes report container state transitions to the
//! ResourceManager via periodic heartbeats; DRESS's "enriched heartbeat
//! message" (paper §V.A.1) carries starting delays too.  Schedulers and the
//! estimator may observe the cluster ONLY through these records — never by
//! peeking at simulator ground truth.
//!
//! The per-tick batch buffer (`buf`) is always kept — schedulers consume
//! it — but the *history* retention is pluggable ([`SinkKind`]): the seed
//! unconditionally double-pushed every transition into a full-run history
//! vector, which dominated memory on 100k-job runs even when the engine's
//! trace opt-out was set.  Counting retention keeps a count only; ring
//! retention keeps the last `cap` transitions.

use super::container::{ContainerId, ContainerState};
use crate::jobs::JobId;
use crate::sim::SinkKind;
use crate::util::Time;

/// One observed container state transition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Transition {
    pub time: Time,
    pub container: ContainerId,
    pub job: JobId,
    /// Task index *within the job* (YARN exposes task attempt ids).
    pub task: usize,
    pub to: ContainerState,
}

/// History retention state, mirroring [`SinkKind`].
#[derive(Debug, Clone)]
enum History {
    Full(Vec<Transition>),
    Counting,
    Ring { cap: usize, buf: Vec<Transition>, head: usize },
}

/// Accumulates transitions between scheduler ticks and hands them out as
/// heartbeat batches.
#[derive(Debug, Clone)]
pub struct HeartbeatLog {
    buf: Vec<Transition>,
    history: History,
    /// Total transitions observed (independent of retention).
    recorded: u64,
}

impl Default for HeartbeatLog {
    fn default() -> Self {
        HeartbeatLog::new()
    }
}

impl HeartbeatLog {
    /// Full-history log (figures / validation — the seed behavior).
    pub fn new() -> Self {
        HeartbeatLog::with_retention(SinkKind::Full)
    }

    /// Log with an explicit history retention policy.
    pub fn with_retention(kind: SinkKind) -> Self {
        let history = match kind {
            SinkKind::Full => History::Full(Vec::new()),
            SinkKind::Counting | SinkKind::Ring(0) => History::Counting,
            SinkKind::Ring(cap) => History::Ring { cap, buf: Vec::with_capacity(cap), head: 0 },
        };
        HeartbeatLog { buf: Vec::new(), history, recorded: 0 }
    }

    /// Record a transition (called by the engine when containers move).
    pub fn record(&mut self, t: Transition) {
        self.buf.push(t);
        self.recorded += 1;
        match &mut self.history {
            History::Full(h) => h.push(t),
            History::Counting => {}
            History::Ring { cap, buf, head } => {
                if buf.len() < *cap {
                    buf.push(t);
                } else {
                    buf[*head] = t;
                    *head = (*head + 1) % *cap;
                }
            }
        }
    }

    /// Drain everything observed since the previous heartbeat.
    pub fn drain(&mut self) -> Vec<Transition> {
        std::mem::take(&mut self.buf)
    }

    /// Retained history (figures / validation only), always in
    /// chronological order: complete under full retention; empty under
    /// counting; the last `cap` transitions under ring retention —
    /// unrotated here, exactly like `TraceSink::finish`, so consumers
    /// never see the ring's internal rotation.  Borrowed (no copy) except
    /// in the ring arm, the only retention that needs materialization.
    pub fn history(&self) -> std::borrow::Cow<'_, [Transition]> {
        use std::borrow::Cow;
        match &self.history {
            History::Full(h) => Cow::Borrowed(h.as_slice()),
            History::Counting => Cow::Borrowed(&[]),
            History::Ring { buf, head, .. } => {
                let mut out = Vec::with_capacity(buf.len());
                out.extend_from_slice(&buf[*head..]);
                out.extend_from_slice(&buf[..*head]);
                Cow::Owned(out)
            }
        }
    }

    /// Transitions currently retained in memory (no copy).
    pub fn history_len(&self) -> usize {
        match &self.history {
            History::Full(h) => h.len(),
            History::Counting => 0,
            History::Ring { buf, .. } => buf.len(),
        }
    }

    /// Total transitions observed over the run, independent of retention.
    pub fn recorded(&self) -> u64 {
        self.recorded
    }

    /// Pending (not yet drained) count.
    pub fn pending(&self) -> usize {
        self.buf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tr(time: Time, c: ContainerId, to: ContainerState) -> Transition {
        Transition { time, container: c, job: 1, task: 0, to }
    }

    #[test]
    fn drain_clears_buffer_keeps_history() {
        let mut log = HeartbeatLog::new();
        log.record(tr(10, 0, ContainerState::Running));
        log.record(tr(20, 1, ContainerState::Completed));
        assert_eq!(log.pending(), 2);
        let batch = log.drain();
        assert_eq!(batch.len(), 2);
        assert_eq!(log.pending(), 0);
        assert_eq!(log.history().len(), 2);
        log.record(tr(30, 2, ContainerState::Running));
        assert_eq!(log.drain().len(), 1);
        assert_eq!(log.history().len(), 3);
        assert_eq!(log.recorded(), 3);
    }

    #[test]
    fn counting_retention_drops_history_but_counts() {
        let mut log = HeartbeatLog::with_retention(SinkKind::Counting);
        for i in 0..100 {
            log.record(tr(i, i as u32, ContainerState::Running));
        }
        // Batches still flow to the scheduler...
        assert_eq!(log.pending(), 100);
        assert_eq!(log.drain().len(), 100);
        // ...but nothing is retained beyond the count.
        assert_eq!(log.history_len(), 0);
        assert_eq!(log.recorded(), 100);
    }

    #[test]
    fn ring_retention_bounds_history() {
        let mut log = HeartbeatLog::with_retention(SinkKind::Ring(8));
        for i in 0..50 {
            log.record(tr(i, i as u32, ContainerState::Running));
        }
        assert_eq!(log.history_len(), 8);
        assert_eq!(log.recorded(), 50);
        // The ring holds exactly the last 8 transitions, already in
        // chronological order — no sort: the rotation-order bug this
        // guards against returned [48, 49, 42, 43, ...].
        let times: Vec<Time> = log.history().iter().map(|t| t.time).collect();
        assert_eq!(times, (42..50).collect::<Vec<_>>());
    }

    #[test]
    fn ring_below_capacity_is_chronological_too() {
        let mut log = HeartbeatLog::with_retention(SinkKind::Ring(8));
        for i in 0..5 {
            log.record(tr(i * 10, i as u32, ContainerState::Running));
        }
        let times: Vec<Time> = log.history().iter().map(|t| t.time).collect();
        assert_eq!(times, vec![0, 10, 20, 30, 40]);
    }
}
