//! Heartbeat observation channel.
//!
//! On YARN, slave nodes report container state transitions to the
//! ResourceManager via periodic heartbeats; DRESS's "enriched heartbeat
//! message" (paper §V.A.1) carries starting delays too.  Schedulers and the
//! estimator may observe the cluster ONLY through these records — never by
//! peeking at simulator ground truth.

use super::container::{ContainerId, ContainerState};
use crate::jobs::JobId;
use crate::util::Time;

/// One observed container state transition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Transition {
    pub time: Time,
    pub container: ContainerId,
    pub job: JobId,
    /// Task index *within the job* (YARN exposes task attempt ids).
    pub task: usize,
    pub to: ContainerState,
}

/// Accumulates transitions between scheduler ticks and hands them out as
/// heartbeat batches.
#[derive(Debug, Default, Clone)]
pub struct HeartbeatLog {
    buf: Vec<Transition>,
    /// Complete history (for trace export / figures).
    history: Vec<Transition>,
}

impl HeartbeatLog {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a transition (called by the engine when containers move).
    pub fn record(&mut self, t: Transition) {
        self.buf.push(t);
        self.history.push(t);
    }

    /// Drain everything observed since the previous heartbeat.
    pub fn drain(&mut self) -> Vec<Transition> {
        std::mem::take(&mut self.buf)
    }

    /// Full history (figures / validation only).
    pub fn history(&self) -> &[Transition] {
        &self.history
    }

    /// Pending (not yet drained) count.
    pub fn pending(&self) -> usize {
        self.buf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tr(time: Time, c: ContainerId, to: ContainerState) -> Transition {
        Transition { time, container: c, job: 1, task: 0, to }
    }

    #[test]
    fn drain_clears_buffer_keeps_history() {
        let mut log = HeartbeatLog::new();
        log.record(tr(10, 0, ContainerState::Running));
        log.record(tr(20, 1, ContainerState::Completed));
        assert_eq!(log.pending(), 2);
        let batch = log.drain();
        assert_eq!(batch.len(), 2);
        assert_eq!(log.pending(), 0);
        assert_eq!(log.history().len(), 2);
        log.record(tr(30, 2, ContainerState::Running));
        assert_eq!(log.drain().len(), 1);
        assert_eq!(log.history().len(), 3);
    }
}
