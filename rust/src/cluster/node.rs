//! A slave node: a bundle of container slots plus occupancy accounting.

/// Node identifier.
pub type NodeId = u16;

/// One slave node. The paper's testbed has 5 of these (c220g2).
///
/// Besides container slots (the cpu axis), every node carries a memory
/// budget of one unit per slot.  Scalar-demand containers have a
/// one-unit footprint, so in scalar runs `mem_in_use == in_use` and
/// `mem_free() == free()` invariantly — the memory axis can never bind.
/// Vector-demand containers carry `Demand::mem_per_container()` units
/// each, so a node can run out of memory before it runs out of slots.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Node {
    pub id: NodeId,
    /// Container slots this node offers (cpu-axis capacity).
    pub capacity: u32,
    /// Slots currently held by live containers.
    pub in_use: u32,
    /// Memory units this node offers: one per slot.
    pub mem_capacity: u32,
    /// Memory units currently held by live containers.
    pub mem_in_use: u32,
    /// False while the node is crashed (fault injection). A down node
    /// contributes nothing to capacity, free, or used — on either axis.
    pub up: bool,
}

impl Node {
    pub fn new(id: NodeId, capacity: u32) -> Self {
        Node { id, capacity, in_use: 0, mem_capacity: capacity, mem_in_use: 0, up: true }
    }

    pub fn free(&self) -> u32 {
        if !self.up {
            return 0;
        }
        self.capacity - self.in_use
    }

    pub fn mem_free(&self) -> u32 {
        if !self.up {
            return 0;
        }
        self.mem_capacity - self.mem_in_use
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn free_tracks_in_use() {
        let mut n = Node::new(0, 8);
        assert_eq!(n.free(), 8);
        n.in_use = 3;
        assert_eq!(n.free(), 5);
        n.in_use = 8;
        assert_eq!(n.free(), 0);
    }

    #[test]
    fn down_node_has_no_free_slots() {
        let mut n = Node::new(0, 8);
        n.up = false;
        assert_eq!(n.free(), 0);
        assert_eq!(n.mem_free(), 0);
        n.up = true;
        assert_eq!(n.free(), 8);
        assert_eq!(n.mem_free(), 8);
    }

    #[test]
    fn mem_axis_tracks_independently() {
        let mut n = Node::new(0, 8);
        assert_eq!(n.mem_capacity, 8, "one memory unit per slot");
        n.in_use = 2;
        n.mem_in_use = 6; // two 3-unit containers
        assert_eq!(n.free(), 6);
        assert_eq!(n.mem_free(), 2);
    }
}
