//! A slave node: a bundle of container slots plus occupancy accounting.

/// Node identifier.
pub type NodeId = u16;

/// One slave node. The paper's testbed has 5 of these (c220g2).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Node {
    pub id: NodeId,
    /// Container slots this node offers.
    pub capacity: u32,
    /// Slots currently held by live containers.
    pub in_use: u32,
}

impl Node {
    pub fn new(id: NodeId, capacity: u32) -> Self {
        Node { id, capacity, in_use: 0 }
    }

    pub fn free(&self) -> u32 {
        self.capacity - self.in_use
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn free_tracks_in_use() {
        let mut n = Node::new(0, 8);
        assert_eq!(n.free(), 8);
        n.in_use = 3;
        assert_eq!(n.free(), 5);
        n.in_use = 8;
        assert_eq!(n.free(), 0);
    }
}
