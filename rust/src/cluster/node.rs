//! A slave node: a bundle of container slots plus occupancy accounting.

/// Node identifier.
pub type NodeId = u16;

/// One slave node. The paper's testbed has 5 of these (c220g2).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Node {
    pub id: NodeId,
    /// Container slots this node offers.
    pub capacity: u32,
    /// Slots currently held by live containers.
    pub in_use: u32,
    /// False while the node is crashed (fault injection). A down node
    /// contributes nothing to capacity, free, or used.
    pub up: bool,
}

impl Node {
    pub fn new(id: NodeId, capacity: u32) -> Self {
        Node { id, capacity, in_use: 0, up: true }
    }

    pub fn free(&self) -> u32 {
        if !self.up {
            return 0;
        }
        self.capacity - self.in_use
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn free_tracks_in_use() {
        let mut n = Node::new(0, 8);
        assert_eq!(n.free(), 8);
        n.in_use = 3;
        assert_eq!(n.free(), 5);
        n.in_use = 8;
        assert_eq!(n.free(), 0);
    }

    #[test]
    fn down_node_has_no_free_slots() {
        let mut n = Node::new(0, 8);
        n.up = false;
        assert_eq!(n.free(), 0);
        n.up = true;
        assert_eq!(n.free(), 8);
    }
}
