//! YARN-fidelity cluster substrate: nodes with container slots, the
//! container state machine (New -> Reserved -> Allocated -> Acquired ->
//! Running -> Completed, paper §III.A.1), and heartbeat reports — the only
//! observation channel schedulers and the estimator may use.

pub mod container;
pub mod heartbeat;
pub mod node;

pub use container::{Container, ContainerId, ContainerState};
pub use heartbeat::{HeartbeatLog, Transition};
pub use node::{Node, NodeId};

use crate::jobs::JobId;
use crate::util::Time;

/// The cluster: a set of nodes plus live container records.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub nodes: Vec<Node>,
    /// All containers ever created (index == ContainerId).
    pub containers: Vec<Container>,
}

impl Cluster {
    /// `nodes` nodes with `slots` container slots each (paper: 5 nodes).
    pub fn new(nodes: u16, slots: u32) -> Self {
        Cluster {
            nodes: (0..nodes).map(|id| Node::new(id, slots)).collect(),
            containers: Vec::new(),
        }
    }

    /// Live container capacity (the paper's `Tot_R`).  Crashed nodes
    /// contribute nothing, so this is time-varying under a fault plan.
    pub fn total(&self) -> u32 {
        self.nodes.iter().filter(|n| n.up).map(|n| n.capacity).sum()
    }

    /// Capacity as provisioned, ignoring crashes — the fixed `Tot_R` the
    /// cluster was built with.  Demand clamping uses this so a job's
    /// request is not permanently truncated by a transient outage.
    pub fn nominal_total(&self) -> u32 {
        self.nodes.iter().map(|n| n.capacity).sum()
    }

    /// Currently free slots (the paper's `A_c`).
    pub fn free(&self) -> u32 {
        self.nodes.iter().map(|n| n.free()).sum()
    }

    /// Currently occupied slots.
    pub fn used(&self) -> u32 {
        self.nodes.iter().filter(|n| n.up).map(|n| n.in_use).sum()
    }

    /// Allocate a new container for (job, phase, task) on the least-loaded
    /// node with a free slot. Returns the container id, or None if full.
    pub fn allocate(
        &mut self,
        job: JobId,
        phase: usize,
        task: usize,
        now: Time,
    ) -> Option<ContainerId> {
        let node = self
            .nodes
            .iter_mut()
            .filter(|n| n.up && n.free() > 0)
            .min_by_key(|n| n.in_use)?;
        node.in_use += 1;
        let id = self.containers.len() as ContainerId;
        self.containers.push(Container::new(id, node.id, job, phase, task, now));
        Some(id)
    }

    /// Release the slot held by a completed container.
    pub fn release(&mut self, cid: ContainerId) {
        let c = &self.containers[cid as usize];
        debug_assert_eq!(c.state, ContainerState::Completed, "release of live container");
        let node = &mut self.nodes[c.node as usize];
        debug_assert!(node.in_use > 0);
        node.in_use -= 1;
    }

    /// Crash `node` at time `now`: take it out of capacity and kill every
    /// live container on it.  Returns the killed container ids so the
    /// engine can requeue their tasks.  The node's slot accounting is
    /// zeroed here; the killed containers must NOT also be `release`d.
    pub fn fail_node(&mut self, node: NodeId, now: Time) -> Vec<ContainerId> {
        let n = &mut self.nodes[node as usize];
        debug_assert!(n.up, "fail of already-down node {node}");
        n.up = false;
        n.in_use = 0;
        let mut killed = Vec::new();
        for c in self.containers.iter_mut() {
            if c.node == node && !c.dead && c.state != ContainerState::Completed {
                c.kill(now);
                killed.push(c.id);
            }
        }
        killed
    }

    /// Bring a crashed node back. Its slots rejoin `total`/`free` empty.
    pub fn recover_node(&mut self, node: NodeId) {
        let n = &mut self.nodes[node as usize];
        debug_assert!(!n.up, "recover of live node {node}");
        debug_assert_eq!(n.in_use, 0, "down node held slots");
        n.up = true;
    }

    pub fn container(&self, cid: ContainerId) -> &Container {
        &self.containers[cid as usize]
    }

    pub fn container_mut(&mut self, cid: ContainerId) -> &mut Container {
        &mut self.containers[cid as usize]
    }

    /// Invariant: free + used == total (checked by property tests).
    pub fn conservation_holds(&self) -> bool {
        self.free() + self.used() == self.total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_accounting() {
        let mut cl = Cluster::new(5, 8);
        assert_eq!(cl.total(), 40);
        assert_eq!(cl.free(), 40);
        let c0 = cl.allocate(1, 0, 0, 100).unwrap();
        let _c1 = cl.allocate(1, 0, 1, 100).unwrap();
        assert_eq!(cl.free(), 38);
        assert!(cl.conservation_holds());
        cl.container_mut(c0).state = ContainerState::Completed;
        cl.release(c0);
        assert_eq!(cl.free(), 39);
        assert!(cl.conservation_holds());
    }

    #[test]
    fn allocate_balances_nodes() {
        let mut cl = Cluster::new(2, 2);
        let a = cl.allocate(1, 0, 0, 0).unwrap();
        let b = cl.allocate(1, 0, 1, 0).unwrap();
        assert_ne!(cl.container(a).node, cl.container(b).node);
    }

    #[test]
    fn fail_node_kills_containers_and_drops_capacity() {
        let mut cl = Cluster::new(2, 2);
        let a = cl.allocate(1, 0, 0, 0).unwrap();
        let b = cl.allocate(1, 0, 1, 0).unwrap();
        let victim = cl.container(a).node;
        let killed = cl.fail_node(victim, 50);
        assert_eq!(killed, vec![a]);
        assert!(cl.container(a).dead);
        assert_eq!(cl.container(a).state, ContainerState::Completed);
        assert!(!cl.container(b).dead);
        assert_eq!(cl.total(), 2);
        assert_eq!(cl.used(), 1);
        assert_eq!(cl.free(), 1);
        assert!(cl.conservation_holds());
        // Allocation avoids the down node.
        let c = cl.allocate(2, 0, 0, 60).unwrap();
        assert_ne!(cl.container(c).node, victim);
        assert!(cl.allocate(2, 0, 1, 60).is_none(), "no slots on the up node left");
        cl.recover_node(victim);
        assert_eq!(cl.total(), 4);
        assert_eq!(cl.nominal_total(), 4);
        assert!(cl.conservation_holds());
        let d = cl.allocate(2, 0, 1, 70).unwrap();
        assert_eq!(cl.container(d).node, victim, "recovered node is emptiest");
    }

    #[test]
    fn allocate_exhausts_to_none() {
        let mut cl = Cluster::new(1, 2);
        assert!(cl.allocate(1, 0, 0, 0).is_some());
        assert!(cl.allocate(1, 0, 1, 0).is_some());
        assert!(cl.allocate(1, 0, 2, 0).is_none());
        assert_eq!(cl.free(), 0);
    }
}
