//! YARN-fidelity cluster substrate: nodes with container slots, the
//! container state machine (New -> Reserved -> Allocated -> Acquired ->
//! Running -> Completed, paper §III.A.1), and heartbeat reports — the only
//! observation channel schedulers and the estimator may use.

pub mod container;
pub mod heartbeat;
pub mod node;

pub use container::{Container, ContainerId, ContainerState};
pub use heartbeat::{HeartbeatLog, Transition};
pub use node::{Node, NodeId};

use crate::jobs::JobId;
use crate::util::Time;

/// The cluster: a set of nodes plus live container records.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub nodes: Vec<Node>,
    /// All containers ever created (index == ContainerId).
    pub containers: Vec<Container>,
}

impl Cluster {
    /// `nodes` nodes with `slots` container slots each (paper: 5 nodes).
    pub fn new(nodes: u16, slots: u32) -> Self {
        Cluster {
            nodes: (0..nodes).map(|id| Node::new(id, slots)).collect(),
            containers: Vec::new(),
        }
    }

    /// Live container capacity (the paper's `Tot_R`).  Crashed nodes
    /// contribute nothing, so this is time-varying under a fault plan.
    pub fn total(&self) -> u32 {
        self.nodes.iter().filter(|n| n.up).map(|n| n.capacity).sum()
    }

    /// Capacity as provisioned, ignoring crashes — the fixed `Tot_R` the
    /// cluster was built with.  Demand clamping uses this so a job's
    /// request is not permanently truncated by a transient outage.
    pub fn nominal_total(&self) -> u32 {
        self.nodes.iter().map(|n| n.capacity).sum()
    }

    /// Live memory capacity (one unit per provisioned slot); crashed
    /// nodes contribute nothing, mirroring `total`.
    pub fn total_mem(&self) -> u32 {
        self.nodes.iter().filter(|n| n.up).map(|n| n.mem_capacity).sum()
    }

    /// Memory capacity as provisioned, ignoring crashes (mem-axis
    /// counterpart of `nominal_total`, used for per-axis demand clamping).
    pub fn nominal_total_mem(&self) -> u32 {
        self.nodes.iter().map(|n| n.mem_capacity).sum()
    }

    /// Largest single-node memory capacity as provisioned (crashed nodes
    /// included — the bound must not shrink during a transient outage).
    /// This is the widest per-container footprint any node can ever
    /// host: a job demanding more memory per container than this fits no
    /// node and would starve forever, so the engine clamps to it.
    pub fn max_node_mem(&self) -> u32 {
        self.nodes.iter().map(|n| n.mem_capacity).max().unwrap_or(1)
    }

    /// Currently free slots (the paper's `A_c`).
    pub fn free(&self) -> u32 {
        self.nodes.iter().map(|n| n.free()).sum()
    }

    /// Currently occupied slots.
    pub fn used(&self) -> u32 {
        self.nodes.iter().filter(|n| n.up).map(|n| n.in_use).sum()
    }

    /// Currently free memory units across live nodes.
    pub fn free_mem(&self) -> u32 {
        self.nodes.iter().map(|n| n.mem_free()).sum()
    }

    /// Currently occupied memory units.
    pub fn used_mem(&self) -> u32 {
        self.nodes.iter().filter(|n| n.up).map(|n| n.mem_in_use).sum()
    }

    /// Allocate a new container of `mem` memory units for (job, phase,
    /// task) on the least-loaded node with a free slot and enough free
    /// memory. Returns the container id, or None if no node fits.
    ///
    /// For `mem == 1` (every scalar demand) the memory filter is
    /// implied by `free() > 0`, so node choice is bit-identical to the
    /// pre-vector scheme: least `in_use` among up nodes with a free slot.
    pub fn allocate(
        &mut self,
        job: JobId,
        phase: usize,
        task: usize,
        mem: u32,
        now: Time,
    ) -> Option<ContainerId> {
        let node = self
            .nodes
            .iter_mut()
            .filter(|n| n.up && n.free() > 0 && n.mem_free() >= mem)
            .min_by_key(|n| n.in_use)?;
        node.in_use += 1;
        node.mem_in_use += mem;
        let id = self.containers.len() as ContainerId;
        self.containers.push(Container::new(id, node.id, job, phase, task, mem, now));
        Some(id)
    }

    /// Release the slot (and memory) held by a completed container.
    pub fn release(&mut self, cid: ContainerId) {
        let c = &self.containers[cid as usize];
        debug_assert_eq!(c.state, ContainerState::Completed, "release of live container");
        let mem = c.mem;
        let node = &mut self.nodes[c.node as usize];
        debug_assert!(node.in_use > 0);
        debug_assert!(node.mem_in_use >= mem);
        node.in_use -= 1;
        node.mem_in_use -= mem;
    }

    /// Crash `node` at time `now`: take it out of capacity and kill every
    /// live container on it.  Returns the killed container ids so the
    /// engine can requeue their tasks.  The node's slot accounting is
    /// zeroed here; the killed containers must NOT also be `release`d.
    pub fn fail_node(&mut self, node: NodeId, now: Time) -> Vec<ContainerId> {
        let n = &mut self.nodes[node as usize];
        debug_assert!(n.up, "fail of already-down node {node}");
        n.up = false;
        n.in_use = 0;
        n.mem_in_use = 0;
        let mut killed = Vec::new();
        for c in self.containers.iter_mut() {
            if c.node == node && !c.dead && c.state != ContainerState::Completed {
                c.kill(now);
                killed.push(c.id);
            }
        }
        killed
    }

    /// Whether `node` is currently up (cell-level fault handling guards
    /// on this before `fail_node`/`recover_node`, whose debug asserts
    /// require a state change).
    pub fn node_up(&self, node: NodeId) -> bool {
        self.nodes[node as usize].up
    }

    /// Bring a crashed node back. Its slots rejoin `total`/`free` empty.
    pub fn recover_node(&mut self, node: NodeId) {
        let n = &mut self.nodes[node as usize];
        debug_assert!(!n.up, "recover of live node {node}");
        debug_assert_eq!(n.in_use, 0, "down node held slots");
        debug_assert_eq!(n.mem_in_use, 0, "down node held memory");
        n.up = true;
    }

    pub fn container(&self, cid: ContainerId) -> &Container {
        &self.containers[cid as usize]
    }

    pub fn container_mut(&mut self, cid: ContainerId) -> &mut Container {
        &mut self.containers[cid as usize]
    }

    /// Invariant: free + used == total, on both resource axes (checked by
    /// property tests and engine debug assertions).
    pub fn conservation_holds(&self) -> bool {
        self.free() + self.used() == self.total()
            && self.free_mem() + self.used_mem() == self.total_mem()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_accounting() {
        let mut cl = Cluster::new(5, 8);
        assert_eq!(cl.total(), 40);
        assert_eq!(cl.free(), 40);
        assert_eq!(cl.total_mem(), 40);
        assert_eq!(cl.free_mem(), 40);
        let c0 = cl.allocate(1, 0, 0, 1, 100).unwrap();
        let _c1 = cl.allocate(1, 0, 1, 1, 100).unwrap();
        assert_eq!(cl.free(), 38);
        assert_eq!(cl.free_mem(), 38);
        assert!(cl.conservation_holds());
        cl.container_mut(c0).state = ContainerState::Completed;
        cl.release(c0);
        assert_eq!(cl.free(), 39);
        assert_eq!(cl.free_mem(), 39);
        assert!(cl.conservation_holds());
    }

    #[test]
    fn allocate_balances_nodes() {
        let mut cl = Cluster::new(2, 2);
        let a = cl.allocate(1, 0, 0, 1, 0).unwrap();
        let b = cl.allocate(1, 0, 1, 1, 0).unwrap();
        assert_ne!(cl.container(a).node, cl.container(b).node);
    }

    #[test]
    fn fail_node_kills_containers_and_drops_capacity() {
        let mut cl = Cluster::new(2, 2);
        let a = cl.allocate(1, 0, 0, 1, 0).unwrap();
        let b = cl.allocate(1, 0, 1, 1, 0).unwrap();
        let victim = cl.container(a).node;
        let killed = cl.fail_node(victim, 50);
        assert_eq!(killed, vec![a]);
        assert!(cl.container(a).dead);
        assert_eq!(cl.container(a).state, ContainerState::Completed);
        assert!(!cl.container(b).dead);
        assert_eq!(cl.total(), 2);
        assert_eq!(cl.used(), 1);
        assert_eq!(cl.free(), 1);
        assert!(cl.conservation_holds());
        // Allocation avoids the down node.
        let c = cl.allocate(2, 0, 0, 1, 60).unwrap();
        assert_ne!(cl.container(c).node, victim);
        assert!(cl.allocate(2, 0, 1, 1, 60).is_none(), "no slots on the up node left");
        cl.recover_node(victim);
        assert_eq!(cl.total(), 4);
        assert_eq!(cl.nominal_total(), 4);
        assert_eq!(cl.nominal_total_mem(), 4);
        assert!(cl.conservation_holds());
        let d = cl.allocate(2, 0, 1, 1, 70).unwrap();
        assert_eq!(cl.container(d).node, victim, "recovered node is emptiest");
    }

    #[test]
    fn allocate_exhausts_to_none() {
        let mut cl = Cluster::new(1, 2);
        assert!(cl.allocate(1, 0, 0, 1, 0).is_some());
        assert!(cl.allocate(1, 0, 1, 1, 0).is_some());
        assert!(cl.allocate(1, 0, 2, 1, 0).is_none());
        assert_eq!(cl.free(), 0);
    }

    #[test]
    fn memory_binds_before_slots_for_fat_containers() {
        // 1 node x 4 slots = 4 mem units; 3-unit containers exhaust
        // memory after one grant even though 3 slots remain.
        let mut cl = Cluster::new(1, 4);
        let a = cl.allocate(1, 0, 0, 3, 0).unwrap();
        assert_eq!(cl.free(), 3);
        assert_eq!(cl.free_mem(), 1);
        assert!(cl.conservation_holds());
        assert!(cl.allocate(1, 0, 1, 3, 0).is_none(), "memory axis must bind");
        // A thin container still fits.
        assert!(cl.allocate(1, 0, 1, 1, 0).is_some());
        // Releasing the fat container returns all 3 units.
        cl.container_mut(a).state = ContainerState::Completed;
        cl.release(a);
        assert_eq!(cl.free_mem(), 3);
        assert!(cl.conservation_holds());
    }

    #[test]
    fn fail_node_zeroes_memory_accounting() {
        let mut cl = Cluster::new(2, 4);
        let a = cl.allocate(1, 0, 0, 3, 0).unwrap();
        let victim = cl.container(a).node;
        cl.fail_node(victim, 10);
        assert!(cl.conservation_holds());
        cl.recover_node(victim);
        assert_eq!(cl.free_mem(), 8);
        assert!(cl.conservation_holds());
    }
}
