//! The YARN container state machine.
//!
//! Paper §III.A.1: "the transition delay varies from time to time when a
//! container's state moves from New to Running, that passes by the other
//! three states, Reserved, Allocated, and Acquired."  Those stochastic
//! per-hop delays, combined with multi-round allocation, produce the
//! starting-time variation Δps that DRESS's estimator measures.

use crate::jobs::JobId;
use crate::util::Time;

/// Container identifier (monotonically increasing per simulation).
pub type ContainerId = u32;

/// Container lifecycle states, in order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ContainerState {
    New,
    Reserved,
    Allocated,
    Acquired,
    Running,
    Completed,
}

impl ContainerState {
    /// The successor state, or None for Completed.
    pub fn next(self) -> Option<ContainerState> {
        use ContainerState::*;
        match self {
            New => Some(Reserved),
            Reserved => Some(Allocated),
            Allocated => Some(Acquired),
            Acquired => Some(Running),
            Running => Some(Completed),
            Completed => None,
        }
    }

    /// All states in machine order.
    pub const ALL: [ContainerState; 6] = [
        ContainerState::New,
        ContainerState::Reserved,
        ContainerState::Allocated,
        ContainerState::Acquired,
        ContainerState::Running,
        ContainerState::Completed,
    ];
}

impl std::fmt::Display for ContainerState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            ContainerState::New => "new",
            ContainerState::Reserved => "reserved",
            ContainerState::Allocated => "allocated",
            ContainerState::Acquired => "acquired",
            ContainerState::Running => "running",
            ContainerState::Completed => "completed",
        };
        write!(f, "{s}")
    }
}

/// A live (or completed) container bound to one task of one job.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Container {
    pub id: ContainerId,
    pub node: super::NodeId,
    pub job: JobId,
    /// Ground-truth phase index — available to the *simulator* and to
    /// validation tests, but NOT to the estimator (which must infer phases
    /// from timing alone, per Algorithms 1-2).
    pub phase: usize,
    pub task: usize,
    pub state: ContainerState,
    /// Memory units this container occupies on its node
    /// (`Demand::mem_per_container()`; exactly 1 for scalar demands).
    pub mem: u32,
    /// When the container entered `state`.
    pub state_since: Time,
    /// When the container entered Running (0 until then).
    pub run_start: Time,
    /// Set when the container was killed by a node crash. Dead containers
    /// are parked in Completed; any events still queued for them must be
    /// ignored (the queue cannot remove entries).
    pub dead: bool,
}

impl Container {
    pub fn new(
        id: ContainerId,
        node: super::NodeId,
        job: JobId,
        phase: usize,
        task: usize,
        mem: u32,
        now: Time,
    ) -> Self {
        Container {
            id,
            node,
            job,
            phase,
            task,
            state: ContainerState::New,
            mem,
            state_since: now,
            run_start: 0,
            dead: false,
        }
    }

    /// Kill the container at time `now` (node crash): park it in
    /// Completed so the lifecycle never advances again, and flag it dead
    /// so stale queued events can be recognized and dropped.
    pub fn kill(&mut self, now: Time) {
        debug_assert!(!self.dead, "double kill of container {}", self.id);
        self.dead = true;
        self.state = ContainerState::Completed;
        self.state_since = now;
    }

    /// Advance to the next state at time `now`; returns the new state.
    /// Panics if called on a Completed container.
    pub fn advance(&mut self, now: Time) -> ContainerState {
        let next = self
            .state
            .next()
            .unwrap_or_else(|| panic!("advance on completed container {}", self.id));
        self.state = next;
        self.state_since = now;
        if next == ContainerState::Running {
            self.run_start = now;
        }
        next
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_machine_order() {
        let mut s = ContainerState::New;
        let mut seen = vec![s];
        while let Some(n) = s.next() {
            seen.push(n);
            s = n;
        }
        assert_eq!(seen, ContainerState::ALL.to_vec());
        assert_eq!(ContainerState::Completed.next(), None);
    }

    #[test]
    fn advance_walks_all_states() {
        let mut c = Container::new(0, 0, 1, 0, 0, 1, 10);
        let mut t = 10;
        for expect in &ContainerState::ALL[1..] {
            t += 5;
            assert_eq!(c.advance(t), *expect);
            assert_eq!(c.state_since, t);
        }
        assert_eq!(c.run_start, 10 + 5 * 4);
    }

    #[test]
    #[should_panic(expected = "advance on completed")]
    fn advance_past_completed_panics() {
        let mut c = Container::new(0, 0, 1, 0, 0, 1, 0);
        for _ in 0..6 {
            c.advance(1);
        }
    }
}
