//! The paper's published numbers, encoded as [`PaperClaim`]s so every run
//! prints paper-vs-measured rows (EXPERIMENTS.md records them).

use crate::report::PaperClaim;

fn c(id: &str, description: &str, paper: f64, direction: i8) -> PaperClaim {
    PaperClaim { id: id.into(), description: description.into(), paper, direction }
}

/// All claims extracted from §I, §V.B and Table II.
pub fn paper_claims() -> Vec<PaperClaim> {
    vec![
        // Fig 1 (motivating example, §I).
        c("FIG1.fcfs-makespan-s", "FCFS makespan of the 4-job example", 40.0, 0),
        c("FIG1.fcfs-avg-wait-s", "FCFS average waiting time", 16.0, 0),
        c("FIG1.rearranged-makespan-s", "rearranged makespan (DRESS should reach <= ~30s)", 30.0, 0),
        c("FIG1.rearranged-avg-wait-s", "rearranged average waiting (DRESS <= 5.75s)", 5.75, 2),
        // Fig 6/7 + Table II (Spark-on-YARN, 20 jobs).
        c("FIG6.small-waiting-change-pct", "small-job waiting reduction (Spark)", -80.0, -1),
        c("FIG7.small-completion-change-pct", "small-job completion change (Spark), paper -27.6%", -27.6, -1),
        c("FIG7.large-penalized-mean-pct", "affected large jobs pay a bounded penalty, paper +16.1%", 16.1, 1),
        c("TAB2.makespan-change-pct", "makespan stays stable (paper +0.64%; band |x|<=10%)", 0.64, 3),
        c("TAB2.avg-wait-change-pct", "avg waiting improves (paper -14.7%)", -14.7, -1),
        c("TAB2.avg-completion-change-pct", "avg completion improves (paper -6.6%)", -6.6, -1),
        // Fig 8/9 (MapReduce, 20 jobs).
        c("FIG8.small-waiting-change-pct", "small-job waiting reduction (MR)", -80.0, -1),
        c("FIG9.small-completion-change-pct", "small-job completion change (MR), paper -25.7%", -25.7, -1),
        // Fig 10-13 (mixed, small fraction sweep).
        c("FIG10.small-completion-change-pct", "10% small jobs, paper -76.1% (best pair)", -76.1, -1),
        c("FIG11.small-completion-change-pct", "20% small jobs, paper -36.2%", -36.2, -1),
        c("FIG12.small-completion-change-pct", "30% small jobs, paper -21.9%", -21.9, -1),
        c("FIG13.small-completion-change-pct", "40% small jobs, paper -23.7%", -23.7, -1),
    ]
}

/// The claims a multi-seed sweep evaluates as mean-over-seeds: the
/// small-job completion headlines (Figs 7/9) and the makespan-stability
/// row of Table II.  `expt::sweep::run_pair_sweep` produces one
/// [`crate::expt::ExperimentPair`] per seed; the CLI `sweep --paper`
/// path averages each claim's measured value across seeds and prints
/// paper-vs-measured rows — single-seed repro numbers are noisy, and the
/// paper itself reports means over repeated runs.
pub fn sweep_claims() -> Vec<PaperClaim> {
    vec![
        claim("FIG7.small-completion-change-pct"),
        claim("FIG9.small-completion-change-pct"),
        claim("TAB2.makespan-change-pct"),
    ]
}

/// Look up one claim by id.
pub fn claim(id: &str) -> PaperClaim {
    paper_claims()
        .into_iter()
        .find(|c| c.id == id)
        .unwrap_or_else(|| panic!("unknown paper claim {id}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn claims_cover_all_figures_and_table() {
        let ids: Vec<String> = paper_claims().iter().map(|c| c.id.clone()).collect();
        for fig in ["FIG1", "FIG6", "FIG7", "FIG8", "FIG9", "FIG10", "FIG11", "FIG12", "FIG13", "TAB2"] {
            assert!(ids.iter().any(|i| i.starts_with(fig)), "missing {fig}");
        }
    }

    #[test]
    fn claim_lookup() {
        assert_eq!(claim("FIG1.fcfs-makespan-s").paper, 40.0);
    }

    #[test]
    fn sweep_claims_are_known_claims() {
        let ids: Vec<String> = paper_claims().iter().map(|c| c.id.clone()).collect();
        let sc = sweep_claims();
        assert_eq!(sc.len(), 3);
        for c in sc {
            assert!(ids.contains(&c.id), "sweep claim {} not in registry", c.id);
        }
    }

    #[test]
    #[should_panic(expected = "unknown paper claim")]
    fn unknown_claim_panics() {
        claim("FIG99.nope");
    }
}
