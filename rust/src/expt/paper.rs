//! The paper's published numbers, encoded as [`PaperClaim`]s so every run
//! prints paper-vs-measured rows (EXPERIMENTS.md records them).

use crate::metrics::SmallLargeComparison;
use crate::report::{ci_holds, PaperClaim};
use crate::util::stats::Ci95;

fn c(id: &str, description: &str, paper: f64, direction: i8) -> PaperClaim {
    PaperClaim { id: id.into(), description: description.into(), paper, direction }
}

/// All claims extracted from §I, §V.B and Table II.
pub fn paper_claims() -> Vec<PaperClaim> {
    vec![
        // Fig 1 (motivating example, §I).
        c("FIG1.fcfs-makespan-s", "FCFS makespan of the 4-job example", 40.0, 0),
        c("FIG1.fcfs-avg-wait-s", "FCFS average waiting time", 16.0, 0),
        c("FIG1.rearranged-makespan-s", "rearranged makespan (DRESS should reach <= ~30s)", 30.0, 0),
        c("FIG1.rearranged-avg-wait-s", "rearranged average waiting (DRESS <= 5.75s)", 5.75, 2),
        // Fig 6/7 + Table II (Spark-on-YARN, 20 jobs).
        c("FIG6.small-waiting-change-pct", "small-job waiting reduction (Spark)", -80.0, -1),
        c("FIG7.small-completion-change-pct", "small-job completion change (Spark), paper -27.6%", -27.6, -1),
        c("FIG7.large-penalized-mean-pct", "affected large jobs pay a bounded penalty, paper +16.1%", 16.1, 1),
        c("TAB2.makespan-change-pct", "makespan stays stable (paper +0.64%; band |x|<=10%)", 0.64, 3),
        c("TAB2.avg-wait-change-pct", "avg waiting improves (paper -14.7%)", -14.7, -1),
        c("TAB2.avg-completion-change-pct", "avg completion improves (paper -6.6%)", -6.6, -1),
        // Fig 8/9 (MapReduce, 20 jobs).
        c("FIG8.small-waiting-change-pct", "small-job waiting reduction (MR)", -80.0, -1),
        c("FIG9.small-completion-change-pct", "small-job completion change (MR), paper -25.7%", -25.7, -1),
        // Fig 10-13 (mixed, small fraction sweep).
        c("FIG10.small-completion-change-pct", "10% small jobs, paper -76.1% (best pair)", -76.1, -1),
        c("FIG11.small-completion-change-pct", "20% small jobs, paper -36.2%", -36.2, -1),
        c("FIG12.small-completion-change-pct", "30% small jobs, paper -21.9%", -21.9, -1),
        c("FIG13.small-completion-change-pct", "40% small jobs, paper -23.7%", -23.7, -1),
    ]
}

/// The claims a multi-seed sweep evaluates across seeds: the small-job
/// completion headlines (Figs 7/9) and the makespan-stability row of
/// Table II.  Single-seed repro numbers are noisy — the paper itself
/// reports means over repeated runs — so the `sweep --paper` path runs
/// one DRESS-vs-Capacity pair per seed and judges each claim on its
/// `mean ± 95% CI` via [`evaluate_sweep_claims`], not the point mean.
pub fn sweep_claims() -> Vec<PaperClaim> {
    vec![
        claim("FIG7.small-completion-change-pct"),
        claim("FIG9.small-completion-change-pct"),
        claim("TAB2.makespan-change-pct"),
    ]
}

/// One sweep claim judged on its confidence bound: the per-seed measured
/// values, their Student-t 95% CI, and whether the claim's shape holds
/// over the whole interval ([`crate::report::ci_holds`]).
#[derive(Debug, Clone)]
pub struct SweepClaimCheck {
    pub claim: PaperClaim,
    pub per_seed: Vec<f64>,
    pub ci: Ci95,
    pub holds: bool,
}

/// Evaluate every [`sweep_claims`] entry from per-seed DRESS-vs-baseline
/// comparisons (`spark` = the Fig 7 / Table II workload, `mr` = Fig 9),
/// one comparison per seed in seed order.  A claim passes only if its
/// entire 95% interval satisfies the paper's shape — one lucky seed can
/// no longer carry the headline number.
pub fn evaluate_sweep_claims(
    spark: &[SmallLargeComparison],
    mr: &[SmallLargeComparison],
) -> Vec<SweepClaimCheck> {
    assert_eq!(spark.len(), mr.len(), "one comparison per seed for both workloads");
    sweep_claims()
        .into_iter()
        .map(|claim| {
            let per_seed: Vec<f64> = match claim.id.as_str() {
                "FIG7.small-completion-change-pct" => {
                    spark.iter().map(|c| c.small_completion_change_pct).collect()
                }
                "FIG9.small-completion-change-pct" => {
                    mr.iter().map(|c| c.small_completion_change_pct).collect()
                }
                "TAB2.makespan-change-pct" => {
                    spark.iter().map(|c| c.makespan_change_pct).collect()
                }
                other => panic!("no sweep aggregation defined for claim {other}"),
            };
            let ci = Ci95::of(&per_seed);
            let holds = ci_holds(&claim, &ci);
            SweepClaimCheck { claim, per_seed, ci, holds }
        })
        .collect()
}

/// Look up one claim by id.
pub fn claim(id: &str) -> PaperClaim {
    paper_claims()
        .into_iter()
        .find(|c| c.id == id)
        .unwrap_or_else(|| panic!("unknown paper claim {id}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn claims_cover_all_figures_and_table() {
        let ids: Vec<String> = paper_claims().iter().map(|c| c.id.clone()).collect();
        for fig in ["FIG1", "FIG6", "FIG7", "FIG8", "FIG9", "FIG10", "FIG11", "FIG12", "FIG13", "TAB2"] {
            assert!(ids.iter().any(|i| i.starts_with(fig)), "missing {fig}");
        }
    }

    #[test]
    fn claim_lookup() {
        assert_eq!(claim("FIG1.fcfs-makespan-s").paper, 40.0);
    }

    #[test]
    fn sweep_claims_are_known_claims() {
        let ids: Vec<String> = paper_claims().iter().map(|c| c.id.clone()).collect();
        let sc = sweep_claims();
        assert_eq!(sc.len(), 3);
        for c in sc {
            assert!(ids.contains(&c.id), "sweep claim {} not in registry", c.id);
        }
    }

    #[test]
    #[should_panic(expected = "unknown paper claim")]
    fn unknown_claim_panics() {
        claim("FIG99.nope");
    }

    fn cmp(small_completion: f64, makespan: f64) -> SmallLargeComparison {
        SmallLargeComparison {
            small_ids: vec![1],
            small_completion_change_pct: small_completion,
            large_completion_change_pct: 0.0,
            large_penalized_mean_pct: 0.0,
            small_waiting_change_pct: 0.0,
            best_small_reduction_pct: small_completion,
            makespan_change_pct: makespan,
        }
    }

    #[test]
    fn evaluate_judges_on_the_ci_bound() {
        // Spark seeds consistently negative and tight: FIG7 + TAB2 hold.
        let spark = [cmp(-30.0, 1.0), cmp(-28.0, -1.0), cmp(-26.0, 0.5)];
        // MR seeds straddle zero with huge spread: FIG9's CI crosses zero
        // even though its *mean* is negative — the point check would pass,
        // the CI-bound check must not.
        let mr = [cmp(-40.0, 0.0), cmp(35.0, 0.0), cmp(-10.0, 0.0)];
        let checks = evaluate_sweep_claims(&spark, &mr);
        assert_eq!(checks.len(), 3);
        let by_id = |id: &str| checks.iter().find(|c| c.claim.id == id).unwrap();

        let fig7 = by_id("FIG7.small-completion-change-pct");
        assert_eq!(fig7.per_seed, vec![-30.0, -28.0, -26.0]);
        assert_eq!(fig7.ci.n, 3);
        assert!(fig7.holds, "tight all-negative interval must hold: {:?}", fig7.ci);

        let fig9 = by_id("FIG9.small-completion-change-pct");
        assert!(fig9.ci.mean < 0.0, "point mean is negative");
        assert!(!fig9.holds, "zero-crossing interval must miss: {:?}", fig9.ci);

        let tab2 = by_id("TAB2.makespan-change-pct");
        assert!(tab2.holds, "makespan stays in the stability band: {:?}", tab2.ci);
    }

    #[test]
    fn evaluate_single_seed_degrades_to_point_check() {
        let checks = evaluate_sweep_claims(&[cmp(-20.0, 2.0)], &[cmp(-15.0, 0.0)]);
        assert!(checks.iter().all(|c| c.ci.n == 1 && c.ci.half == 0.0));
        assert!(checks.iter().all(|c| c.holds));
    }
}
