//! Experiment registry: one entry per table/figure of the paper's
//! evaluation (§V), each reproducible from the CLI (`dress repro <id>`),
//! from benches (`cargo bench`), and from integration tests.

pub mod experiments;
pub mod paper;
pub mod sweep;

pub use experiments::{
    ablation, fig1, mixed_setting, mr20, run_pair, spark20, trace_benchmark, DressVariant,
    ExperimentPair, Fig1Result,
};
pub use paper::{paper_claims, sweep_claims};
pub use sweep::{run_pair_sweep, run_sweep, SweepGrid, SweepPoint, SweepWorkload};
