//! Experiment registry: one entry per table/figure of the paper's
//! evaluation (§V), each reproducible from the CLI (`dress repro <id>`),
//! from benches (`cargo bench`), and from integration tests.

pub mod experiments;
pub mod paper;
pub mod shard;
pub mod sweep;

pub use experiments::{
    ablation, fig1, mixed_setting, mr20, run_pair, spark20, trace_benchmark, DressVariant,
    ExperimentPair, Fig1Result,
};
pub use paper::{evaluate_sweep_claims, paper_claims, sweep_claims, SweepClaimCheck};
pub use shard::{
    grid_fingerprint, merge_shards, render_sweep_report, run_shard, shard_from_json,
    shard_to_json, sweep_claim_checks, sweep_stat_rows, CellSummary, ShardFile, ShardSpec,
    SweepMeta, SweepMode,
};
pub use sweep::{
    bench_grid, paper_grid, run_cells, run_pair_sweep, run_sweep, SweepGrid, SweepPoint,
    SweepWorkload,
};
