//! Experiment implementations: the workload + config + run recipe behind
//! every figure and table.

use crate::config::{ExperimentConfig, SchedKind};
use crate::jobs::{JobSpec, Platform};
use crate::metrics::{compare_small_large, SmallLargeComparison};
use crate::sim::engine::run_experiment;
use crate::sim::RunResult;
use crate::workload::{generate, motivating_example, Benchmark, WorkloadMix};

/// Demand cutoff used for small/large *reporting* (matches the realized
/// θ=10% rule on the 40-container default cluster).
pub const SMALL_DEMAND: u32 = 4;

/// A DRESS-vs-baseline pair on the identical workload.
#[derive(Debug, Clone)]
pub struct ExperimentPair {
    pub dress: RunResult,
    pub baseline: RunResult,
    pub comparison: SmallLargeComparison,
}

/// Run the same spec list under DRESS and under `baseline_kind`.
pub fn run_pair(
    cfg: &ExperimentConfig,
    specs: Vec<JobSpec>,
    baseline_kind: SchedKind,
) -> ExperimentPair {
    let mut dress_cfg = cfg.clone();
    dress_cfg.sched.kind = SchedKind::Dress;
    let mut base_cfg = cfg.clone();
    base_cfg.sched.kind = baseline_kind;

    let dress = run_experiment(&dress_cfg, specs.clone());
    let baseline = run_experiment(&base_cfg, specs);
    let comparison = compare_small_large(
        &dress.jobs,
        &baseline.jobs,
        dress.system.makespan_ms,
        baseline.system.makespan_ms,
        SMALL_DEMAND,
    );
    ExperimentPair { dress, baseline, comparison }
}

// ---------------------------------------------------------------- Fig 1

/// Fig. 1 outcome: makespan + average waiting under FCFS vs DRESS.
#[derive(Debug, Clone)]
pub struct Fig1Result {
    pub fcfs_makespan_s: f64,
    pub fcfs_avg_wait_s: f64,
    pub dress_makespan_s: f64,
    pub dress_avg_wait_s: f64,
}

/// The motivating example: 6 containers, 4 jobs (R3/L10, R4/L20, R2/L5,
/// R2/L8) at 1 s arrivals.  FCFS serializes J2 behind J1; DRESS's reserve
/// lets the small jobs run alongside, reproducing the rearrangement.
pub fn fig1() -> Fig1Result {
    let mut cfg = ExperimentConfig::default();
    cfg.cluster.nodes = 1;
    cfg.cluster.slots_per_node = 6;
    cfg.cluster.hb_ms = 500;
    // The paper's idealized example has no startup latency; keep delays
    // tiny so the numbers land near the idealized 40 s / 30 s.
    cfg.cluster.delays.new_to_reserved_ms = 1.0;
    cfg.cluster.delays.reserved_to_allocated_ms = 1.0;
    cfg.cluster.delays.allocated_to_acquired_ms = 1.0;
    cfg.cluster.delays.acquired_to_running_ms = 2.0;
    cfg.cluster.delays.sigma = 0.01;
    cfg.sched.theta = 0.4; // R2 jobs are "small" on a 6-container cluster

    // The paper's idealized FCFS narrative freezes the queue behind the
    // delayed J2 (waits 0/9/28/27 s) — strict FIFO reproduces that.
    let mut fifo_cfg = cfg.clone();
    fifo_cfg.sched.kind = SchedKind::Fifo;
    let fifo = crate::sim::Engine::new(
        fifo_cfg,
        motivating_example(),
        Box::new(crate::sched::FifoScheduler::strict()),
    )
    .run();

    let mut dress_cfg = cfg;
    dress_cfg.sched.kind = SchedKind::Dress;
    dress_cfg.sched.delta0 = 0.34; // reserve ~2 of 6 containers
    let dress = run_experiment(&dress_cfg, motivating_example());

    Fig1Result {
        fcfs_makespan_s: fifo.system.makespan_ms as f64 / 1000.0,
        fcfs_avg_wait_s: fifo.system.avg_waiting_ms / 1000.0,
        dress_makespan_s: dress.system.makespan_ms as f64 / 1000.0,
        dress_avg_wait_s: dress.system.avg_waiting_ms / 1000.0,
    }
}

// ------------------------------------------------------------- Figs 2-4

/// Run a single benchmark job alone on the default cluster and return its
/// task trace (Figs 2, 3, 4).
pub fn trace_benchmark(bench: Benchmark, platform: Platform, seed: u64) -> RunResult {
    let mut cfg = ExperimentConfig::default();
    cfg.sched.kind = SchedKind::Capacity;
    cfg.workload.seed = seed;
    let mut rng = crate::util::rng::Rng::new(seed);
    let spec = crate::workload::build_job(1, bench, platform, false, 0, 1.0, &mut rng);
    run_experiment(&cfg, vec![spec])
}

// ------------------------------------------- Figs 6/7 + Table II, Figs 8/9

/// 20 Spark-on-YARN jobs vs Capacity (Figs 6-7, Table II).
pub fn spark20(seed: u64) -> ExperimentPair {
    let mut cfg = ExperimentConfig::default();
    cfg.workload.seed = seed;
    let specs = generate(20, WorkloadMix::Spark, 0.30, 5_000, seed);
    run_pair(&cfg, specs, SchedKind::Capacity)
}

/// 20 MapReduce jobs vs Capacity (Figs 8-9).
pub fn mr20(seed: u64) -> ExperimentPair {
    let mut cfg = ExperimentConfig::default();
    cfg.workload.seed = seed;
    let specs = generate(20, WorkloadMix::MapReduce, 0.30, 5_000, seed);
    run_pair(&cfg, specs, SchedKind::Capacity)
}

// ---------------------------------------------------------- Figs 10-13

/// Mixed MR+Spark setting with the given small-job fraction (Figs 10-13:
/// 10% / 20% / 30% / 40%).
pub fn mixed_setting(small_frac: f64, seed: u64) -> ExperimentPair {
    let mut cfg = ExperimentConfig::default();
    cfg.workload.seed = seed;
    cfg.workload.small_frac = small_frac;
    let specs = generate(20, WorkloadMix::Mixed, small_frac, 5_000, seed);
    run_pair(&cfg, specs, SchedKind::Capacity)
}

// ----------------------------------------------------------- Ablations

/// Ablation variants of DRESS (DESIGN.md §5: design-choice benches).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DressVariant {
    /// Full DRESS: dynamic δ (Algorithm 3) + release estimator (Algo 1-2).
    Full,
    /// δ frozen at δ₀ — measures the value of dynamic adjustment.
    StaticDelta,
    /// Dynamic δ but F₁ = F₂ = 0 — measures the value of the estimator.
    NoEstimator,
}

/// Run one DRESS variant against Capacity on the standard mixed workload.
pub fn ablation(variant: DressVariant, seed: u64) -> ExperimentPair {
    let cfg = ExperimentConfig::default();
    let specs = generate(20, WorkloadMix::Mixed, 0.3, 5_000, seed);

    let mut dress =
        crate::sched::DressScheduler::new(&cfg.sched, cfg.cluster.total_containers());
    match variant {
        DressVariant::Full => {}
        DressVariant::StaticDelta => dress.freeze_delta = true,
        DressVariant::NoEstimator => dress.disable_estimator = true,
    }
    let mut dress_cfg = cfg.clone();
    dress_cfg.sched.kind = SchedKind::Dress;
    let dress_run = crate::sim::Engine::new(dress_cfg, specs.clone(), Box::new(dress)).run();

    let mut base_cfg = cfg;
    base_cfg.sched.kind = SchedKind::Capacity;
    let baseline = run_experiment(&base_cfg, specs);

    let comparison = compare_small_large(
        &dress_run.jobs,
        &baseline.jobs,
        dress_run.system.makespan_ms,
        baseline.system.makespan_ms,
        SMALL_DEMAND,
    );
    ExperimentPair { dress: dress_run, baseline, comparison }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_shape_holds() {
        let r = fig1();
        // FCFS serializes: makespan near the paper's 40 s (startup noise
        // allowed); DRESS rearranges: strictly better on both metrics.
        assert!(r.fcfs_makespan_s > 35.0, "fcfs makespan {}", r.fcfs_makespan_s);
        assert!(
            r.dress_makespan_s < r.fcfs_makespan_s,
            "dress {} !< fcfs {}",
            r.dress_makespan_s,
            r.fcfs_makespan_s
        );
        assert!(
            r.dress_avg_wait_s < r.fcfs_avg_wait_s,
            "dress wait {} !< fcfs wait {}",
            r.dress_avg_wait_s,
            r.fcfs_avg_wait_s
        );
    }

    #[test]
    fn trace_produces_phases() {
        let r = trace_benchmark(Benchmark::WordCount, Platform::MapReduce, 3);
        let tasks = r.trace.job_tasks(1);
        assert!(tasks.len() >= 24, "20 map + 4 reduce tasks, got {}", tasks.len());
        assert!(tasks.iter().any(|t| t.phase == 1), "reduce phase ran");
    }
}
