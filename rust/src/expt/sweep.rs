//! Parallel sweep executor: fan independent `run_experiment` invocations —
//! a seed × scheduler × workload grid — across all cores.
//!
//! DRESS's headline numbers come from sweeping seeds, schedulers and
//! workload mixes over congested clusters; each cell is an independent,
//! deterministic simulation, so the sweep is embarrassingly parallel.
//! The executor is zero-dependency: `std::thread::scope` workers steal
//! cells from a shared atomic cursor, and results land **by grid index,
//! not completion order**, so `run_sweep(grid, n)` is bit-identical to
//! `run_sweep(grid, 1)` for every `n` (enforced by
//! `tests/golden_determinism.rs`).
//!
//! Grid index layout (workload-major, seed-minor):
//!
//! ```text
//! idx = (workload_i * scheds.len() + sched_i) * seeds.len() + seed_i
//! ```

use crate::config::{ExperimentConfig, SchedKind};
use crate::jobs::JobSpec;
use crate::metrics::compare_small_large;
use crate::sim::{run_experiment_with, EngineOptions, RunResult};
use crate::workload::WorkloadMix;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use super::experiments::{ExperimentPair, SMALL_DEMAND};

/// One workload axis point; `build(seed)` materializes the spec list.
///
/// An alias for [`crate::workload::WorkloadSource`] — the enum moved to
/// the workload layer when trace ingestion joined the sweep grid (its
/// variant set, field names, and `Debug` form are unchanged, so existing
/// grid fingerprints are preserved).
pub use crate::workload::WorkloadSource as SweepWorkload;

/// The full sweep specification: every (workload, sched, seed) cell runs
/// `base` with that scheduler and that seed.
#[derive(Debug, Clone)]
pub struct SweepGrid {
    pub base: ExperimentConfig,
    pub seeds: Vec<u64>,
    pub scheds: Vec<SchedKind>,
    pub workloads: Vec<SweepWorkload>,
    pub opts: EngineOptions,
}

/// Decomposed grid coordinates of one cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SweepPoint {
    pub workload: usize,
    pub sched: usize,
    pub seed: usize,
}

impl SweepGrid {
    /// Total number of cells.
    pub fn len(&self) -> usize {
        self.workloads.len() * self.scheds.len() * self.seeds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Coordinates of cell `idx` (workload-major, seed-minor).
    pub fn point(&self, idx: usize) -> SweepPoint {
        assert!(idx < self.len(), "cell {idx} out of range {}", self.len());
        let per_workload = self.scheds.len() * self.seeds.len();
        SweepPoint {
            workload: idx / per_workload,
            sched: (idx % per_workload) / self.seeds.len(),
            seed: idx % self.seeds.len(),
        }
    }

    /// Inverse of [`Self::point`].
    pub fn index(&self, p: SweepPoint) -> usize {
        (p.workload * self.scheds.len() + p.sched) * self.seeds.len() + p.seed
    }

    /// Materialize the config + specs for one cell.
    pub fn cell(&self, idx: usize) -> (ExperimentConfig, Vec<JobSpec>) {
        let p = self.point(idx);
        let seed = self.seeds[p.seed];
        let mut cfg = self.base.clone();
        cfg.sched.kind = self.scheds[p.sched];
        cfg.workload.seed = seed;
        (cfg, self.workloads[p.workload].build(seed))
    }

    fn run_cell(&self, idx: usize) -> RunResult {
        let (cfg, specs) = self.cell(idx);
        run_experiment_with(&cfg, specs, self.opts)
    }
}

/// Resolve a `--jobs` value: 0 means "all cores".
pub fn effective_jobs(jobs: usize) -> usize {
    if jobs > 0 {
        jobs
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Run an explicit set of grid cells on up to `jobs` worker threads
/// (0 = all cores).  Returns `(grid_index, RunResult)` pairs **in the
/// order of `indices`** — identical output for any `jobs`, since cells
/// are independent and each run is deterministic.  This is the primitive
/// both [`run_sweep`] (all cells) and the shard runner
/// (`expt::shard::run_shard`, every Nth cell) fan out through.
pub fn run_cells(grid: &SweepGrid, indices: &[usize], jobs: usize) -> Vec<(usize, RunResult)> {
    let total = indices.len();
    let jobs = effective_jobs(jobs).min(total.max(1));
    if jobs <= 1 {
        return indices.iter().map(|&i| (i, grid.run_cell(i))).collect();
    }
    let cursor = AtomicUsize::new(0);
    let done: Mutex<Vec<(usize, RunResult)>> = Mutex::new(Vec::with_capacity(total));
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| {
                // Work stealing over the shared cursor: threads that draw
                // short cells immediately pull the next index, so the
                // sweep load-balances without a scheduler.
                let mut local: Vec<(usize, RunResult)> = Vec::new();
                loop {
                    let k = cursor.fetch_add(1, Ordering::Relaxed);
                    if k >= total {
                        break;
                    }
                    local.push((k, grid.run_cell(indices[k])));
                }
                done.lock().unwrap().extend(local);
            });
        }
    });
    let mut tagged = done.into_inner().unwrap();
    // Deterministic ordering: land results by position in `indices`, not
    // completion order.  Positions are unique, so the sort is total.
    tagged.sort_by_key(|&(k, _)| k);
    assert_eq!(tagged.len(), total, "sweep lost cells");
    tagged.into_iter().map(|(k, r)| (indices[k], r)).collect()
}

/// Run every cell of `grid` on up to `jobs` worker threads (0 = all
/// cores).  Returns one `RunResult` per cell **in grid-index order** —
/// identical output for any `jobs`, since cells are independent and each
/// run is deterministic.
pub fn run_sweep(grid: &SweepGrid, jobs: usize) -> Vec<RunResult> {
    let indices: Vec<usize> = (0..grid.len()).collect();
    run_cells(grid, &indices, jobs).into_iter().map(|(_, r)| r).collect()
}

/// The DRESS-vs-Capacity pair grid behind the multi-seed paper-claim
/// sweep (Figs 7/9 + Table II): workload 0 is the 20-job Spark mix,
/// workload 1 the 20-job MapReduce mix, schedulers `[dress, capacity]`.
/// Shared by `dress sweep --paper`, the shard runner, and the CI sweep
/// matrix so every path fingerprints the identical grid.
pub fn paper_grid(seeds: &[u64]) -> SweepGrid {
    SweepGrid {
        base: ExperimentConfig::default(),
        seeds: seeds.to_vec(),
        scheds: vec![SchedKind::Dress, SchedKind::Capacity],
        workloads: vec![
            SweepWorkload::Generate {
                n: 20,
                mix: WorkloadMix::Spark,
                small_frac: 0.30,
                arrival_ms: 5_000,
            },
            SweepWorkload::Generate {
                n: 20,
                mix: WorkloadMix::MapReduce,
                small_frac: 0.30,
                arrival_ms: 5_000,
            },
        ],
        opts: EngineOptions::default(),
    }
}

/// The fixed grid `benches/perf_sweep.rs` measures.  Lives in the library
/// so `tests/bench_schema.rs` can recompute its fingerprint and reject a
/// checked-in `BENCH_engine.json` that silently drifted from the current
/// grid definition.
pub fn bench_grid() -> SweepGrid {
    SweepGrid {
        base: ExperimentConfig::default(),
        seeds: (0..8).map(|i| 0xD8E5 + i).collect(),
        scheds: vec![SchedKind::Capacity, SchedKind::Dress],
        workloads: vec![SweepWorkload::CongestedBurst { n: 500, arrival_mean_ms: 50 }],
        opts: EngineOptions::throughput(),
    }
}

/// DRESS-vs-baseline pair sweep: for each seed × workload, run DRESS and
/// `baseline` on the identical spec list (two grid cells) and fold the
/// results into [`ExperimentPair`]s, in (workload-major, seed-minor)
/// order.  This is the multi-seed version of `expt::run_pair`, fanned
/// across cores.
pub fn run_pair_sweep(
    base: &ExperimentConfig,
    workloads: Vec<SweepWorkload>,
    seeds: Vec<u64>,
    baseline: SchedKind,
    jobs: usize,
) -> Vec<ExperimentPair> {
    let grid = SweepGrid {
        base: base.clone(),
        seeds,
        scheds: vec![SchedKind::Dress, baseline],
        workloads,
        opts: EngineOptions::default(),
    };
    let results = run_sweep(&grid, jobs);
    let mut pairs = Vec::with_capacity(grid.workloads.len() * grid.seeds.len());
    // Option slots let each cell be moved out by grid index exactly once.
    let mut slots: Vec<Option<RunResult>> = results.into_iter().map(Some).collect();
    for w in 0..grid.workloads.len() {
        for s in 0..grid.seeds.len() {
            let di = grid.index(SweepPoint { workload: w, sched: 0, seed: s });
            let bi = grid.index(SweepPoint { workload: w, sched: 1, seed: s });
            let dress = slots[di].take().expect("dress cell");
            let baseline = slots[bi].take().expect("baseline cell");
            let comparison = compare_small_large(
                &dress.jobs,
                &baseline.jobs,
                dress.system.makespan_ms,
                baseline.system.makespan_ms,
                SMALL_DEMAND,
            );
            pairs.push(ExperimentPair { dress, baseline, comparison });
        }
    }
    pairs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_grid(seeds: Vec<u64>) -> SweepGrid {
        let mut base = ExperimentConfig::default();
        base.cluster.nodes = 2;
        base.cluster.slots_per_node = 4;
        SweepGrid {
            base,
            seeds,
            scheds: vec![SchedKind::Fifo, SchedKind::Dress],
            workloads: vec![SweepWorkload::Generate {
                n: 4,
                mix: WorkloadMix::Mixed,
                small_frac: 0.3,
                arrival_ms: 2_000,
            }],
            opts: EngineOptions::default(),
        }
    }

    #[test]
    fn point_index_roundtrip() {
        let g = tiny_grid(vec![1, 2, 3]);
        assert_eq!(g.len(), 6);
        for i in 0..g.len() {
            assert_eq!(g.index(g.point(i)), i);
        }
        // Layout: seed-minor within scheduler.
        assert_eq!(g.point(0), SweepPoint { workload: 0, sched: 0, seed: 0 });
        assert_eq!(g.point(2), SweepPoint { workload: 0, sched: 0, seed: 2 });
        assert_eq!(g.point(3), SweepPoint { workload: 0, sched: 1, seed: 0 });
    }

    #[test]
    fn parallel_sweep_matches_serial() {
        let g = tiny_grid(vec![5, 6]);
        let serial = run_sweep(&g, 1);
        let parallel = run_sweep(&g, 4);
        assert_eq!(serial.len(), 4);
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.scheduler, b.scheduler);
            assert_eq!(a.system.makespan_ms, b.system.makespan_ms);
            assert_eq!(a.events, b.events);
            assert_eq!(a.trace.tasks, b.trace.tasks);
            assert_eq!(a.delta_history, b.delta_history);
            assert_eq!(a.util, b.util, "utilization integers must be thread-invariant");
            assert_eq!(
                a.system.mean_utilization.to_bits(),
                b.system.mean_utilization.to_bits()
            );
        }
    }

    #[test]
    fn sweep_cells_see_their_own_seed_and_scheduler() {
        let g = tiny_grid(vec![11, 12]);
        let res = run_sweep(&g, 2);
        assert_eq!(res[0].scheduler, "fifo");
        assert_eq!(res[2].scheduler, "dress");
        // Different seeds produce different runs within a scheduler row.
        assert_ne!(
            (res[2].system.makespan_ms, res[2].events),
            (res[3].system.makespan_ms, res[3].events),
            "seed axis inert"
        );
    }

    #[test]
    fn pair_sweep_builds_comparisons_per_seed() {
        let base = ExperimentConfig::default();
        let pairs = run_pair_sweep(
            &base,
            vec![SweepWorkload::Generate {
                n: 6,
                mix: WorkloadMix::Mixed,
                small_frac: 0.3,
                arrival_ms: 2_000,
            }],
            vec![3, 4],
            SchedKind::Capacity,
            0,
        );
        assert_eq!(pairs.len(), 2);
        for p in &pairs {
            assert_eq!(p.dress.scheduler, "dress");
            assert_eq!(p.baseline.scheduler, "capacity");
            assert_eq!(p.dress.jobs.len(), p.baseline.jobs.len());
        }
    }

    #[test]
    fn effective_jobs_resolves_zero_to_cores() {
        assert!(effective_jobs(0) >= 1);
        assert_eq!(effective_jobs(3), 3);
    }

    #[test]
    fn run_cells_subset_matches_full_sweep() {
        let g = tiny_grid(vec![5, 6]);
        let full = run_sweep(&g, 1);
        // Every-other-cell subset, run in parallel: each pair must carry
        // its grid index and reproduce the full run's cell bit-for-bit.
        let indices: Vec<usize> = (0..g.len()).filter(|i| i % 2 == 1).collect();
        let subset = run_cells(&g, &indices, 3);
        assert_eq!(subset.len(), 2);
        for (idx, r) in &subset {
            assert!(indices.contains(idx));
            assert_eq!(r.system.makespan_ms, full[*idx].system.makespan_ms);
            assert_eq!(r.events, full[*idx].events);
            assert_eq!(r.trace.tasks, full[*idx].trace.tasks);
        }
        assert!(run_cells(&g, &[], 4).is_empty());
    }

    #[test]
    fn paper_grid_shape() {
        let g = paper_grid(&[42, 43, 44]);
        assert_eq!(g.len(), 12);
        assert_eq!(g.scheds, vec![SchedKind::Dress, SchedKind::Capacity]);
        assert_eq!(g.workloads.len(), 2);
        assert!(matches!(
            g.workloads[0],
            SweepWorkload::Generate { mix: WorkloadMix::Spark, .. }
        ));
        assert!(matches!(
            g.workloads[1],
            SweepWorkload::Generate { mix: WorkloadMix::MapReduce, .. }
        ));
    }

    #[test]
    fn bench_grid_matches_perf_sweep_documentation() {
        let g = bench_grid();
        assert_eq!(g.seeds.len(), 8);
        assert_eq!(g.seeds[0], 0xD8E5);
        assert_eq!(g.scheds, vec![SchedKind::Capacity, SchedKind::Dress]);
        assert!(matches!(
            g.workloads[0],
            SweepWorkload::CongestedBurst { n: 500, arrival_mean_ms: 50 }
        ));
    }
}
